"""Live dictionary updates: delta builders + epoch-versioned state.

The frozen-dictionary assumption of the paper (build once per session)
meets real extraction traffic here: ``delta`` models updates (adds +
tombstones) over an epoch-numbered ``DictionaryVersion`` chain;
``builders`` turns each epoch into executable prepared state — Bloom
bit-unions for adds, LSM-style delta segments probed beside the base,
tombstone masks at emit — and folds segments away when the cost model's
maintenance terms (``core.cost_model.maintenance_plan``) say the open-
segment probe overhead outweighs an amortised rebuild. Serving sessions
(``serving.session.DictionarySession.apply_delta``) hot-swap epochs with
no drain: in-flight batches finish on the epoch they were admitted
under, new admissions see the new epoch.
"""
from repro.updates.delta import (
    DictionaryDelta,
    DictionaryVersion,
    arrays_fingerprint,
    dictionary_from_arrays,
    dictionary_to_arrays,
    pack_arrays,
    random_delta,
    segment_dictionary,
    unpack_arrays,
)
from repro.updates.builders import (
    EpochSide,
    EpochState,
    absorb_delta,
    build_segment_side,
    compact_epoch,
    epoch_matches,
    epoch_side_matches,
    execute_epoch,
    initial_epoch,
    oracle_matches,
    rebuild_epoch,
    rebuild_oracle,
    union_filter_words,
)

__all__ = [
    "DictionaryDelta",
    "DictionaryVersion",
    "EpochSide",
    "EpochState",
    "absorb_delta",
    "arrays_fingerprint",
    "build_segment_side",
    "dictionary_from_arrays",
    "dictionary_to_arrays",
    "compact_epoch",
    "epoch_matches",
    "epoch_side_matches",
    "execute_epoch",
    "initial_epoch",
    "oracle_matches",
    "pack_arrays",
    "random_delta",
    "rebuild_epoch",
    "rebuild_oracle",
    "segment_dictionary",
    "union_filter_words",
    "unpack_arrays",
]

"""Incremental (delta) builders + epoch-versioned prepared state.

The build side of live dictionary updates: given a ``DictionaryVersion``
chain (``updates.delta``), produce *prepared* extraction state for each
epoch **without touching the base structures**:

* **Bloom filter** — adds absorb by bit-union: a segment filter is
  built over just the added entities' prefix tokens and OR-ed into the
  side's serving bitmap. Because a Bloom build is a deterministic OR of
  per-token bit patterns, the union over (base ∪ adds) is bit-identical
  to a from-scratch build over the merged entity set. Deletes never
  rebuild the filter (bits cannot be unset) — tombstoned entities are
  masked at emit, and the filter merely keeps a few soundness-preserving
  false positives.
* **Signature tables / indexes** — LSM-style delta segments: each
  absorbed delta gets its own small ``SigTable`` or index partitions
  (entity ids offset into the global id space), probed alongside the
  base with the *same* compacted candidate dict; per-segment ``Matches``
  merge through the existing ``results.merge_matches`` path.
* **Tombstones** — a device-resident live mask applied to the merged
  matches (``results.filter_matches``) after verification.

``EpochState`` is one epoch's complete executable view: per plan side
the base ``PreparedSide``, the open segment sides, and the unioned
filter; plus the live mask. ``execute_epoch`` runs it one-shot (the
versioned analogue of ``EEJoinOperator.execute``); the serving pipeline
streams the same sides through ``shard_lane`` (``serving/service.py``).

Epoch swap protocol: ``absorb_delta`` shares every pre-existing
structure with the previous epoch (O(delta) build work), so multiple
epochs coexist cheaply — in-flight batches pinned to epoch *n* keep
executing against its state while new admissions see *n+1*.
``compact_epoch`` / ``rebuild_epoch`` fold segments + tombstones into a
fresh base (the cost-model ``maintenance_plan`` decides when); only
then do entity ids renumber, surfaced through ``EpochState.id_map``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp

from repro.core.cost_model import ALGO_INDEX, ALGO_SSJOIN, CostParams
from repro.core.dictionary import Dictionary
from repro.core.eejoin import (
    EEJoinConfig,
    EEJoinOperator,
    PreparedPlan,
    PreparedSide,
    side_matches,
)
from repro.core.filter import BloomFilter, build_ish_filter
from repro.core.plan import Plan
from repro.core.signatures import entity_signatures
from repro.extraction import engine
from repro.extraction.results import Matches, filter_matches, merge_matches
from repro.updates.delta import DictionaryDelta, DictionaryVersion


@dataclasses.dataclass
class EpochSide:
    """One plan side of one epoch: base + open delta segments + filter.

    ``filter_words`` is the host-side union bitmap (base | segments) the
    next absorb ORs into; ``flt`` its device tuple, in the same
    ``(bits, num_bits, num_hashes)`` wire format every probe consumes.
    Segments carry no own ``flt`` — the side-level union is the filter.
    """

    base: PreparedSide
    segments: list[PreparedSide]
    filter_words: np.ndarray | None
    flt: tuple | None

    @property
    def params(self) -> engine.ExtractParams:
        return self.base.params

    def all_sides(self) -> list[PreparedSide]:
        return [self.base, *self.segments]


@dataclasses.dataclass
class EpochState:
    """One epoch's complete executable extraction state."""

    epoch: int
    version: DictionaryVersion
    plan: Plan
    sides: list[EpochSide]
    live: jnp.ndarray  # [total_entities] bool device mask
    has_tombstones: bool
    # set on compact/rebuild epochs: id_map[new_global_id] = the id the
    # same entity had in the *previous* epoch (adds renumber only here)
    id_map: np.ndarray | None = None
    # in-flight batches executing on this epoch (serving pin refcount)
    pins: int = 0

    @property
    def max_len(self) -> int:
        return self.version.max_len

    @property
    def open_segments(self) -> int:
        return self.version.num_segments


def _side_filter(
    dictionary: Dictionary, config: EEJoinConfig
) -> tuple[BloomFilter | None, np.ndarray | None, tuple | None]:
    """(host BloomFilter, host words, device flt tuple) for one side."""
    if not config.use_filter:
        return None, None, None
    f = build_ish_filter(dictionary, config.gamma, num_bits=config.filter_bits)
    return f, f.bits, (jnp.asarray(f.bits), f.num_bits, f.num_hashes)


def build_segment_side(
    segment: Dictionary,
    entity_offset: int,
    template: PreparedSide,
    config: EEJoinConfig,
    hbm_budget: float,
) -> PreparedSide:
    """Prepared structures for one delta segment under a side's spec.

    The mirror of ``EEJoinOperator._prepare_side`` for an append
    segment: same (algo, scheme) and ``ExtractParams`` as the side it
    rides with (candidate dicts are shared, so the params must agree),
    entity ids offset to the segment's global range, no own filter (the
    side-level union covers it).
    """
    side = template.side
    ddict = engine.DeviceDictionary.from_host(segment, entity_offset=entity_offset)
    prepared = PreparedSide(
        side=side, params=template.params, ddict=ddict, flt=None
    )
    if side.algo == ALGO_INDEX:
        prepared.index_parts = engine.build_index_partitions(
            segment, side.scheme, config.gamma, int(hbm_budget),
            entity_offset=entity_offset,
        )
    elif side.algo == ALGO_SSJOIN:
        esig = entity_signatures(side.scheme, segment, config.gamma, config.lsh)
        prepared.sig_table = engine.build_sig_table(
            esig, entity_offset=entity_offset
        )
    else:
        raise ValueError(side.algo)
    return prepared


def union_filter_words(
    words: np.ndarray | None, segment_filter: BloomFilter | None
) -> np.ndarray | None:
    """OR a segment's Bloom bitmap into the side union (host uint32)."""
    if words is None or segment_filter is None:
        return words
    return words | segment_filter.bits


def initial_epoch(
    dictionary: Dictionary, plan: Plan, prepared: PreparedPlan
) -> EpochState:
    """Epoch 0: the frozen-dictionary state every session starts from."""
    version = DictionaryVersion.initial(dictionary)
    sides = []
    for s in prepared.sides:
        words = np.asarray(s.flt[0]) if s.flt is not None else None
        sides.append(
            EpochSide(base=s, segments=[], filter_words=words, flt=s.flt)
        )
    return EpochState(
        epoch=0,
        version=version,
        plan=plan,
        sides=sides,
        live=jnp.ones((dictionary.num_entities,), dtype=bool),
        has_tombstones=False,
    )


def absorb_delta(
    state: EpochState,
    delta: DictionaryDelta,
    config: EEJoinConfig,
    cost_params: CostParams | None = None,
) -> EpochState:
    """Next epoch with the delta absorbed as an open segment.

    O(delta) build work: the base sides (and every previously absorbed
    segment) are *shared by reference* with the prior epoch — only the
    new segment's structures, the filter union, and the live mask are
    built. Adds ride the plan's **tail** side (the last prepared side):
    appended entities have no frequency history, which is exactly the
    tail of the frequency-sorted order.
    """
    cp = cost_params or CostParams(num_devices=1)
    offset = state.version.total_entities
    version = state.version.apply(delta)
    sides = [
        EpochSide(
            base=es.base,
            segments=list(es.segments),
            filter_words=es.filter_words,
            flt=es.flt,
        )
        for es in state.sides
    ]
    if version.num_segments > state.version.num_segments:
        segment = version.segments[-1]
        tail = sides[-1]
        tail.segments.append(
            build_segment_side(
                segment, offset, tail.base, config, cp.hbm_budget_bytes
            )
        )
        if config.use_filter and tail.filter_words is not None:
            segf = build_ish_filter(
                segment, config.gamma, num_bits=config.filter_bits
            )
            tail.filter_words = union_filter_words(tail.filter_words, segf)
            tail.flt = (jnp.asarray(tail.filter_words), segf.num_bits,
                        segf.num_hashes)
    return EpochState(
        epoch=version.epoch,
        version=version,
        plan=state.plan,
        sides=sides,
        live=jnp.asarray(version.live_mask()),
        has_tombstones=bool(version.tombstones.any()),
    )


def compact_epoch(
    state: EpochState,
    config: EEJoinConfig,
    cost_params: CostParams | None = None,
    plan: Plan | None = None,
) -> tuple[EpochState, EEJoinOperator]:
    """Fold segments + tombstones into a fresh single-base epoch.

    The plan (and any calibration in ``cost_params``) carries forward:
    the head split is re-anchored to the live id space
    (``DictionaryVersion.effective_split``) but no plan search runs —
    that is ``rebuild_epoch``. Entity ids renumber densely;
    ``EpochState.id_map`` records new → old.
    """
    cp = cost_params or CostParams(num_devices=1)
    version, id_map = state.version.compact()
    op = EEJoinOperator(version.base, config)
    plan = plan or dataclasses.replace(
        state.plan, split=state.version.effective_split(state.plan.split)
    )
    prepared = op.prepare(plan, cp)
    out = initial_epoch(version.base, plan, prepared)
    out.epoch = version.epoch
    out.version = version
    out.id_map = id_map
    return out, op


def rebuild_epoch(
    state: EpochState,
    config: EEJoinConfig,
    cost_params: CostParams,
    sample_docs: np.ndarray,
    total_docs: int | None = None,
) -> tuple[EpochState, EEJoinOperator]:
    """Full rebuild: compact, re-sort by frequency, re-run the §5 search.

    The maintenance action for *stat drift*: absorbed adds and
    tombstones eventually invalidate the frequency-descending order
    that Lemma 1's monotonic plan search needs, and the measured
    statistics the plan was chosen under. Ids renumber (twice removed
    from the pre-compaction space); ``id_map`` maps straight back to
    the previous epoch's global ids.
    """
    version, id_map = state.version.compact()
    order = np.argsort(-version.base.freq, kind="stable")
    base = Dictionary(
        tokens=version.base.tokens[order],
        lengths=version.base.lengths[order],
        freq=version.base.freq[order],
        token_weight=version.base.token_weight,
        entity_weight=version.base.entity_weight[order],
    )
    id_map = id_map[order]
    op = EEJoinOperator(base, config)
    stats = op.gather_statistics(
        np.asarray(sample_docs), total_docs=total_docs or len(sample_docs)
    )
    plan = op.choose_plan(stats, cost_params)
    prepared = op.prepare(plan, cost_params)
    out = initial_epoch(base, plan, prepared)
    out.epoch = version.epoch
    out.version = dataclasses.replace(version, base=base)
    out.id_map = id_map
    return out, op


def replan_epoch(
    state: EpochState,
    plan: Plan,
    config: EEJoinConfig,
    cost_params: CostParams,
) -> EpochState:
    """Next epoch with a *new plan* over the *same dictionary version*.

    The online-replanning swap unit: entity ids, segments, tombstones
    and the live mask all carry over unchanged — only the prepared base
    structures are rebuilt under ``plan`` (and every open segment
    re-attached to the new tail side, filter union refreshed). Because
    no id renumbers and every plan computes the same match set, a
    replan can never change the results of any batch — pinned in-flight
    batches keep their epoch, new admissions pay the new plan's cost.

    The epoch number bumps *through the version* (not just the state):
    a later ``apply_delta`` numbers its epoch ``version.epoch + 1``, so
    leaving the version untouched would collide a future delta epoch
    with this one.
    """
    version = dataclasses.replace(state.version, epoch=state.version.epoch + 1)
    op = EEJoinOperator(version.base, config)
    prepared = op.prepare(plan, cost_params)
    out = initial_epoch(version.base, plan, prepared)
    tail = out.sides[-1]
    for segment, offset in zip(version.segments, version.segment_offsets):
        tail.segments.append(
            build_segment_side(
                segment, offset, tail.base, config,
                cost_params.hbm_budget_bytes,
            )
        )
        if config.use_filter and tail.filter_words is not None:
            segf = build_ish_filter(
                segment, config.gamma, num_bits=config.filter_bits
            )
            tail.filter_words = union_filter_words(tail.filter_words, segf)
            tail.flt = (jnp.asarray(tail.filter_words), segf.num_bits,
                        segf.num_hashes)
    out.epoch = version.epoch
    out.version = version
    out.live = jnp.asarray(version.live_mask())
    out.has_tombstones = bool(version.tombstones.any())
    return out


# --------------------------------------------------------------------------
# Execution over an epoch
# --------------------------------------------------------------------------


def epoch_side_matches(
    cands: dict, eside: EpochSide, result_capacity: int
) -> Matches:
    """Probe + verify one epoch side: base, then every open segment.

    All structures consume the *same* compacted candidate dict (they
    share scheme and params by construction), so the delta path pays
    one probe per open structure but never re-enumerates, re-filters or
    re-compacts — the LSM read path of the subsystem.
    """
    out: Matches | None = None
    for prepared in eside.all_sides():
        m = side_matches(cands, prepared, result_capacity)
        out = m if out is None else merge_matches(out, m, result_capacity)
    return out


def execute_epoch(state: EpochState, doc_tokens, config: EEJoinConfig) -> Matches:
    """One-shot extraction against an epoch (versioned ``execute``).

    Bit-equal in result *set* to a from-scratch rebuild over the
    epoch's effective dictionary: the union filter admits a superset of
    the rebuild's survivors (extra candidates die at probe/verify), and
    tombstoned entities' matches are masked after the merge — asserted
    property-based in ``tests/test_updates.py``.
    """
    out: Matches | None = None
    for eside in state.sides:
        if config.use_kernel:
            cands = engine.fused_filter_compact(
                doc_tokens, state.max_len, eside.flt, eside.params
            )
        else:
            base, surv = engine.survival_mask(
                doc_tokens, state.max_len, eside.flt, False
            )
            cands = engine.compact_candidates(
                base, surv, eside.params.max_candidates
            )
        m = epoch_side_matches(cands, eside, config.result_capacity)
        out = m if out is None else merge_matches(
            out, m, config.result_capacity
        )
    assert out is not None, "empty plan"
    if state.has_tombstones:
        out = filter_matches(out, state.live, config.result_capacity)
    return out


# --------------------------------------------------------------------------
# From-scratch rebuild oracle (the parity target of the whole subsystem)
# --------------------------------------------------------------------------


def rebuild_oracle(
    version: DictionaryVersion,
    config: EEJoinConfig,
    plan: Plan,
    cost_params: CostParams | None = None,
) -> tuple[EEJoinOperator, PreparedPlan, np.ndarray]:
    """From-scratch prepared state over the live entities of ``version``.

    Builds a plain ``Dictionary`` of exactly the live entities (global-
    id order, see ``effective_dictionary``), re-anchors the plan split
    to it, and runs the ordinary frozen-dictionary ``prepare`` — no
    segments, no tombstones, no unions. Returns ``(operator, prepared,
    id_map)``; oracle match entity ids map back through ``id_map``.
    """
    eff, id_map = version.effective_dictionary()
    plan = dataclasses.replace(plan, split=version.effective_split(plan.split))
    op = EEJoinOperator(eff, config)
    prepared = op.prepare(plan, cost_params or CostParams(num_devices=1))
    return op, prepared, id_map


def oracle_matches(
    version: DictionaryVersion,
    config: EEJoinConfig,
    plan: Plan,
    doc_tokens,
    cost_params: CostParams | None = None,
) -> set[tuple[int, int, int, int]]:
    """(doc, pos, len, global-entity) set of the from-scratch rebuild."""
    op, prepared, id_map = rebuild_oracle(version, config, plan, cost_params)
    got = op.execute(prepared, doc_tokens)
    return {
        (d, p, length, int(id_map[e])) for (d, p, length, e) in got.to_set()
    }


def epoch_matches(
    state: EpochState, doc_tokens, config: EEJoinConfig
) -> set[tuple[int, int, int, int]]:
    """(doc, pos, len, global-entity) set of the delta-served epoch."""
    return execute_epoch(state, doc_tokens, config).to_set()

"""Dictionary deltas and the epoch-versioned dictionary chain.

The paper's operator — and this repo up to PR 4 — freezes the
dictionary per ``DictionarySession``: filter, signature tables, indexes
and the calibrated plan are built once and never change. Live
extraction workloads (watchlist screening: Budur 2017 in PAPERS.md)
churn continuously, and a full rebuild + session eviction per update
both costs O(|E|) host work and drops the warm plan/calibration.

This module is the *data* layer of live updates:

* ``DictionaryDelta`` — one update: entities to add (token lists) plus
  entity ids to *tombstone* (logical delete).
* ``DictionaryVersion`` — one epoch of the versioned dictionary: the
  compacted **base** ``Dictionary``, a list of append-only **segments**
  (one per absorbed delta, LSM-style), and a **tombstone mask** over the
  whole global id space. ``apply`` produces the next epoch without
  touching the base; ``compact`` folds segments + tombstones into a new
  base (renumbering ids — the epoch bump makes that visible).

Global entity ids are positional: base entities keep their frequency-
sorted ids ``0..E-1``; each segment's entities are appended after
everything before it, in insertion order. Ids are therefore stable
across ``apply`` (an entity never moves until a ``compact``), which is
what lets in-flight batches finish on the epoch they were admitted
under while new admissions see the new epoch.

Deletes are tombstones, not structure edits: a Bloom filter cannot
unset bits and signature tables cannot cheaply shrink, so a tombstoned
entity stays in the prepared structures and its matches are masked at
the verify/emit stage (``extraction.results.filter_matches``). The
cost-model maintenance terms (``core.cost_model.maintenance_plan``)
decide when accumulated segments + tombstones are worth folding away.
"""
from __future__ import annotations

import dataclasses
import hashlib
import io
import json

import numpy as np

from repro.core.dictionary import PAD, Dictionary

# --------------------------------------------------------------------------
# Wire container: npz body + JSON header, sha256-fingerprinted.
#
# The persistence / replication format of the updates subsystem (and the
# payload container of ``repro.fabric.wire``): a dict of named numpy
# arrays saved through ``np.savez`` (lossless for every dtype we ship)
# with a JSON metadata header riding along as a uint8 array. The header
# carries a sha256 over the arrays' (name, dtype, shape, bytes) — the
# same content-hash discipline as ``sharded.job_manifest`` /
# ``serving.dictionary_fingerprint`` — so a decoder detects truncation
# or mixing of payloads from different objects instead of silently
# deserializing garbage.
# --------------------------------------------------------------------------

_META_KEY = "__meta__"


def arrays_fingerprint(arrays: dict[str, np.ndarray]) -> str:
    """sha256 over the arrays' names, dtypes, shapes and raw bytes."""
    h = hashlib.sha256()
    for name in sorted(arrays):
        a = np.ascontiguousarray(arrays[name])
        h.update(name.encode())
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def pack_arrays(meta: dict, arrays: dict[str, np.ndarray]) -> bytes:
    """Serialize ``(meta, arrays)`` into one self-describing byte blob.

    ``meta`` must be JSON-serializable; array names must not collide
    with the reserved ``__meta__`` key. The stored header always gains
    a ``fingerprint`` entry over the arrays (see
    ``arrays_fingerprint``); ``unpack_arrays`` re-hashes and compares.
    """
    if _META_KEY in arrays:
        raise ValueError(f"pack_arrays: array name {_META_KEY!r} is reserved")
    meta = dict(meta)
    meta["fingerprint"] = arrays_fingerprint(arrays)
    header = np.frombuffer(json.dumps(meta, sort_keys=True).encode(),
                           dtype=np.uint8)
    buf = io.BytesIO()
    np.savez(buf, **{_META_KEY: header}, **arrays)
    return buf.getvalue()


def unpack_arrays(data: bytes) -> tuple[dict, dict[str, np.ndarray]]:
    """Inverse of ``pack_arrays``; raises ValueError on any corruption.

    Bad zip structure, a missing header, or a fingerprint mismatch all
    raise — a truncated or cross-wired payload never deserializes
    quietly into a plausible-but-wrong object.
    """
    try:
        with np.load(io.BytesIO(data), allow_pickle=False) as z:
            arrays = {k: z[k] for k in z.files}
    except Exception as exc:
        raise ValueError(f"unpack_arrays: undecodable payload ({exc})") from exc
    header = arrays.pop(_META_KEY, None)
    if header is None:
        raise ValueError("unpack_arrays: payload has no __meta__ header")
    meta = json.loads(bytes(header.tobytes()).decode())
    want = meta.get("fingerprint")
    got = arrays_fingerprint(arrays)
    if want != got:
        raise ValueError(
            f"unpack_arrays: content fingerprint mismatch (header "
            f"{str(want)[:12]}..., arrays {got[:12]}...): payload is "
            "truncated or belongs to a different object"
        )
    return meta, arrays


def dictionary_to_arrays(d: Dictionary, prefix: str = "",
                         token_weight: bool = True) -> dict[str, np.ndarray]:
    """Flatten a ``Dictionary`` into named arrays (``prefix`` namespaces
    several dictionaries — base + segments — inside one payload)."""
    out = {
        f"{prefix}tokens": np.asarray(d.tokens, dtype=np.int32),
        f"{prefix}lengths": np.asarray(d.lengths, dtype=np.int32),
        f"{prefix}freq": np.asarray(d.freq, dtype=np.float32),
        f"{prefix}entity_weight": np.asarray(d.entity_weight,
                                             dtype=np.float32),
    }
    if token_weight:
        out[f"{prefix}token_weight"] = np.asarray(d.token_weight,
                                                  dtype=np.float32)
    return out


def dictionary_from_arrays(arrays: dict, prefix: str = "",
                           token_weight: np.ndarray | None = None
                           ) -> Dictionary:
    """Inverse of ``dictionary_to_arrays`` (``token_weight`` may be
    shared externally, e.g. segments reuse the base's table)."""
    tw = (arrays[f"{prefix}token_weight"]
          if token_weight is None else token_weight)
    return Dictionary(
        tokens=np.asarray(arrays[f"{prefix}tokens"], dtype=np.int32),
        lengths=np.asarray(arrays[f"{prefix}lengths"], dtype=np.int32),
        freq=np.asarray(arrays[f"{prefix}freq"], dtype=np.float32),
        token_weight=np.asarray(tw, dtype=np.float32),
        entity_weight=np.asarray(arrays[f"{prefix}entity_weight"],
                                 dtype=np.float32),
    )


@dataclasses.dataclass(frozen=True)
class DictionaryDelta:
    """One live update: entities to add + global entity ids to delete.

    ``added`` are per-entity token-id lists (duplicates dropped with set
    semantics, like ``build_dictionary``); ``added_freq`` optional
    estimated mention frequencies (default 1.0 — adds have no history).
    ``tombstones`` are *global* entity ids valid in the version the
    delta is applied to. Both halves may be empty (an empty delta is a
    legal no-op that still bumps the epoch).
    """

    added: tuple[tuple[int, ...], ...] = ()
    tombstones: tuple[int, ...] = ()
    added_freq: tuple[float, ...] | None = None

    def __post_init__(self):
        if self.added_freq is not None and len(self.added_freq) != len(self.added):
            raise ValueError(
                f"DictionaryDelta: added_freq has {len(self.added_freq)} "
                f"entries for {len(self.added)} added entities"
            )

    @property
    def num_added(self) -> int:
        return len(self.added)

    @property
    def num_tombstoned(self) -> int:
        return len(self.tombstones)

    @property
    def empty(self) -> bool:
        return not self.added and not self.tombstones

    def to_bytes(self) -> bytes:
        """Stable wire encoding (npz + JSON header, sha256-guarded).

        The ragged ``added`` token lists flatten to one int32 array plus
        per-entity lengths; ``from_bytes`` round-trips bit-exactly, so a
        replica replaying shipped deltas builds byte-identical segments.
        """
        flat = [t for ent in self.added for t in ent]
        arrays = {
            "added_flat": np.asarray(flat, dtype=np.int32),
            "added_lengths": np.asarray(
                [len(ent) for ent in self.added], dtype=np.int32
            ),
            "tombstones": np.asarray(self.tombstones, dtype=np.int64),
        }
        if self.added_freq is not None:
            arrays["added_freq"] = np.asarray(self.added_freq,
                                              dtype=np.float32)
        return pack_arrays({"kind": "dictionary_delta", "v": 1}, arrays)

    @classmethod
    def from_bytes(cls, data: bytes) -> "DictionaryDelta":
        meta, arrays = unpack_arrays(data)
        if meta.get("kind") != "dictionary_delta":
            raise ValueError(
                f"DictionaryDelta.from_bytes: payload kind "
                f"{meta.get('kind')!r} is not a dictionary_delta"
            )
        flat = arrays["added_flat"]
        added = []
        off = 0
        for n in arrays["added_lengths"]:
            n = int(n)
            added.append(tuple(int(t) for t in flat[off:off + n]))
            off += n
        freq = arrays.get("added_freq")
        return cls(
            added=tuple(added),
            tombstones=tuple(int(t) for t in arrays["tombstones"]),
            added_freq=(tuple(float(f) for f in freq)
                        if freq is not None else None),
        )


def segment_dictionary(
    delta: DictionaryDelta, base: Dictionary
) -> Dictionary | None:
    """Build the delta's add-segment as a ``Dictionary`` (None if no adds).

    Unlike ``build_dictionary`` the segment preserves **insertion
    order** (no frequency sort): global ids are positional and must be
    deterministic across hosts applying the same delta stream. The
    segment shares the base's token-weight table and max_len, so every
    prepared structure built from it composes with the base's (same
    static shapes, same hashing).
    """
    if not delta.added:
        return None
    L = base.max_len
    V = base.vocab_size
    dedup: list[list[int]] = []
    for ent in delta.added:
        seen: list[int] = []
        for t in ent:
            t = int(t)
            if t == PAD:
                raise ValueError("delta entity contains PAD (token id 0)")
            if not 0 < t < V:
                raise ValueError(
                    f"delta entity token {t} out of vocab range [1, {V})"
                )
            if t not in seen:
                seen.append(t)
        if not seen:
            raise ValueError("delta contains an empty entity")
        if len(seen) > L:
            raise ValueError(
                f"delta entity has {len(seen)} distinct tokens > base "
                f"max_len {L}: prepared structures are static-shape, so "
                "added entities must fit the base width (rebuild with a "
                "larger max_len to grow it)"
            )
        dedup.append(seen)
    E = len(dedup)
    toks = np.zeros((E, L), dtype=np.int32)
    lens = np.zeros((E,), dtype=np.int32)
    for i, ent in enumerate(dedup):
        toks[i, : len(ent)] = ent
        lens[i] = len(ent)
    freq = (
        np.asarray(delta.added_freq, dtype=np.float32)
        if delta.added_freq is not None
        else np.ones((E,), dtype=np.float32)
    )
    ent_w = base.token_weight[toks].sum(axis=1).astype(np.float32)
    return Dictionary(toks, lens, freq, base.token_weight, ent_w)


@dataclasses.dataclass(frozen=True)
class DictionaryVersion:
    """One epoch of the versioned dictionary chain.

    ``base`` holds entities ``[0, base.num_entities)``; ``segments[i]``
    holds ``segment_offsets[i] .. + segments[i].num_entities`` (offsets
    ascend, segments are contiguous after the base). ``tombstones`` is a
    bool mask over the whole ``[0, total_entities)`` id space.
    """

    epoch: int
    base: Dictionary
    segments: tuple[Dictionary, ...]
    segment_offsets: tuple[int, ...]
    tombstones: np.ndarray  # [total_entities] bool

    @classmethod
    def initial(cls, base: Dictionary) -> "DictionaryVersion":
        return cls(
            epoch=0,
            base=base,
            segments=(),
            segment_offsets=(),
            tombstones=np.zeros((base.num_entities,), dtype=bool),
        )

    @property
    def total_entities(self) -> int:
        return int(self.tombstones.shape[0])

    @property
    def num_live(self) -> int:
        return int((~self.tombstones).sum())

    @property
    def num_segments(self) -> int:
        return len(self.segments)

    @property
    def max_len(self) -> int:
        return self.base.max_len

    def live_mask(self) -> np.ndarray:
        """[total_entities] bool, True where the entity is live."""
        return ~self.tombstones

    def apply(self, delta: DictionaryDelta) -> "DictionaryVersion":
        """Next epoch: append the delta's adds, extend the tombstones.

        Never touches the base or earlier segments (their prepared
        structures stay shared across epochs); O(delta) host work.
        Tombstoning an already-dead id raises — callers see the current
        epoch, so a double-delete is a protocol error worth surfacing.
        """
        total = self.total_entities
        tombs = self.tombstones.copy()
        for tid in delta.tombstones:
            tid = int(tid)
            if not 0 <= tid < total:
                raise ValueError(
                    f"tombstone id {tid} out of range [0, {total}) at "
                    f"epoch {self.epoch}"
                )
            if tombs[tid]:
                raise ValueError(
                    f"tombstone id {tid} is already dead at epoch "
                    f"{self.epoch} (double delete)"
                )
            tombs[tid] = True
        seg = segment_dictionary(delta, self.base)
        if seg is None:
            return dataclasses.replace(
                self, epoch=self.epoch + 1, tombstones=tombs
            )
        return DictionaryVersion(
            epoch=self.epoch + 1,
            base=self.base,
            segments=self.segments + (seg,),
            segment_offsets=self.segment_offsets + (total,),
            tombstones=np.concatenate(
                [tombs, np.zeros((seg.num_entities,), dtype=bool)]
            ),
        )

    def entity_rows(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(tokens [N, L], lengths [N], freq [N]) over the full id space."""
        toks = [self.base.tokens]
        lens = [self.base.lengths]
        freq = [self.base.freq]
        for seg in self.segments:
            toks.append(seg.tokens)
            lens.append(seg.lengths)
            freq.append(seg.freq)
        return (
            np.concatenate(toks, axis=0),
            np.concatenate(lens),
            np.concatenate(freq),
        )

    def effective_dictionary(self) -> tuple[Dictionary, np.ndarray]:
        """(live dictionary in global-id order, id_map [N_live] -> global).

        The from-scratch rebuild target: a plain ``Dictionary`` holding
        exactly the live entities, rows ordered by ascending global id
        (NOT re-sorted by frequency — id stability is the point; a full
        re-plan that re-sorts is the ``rebuild`` maintenance action).
        ``id_map[local]`` maps the rebuilt dictionary's row ids back to
        this version's global ids, so oracle matches compare 1:1 with
        delta-served matches.
        """
        toks, lens, freq = self.entity_rows()
        live = self.live_mask()
        if not live.any():
            raise ValueError(
                f"epoch {self.epoch} has no live entities: an all-"
                "tombstoned dictionary cannot be rebuilt (retire the "
                "session instead)"
            )
        id_map = np.nonzero(live)[0].astype(np.int32)
        toks = toks[live]
        ent_w = self.base.token_weight[toks].sum(axis=1).astype(np.float32)
        d = Dictionary(
            tokens=toks,
            lengths=lens[live],
            freq=freq[live],
            token_weight=self.base.token_weight,
            entity_weight=ent_w,
        )
        return d, id_map

    def effective_split(self, base_split: int) -> int:
        """Plan head split against the live id space.

        The base plan splits the frequency-sorted base at ``base_split``
        (head = index side, tail = ssjoin side, or vice versa). Rebuilt
        over the effective dictionary, head entities are the live base
        entities below the split: the split shrinks by the tombstones
        inside it. Added entities (appended after the base) always land
        in the tail, matching the delta path where segments adopt the
        tail side's (algo, scheme).
        """
        if int(base_split) >= self.base.num_entities:
            # pure-head plan: the head keeps covering everything,
            # including appended segments
            return self.num_live
        s = max(int(base_split), 0)
        return s - int(self.tombstones[:s].sum())

    def to_bytes(self) -> bytes:
        """Snapshot encoding: base + segments + offsets + tombstones.

        Segments share the base's token-weight table, so only the base
        ships one; ``from_bytes`` re-threads it. This is the replica
        bootstrap payload — a replica loading the snapshot and then
        replaying the same delta stream holds a version byte-identical
        to the coordinator's.
        """
        arrays = dictionary_to_arrays(self.base, prefix="base_")
        for i, seg in enumerate(self.segments):
            arrays.update(
                dictionary_to_arrays(seg, prefix=f"seg{i}_",
                                     token_weight=False)
            )
        arrays["segment_offsets"] = np.asarray(self.segment_offsets,
                                               dtype=np.int64)
        arrays["tombstones"] = np.asarray(self.tombstones, dtype=bool)
        meta = {
            "kind": "dictionary_version",
            "v": 1,
            "epoch": int(self.epoch),
            "num_segments": len(self.segments),
        }
        return pack_arrays(meta, arrays)

    @classmethod
    def from_bytes(cls, data: bytes) -> "DictionaryVersion":
        meta, arrays = unpack_arrays(data)
        if meta.get("kind") != "dictionary_version":
            raise ValueError(
                f"DictionaryVersion.from_bytes: payload kind "
                f"{meta.get('kind')!r} is not a dictionary_version"
            )
        base = dictionary_from_arrays(arrays, prefix="base_")
        segments = tuple(
            dictionary_from_arrays(arrays, prefix=f"seg{i}_",
                                   token_weight=base.token_weight)
            for i in range(int(meta["num_segments"]))
        )
        return cls(
            epoch=int(meta["epoch"]),
            base=base,
            segments=segments,
            segment_offsets=tuple(
                int(o) for o in arrays["segment_offsets"]
            ),
            tombstones=np.asarray(arrays["tombstones"], dtype=bool),
        )

    def compact(self) -> tuple["DictionaryVersion", np.ndarray]:
        """Fold segments + tombstones into a fresh single-base version.

        Returns ``(version, id_map)``: the new epoch's base is the
        effective dictionary (live entities, global-id order preserved,
        ids renumbered densely) and ``id_map[new_id] = old global id``.
        The epoch bump is what makes the renumbering safe: in-flight
        batches pinned to the old epoch keep reporting old ids, new
        admissions report new ones.
        """
        d, id_map = self.effective_dictionary()
        return (
            DictionaryVersion(
                epoch=self.epoch + 1,
                base=d,
                segments=(),
                segment_offsets=(),
                tombstones=np.zeros((d.num_entities,), dtype=bool),
            ),
            id_map,
        )


def random_delta(
    rng: np.random.Generator,
    version: DictionaryVersion,
    vocab_size: int,
    max_added: int = 8,
    max_tombstoned: int = 8,
    max_entity_len: int | None = None,
) -> DictionaryDelta:
    """Seeded random delta against ``version`` (test/bench helper).

    Adds up to ``max_added`` fresh entities (distinct non-PAD tokens)
    and tombstones up to ``max_tombstoned`` currently-live ids; either
    half may come out empty, including both (the empty-delta case).
    """
    L = max_entity_len or min(version.max_len, 5)
    n_add = int(rng.integers(0, max_added + 1))
    added = []
    for _ in range(n_add):
        n = int(rng.integers(1, L + 1))
        toks = rng.choice(vocab_size - 1, size=n, replace=False) + 1
        added.append(tuple(int(t) for t in toks))
    live = np.nonzero(version.live_mask())[0]
    n_dead = int(rng.integers(0, min(max_tombstoned, max(len(live) - 1, 0)) + 1))
    tombs = rng.choice(live, size=n_dead, replace=False) if n_dead else []
    return DictionaryDelta(
        added=tuple(added),
        tombstones=tuple(int(t) for t in tombs),
    )

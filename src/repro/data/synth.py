"""Synthetic corpora and dictionaries with controlled mention statistics.

The paper evaluates plans over "entity dictionaries consisting of
entities that follow various mention distributions" (§6). This module
generates:

* a Zipfian vocabulary with IDF-style token weights,
* an entity dictionary whose *mention frequencies* follow a chosen
  distribution (``zipf`` / ``uniform`` / ``bimodal``), and
* a document collection of Zipfian background tokens with planted,
  noisy entity mentions (missing words / extra words / permuted order).

All randomness flows from a single seed for reproducibility.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dictionary import Dictionary, build_dictionary

MENTION_DISTS = ("zipf", "uniform", "bimodal")


@dataclasses.dataclass
class SynthCorpus:
    doc_tokens: np.ndarray  # [D, T] int32, PAD=0 tails
    dictionary: Dictionary
    planted: list[tuple[int, int, int, int]]  # (doc, pos, len, entity) as planted
    mention_freq: np.ndarray  # [E] planted mention counts (dictionary order)


def _zipf_probs(n: int, s: float = 1.1) -> np.ndarray:
    p = 1.0 / np.power(np.arange(1, n + 1), s)
    return p / p.sum()


def make_corpus(
    *,
    num_docs: int = 32,
    doc_len: int = 128,
    vocab_size: int = 2048,
    num_entities: int = 64,
    max_entity_len: int = 5,
    min_entity_len: int = 2,
    mention_dist: str = "zipf",
    mentions_per_doc: float = 3.0,
    p_drop: float = 0.25,
    p_insert: float = 0.15,
    p_permute: float = 0.1,
    weighted: bool = True,
    seed: int = 0,
) -> SynthCorpus:
    """Generate a corpus + dictionary with planted noisy mentions."""
    rng = np.random.default_rng(seed)
    bg_probs = _zipf_probs(vocab_size - 1)

    # --- entities: distinct tokens, biased to mid-frequency vocabulary
    ent_tokens: list[list[int]] = []
    seen_ents: set[tuple[int, ...]] = set()
    while len(ent_tokens) < num_entities:
        n = int(rng.integers(min_entity_len, max_entity_len + 1))
        toks = rng.choice(vocab_size - 1, size=n, replace=False, p=bg_probs) + 1
        key = tuple(sorted(int(t) for t in toks))
        if key in seen_ents:
            continue
        seen_ents.add(key)
        ent_tokens.append([int(t) for t in toks])

    # --- token weights: IDF-style from background probabilities
    if weighted:
        tw = np.zeros((vocab_size,), dtype=np.float32)
        tw[1:] = np.log1p(1.0 / (bg_probs * vocab_size)).astype(np.float32) + 0.1
    else:
        tw = np.ones((vocab_size,), dtype=np.float32)

    # --- mention frequency distribution over entities
    if mention_dist == "zipf":
        mf = _zipf_probs(num_entities, s=1.3)
    elif mention_dist == "uniform":
        mf = np.full((num_entities,), 1.0 / num_entities)
    elif mention_dist == "bimodal":
        hot = max(1, num_entities // 10)
        mf = np.concatenate(
            [np.full((hot,), 0.8 / hot), np.full((num_entities - hot,), 0.2 / (num_entities - hot))]
        )
    else:
        raise ValueError(f"unknown mention_dist {mention_dist!r}")

    total_mentions = int(mentions_per_doc * num_docs)
    ent_of_mention = rng.choice(num_entities, size=total_mentions, p=mf)

    dictionary = build_dictionary(
        ent_tokens, vocab_size, token_weight=tw, freq=np.bincount(
            ent_of_mention, minlength=num_entities
        ).astype(np.float32), max_len=max_entity_len,
    )
    # entity ids below refer to the *sorted* dictionary order; rebuild the
    # mention stream in sorted ids for planting.
    order = np.argsort(
        -np.bincount(ent_of_mention, minlength=num_entities).astype(np.float32),
        kind="stable",
    )
    inv = np.empty_like(order)
    inv[order] = np.arange(num_entities)
    ent_of_mention = inv[ent_of_mention]

    # --- documents: background + planted mentions
    docs = np.zeros((num_docs, doc_len), dtype=np.int32)
    for d in range(num_docs):
        docs[d] = rng.choice(vocab_size - 1, size=doc_len, p=bg_probs) + 1

    planted: list[tuple[int, int, int, int]] = []
    mention_freq = np.zeros((num_entities,), dtype=np.int64)
    for e in ent_of_mention:
        n = int(dictionary.lengths[e])
        toks = list(dictionary.tokens[e, :n])
        # noise: drop / permute / insert
        if n > 1 and rng.random() < p_drop:
            toks.pop(int(rng.integers(len(toks))))
        if len(toks) > 1 and rng.random() < p_permute:
            i, j = rng.choice(len(toks), size=2, replace=False)
            toks[i], toks[j] = toks[j], toks[i]
        if rng.random() < p_insert:
            junk = int(rng.choice(vocab_size - 1, p=bg_probs)) + 1
            toks.insert(int(rng.integers(len(toks) + 1)), junk)
        m = len(toks)
        d = int(rng.integers(num_docs))
        p = int(rng.integers(0, doc_len - m))
        docs[d, p : p + m] = np.array(toks, dtype=np.int32)
        planted.append((d, p, m, int(e)))
        mention_freq[e] += 1

    return SynthCorpus(
        doc_tokens=docs,
        dictionary=dictionary,
        planted=planted,
        mention_freq=mention_freq,
    )


def skewed_mention_probs(num_entities: int, kind: str = "head",
                         s: float = 1.3) -> np.ndarray:
    """Per-entity mention distribution for drift workloads.

    ``head``: Zipf mass on the frequency-sorted head (matches the
    distribution a ``make_corpus`` dictionary was built under);
    ``tail``: the same Zipf reversed (mentions concentrate on entities
    the plan's head/tail split assumed were cold — the "dictionary
    skew" axis of a drift injection); ``uniform``: flat.
    """
    if kind == "head":
        return _zipf_probs(num_entities, s=s)
    if kind == "tail":
        return _zipf_probs(num_entities, s=s)[::-1].copy()
    if kind == "uniform":
        return np.full((num_entities,), 1.0 / num_entities)
    raise ValueError(f"unknown mention-probs kind {kind!r}")


def drift_docs(
    dictionary: Dictionary,
    *,
    num_docs: int,
    doc_len: int,
    mention_probs: np.ndarray | None,
    mentions_per_doc: float,
    seed: int,
    p_drop: float = 0.25,
    p_insert: float = 0.15,
    p_permute: float = 0.1,
) -> np.ndarray:
    """Documents over an *existing* dictionary with chosen statistics.

    The drift-injection workload generator: unlike ``make_corpus`` (one
    dictionary + one corpus from one seed), this plants noisy mentions
    of ``dictionary``'s entities into fresh background documents under
    an explicit per-entity distribution, mention rate and document
    length — so a serving run can shift mention frequency, doc length
    and entity skew *mid-stream* while every phase shares the same
    dictionary (and therefore the same serving session). Deterministic
    for a given seed; ``mention_probs=None`` plants nothing (pure
    background). Returns [num_docs, doc_len] int32, PAD-free rows.
    """
    rng = np.random.default_rng(seed)
    E = dictionary.num_entities
    V = int(dictionary.token_weight.shape[0])
    bg_probs = _zipf_probs(V - 1)
    docs = np.zeros((num_docs, doc_len), dtype=np.int32)
    for d in range(num_docs):
        docs[d] = rng.choice(V - 1, size=doc_len, p=bg_probs) + 1
    if mention_probs is None:
        return docs
    mention_probs = np.asarray(mention_probs, dtype=np.float64)
    if mention_probs.shape != (E,):
        raise ValueError(
            f"mention_probs shape {mention_probs.shape} != ({E},)"
        )
    mention_probs = mention_probs / mention_probs.sum()
    total = int(round(mentions_per_doc * num_docs))
    for e in rng.choice(E, size=total, p=mention_probs):
        n = int(dictionary.lengths[e])
        toks = list(dictionary.tokens[e, :n])
        if n > 1 and rng.random() < p_drop:
            toks.pop(int(rng.integers(len(toks))))
        if len(toks) > 1 and rng.random() < p_permute:
            i, j = rng.choice(len(toks), size=2, replace=False)
            toks[i], toks[j] = toks[j], toks[i]
        if rng.random() < p_insert:
            junk = int(rng.choice(V - 1, p=bg_probs)) + 1
            toks.insert(int(rng.integers(len(toks) + 1)), junk)
        m = len(toks)
        if m > doc_len:
            continue
        d = int(rng.integers(num_docs))
        p = int(rng.integers(0, doc_len - m + 1))
        docs[d, p : p + m] = np.array(toks, dtype=np.int32)
    return docs

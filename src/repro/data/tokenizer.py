"""Word tokenizers: a hash tokenizer for open-vocabulary streams and a
small fitted vocabulary for demos (detokenizable)."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import hashing
from repro.core.dictionary import PAD


@dataclasses.dataclass(frozen=True)
class HashTokenizer:
    """word -> 1 + hash(word) % (V-1); PAD=0 reserved. Stateless."""

    vocab_size: int

    def encode_word(self, word: str) -> int:
        h = hashing.hash_u32(
            np.frombuffer(word.encode(), dtype=np.uint8).astype(np.int64).sum()
            + np.int64(len(word)) * 1315423911,
            seed=5,
            xp=np,
        )
        return 1 + int(h) % (self.vocab_size - 1)

    def encode(self, text: str) -> list[int]:
        return [self.encode_word(w) for w in text.lower().split()]

    def encode_docs(self, docs: list[str], doc_len: int) -> np.ndarray:
        out = np.full((len(docs), doc_len), PAD, dtype=np.int32)
        for i, d in enumerate(docs):
            ids = self.encode(d)[:doc_len]
            out[i, : len(ids)] = ids
        return out


@dataclasses.dataclass
class Vocab:
    """Fitted word vocabulary (id 0 = PAD, id 1 = <unk>)."""

    word_to_id: dict
    id_to_word: list

    @classmethod
    def fit(cls, texts: list[str], max_size: int = 50_000) -> "Vocab":
        from collections import Counter

        cnt = Counter(w for t in texts for w in t.lower().split())
        words = [w for w, _ in cnt.most_common(max_size - 2)]
        w2i = {w: i + 2 for i, w in enumerate(words)}
        return cls(w2i, ["<pad>", "<unk>"] + words)

    @property
    def size(self) -> int:
        return len(self.id_to_word)

    def encode(self, text: str) -> list[int]:
        return [self.word_to_id.get(w, 1) for w in text.lower().split()]

    def decode(self, ids) -> str:
        return " ".join(self.id_to_word[int(i)] for i in ids if int(i) > 1)

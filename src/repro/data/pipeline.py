"""Sharded, deterministic, restart-safe data pipeline with the EE-Join
operator as a first-class annotation stage.

The pipeline turns a document stream into LM training batches:

    docs -> [EE-Join annotate] -> pack/shift -> {tokens, labels,
                                                 entity_mask} batches

The EE-Join stage tags every token covered by a dictionary-entity
mention (the paper's operator used for corpus annotation — e.g.
entity-aware loss weighting or eval tagging). It runs the *chosen plan*,
so the same cost-based optimisation that speeds up offline extraction
speeds up the training input pipeline.

Determinism/restart: batches are a pure function of (seed, step), so a
job restarted at step k sees exactly the batches it would have seen —
required for exact checkpoint-resume (tests/test_train.py).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

import jax.numpy as jnp

from repro.core.eejoin import EEJoinOperator, PreparedPlan
from repro.data.synth import SynthCorpus, make_corpus


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 0
    annotate: bool = True


def annotate_docs(
    op: EEJoinOperator, prepared: PreparedPlan, doc_tokens: np.ndarray
) -> np.ndarray:
    """Run the prepared plan; return [D, T] bool mask of mention tokens."""
    m = op.execute(prepared, jnp.asarray(doc_tokens))
    mask = np.zeros(doc_tokens.shape, dtype=bool)
    doc = np.asarray(m.doc)
    pos = np.asarray(m.pos)
    ln = np.asarray(m.length)
    keep = doc >= 0
    for d, p, l in zip(doc[keep], pos[keep], ln[keep]):
        mask[d, p : p + l] = True
    return mask


def batches(
    corpus: SynthCorpus,
    cfg: PipelineConfig,
    op: EEJoinOperator | None = None,
    prepared: PreparedPlan | None = None,
) -> Iterator[dict]:
    """Deterministic infinite batch stream (pure function of step)."""
    docs = corpus.doc_tokens
    D, T = docs.shape
    mask = None
    if cfg.annotate and op is not None and prepared is not None:
        mask = annotate_docs(op, prepared, docs)

    flat = docs.reshape(-1)
    flat_mask = mask.reshape(-1) if mask is not None else np.zeros_like(flat, bool)
    n_tokens = flat.shape[0]
    window = cfg.seq_len + 1
    step = 0
    while True:
        rng = np.random.default_rng(cfg.seed * 100_003 + step)
        starts = rng.integers(0, n_tokens - window, size=cfg.global_batch)
        idx = starts[:, None] + np.arange(window)[None, :]
        chunk = flat[idx]
        emask = flat_mask[idx]
        yield {
            "tokens": jnp.asarray(chunk[:, :-1]),
            "labels": jnp.asarray(
                np.where(chunk[:, 1:] > 0, chunk[:, 1:], -1).astype(np.int32)
            ),
            "entity_mask": jnp.asarray(emask[:, :-1]),
        }
        step += 1

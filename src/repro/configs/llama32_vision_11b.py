"""Llama-3.2-11B-Vision [hf:meta-llama]: 40L d4096 32H (kv=8) ff14336
v128256 — every 5th layer is a tanh-gated cross-attention layer over
image-patch embeddings (8 cross layers in 40).

The vision frontend is a STUB per the assignment: input_specs() provides
1601 precomputed patch embeddings of width 4096.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=500_000.0,
    block_pattern=("attn", "attn", "attn", "attn", "cross_attn_gated"),
    context_len=1601,
    context_dim=4096,
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, num_layers=5, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, context_len=17, context_dim=64,
        attn_chunk=32,
    )

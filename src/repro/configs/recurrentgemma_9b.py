"""RecurrentGemma-9B [arXiv:2402.19427]: 38L d4096 16H (kv=1, MQA)
ff12288 v256000 — Griffin: repeating (RG-LRU, RG-LRU, local-attn) with a
2048 sliding window; 38 = 12*3 + 2 trailing recurrent blocks.

RG-LRU state is O(1) and attention is windowed -> runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    norm="rmsnorm",
    act="geglu",
    block_pattern=("rglru", "rglru", "local_attn"),
    extra_tail_blocks=("rglru", "rglru"),
    local_window=2048,
    supports_long_context=True,
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, num_layers=8, d_model=64, num_heads=2, num_kv_heads=1,
        d_ff=128, vocab_size=256, local_window=16, attn_chunk=16,
        extra_tail_blocks=("rglru", "rglru"),
    )

"""Architecture registry: ``--arch <id>`` -> ModelConfig."""
from __future__ import annotations

from repro.configs import (
    dbrx_132b,
    glm4_9b,
    granite_moe_1b,
    llama32_vision_11b,
    olmo_1b,
    recurrentgemma_9b,
    starcoder2_7b,
    whisper_large_v3,
    xlstm_125m,
    yi_9b,
)
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

_MODULES = {
    "olmo-1b": olmo_1b,
    "starcoder2-7b": starcoder2_7b,
    "yi-9b": yi_9b,
    "glm4-9b": glm4_9b,
    "xlstm-125m": xlstm_125m,
    "granite-moe-1b-a400m": granite_moe_1b,
    "dbrx-132b": dbrx_132b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "llama-3.2-vision-11b": llama32_vision_11b,
    "whisper-large-v3": whisper_large_v3,
}

ARCH_IDS = tuple(_MODULES.keys())


def get_config(arch: str) -> ModelConfig:
    return _MODULES[arch].CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].smoke_config()


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a shape cell applies to an arch (DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "full attention is O(S^2)/O(S) per token at 512k; skipped per assignment (sub-quadratic archs only)"
    return True, ""


def all_cells():
    """All 40 (arch, shape) cells with applicability flags."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            out.append((arch, shape.name, ok, why))
    return out

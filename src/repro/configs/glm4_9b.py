"""GLM-4-9B [hf:THUDM/glm-4-9b]: 40L d4096 32H (kv=2) ff13696 v151552."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=10_000.0,
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, attn_chunk=32,
    )

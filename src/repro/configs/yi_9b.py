"""Yi-9B [arXiv:2403.04652; hf]: 48L d4096 32H (kv=4) ff11008 v64000.

Llama-architecture GQA decoder."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=10_000.0,
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, attn_chunk=32,
    )

"""StarCoder2-7B [arXiv:2402.19173; hf]: 32L d4608 36H (kv=4) ff18432 v49152.

36 q-heads do not divide a 16-way model axis: the baseline replicates the
head dim (params still FSDP-sharded); §Perf logs the head-padding
hillclimb.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    norm="layernorm",
    act="gelu",
    rope_theta=100_000.0,
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=72, num_heads=6, num_kv_heads=2,
        d_ff=160, vocab_size=256, attn_chunk=32,
    )

"""Model / run configuration schema shared by all assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Sequence

# block kinds usable in a layer-group pattern
BLK_ATTN = "attn"  # global causal self-attention
BLK_LOCAL = "local_attn"  # sliding-window causal self-attention
BLK_RGLRU = "rglru"  # Griffin RG-LRU recurrent block
BLK_MLSTM = "mlstm"  # xLSTM matrix-memory block
BLK_SLSTM = "slstm"  # xLSTM scalar-memory block
BLK_XATTN = "cross_attn"  # cross-attention (VLM / enc-dec decoder)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # --- norm / activation / embedding
    norm: str = "rmsnorm"  # rmsnorm | layernorm | layernorm_nonparam
    act: str = "swiglu"  # swiglu | geglu | gelu
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    # --- layer-group pattern: scan runs over groups of these blocks.
    # Default single-block group ("attn",) x num_layers.
    block_pattern: tuple[str, ...] = (BLK_ATTN,)
    extra_tail_blocks: tuple[str, ...] = ()  # unrolled remainder layers
    # how many of num_layers one group accounts for (0 -> len(pattern));
    # whisper's (self, cross) pair counts as ONE layer.
    layers_per_group: int = 0
    local_window: int = 2048
    # --- MoE
    num_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.25
    # --- cross-attention context (VLM image tokens / encoder frames)
    context_len: int = 0  # 0 -> no cross-attn context input
    context_dim: int = 0  # raw context embedding dim (projected to d_model)
    # --- encoder-decoder (whisper): encoder is bidirectional attn stack
    encoder_layers: int = 0
    encoder_len: int = 0
    # --- numerics / memory levers
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    attn_chunk: int = 1024  # kv-block size of the blockwise attention
    # flash-attention custom-VJP backward (§Perf hillclimb #1): identical
    # math, saves (out, lse) instead of the per-chunk-pair P matrices.
    # False reproduces the pre-hillclimb baseline backward.
    use_flash: bool = True
    # chunkwise-parallel mLSTM (§Perf hillclimb, xlstm cell): 0 = exact
    # sequential scan baseline; >0 = chunk length of the parallel form.
    mlstm_chunk: int = 128
    # sLSTM scan unroll factor (sequential by nature; this amortises
    # while-loop overhead and weight re-reads).
    slstm_unroll: int = 8
    # long_500k applicability (sub-quadratic archs only)
    supports_long_context: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def num_groups(self) -> int:
        lpg = self.layers_per_group or len(self.block_pattern)
        n = self.num_layers - len(self.extra_tail_blocks)
        assert n % lpg == 0, (
            f"{self.name}: {n} layers not divisible by group span {lpg}"
        )
        return n // lpg

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128 for clean TP sharding."""
        return ((self.vocab_size + 127) // 128) * 128


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Execution knobs resolved per (arch x shape x mesh)."""

    microbatch_per_device: int = 0  # 0 -> whole per-device batch at once
    use_remat: bool = True
    logits_fp32: bool = True

"""DBRX-132B [hf:databricks/dbrx-base]: 40L d6144 48H (kv=8) v100352,
MoE 16 experts top-4, d_ff=10752 per expert.

16 experts on a 16-way model axis -> exactly one expert per device in the
shard_map MoE (zero masked-compute waste). train_4k needs per-device
microbatching (see configs/runtime table in EXPERIMENTS.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    norm="layernorm",
    act="swiglu",
    num_experts=16,
    top_k=4,
    rope_theta=500_000.0,
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=256, num_experts=4, top_k=2, attn_chunk=32,
    )

"""Granite-3.0-1B-A400M [hf:ibm-granite]: 24L d1024 16H (kv=8) v49155,
MoE 32 experts top-8, d_ff=512 per expert (fine-grained).

Vocab padded 49155 -> 49280 for clean 128-aligned TP sharding.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    norm="rmsnorm",
    act="swiglu",
    num_experts=32,
    top_k=8,
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=64, vocab_size=256, num_experts=4, top_k=2, attn_chunk=32,
    )

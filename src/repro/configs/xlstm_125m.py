"""xLSTM-125M [arXiv:2405.04517]: 12L d768 4H v50304, alternating
mLSTM/sLSTM blocks (d_ff=0: the blocks carry their own projections).

Recurrent state is O(1) in sequence length -> runs the long_500k cell.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    norm="layernorm",
    act="gelu",
    block_pattern=("mlstm", "slstm"),
    supports_long_context=True,
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
        vocab_size=256, attn_chunk=32,
    )

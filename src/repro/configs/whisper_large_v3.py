"""Whisper-large-v3 [arXiv:2212.04356]: enc-dec, 32+32L d1280 20H ff5120
v51866 (padded -> 51968). Conv frontend is a STUB: input_specs() provides
1500 precomputed frame embeddings; the encoder is the bidirectional
attention stack, each decoder layer is (self-attn, cross-attn + MLP).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,  # decoder layers; encoder_layers below
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    norm="layernorm",
    act="gelu",
    block_pattern=("attn_nomlp", "cross_attn"),
    layers_per_group=1,
    context_len=1500,
    context_dim=1280,
    encoder_layers=32,
    encoder_len=1500,
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, context_len=12, context_dim=64,
        encoder_layers=2, encoder_len=12, attn_chunk=32,
    )

"""OLMo-1B [arXiv:2402.00838; hf]: 16L d2048 16H (kv=16) ff8192 v50304.

Distinguishing trait: non-parametric LayerNorm (no learnable affine).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    norm="layernorm_nonparam",
    act="swiglu",
    rope_theta=10_000.0,
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, attn_chunk=32,
    )

"""Replica server: snapshot bootstrap + delta replay + epoch-acked serving.

A replica is a verify/serving process that holds its own built
extraction state and keeps it bit-identical to the coordinator's by
construction:

* **bootstrap** — a session ships once as a *compacted base snapshot*
  (``snapshot_session``): the ``DictionaryVersion`` bytes plus the
  JSON-coded config / plan / cost params. The replica rebuilds the
  session locally (filters, signature tables, indexes) — structures
  are deterministic functions of (dictionary, config, plan), so
  rebuilding from the same bytes yields the same state without ever
  shipping device structures.
* **replication** — every subsequent change ships as the serialized
  ``DictionaryDelta`` (or replan) *with the maintenance action the
  coordinator actually took* (``force_action``). Replaying the same
  (delta, action) chain through the same ``apply_delta`` code path
  reproduces the same epoch numbers and the same global entity id
  space — compaction renumbers identically on every host.
* **epoch agreement** — each applied change is acked with the
  replica's resulting epoch; the coordinator routes a request admitted
  at epoch E only to replicas that acked >= E. The replica holds a
  retention pin on every epoch it has built and releases it on the
  coordinator's RELEASE frame (cluster-wide drain), so a request at a
  past epoch still finds its exact state.

Requests execute through the same ``updates.builders`` entry points as
single-host serving: ``execute_epoch`` for full documents (FT_REQUEST)
and the lane-verify path (FT_LANES) for the remote half of
``ExtractionService``'s probe→verify split.
"""
from __future__ import annotations

import dataclasses
import json
import socket

import numpy as np

import jax.numpy as jnp

from repro.core.cost_model import CostParams
from repro.core.eejoin import EEJoinConfig, PreparedPlan
from repro.core.plan import Plan, PlanSide
from repro.core.signatures import LshParams
from repro.fabric.transport import SocketChannel, serve_frames
from repro.fabric.wire import (
    FT_ACK,
    FT_DELTA,
    FT_LANES,
    FT_MATCHES,
    FT_RELEASE,
    FT_REQUEST,
    FT_SHUTDOWN,
    FT_SNAPSHOT,
    FT_STATS,
    Frame,
    encode_frame,
    matches_to_wire,
)
from repro.serving.session import DictionarySession, SessionCache, pure_plan
from repro.updates.delta import (
    DictionaryDelta,
    DictionaryVersion,
    pack_arrays,
    unpack_arrays,
)

# ------------------------------------------------------------ JSON codecs
# Config / plan / cost-params travel as JSON inside payload headers.
# Reconstruction must restore *exact* types — ``dictionary_fingerprint``
# folds in ``repr(config)``, so a list where a tuple was, or a dict
# where an LshParams was, would silently give the replica a different
# session key than the coordinator's.


def config_to_json(cfg: EEJoinConfig) -> dict:
    d = dataclasses.asdict(cfg)
    d["lsh"] = {"bands": cfg.lsh.bands, "rows": cfg.lsh.rows}
    d["options"] = [list(o) for o in cfg.options]
    return d


def config_from_json(d: dict) -> EEJoinConfig:
    d = dict(d)
    d["lsh"] = LshParams(**d["lsh"])
    d["options"] = tuple(tuple(o) for o in d["options"])
    return EEJoinConfig(**d)


def plan_to_json(plan: Plan) -> dict:
    # only the executable identity of the plan travels: split + sides +
    # objective fully determine ``prepare``; cost predictions are local
    # diagnostics and are zeroed on the far side (pure_plan pattern)
    return {
        "split": int(plan.split),
        "head": [plan.head.algo, plan.head.scheme],
        "tail": [plan.tail.algo, plan.tail.scheme],
        "objective": plan.objective,
    }


def plan_from_json(d: dict) -> Plan:
    z = pure_plan("prefix")  # donor for zeroed cost fields
    return Plan(
        split=int(d["split"]),
        head=PlanSide(*d["head"]),
        tail=PlanSide(*d["tail"]),
        objective=d["objective"],
        predicted_cost=0.0,
        head_cost=z.head_cost,
        tail_cost=z.tail_cost,
        evaluations=0,
    )


def cost_params_to_json(cp: CostParams) -> dict:
    return dataclasses.asdict(cp)


def cost_params_from_json(d: dict) -> CostParams:
    return CostParams(**d)


# ------------------------------------------------------- snapshot payloads


def snapshot_session(sess: DictionarySession) -> bytes:
    """Bootstrap payload: compacted base version + config/plan/params.

    Requires the current epoch to be segment- and tombstone-free (a
    compacted base): open segments can't be reconstructed by a session
    build, only replayed — snapshot at session creation or right after
    a compaction, then ship the delta stream.
    """
    state = sess.current_state
    version = state.version
    if version.num_segments or bool(version.tombstones.any()):
        raise ValueError(
            f"snapshot_session: epoch {sess.epoch} has "
            f"{version.num_segments} open segment(s) and "
            f"{int(version.tombstones.sum())} tombstone(s); replicas "
            "bootstrap from a compacted base only — snapshot before "
            "applying deltas, or after a compact"
        )
    meta = {
        "kind": "session_snapshot",
        "session": sess.key,
        "epoch": int(sess.epoch),
        "config": config_to_json(sess.config),
        "plan": plan_to_json(state.plan),
        "cost_params": cost_params_to_json(
            sess.cost_params or CostParams(num_devices=1)
        ),
    }
    blob = version.to_bytes()
    return pack_arrays(meta, {
        "version": np.frombuffer(blob, dtype=np.uint8).copy()
    })


def encode_delta_ship(session_key: str, parent_epoch: int, action: str,
                      delta: DictionaryDelta,
                      sample_docs: np.ndarray | None = None) -> bytes:
    """One replicated update: the delta bytes + the forced action."""
    meta = {
        "kind": "delta_ship",
        "session": session_key,
        "parent_epoch": int(parent_epoch),
        "action": action,
    }
    arrays = {
        "delta": np.frombuffer(delta.to_bytes(), dtype=np.uint8).copy()
    }
    if sample_docs is not None:
        arrays["sample_docs"] = np.asarray(sample_docs, dtype=np.int32)
    return pack_arrays(meta, arrays)


def encode_replan_ship(session_key: str, parent_epoch: int, plan: Plan,
                       cost_params: CostParams) -> bytes:
    return pack_arrays({
        "kind": "replan_ship",
        "session": session_key,
        "parent_epoch": int(parent_epoch),
        "plan": plan_to_json(plan),
        "cost_params": cost_params_to_json(cost_params),
    }, {})


def encode_request(session_key: str, epoch: int,
                   docs: np.ndarray) -> bytes:
    return pack_arrays(
        {"kind": "extract_request", "session": session_key,
         "epoch": int(epoch)},
        {"docs": np.asarray(docs, dtype=np.int32)},
    )


def verify_lanes_on_state(state, config: EEJoinConfig, docs: np.ndarray,
                          lanes: list):
    """The verify stage over shipped lanes — remote half of
    ``ExtractionService._verify_batch``.

    ``lanes`` is the wire list: per plan side ``(count [1] i32,
    lane [1, NC] i32, keys [1, NC, 2] u32 | None)``. Returns
    ``(Matches, overflow)``; bit-identical to running the local verify
    stage because it is the same sequence of calls over the same
    (replicated) epoch state.
    """
    from repro.extraction import engine
    from repro.extraction.results import (
        filter_matches,
        gather_from_tiles,
        merge_matches,
        select_from_tiles,
    )
    from repro.updates.builders import epoch_side_matches

    if len(lanes) != len(state.sides):
        raise ValueError(
            f"lane frame has {len(lanes)} sides, epoch state has "
            f"{len(state.sides)} — plan mismatch between hosts"
        )
    docs_j = jnp.asarray(np.asarray(docs, dtype=np.int32))
    out = None
    overflow = 0
    for eside, (count, lane, keys) in zip(state.sides, lanes):
        count = jnp.asarray(count)
        lane = jnp.asarray(lane)
        NC = eside.params.max_candidates
        sel, ok, n = select_from_tiles(count, lane, NC)
        cands = engine.candidates_from_flat(
            docs_j, sel, ok, n, state.max_len, NC
        )
        if keys is not None:
            cands = engine.attach_variant_keys(
                cands, gather_from_tiles(count, jnp.asarray(keys), NC)
            )
        overflow += int(cands["overflow"])
        m = epoch_side_matches(cands, eside, config.result_capacity)
        out = m if out is None else merge_matches(
            out, m, config.result_capacity
        )
    if state.has_tombstones:
        out = filter_matches(out, state.live, config.result_capacity)
    return out, overflow


class ReplicaServer:
    """One replica's sessions + the frame handler driving them."""

    def __init__(self, name: str):
        self.name = name
        # build logic reuses SessionCache.get_or_create; lookup happens
        # on this dict under the *coordinator's* session key (which may
        # differ from the local fingerprint when the snapshot was taken
        # after a compaction changed the dictionary bytes)
        self._cache = SessionCache(max_sessions=64)
        self.sessions: dict[str, DictionarySession] = {}
        self.requests_served = 0
        self.lane_batches_served = 0
        self.deltas_applied = 0
        self.replans_applied = 0
        self.released_epochs = 0

    # ------------------------------------------------------------ handlers
    def _bootstrap(self, payload: bytes) -> tuple[int, bytes]:
        meta, arrays = unpack_arrays(payload)
        if meta.get("kind") != "session_snapshot":
            raise ValueError(f"SNAPSHOT payload kind {meta.get('kind')!r}")
        version = DictionaryVersion.from_bytes(arrays["version"].tobytes())
        if version.num_segments or bool(version.tombstones.any()):
            raise ValueError(
                "snapshot is not a compacted base (open segments or "
                "tombstones present)"
            )
        config = config_from_json(meta["config"])
        plan = plan_from_json(meta["plan"])
        cp = cost_params_from_json(meta["cost_params"])
        sess = self._cache.get_or_create(
            version.base, config, plan=plan, cost_params=cp
        )
        snap_epoch = int(meta["epoch"])
        if snap_epoch != sess.epoch:
            # snapshot taken at a compacted epoch > 0: adopt the
            # coordinator's numbering so the replayed delta chain and
            # the acks line up
            state = sess.epochs.pop(sess.epoch)
            state.epoch = snap_epoch
            state.version = dataclasses.replace(
                state.version, epoch=snap_epoch
            )
            sess.epochs[snap_epoch] = state
            sess.epoch = snap_epoch
        key = meta["session"]
        self.sessions[key] = sess
        # retention pin: the bootstrap epoch stays until RELEASEd
        sess.epochs[sess.epoch].pins += 1
        return self._ack(key, sess)

    def _ack(self, key: str, sess: DictionarySession) -> tuple[int, bytes]:
        return FT_ACK, json.dumps({
            "replica": self.name,
            "session": key,
            "epoch": int(sess.epoch),
        }).encode()

    def _session(self, key: str) -> DictionarySession:
        sess = self.sessions.get(key)
        if sess is None:
            raise KeyError(
                f"replica {self.name}: unknown session {key!r} "
                "(not bootstrapped)"
            )
        return sess

    def _apply_delta(self, payload: bytes) -> tuple[int, bytes]:
        meta, arrays = unpack_arrays(payload)
        kind = meta.get("kind")
        sess = self._session(meta["session"])
        parent = int(meta["parent_epoch"])
        if sess.epoch != parent:
            raise ValueError(
                f"replica {self.name}: delta parented at epoch {parent} "
                f"but session {meta['session']} is at {sess.epoch} — "
                "replication gap; re-bootstrap from a fresh snapshot"
            )
        if kind == "delta_ship":
            delta = DictionaryDelta.from_bytes(arrays["delta"].tobytes())
            sample = arrays.get("sample_docs")
            sess.apply_delta(
                delta,
                sample_docs=sample,
                force_action=meta["action"],
            )
            self.deltas_applied += 1
        elif kind == "replan_ship":
            sess.apply_replan(
                plan_from_json(meta["plan"]),
                cost_params_from_json(meta["cost_params"]),
                reason="replicated",
            )
            self.replans_applied += 1
        else:
            raise ValueError(f"DELTA payload kind {kind!r}")
        # retention pin on the new epoch until the coordinator RELEASEs
        # it (apply_delta/apply_replan already GC'd the parent only if
        # it was unpinned — it wasn't, it holds the previous retention
        # pin)
        sess.epochs[sess.epoch].pins += 1
        return self._ack(meta["session"], sess)

    def _state_for(self, sess: DictionarySession, epoch: int):
        if epoch > sess.epoch:
            raise ValueError(
                f"replica {self.name} lags: request at epoch {epoch}, "
                f"applied epoch {sess.epoch} — coordinator must not "
                "route ahead of the ack"
            )
        try:
            return sess.state_for(epoch)
        except KeyError:
            raise ValueError(
                f"replica {self.name}: epoch {epoch} already released"
            ) from None

    def _extract(self, payload: bytes) -> tuple[int, bytes]:
        from repro.updates.builders import execute_epoch

        meta, arrays = unpack_arrays(payload)
        if meta.get("kind") != "extract_request":
            raise ValueError(f"REQUEST payload kind {meta.get('kind')!r}")
        sess = self._session(meta["session"])
        epoch = int(meta["epoch"])
        state = self._state_for(sess, epoch)
        matches = execute_epoch(
            state, jnp.asarray(arrays["docs"]), sess.config
        )
        self.requests_served += 1
        return FT_MATCHES, matches_to_wire(
            matches, {"epoch": epoch, "replica": self.name}
        )

    def _verify_lanes(self, payload: bytes) -> tuple[int, bytes]:
        from repro.extraction.sharded import lanes_from_wire

        meta, docs, lanes = lanes_from_wire(payload)
        sess = self._session(meta["session"])
        epoch = int(meta["epoch"])
        state = self._state_for(sess, epoch)
        matches, overflow = verify_lanes_on_state(
            state, sess.config, docs, lanes
        )
        self.lane_batches_served += 1
        return FT_MATCHES, matches_to_wire(
            matches,
            {"epoch": epoch, "replica": self.name, "overflow": overflow},
        )

    def _release(self, payload: bytes) -> tuple[int, bytes]:
        meta = json.loads(payload.decode())
        sess = self._session(meta["session"])
        epoch = int(meta["epoch"])
        if epoch in sess.epochs:
            sess.unpin_epoch(epoch)
            self.released_epochs += 1
        return self._ack(meta["session"], sess)

    def stats(self) -> dict:
        return {
            "replica": self.name,
            "sessions": {
                k: int(s.epoch) for k, s in self.sessions.items()
            },
            "retained_epochs": {
                k: sorted(int(e) for e in s.epochs)
                for k, s in self.sessions.items()
            },
            "requests_served": self.requests_served,
            "lane_batches_served": self.lane_batches_served,
            "deltas_applied": self.deltas_applied,
            "replans_applied": self.replans_applied,
            "released_epochs": self.released_epochs,
        }

    def handle(self, frame: Frame):
        """``transport.serve_frames`` handler: dispatch one frame."""
        if frame.ftype == FT_SNAPSHOT:
            return self._bootstrap(frame.payload)
        if frame.ftype == FT_DELTA:
            return self._apply_delta(frame.payload)
        if frame.ftype == FT_REQUEST:
            return self._extract(frame.payload)
        if frame.ftype == FT_LANES:
            return self._verify_lanes(frame.payload)
        if frame.ftype == FT_RELEASE:
            return self._release(frame.payload)
        if frame.ftype == FT_STATS:
            return FT_STATS, json.dumps(self.stats()).encode()
        if frame.ftype == FT_SHUTDOWN:
            return None  # ends the serve loop; peer sees the close
        raise ValueError(
            f"replica {self.name}: unexpected frame {frame.type_name}"
        )


def replica_main(host: str, port: int, name: str,
                 idle_timeout: float = 600.0) -> None:
    """Child-process entrypoint: connect back, announce, serve frames.

    Spawned by ``cluster.launch_local_cluster`` (multiprocessing
    ``spawn`` context — safe next to jax's thread pools). The hello
    frame carries the replica name so the accepting coordinator can
    map connections to ring members. ``idle_timeout`` bounds orphaned
    children: no frame for that long and the process exits.
    """
    sock = socket.create_connection((host, port))
    channel = SocketChannel(sock)
    channel.send(encode_frame(
        FT_ACK, 0, json.dumps({"replica": name}).encode()
    ))
    server = ReplicaServer(name)
    try:
        serve_frames(channel, server.handle, idle_timeout=idle_timeout)
    finally:
        channel.close()

"""Consistent hashing: dictionary fingerprints -> replica names.

Sessions are identified by ``serving.session.dictionary_fingerprint``
(sha256 over the dictionary arrays + config repr). The ring maps each
fingerprint to an ordered preference list of replicas: ``owners(key,
n)`` walks clockwise from the key's hash point collecting distinct
replicas, so the coordinator gets a primary plus fallbacks for
shed/retry in one lookup.

Standard virtual-node construction: each replica contributes
``vnodes`` points at ``sha256(f"{name}#{i}")``; a key belongs to the
first point at or after its own hash (wrapping). Properties the
fabric relies on, asserted in ``tests/test_fabric.py``:

* deterministic — same membership, same assignment, on every host;
* minimal movement — adding/removing a replica only remaps keys whose
  arc it owned (~1/n of the space), everything else stays put, so a
  membership change invalidates few replica-side session caches.
"""
from __future__ import annotations

import bisect
import hashlib


def _point(data: str) -> int:
    """Hash a string to a 64-bit ring position."""
    return int.from_bytes(
        hashlib.sha256(data.encode()).digest()[:8], "big"
    )


class HashRing:
    """Consistent-hash ring over named replicas with virtual nodes."""

    def __init__(self, replicas: list[str] | None = None, *,
                 vnodes: int = 64):
        if vnodes < 1:
            raise ValueError(f"vnodes must be >= 1, got {vnodes}")
        self.vnodes = vnodes
        self._points: list[int] = []
        self._owners: dict[int, str] = {}
        self._members: set[str] = set()
        for name in replicas or []:
            self.add(name)

    @property
    def members(self) -> tuple[str, ...]:
        return tuple(sorted(self._members))

    def add(self, name: str) -> None:
        if name in self._members:
            raise ValueError(f"replica {name!r} already on the ring")
        self._members.add(name)
        for i in range(self.vnodes):
            p = _point(f"{name}#{i}")
            if p in self._owners:
                # 64-bit collision across names: astronomically
                # unlikely, but silent overwrite would desync rings
                # built in different orders — fail loudly instead.
                raise RuntimeError(
                    f"ring point collision between {name!r} and "
                    f"{self._owners[p]!r}"
                )
            bisect.insort(self._points, p)
            self._owners[p] = name

    def remove(self, name: str) -> None:
        if name not in self._members:
            raise ValueError(f"replica {name!r} not on the ring")
        self._members.discard(name)
        for i in range(self.vnodes):
            p = _point(f"{name}#{i}")
            self._points.remove(p)
            del self._owners[p]

    def owners(self, key: str, n: int = 1) -> list[str]:
        """First ``n`` distinct replicas clockwise from ``key``'s point.

        ``owners(key, 1)[0]`` is the primary; the rest are the
        deterministic fallback order used when the primary is shed.
        """
        if not self._members:
            raise ValueError("ring has no replicas")
        n = min(n, len(self._members))
        start = bisect.bisect_left(self._points, _point(key))
        out: list[str] = []
        for off in range(len(self._points)):
            p = self._points[(start + off) % len(self._points)]
            owner = self._owners[p]
            if owner not in out:
                out.append(owner)
                if len(out) == n:
                    break
        return out

    def primary(self, key: str) -> str:
        return self.owners(key, 1)[0]

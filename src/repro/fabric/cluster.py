"""Cluster coordinator: epoch-agreed routing over replicated sessions.

The coordinator owns the authoritative ``DictionarySession`` mirrors
(deltas apply here first), replicates every change to the replicas as
serialized deltas with the chosen maintenance action
(``session.delta_log`` is the replication source of truth), and routes
requests by three rules, in order:

1. **ring placement** — ``HashRing.owners(session_key)`` gives the
   deterministic preference order of replicas for a session;
2. **epoch agreement** — a request admitted (pinned) at epoch E is
   only sent to replicas whose last ack for that session is >= E; a
   lagging replica is skipped, never asked and never wrong;
3. **admission accounting** — per-replica inflight is capped, dead
   replicas (transport failures) are shed with bounded retry + backoff
   over the remaining candidates; if every candidate is shed the
   request errors — shed loudly, never silently dropped.

Epoch release: the coordinator refcounts outstanding requests per
(session, epoch); when an epoch older than current drains to zero it
broadcasts RELEASE so replicas drop their retention pins —
``hold_epochs=True`` keeps the coordinator-side pins (and skips local
GC) so tests and ``serve_cluster --check`` can still compute
``one_shot_reference`` at any admitted epoch after the run.
"""
from __future__ import annotations

import dataclasses
import json
import socket
import time

import numpy as np

from repro.fabric.replica import (
    encode_delta_ship,
    encode_replan_ship,
    encode_request,
    replica_main,
    snapshot_session,
)
from repro.fabric.ring import HashRing
from repro.fabric.transport import (
    ChannelClosed,
    Endpoint,
    SocketChannel,
    TransportTimeout,
)
from repro.fabric.wire import (
    FT_DELTA,
    FT_LANES,
    FT_RELEASE,
    FT_REQUEST,
    FT_SHUTDOWN,
    FT_SNAPSHOT,
    FT_STATS,
    decode_frame,
    matches_from_wire,
)


class ClusterShed(RuntimeError):
    """Every candidate replica was shed (dead, lagging, or saturated)."""


@dataclasses.dataclass
class ReplicaHandle:
    """Coordinator-side view of one replica."""

    name: str
    endpoint: Endpoint
    alive: bool = True
    inflight: int = 0
    routed: int = 0
    shed: int = 0
    failures: int = 0
    lane_bytes: int = 0
    # session key -> last acked epoch (-1 = not bootstrapped)
    acked: dict = dataclasses.field(default_factory=dict)
    # session key -> how many delta_log entries have been shipped
    log_pos: dict = dataclasses.field(default_factory=dict)


def pad_docs(docs) -> np.ndarray:
    """Variable-length docs -> one [N, T] PAD-padded int32 array.

    Row i = doc i, exactly like ``serving.service.one_shot_reference``
    pads — the wire request must describe the same batch the reference
    executes.
    """
    from repro.core.dictionary import PAD

    rows = [np.asarray(d, dtype=np.int32).reshape(-1) for d in docs]
    T = max((len(r) for r in rows), default=1)
    out = np.full((len(rows), max(T, 1)), PAD, dtype=np.int32)
    for i, r in enumerate(rows):
        out[i, : len(r)] = r
    return out


class ClusterCoordinator:
    """Routes extraction over replicated sessions with epoch agreement."""

    def __init__(self, replicas: dict[str, Endpoint], *,
                 metrics=None, max_inflight_per_replica: int = 8,
                 route_retries: int = 2, retry_backoff_s: float = 0.05,
                 hold_epochs: bool = False):
        if not replicas:
            raise ValueError("ClusterCoordinator needs >= 1 replica")
        self.handles = {
            name: ReplicaHandle(name=name, endpoint=ep)
            for name, ep in replicas.items()
        }
        self.ring = HashRing(list(replicas))
        self.metrics = metrics
        self.max_inflight_per_replica = max_inflight_per_replica
        self.route_retries = route_retries
        self.retry_backoff_s = retry_backoff_s
        self.hold_epochs = hold_epochs
        self.sessions: dict = {}  # key -> coordinator-local session
        # (session, epoch) -> outstanding request count (release protocol)
        self._outstanding: dict = {}
        # key -> epochs the replicas still hold retention pins for
        self._retained: dict = {}
        self.released: list = []  # (session, epoch) broadcast log

    # --------------------------------------------------------- replication
    def add_session(self, sess) -> None:
        """Register + bootstrap ``sess`` on every replica (snapshot)."""
        self.sessions[sess.key] = sess
        payload = snapshot_session(sess)
        for h in self.handles.values():
            ack = json.loads(
                h.endpoint.call(FT_SNAPSHOT, payload).payload.decode()
            )
            if int(ack["epoch"]) != sess.epoch:
                raise RuntimeError(
                    f"replica {h.name} bootstrapped session {sess.key} "
                    f"at epoch {ack['epoch']}, coordinator is at "
                    f"{sess.epoch}"
                )
            h.acked[sess.key] = int(ack["epoch"])
            h.log_pos[sess.key] = len(sess.delta_log)
        self._retained[sess.key] = {int(sess.epoch)}
        if self.hold_epochs:
            sess.pin_current()

    def sync_session(self, key: str) -> None:
        """Ship un-replicated ``delta_log`` entries; collect epoch acks.

        The log replays in order with the coordinator's *actual*
        maintenance action forced, so every replica walks the identical
        epoch chain (same numbers, same id renumbering on compaction).
        Divergent ack epochs are a protocol failure and raise.
        """
        sess = self.sessions[key]
        log = list(sess.delta_log)
        for h in self.handles.values():
            if not h.alive:
                continue
            pos = h.log_pos.get(key, 0)
            for entry in log[pos:]:
                if entry["action"] == "replan":
                    payload = encode_replan_ship(
                        key, entry["parent_epoch"], entry["plan"],
                        entry["cost_params"],
                    )
                else:
                    payload = encode_delta_ship(
                        key, entry["parent_epoch"], entry["action"],
                        entry["delta"], entry.get("sample_docs"),
                    )
                try:
                    ack = json.loads(
                        h.endpoint.call(FT_DELTA, payload).payload.decode()
                    )
                except (TransportTimeout, ChannelClosed):
                    h.alive = False
                    h.failures += 1
                    break
                if int(ack["epoch"]) != entry["epoch"]:
                    raise RuntimeError(
                        f"replication diverged: replica {h.name} acked "
                        f"epoch {ack['epoch']} for session {key}, "
                        f"coordinator log says {entry['epoch']}"
                    )
                h.acked[key] = int(ack["epoch"])
                h.log_pos[key] = pos = pos + 1
                self._retained.setdefault(key, set()).add(
                    int(entry["epoch"])
                )
        self._sweep_drained(key)

    def _sweep_drained(self, key: str) -> None:
        """Release retained epochs that predate current and have no
        outstanding requests — an epoch that drained *before* the next
        delta landed would otherwise stay pinned on every replica
        forever (``_finish`` only fires for requests still in flight
        across the flip)."""
        sess = self.sessions[key]
        for epoch in sorted(self._retained.get(key, ())):
            if epoch != sess.epoch \
                    and self._outstanding.get((key, epoch), 0) <= 0:
                self.release_epoch(key, epoch)

    def apply_delta(self, key: str, delta, sample_docs=None, **kw):
        """Apply on the coordinator mirror, then replicate the log."""
        sess = self.sessions[key]
        if self.hold_epochs:
            # keep the parent epoch for post-run reference checks
            sess.pin_current()
        state = sess.apply_delta(delta, sample_docs=sample_docs, **kw)
        self.sync_session(key)
        return state

    # ------------------------------------------------------------- routing
    def _candidates(self, key: str, epoch: int):
        """Ring-ordered eligible replicas for a request at ``epoch``."""
        out = []
        for name in self.ring.owners(key, n=len(self.handles)):
            h = self.handles[name]
            if not h.alive:
                h.shed += 1
                continue
            if h.acked.get(key, -1) < epoch:
                h.shed += 1  # lagging: epoch agreement forbids routing
                continue
            if h.inflight >= self.max_inflight_per_replica:
                h.shed += 1
                continue
            out.append(h)
        return out

    def _route(self, key: str, epoch: int, ftype: int, payload: bytes,
               timeout: float | None = None):
        """Send to the first healthy candidate; fail over with backoff."""
        last_exc = None
        for attempt in range(self.route_retries + 1):
            for h in self._candidates(key, epoch):
                h.inflight += 1
                try:
                    frame = h.endpoint.call(ftype, payload,
                                            timeout=timeout)
                except (TransportTimeout, ChannelClosed) as exc:
                    # dead or wedged replica: mark and fail over — the
                    # endpoint already burned its own frame-level
                    # retries before giving up
                    h.alive = False
                    h.failures += 1
                    last_exc = exc
                    continue
                finally:
                    h.inflight -= 1
                h.routed += 1
                if ftype == FT_LANES:
                    h.lane_bytes += len(payload)
                return h, frame
            if attempt < self.route_retries:
                time.sleep(self.retry_backoff_s * (2 ** attempt))
        raise ClusterShed(
            f"no replica could serve session {key} at epoch {epoch}: "
            f"members {self.ring.members}, acks "
            f"{ {n: h.acked.get(key, -1) for n, h in self.handles.items()} }, "
            f"alive { {n: h.alive for n, h in self.handles.items()} }"
            + (f"; last transport error: {last_exc}" if last_exc else "")
        )

    def _admit(self, key: str, epoch: int) -> None:
        self._outstanding[(key, epoch)] = (
            self._outstanding.get((key, epoch), 0) + 1
        )

    def _finish(self, key: str, epoch: int) -> None:
        left = self._outstanding.get((key, epoch), 0) - 1
        self._outstanding[(key, epoch)] = max(left, 0)
        sess = self.sessions[key]
        if left <= 0 and epoch != sess.epoch:
            self.release_epoch(key, epoch)

    def release_epoch(self, key: str, epoch: int) -> None:
        """Broadcast RELEASE: the cluster drained epoch ``epoch``."""
        if (key, epoch) in self.released:
            return  # a second broadcast would double-unpin on replicas
        self._retained.get(key, set()).discard(epoch)
        body = json.dumps({"session": key, "epoch": epoch}).encode()
        for h in self.handles.values():
            if not h.alive:
                continue
            try:
                h.endpoint.call(FT_RELEASE, body)
            except (TransportTimeout, ChannelClosed):
                h.alive = False
                h.failures += 1
        self.released.append((key, epoch))
        if not self.hold_epochs:
            sess = self.sessions[key]
            if epoch in sess.epochs and epoch != sess.epoch:
                sess.unpin_epoch(epoch)

    def extract(self, key: str, docs, timeout: float | None = None):
        """Serve one request: pin epoch, route, decode, release.

        Returns ``(epoch, Matches)`` — the admitted epoch is part of
        the result because the caller's parity reference is
        ``one_shot_reference(sess, docs, epoch=epoch)``.
        """
        sess = self.sessions[key]
        epoch = sess.pin_current()
        self._admit(key, epoch)
        try:
            payload = encode_request(key, epoch, pad_docs(docs))
            _h, frame = self._route(key, epoch, FT_REQUEST, payload,
                                    timeout=timeout)
            meta, matches = matches_from_wire(frame.payload)
            if int(meta["epoch"]) != epoch:
                raise RuntimeError(
                    f"replica {meta.get('replica')} answered for epoch "
                    f"{meta['epoch']}, request was pinned at {epoch}"
                )
            return epoch, matches
        finally:
            sess.unpin_epoch(epoch)
            self._finish(key, epoch)

    def verify_lanes(self, session_key: str, epoch: int, docs, lanes):
        """Remote verify: ship probed lanes, get Matches back.

        The ``ExtractionService.remote_verify`` hook — the service's
        probe stage already pinned ``epoch`` for the batch, so there is
        no pin here, only epoch-agreed routing. Returns
        ``(Matches, overflow)`` with host arrays.
        """
        from repro.extraction.sharded import lanes_to_wire

        self._admit(session_key, epoch)
        try:
            payload = lanes_to_wire(
                docs, lanes, {"session": session_key, "epoch": int(epoch)}
            )
            _h, frame = self._route(session_key, epoch, FT_LANES, payload)
            meta, matches = matches_from_wire(frame.payload)
            return matches, int(meta.get("overflow", 0))
        finally:
            self._finish(session_key, epoch)

    # ----------------------------------------------------------- lifecycle
    def poll_stats(self) -> dict:
        """Collect replica stats; fold per-replica rows into metrics."""
        out = {}
        for name, h in self.handles.items():
            remote = {}
            if h.alive:
                try:
                    remote = json.loads(
                        h.endpoint.call(FT_STATS, b"").payload.decode()
                    )
                except (TransportTimeout, ChannelClosed):
                    h.alive = False
                    h.failures += 1
            ch = h.endpoint.channel
            lag = {
                key: int(self.sessions[key].epoch) - int(e)
                for key, e in h.acked.items()
                if key in self.sessions
            }
            row = {
                "alive": h.alive,
                "routed": h.routed,
                "shed": h.shed,
                "failures": h.failures,
                "frames_sent": h.endpoint.frames_sent,
                "frames_retried": h.endpoint.frames_retried,
                "frames_damaged": h.endpoint.frames_damaged,
                "lane_bytes": h.lane_bytes,
                "bytes_sent": getattr(ch, "bytes_sent", 0),
                "bytes_received": getattr(ch, "bytes_received", 0),
                "replication_lag_epochs": max(lag.values(), default=0),
                "remote": remote,
            }
            out[name] = row
            if self.metrics is not None:
                self.metrics.record_replica(name, row)
        return out

    def shutdown(self) -> None:
        for h in self.handles.values():
            try:
                # no reply: the handler returning None ends the serve
                # loop without sending, so fire-and-forget
                from repro.fabric.wire import encode_frame

                h.endpoint.channel.send(
                    encode_frame(FT_SHUTDOWN, h.endpoint.next_seq(), b"")
                )
            except (ChannelClosed, OSError):
                pass
            try:
                h.endpoint.close()
            except (ChannelClosed, OSError):
                pass


# ------------------------------------------------- multi-process topology


def launch_local_cluster(names, *, timeout: float = 120.0,
                         endpoint_timeout: float = 60.0,
                         retries: int = 3):
    """Spawn one replica process per name; return (procs, endpoints).

    The coordinator listens on an ephemeral 127.0.0.1 port; each child
    (``replica.replica_main``, spawn context — safe next to jax's
    threads) connects back and announces its name in a hello frame.
    ``endpoint_timeout`` is generous by default: a replica's first
    request pays jit compilation.
    """
    import multiprocessing as mp

    ctx = mp.get_context("spawn")
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.bind(("127.0.0.1", 0))
    srv.listen(len(names))
    host, port = srv.getsockname()
    procs = []
    for name in names:
        p = ctx.Process(target=replica_main, args=(host, port, name),
                        daemon=True)
        p.start()
        procs.append(p)
    endpoints: dict[str, Endpoint] = {}
    srv.settimeout(timeout)
    try:
        for _ in names:
            conn, _addr = srv.accept()
            channel = SocketChannel(conn)
            hello = decode_frame(channel.recv(timeout=timeout))
            name = json.loads(hello.payload.decode())["replica"]
            endpoints[name] = Endpoint(
                channel, timeout=endpoint_timeout, retries=retries
            )
    finally:
        srv.close()
    if set(endpoints) != set(names):
        raise RuntimeError(
            f"cluster launch: expected replicas {sorted(names)}, "
            f"got {sorted(endpoints)}"
        )
    return procs, endpoints

"""Pluggable channels + seq-matched RPC for the serving fabric.

Channel layer
-------------
A channel moves whole frames (opaque byte strings) with an outer
little-endian u32 length prefix. Two real implementations:

* ``LoopbackChannel`` — an in-process queue pair (tests, benches).
* ``SocketChannel`` — a TCP stream (the multi-process CI topology and
  the real multi-host deployment).

Both expose the same three methods (``send``, ``recv``, ``close``), so
everything above the channel — fault handling, RPC, replication — is
transport-agnostic.

Fault injection
---------------
``FaultyChannel`` wraps any channel and perturbs *whole frames* on
send: drop, duplicate, reorder (hold one frame, emit it after the
next), truncate (cut the frame short — the outer length prefix stays
consistent with the shortened bytes, so damage is only detectable by
the frame header's redundant length + crc), and corrupt (flip one
payload byte). This models a lossy transport above a reliable stream:
the outer framing survives, the frame codec must catch the rest.

RPC layer
---------
``Endpoint`` turns a channel into a call/response port. Every call
stamps a fresh seq; the caller waits for a frame echoing that seq,
discarding strays (stale duplicates, reordered leftovers). Timeouts
and damaged frames trigger bounded retries with exponential backoff —
resending the *same seq*, so the server side can deduplicate: replica
servers cache the last response per seq and replay it instead of
re-executing, which makes retries safe even for non-idempotent
operations (applying a delta twice would corrupt the epoch chain).
"""
from __future__ import annotations

import dataclasses
import queue
import socket
import struct
import threading
import time

from repro.fabric.wire import (
    FT_ERROR,
    Frame,
    FrameError,
    decode_frame,
    encode_frame,
)

_LEN = struct.Struct("<I")


class TransportTimeout(TimeoutError):
    """No (valid) response arrived within the deadline + retry budget."""


class RemoteError(RuntimeError):
    """The remote handler failed; message carried back in an ERROR frame."""


class ChannelClosed(ConnectionError):
    """The peer closed the channel."""


class LoopbackChannel:
    """In-process channel half: one send queue, one recv queue."""

    def __init__(self, tx: "queue.Queue[bytes | None]",
                 rx: "queue.Queue[bytes | None]"):
        self._tx = tx
        self._rx = rx
        self._closed = False

    def send(self, frame: bytes) -> None:
        if self._closed:
            raise ChannelClosed("loopback channel is closed")
        # length prefix kept for symmetry with SocketChannel so fault
        # injection and byte accounting behave identically on both
        self._tx.put(_LEN.pack(len(frame)) + frame)

    def recv(self, timeout: float | None = None) -> bytes:
        if self._closed:
            raise ChannelClosed("loopback channel is closed")
        try:
            data = self._rx.get(timeout=timeout)
        except queue.Empty:
            raise TransportTimeout(
                f"loopback recv timed out after {timeout}s"
            ) from None
        if data is None:
            raise ChannelClosed("loopback peer closed")
        (n,) = _LEN.unpack_from(data)
        body = data[_LEN.size:]
        if n != len(body):
            raise FrameError(
                f"outer length prefix says {n} bytes, got {len(body)}"
            )
        return body

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self._tx.put(None)


def loopback_pair() -> tuple[LoopbackChannel, LoopbackChannel]:
    """Two connected in-process channel halves."""
    a: "queue.Queue[bytes | None]" = queue.Queue()
    b: "queue.Queue[bytes | None]" = queue.Queue()
    return LoopbackChannel(a, b), LoopbackChannel(b, a)


class SocketChannel:
    """Frame channel over a connected TCP (or AF_UNIX) stream socket."""

    def __init__(self, sock: socket.socket):
        self._sock = sock
        if sock.family in (socket.AF_INET, socket.AF_INET6):
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.bytes_sent = 0
        self.bytes_received = 0

    def send(self, frame: bytes) -> None:
        data = _LEN.pack(len(frame)) + frame
        try:
            self._sock.sendall(data)
        except (BrokenPipeError, ConnectionResetError, OSError) as exc:
            raise ChannelClosed(f"socket send failed: {exc}") from exc
        self.bytes_sent += len(data)

    def _recv_exact(self, n: int, deadline: float | None) -> bytes:
        chunks = []
        got = 0
        while got < n:
            if deadline is not None:
                left = deadline - time.monotonic()
                if left <= 0:
                    raise TransportTimeout("socket recv timed out")
                self._sock.settimeout(left)
            else:
                self._sock.settimeout(None)
            try:
                chunk = self._sock.recv(n - got)
            except socket.timeout:
                raise TransportTimeout("socket recv timed out") from None
            except (ConnectionResetError, OSError) as exc:
                raise ChannelClosed(f"socket recv failed: {exc}") from exc
            if not chunk:
                raise ChannelClosed("socket peer closed")
            chunks.append(chunk)
            got += len(chunk)
        return b"".join(chunks)

    def recv(self, timeout: float | None = None) -> bytes:
        deadline = None if timeout is None else time.monotonic() + timeout
        head = self._recv_exact(_LEN.size, deadline)
        (n,) = _LEN.unpack(head)
        body = self._recv_exact(n, deadline)
        self.bytes_received += _LEN.size + n
        return body

    def close(self) -> None:
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()


def socket_pair() -> tuple[SocketChannel, SocketChannel]:
    """Two connected TCP channel halves over 127.0.0.1 (tests/benches)."""
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    cli = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    cli.connect(srv.getsockname())
    acc, _ = srv.accept()
    srv.close()
    return SocketChannel(cli), SocketChannel(acc)


@dataclasses.dataclass
class FaultPlan:
    """Which outgoing frames to damage, and how.

    ``action`` in {"drop", "dup", "reorder", "truncate", "corrupt"};
    ``frames`` is the set of 0-based send indices to hit (every send
    through the wrapper increments the index, damaged or not).
    """

    action: str
    frames: frozenset[int] = frozenset()

    _ACTIONS = ("drop", "dup", "reorder", "truncate", "corrupt")

    def __post_init__(self):
        if self.action not in self._ACTIONS:
            raise ValueError(
                f"FaultPlan action {self.action!r} not in {self._ACTIONS}"
            )
        self.frames = frozenset(int(i) for i in self.frames)


class FaultyChannel:
    """Wrap a channel; perturb whole frames on send per ``FaultPlan``s."""

    def __init__(self, inner, plans: list[FaultPlan] | None = None):
        self._inner = inner
        self.plans = list(plans or [])
        self.sends = 0
        self.faults_injected = 0
        self._held: bytes | None = None  # reorder buffer

    def _plan_for(self, idx: int) -> FaultPlan | None:
        for p in self.plans:
            if idx in p.frames:
                return p
        return None

    def send(self, frame: bytes) -> None:
        idx = self.sends
        self.sends += 1
        plan = self._plan_for(idx)
        if plan is None:
            self._inner.send(frame)
            if self._held is not None:
                held, self._held = self._held, None
                self._inner.send(held)
            return
        self.faults_injected += 1
        if plan.action == "drop":
            return
        if plan.action == "dup":
            self._inner.send(frame)
            self._inner.send(frame)
            return
        if plan.action == "reorder":
            # hold this frame; it goes out right after the next send
            if self._held is not None:
                self._inner.send(self._held)
            self._held = frame
            return
        if plan.action == "truncate":
            # outer prefix stays consistent with the shortened bytes:
            # only the frame header's redundant length/crc can tell
            cut = max(len(frame) // 2, 1)
            self._inner.send(frame[:cut])
            return
        if plan.action == "corrupt":
            pos = len(frame) // 2
            damaged = bytearray(frame)
            damaged[pos] ^= 0xFF
            self._inner.send(bytes(damaged))
            return

    def recv(self, timeout: float | None = None) -> bytes:
        return self._inner.recv(timeout=timeout)

    def close(self) -> None:
        self._inner.close()


class Endpoint:
    """Seq-matched RPC port over a frame channel (client side).

    ``call`` retries on timeout and on damaged/unmatched responses,
    re-sending the same seq each time; pair with a server that dedupes
    by seq (``replica.ReplicaServer``) and retries become safe for
    non-idempotent operations too.
    """

    def __init__(self, channel, *, timeout: float = 10.0,
                 retries: int = 3, backoff: float = 0.05):
        self.channel = channel
        self.timeout = timeout
        self.retries = retries
        self.backoff = backoff
        self._seq = 0
        self.frames_sent = 0
        self.frames_retried = 0
        self.frames_damaged = 0
        # one in-flight call per endpoint: the channel is a single
        # stream and responses are matched by seq, so concurrent
        # callers (service verify worker + coordinator control plane)
        # must serialize here
        self._lock = threading.Lock()

    def next_seq(self) -> int:
        self._seq = (self._seq + 1) % 2**32
        return self._seq

    def call(self, ftype: int, payload: bytes,
             timeout: float | None = None) -> Frame:
        with self._lock:
            return self._call_locked(ftype, payload, timeout)

    def _call_locked(self, ftype: int, payload: bytes,
                     timeout: float | None = None) -> Frame:
        seq = self.next_seq()
        wire = encode_frame(ftype, seq, payload)
        deadline_each = self.timeout if timeout is None else timeout
        last_err: Exception | None = None
        for attempt in range(self.retries + 1):
            if attempt:
                self.frames_retried += 1
                time.sleep(self.backoff * (2 ** (attempt - 1)))
            try:
                self.channel.send(wire)
                self.frames_sent += 1
                resp = self._await(seq, deadline_each)
            except TransportTimeout as exc:
                last_err = exc
                continue
            if resp.ftype == FT_ERROR:
                raise RemoteError(resp.payload.decode("utf-8", "replace"))
            return resp
        raise TransportTimeout(
            f"no response for seq={seq} after {self.retries + 1} "
            f"attempts ({last_err})"
        )

    def _await(self, seq: int, timeout: float) -> Frame:
        deadline = time.monotonic() + timeout
        while True:
            left = deadline - time.monotonic()
            if left <= 0:
                raise TransportTimeout(f"seq={seq} timed out")
            raw = self.channel.recv(timeout=left)
            try:
                frame = decode_frame(raw)
            except FrameError:
                # damaged response: keep waiting; the send-side retry
                # loop re-asks if nothing clean arrives in time
                self.frames_damaged += 1
                continue
            if frame.seq != seq:
                # stale duplicate or reordered leftover — not ours
                continue
            return frame

    def close(self) -> None:
        self.channel.close()


def serve_frames(channel, handler, *, idle_timeout: float | None = None,
                 dedupe_depth: int = 128) -> None:
    """Server loop: decode, dedupe by seq, dispatch, reply.

    ``handler(frame) -> (ftype, payload) | None`` — ``None`` ends the
    loop (after any reply is sent the handler arranged itself).
    Damaged inbound frames are dropped silently: the client's retry
    re-sends them. Responses are cached per seq (bounded LRU of
    ``dedupe_depth``) and replayed on duplicate seqs, so a retried
    non-idempotent request executes exactly once.
    """
    seen: dict[int, tuple[int, bytes]] = {}
    order: list[int] = []
    while True:
        try:
            raw = channel.recv(timeout=idle_timeout)
        except (ChannelClosed, TransportTimeout):
            return
        try:
            frame = decode_frame(raw)
        except FrameError:
            continue
        if frame.seq in seen:
            ftype, payload = seen[frame.seq]
            channel.send(encode_frame(ftype, frame.seq, payload))
            continue
        try:
            result = handler(frame)
        except Exception as exc:  # noqa: BLE001 — surfaced to the peer
            msg = f"{type(exc).__name__}: {exc}".encode()
            channel.send(encode_frame(FT_ERROR, frame.seq, msg))
            continue
        if result is None:
            return
        ftype, payload = result
        seen[frame.seq] = (ftype, payload)
        order.append(frame.seq)
        if len(order) > dedupe_depth:
            seen.pop(order.pop(0), None)
        channel.send(encode_frame(ftype, frame.seq, payload))

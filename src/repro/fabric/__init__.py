"""Multi-host serving fabric: lane transport, replication, routing.

The distributed tier of the operator (ROADMAP item 1). Everything
before this package runs on one host; ``fabric`` moves the two things
worth moving between hosts — candidate lanes and dictionary state —
without recomputing either:

* ``wire`` — framed, versioned, crc32-guarded binary codec. One frame
  per message; payloads are the npz+JSON containers of
  ``updates.delta.pack_arrays``, so every payload carries its own
  sha256 content fingerprint on top of the frame crc.
* ``transport`` — pluggable channels (in-process loopback, TCP
  sockets), fault injection (drop/duplicate/reorder/truncate/corrupt a
  frame), and a seq-matched RPC endpoint with timeout + bounded retry
  + server-side dedupe (retries are safe even for non-idempotent
  operations like delta application).
* ``replica`` — a verify/serving replica: bootstraps from a compacted
  base snapshot, stays current by replaying serialized
  ``DictionaryDelta``s (never shipped rebuilt structures), acks the
  epoch it has applied, retains recent epochs until released.
* ``ring`` — consistent hashing on the dictionary fingerprint, with
  deterministic rebalance on membership change.
* ``cluster`` — the coordinator: epoch-agreement routing (a request
  admitted at epoch E only goes to replicas that ack >= E),
  cluster-wide admission accounting (per-replica inflight, shed on
  dead/lagging replicas, bounded retry with backoff), and the epoch
  release protocol.

Served results are bit-identical to single-host
``serving.service.one_shot_reference`` at the request's admitted epoch
— the transport moves bytes, never semantics.
"""
from repro.fabric.wire import (
    FRAME_TYPES,
    Frame,
    FrameError,
    decode_frame,
    encode_frame,
    matches_from_wire,
    matches_to_wire,
)
from repro.fabric.transport import (
    Endpoint,
    FaultPlan,
    FaultyChannel,
    LoopbackChannel,
    RemoteError,
    SocketChannel,
    TransportTimeout,
    loopback_pair,
    socket_pair,
)
from repro.fabric.ring import HashRing
from repro.fabric.replica import ReplicaServer, replica_main
from repro.fabric.cluster import ClusterCoordinator, ReplicaHandle

__all__ = [
    "ClusterCoordinator",
    "Endpoint",
    "FRAME_TYPES",
    "FaultPlan",
    "FaultyChannel",
    "Frame",
    "FrameError",
    "HashRing",
    "LoopbackChannel",
    "RemoteError",
    "ReplicaHandle",
    "ReplicaServer",
    "SocketChannel",
    "TransportTimeout",
    "decode_frame",
    "encode_frame",
    "loopback_pair",
    "matches_from_wire",
    "matches_to_wire",
    "replica_main",
    "socket_pair",
]

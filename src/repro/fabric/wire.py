"""Framed, versioned binary codec for the serving fabric.

One message = one frame:

========  ====  =====================================================
offset    size  field
========  ====  =====================================================
0         4     magic ``b"EEF1"``
4         1     wire version (currently 1)
5         1     frame type (see ``FRAME_TYPES``)
6         2     flags, little-endian u16 (reserved, must be 0)
8         4     seq, little-endian u32 (RPC correlation id)
12        4     payload length, little-endian u32
16        4     crc32, little-endian u32, over header[0:16] + payload
20        n     payload bytes
==========================================================================

The crc covers the header fields too, so a flipped type/seq/length byte
is caught, not just payload damage. Payloads are opaque here; fabric
messages use ``updates.delta.pack_arrays`` containers, which add their
own sha256 content fingerprint — belt (frame crc, catches transport
damage) and braces (payload hash, catches application-level mixups).

Decoding is strict: wrong magic, unknown version/type, nonzero reserved
flags, a length that disagrees with the bytes in hand, or a crc
mismatch each raise ``FrameError``. A damaged frame never decodes into
a plausible message — callers retry or surface, per
``transport.Endpoint``.
"""
from __future__ import annotations

import dataclasses
import struct
import zlib

import numpy as np

MAGIC = b"EEF1"
WIRE_VERSION = 1
HEADER = struct.Struct("<4sBBHIII")  # magic, ver, ftype, flags, seq, len, crc
HEADER_SIZE = HEADER.size  # 20

# Frame types. Requests flow coordinator -> replica; MATCHES / ACK /
# ERROR / STATS flow back. LANES carries the probe->verify shard_lane
# handoff when the verify pool is remote.
FT_SNAPSHOT = 1   # DictionaryVersion bootstrap payload
FT_DELTA = 2      # serialized DictionaryDelta + forced maintenance action
FT_ACK = 3        # replica ack: {applied epoch, session}
FT_REQUEST = 4    # full extraction request (docs) at a pinned epoch
FT_MATCHES = 5    # extraction result arrays
FT_LANES = 6      # shard_lane wire unit (probe->verify handoff)
FT_RELEASE = 7    # coordinator: epoch E fully drained, replica may GC
FT_ERROR = 8      # remote failure, payload = utf-8 message
FT_STATS = 9      # replica metrics snapshot
FT_SHUTDOWN = 10  # orderly replica shutdown

FRAME_TYPES = {
    FT_SNAPSHOT: "SNAPSHOT",
    FT_DELTA: "DELTA",
    FT_ACK: "ACK",
    FT_REQUEST: "REQUEST",
    FT_MATCHES: "MATCHES",
    FT_LANES: "LANES",
    FT_RELEASE: "RELEASE",
    FT_ERROR: "ERROR",
    FT_STATS: "STATS",
    FT_SHUTDOWN: "SHUTDOWN",
}


class FrameError(ValueError):
    """A frame failed structural or integrity validation."""


@dataclasses.dataclass(frozen=True)
class Frame:
    ftype: int
    seq: int
    payload: bytes

    @property
    def type_name(self) -> str:
        return FRAME_TYPES.get(self.ftype, f"?{self.ftype}")


def encode_frame(ftype: int, seq: int, payload: bytes) -> bytes:
    """Serialize one frame; validates type and seq range up front."""
    if ftype not in FRAME_TYPES:
        raise FrameError(f"encode_frame: unknown frame type {ftype}")
    if not 0 <= seq < 2**32:
        raise FrameError(f"encode_frame: seq {seq} out of u32 range")
    head = HEADER.pack(MAGIC, WIRE_VERSION, ftype, 0, seq, len(payload), 0)
    crc = zlib.crc32(head[:16] + payload) & 0xFFFFFFFF
    return HEADER.pack(
        MAGIC, WIRE_VERSION, ftype, 0, seq, len(payload), crc
    ) + payload


def decode_frame(data: bytes) -> Frame:
    """Parse + verify one frame; raises ``FrameError`` on any damage."""
    if len(data) < HEADER_SIZE:
        raise FrameError(
            f"frame truncated: {len(data)} bytes < {HEADER_SIZE}-byte header"
        )
    magic, ver, ftype, flags, seq, plen, crc = HEADER.unpack_from(data)
    if magic != MAGIC:
        raise FrameError(f"bad magic {magic!r} (want {MAGIC!r})")
    if ver != WIRE_VERSION:
        raise FrameError(f"unsupported wire version {ver}")
    if ftype not in FRAME_TYPES:
        raise FrameError(f"unknown frame type {ftype}")
    if flags != 0:
        raise FrameError(f"reserved flags set: {flags:#06x}")
    if len(data) != HEADER_SIZE + plen:
        raise FrameError(
            f"length mismatch: header says {plen} payload bytes, "
            f"frame has {len(data) - HEADER_SIZE}"
        )
    payload = data[HEADER_SIZE:]
    want = zlib.crc32(data[:16] + payload) & 0xFFFFFFFF
    if crc != want:
        raise FrameError(
            f"crc mismatch on {FRAME_TYPES[ftype]} seq={seq}: "
            f"frame carries {crc:#010x}, computed {want:#010x}"
        )
    return Frame(ftype=ftype, seq=seq, payload=bytes(payload))


# --------------------------------------------------------------------------
# Matches payload: the result arrays of ``extraction.results.Matches``
# round-tripped through the npz container. ``count`` rides along so
# capacity-overflow reporting survives the wire.
# --------------------------------------------------------------------------


def matches_to_wire(matches, meta: dict | None = None) -> bytes:
    """Encode a ``Matches`` batch (host arrays) for the wire."""
    from repro.updates.delta import pack_arrays

    m = dict(meta or {})
    m["kind"] = "matches"
    return pack_arrays(
        m,
        {
            "doc": np.asarray(matches.doc, dtype=np.int32),
            "pos": np.asarray(matches.pos, dtype=np.int32),
            "length": np.asarray(matches.length, dtype=np.int32),
            "entity": np.asarray(matches.entity, dtype=np.int32),
            "score": np.asarray(matches.score, dtype=np.float32),
            "count": np.asarray(matches.count, dtype=np.int32),
        },
    )


def matches_from_wire(data: bytes):
    """Decode a matches payload -> (meta, Matches of numpy arrays)."""
    from repro.extraction.results import Matches
    from repro.updates.delta import unpack_arrays

    meta, arrays = unpack_arrays(data)
    if meta.get("kind") != "matches":
        raise FrameError(
            f"matches_from_wire: payload kind {meta.get('kind')!r}"
        )
    return meta, Matches(
        doc=arrays["doc"],
        pos=arrays["pos"],
        length=arrays["length"],
        entity=arrays["entity"],
        score=arrays["score"],
        count=arrays["count"],
    )

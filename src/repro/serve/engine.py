"""Batched serving engine: fixed-slot continuous batching over the
model's decode_step, with greedy/temperature sampling.

Slots hold independent requests; finished slots are refilled from the
queue each step (continuous batching-lite). The decode step itself is a
single jitted call over the whole slot batch — one program regardless of
request mix — with per-slot position masking, which is what keeps the
engine shape-static and dry-runnable on the production mesh.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.model import LM


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 16
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(
        self,
        model: LM,
        params,
        batch_slots: int = 8,
        max_len: int = 256,
        kv_splits: int = 1,
        temperature: float = 0.0,
        seed: int = 0,
    ):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.temperature = temperature
        self.cache = model.init_cache(params, batch_slots, max_len, kv_splits)
        self._step = jax.jit(model.decode_step)
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * batch_slots
        # per-slot progress: index of the next prompt token to feed (-1 idle)
        self._feed = np.full((batch_slots,), -1, dtype=np.int64)
        self._rng = jax.random.PRNGKey(seed)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _reset_slot_cache(self, slot: int) -> None:
        """Invalidate a slot's KV/state so a refilled request never
        attends to the previous occupant's cache entries."""

        def reset(tree, batch_dim: int):
            def one(path, arr):
                name = str(getattr(path[-1], "key", ""))
                if name in ("kvpos", "ckpos"):
                    idx = (slice(None),) * batch_dim + (slot,)
                    return arr.at[idx].set(-1)
                if name in ("k", "v", "C", "n", "h", "c", "conv"):
                    idx = (slice(None),) * batch_dim + (slot,)
                    return arr.at[idx].set(0)
                return arr

            return jax.tree_util.tree_map_with_path(one, tree)

        self.cache = dict(
            self.cache,
            layers=reset(self.cache["layers"], 1),  # [G, B, ...]
            tail=reset(self.cache["tail"], 0),  # [B, ...]
        )

    def _fill_slots(self) -> None:
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                self.active[s] = self.queue.popleft()
                self._feed[s] = 0
                self._reset_slot_cache(s)

    def step(self) -> int:
        """One global decode step across all slots; returns #active."""
        self._fill_slots()
        tokens = np.zeros((self.slots,), dtype=np.int32)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            if self._feed[s] < len(req.prompt):  # still feeding the prompt
                tokens[s] = req.prompt[self._feed[s]]
            else:
                tokens[s] = req.out[-1] if req.out else req.prompt[-1]
        logits, self.cache = self._step(self.params, self.cache, jnp.asarray(tokens))
        if self.temperature > 0:
            self._rng, k = jax.random.split(self._rng)
            nxt = jax.random.categorical(k, logits / self.temperature, axis=-1)
        else:
            nxt = logits.argmax(axis=-1)
        nxt = np.asarray(nxt)
        n_active = 0
        for s, req in enumerate(self.active):
            if req is None:
                continue
            n_active += 1
            if self._feed[s] < len(req.prompt) - 1:
                self._feed[s] += 1  # prompt not exhausted: discard logits
                continue
            self._feed[s] += 1
            req.out.append(int(nxt[s]))
            if len(req.out) >= req.max_new_tokens:
                req.done = True
                self.active[s] = None
                self._feed[s] = -1
        return n_active

    def run(self, max_steps: int = 10_000) -> None:
        """Drain the queue (shared cache position: single stream window)."""
        for _ in range(max_steps):
            if not any(self.active) and not self.queue:
                break
            if int(self.cache["pos"]) >= self.max_len:
                break
            self.step()

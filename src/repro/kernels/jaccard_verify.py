"""Pallas TPU kernel: batched weighted Jaccard-containment verification.

The verify step is the per-record hot loop of both EE-Join algorithms
(Def. 3's post-lookup check, Def. 4's reducer verify): for each candidate
window and each of its K candidate entities, compute

    score = w(e ∩ s) / w(e)        (mode "extra")
          = w(e ∩ s) / w(s)        (mode "missing")

over PAD(0)-padded token rows. Token weights are pre-gathered outside
the kernel (the [V] weight table stays in HBM; the kernel sees only
dense per-row tiles), so the kernel body is a pure VPU compare/reduce:

    eq[n,k,i,j] = ent_tokens[n,k,i] == win_tokens[n,j]   (L x L compare)
    inter[n,k]  = Σ_i ent_w[n,k,i] * any_j eq[n,k,i,j]

Tiling: grid over (N/Bn, K/Bk); each step holds
  win  [Bn, L] i32 + [Bn, L] f32
  ent  [Bn, Bk, L] i32 + f32
  out  [Bn, Bk] f32
in VMEM — ~0.6 MB at (Bn=128, Bk=128, L=8), far under the ~16 MB budget,
leaving headroom for double buffering. L is the static max entity length
(4–16), padded to the tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BN = 128
DEFAULT_BK = 128


def _kernel(win_t_ref, win_w_ref, ent_t_ref, ent_w_ref, out_ref, *, mode: str):
    win_t = win_t_ref[...]  # [Bn, L]
    win_w = win_w_ref[...]
    ent_t = ent_t_ref[...]  # [Bn, Bk, L]
    ent_w = ent_w_ref[...]

    eq = ent_t[:, :, :, None] == win_t[:, None, None, :]  # [Bn,Bk,L,L]
    both = eq & (ent_t[:, :, :, None] != 0) & (win_t[:, None, None, :] != 0)
    hit = both.any(axis=-1)
    inter = (ent_w * hit.astype(ent_w.dtype)).sum(axis=-1)  # [Bn,Bk]
    ws = win_w.sum(axis=-1)[:, None]
    if mode == "extra":
        denom = ent_w.sum(axis=-1)
    else:  # missing
        denom = jnp.broadcast_to(ws, inter.shape)
    score = inter / jnp.maximum(denom, 1e-30)
    out_ref[...] = jnp.where(ws > 0, score, 0.0).astype(jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("mode", "bn", "bk", "interpret")
)
def jaccard_verify_pallas(
    win_tokens,  # [N, L] i32
    win_w,  # [N, L] f32
    ent_tokens,  # [N, K, L] i32
    ent_w,  # [N, K, L] f32
    mode: str = "extra",
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
):
    N, L = win_tokens.shape
    K = ent_tokens.shape[1]
    bn = min(bn, N)
    bk = min(bk, K)
    # pad to tile multiples (PAD tokens give zero scores)
    Np = -(-N // bn) * bn
    Kp = -(-K // bk) * bk
    if (Np, Kp) != (N, K):
        win_tokens = jnp.pad(win_tokens, ((0, Np - N), (0, 0)))
        win_w = jnp.pad(win_w, ((0, Np - N), (0, 0)))
        ent_tokens = jnp.pad(ent_tokens, ((0, Np - N), (0, Kp - K), (0, 0)))
        ent_w = jnp.pad(ent_w, ((0, Np - N), (0, Kp - K), (0, 0)))

    grid = (Np // bn, Kp // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, L), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, L), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, bk, L), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bn, bk, L), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Np, Kp), jnp.float32),
        interpret=interpret,
    )(win_tokens, win_w, ent_tokens, ent_w)
    return out[:N, :K]

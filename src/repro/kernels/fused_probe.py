"""Pallas TPU megakernel: fused ISH-filter probe + window signatures.

This fuses the whole map-side candidate front end — validity, Bloom
survival, and (for the LSH scheme) per-window MinHash band signatures —
into ONE ``pallas_call`` that streams each ``[Bd, T]`` document tile
HBM->VMEM exactly once. The unfused pipeline runs three jitted passes
(``window_base`` -> ``token_in_filter`` -> ``window_signatures``) and
round-trips the ``L``-times-expanded ``[D, T, L]`` base / survival
tensors through HBM between them; here every per-window quantity is a
*running* recurrence over one in-register token stream:

    real[t]          = tok[t] != PAD
    hit[t]           = all k Bloom probes of tok[t] set   (bitmap VMEM-resident)
    valid[t, l]      = AND(real[t .. t+l])                (running-and)
    survive[t, l]    = valid[t, l] & OR(hit[t .. t+l])    (running-or)
    rmin_i[t, l]     = MIN(h_i(tok[t .. t+l]))            (running-min, i < B*R)
    sig[t, l, b]     = combine(rmin_{bR} .. rmin_{bR+R-1}, b+1)
    dup[t, l]        = OR(tok[t+l] == tok[t .. t+l-1])    (shifted compares)
    fold_i[t, l]     = (SUM, XOR, COUNT) of h_i(tok[t+j]) over the
                       first-occurrence positions j <= l  (running fold)
    key_i[t, l]      = mix(sum ^ xor*C1 ^ cnt*GOLDEN)     (set_hash finalise)

The survival mask is emitted *packed*: bit ``l`` of ``packed[d, t]``
(uint32, so L <= 32) is ``survive[d, t, l]`` — a 4 B/token store instead
of the unfused path's L B/token int8 mask and 4L B/token int32 base.
Band signatures are bit-identical to ``core.signatures.window_signatures``
for the ``lsh`` scheme: MinHash minima are duplicate-insensitive, so the
first-occurrence masking the jnp path applies never changes a row
minimum, and the seeds / murmur3 finaliser / combine below match
``core.hashing`` exactly.

The ``variant`` scheme (paper Definition 2) is fused the same way:
``core.hashing.set_hash`` is a commutative (sum, xor, count) fold over
per-token hashes, so both 32-bit variant keys extend token by token —
the only obstacle to streaming is first-occurrence masking, which the
kernel makes streamable with a *register-resident duplicate mask*:
token ``t+l`` is a duplicate inside window ``[t, t+l]`` iff it equals
any of ``tok[t .. t+l-1]``, i.e. iff the current shifted token stream
matches any of the <= 31 previously shifted streams (all VMEM/register
resident, no HBM traffic). Masked contributions then feed the running
fold, and the finalised keys are bit-identical to
``core.variants.window_variant_key`` at every (pos, len) — including
PAD-heavy and all-duplicate windows (see ``streaming_first_occurrence``
for the host-testable reference of the mask). With the compaction
epilogue on, the keys are not stored densely: they ride the candidate
lanes as a tiny ``[G, NC, 2]`` payload gathered at the surviving flat
indices.

HBM-traffic accounting (per document token; L = max_len, K = num_hashes,
B = bands; see ``hbm_bytes_unfused`` / ``hbm_bytes_fused``):

    unfused  read 4 (docs) + write 4L (base) + read 4L (filter probe)
             + write L (int8 mask) + read L (compaction scan)
    fused    read 4 (docs) + write 4 (packed bitmap)
             [+ write 4LB (band sigs, lsh mode only)]
             [+ G*(1+W)*4 lane ints + G*W*8 variant-key payload,
                epilogue mode; W = NC one-pass, measured two-pass]

For the filter stages alone that is a ~(10L+4)/8 ≈ 10x traffic cut at
L = 8; the kernel additionally hashes each token K times instead of the
unfused path's K*L times (the [D,T,L] base repeats every token L times).
Downstream, the engine's fused compaction gathers candidate windows
straight from the [D, T] token array — ``window_base`` is never
materialised (see ``extraction.engine.fused_filter_compact``).

With ``candidates > 0`` the kernel also runs a *compaction epilogue*:
the per-tile survivor count is accumulated in registers as the length
recurrence runs, and the tile's first ``candidates``
surviving (doc, pos, len) triples are rank-compacted (prefix-sum over
the register-resident bit expansion) into an ascending [G, candidates]
flat-index lane. Candidate selection then reads only these lanes — the
last XLA pass over the full [D, T] bitmap (cumsum + searchsorted in
``extraction.results.select_nonzero``) disappears, which matters because
candidate-generation traffic, not verification, dominates at scale.

The lane width is *decoupled* from the candidate capacity: a one-pass
emit must keep ``candidates = NC`` wide lanes for bit parity (the
global first-NC could all land in one tile), but an **adaptive
two-pass** run first streams a ``count_only=True`` pass (per-tile
counts, no lane store), sizes the emit pass's lane width to the
measured per-tile survivor maximum (``round_lane_width``), and re-runs
with ``candidates = W << NC`` — every tile's lane then holds *all* of
its survivors, so the ``select_from_tiles`` merge stays bit-identical
while lane traffic drops from ``G*(1+NC)`` to ``G*(1+W)`` ints. Both
passes share the NC-derived tile height (``compact_tile_height``) so
their grids — and therefore the per-tile counts — line up exactly.

Tiling: one full document row per grid row ([Bd, T] tiles) so windows
never straddle a tile edge; the Bloom bitmap block is grid-invariant
(loaded once, reused across steps). Validated in interpret mode on CPU;
on TPU the bitmap gather uses dynamic VMEM indexing (minor-dim gather,
Mosaic v4+).

**Streaming mode** (``fused_probe_stream_pallas``): the per-tile grid
itself becomes an in-kernel loop. The doc array stays in HBM
(``memory_space=ANY``) and the kernel double-buffers [bd, T] chunks
through a 2-slot VMEM buffer with ``make_async_copy`` — the DMA for
chunk g+1 is started before chunk g's recurrence runs, so one launch
consumes an entire shard with copy-in overlapped against compute. The
recurrence and lane epilogue are the *same functions* the grid kernel
runs (``_probe_recurrence`` / ``_emit_lane``), so streamed outputs are
bit-identical to the per-tile launch loop; only the packed bitmap and
dense signature tensors are dropped (they are exactly the per-launch
HBM round trips streaming exists to elide — see ``hbm_bytes_fused``
with ``streamed=True``).
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import hashing
from repro.core.filter import _BLOOM_SEED_BASE  # single source of truth
from repro.core.hashing import _C1, _GOLDEN
from repro.core.signatures import _LSH_SEED_BASE
from repro.core.variants import VARIANT_SEEDS
from repro.kernels._hashing import combine as _combine
from repro.kernels._hashing import hash_seeded as _hash
from repro.kernels._hashing import mix as _mix

_MAX_U32 = 0xFFFFFFFF

DEFAULT_BD = 8

#: smallest adaptive emit-pass lane width: keeps the lane store aligned
#: and bounds recompiles (widths are rounded up to powers of two).
MIN_LANE_WIDTH = 8


def compact_tile_height(D: int, T: int, candidates: int) -> int:
    """Doc-tile height for the compaction epilogue.

    Each grid tile emits a full-width [1 + candidates] lane (parity
    requires it — the global first-NC could all land in one tile), so
    lane traffic is G * (1 + NC) * 8 B and only stays well under the
    bitmap bytes it replaces when bd >= 4 * NC / T. Single source of
    truth for ``ops.fused_probe_compact`` and ``hbm_bytes_fused``.

    Adaptive two-pass runs keep this NC-derived height for *both* the
    count pass and the narrower emit pass: the emit width W is chosen
    from the count pass's per-tile maxima, which is only sound if the
    two grids tile the documents identically. The narrower lanes then
    undercut even this conservative geometry (G*(1+W) vs G*(1+NC)
    ints); see ``hbm_bytes_fused(two_pass=True)`` for the full trade.
    """
    return min(max(DEFAULT_BD, -(-4 * candidates // max(T, 1))), max(D, 1))


def round_lane_width(max_count: int, cap: int,
                     floor: int = MIN_LANE_WIDTH) -> int:
    """Adaptive emit-pass lane width for a measured per-tile maximum.

    Rounds the measured per-tile survivor maximum up to a power of two
    (>= ``floor``) so repeated runs at similar densities reuse the same
    compiled kernel, and caps at ``cap`` (= NC: wider lanes than the
    merge capacity are never read). Any W >= max_count keeps the merge
    exact — every tile's lane holds all of its survivors.
    """
    w = max(int(max_count), int(floor), 1)
    w = 1 << (w - 1).bit_length()
    return max(min(w, int(cap)), 1)


SIG_MODE_NONE = "none"
SIG_MODE_LSH = "lsh"
SIG_MODE_VARIANT = "variant"


def streaming_first_occurrence(tokens, *, xp=np):
    """First-occurrence mask via the kernel's shifted-compare recurrence.

    Host-testable reference of the in-kernel duplicate mask: position
    ``j`` of each padded window row is marked iff it is real (non-PAD)
    and equals none of positions ``0 .. j-1`` — exactly the <= L-1
    shifted compares the kernel performs against its previously shifted
    token streams. Bit-identical to
    ``core.semantics.first_occurrence_mask`` (property-tested); kept
    next to the kernel so the trick has a readable, testable form.
    """
    L = tokens.shape[-1]
    dup = xp.zeros(tokens.shape, dtype=bool)
    for j in range(1, L):
        hit = xp.zeros(tokens.shape[:-1], dtype=bool)
        for i in range(j):
            hit = hit | (tokens[..., i] == tokens[..., j])
        if xp is np:
            dup[..., j] = hit
        else:
            dup = dup.at[..., j].set(hit)
    return (tokens != 0) & ~dup  # PAD == 0


def empty_band_sigs(bands: int, rows: int) -> np.ndarray:
    """[bands] uint32: the band signatures of an all-invalid window.

    Matches ``signatures._minhash_np`` on a row with no valid tokens
    (every row-minimum is 0xFFFFFFFF). Used by the engine to pad
    non-surviving candidate slots so the fused signature tensor is
    bit-identical to ``window_signatures`` on PAD-only windows too.
    """
    row = np.full((1,), _MAX_U32, dtype=np.uint32)
    out = []
    for b in range(bands):
        band = row
        for _ in range(1, rows):
            band = hashing.combine(band, row, xp=np)
        band = hashing.combine(band, np.full((1,), b + 1, dtype=np.uint32), xp=np)
        out.append(band[0])
    return np.array(out, dtype=np.uint32)


def _probe_recurrence(
    docs,
    bits,
    *,
    num_bits: int,
    num_hashes: int,
    max_len: int,
    bands: int,
    rows: int,
    use_filter: bool,
    sig_mode: str,
    sig_store=None,
):
    """The filter -> signature recurrence over one [Bd, T] doc tile.

    Pure function of the tile's token block (plus the VMEM-resident
    Bloom words): runs the validity/survival/signature recurrences
    documented in the module docstring and returns ``(pack, count,
    k1_flat, k2_flat)`` — the packed survival bitmap, the tile's true
    survivor total, and (variant mode) the [Bd*T*L] flattened finalised
    key streams (``None`` otherwise). ``sig_store(l, values)`` is the
    dense-emission hook: called once per window length with the band
    sigs (lsh) or key pair (variant) so the grid-mode kernel can store
    them without the streaming kernel paying for a dense tensor.

    Shared verbatim by the per-tile grid kernel (``_kernel``) and the
    in-kernel DMA streaming kernel (``_stream_kernel``) so the two are
    bit-identical by construction.
    """
    Bd, T = docs.shape
    real = docs != 0  # PAD == 0

    if use_filter:
        hit = jnp.ones(docs.shape, bool)
        for k in range(num_hashes):
            h = _hash(docs, _BLOOM_SEED_BASE + k)
            pos = h % jnp.uint32(num_bits)
            word = bits[(pos // 32).astype(jnp.int32)]  # VMEM gather
            bit = (word >> (pos % 32)) & jnp.uint32(1)
            hit = hit & (bit == 1)
    else:
        hit = real  # survival degenerates to validity

    lsh = sig_mode == SIG_MODE_LSH
    var = sig_mode == SIG_MODE_VARIANT
    if lsh:
        # per-token row hashes, invalid -> MAX so they never win a min
        hv = [
            jnp.where(real, _hash(docs, _LSH_SEED_BASE + i), jnp.uint32(_MAX_U32))
            for i in range(bands * rows)
        ]
        rmin = [jnp.full(docs.shape, _MAX_U32, dtype=jnp.uint32) for _ in hv]
    if var:
        # variant set-hash recurrence: per-window running (sum, xor,
        # count) folds for both 32-bit keys; first-occurrence masking is
        # streamed via the duplicate mask below (shifted compares
        # against the previously shifted token streams — all register
        # resident), bit-identical to core.variants.window_variant_key.
        zero = jnp.zeros(docs.shape, dtype=jnp.uint32)
        vs1, vx1, vs2, vx2, vcnt = zero, zero, zero, zero, zero
        prev_toks: list = []  # token streams shifted by 0 .. l-1
        vkeys1: list = []  # per-length finalised keys (lane/dense store)
        vkeys2: list = []

    vand = jnp.ones(docs.shape, bool)
    vor = jnp.zeros(docs.shape, bool)
    pack = jnp.zeros(docs.shape, dtype=jnp.uint32)
    count = jnp.int32(0)
    sh_real, sh_hit = real, hit
    sh_hv = list(hv) if lsh else []
    sh_tok = docs if var else None
    zero_row = jnp.zeros((Bd, 1), bool)
    max_row = jnp.full((Bd, 1), _MAX_U32, dtype=jnp.uint32)
    pad_row = jnp.zeros((Bd, 1), dtype=docs.dtype)
    for l in range(max_len):
        vand = vand & sh_real
        vor = vor | sh_hit
        surv = vand & vor
        pack = pack | (surv.astype(jnp.uint32) << jnp.uint32(l))
        # per-tile survivor count, accumulated as the length recurrence
        # runs (feeds the compaction epilogue / sizing pass)
        count = count + surv.sum().astype(jnp.int32)
        if lsh:
            for i in range(bands * rows):
                rmin[i] = jnp.minimum(rmin[i], sh_hv[i])
            bands_l = []
            for b in range(bands):
                band = rmin[b * rows]
                for r in range(1, rows):
                    band = _combine(band, rmin[b * rows + r])
                band = _combine(band, jnp.full_like(band, jnp.uint32(b + 1)))
                bands_l.append(band)
            if sig_store is not None:
                sig_store(l, bands_l)
        if var:
            # duplicate mask: tok[t+l] repeats inside [t, t+l] iff the
            # current shifted stream equals any earlier shifted stream
            # (PAD-vs-PAD hits are masked out by sh_real below)
            dup = jnp.zeros(docs.shape, bool)
            for pv in prev_toks:
                dup = dup | (pv == sh_tok)
            contrib = sh_real & ~dup  # == first_occurrence_mask position
            h1 = jnp.where(contrib, _hash(sh_tok, VARIANT_SEEDS[0]),
                           jnp.uint32(0))
            h2 = jnp.where(contrib, _hash(sh_tok, VARIANT_SEEDS[1]),
                           jnp.uint32(0))
            vs1, vx1 = vs1 + h1, vx1 ^ h1
            vs2, vx2 = vs2 + h2, vx2 ^ h2
            vcnt = vcnt + contrib.astype(jnp.uint32)
            # set_hash finalise (core.hashing.set_hash, bit-identical)
            fin = vcnt * jnp.uint32(_GOLDEN)
            k1 = _mix(vs1 ^ (vx1 * jnp.uint32(_C1)) ^ fin)
            k2 = _mix(vs2 ^ (vx2 * jnp.uint32(_C1)) ^ fin)
            vkeys1.append(k1)
            vkeys2.append(k2)
            if sig_store is not None:
                sig_store(l, [k1, k2])
            prev_toks.append(sh_tok)
        if l + 1 < max_len:
            sh_real = jnp.concatenate([sh_real[:, 1:], zero_row], axis=1)
            sh_hit = jnp.concatenate([sh_hit[:, 1:], zero_row], axis=1)
            if lsh:
                sh_hv = [
                    jnp.concatenate([v[:, 1:], max_row], axis=1) for v in sh_hv
                ]
            if var:
                sh_tok = jnp.concatenate([sh_tok[:, 1:], pad_row], axis=1)
    k1_flat = jnp.stack(vkeys1, axis=-1).reshape(-1) if var else None
    k2_flat = jnp.stack(vkeys2, axis=-1).reshape(-1) if var else None
    return pack, count, k1_flat, k2_flat


def _emit_lane(pack, count, cand_cap: int, max_len: int):
    """Compaction epilogue selection: first ``cand_cap`` survivors.

    Two-stage (word -> bit) selection, sort- and scatter-free ("the
    k-th survivor lives where the prefix sum first reaches k"):
    survivor density is low, so first pick the <= cand_cap tokens with
    any surviving length (the first cand_cap set bits always live
    inside the first cand_cap nonzero words), then rank only their
    unpacked bits. Returns ``(flat, ok)``: the tile-local flat
    (row*T + pos)*L + (len-1) indices of the tile's first ``cand_cap``
    survivors and their validity lane — everything VMEM-resident, so
    the [D, T] bitmap is never re-read from HBM to compact it.
    """
    Bd, T = pack.shape
    L = max_len
    lane = jax.lax.iota(jnp.int32, cand_cap)  # iota: no captured consts
    nz = (pack != 0).reshape(-1)  # [Bd*T]
    cw = jnp.cumsum(nz.astype(jnp.int32))
    wk = jnp.searchsorted(cw, lane + 1, side="left").astype(jnp.int32)
    wok = lane < jnp.minimum(cw[-1], cand_cap)
    words = pack.reshape(-1)[jnp.minimum(wk, Bd * T - 1)]
    words = words * wok.astype(jnp.uint32)  # [cand_cap] u32
    sub = ((words[:, None] >> jax.lax.iota(jnp.uint32, L))
           & jnp.uint32(1)) != 0  # [cand_cap, L]
    cb = jnp.cumsum(sub.reshape(-1).astype(jnp.int32))
    k = jnp.searchsorted(cb, lane + 1, side="left").astype(jnp.int32)
    ok = lane < jnp.minimum(count, cand_cap)
    flat = jnp.minimum(wk[jnp.minimum(k // L, cand_cap - 1)],
                       Bd * T - 1) * L + k % L
    return flat, ok


def _gather_lane_keys(k1_flat, k2_flat, flat, ok, span: int):
    """Gather both finalised variant keys at the selected flat indices.

    The dense [Bd, T, L, 2] tensor never leaves registers/VMEM, only
    the [cand_cap, 2] payload is stored. Padded slots carry 0, the
    set_hash of the empty window (bit-parity with window_variant_key
    on all-PAD windows).
    """
    sel = jnp.clip(flat, 0, span - 1)
    return (jnp.where(ok, k1_flat[sel], jnp.uint32(0)),
            jnp.where(ok, k2_flat[sel], jnp.uint32(0)))


def _kernel(
    doc_ref,
    bits_ref,
    packed_ref,
    *rest_refs,
    num_bits: int,
    num_hashes: int,
    max_len: int,
    bands: int,
    rows: int,
    use_filter: bool,
    sig_mode: str,
    dense_sigs: bool,
    count_tiles: bool,
    cand_cap: int,
):
    # ref layout after packed_ref:
    #   [sig_ref] [count_ref] [cand_ref [vkey_ref]]
    refs = list(rest_refs)
    sig_ref = refs.pop(0) if dense_sigs else None
    count_ref = refs.pop(0) if count_tiles else None
    cand_ref = refs.pop(0) if cand_cap else None
    var = sig_mode == SIG_MODE_VARIANT
    vkey_ref = refs.pop(0) if (var and cand_cap) else None
    docs = doc_ref[...]  # [Bd, T] int32
    Bd, T = docs.shape

    def sig_store(l, vals):
        for i, v in enumerate(vals):
            sig_ref[:, :, l, i] = v

    pack, count, k1_flat, k2_flat = _probe_recurrence(
        docs,
        bits_ref[...] if use_filter else None,
        num_bits=num_bits,
        num_hashes=num_hashes,
        max_len=max_len,
        bands=bands,
        rows=rows,
        use_filter=use_filter,
        sig_mode=sig_mode,
        sig_store=sig_store if dense_sigs else None,
    )
    packed_ref[...] = pack
    if count_tiles:
        count_ref[0] = count
    if cand_cap:
        # compaction epilogue: emit the tile's surviving (doc, pos, len)
        # triples as ascending *global* flat indices, packed to the
        # front of a fixed [cand_cap] lane.
        L = max_len
        flat, ok = _emit_lane(pack, count, cand_cap, L)
        cand_ref[0, :] = jnp.where(
            ok, pl.program_id(0) * Bd * T * L + flat, -1
        )
        if var:
            # variant keys ride the lane, gathered at the selection
            k1, k2 = _gather_lane_keys(k1_flat, k2_flat, flat, ok, Bd * T * L)
            vkey_ref[0, :, 0] = k1
            vkey_ref[0, :, 1] = k2


@functools.partial(
    jax.jit,
    static_argnames=(
        "num_bits",
        "num_hashes",
        "max_len",
        "sig_mode",
        "bands",
        "rows",
        "use_filter",
        "bd",
        "candidates",
        "count_only",
        "interpret",
    ),
)
def fused_probe_pallas(
    doc_tokens,  # [D, T] i32
    bits,  # [num_bits // 32] uint32 (ignored when use_filter=False)
    num_bits: int,
    num_hashes: int,
    max_len: int,
    sig_mode: str = SIG_MODE_NONE,
    bands: int = 4,
    rows: int = 2,
    use_filter: bool = True,
    bd: int = DEFAULT_BD,
    candidates: int = 0,
    count_only: bool = False,
    interpret: bool = True,
):
    """One-pass filter+signature probe with optional compaction epilogue.

    Returns ``(packed, sigs, counts, cands, vkeys)``:

    * ``packed`` [D, T] uint32 with bit ``l`` = survive(pos, len=l+1)
      (validity AND Bloom survival; validity only when
      ``use_filter=False``);
    * ``sigs`` — [D, T, max_len, bands] uint32 MinHash band signatures
      when ``sig_mode == "lsh"``; [D, T, max_len, 2] uint32 variant key
      pairs when ``sig_mode == "variant"`` *without* the epilogue
      (dense mode); else ``None``;
    * with ``candidates > 0``, the in-kernel compaction epilogue:
      ``counts`` [G] int32 holds each grid tile's true survivor count
      (scratch-accumulated; may exceed ``candidates``) and ``cands``
      [G, candidates] int32 the tile's first ``candidates`` survivors as
      ascending global flat (doc*T + pos)*max_len + (len-1) indices, -1
      padded — downstream compaction reads these tiny per-tile lanes and
      never re-reads the [D, T] bitmap (see
      ``extraction.results.select_from_tiles``). ``candidates`` is the
      *lane width*: callers shrink it below the merge capacity after a
      count pass (adaptive two-pass; see ``round_lane_width``);
    * ``vkeys`` [G, candidates, 2] uint32 — the variant key pairs of
      each lane slot (``sig_mode == "variant"`` with the epilogue; the
      dense ``sigs`` tensor is *not* emitted then), 0 in padded slots;
    * ``count_only=True`` (with ``candidates > 0``) emits ``counts``
      but skips the lane (and key) stores — the cheap sizing pass of
      the adaptive two-pass scheme. ``candidates`` then only sets the
      tile geometry via the caller's ``bd`` choice.
    """
    assert max_len <= 32, "packed survival bitmap holds at most 32 lengths"
    assert candidates or not count_only, "count_only needs candidates > 0"
    assert not (count_only and sig_mode != SIG_MODE_NONE), (
        "count_only is the sizing pass: signatures belong to the emit pass"
    )
    D, T = doc_tokens.shape
    bd = min(bd, D)
    Dp = -(-D // bd) * bd
    G = Dp // bd
    if Dp != D:
        doc_tokens = jnp.pad(doc_tokens, ((0, Dp - D), (0, 0)))
    count_tiles = candidates > 0
    cand_cap = 0 if count_only else candidates
    dense_sigs = sig_mode == SIG_MODE_LSH or (
        sig_mode == SIG_MODE_VARIANT and not cand_cap
    )
    sig_depth = {SIG_MODE_LSH: bands, SIG_MODE_VARIANT: 2}

    out_shape = [jax.ShapeDtypeStruct((Dp, T), jnp.uint32)]
    out_specs = [pl.BlockSpec((bd, T), lambda i: (i, 0))]
    if sig_mode not in (SIG_MODE_NONE, SIG_MODE_LSH, SIG_MODE_VARIANT):
        raise ValueError(f"unknown sig_mode {sig_mode!r}")
    if dense_sigs:
        S = sig_depth[sig_mode]
        out_shape.append(
            jax.ShapeDtypeStruct((Dp, T, max_len, S), jnp.uint32)
        )
        out_specs.append(
            pl.BlockSpec((bd, T, max_len, S), lambda i: (i, 0, 0, 0))
        )
    if count_tiles:
        out_shape.append(jax.ShapeDtypeStruct((G,), jnp.int32))
        out_specs.append(pl.BlockSpec((1,), lambda i: (i,)))
    if cand_cap:
        out_shape.append(jax.ShapeDtypeStruct((G, cand_cap), jnp.int32))
        out_specs.append(pl.BlockSpec((1, cand_cap), lambda i: (i, 0)))
        if sig_mode == SIG_MODE_VARIANT:
            out_shape.append(
                jax.ShapeDtypeStruct((G, cand_cap, 2), jnp.uint32)
            )
            out_specs.append(
                pl.BlockSpec((1, cand_cap, 2), lambda i: (i, 0, 0))
            )

    outs = pl.pallas_call(
        functools.partial(
            _kernel,
            num_bits=num_bits,
            num_hashes=num_hashes,
            max_len=max_len,
            bands=bands,
            rows=rows,
            use_filter=use_filter,
            sig_mode=sig_mode,
            dense_sigs=dense_sigs,
            count_tiles=count_tiles,
            cand_cap=cand_cap,
        ),
        grid=(Dp // bd,),
        in_specs=[
            pl.BlockSpec((bd, T), lambda i: (i, 0)),
            pl.BlockSpec((bits.shape[0],), lambda i: (0,)),  # grid-invariant
        ],
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shape),
        interpret=interpret,
    )(doc_tokens, bits)
    outs = list(outs)
    packed = outs.pop(0)[:D]
    sigs = outs.pop(0)[:D] if dense_sigs else None
    counts = outs.pop(0) if count_tiles else None
    cands = outs.pop(0) if cand_cap else None
    vkeys = outs.pop(0) if (cand_cap and sig_mode == SIG_MODE_VARIANT) else None
    return packed, sigs, counts, cands, vkeys


# --------------------------------------------------------------------------
# Streaming mode: in-kernel double-buffered DMA over the tile loop
# --------------------------------------------------------------------------


def _stream_kernel(
    offs_ref,  # [G] i32 SMEM: absolute doc-row offset of each chunk
    doc_ref,  # [G*bd, T] i32, memory_space=ANY (stays in HBM)
    bits_ref,  # [num_bits // 32] u32, VMEM-resident
    counts_ref,  # [G] i32 out
    *rest_refs,  # [cand_ref [vkey_ref]]
    num_bits: int,
    num_hashes: int,
    max_len: int,
    bands: int,
    rows: int,
    use_filter: bool,
    sig_mode: str,
    chunks: int,
    bd: int,
    cand_cap: int,
):
    """One launch consumes an entire shard: the tile loop runs *inside*
    the kernel as a ``fori_loop`` over ``chunks`` [bd, T] tiles, each
    DMA'd HBM->VMEM into a 2-slot buffer. The copy-in of tile g+1 is
    issued before tile g's recurrence runs (double buffering), so on
    real hardware the DMA engine overlaps the VPU work; per-tile
    lane/count/key outputs come from the same ``_emit_lane`` epilogue
    the grid-mode kernel uses, with the absolute row offset read from
    SMEM instead of ``pl.program_id`` — flat indices are bit-identical
    to the per-tile launch loop at any geometry.
    """
    var = sig_mode == SIG_MODE_VARIANT
    refs = list(rest_refs)
    cand_ref = refs.pop(0) if cand_cap else None
    vkey_ref = refs.pop(0) if (var and cand_cap) else None
    T = doc_ref.shape[1]
    L = max_len
    bits = bits_ref[...] if use_filter else None

    def body(buf, sem):
        def dma(slot, g):
            return pltpu.make_async_copy(
                doc_ref.at[pl.ds(g * bd, bd), :], buf.at[slot], sem.at[slot]
            )

        dma(0, 0).start()  # warm-up: first tile in flight before the loop

        def chunk(g, _):
            slot = jax.lax.rem(g, 2)

            @pl.when(g + 1 < chunks)
            def _prefetch():
                dma(jax.lax.rem(g + 1, 2), g + 1).start()

            dma(slot, g).wait()
            docs = buf[slot]  # [bd, T]
            pack, cnt, k1_flat, k2_flat = _probe_recurrence(
                docs,
                bits,
                num_bits=num_bits,
                num_hashes=num_hashes,
                max_len=max_len,
                bands=bands,
                rows=rows,
                use_filter=use_filter,
                sig_mode=sig_mode,
            )
            counts_ref[pl.ds(g, 1)] = cnt[None]
            if cand_cap:
                flat, ok = _emit_lane(pack, cnt, cand_cap, L)
                off = offs_ref[g]
                cand_ref[pl.ds(g, 1), :] = jnp.where(
                    ok, off * T * L + flat, -1
                )[None]
                if var:
                    k1, k2 = _gather_lane_keys(
                        k1_flat, k2_flat, flat, ok, bd * T * L
                    )
                    vkey_ref[pl.ds(g, 1), :, :] = jnp.stack(
                        [k1, k2], axis=-1
                    )[None]
            return 0

        jax.lax.fori_loop(0, chunks, chunk, 0)

    pl.run_scoped(
        body,
        buf=pltpu.VMEM((2, bd, T), jnp.int32),
        sem=pltpu.SemaphoreType.DMA((2,)),
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "num_bits",
        "num_hashes",
        "max_len",
        "sig_mode",
        "bands",
        "rows",
        "use_filter",
        "bd",
        "candidates",
        "count_only",
        "interpret",
    ),
)
def fused_probe_stream_pallas(
    doc_tokens,  # [G*bd, T] i32, pre-padded so every chunk is full height
    bits,  # [num_bits // 32] uint32 (ignored when use_filter=False)
    row_offs,  # [G] i32: absolute doc-row offset of each chunk's tile
    num_bits: int,
    num_hashes: int,
    max_len: int,
    sig_mode: str = SIG_MODE_NONE,
    bands: int = 4,
    rows: int = 2,
    use_filter: bool = True,
    bd: int = DEFAULT_BD,
    candidates: int = 0,
    count_only: bool = False,
    interpret: bool = True,
):
    """Streamed megakernel: one launch, ``G`` double-buffered DMA tiles.

    The per-tile grid of ``fused_probe_pallas`` becomes an in-kernel
    loop: ``doc_tokens`` stays in HBM (``memory_space=ANY``) and each
    [bd, T] chunk is async-copied into a 2-slot VMEM buffer while the
    previous chunk's recurrence runs. Returns ``(counts, cands, vkeys)``
    with the same per-tile wire unit as the grid kernel — ``counts``
    [G] int32 true survivor totals, ``cands`` [G, candidates] int32
    ascending *global* flat indices (``row_offs[g]`` replaces the grid
    kernel's ``program_id * bd`` row base, so callers control the
    numbering — shard row offsets and uneven upstream tile heights fold
    into it), ``vkeys`` [G, candidates, 2] uint32 key lanes (variant
    mode). ``count_only=True`` emits only ``counts`` (the adaptive
    sizing pass).

    No packed bitmap and no dense signature tensor are emitted — that
    is the point: input bytes are paid once over the DMA pipeline and
    only the tiny per-tile lanes travel back (see ``hbm_bytes_fused``
    with ``streamed=True``). Dense-sig modes (``lsh`` without lane
    recompute, ``variant`` without the epilogue) therefore raise; the
    streaming paths in ``extraction.sharded`` recompute band signatures
    post-compaction instead.

    Callers pre-pad ``doc_tokens`` to a multiple of ``bd`` *per
    upstream tile* and pass the matching ``row_offs`` so flat indices
    stay bit-identical to the per-tile launch loop at any geometry
    (see ``extraction.sharded.stream_probe_tiles``).
    """
    assert max_len <= 32, "packed survival bitmap holds at most 32 lengths"
    if sig_mode not in (SIG_MODE_NONE, SIG_MODE_VARIANT):
        raise ValueError(
            "streamed kernel emits no dense signature tensor: sig_mode "
            f"{sig_mode!r} unsupported (lsh band sigs are recomputed "
            "post-compaction on streaming paths)"
        )
    if candidates <= 0:
        raise ValueError(
            "streamed kernel has no bitmap output: candidates > 0 required"
        )
    R, T = doc_tokens.shape
    if R % bd != 0:
        raise ValueError(
            f"streamed input rows ({R}) must be a multiple of bd ({bd}): "
            "callers pre-pad each upstream tile to full chunk height"
        )
    G = R // bd
    cand_cap = 0 if count_only else candidates

    out_shape = [jax.ShapeDtypeStruct((G,), jnp.int32)]
    out_specs = [pl.BlockSpec(memory_space=pltpu.VMEM)]
    if cand_cap:
        out_shape.append(jax.ShapeDtypeStruct((G, cand_cap), jnp.int32))
        out_specs.append(pl.BlockSpec(memory_space=pltpu.VMEM))
        if sig_mode == SIG_MODE_VARIANT:
            out_shape.append(
                jax.ShapeDtypeStruct((G, cand_cap, 2), jnp.uint32)
            )
            out_specs.append(pl.BlockSpec(memory_space=pltpu.VMEM))

    outs = pl.pallas_call(
        functools.partial(
            _stream_kernel,
            num_bits=num_bits,
            num_hashes=num_hashes,
            max_len=max_len,
            bands=bands,
            rows=rows,
            use_filter=use_filter,
            sig_mode=sig_mode,
            chunks=G,
            bd=bd,
            cand_cap=cand_cap,
        ),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # row_offs
            pl.BlockSpec(memory_space=pltpu.ANY),  # docs stay in HBM
            pl.BlockSpec(memory_space=pltpu.VMEM),  # Bloom words
        ],
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shape),
        interpret=interpret,
    )(row_offs, doc_tokens, bits)
    outs = [outs] if not isinstance(outs, (tuple, list)) else list(outs)
    counts = outs.pop(0)
    cands = outs.pop(0) if cand_cap else None
    vkeys = outs.pop(0) if (cand_cap and sig_mode == SIG_MODE_VARIANT) else None
    return counts, cands, vkeys


# --------------------------------------------------------------------------
# HBM-traffic accounting (the analytic model the benchmark reports)
# --------------------------------------------------------------------------


def hbm_bytes_unfused(D: int, T: int, max_len: int, max_candidates: int,
                      sig_width: int, streamed: bool = False) -> int:
    """Bytes moved by the unfused survival_mask->compact->signatures
    pipeline: docs read, [D,T,L] int32 base write + probe re-read,
    [D,T,L] survival write + compaction re-read, compacted [N,L] window
    gather + [N,S] signature store.

    ``streamed=`` is accepted for symmetry with ``hbm_bytes_fused`` but
    changes nothing: the unfused pipeline's inter-pass tensors (the
    L-expanded base and survival arrays) are HBM-resident *between*
    jitted passes, so streaming the input cannot elide their round
    trips — which is exactly why only the fused megakernel has a
    streaming mode worth modeling.
    """
    del streamed  # see docstring: no term to elide
    tokens = D * T
    base = tokens * max_len * 4
    mask = tokens * max_len  # int8
    gather = max_candidates * max_len * 4
    sig = max_candidates * sig_width * 4
    return tokens * 4 + 2 * base + 2 * mask + 2 * gather + sig


def hbm_bytes_fused(D: int, T: int, max_len: int, max_candidates: int,
                    bands: int, lsh: bool, sig_width: int = 0,
                    kernel_compact: bool = False, bd: int | None = None,
                    lane_width: int | None = None, two_pass: bool = False,
                    variant_keys: bool = False,
                    streamed: bool = False) -> int:
    """Bytes moved by the fused megakernel pipeline: docs read once,
    packed [D,T] uint32 bitmap write (+ compaction re-read unless the
    in-kernel epilogue runs), compacted [N,L] window gather straight
    from docs, and either the in-kernel [D,T,L,B] signature store +
    [N,B] gather (``lsh=True``) or the same post-compaction
    [N, sig_width] signature store the unfused pipeline pays
    (``lsh=False``; pass the scheme's ``sig_width`` so the two models
    stay symmetric). With ``kernel_compact=True`` the epilogue emits
    per-tile [G, 1 + W] count/candidate lanes instead: the bitmap is
    written once for inspection but never re-read, and the host-side
    combine touches only the lanes. ``W = lane_width or
    max_candidates``: the adaptive two-pass scheme shrinks W to the
    measured per-tile survivor maximum, paying for it with a count-only
    sizing pass (``two_pass=True``: docs re-read + bitmap re-write +
    [G] count round trip). ``variant_keys=True`` models the fused
    variant scheme: the post-compaction [N, sig_width] signature store
    is replaced by the [G, W, 2] key-lane payload (write + combine
    read) riding the candidate lanes. ``streamed=True`` (requires
    ``kernel_compact``) models the in-kernel DMA pipeline
    (``fused_probe_stream_pallas``): input bytes are counted exactly
    once over the double-buffered copy-in and the packed bitmap is
    never materialised — the per-launch bitmap write of the per-tile
    loop disappears from both the emit and (``two_pass``) sizing
    passes, leaving only the docs read and the tiny per-tile lane
    round trips."""
    if streamed and not kernel_compact:
        raise ValueError("streamed modeling requires kernel_compact=True "
                         "(the streamed kernel has no bitmap output)")
    tokens = D * T
    packed = 0 if streamed else tokens * 4
    gather = max_candidates * max_len * 4
    if kernel_compact:
        if bd is None:
            bd = compact_tile_height(D, T, max_candidates)
        W = lane_width if lane_width is not None else max_candidates
        G = -(-D // bd)
        tiles = G * (1 + W) * 4  # write + combine read
        total = tokens * 4 + packed + 2 * tiles + 2 * gather
        if two_pass:
            # count-only sizing pass: docs read + bitmap write again
            # (elided when streamed), plus the [G] per-tile counts'
            # write and host read-back
            total += tokens * 4 + packed + 2 * G * 4
        if variant_keys:
            total += 2 * G * W * 8  # [G, W, 2] u32 key lanes, write+read
    else:
        total = tokens * 4 + 2 * packed + 2 * gather
        if variant_keys:
            # dense mode: [D, T, L, 2] key tensor store + [N, 2] gather
            total += tokens * max_len * 8 + max_candidates * 8
    if lsh:
        total += tokens * max_len * bands * 4 + max_candidates * bands * 4
    elif not variant_keys:
        total += max_candidates * sig_width * 4
    return total

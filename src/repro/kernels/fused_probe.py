"""Pallas TPU megakernel: fused ISH-filter probe + window signatures.

This fuses the whole map-side candidate front end — validity, Bloom
survival, and (for the LSH scheme) per-window MinHash band signatures —
into ONE ``pallas_call`` that streams each ``[Bd, T]`` document tile
HBM->VMEM exactly once. The unfused pipeline runs three jitted passes
(``window_base`` -> ``token_in_filter`` -> ``window_signatures``) and
round-trips the ``L``-times-expanded ``[D, T, L]`` base / survival
tensors through HBM between them; here every per-window quantity is a
*running* recurrence over one in-register token stream:

    real[t]          = tok[t] != PAD
    hit[t]           = all k Bloom probes of tok[t] set   (bitmap VMEM-resident)
    valid[t, l]      = AND(real[t .. t+l])                (running-and)
    survive[t, l]    = valid[t, l] & OR(hit[t .. t+l])    (running-or)
    rmin_i[t, l]     = MIN(h_i(tok[t .. t+l]))            (running-min, i < B*R)
    sig[t, l, b]     = combine(rmin_{bR} .. rmin_{bR+R-1}, b+1)
    dup[t, l]        = OR(tok[t+l] == tok[t .. t+l-1])    (shifted compares)
    fold_i[t, l]     = (SUM, XOR, COUNT) of h_i(tok[t+j]) over the
                       first-occurrence positions j <= l  (running fold)
    key_i[t, l]      = mix(sum ^ xor*C1 ^ cnt*GOLDEN)     (set_hash finalise)

The survival mask is emitted *packed*: bit ``l`` of ``packed[d, t]``
(uint32, so L <= 32) is ``survive[d, t, l]`` — a 4 B/token store instead
of the unfused path's L B/token int8 mask and 4L B/token int32 base.
Band signatures are bit-identical to ``core.signatures.window_signatures``
for the ``lsh`` scheme: MinHash minima are duplicate-insensitive, so the
first-occurrence masking the jnp path applies never changes a row
minimum, and the seeds / murmur3 finaliser / combine below match
``core.hashing`` exactly.

The ``variant`` scheme (paper Definition 2) is fused the same way:
``core.hashing.set_hash`` is a commutative (sum, xor, count) fold over
per-token hashes, so both 32-bit variant keys extend token by token —
the only obstacle to streaming is first-occurrence masking, which the
kernel makes streamable with a *register-resident duplicate mask*:
token ``t+l`` is a duplicate inside window ``[t, t+l]`` iff it equals
any of ``tok[t .. t+l-1]``, i.e. iff the current shifted token stream
matches any of the <= 31 previously shifted streams (all VMEM/register
resident, no HBM traffic). Masked contributions then feed the running
fold, and the finalised keys are bit-identical to
``core.variants.window_variant_key`` at every (pos, len) — including
PAD-heavy and all-duplicate windows (see ``streaming_first_occurrence``
for the host-testable reference of the mask). With the compaction
epilogue on, the keys are not stored densely: they ride the candidate
lanes as a tiny ``[G, NC, 2]`` payload gathered at the surviving flat
indices.

HBM-traffic accounting (per document token; L = max_len, K = num_hashes,
B = bands; see ``hbm_bytes_unfused`` / ``hbm_bytes_fused``):

    unfused  read 4 (docs) + write 4L (base) + read 4L (filter probe)
             + write L (int8 mask) + read L (compaction scan)
    fused    read 4 (docs) + write 4 (packed bitmap)
             [+ write 4LB (band sigs, lsh mode only)]
             [+ G*(1+W)*4 lane ints + G*W*8 variant-key payload,
                epilogue mode; W = NC one-pass, measured two-pass]

For the filter stages alone that is a ~(10L+4)/8 ≈ 10x traffic cut at
L = 8; the kernel additionally hashes each token K times instead of the
unfused path's K*L times (the [D,T,L] base repeats every token L times).
Downstream, the engine's fused compaction gathers candidate windows
straight from the [D, T] token array — ``window_base`` is never
materialised (see ``extraction.engine.fused_filter_compact``).

With ``candidates > 0`` the kernel also runs a *compaction epilogue*:
the per-tile survivor count is accumulated in an SMEM scratch cell as
the length recurrence runs, and the tile's first ``candidates``
surviving (doc, pos, len) triples are rank-compacted (prefix-sum over
the register-resident bit expansion) into an ascending [G, candidates]
flat-index lane. Candidate selection then reads only these lanes — the
last XLA pass over the full [D, T] bitmap (cumsum + searchsorted in
``extraction.results.select_nonzero``) disappears, which matters because
candidate-generation traffic, not verification, dominates at scale.

The lane width is *decoupled* from the candidate capacity: a one-pass
emit must keep ``candidates = NC`` wide lanes for bit parity (the
global first-NC could all land in one tile), but an **adaptive
two-pass** run first streams a ``count_only=True`` pass (per-tile SMEM
counts, no lane store), sizes the emit pass's lane width to the
measured per-tile survivor maximum (``round_lane_width``), and re-runs
with ``candidates = W << NC`` — every tile's lane then holds *all* of
its survivors, so the ``select_from_tiles`` merge stays bit-identical
while lane traffic drops from ``G*(1+NC)`` to ``G*(1+W)`` ints. Both
passes share the NC-derived tile height (``compact_tile_height``) so
their grids — and therefore the per-tile counts — line up exactly.

Tiling: one full document row per grid row ([Bd, T] tiles) so windows
never straddle a tile edge; the Bloom bitmap block is grid-invariant
(loaded once, reused across steps). Validated in interpret mode on CPU;
on TPU the bitmap gather uses dynamic VMEM indexing (minor-dim gather,
Mosaic v4+).
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import hashing
from repro.core.filter import _BLOOM_SEED_BASE  # single source of truth
from repro.core.hashing import _C1, _GOLDEN
from repro.core.signatures import _LSH_SEED_BASE
from repro.core.variants import VARIANT_SEEDS
from repro.kernels._hashing import combine as _combine
from repro.kernels._hashing import hash_seeded as _hash
from repro.kernels._hashing import mix as _mix

_MAX_U32 = 0xFFFFFFFF

DEFAULT_BD = 8

#: smallest adaptive emit-pass lane width: keeps the lane store aligned
#: and bounds recompiles (widths are rounded up to powers of two).
MIN_LANE_WIDTH = 8


def compact_tile_height(D: int, T: int, candidates: int) -> int:
    """Doc-tile height for the compaction epilogue.

    Each grid tile emits a full-width [1 + candidates] lane (parity
    requires it — the global first-NC could all land in one tile), so
    lane traffic is G * (1 + NC) * 8 B and only stays well under the
    bitmap bytes it replaces when bd >= 4 * NC / T. Single source of
    truth for ``ops.fused_probe_compact`` and ``hbm_bytes_fused``.

    Adaptive two-pass runs keep this NC-derived height for *both* the
    count pass and the narrower emit pass: the emit width W is chosen
    from the count pass's per-tile maxima, which is only sound if the
    two grids tile the documents identically. The narrower lanes then
    undercut even this conservative geometry (G*(1+W) vs G*(1+NC)
    ints); see ``hbm_bytes_fused(two_pass=True)`` for the full trade.
    """
    return min(max(DEFAULT_BD, -(-4 * candidates // max(T, 1))), max(D, 1))


def round_lane_width(max_count: int, cap: int,
                     floor: int = MIN_LANE_WIDTH) -> int:
    """Adaptive emit-pass lane width for a measured per-tile maximum.

    Rounds the measured per-tile survivor maximum up to a power of two
    (>= ``floor``) so repeated runs at similar densities reuse the same
    compiled kernel, and caps at ``cap`` (= NC: wider lanes than the
    merge capacity are never read). Any W >= max_count keeps the merge
    exact — every tile's lane holds all of its survivors.
    """
    w = max(int(max_count), int(floor), 1)
    w = 1 << (w - 1).bit_length()
    return max(min(w, int(cap)), 1)


SIG_MODE_NONE = "none"
SIG_MODE_LSH = "lsh"
SIG_MODE_VARIANT = "variant"


def streaming_first_occurrence(tokens, *, xp=np):
    """First-occurrence mask via the kernel's shifted-compare recurrence.

    Host-testable reference of the in-kernel duplicate mask: position
    ``j`` of each padded window row is marked iff it is real (non-PAD)
    and equals none of positions ``0 .. j-1`` — exactly the <= L-1
    shifted compares the kernel performs against its previously shifted
    token streams. Bit-identical to
    ``core.semantics.first_occurrence_mask`` (property-tested); kept
    next to the kernel so the trick has a readable, testable form.
    """
    L = tokens.shape[-1]
    dup = xp.zeros(tokens.shape, dtype=bool)
    for j in range(1, L):
        hit = xp.zeros(tokens.shape[:-1], dtype=bool)
        for i in range(j):
            hit = hit | (tokens[..., i] == tokens[..., j])
        if xp is np:
            dup[..., j] = hit
        else:
            dup = dup.at[..., j].set(hit)
    return (tokens != 0) & ~dup  # PAD == 0


def empty_band_sigs(bands: int, rows: int) -> np.ndarray:
    """[bands] uint32: the band signatures of an all-invalid window.

    Matches ``signatures._minhash_np`` on a row with no valid tokens
    (every row-minimum is 0xFFFFFFFF). Used by the engine to pad
    non-surviving candidate slots so the fused signature tensor is
    bit-identical to ``window_signatures`` on PAD-only windows too.
    """
    row = np.full((1,), _MAX_U32, dtype=np.uint32)
    out = []
    for b in range(bands):
        band = row
        for _ in range(1, rows):
            band = hashing.combine(band, row, xp=np)
        band = hashing.combine(band, np.full((1,), b + 1, dtype=np.uint32), xp=np)
        out.append(band[0])
    return np.array(out, dtype=np.uint32)


def _kernel(
    doc_ref,
    bits_ref,
    packed_ref,
    *rest_refs,
    num_bits: int,
    num_hashes: int,
    max_len: int,
    bands: int,
    rows: int,
    use_filter: bool,
    sig_mode: str,
    dense_sigs: bool,
    count_tiles: bool,
    cand_cap: int,
):
    # ref layout after packed_ref:
    #   [sig_ref] [count_ref] [cand_ref [vkey_ref]] [cnt_scr]
    refs = list(rest_refs)
    sig_ref = refs.pop(0) if dense_sigs else None
    count_ref = refs.pop(0) if count_tiles else None
    cand_ref = refs.pop(0) if cand_cap else None
    var = sig_mode == SIG_MODE_VARIANT
    vkey_ref = refs.pop(0) if (var and cand_cap) else None
    cnt_scr = refs.pop(0) if count_tiles else None
    docs = doc_ref[...]  # [Bd, T] int32
    Bd, T = docs.shape
    real = docs != 0  # PAD == 0

    if use_filter:
        bits = bits_ref[...]  # [num_bits // 32] uint32 (VMEM-resident)
        hit = jnp.ones(docs.shape, bool)
        for k in range(num_hashes):
            h = _hash(docs, _BLOOM_SEED_BASE + k)
            pos = h % jnp.uint32(num_bits)
            word = bits[(pos // 32).astype(jnp.int32)]  # VMEM gather
            bit = (word >> (pos % 32)) & jnp.uint32(1)
            hit = hit & (bit == 1)
    else:
        hit = real  # survival degenerates to validity

    lsh = sig_mode == SIG_MODE_LSH
    if lsh:
        # per-token row hashes, invalid -> MAX so they never win a min
        hv = [
            jnp.where(real, _hash(docs, _LSH_SEED_BASE + i), jnp.uint32(_MAX_U32))
            for i in range(bands * rows)
        ]
        rmin = [jnp.full(docs.shape, _MAX_U32, dtype=jnp.uint32) for _ in hv]
    if var:
        # variant set-hash recurrence: per-window running (sum, xor,
        # count) folds for both 32-bit keys; first-occurrence masking is
        # streamed via the duplicate mask below (shifted compares
        # against the previously shifted token streams — all register
        # resident), bit-identical to core.variants.window_variant_key.
        zero = jnp.zeros(docs.shape, dtype=jnp.uint32)
        vs1, vx1, vs2, vx2, vcnt = zero, zero, zero, zero, zero
        prev_toks: list = []  # token streams shifted by 0 .. l-1
        vkeys1: list = []  # per-length finalised keys (lane/dense store)
        vkeys2: list = []

    vand = jnp.ones(docs.shape, bool)
    vor = jnp.zeros(docs.shape, bool)
    pack = jnp.zeros(docs.shape, dtype=jnp.uint32)
    sh_real, sh_hit = real, hit
    sh_hv = list(hv) if lsh else []
    sh_tok = docs if var else None
    zero_row = jnp.zeros((Bd, 1), bool)
    max_row = jnp.full((Bd, 1), _MAX_U32, dtype=jnp.uint32)
    pad_row = jnp.zeros((Bd, 1), dtype=docs.dtype)
    if count_tiles:
        cnt_scr[0] = jnp.int32(0)  # scratch persists across grid steps
    for l in range(max_len):
        vand = vand & sh_real
        vor = vor | sh_hit
        surv = vand & vor
        pack = pack | (surv.astype(jnp.uint32) << jnp.uint32(l))
        if count_tiles:
            # per-tile survivor count, accumulated in scratch as the
            # length recurrence runs (feeds the compaction epilogue)
            cnt_scr[0] += surv.sum().astype(jnp.int32)
        if lsh:
            for i in range(bands * rows):
                rmin[i] = jnp.minimum(rmin[i], sh_hv[i])
            for b in range(bands):
                band = rmin[b * rows]
                for r in range(1, rows):
                    band = _combine(band, rmin[b * rows + r])
                band = _combine(band, jnp.full_like(band, jnp.uint32(b + 1)))
                sig_ref[:, :, l, b] = band
        if var:
            # duplicate mask: tok[t+l] repeats inside [t, t+l] iff the
            # current shifted stream equals any earlier shifted stream
            # (PAD-vs-PAD hits are masked out by sh_real below)
            dup = jnp.zeros(docs.shape, bool)
            for pv in prev_toks:
                dup = dup | (pv == sh_tok)
            contrib = sh_real & ~dup  # == first_occurrence_mask position
            h1 = jnp.where(contrib, _hash(sh_tok, VARIANT_SEEDS[0]),
                           jnp.uint32(0))
            h2 = jnp.where(contrib, _hash(sh_tok, VARIANT_SEEDS[1]),
                           jnp.uint32(0))
            vs1, vx1 = vs1 + h1, vx1 ^ h1
            vs2, vx2 = vs2 + h2, vx2 ^ h2
            vcnt = vcnt + contrib.astype(jnp.uint32)
            # set_hash finalise (core.hashing.set_hash, bit-identical)
            fin = vcnt * jnp.uint32(_GOLDEN)
            k1 = _mix(vs1 ^ (vx1 * jnp.uint32(_C1)) ^ fin)
            k2 = _mix(vs2 ^ (vx2 * jnp.uint32(_C1)) ^ fin)
            vkeys1.append(k1)
            vkeys2.append(k2)
            if dense_sigs:
                sig_ref[:, :, l, 0] = k1
                sig_ref[:, :, l, 1] = k2
            prev_toks.append(sh_tok)
        if l + 1 < max_len:
            sh_real = jnp.concatenate([sh_real[:, 1:], zero_row], axis=1)
            sh_hit = jnp.concatenate([sh_hit[:, 1:], zero_row], axis=1)
            if lsh:
                sh_hv = [
                    jnp.concatenate([v[:, 1:], max_row], axis=1) for v in sh_hv
                ]
            if var:
                sh_tok = jnp.concatenate([sh_tok[:, 1:], pad_row], axis=1)
    packed_ref[...] = pack
    if count_tiles:
        count_ref[0] = cnt_scr[0]
    if cand_cap:
        # compaction epilogue: emit the tile's surviving (doc, pos, len)
        # triples as ascending *global* flat indices, packed to the front
        # of a fixed [cand_cap] lane — everything VMEM-resident, so the
        # [D, T] bitmap is never re-read from HBM to compact it.
        L = max_len
        lane = jax.lax.iota(jnp.int32, cand_cap)  # iota: no captured consts
        # two-stage (word -> bit) selection, sort- and scatter-free
        # ("the k-th survivor lives where the prefix sum first reaches
        # k"): survivor density is low, so first pick the <= cand_cap
        # tokens with any surviving length (the first cand_cap set bits
        # always live inside the first cand_cap nonzero words), then
        # rank only their unpacked bits.
        nz = (pack != 0).reshape(-1)  # [Bd*T]
        cw = jnp.cumsum(nz.astype(jnp.int32))
        wk = jnp.searchsorted(cw, lane + 1, side="left").astype(jnp.int32)
        wok = lane < jnp.minimum(cw[-1], cand_cap)
        words = pack.reshape(-1)[jnp.minimum(wk, Bd * T - 1)]
        words = words * wok.astype(jnp.uint32)  # [cand_cap] u32
        sub = ((words[:, None] >> jax.lax.iota(jnp.uint32, L))
               & jnp.uint32(1)) != 0  # [cand_cap, L]
        cb = jnp.cumsum(sub.reshape(-1).astype(jnp.int32))
        k = jnp.searchsorted(cb, lane + 1, side="left").astype(jnp.int32)
        ok = lane < jnp.minimum(cnt_scr[0], cand_cap)
        flat = jnp.minimum(wk[jnp.minimum(k // L, cand_cap - 1)],
                           Bd * T - 1) * L + k % L
        cand_ref[0, :] = jnp.where(
            ok, pl.program_id(0) * Bd * T * L + flat, -1
        )
        if var:
            # variant keys ride the lane: gather both finalised keys at
            # the selected local flat indices — the dense [Bd, T, L, 2]
            # tensor never leaves registers/VMEM, only the [cand_cap, 2]
            # payload is stored. Padded slots carry 0, the set_hash of
            # the empty window (bit-parity with window_variant_key on
            # all-PAD windows).
            sel = jnp.clip(flat, 0, Bd * T * L - 1)
            k1_flat = jnp.stack(vkeys1, axis=-1).reshape(-1)  # [Bd*T*L]
            k2_flat = jnp.stack(vkeys2, axis=-1).reshape(-1)
            vkey_ref[0, :, 0] = jnp.where(ok, k1_flat[sel], jnp.uint32(0))
            vkey_ref[0, :, 1] = jnp.where(ok, k2_flat[sel], jnp.uint32(0))


@functools.partial(
    jax.jit,
    static_argnames=(
        "num_bits",
        "num_hashes",
        "max_len",
        "sig_mode",
        "bands",
        "rows",
        "use_filter",
        "bd",
        "candidates",
        "count_only",
        "interpret",
    ),
)
def fused_probe_pallas(
    doc_tokens,  # [D, T] i32
    bits,  # [num_bits // 32] uint32 (ignored when use_filter=False)
    num_bits: int,
    num_hashes: int,
    max_len: int,
    sig_mode: str = SIG_MODE_NONE,
    bands: int = 4,
    rows: int = 2,
    use_filter: bool = True,
    bd: int = DEFAULT_BD,
    candidates: int = 0,
    count_only: bool = False,
    interpret: bool = True,
):
    """One-pass filter+signature probe with optional compaction epilogue.

    Returns ``(packed, sigs, counts, cands, vkeys)``:

    * ``packed`` [D, T] uint32 with bit ``l`` = survive(pos, len=l+1)
      (validity AND Bloom survival; validity only when
      ``use_filter=False``);
    * ``sigs`` — [D, T, max_len, bands] uint32 MinHash band signatures
      when ``sig_mode == "lsh"``; [D, T, max_len, 2] uint32 variant key
      pairs when ``sig_mode == "variant"`` *without* the epilogue
      (dense mode); else ``None``;
    * with ``candidates > 0``, the in-kernel compaction epilogue:
      ``counts`` [G] int32 holds each grid tile's true survivor count
      (scratch-accumulated; may exceed ``candidates``) and ``cands``
      [G, candidates] int32 the tile's first ``candidates`` survivors as
      ascending global flat (doc*T + pos)*max_len + (len-1) indices, -1
      padded — downstream compaction reads these tiny per-tile lanes and
      never re-reads the [D, T] bitmap (see
      ``extraction.results.select_from_tiles``). ``candidates`` is the
      *lane width*: callers shrink it below the merge capacity after a
      count pass (adaptive two-pass; see ``round_lane_width``);
    * ``vkeys`` [G, candidates, 2] uint32 — the variant key pairs of
      each lane slot (``sig_mode == "variant"`` with the epilogue; the
      dense ``sigs`` tensor is *not* emitted then), 0 in padded slots;
    * ``count_only=True`` (with ``candidates > 0``) emits ``counts``
      but skips the lane (and key) stores — the cheap sizing pass of
      the adaptive two-pass scheme. ``candidates`` then only sets the
      tile geometry via the caller's ``bd`` choice.
    """
    assert max_len <= 32, "packed survival bitmap holds at most 32 lengths"
    assert candidates or not count_only, "count_only needs candidates > 0"
    assert not (count_only and sig_mode != SIG_MODE_NONE), (
        "count_only is the sizing pass: signatures belong to the emit pass"
    )
    D, T = doc_tokens.shape
    bd = min(bd, D)
    Dp = -(-D // bd) * bd
    G = Dp // bd
    if Dp != D:
        doc_tokens = jnp.pad(doc_tokens, ((0, Dp - D), (0, 0)))
    count_tiles = candidates > 0
    cand_cap = 0 if count_only else candidates
    dense_sigs = sig_mode == SIG_MODE_LSH or (
        sig_mode == SIG_MODE_VARIANT and not cand_cap
    )
    sig_depth = {SIG_MODE_LSH: bands, SIG_MODE_VARIANT: 2}

    out_shape = [jax.ShapeDtypeStruct((Dp, T), jnp.uint32)]
    out_specs = [pl.BlockSpec((bd, T), lambda i: (i, 0))]
    if sig_mode not in (SIG_MODE_NONE, SIG_MODE_LSH, SIG_MODE_VARIANT):
        raise ValueError(f"unknown sig_mode {sig_mode!r}")
    if dense_sigs:
        S = sig_depth[sig_mode]
        out_shape.append(
            jax.ShapeDtypeStruct((Dp, T, max_len, S), jnp.uint32)
        )
        out_specs.append(
            pl.BlockSpec((bd, T, max_len, S), lambda i: (i, 0, 0, 0))
        )
    scratch_shapes = []
    if count_tiles:
        out_shape.append(jax.ShapeDtypeStruct((G,), jnp.int32))
        out_specs.append(pl.BlockSpec((1,), lambda i: (i,)))
        from jax.experimental.pallas import tpu as pltpu

        scratch_shapes = [pltpu.SMEM((1,), jnp.int32)]
    if cand_cap:
        out_shape.append(jax.ShapeDtypeStruct((G, cand_cap), jnp.int32))
        out_specs.append(pl.BlockSpec((1, cand_cap), lambda i: (i, 0)))
        if sig_mode == SIG_MODE_VARIANT:
            out_shape.append(
                jax.ShapeDtypeStruct((G, cand_cap, 2), jnp.uint32)
            )
            out_specs.append(
                pl.BlockSpec((1, cand_cap, 2), lambda i: (i, 0, 0))
            )

    outs = pl.pallas_call(
        functools.partial(
            _kernel,
            num_bits=num_bits,
            num_hashes=num_hashes,
            max_len=max_len,
            bands=bands,
            rows=rows,
            use_filter=use_filter,
            sig_mode=sig_mode,
            dense_sigs=dense_sigs,
            count_tiles=count_tiles,
            cand_cap=cand_cap,
        ),
        grid=(Dp // bd,),
        in_specs=[
            pl.BlockSpec((bd, T), lambda i: (i, 0)),
            pl.BlockSpec((bits.shape[0],), lambda i: (0,)),  # grid-invariant
        ],
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shape),
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(doc_tokens, bits)
    outs = list(outs)
    packed = outs.pop(0)[:D]
    sigs = outs.pop(0)[:D] if dense_sigs else None
    counts = outs.pop(0) if count_tiles else None
    cands = outs.pop(0) if cand_cap else None
    vkeys = outs.pop(0) if (cand_cap and sig_mode == SIG_MODE_VARIANT) else None
    return packed, sigs, counts, cands, vkeys


# --------------------------------------------------------------------------
# HBM-traffic accounting (the analytic model the benchmark reports)
# --------------------------------------------------------------------------


def hbm_bytes_unfused(D: int, T: int, max_len: int, max_candidates: int,
                      sig_width: int) -> int:
    """Bytes moved by the unfused survival_mask->compact->signatures
    pipeline: docs read, [D,T,L] int32 base write + probe re-read,
    [D,T,L] survival write + compaction re-read, compacted [N,L] window
    gather + [N,S] signature store."""
    tokens = D * T
    base = tokens * max_len * 4
    mask = tokens * max_len  # int8
    gather = max_candidates * max_len * 4
    sig = max_candidates * sig_width * 4
    return tokens * 4 + 2 * base + 2 * mask + 2 * gather + sig


def hbm_bytes_fused(D: int, T: int, max_len: int, max_candidates: int,
                    bands: int, lsh: bool, sig_width: int = 0,
                    kernel_compact: bool = False, bd: int | None = None,
                    lane_width: int | None = None, two_pass: bool = False,
                    variant_keys: bool = False) -> int:
    """Bytes moved by the fused megakernel pipeline: docs read once,
    packed [D,T] uint32 bitmap write (+ compaction re-read unless the
    in-kernel epilogue runs), compacted [N,L] window gather straight
    from docs, and either the in-kernel [D,T,L,B] signature store +
    [N,B] gather (``lsh=True``) or the same post-compaction
    [N, sig_width] signature store the unfused pipeline pays
    (``lsh=False``; pass the scheme's ``sig_width`` so the two models
    stay symmetric). With ``kernel_compact=True`` the epilogue emits
    per-tile [G, 1 + W] count/candidate lanes instead: the bitmap is
    written once for inspection but never re-read, and the host-side
    combine touches only the lanes. ``W = lane_width or
    max_candidates``: the adaptive two-pass scheme shrinks W to the
    measured per-tile survivor maximum, paying for it with a count-only
    sizing pass (``two_pass=True``: docs re-read + bitmap re-write +
    [G] count round trip). ``variant_keys=True`` models the fused
    variant scheme: the post-compaction [N, sig_width] signature store
    is replaced by the [G, W, 2] key-lane payload (write + combine
    read) riding the candidate lanes."""
    tokens = D * T
    packed = tokens * 4
    gather = max_candidates * max_len * 4
    if kernel_compact:
        if bd is None:
            bd = compact_tile_height(D, T, max_candidates)
        W = lane_width if lane_width is not None else max_candidates
        G = -(-D // bd)
        tiles = G * (1 + W) * 4  # write + combine read
        total = tokens * 4 + packed + 2 * tiles + 2 * gather
        if two_pass:
            # count-only sizing pass: docs read + bitmap write again,
            # plus the [G] per-tile counts' write and host read-back
            total += tokens * 4 + packed + 2 * G * 4
        if variant_keys:
            total += 2 * G * W * 8  # [G, W, 2] u32 key lanes, write+read
    else:
        total = tokens * 4 + 2 * packed + 2 * gather
        if variant_keys:
            # dense mode: [D, T, L, 2] key tensor store + [N, 2] gather
            total += tokens * max_len * 8 + max_candidates * 8
    if lsh:
        total += tokens * max_len * bands * 4 + max_candidates * bands * 4
    elif not variant_keys:
        total += max_candidates * sig_width * 4
    return total

"""Pallas TPU kernel: banded MinHash (LSH) signature generation.

Signature generation is the map-side cost ``C_sig`` of Def. 4 for the
LSH scheme: for every candidate window, hash its tokens with B*R
affine-mix hash functions, take per-row minima over the (masked) window,
and fold R row-minima into one band signature.

The whole computation is elementwise uint32 arithmetic + an L-reduce —
pure VPU work with zero MXU involvement, so the kernel's job is purely
bandwidth discipline: one HBM->VMEM stream of [Bn, L] token tiles and one
[Bn, B] store, with all B*R hash evaluations fused in VMEM (the unfused
jnp version re-reads the token tile from HBM once per hash function —
B*R x more HBM traffic).

Bit-identical to ``core.signatures._minhash_np/_jnp`` (same seeds,
murmur3 finaliser, and combine), which the EE-Join dictionary side uses —
a signature produced here matches the host-built table.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.signatures import _LSH_SEED_BASE
from repro.kernels._hashing import combine as _combine
from repro.kernels._hashing import hash_seeded as _hash

DEFAULT_BN = 256


def _kernel(tok_ref, valid_ref, out_ref, *, bands: int, rows: int):
    toks = tok_ref[...]  # [Bn, L] int32
    valid = valid_ref[...] != 0  # [Bn, L]
    for b in range(bands):
        band = None
        for r in range(rows):
            h = _hash(toks, _LSH_SEED_BASE + b * rows + r)
            h = jnp.where(valid, h, jnp.uint32(0xFFFFFFFF))
            m = h.min(axis=-1)  # [Bn]
            band = m if band is None else _combine(band, m)
        band = _combine(band, jnp.full_like(band, jnp.uint32(b + 1)))
        out_ref[:, b] = band


@functools.partial(jax.jit, static_argnames=("bands", "rows", "bn", "interpret"))
def minhash_pallas(
    tokens,  # [N, L] i32
    valid,  # [N, L] bool
    bands: int = 4,
    rows: int = 2,
    bn: int = DEFAULT_BN,
    interpret: bool = True,
):
    N, L = tokens.shape
    bn = min(bn, N)
    Np = -(-N // bn) * bn
    if Np != N:
        tokens = jnp.pad(tokens, ((0, Np - N), (0, 0)))
        valid = jnp.pad(valid, ((0, Np - N), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_kernel, bands=bands, rows=rows),
        grid=(Np // bn,),
        in_specs=[
            pl.BlockSpec((bn, L), lambda i: (i, 0)),
            pl.BlockSpec((bn, L), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bands), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, bands), jnp.uint32),
        interpret=interpret,
    )(tokens, valid.astype(jnp.int8))
    return out[:N]

"""Pallas TPU kernels for the extraction hot path (jnp oracles in ref.py).

Single-pass streaming architecture
----------------------------------
``fused_probe`` is the map-side front end: ONE ``pallas_call`` streams
each [Bd, T] document tile HBM->VMEM, keeps the Bloom bitmap
VMEM-resident, and emits (a) the window-survival mask *packed* as a
[D, T] uint32 bitmap (bit l = survive(pos, len=l+1)) and (b), in dense
regimes, per-window MinHash band signatures — all as running
and/or/min recurrences over the in-register token stream. Downstream,
``extraction.engine.fused_filter_compact`` compacts candidates straight
off the packed bitmap and gathers their tokens from the [D, T] array;
the L-times-expanded [D, T, L] window base of the unfused pipeline is
never materialised.

HBM-traffic accounting (per token; L = max window length, K = Bloom
hashes, B = LSH bands): the unfused pipeline moves ~4 + 8L + 2L bytes
(docs read, int32 base write+re-read, int8 mask write+re-read) while the
fused pass moves 4 + 8 bytes (+4LB when emitting signatures in-kernel) —
see ``fused_probe.hbm_bytes_unfused`` / ``hbm_bytes_fused``, reported by
``benchmarks/bench_kernels.py``. Each token is also hashed K times
instead of K*L.

Standalone kernels (pre-fusion stages, kept for comparison + fallback):
``window_filter`` (survival mask only, [D,T,L] int8 output),
``minhash`` (banded signatures over compacted windows),
``jaccard_verify`` (weighted-containment verification).

All kernels validate in interpret mode on CPU (the kernel body lowers
through XLA); ``ops.py`` is the dispatch layer the engine calls with
``use_kernel=True`` and selects interpret mode off-TPU.
"""

"""Shared jnp hash helpers for Pallas kernel bodies.

One copy of the murmur3-finaliser family for every kernel module, with
the constants imported from ``core.hashing`` — bit-parity with the
host-side builds is a hard correctness contract, so there is exactly
one in-kernel implementation to keep in sync. ``ref.py`` keeps its own
independent copy on purpose: it is the oracle the kernels are tested
against and must not share the implementation under test.

Plain jnp ops, usable inside Pallas kernel bodies and under jit alike.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.hashing import _C1, _C2, _GOLDEN


def mix(x):
    """murmur3 finaliser over uint32 (bit-identical to hashing._mix)."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(_C1)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(_C2)
    x = x ^ (x >> 16)
    return x


def hash_seeded(x, seed: int):
    """hashing.hash_u32 for kernel bodies (seed folded in host-side)."""
    off = np.uint32((_GOLDEN * (seed + 1)) & 0xFFFFFFFF)
    return mix(x.astype(jnp.uint32) + off)


def combine(h, g):
    """Order-dependent combine (bit-identical to hashing.combine)."""
    return mix(h ^ (g + jnp.uint32(_GOLDEN) + (h << 6) + (h >> 2)))

"""Pallas TPU kernel: fused ISH-filter probe over every document window.

This is the paper's key pruning step fused into one pass: instead of
materialising the L x |d| candidate substrings and probing each (the
baseline SSJoin's failure mode, §3.1), the kernel streams document tiles
HBM->VMEM once, keeps the entire Bloom bitmap VMEM-resident (32 KiB at
2^18 bits — sized for exactly this), and emits the [D, T, L] survival
mask:

    hit[d, t]        = all k probes of token (d, t) set in the bitmap
    survive[d, t, l] = any(hit[d, t .. t+l])     (running-or, registers)

HBM traffic: 4B/token read + L B/token written vs. the unfused path's
L x (window materialisation + k bitmap reads). The bitmap gather uses
dynamic VMEM indexing (Mosaic supports minor-dim gather on v4+; the
kernel is validated in interpret mode on CPU per the assignment).

Tiling: one full document row per grid row ([Bd, T] tiles) so windows
never straddle a tile edge; the bitmap block is grid-invariant (loaded
once, reused across steps).

NOTE: the production fast path is ``fused_probe``, which subsumes this
kernel (packed uint32 survival bitmap instead of the [D, T, L] int8
mask — L x less output traffic — plus optional in-pass signature
emission). This standalone version is kept as the minimal reference
fusion and for the ops/ref parity sweeps.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.filter import _BLOOM_SEED_BASE
from repro.kernels._hashing import hash_seeded as _hash

DEFAULT_BD = 8


def _kernel(doc_ref, bits_ref, out_ref, *, num_bits: int, num_hashes: int, max_len: int):
    docs = doc_ref[...]  # [Bd, T] int32
    bits = bits_ref[...]  # [num_bits // 32] uint32 (VMEM-resident)
    hit = jnp.ones(docs.shape, bool)
    for k in range(num_hashes):
        h = _hash(docs, _BLOOM_SEED_BASE + k)
        pos = h % jnp.uint32(num_bits)
        word = bits[(pos // 32).astype(jnp.int32)]  # VMEM gather
        bit = (word >> (pos % 32)) & jnp.uint32(1)
        hit = hit & (bit == 1)

    Bd, T = docs.shape
    acc = jnp.zeros((Bd, T), bool)
    shifted = hit
    for l in range(max_len):
        acc = acc | shifted
        out_ref[:, :, l] = acc.astype(jnp.int8)
        if l + 1 < max_len:
            shifted = jnp.concatenate(
                [shifted[:, 1:], jnp.zeros((Bd, 1), bool)], axis=1
            )


@functools.partial(
    jax.jit, static_argnames=("num_bits", "num_hashes", "max_len", "bd", "interpret")
)
def window_filter_pallas(
    doc_tokens,  # [D, T] i32
    bits,  # [num_bits // 32] uint32
    num_bits: int,
    num_hashes: int,
    max_len: int,
    bd: int = DEFAULT_BD,
    interpret: bool = True,
):
    D, T = doc_tokens.shape
    bd = min(bd, D)
    Dp = -(-D // bd) * bd
    if Dp != D:
        doc_tokens = jnp.pad(doc_tokens, ((0, Dp - D), (0, 0)))

    out = pl.pallas_call(
        functools.partial(
            _kernel, num_bits=num_bits, num_hashes=num_hashes, max_len=max_len
        ),
        grid=(Dp // bd,),
        in_specs=[
            pl.BlockSpec((bd, T), lambda i: (i, 0)),
            pl.BlockSpec((bits.shape[0],), lambda i: (0,)),  # grid-invariant
        ],
        out_specs=pl.BlockSpec((bd, T, max_len), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Dp, T, max_len), jnp.int8),
        interpret=interpret,
    )(doc_tokens, bits)
    return out[:D].astype(bool)

"""Jit'd public wrappers around the Pallas kernels.

These are the dispatch points the extraction engine calls when
``use_kernel=True``: they adapt engine-level arguments (entity-id lists,
weight tables) to the dense tile layout the kernels consume, and select
interpret mode off-TPU (the assignment's validation path — the kernel
*body* still executes, in Python, on CPU).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import fused_probe as _fp
from repro.kernels import jaccard_verify as _jv
from repro.kernels import minhash as _mh
from repro.kernels import window_filter as _wf


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def jaccard_verify(win_tokens, ent_ids, dict_tokens, token_weight, sim_name: str):
    """Engine-facing verify: gathers entity rows/weights, runs the kernel.

    win_tokens [N, L]; ent_ids [N, K] (-1 invalid); dict_tokens [E, L];
    token_weight [V]. Returns scores [N, K] f32 (0 for invalid ids).
    Falls back to the jnp reference for modes the kernel doesn't fuse.
    """
    if sim_name not in ("extra", "missing"):
        from repro.core.semantics import similarity

        safe = jnp.maximum(ent_ids, 0)
        return similarity(
            sim_name, dict_tokens[safe], win_tokens[:, None, :], token_weight, xp=jnp
        )

    from repro.core.semantics import first_occurrence_mask

    safe = jnp.maximum(ent_ids, 0)
    ent_toks = dict_tokens[safe]  # [N, K, L]
    ent_w = token_weight[ent_toks] * (ent_toks != 0)
    first = first_occurrence_mask(win_tokens, xp=jnp)
    win_w = token_weight[win_tokens] * first
    scores = _jv.jaccard_verify_pallas(
        win_tokens,
        win_w.astype(jnp.float32),
        ent_toks,
        ent_w.astype(jnp.float32),
        mode=sim_name,
        interpret=_interpret(),
    )
    return jnp.where(ent_ids >= 0, scores, 0.0)


def minhash(tokens, valid, bands: int, rows: int):
    """[N, L] tokens -> [N, bands] uint32 banded minhash signatures."""
    return _mh.minhash_pallas(
        tokens, valid, bands=bands, rows=rows, interpret=_interpret()
    )


def window_filter(doc_tokens, bits, num_bits: int, num_hashes: int, max_len: int):
    """[D, T] docs -> [D, T, L] bool window-survival mask (Bloom probe)."""
    return _wf.window_filter_pallas(
        doc_tokens,
        bits,
        num_bits=num_bits,
        num_hashes=num_hashes,
        max_len=max_len,
        interpret=_interpret(),
    )


def fused_probe(
    doc_tokens,
    flt: tuple | None,
    max_len: int,
    sig_mode: str = _fp.SIG_MODE_NONE,
    bands: int = 4,
    rows: int = 2,
):
    """One-pass filter+signature megakernel (the use_kernel fast path).

    ``flt`` is (bits, num_bits, num_hashes) or None (validity only).
    Returns (packed [D, T] uint32 survival bitmap, sigs or None) — see
    ``fused_probe.fused_probe_pallas``; ``sigs`` holds [.., bands]
    MinHash band sigs (``sig_mode="lsh"``) or [.., 2] variant key
    pairs (``sig_mode="variant"``, dense mode).
    """
    packed, sigs, _, _, _ = _probe(
        doc_tokens, flt, max_len, sig_mode, bands, rows, 0
    )
    return packed, sigs


def fused_probe_compact(
    doc_tokens,
    flt: tuple | None,
    max_len: int,
    candidates: int,
    sig_mode: str = _fp.SIG_MODE_NONE,
    bands: int = 4,
    rows: int = 2,
    lane_width: int | None = None,
):
    """``fused_probe`` plus the in-kernel compaction epilogue.

    Returns (packed, sigs, counts [G] int32, cands [G, W] int32, vkeys):
    per grid tile, the true survivor count and the tile's first ``W``
    survivors as ascending global flat window indices (-1 pad), where
    ``W = lane_width or candidates``. With ``sig_mode="variant"`` the
    survivors' key pairs ride along as ``vkeys`` [G, W, 2] uint32 (and
    no dense ``sigs`` tensor is emitted). Combine across tiles with
    ``extraction.results.select_from_tiles`` — no pass over ``packed``
    is needed.

    ``lane_width`` narrows the emitted lanes below the merge capacity
    (the adaptive two-pass emit pass, sized by ``fused_probe_count``);
    the tile height stays derived from ``candidates`` so the count and
    emit passes share one grid — see ``fused_probe.compact_tile_height``.
    """
    if candidates <= 0:
        raise ValueError(
            f"fused_probe_compact(candidates={candidates}): the compaction "
            "epilogue needs a positive [G, NC] lane width (NC = "
            "ExtractParams.max_candidates); use fused_probe() if you only "
            "want the packed survival bitmap"
        )
    if max_len > 32:
        raise ValueError(
            f"fused_probe_compact(max_len={max_len}): the packed survival "
            "bitmap holds one window length per uint32 bit, so the epilogue "
            "supports max_len <= 32; route longer windows through "
            "engine.fused_filter_compact, which falls back to the "
            "standalone window_filter kernel + dense compaction"
        )
    if lane_width is not None and not 0 < lane_width <= candidates:
        raise ValueError(
            f"fused_probe_compact(lane_width={lane_width}): the emit-pass "
            f"lane width must be in (0, candidates={candidates}] — wider "
            "lanes than the merge capacity are never read, and the merge "
            "is only exact when every tile's survivors fit the lane "
            "(choose the width with fused_probe.round_lane_width over "
            "fused_probe_count's per-tile counts)"
        )
    D, T = doc_tokens.shape
    bd = _fp.compact_tile_height(D, T, candidates)
    return _probe(doc_tokens, flt, max_len, sig_mode, bands, rows,
                  lane_width or candidates, bd=bd)


def fused_probe_count(
    doc_tokens,
    flt: tuple | None,
    max_len: int,
    candidates: int,
):
    """Count-only probe pass: per-tile survivor counts, no lane store.

    The cheap first pass of the adaptive two-pass compaction: streams
    the same tiles as ``fused_probe_compact(..., candidates)`` (same
    ``compact_tile_height`` grid, so counts line up tile for tile) but
    emits only the [G] int32 SMEM-accumulated survivor counts. Size the
    emit pass with ``fused_probe.round_lane_width(counts.max(), NC)``.
    """
    if candidates <= 0:
        raise ValueError(
            f"fused_probe_count(candidates={candidates}): the count pass "
            "sizes lanes for a positive merge capacity (NC = "
            "ExtractParams.max_candidates)"
        )
    D, T = doc_tokens.shape
    bd = _fp.compact_tile_height(D, T, candidates)
    _, _, counts, _, _ = _probe(
        doc_tokens, flt, max_len, _fp.SIG_MODE_NONE, 4, 2, candidates,
        bd=bd, count_only=True,
    )
    return counts


def fused_probe_stream(
    doc_tokens,
    flt: tuple | None,
    max_len: int,
    candidates: int,
    row_offs,
    sig_mode: str = _fp.SIG_MODE_NONE,
    bd: int | None = None,
    lane_width: int | None = None,
    count_only: bool = False,
):
    """Single-launch streamed probe over a whole shard (DMA pipeline).

    ``doc_tokens`` [G*bd, T] must be pre-padded so each [bd, T] chunk is
    full height; ``row_offs`` [G] int32 carries each chunk's absolute
    doc-row offset (upstream tile boundaries and shard offsets fold in
    here, which is what keeps flat indices bit-identical to the
    per-tile launch loop). Returns ``(counts [G], cands [G, W], vkeys)``
    — the same wire unit as ``fused_probe_compact`` minus the packed
    bitmap and dense sigs, which the streamed kernel never materialises
    (``sig_mode="lsh"`` therefore raises; streaming paths recompute
    band sigs post-compaction). ``count_only=True`` is the adaptive
    sizing pass: lanes are skipped, only ``counts`` comes back.
    """
    if candidates <= 0:
        raise ValueError(
            f"fused_probe_stream(candidates={candidates}): the streamed "
            "kernel has no bitmap output, so it always runs the compaction "
            "epilogue — a positive merge capacity (NC = "
            "ExtractParams.max_candidates) is required"
        )
    if max_len > 32:
        raise ValueError(
            f"fused_probe_stream(max_len={max_len}): the packed survival "
            "bitmap holds one window length per uint32 bit, so the "
            "streamed epilogue supports max_len <= 32"
        )
    if lane_width is not None and not 0 < lane_width <= candidates:
        raise ValueError(
            f"fused_probe_stream(lane_width={lane_width}): the emit-pass "
            f"lane width must be in (0, candidates={candidates}]"
        )
    if flt is None:
        bits = jnp.zeros((8,), dtype=jnp.uint32)
        num_bits, num_hashes, use_filter = 256, 1, False
    else:
        bits, num_bits, num_hashes = flt
        use_filter = True
    if bd is None:
        bd = _fp.compact_tile_height(doc_tokens.shape[0],
                                     doc_tokens.shape[1], candidates)
    return _fp.fused_probe_stream_pallas(
        doc_tokens,
        bits,
        row_offs,
        num_bits=num_bits,
        num_hashes=num_hashes,
        max_len=max_len,
        sig_mode=sig_mode,
        use_filter=use_filter,
        bd=bd,
        candidates=lane_width or candidates,
        count_only=count_only,
        interpret=_interpret(),
    )


def _probe(doc_tokens, flt, max_len, sig_mode, bands, rows, candidates,
           bd: int = _fp.DEFAULT_BD, count_only: bool = False):
    if flt is None:
        bits = jnp.zeros((8,), dtype=jnp.uint32)
        num_bits, num_hashes, use_filter = 256, 1, False
    else:
        bits, num_bits, num_hashes = flt
        use_filter = True
    return _fp.fused_probe_pallas(
        doc_tokens,
        bits,
        num_bits=num_bits,
        num_hashes=num_hashes,
        max_len=max_len,
        sig_mode=sig_mode,
        bands=bands,
        rows=rows,
        use_filter=use_filter,
        bd=bd,
        candidates=candidates,
        count_only=count_only,
        interpret=_interpret(),
    )

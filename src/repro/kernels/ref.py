"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each function is the bit-semantics reference the kernels are tested
against across shape/dtype sweeps (tests/test_kernels.py). They are also
the CPU fallbacks used when kernels are disabled.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import hashing

_C1 = 0x85EBCA6B
_C2 = 0xC2B2AE35
_GOLDEN = 0x9E3779B9
_BLOOM_SEED_BASE = 9100
_LSH_SEED_BASE = 7000


def jaccard_verify_ref(win_tokens, win_w, ent_tokens, ent_w, mode: str):
    """Weighted containment scores for (window, entity-candidate) pairs.

    win_tokens [N, L] i32 (PAD=0), win_w [N, L] f32 (0 where invalid /
    duplicate), ent_tokens [N, K, L] i32, ent_w [N, K, L] f32 (0 pad).
    mode: "extra" | "missing".
    Returns scores [N, K] f32 = w(e ∩ s) / w(e or s).
    """
    eq = ent_tokens[:, :, :, None] == win_tokens[:, None, None, :]  # [N,K,L,Lw]
    valid = (ent_tokens[:, :, :, None] != 0) & (win_tokens[:, None, None, :] != 0)
    hit = (eq & valid).any(axis=-1)  # entity token appears in window
    inter = (ent_w * hit).sum(axis=-1)  # [N, K]
    we = ent_w.sum(axis=-1)
    ws = win_w.sum(axis=-1)[:, None]
    denom = we if mode == "extra" else jnp.broadcast_to(ws, we.shape)
    scores = inter / jnp.maximum(denom, 1e-30)
    return jnp.where(ws > 0, scores, 0.0).astype(jnp.float32)


def _hash_u32(x, seed):
    off = np.uint32((_GOLDEN * (seed + 1)) & 0xFFFFFFFF)
    x = x.astype(jnp.uint32) + off
    x = x ^ (x >> 16)
    x = x * jnp.uint32(_C1)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(_C2)
    x = x ^ (x >> 16)
    return x


def _combine(h, g):
    return _mix(h ^ (g + jnp.uint32(_GOLDEN) + (h << 6) + (h >> 2)))


def _mix(x):
    x = x ^ (x >> 16)
    x = x * jnp.uint32(_C1)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(_C2)
    x = x ^ (x >> 16)
    return x


def minhash_ref(tokens, valid, bands: int, rows: int):
    """Banded MinHash signatures. tokens [N, L] i32, valid [N, L] bool.

    Returns [N, bands] uint32 — bit-identical to
    ``signatures._minhash_jnp`` (same seeds/combine).
    """
    outs = []
    for b in range(bands):
        mins = []
        for r in range(rows):
            h = _hash_u32(tokens, _LSH_SEED_BASE + b * rows + r)
            h = jnp.where(valid, h, jnp.uint32(0xFFFFFFFF))
            mins.append(h.min(axis=-1))
        band = mins[0]
        for m in mins[1:]:
            band = _combine(band, m)
        band = _combine(band, jnp.full_like(band, jnp.uint32(b + 1)))
        outs.append(band)
    return jnp.stack(outs, axis=-1)


def window_filter_ref(doc_tokens, bits, num_bits: int, num_hashes: int, max_len: int):
    """Fused ISH-filter probe over all (pos, len) windows.

    doc_tokens [D, T] i32; bits [num_bits//32] uint32.
    Returns survive [D, T, L] bool: window (p, l) contains >= 1 token
    probing into the Bloom filter (ignoring PAD validity, which the
    caller combines in).
    """
    hit = jnp.ones(doc_tokens.shape, bool)
    for k in range(num_hashes):
        h = _hash_u32(doc_tokens, _BLOOM_SEED_BASE + k)
        pos = h % jnp.uint32(num_bits)
        word = bits[(pos // 32).astype(jnp.int32)]
        bit = (word >> (pos % 32)) & jnp.uint32(1)
        hit = hit & (bit == 1)
    D, T = doc_tokens.shape
    # window (p, l) covers tokens p..p+l: running-or over shifted hits
    outs = []
    acc = jnp.zeros((D, T), bool)
    shifted = hit
    for l in range(max_len):
        acc = acc | shifted
        outs.append(acc)
        shifted = jnp.concatenate(
            [shifted[:, 1:], jnp.zeros((D, 1), bool)], axis=1
        )
    return jnp.stack(outs, axis=-1)

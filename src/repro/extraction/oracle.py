"""Brute-force numpy oracle: the ground-truth extraction every algorithm
must reproduce (up to each scheme's documented recall caveats)."""
from __future__ import annotations

import numpy as np

from repro.core.dictionary import Dictionary
from repro.core.semantics import similarity
from repro.extraction.substrings import window_base_np


def oracle_extract(
    doc_tokens: np.ndarray,
    dictionary: Dictionary,
    gamma: float,
    sim_name: str = "extra",
    entity_chunk: int = 64,
) -> set[tuple[int, int, int, int]]:
    """All (doc, pos, len, entity) with sim >= gamma, by brute force."""
    D, T = doc_tokens.shape
    L = dictionary.max_len
    base = window_base_np(doc_tokens, L)  # [D, T, L]
    real = base != 0
    valid_len = np.cumprod(real, axis=-1).astype(bool)  # [D, T, L] cand validity

    # candidate tokens [D, T, L(len), L(tok)]
    keep = np.tril(np.ones((L, L), dtype=bool))
    cand = np.where(keep[None, None], base[:, :, None, :], 0).astype(np.int32)
    flat = cand.reshape(-1, L)
    flat_valid = valid_len.reshape(-1)

    out: set[tuple[int, int, int, int]] = set()
    tw = dictionary.token_weight
    E = dictionary.num_entities
    for e0 in range(0, E, entity_chunk):
        ents = dictionary.tokens[e0 : e0 + entity_chunk]  # [C, L]
        sim = similarity(
            sim_name,
            ents[None, :, :],
            flat[:, None, :],
            tw,
            xp=np,
        )  # [N, C]
        hits = (sim >= gamma - 1e-6) & flat_valid[:, None]
        ns, cs = np.nonzero(hits)
        for n, c in zip(ns.tolist(), cs.tolist()):
            d, rem = divmod(n, T * L)
            p, l = divmod(rem, L)
            out.add((d, p, l + 1, e0 + c))
    return out

"""Single-shard extraction cores.

These pure functions are the per-device bodies that the distributed
(shard_map) algorithms in ``extraction/distributed.py`` wrap. Both the
Index-on-Entities and the (ISHFilter &) SSJoin paths share the candidate
machinery: enumerate → (filter) → compact → probe → verify → emit.

Everything is static-shape: candidate and result buffers have fixed
capacities with surfaced overflow counts (never silent truncation).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import hashing
from repro.core.dictionary import PAD, Dictionary
from repro.core.filter import BloomFilter, token_in_filter
from repro.core.index import (
    INDEX_VARIANT,
    InvertedIndex,
    VariantIndex,
    build_inverted_index,
    build_variant_index,
    query_inverted,
    query_variant,
)
from repro.core.signatures import (
    SIG_LSH,
    SIG_NAMES,
    SIG_PREFIX,
    SIG_VARIANT,
    SIG_WORD,
    EntitySignatures,
    LshParams,
    entity_signatures,
    num_window_signatures,
    window_signatures,
)
from repro.core.variants import VARIANT_SEEDS, window_variant_key
from repro.extraction.results import (
    Matches,
    compact_matches,
    gather_from_tiles,
    select_from_tiles,
    select_nonzero,
)
from repro.extraction.substrings import window_base
from repro.extraction.verify import dedup_hits, verify_pairs

_SIGKEY_SEED = 33
# Bucket choice uses an independent hash of the signature so that bucket
# bits do not correlate with the owner-routing bits (sig % ndev) in the
# distributed shuffle — both are powers of two.
_BUCKET_SEED = 47


def _bucket_of(sig, n_buckets: int, *, xp):
    return (hashing.hash_u32(sig, seed=_BUCKET_SEED, xp=xp) % xp.uint32(n_buckets))


@dataclasses.dataclass(frozen=True)
class ExtractParams:
    """Static knobs of one extraction sub-job (one side of a plan).

    Construction validates every cross-field constraint up front (with
    the failing knob and the fix in the message) so misconfigurations
    surface here instead of as a shape/assert error deep inside a
    Pallas kernel.
    """

    gamma: float
    scheme: str  # index kind or signature scheme: word|prefix|lsh|variant
    sim_name: str = "extra"
    use_filter: bool = True
    max_candidates: int = 4096
    result_capacity: int = 4096
    lsh: LshParams = LshParams()
    use_kernel: bool = False
    # use_kernel only: compact candidates inside the fused_probe epilogue
    # (per-tile count + packed-index lanes). None resolves to
    # ``use_kernel`` (the epilogue lives inside the kernel, so it is the
    # default exactly when the kernel path is on). False keeps the
    # legacy XLA cumsum+searchsorted pass over the packed bitmap as a
    # live fallback.
    kernel_compact: bool | None = None
    # kernel_compact only: adaptive two-pass lane compaction — a cheap
    # count-only probe pass sizes the emit pass's lane width to the
    # measured per-tile survivor maximum (exact at any density) instead
    # of paying worst-case [G, NC] lanes. Needs a host sync between the
    # passes, so it is rejected under jit tracing.
    adaptive_lanes: bool = False
    # adaptive_lanes only: floor (and power-of-two rounding base) for
    # the adaptive emit-pass lane width. None -> fused_probe.MIN_LANE_WIDTH.
    lane_width: int | None = None
    # use_kernel only: emit window signatures inside the fused kernel.
    # None = auto (variant: lane-resident keys whenever the compaction
    # epilogue runs, dense tensor in the high-density regime; lsh: dense
    # tensor in the high-density regime — see ``resolve_sig_mode``).
    # True forces in-kernel emission (rejected for word/prefix, which
    # have no in-kernel recurrence); False forces the post-compaction
    # jnp signature path.
    kernel_sigs: bool | None = None
    # kernel_compact only: run whole shards through the single-launch
    # streamed megakernel (in-kernel double-buffered DMA over the tile
    # loop, ``ops.fused_probe_stream``) instead of one ``pallas_call``
    # per tile. None = auto: the streaming drivers stream whenever a
    # shard spans >= 2 tiles (a single tile has no pipeline to win).
    # True forces the streamed launch even for one tile; False pins the
    # per-tile launch loop (the parity baseline).
    streamed: bool | None = None

    def __post_init__(self):
        if self.kernel_compact is None:
            object.__setattr__(self, "kernel_compact", self.use_kernel)
        if self.scheme not in SIG_NAMES:
            raise ValueError(
                f"ExtractParams.scheme={self.scheme!r} is not a known "
                f"index kind / signature scheme; pick one of {SIG_NAMES}"
            )
        if not 0.0 < self.gamma <= 1.0:
            raise ValueError(
                f"ExtractParams.gamma={self.gamma} must be in (0, 1]: it is "
                "the similarity threshold of Def. 1 (1.0 = exact match)"
            )
        if self.max_candidates <= 0:
            raise ValueError(
                f"ExtractParams.max_candidates={self.max_candidates} must be "
                "positive: it is the static candidate-buffer capacity (and "
                "the [G, NC] lane width of ops.fused_probe_compact — the "
                "select_from_tiles merge requires lane width >= capacity)"
            )
        if self.result_capacity <= 0:
            raise ValueError(
                f"ExtractParams.result_capacity={self.result_capacity} must "
                "be positive: it is the static Matches-buffer capacity"
            )
        if self.lsh.bands <= 0 or self.lsh.rows <= 0:
            raise ValueError(
                f"ExtractParams.lsh bands={self.lsh.bands} rows="
                f"{self.lsh.rows} must both be positive"
            )
        if self.kernel_compact and not self.use_kernel:
            raise ValueError(
                "ExtractParams(kernel_compact=True) requires use_kernel=True: "
                "the compaction epilogue runs inside the fused_probe Pallas "
                "kernel, so there is no epilogue to enable on the unfused "
                "path (set use_kernel=True, or leave kernel_compact unset "
                "to track use_kernel automatically)"
            )
        if self.adaptive_lanes and not self.kernel_compact:
            raise ValueError(
                "ExtractParams(adaptive_lanes=True) requires "
                "kernel_compact=True: the two-pass lane sizing narrows the "
                "compaction epilogue's [G, NC] lanes, so there are no lanes "
                "to size on the legacy XLA compaction path (set "
                "use_kernel=True and leave kernel_compact unset, or drop "
                "adaptive_lanes)"
            )
        if self.lane_width is not None and not self.adaptive_lanes:
            raise ValueError(
                f"ExtractParams(lane_width={self.lane_width}) requires "
                "adaptive_lanes=True: lane_width is the floor of the "
                "adaptive emit-pass width — a fixed width below "
                "max_candidates cannot guarantee bit-exact lane merges, so "
                "the one-pass path always emits full [G, NC] lanes (enable "
                "adaptive_lanes, or drop lane_width)"
            )
        if self.lane_width is not None and not (
            0 < self.lane_width <= self.max_candidates
        ):
            raise ValueError(
                f"ExtractParams(lane_width={self.lane_width}) must be in "
                f"(0, max_candidates={self.max_candidates}]: it floors the "
                "adaptive emit-pass lane width, and lanes wider than the "
                "select_from_tiles merge capacity are never read"
            )
        if self.streamed and not self.kernel_compact:
            raise ValueError(
                "ExtractParams(streamed=True) requires kernel_compact=True: "
                "the streamed megakernel has no packed-bitmap output — its "
                "only products are the compaction epilogue's per-tile "
                "count/index lanes, so there is nothing to stream on the "
                "legacy XLA compaction path (set use_kernel=True and leave "
                "kernel_compact unset, or drop streamed)"
            )
        if self.kernel_sigs and not self.use_kernel:
            raise ValueError(
                "ExtractParams(kernel_sigs=True) requires use_kernel=True: "
                "in-kernel signature emission happens inside the fused_probe "
                "megakernel (set use_kernel=True, or leave kernel_sigs unset "
                "to let resolve_sig_mode decide)"
            )
        if self.kernel_sigs and self.scheme in (SIG_WORD, SIG_PREFIX):
            raise ValueError(
                f"ExtractParams(kernel_sigs=True, scheme={self.scheme!r}): "
                "the word/prefix schemes have no in-kernel signature "
                "recurrence — their window-side signatures are plain token "
                "hashes computed post-compaction, which previously made "
                "this combination fall back silently; use scheme='lsh' or "
                "'variant', or leave kernel_sigs unset"
            )


def check_flat_index_space(D: int, T: int, max_len: int) -> None:
    """Fail fast (and actionably) when flat window indices overflow int32.

    The [G, NC] candidate lanes carry flat (doc*T + pos)*L + (len-1)
    window indices as int32 end to end; past 2**31 the row offsets in
    ``sharded.stream_probe_tiles`` would wrap silently. Checked at every
    lane-producing entry point (sharded driver, serving pipeline).
    """
    if D * T * max_len >= 2**31:
        raise ValueError(
            f"flat window index space D*T*L = {D}x{T}x{max_len} = "
            f"{D * T * max_len} overflows int32 lane indices; split the "
            "corpus into separate driver calls (or shrink shard/batch rows)"
        )


@dataclasses.dataclass
class DeviceDictionary:
    """Device-resident dictionary slice (tokens + weights)."""

    tokens: jnp.ndarray  # [E, L] int32
    token_weight: jnp.ndarray  # [V] f32
    entity_offset: int  # global id of entity 0 in this slice

    @classmethod
    def from_host(cls, d: Dictionary, entity_offset: int = 0) -> "DeviceDictionary":
        return cls(
            tokens=jnp.asarray(d.tokens),
            token_weight=jnp.asarray(d.token_weight),
            entity_offset=entity_offset,
        )


# --------------------------------------------------------------------------
# Candidate gathering (shared front end; fused-filter Pallas kernel target)
# --------------------------------------------------------------------------


def survival_mask(doc_tokens, max_len: int, flt: tuple | None, use_kernel: bool = False):
    """[D,T] docs -> (base [D,T,L], survive [D,T,L]).

    ``flt`` is (bits, num_bits, num_hashes) or None. Candidate (p, l)
    survives iff valid (no PAD inside) and — when filtering — at least
    one of its tokens probes into the Bloom filter.
    """
    base = window_base(doc_tokens, max_len)
    real = base != PAD
    valid = jnp.cumprod(real.astype(jnp.int32), axis=-1).astype(bool)
    if flt is None:
        return base, valid
    bits, num_bits, num_hashes = flt
    if use_kernel:
        from repro.kernels import ops as kops

        surv = kops.window_filter(doc_tokens, bits, num_bits, num_hashes, max_len)
    else:
        tok_hit = token_in_filter(bits, num_bits, num_hashes, base)
        surv = jnp.cumsum(tok_hit.astype(jnp.int32), axis=-1) > 0
    return base, valid & surv


def _compact_bit_indices(rows, max_candidates: int):
    """rows [M, L] bool -> ascending flat set-bit indices [NC] (-1 pad).

    Two-stage static-shape compaction: survivor density is low (the
    whole point of the ISH filter), so a flat ``nonzero`` over M*L
    elements is the pipeline bottleneck — selecting the (at most NC)
    rows with any set bit first shrinks the second ``nonzero`` to NC*L
    elements (~5x wall-clock on CPU at D128xT512xL8). Exact at any
    density: every selected row holds >= 1 set bit, so NC rows always
    cover the first NC set bits.
    """
    M, L = rows.shape
    starts, _ = select_nonzero(rows.any(axis=-1), max_candidates)
    sub = rows[jnp.maximum(starts, 0)] & (starts >= 0)[:, None]  # [NC, L]
    sel, ok = select_nonzero(sub.reshape(-1), max_candidates)
    safe = jnp.maximum(sel, 0)
    idx = jnp.maximum(starts[safe // L], 0) * L + safe % L
    return jnp.where(ok, idx, -1), ok


def compact_candidates(base, survive, max_candidates: int):
    """Flatten surviving candidates into fixed-capacity buffers.

    Returns dict with win_tokens [N, L], doc/pos/length [N] (-1 pad),
    n_survive [] and overflow [] counters.
    """
    D, T, L = base.shape
    idx, ok = _compact_bit_indices(survive.reshape(-1, L), max_candidates)
    flat = survive.reshape(-1)
    safe = jnp.maximum(idx, 0)
    d = safe // (T * L)
    rem = safe % (T * L)
    p = rem // L
    l = rem % L  # length-1
    toks = base[d, p]  # [N, L]
    lens_mask = jnp.arange(L)[None, :] <= l[:, None]
    toks = jnp.where(lens_mask & ok[:, None], toks, PAD)
    n = flat.sum().astype(jnp.int32)
    return dict(
        win_tokens=toks.astype(jnp.int32),
        win_valid=ok,
        doc=jnp.where(ok, d, -1).astype(jnp.int32),
        pos=jnp.where(ok, p, -1).astype(jnp.int32),
        length=jnp.where(ok, l + 1, -1).astype(jnp.int32),
        n_survive=n,
        overflow=jnp.maximum(n - max_candidates, 0).astype(jnp.int32),
    )


def candidates_from_flat(doc_tokens, flat_idx, ok, n_survive, max_len: int,
                         max_candidates: int) -> dict:
    """Build the ``compact_candidates`` dict from selected flat indices.

    ``flat_idx`` [N] are (doc*T + pos)*max_len + (len-1) window indices
    (already clamped >= 0 where ``ok`` is False); windows are gathered
    straight from the [D, T] token rows — no [D,T,L] base tensor. Shared
    tail of the fused single-call, legacy-XLA, and sharded-streaming
    compaction paths, so they stay field-for-field identical.
    """
    D, T = doc_tokens.shape
    L = max_len
    safe = jnp.maximum(flat_idx, 0)
    d = safe // (T * L)
    rem = safe % (T * L)
    p = rem // L
    l = rem % L  # length-1
    cols = p[:, None] + jnp.arange(L)[None, :]  # [N, L]
    toks = doc_tokens[d[:, None], jnp.minimum(cols, T - 1)]
    lens_mask = (jnp.arange(L)[None, :] <= l[:, None]) & (cols < T)
    toks = jnp.where(lens_mask & ok[:, None], toks, PAD)
    n = n_survive.astype(jnp.int32)
    return dict(
        win_tokens=toks.astype(jnp.int32),
        win_valid=ok,
        doc=jnp.where(ok, d, -1).astype(jnp.int32),
        pos=jnp.where(ok, p, -1).astype(jnp.int32),
        length=jnp.where(ok, l + 1, -1).astype(jnp.int32),
        n_survive=n,
        overflow=jnp.maximum(n - max_candidates, 0).astype(jnp.int32),
    )


def candidates_from_flat_host(doc_tokens, flat_idx, ok, n_survive,
                              max_len: int, max_candidates: int) -> dict:
    """``candidates_from_flat`` with the window gather on the *host*.

    The spill-streaming driver selects candidates from per-shard lanes
    without the corpus ever being device-resident, so the final [N, L]
    window gather must read token rows from the host corpus (typically
    a ``np.memmap`` — fancy-indexing it touches only the ~N needed
    rows, not the file). Field-for-field and bit-identical to the
    device gather; only the produced [N, L] windows (N = NC, tiny) are
    shipped to the device.
    """
    T = doc_tokens.shape[1]
    L = max_len
    flat = np.asarray(flat_idx)
    okh = np.asarray(ok)
    safe = np.maximum(flat, 0).astype(np.int64)
    d = safe // (T * L)
    rem = safe % (T * L)
    p = rem // L
    l = rem % L  # length-1
    rows = np.asarray(doc_tokens[d])  # [N, T]: the only corpus touch
    cols = p[:, None] + np.arange(L)[None, :]  # [N, L]
    toks = rows[np.arange(rows.shape[0])[:, None], np.minimum(cols, T - 1)]
    lens_mask = (np.arange(L)[None, :] <= l[:, None]) & (cols < T)
    toks = np.where(lens_mask & okh[:, None], toks, PAD)
    n = np.int32(np.asarray(n_survive))
    return dict(
        win_tokens=jnp.asarray(toks.astype(np.int32)),
        win_valid=jnp.asarray(okh),
        doc=jnp.asarray(np.where(okh, d, -1).astype(np.int32)),
        pos=jnp.asarray(np.where(okh, p, -1).astype(np.int32)),
        length=jnp.asarray(np.where(okh, l + 1, -1).astype(np.int32)),
        n_survive=jnp.asarray(n),
        overflow=jnp.asarray(np.int32(max(int(n) - max_candidates, 0))),
    )


def attach_kernel_sigs(cands: dict, kernel_sigs, params: ExtractParams) -> dict:
    """Gather in-kernel [D,T,L,B] band sigs at the compacted candidates.

    Padded slots carry the all-invalid-window band constants so the
    tensor stays bit-identical to ``window_signatures`` on them too.
    """
    from repro.kernels.fused_probe import empty_band_sigs

    ok = cands["win_valid"]
    d = jnp.maximum(cands["doc"], 0)
    p = jnp.maximum(cands["pos"], 0)
    l = jnp.maximum(cands["length"] - 1, 0)
    gathered = kernel_sigs[d, p, l]  # [N, B]
    empty = jnp.asarray(empty_band_sigs(params.lsh.bands, params.lsh.rows))
    cands["sigs"] = jnp.where(ok[:, None], gathered, empty[None, :])
    cands["sig_mask"] = jnp.broadcast_to(ok[:, None], gathered.shape)
    return cands


def resolve_sig_mode(params: ExtractParams, D: int, T: int, L: int) -> str:
    """Pick the kernel's in-kernel signature emission mode for a shape.

    * ``lsh`` — band-sig emission computes minima for every (pos, len)
      window and stores a [D,T,L,B] tensor: profitable only when the
      compacted candidate stream covers the whole window grid (then the
      post-compaction re-gather would move the same bytes); in the
      filter's target low-density regime, post-compaction signatures
      over [N, L] windows are far less work. ``kernel_sigs=True``
      forces dense emission regardless.
    * ``variant`` — with the compaction epilogue the key pairs ride the
      candidate lanes ([G, NC, 2], no dense tensor), which is cheap at
      *any* density, so the fused path is the default whenever the
      epilogue runs; without the epilogue the dense [D,T,L,2] tensor
      follows the same density rule as lsh (or ``kernel_sigs=True``).
    * ``kernel_sigs=False`` forces the post-compaction jnp path.
    """
    from repro.kernels.fused_probe import (
        SIG_MODE_LSH,
        SIG_MODE_NONE,
        SIG_MODE_VARIANT,
    )

    if params.kernel_sigs is False:
        return SIG_MODE_NONE
    forced = params.kernel_sigs is True
    dense = params.max_candidates >= D * T * L
    if params.scheme == SIG_LSH and (dense or forced):
        return SIG_MODE_LSH
    if params.scheme == SIG_VARIANT and (
        params.kernel_compact or dense or forced
    ):
        return SIG_MODE_VARIANT
    return SIG_MODE_NONE


def attach_variant_keys(cands: dict, keys) -> dict:
    """Attach fused variant key pairs [N, 2] to compacted candidates.

    Sets ``sigs``/``sig_mask`` bit-identically to
    ``window_signatures("variant", ...)`` over the gathered windows
    (the window-side SSJoin signature is key1) and ``variant_keys`` =
    (k1, k2) for the variant index probe (``extract_index_part``).
    Padded slots carry 0 — the ``set_hash`` of an all-PAD window under
    either seed — so no consumer needs a special case for them.
    """
    ok = cands["win_valid"]
    k1 = jnp.where(ok, keys[:, 0], jnp.uint32(0))
    k2 = jnp.where(ok, keys[:, 1], jnp.uint32(0))
    cands["sigs"] = k1[:, None]
    cands["sig_mask"] = ok[:, None]
    cands["variant_keys"] = (k1, k2)
    return cands


def fused_filter_compact(
    doc_tokens,
    max_len: int,
    flt: tuple | None,
    params: ExtractParams,
    sig_mode: str | None = None,
) -> dict:
    """use_kernel fast path: one-pass megakernel -> direct compaction.

    Replaces ``survival_mask`` + ``compact_candidates`` (and, for the
    LSH scheme, ``window_signatures``) with a single streaming
    ``fused_probe`` kernel pass: the [D,T,L] int32 base and int8 mask
    are never materialised — survival arrives as a packed [D,T] uint32
    bitmap, candidate windows are gathered straight from the [D,T]
    token array, and LSH band signatures come out of the kernel
    (bit-identical to ``window_signatures``; padded slots carry the
    all-invalid-window band constants). Returns the ``compact_candidates``
    dict, plus ``sigs``/``sig_mask`` when the scheme is ``lsh``.

    Candidate selection runs in the kernel's compaction epilogue by
    default (per-tile survivor counts + packed-index lanes merged by
    ``select_from_tiles``; the [D, T] bitmap is never re-read).
    ``params.kernel_compact=False`` keeps the legacy two-stage XLA
    compaction over the packed bitmap — same outputs, exercised by tests
    so the fallback cannot rot.

    For the ``variant`` scheme the kernel emits both 32-bit set-hash
    keys in-kernel (lane payload with the epilogue, dense tensor on the
    legacy path in the high-density regime) — bit-identical to
    ``core.variants.window_variant_key`` over the gathered windows.
    ``params.adaptive_lanes`` enables the two-pass lane compaction: a
    count-only pass measures per-tile survivor maxima, the emit pass
    then runs with ``round_lane_width``-sized lanes (exact merge at any
    density). The sizing needs a host sync, so adaptive runs cannot be
    traced under jit — call un-jitted (every step here is jitted
    internally) or drop ``adaptive_lanes``.
    """
    import numpy as _np

    from repro.kernels import ops as kops
    from repro.kernels.fused_probe import (
        MIN_LANE_WIDTH,
        SIG_MODE_LSH,
        SIG_MODE_VARIANT,
        round_lane_width,
    )

    D, T = doc_tokens.shape
    L = max_len
    if L > 32:
        # the packed bitmap holds one length per uint32 bit; longer
        # windows fall back to the standalone window_filter kernel +
        # dense compaction (still a single streaming probe pass)
        base, surv = survival_mask(doc_tokens, max_len, flt, use_kernel=True)
        return compact_candidates(base, surv, params.max_candidates)
    if sig_mode is None:
        sig_mode = resolve_sig_mode(params, D, T, L)
    lsh = sig_mode == SIG_MODE_LSH
    var = sig_mode == SIG_MODE_VARIANT
    NC = params.max_candidates
    keys = None
    if params.kernel_compact:
        lane_w = None
        if params.adaptive_lanes:
            if isinstance(doc_tokens, jax.core.Tracer):
                raise ValueError(
                    "ExtractParams(adaptive_lanes=True) cannot run under "
                    "jit tracing: sizing the emit pass's lane width needs "
                    "a host read of the count pass's per-tile survivor "
                    "maxima; call fused_filter_compact un-jitted (its "
                    "kernel passes are jitted internally) or use the "
                    "fixed worst-case lanes"
                )
            counts0 = kops.fused_probe_count(doc_tokens, flt, max_len, NC)
            mx = int(_np.asarray(counts0).max())
            lane_w = round_lane_width(
                mx, NC, params.lane_width or MIN_LANE_WIDTH
            )
        # in-kernel compaction epilogue: per-tile survivor counts and
        # ascending packed-index lanes; the O(G + NC) merge below is the
        # only XLA-side work — no pass over the [D, T] bitmap.
        packed, kernel_sigs, counts, tiles, vkeys = kops.fused_probe_compact(
            doc_tokens, flt, max_len, NC, sig_mode,
            params.lsh.bands, params.lsh.rows, lane_width=lane_w,
        )
        sel, ok, n = select_from_tiles(
            counts, tiles, NC, complete_tiles=lane_w is not None
        )
        if var:
            keys = gather_from_tiles(counts, vkeys, NC)  # [NC, 2]
    else:
        packed, kernel_sigs = kops.fused_probe(
            doc_tokens, flt, max_len, sig_mode, params.lsh.bands, params.lsh.rows
        )
        # legacy two-stage compaction off the packed bitmap: nonzero over
        # the [D*T] word stream, then unpack only the selected words' bits
        # — the [D,T,L] bool survival tensor is never materialised.
        shifts = jnp.arange(L, dtype=jnp.uint32)
        flat_words = packed.reshape(-1)
        starts, _ = select_nonzero(flat_words != 0, NC)
        words = flat_words[jnp.maximum(starts, 0)] * (starts >= 0)
        sub = ((words[:, None] >> shifts[None, :]) & jnp.uint32(1)).astype(bool)
        ssel, ok = select_nonzero(sub.reshape(-1), NC)
        ssafe = jnp.maximum(ssel, 0)
        sel = jnp.maximum(starts[ssafe // L], 0) * L + ssafe % L
        n = jax.lax.population_count(packed).sum().astype(jnp.int32)
        if var:
            # dense [D, T, L, 2] key tensor: gather at the selection
            safe = jnp.maximum(sel, 0)
            d, rem = safe // (T * L), safe % (T * L)
            keys = kernel_sigs[d, rem // L, rem % L]  # [NC, 2]
    cands = candidates_from_flat(doc_tokens, sel, ok, n, max_len, NC)
    if lsh:
        cands = attach_kernel_sigs(cands, kernel_sigs, params)
    if var:
        cands = attach_variant_keys(cands, keys)
    return cands


def window_sigs_for(cands: dict, params: ExtractParams):
    """Window signatures for compacted candidates: kernel-emitted when
    the fused path provided them (``cands["sigs"]``), else computed from
    the gathered windows. Returns (sigs [N, S], mask [N, S]); callers
    still AND the mask with ``cands["win_valid"]``."""
    if "sigs" in cands:
        return cands["sigs"], cands["sig_mask"]
    toks = cands["win_tokens"]
    return window_signatures(params.scheme, toks, toks != PAD, params.gamma, params.lsh)


def _emit(cands, hits, scores, ent_global, params: ExtractParams) -> Matches:
    """Flatten per-candidate [N,K] hits into a Matches buffer."""
    N, K = hits.shape
    rep = lambda a: jnp.repeat(a, K)
    return compact_matches(
        hits.reshape(-1),
        rep(cands["doc"]),
        rep(cands["pos"]),
        rep(cands["length"]),
        ent_global.reshape(-1),
        scores.reshape(-1),
        params.result_capacity,
    )


# --------------------------------------------------------------------------
# Index-on-Entities (§3.2): broadcast index, local lookups, multi-pass
# --------------------------------------------------------------------------


@dataclasses.dataclass
class BuiltIndex:
    """One memory-budget partition of an entity index (device arrays)."""

    kind: str
    entity_offset: int
    # inverted kinds
    postings: jnp.ndarray | None = None  # [V, P]
    # variant kind
    keys1: jnp.ndarray | None = None
    keys2: jnp.ndarray | None = None
    ents: jnp.ndarray | None = None
    n_buckets: int = 0
    nbytes: int = 0


def build_index_partitions(
    dictionary: Dictionary,
    kind: str,
    gamma: float,
    memory_budget_bytes: int,
    entity_offset: int = 0,
) -> list[BuiltIndex]:
    """Split entities into ranges whose index each fits the budget
    (Def. 3's |E| / M_e multi-pass structure)."""
    E = dictionary.num_entities
    if E == 0:
        return []
    parts: list[BuiltIndex] = []
    start = 0
    # Estimate bytes/entity from a probe build on a small slice, then
    # partition; rebuild per part (host-side, cheap vs corpus work).
    probe = dictionary.slice(0, min(E, 256))
    if kind == INDEX_VARIANT:
        probe_idx = build_variant_index(probe, gamma)
    else:
        probe_idx = build_inverted_index(probe, kind, gamma)
    per_entity = max(probe_idx.nbytes / probe.num_entities, 1.0)
    chunk = max(int(memory_budget_bytes / per_entity), 1)
    while start < E:
        stop = min(start + chunk, E)
        sl = dictionary.slice(start, stop)
        if kind == INDEX_VARIANT:
            vi = build_variant_index(sl, gamma)
            parts.append(
                BuiltIndex(
                    kind=kind,
                    entity_offset=entity_offset + start,
                    keys1=jnp.asarray(vi.keys1),
                    keys2=jnp.asarray(vi.keys2),
                    ents=jnp.asarray(vi.entity_id),
                    n_buckets=vi.n_buckets,
                    nbytes=vi.nbytes,
                )
            )
        else:
            ii = build_inverted_index(sl, kind, gamma)
            parts.append(
                BuiltIndex(
                    kind=kind,
                    entity_offset=entity_offset + start,
                    postings=jnp.asarray(ii.postings_padded),
                    nbytes=ii.nbytes,
                )
            )
        start = stop
    return parts


def extract_index_part(
    cands: dict,
    part: BuiltIndex,
    ddict: DeviceDictionary,
    params: ExtractParams,
) -> Matches:
    """One pass of index lookups + verification over compacted candidates."""
    toks, ok = cands["win_tokens"], cands["win_valid"]
    if part.kind == INDEX_VARIANT:
        if "variant_keys" in cands:
            # fused path: both set-hash keys were computed in-kernel
            # (bit-identical to window_variant_key, incl. padded slots)
            k1, k2 = cands["variant_keys"]
        else:
            k1, k2 = window_variant_key(toks, toks != PAD, xp=jnp)
        ents = query_variant(part.keys1, part.keys2, part.ents, part.n_buckets, k1, k2)
        ents = jnp.where(ok[:, None], ents, -1)
        hits, scores = verify_pairs(
            toks,
            ents + jnp.int32(part.entity_offset - ddict.entity_offset) * (ents >= 0),
            ddict.tokens,
            ddict.token_weight,
            gamma=0.0,  # variant lookups are exact: no threshold re-check
            sim_name=params.sim_name,
            use_kernel=params.use_kernel,
        )
    else:
        local = query_inverted(part.postings, toks, toks != PAD)  # [N, L*P]
        local = jnp.where(ok[:, None], local, -1)
        hits, scores = verify_pairs(
            toks,
            local + jnp.int32(part.entity_offset - ddict.entity_offset) * (local >= 0),
            ddict.tokens,
            ddict.token_weight,
            gamma=params.gamma,
            sim_name=params.sim_name,
            use_kernel=params.use_kernel,
        )
        ents = local
    hits = dedup_hits(hits, ents)
    ent_global = jnp.where(ents >= 0, ents + part.entity_offset, -1)
    return _emit(cands, hits, scores, ent_global, params)


# --------------------------------------------------------------------------
# (ISHFilter &) SSJoin (§3.1/3.3): signature probe against a sig table
# --------------------------------------------------------------------------


@dataclasses.dataclass
class SigTable:
    """Static bucketed hash table: signature -> entity ids."""

    keys1: jnp.ndarray  # [B, cap] uint32
    keys2: jnp.ndarray
    ents: jnp.ndarray  # [B, cap] int32, -1 pad
    n_buckets: int
    bucket_cap: int
    entity_offset: int
    nbytes: int = 0
    skew: float = 1.0  # max/mean bucket load (feeds the cost model)


def build_sig_table(
    esigs: EntitySignatures,
    entity_offset: int = 0,
    load_factor: float = 0.5,
) -> SigTable:
    sig = esigs.sig.astype(np.uint32)
    n = max(len(sig), 1)
    n_buckets = 1 << max(3, int(np.ceil(np.log2(n / load_factor + 1))))
    k2 = hashing.hash_u32(sig, seed=_SIGKEY_SEED, xp=np)
    bucket = _bucket_of(sig, n_buckets, xp=np).astype(np.int64)
    counts = np.bincount(bucket, minlength=n_buckets)
    cap = max(4, int(counts.max()) if counts.size else 4)
    keys1 = np.zeros((n_buckets, cap), dtype=np.uint32)
    keys2 = np.zeros((n_buckets, cap), dtype=np.uint32)
    ents = np.full((n_buckets, cap), -1, dtype=np.int32)
    if len(sig):
        # vectorised bucket fill: stable argsort groups rows by bucket
        # (preserving insertion order within each), the rank-in-bucket is
        # position minus the bucket's first position, and one fancy
        # scatter lands every row — no Python-level loop over signatures.
        order = np.argsort(bucket, kind="stable")
        sb = bucket[order]
        rank = np.arange(len(sig)) - np.searchsorted(sb, sb)
        keys1[sb, rank] = sig[order]
        keys2[sb, rank] = k2[order]
        ents[sb, rank] = esigs.entity_id[order]
    mean = max(counts.mean(), 1e-9)
    return SigTable(
        keys1=jnp.asarray(keys1),
        keys2=jnp.asarray(keys2),
        ents=jnp.asarray(ents),
        n_buckets=n_buckets,
        bucket_cap=cap,
        entity_offset=entity_offset,
        nbytes=int(keys1.nbytes + keys2.nbytes + ents.nbytes),
        skew=float(counts.max() / mean) if counts.size else 1.0,
    )


def probe_sig_table(table: SigTable, sigs, sig_mask):
    """sigs [N, S] uint32 -> candidate entities [N, S*cap] (-1 invalid)."""
    k2 = hashing.hash_u32(sigs, seed=_SIGKEY_SEED, xp=jnp)
    b = _bucket_of(sigs, table.n_buckets, xp=jnp).astype(jnp.int32)
    tk1, tk2, te = table.keys1[b], table.keys2[b], table.ents[b]  # [N,S,cap]
    hit = (tk1 == sigs[..., None]) & (tk2 == k2[..., None]) & (te >= 0)
    hit = hit & sig_mask[..., None]
    ents = jnp.where(hit, te, -1)
    return ents.reshape(ents.shape[0], -1)


def extract_ssjoin_local(
    cands: dict,
    table: SigTable,
    ddict: DeviceDictionary,
    params: ExtractParams,
) -> Matches:
    """SSJoin probe+verify with the signature table fully local.

    The distributed version routes candidates to the table's owner
    device between ``window_signatures`` and ``probe_sig_table``.
    """
    toks, ok = cands["win_tokens"], cands["win_valid"]
    sigs, mask = window_sigs_for(cands, params)
    ents = probe_sig_table(table, sigs, mask & ok[:, None])
    gamma = 0.0 if params.scheme == SIG_VARIANT else params.gamma
    hits, scores = verify_pairs(
        toks,
        ents + jnp.int32(table.entity_offset - ddict.entity_offset) * (ents >= 0),
        ddict.tokens,
        ddict.token_weight,
        gamma=gamma,
        sim_name=params.sim_name,
        use_kernel=params.use_kernel,
    )
    hits = dedup_hits(hits, ents)
    ent_global = jnp.where(ents >= 0, ents + table.entity_offset, -1)
    return _emit(cands, hits, scores, ent_global, params)

"""Distributed extraction: the MapReduce algorithms on a jax mesh (§3).

Mapping (see DESIGN.md §2):

* mappers            -> per-device bodies under ``shard_map`` over the
                        worker axes (documents sharded along them)
* broadcast of index -> replicated device arrays
* shuffle on sig key -> capacity-bounded ``jax.lax.all_to_all`` routed by
                        ``sig % n_workers`` (MoE-style dispatch: sort by
                        owner, scatter into per-destination slots, drop +
                        count overflow)
* reducers           -> the signature-table shard owned by each device,
                        probed after the exchange; verification runs
                        against a *replicated* dictionary (beyond-paper
                        tweak: the dictionary is orders of magnitude
                        smaller than the shuffled candidate stream, so we
                        replicate it instead of shuffling entity records
                        as Hadoop does)

Both algorithms return per-device ``Matches`` buffers (left sharded —
result sets stay distributed, as in MapReduce output files) plus a
``ShuffleDiag`` with measured bytes / skew / overflow so the benchmarks
can validate the cost model against reality.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import axis_size, shard_map

from repro.core import hashing
from repro.core.dictionary import PAD
from repro.core.signatures import EntitySignatures, num_window_signatures
from repro.extraction import engine, sharded
from repro.extraction.results import Matches, compact_matches, merge_matches
from repro.extraction.verify import dedup_hits, verify_pairs

_META_FIELDS = 3  # doc, pos, len


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ShuffleDiag:
    """Measured shuffle statistics (feed the cost-model validation)."""

    sent: jnp.ndarray  # [] records actually routed
    send_overflow: jnp.ndarray  # [] records dropped to capacity
    bytes_shuffled: jnp.ndarray  # [] payload bytes over the interconnect
    max_received: jnp.ndarray  # [] max per-device received records
    mean_received: jnp.ndarray  # [] mean per-device received records


def worker_index(axis_names: tuple[str, ...]) -> jnp.ndarray:
    """Flat worker id across (possibly several) mesh axes."""
    idx = jnp.int32(0)
    for name in axis_names:
        idx = idx * axis_size(name) + jax.lax.axis_index(name)
    return idx


def num_workers(mesh: Mesh, axis_names: tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axis_names]))


# --------------------------------------------------------------------------
# Index-on-Entities, distributed: replicate index, map-side everything
# --------------------------------------------------------------------------


def distributed_extract_index(
    mesh: Mesh,
    axis_names: tuple[str, ...],
    doc_tokens,  # [D, T] global, sharded over axis_names
    side,  # eejoin.PreparedSide with index_parts
    max_len: int,
):
    """Run the index algorithm; returns sharded Matches (doc ids global)."""
    n = num_workers(mesh, axis_names)
    D = doc_tokens.shape[0]
    assert D % n == 0, f"docs {D} must divide workers {n}"
    dl = D // n
    params = side.params

    def body(docs):
        docs = docs.reshape(dl, -1)
        if params.use_kernel:
            # per-device double-buffered tile stream (same lanes + merge
            # as the sharded driver; doc ids stay shard-local here)
            cands = sharded.stream_filter_compact(docs, max_len, side.flt, params)
        else:
            base, surv = engine.survival_mask(docs, max_len, side.flt, False)
            cands = engine.compact_candidates(base, surv, params.max_candidates)
        out = None
        for part in side.index_parts:
            m = engine.extract_index_part(cands, part, side.ddict, params)
            out = m if out is None else merge_matches(m, out, params.result_capacity)
        # globalise doc ids
        off = worker_index(axis_names) * dl
        doc = jnp.where(out.doc >= 0, out.doc + off, -1)
        return dataclasses.replace(out, doc=doc, count=jax.lax.psum(out.count, axis_names))

    spec = P(axis_names)
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(spec,),
        out_specs=Matches(
            doc=spec, pos=spec, length=spec, entity=spec, score=spec, count=P()
        ),
        check_vma=False,
    )
    return fn(doc_tokens)


# --------------------------------------------------------------------------
# ISHFilter & SSJoin, distributed: signature-routed all_to_all shuffle
# --------------------------------------------------------------------------


def build_sharded_sig_tables(
    esigs: EntitySignatures, n_workers_: int, entity_offset: int = 0
) -> tuple[engine.SigTable, float]:
    """Partition entity signatures by owner and build per-owner tables
    with a common static (n_buckets, cap); stacked along axis 0."""
    owner = (esigs.sig % np.uint32(n_workers_)).astype(np.int64)
    # common static geometry across all shards
    per_owner = np.bincount(owner, minlength=n_workers_)
    n_max = max(int(per_owner.max()) if per_owner.size else 1, 1)
    n_buckets = 1 << max(3, int(np.ceil(np.log2(n_max / 0.5 + 1))))
    cap = 4
    for w in range(n_workers_):
        sig = esigs.sig[owner == w]
        if len(sig):
            b = engine._bucket_of(sig.astype(np.uint32), n_buckets, xp=np)
            cap = max(cap, int(np.bincount(b.astype(np.int64), minlength=n_buckets).max()))

    k1s, k2s, ens, skews = [], [], [], []
    for w in range(n_workers_):
        keep = owner == w
        sub = EntitySignatures(sig=esigs.sig[keep], entity_id=esigs.entity_id[keep])
        t = _build_table_fixed(sub, n_buckets, cap, entity_offset)
        k1s.append(t[0])
        k2s.append(t[1])
        ens.append(t[2])
        skews.append(t[3])
    counts = np.array([int((owner == w).sum()) for w in range(n_workers_)])
    entity_skew = float(counts.max() / max(counts.mean(), 1e-9))
    stacked = engine.SigTable(
        keys1=jnp.asarray(np.stack(k1s)),
        keys2=jnp.asarray(np.stack(k2s)),
        ents=jnp.asarray(np.stack(ens)),
        n_buckets=n_buckets,
        bucket_cap=cap,
        entity_offset=entity_offset,
        nbytes=int(np.stack(k1s).nbytes * 2 + np.stack(ens).nbytes),
        skew=entity_skew,
    )
    return stacked, entity_skew


def _build_table_fixed(esigs: EntitySignatures, n_buckets: int, cap: int, entity_offset: int):
    sig = esigs.sig.astype(np.uint32)
    k2v = hashing.hash_u32(sig, seed=engine._SIGKEY_SEED, xp=np)
    bucket = engine._bucket_of(sig, n_buckets, xp=np).astype(np.int64)
    keys1 = np.zeros((n_buckets, cap), dtype=np.uint32)
    keys2 = np.zeros((n_buckets, cap), dtype=np.uint32)
    ents = np.full((n_buckets, cap), -1, dtype=np.int32)
    fill = np.zeros((n_buckets,), dtype=np.int64)
    if len(sig):
        # vectorised fill (see engine.build_sig_table): stable sort by
        # bucket, rank-in-bucket scatter, overflow checked in bulk.
        order = np.argsort(bucket, kind="stable")
        sb = bucket[order]
        rank = np.arange(len(sig)) - np.searchsorted(sb, sb)
        dropped = int((rank >= cap).sum())
        assert dropped == 0, "common table geometry must fit every shard"
        keys1[sb, rank] = sig[order]
        keys2[sb, rank] = k2v[order]
        ents[sb, rank] = esigs.entity_id[order]
        np.add.at(fill, sb, 1)
    return keys1, keys2, ents, float(fill.max() / max(fill.mean(), 1e-9))


def shuffle_capacity(
    max_candidates: int, sigs_per_window: int, n_workers_: int, factor: float = 2.0
) -> int:
    """Per-destination record capacity for the all_to_all dispatch."""
    per_dest = max_candidates * sigs_per_window / max(n_workers_, 1)
    return max(16, int(math.ceil(per_dest * factor)))


def distributed_extract_ssjoin(
    mesh: Mesh,
    axis_names: tuple[str, ...],
    doc_tokens,
    side,  # eejoin.PreparedSide with a *stacked* sig_table
    max_len: int,
    capacity_factor: float = 2.0,
):
    """ISHFilter & SSJoin with an explicit signature-keyed shuffle."""
    n = num_workers(mesh, axis_names)
    D = doc_tokens.shape[0]
    assert D % n == 0, f"docs {D} must divide workers {n}"
    dl = D // n
    params = side.params
    table = side.sig_table
    S = num_window_signatures(params.scheme, max_len, params.lsh)
    cap = shuffle_capacity(params.max_candidates, S, n, capacity_factor)
    rec_bytes = 4 * (max_len + _META_FIELDS + 1)  # tokens + meta + sig

    def body(docs, tk1, tk2, ten):
        docs = docs.reshape(dl, -1)
        local_table = engine.SigTable(
            keys1=tk1.reshape(table.n_buckets, table.bucket_cap),
            keys2=tk2.reshape(table.n_buckets, table.bucket_cap),
            ents=ten.reshape(table.n_buckets, table.bucket_cap),
            n_buckets=table.n_buckets,
            bucket_cap=table.bucket_cap,
            entity_offset=table.entity_offset,
        )
        if params.use_kernel:
            # fused megakernel tile stream; window sigs recomputed from
            # the gathered windows (bit-identical to the in-kernel path)
            cands = sharded.stream_filter_compact(docs, max_len, side.flt, params)
        else:
            base, surv = engine.survival_mask(docs, max_len, side.flt, False)
            cands = engine.compact_candidates(base, surv, params.max_candidates)
        toks, ok = cands["win_tokens"], cands["win_valid"]
        N = toks.shape[0]
        sigs, smask = engine.window_sigs_for(cands, params)
        smask = smask & ok[:, None]

        # ---- dispatch: route each (candidate, signature) to its owner
        flat_sig = sigs.reshape(-1)  # [N*S]
        flat_ok = smask.reshape(-1)
        owner = jnp.where(flat_ok, (flat_sig % jnp.uint32(n)).astype(jnp.int32), n)
        order = jnp.argsort(owner, stable=True)
        sowner = owner[order]
        counts = jnp.bincount(owner, length=n + 1)
        starts = jnp.cumsum(counts) - counts
        pos_in = jnp.arange(flat_sig.shape[0]) - starts[sowner]
        keep = (pos_in < cap) & (sowner < n)
        dst_w = jnp.where(keep, sowner, n - 1)
        dst_p = jnp.where(keep, pos_in, cap)  # cap -> dropped via mode="drop"

        cand_idx = order // S
        off = worker_index(axis_names) * dl
        meta_src = jnp.stack(
            [
                jnp.where(cands["doc"][cand_idx] >= 0, cands["doc"][cand_idx] + off, -1),
                cands["pos"][cand_idx],
                cands["length"][cand_idx],
            ],
            axis=-1,
        )  # [N*S, 3]
        send_tok = jnp.full((n, cap, max_len), PAD, dtype=jnp.int32)
        send_meta = jnp.full((n, cap, _META_FIELDS), -1, dtype=jnp.int32)
        send_sig = jnp.zeros((n, cap), dtype=jnp.uint32)
        send_tok = send_tok.at[dst_w, dst_p].set(toks[cand_idx], mode="drop")
        send_meta = send_meta.at[dst_w, dst_p].set(meta_src, mode="drop")
        send_sig = send_sig.at[dst_w, dst_p].set(flat_sig[order], mode="drop")

        sent = (keep & flat_ok[order]).sum()
        overflow = (flat_ok.sum() - sent).astype(jnp.int32)

        # ---- the shuffle
        a2a = partial(
            jax.lax.all_to_all, axis_name=axis_names, split_axis=0, concat_axis=0
        )
        recv_tok = a2a(send_tok)
        recv_meta = a2a(send_meta)
        recv_sig = a2a(send_sig)

        # ---- reduce side: probe own table shard, verify, emit
        r_tok = recv_tok.reshape(n * cap, max_len)
        r_meta = recv_meta.reshape(n * cap, _META_FIELDS)
        r_sig = recv_sig.reshape(n * cap)
        r_ok = r_meta[:, 0] >= 0
        ents = engine.probe_sig_table(local_table, r_sig[:, None], r_ok[:, None])
        gamma = 0.0 if params.scheme == "variant" else params.gamma
        hits, scores = verify_pairs(
            r_tok,
            ents,
            side.ddict.tokens,
            side.ddict.token_weight,
            gamma=gamma,
            sim_name=params.sim_name,
            use_kernel=params.use_kernel,
        )
        hits = dedup_hits(hits, ents)
        # NOTE: the same (window, entity) pair may also arrive via several
        # *distinct* signatures on different reducers; final results are
        # a distributed multiset, deduplicated at collection (as in
        # MapReduce, where reducers write independent output files).
        ent_global = jnp.where(ents >= 0, ents + table.entity_offset, -1)
        K = hits.shape[1]
        rep = lambda a: jnp.repeat(a, K)
        m = compact_matches(
            hits.reshape(-1),
            rep(r_meta[:, 0]),
            rep(r_meta[:, 1]),
            rep(r_meta[:, 2]),
            ent_global.reshape(-1),
            scores.reshape(-1),
            params.result_capacity,
        )
        m = dataclasses.replace(m, count=jax.lax.psum(m.count, axis_names))

        received = r_ok.sum().astype(jnp.float32)
        diag = ShuffleDiag(
            sent=jax.lax.psum(sent, axis_names),
            send_overflow=jax.lax.psum(overflow, axis_names),
            bytes_shuffled=jax.lax.psum(sent * rec_bytes, axis_names),
            max_received=jax.lax.pmax(received, axis_names),
            mean_received=jax.lax.pmean(received, axis_names),
        )
        return m, diag

    spec = P(axis_names)
    rep_spec = P()
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(spec, spec, spec, spec),
        out_specs=(
            Matches(doc=spec, pos=spec, length=spec, entity=spec, score=spec, count=rep_spec),
            ShuffleDiag(
                sent=rep_spec,
                send_overflow=rep_spec,
                bytes_shuffled=rep_spec,
                max_received=rep_spec,
                mean_received=rep_spec,
            ),
        ),
        check_vma=False,
    )
    # table shards travel as [n, ...] arrays sharded along the worker axes
    return fn(doc_tokens, table.keys1, table.keys2, table.ents)


# --------------------------------------------------------------------------
# Distributed statistics gathering (the §"means to gather statistics" job)
# --------------------------------------------------------------------------


def distributed_token_histogram(
    mesh: Mesh, axis_names: tuple[str, ...], doc_tokens, vocab_size: int
):
    """Corpus token histogram as a shard_map + psum job."""

    def body(docs):
        h = jnp.zeros((vocab_size,), dtype=jnp.int32)
        h = h.at[docs.reshape(-1)].add(1)
        return jax.lax.psum(h, axis_names)

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis_names),),
        out_specs=P(),
        check_vma=False,
    )
    return fn(doc_tokens)

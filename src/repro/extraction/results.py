"""Fixed-capacity extraction result buffers (static shapes under jit)."""
from __future__ import annotations

import dataclasses
import os

import numpy as np

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Matches:
    """A batch of extraction matches, -1-padded to static capacity.

    doc/pos/length/entity: [R] int32 (-1 where empty); score: [R] f32;
    count: [] int32 true matches (may exceed R if the buffer overflowed —
    overflow is surfaced, never silent).
    """

    doc: jnp.ndarray
    pos: jnp.ndarray
    length: jnp.ndarray
    entity: jnp.ndarray
    score: jnp.ndarray
    count: jnp.ndarray

    def to_set(self) -> set[tuple[int, int, int, int]]:
        """Host-side dedup'd set of (doc, pos, len, entity)."""
        d = np.asarray(self.doc)
        keep = d >= 0
        return set(
            zip(
                np.asarray(self.doc)[keep].tolist(),
                np.asarray(self.pos)[keep].tolist(),
                np.asarray(self.length)[keep].tolist(),
                np.asarray(self.entity)[keep].tolist(),
            )
        )


def select_nonzero(mask, capacity: int):
    """First ``capacity`` flat indices of set bits in ``mask`` (-1 pad).

    Semantically ``jnp.nonzero(mask, size=capacity, fill_value=-1)``,
    but XLA lowers sized-nonzero through a full sort; this prefix-sum +
    ``searchsorted`` selection (the k-th survivor lives where the cumsum
    first reaches k) is ~5x faster on CPU and sort-free on TPU. Returns
    (idx [capacity] int32, ok [capacity] bool).
    """
    flat = mask.reshape(-1)
    c = jnp.cumsum(flat.astype(jnp.int32))
    idx = jnp.searchsorted(
        c, jnp.arange(1, capacity + 1, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)
    ok = jnp.arange(capacity) < c[-1]
    return jnp.where(ok, idx, -1), ok


def tile_ranks(counts, capacity: int):
    """Global rank -> (tile, within-tile rank) map for per-tile lanes.

    ``counts`` [G] int32 are true per-tile survivor counts. Returns
    ``(g [capacity] int32, within [capacity] int32, ok [capacity] bool,
    total [] int32)``: the tile index and within-tile rank of each of
    the global first ``capacity`` survivors (tiles ordered by ascending
    index range). Shared by ``select_from_tiles`` (index lanes) and
    ``gather_from_tiles`` (payload lanes, e.g. the fused variant keys)
    so both gather the *same* survivors. O(G + capacity).
    """
    G = counts.shape[0]
    cum = jnp.cumsum(counts.astype(jnp.int32))
    total = cum[-1]
    j = jnp.arange(capacity, dtype=jnp.int32)
    ok = j < jnp.minimum(total, capacity)
    g = jnp.searchsorted(cum, j, side="right").astype(jnp.int32)
    gs = jnp.minimum(g, G - 1)
    within = j - (cum[gs] - counts[gs])
    return gs, within, ok, total


def select_from_tiles(counts, cands, capacity: int,
                      complete_tiles: bool = False):
    """Merge per-tile compacted candidate lanes into one global selection.

    ``counts`` [G] int32 are true per-tile survivor counts (may exceed
    the lane width); ``cands`` [G, C] int32 hold each tile's first C
    survivors as ascending flat indices (-1 pad), tiles ordered by
    ascending index range — the layout the ``fused_probe`` compaction
    epilogue emits. Returns (idx [capacity] int32 -1-padded, ok
    [capacity] bool, total [] int32), bit-identical to running
    ``select_nonzero`` over the full bitmap whenever ``C >= capacity``
    (any candidate inside the global first ``capacity`` has within-tile
    rank < capacity, so lane truncation can never hide it). Cost is
    O(G + capacity) — the [D, T] survival bitmap is never touched.

    ``complete_tiles=True`` relaxes the static ``C >= capacity`` check
    for the adaptive two-pass emit: the caller guarantees every tile's
    lane holds *all* of its survivors (``max(counts) <= C``, enforced
    host-side by sizing C from a count pass), under which the merge is
    exact at any C.
    """
    G, C = cands.shape
    assert complete_tiles or C >= capacity, (
        f"lane width {C} < capacity {capacity}: truncated lanes would be "
        "re-read silently (see docstring invariant; pass "
        "complete_tiles=True only when max(counts) <= lane width)"
    )
    gs, within, ok, total = tile_ranks(counts, capacity)
    idx = cands[gs, jnp.clip(within, 0, C - 1)]
    return jnp.where(ok, idx, -1), ok, total


def gather_from_tiles(counts, payload, capacity: int, fill=0):
    """Gather per-lane payload rows for the ``select_from_tiles`` merge.

    ``payload`` [G, C, ...] carries one record per lane slot (e.g. the
    fused variant key pairs [G, C, 2]); returns the [capacity, ...]
    records of the globally selected survivors, ``fill`` in padded
    slots. Must be driven by the same ``counts`` as the index-lane
    merge so both pick identical survivors.
    """
    G, C = payload.shape[:2]
    gs, within, ok, _ = tile_ranks(counts, capacity)
    out = payload[gs, jnp.clip(within, 0, C - 1)]
    mask = ok.reshape(ok.shape + (1,) * (out.ndim - 1))
    return jnp.where(mask, out, fill)


def save_lane_checkpoint(path: str, lane, count, keys=None) -> None:
    """Persist one shard's lane wire unit ``(lane, count[, keys])`` to disk.

    The lane triple is the complete ``select_from_tiles`` /
    ``gather_from_tiles`` input for that shard — persisting it per shard
    is exactly the resumable-merge state: a restarted corpus job reloads
    finished shards' lanes and re-runs only the missing probes, and the
    final merge is bit-identical because the merge never saw anything
    but these lanes in the first place. Written atomically (tmp file +
    ``os.replace``) so a kill mid-write leaves either the old file or
    none, never a torn one.
    """
    arrays = {
        "lane": np.asarray(lane, dtype=np.int32),
        "count": np.asarray(count, dtype=np.int32),
    }
    if keys is not None:
        arrays["keys"] = np.asarray(keys, dtype=np.uint32)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def load_lane_checkpoint(path: str):
    """Load a shard lane persisted by ``save_lane_checkpoint``.

    Returns ``(lane [1, NC] int32, count [1] int32, keys [1, NC, 2]
    uint32 | None)`` as device arrays, ready to concatenate into the
    ``select_from_tiles`` merge alongside freshly probed lanes.
    """
    with np.load(path) as z:
        lane = jnp.asarray(z["lane"])
        count = jnp.asarray(z["count"])
        keys = jnp.asarray(z["keys"]) if "keys" in z.files else None
    return lane, count, keys


def compact_matches(hit_mask, doc, pos, length, entity, score, capacity: int) -> Matches:
    """Compact flat hit arrays into a fixed-capacity Matches buffer.

    All inputs are flat [N]; ``hit_mask`` selects real matches.
    """
    idx, ok = select_nonzero(hit_mask, capacity)
    take = jnp.maximum(idx, 0)
    return Matches(
        doc=jnp.where(ok, doc[take], -1).astype(jnp.int32),
        pos=jnp.where(ok, pos[take], -1).astype(jnp.int32),
        length=jnp.where(ok, length[take], -1).astype(jnp.int32),
        entity=jnp.where(ok, entity[take], -1).astype(jnp.int32),
        score=jnp.where(ok, score[take], 0.0).astype(jnp.float32),
        count=hit_mask.sum().astype(jnp.int32),
    )


def filter_matches(m: Matches, entity_live, capacity: int) -> Matches:
    """Drop matches whose entity is tombstoned (live-updates emit mask).

    ``entity_live`` is a [total_entities] bool device mask (True =
    live). Tombstoned entities stay inside prepared filter/table/index
    structures — deletes are logical — so their matches are produced
    normally and masked here, after verification, before results leave
    the device. ``count`` becomes the number of *live* matches; like
    every fixed-capacity buffer, matches truncated by an upstream
    overflow are gone before masking (overflow stays surfaced via the
    producing buffer's count).
    """
    keep = (m.doc >= 0) & entity_live[jnp.maximum(m.entity, 0)]
    return compact_matches(
        keep, m.doc, m.pos, m.length, m.entity, m.score, capacity
    )


def merge_matches(a: Matches, b: Matches, capacity: int) -> Matches:
    """Merge two buffers into one of ``capacity`` (dedup NOT performed)."""
    doc = jnp.concatenate([a.doc, b.doc])
    hit = doc >= 0
    return compact_matches(
        hit,
        doc,
        jnp.concatenate([a.pos, b.pos]),
        jnp.concatenate([a.length, b.length]),
        jnp.concatenate([a.entity, b.entity]),
        jnp.concatenate([a.score, b.score]),
        capacity,
    )

"""Candidate substring (window) enumeration.

A candidate is a contiguous token window ``(doc, pos, len)`` with
``1 <= len <= L`` (L = longest dictionary entity), the paper's
``L × |d|`` candidate set. Enumeration is fully vectorised and produces
static shapes: for a document shard ``[D, T]`` we build

  ``win_tokens`` [D, T, L]  tokens starting at each position (PAD-padded
                            past the document end), and per-candidate
                            views ``[D, T, L, L]`` where candidate
                            ``(d, p, l)`` is the first ``l+1`` tokens.

The [D,T,L,L] tensor is only materialised by the *baseline* SSJoin (the
paper's strawman); the optimized paths keep the compact [D,T,L] base and
evaluate lengths in place (the ISH filter prunes before any gather).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.dictionary import PAD


def window_base(doc_tokens, max_len: int):
    """[D, T] -> [D, T, L] tokens starting at each position."""
    D, T = doc_tokens.shape
    cols = jnp.arange(T)[:, None] + jnp.arange(max_len)[None, :]  # [T, L]
    gathered = jnp.where(
        cols[None] < T,
        doc_tokens[:, jnp.minimum(cols, T - 1)],
        PAD,
    )
    return gathered


def candidate_tokens(win_base):
    """[D, T, L] -> [D, T, L, L]: candidate (p, l) = first l+1 tokens."""
    L = win_base.shape[-1]
    keep = jnp.tril(jnp.ones((L, L), dtype=bool))  # [len, tok]
    return jnp.where(keep[None, None], win_base[:, :, None, :], PAD)


def candidate_valid(win_base):
    """[D, T, L] -> [D, T, L] validity of candidate (p, l).

    Candidate (p, l) is valid iff all of its l+1 tokens are real (no PAD
    inside the window — PAD only occurs at document tails).
    """
    real = win_base != PAD  # [D, T, L]
    return jnp.cumprod(real.astype(jnp.int32), axis=-1).astype(bool)


def window_base_np(doc_tokens: np.ndarray, max_len: int) -> np.ndarray:
    D, T = doc_tokens.shape
    out = np.full((D, T, max_len), PAD, dtype=np.int32)
    for l in range(max_len):
        out[:, : T - l, l] = doc_tokens[:, l:]
    return out

"""Sharded streaming extraction: the per-device ``fused_probe`` driver.

The paper's operator exists because extraction must scale past one
machine's memory: documents are split into shards and the filter/verify
plan is costed per shard. This module is the execution layer for that
regime — it converts the engine from "one big array per call" into a
*stream of shards per device pool*:

    corpus [D, T]
      └─ shards of ``shard_docs`` rows          (host-side split, PAD-padded)
           └─ wave of ``n_workers`` shards      (shard_map over the mesh axis)
                └─ tiles of ``tile_docs`` rows  (double-buffered probe stream)
                     └─ fused_probe epilogue    (per-tile count + index lanes)

Inside a device, tiles stream through the ``fused_probe`` megakernel
with its in-kernel compaction epilogue; the loop is *double-buffered*:
the next tile's probe is issued before the current tile's lanes are
folded into the shard accumulator, so the two have no data dependency
and a real TPU overlaps the next tile's HBM->VMEM DMA with the current
tile's epilogue math (in interpret mode the structure is identical, the
overlap is just not observable). Every combine step — tile lanes ->
shard lane -> global candidate buffer — runs ``select_from_tiles`` over
tiny [G, NC] count/index lanes, never over the [D, T] survival bitmap.

Because per-tile and per-shard lanes keep the *first NC* survivors in
ascending flat order and true totals ride along, the final selection is
bit-identical to the unsharded ``engine.fused_filter_compact`` fast
path at any shard geometry (uneven shards, PAD-only shards,
zero-survivor shards, more shards than devices) — asserted in
``tests/test_sharded.py`` and re-checked by the sharded smoke bench.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.dictionary import PAD
from repro.extraction import engine
from repro.extraction.results import select_from_tiles

#: default rows per streaming tile: big enough to amortise kernel launch
#: overhead, small enough that two tiles' working sets double-buffer in
#: VMEM (docs + packed bitmap + candidate lanes per tile).
DEFAULT_TILE_DOCS = 64

DEFAULT_AXIS = "workers"


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """Static geometry of one sharded streaming run."""

    total_docs: int  # true corpus rows (pre-padding)
    shard_docs: int  # rows per shard (last shard PAD-padded up to this)
    num_shards: int
    tile_docs: int  # rows per double-buffered probe tile within a shard

    @property
    def tiles_per_shard(self) -> int:
        return -(-self.shard_docs // self.tile_docs)


def plan_shards(
    total_docs: int,
    n_workers: int = 1,
    shard_docs: int | None = None,
    tile_docs: int | None = None,
) -> ShardSpec:
    """Choose a shard geometry: default one shard per worker per wave."""
    assert total_docs > 0
    sd = shard_docs or -(-total_docs // max(n_workers, 1))
    td = min(tile_docs or DEFAULT_TILE_DOCS, sd)
    return ShardSpec(
        total_docs=total_docs,
        shard_docs=sd,
        num_shards=-(-total_docs // sd),
        tile_docs=td,
    )


def stream_probe_tiles(
    docs,
    max_len: int,
    flt: tuple | None,
    params: engine.ExtractParams,
    tile_docs: int = DEFAULT_TILE_DOCS,
    row_offset=0,
):
    """Stream a [S, T] doc shard through ``fused_probe`` tile by tile.

    Returns ``(counts [G], cands [G, NC])`` candidate lanes covering the
    whole shard, with flat indices globalised by ``row_offset`` rows
    (``row_offset`` may be a traced scalar, e.g. a worker index inside
    ``shard_map``). The loop is double-buffered: tile i+1's probe is
    issued before tile i's lanes are globalised, so the probe DMA and
    the combine arithmetic have no dependency edge between them.
    """
    from repro.kernels import ops as kops

    S, T = docs.shape
    L = max_len
    NC = params.max_candidates
    td = min(tile_docs, S)
    n_tiles = -(-S // td)
    if n_tiles * td != S:
        docs = jnp.pad(docs, ((0, n_tiles * td - S), (0, 0)),
                       constant_values=PAD)

    def probe(i):
        return kops.fused_probe_compact(docs[i * td:(i + 1) * td], flt, L, NC)

    def globalise(cnt, cd, tile_row):
        off = (row_offset + tile_row) * T * L
        return cnt, jnp.where(cd >= 0, cd + off, -1)

    out_counts, out_cands = [], []
    _, _, cnt, cd = probe(0)
    cur, cur_row = (cnt, cd), 0
    for i in range(1, n_tiles):
        _, _, cnt, cd = probe(i)  # issue next probe (buffer B) ...
        c, x = globalise(*cur, cur_row)  # ... while current tile combines
        out_counts.append(c)
        out_cands.append(x)
        cur, cur_row = (cnt, cd), i * td
    c, x = globalise(*cur, cur_row)
    out_counts.append(c)
    out_cands.append(x)
    return jnp.concatenate(out_counts), jnp.concatenate(out_cands, axis=0)


def stream_filter_compact(
    doc_tokens,
    max_len: int,
    flt: tuple | None,
    params: engine.ExtractParams,
    tile_docs: int = DEFAULT_TILE_DOCS,
) -> dict:
    """Single-device streaming equivalent of ``engine.fused_filter_compact``.

    Tiles the doc array through the megakernel (double-buffered) instead
    of one monolithic ``pallas_call``, then merges the per-tile lanes.
    Output is bit-identical to the unsharded fast path; LSH schemes get
    their signatures post-compaction (``window_sigs_for`` recomputes
    bit-identical band sigs from the gathered windows), so the dict
    never carries in-kernel ``sigs``. Falls back to the single-call
    engine path when the epilogue cannot run (L > 32 or
    ``params.kernel_compact=False``).
    """
    if max_len > 32 or not params.kernel_compact:
        return engine.fused_filter_compact(doc_tokens, max_len, flt, params)
    NC = params.max_candidates
    counts, cands = stream_probe_tiles(doc_tokens, max_len, flt, params, tile_docs)
    sel, ok, n = select_from_tiles(counts, cands, NC)
    return engine.candidates_from_flat(doc_tokens, sel, ok, n, max_len, NC)


def shard_lane(docs, row_offset, max_len, flt, params,
               tile_docs: int = DEFAULT_TILE_DOCS):
    """Stream one doc shard and reduce it to a single candidate lane —
    the *wire unit* of every lane-shipping consumer (sharded driver
    waves, the serving probe→verify handoff).

    Lane wire format (``[G, NC]`` with ``G = 1`` here):

    * ``cand`` — ``[1, NC]`` **int32**: the shard's first ``NC``
      (``params.max_candidates``) surviving windows as **ascending**
      global flat indices ``(doc * T + pos) * L + (len - 1)``, where
      ``doc`` is globalised by ``row_offset`` rows and ``L`` is
      ``max_len``. Unused slots hold the sentinel ``-1`` (PAD); real
      indices are always ``>= 0``, so sign is the validity bit.
    * ``count`` — ``[1]`` **int32**: the shard's *true* survivor total,
      which may exceed ``NC`` (overflow is surfaced downstream, never
      silent).

    One ``(cand, count)`` pair is exactly one row of a
    ``results.select_from_tiles`` input, so lanes compose hierarchically
    — tile lanes into a shard lane, shard lanes across waves or
    micro-batches into a global selection — and are cheap enough
    (``(1 + NC) * 4`` bytes) to ship across hosts or device pools.
    ``row_offset`` may be a traced scalar (e.g. a worker index inside
    ``shard_map``).
    """
    NC = params.max_candidates
    counts, cands = stream_probe_tiles(
        docs, max_len, flt, params, tile_docs, row_offset=row_offset
    )
    sel, ok, n = select_from_tiles(counts, cands, NC)
    return jnp.where(ok, sel, -1)[None, :], n[None].astype(jnp.int32)


def sharded_filter_compact(
    doc_tokens,
    max_len: int,
    flt: tuple | None,
    params: engine.ExtractParams,
    mesh: Mesh | None = None,
    axis_name: str = DEFAULT_AXIS,
    shard_docs: int | None = None,
    tile_docs: int | None = None,
) -> dict:
    """Shard-parallel streaming candidate front end.

    Splits the corpus into ``shard_docs``-row shards, maps each wave of
    ``n_workers`` shards onto the mesh axis with ``shard_map`` (each
    device streams its shard's tiles through ``fused_probe``), and
    merges the per-shard candidate lanes into one global
    ``compact_candidates`` dict — bit-identical to running the
    unsharded ``engine.fused_filter_compact`` on the whole array. With
    ``mesh=None`` the wave loop degenerates to a sequential stream on
    the local device (same lanes, same merge, same outputs). More
    shards than devices are handled by multiple waves; short corpora
    and ragged tails are PAD-padded (PAD rows can never survive, so
    padding never perturbs the selection).
    """
    if max_len > 32 or not params.kernel_compact:
        # no epilogue -> no lanes to shard over; single-call fallback
        return engine.fused_filter_compact(doc_tokens, max_len, flt, params)
    D, T = doc_tokens.shape
    engine.check_flat_index_space(D, T, max_len)
    n_workers = int(mesh.shape[axis_name]) if mesh is not None else 1
    spec = plan_shards(D, n_workers, shard_docs, tile_docs)
    NC = params.max_candidates
    n_waves = -(-spec.num_shards // n_workers)
    rows_padded = n_waves * n_workers * spec.shard_docs
    padded = doc_tokens
    if rows_padded != D:
        padded = jnp.pad(doc_tokens, ((0, rows_padded - D), (0, 0)),
                         constant_values=PAD)

    lanes, totals = [], []
    if mesh is None:
        for s in range(n_waves * n_workers):
            lane, n = shard_lane(
                padded[s * spec.shard_docs:(s + 1) * spec.shard_docs],
                s * spec.shard_docs,
                max_len, flt, params, spec.tile_docs,
            )
            lanes.append(lane)
            totals.append(n)
    else:
        def wave_body(docs, row_off):
            return shard_lane(
                docs, row_off[0], max_len, flt, params, spec.tile_docs
            )

        wave_fn = shard_map(
            wave_body,
            mesh=mesh,
            in_specs=(P(axis_name), P(axis_name)),
            out_specs=(P(axis_name), P(axis_name)),
            check_vma=False,
        )
        for w in range(n_waves):
            block = padded[
                w * n_workers * spec.shard_docs:(w + 1) * n_workers * spec.shard_docs
            ]
            offs = (
                (w * n_workers + jnp.arange(n_workers)) * spec.shard_docs
            ).astype(jnp.int32)
            lane, n = wave_fn(block, offs)
            lanes.append(lane.reshape(n_workers, NC))
            totals.append(n.reshape(n_workers))

    counts = jnp.concatenate(totals)
    cands = jnp.concatenate(lanes, axis=0)
    sel, ok, n = select_from_tiles(counts, cands, NC)
    return engine.candidates_from_flat(doc_tokens, sel, ok, n, max_len, NC)

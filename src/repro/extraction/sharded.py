"""Sharded streaming extraction: the per-device ``fused_probe`` driver.

The paper's operator exists because extraction must scale past one
machine's memory: documents are split into shards and the filter/verify
plan is costed per shard. This module is the execution layer for that
regime — it converts the engine from "one big array per call" into a
*stream of shards per device pool*:

    corpus [D, T]
      └─ shards of ``shard_docs`` rows          (host-side split, PAD-padded)
           └─ wave of ``n_workers`` shards      (shard_map over the mesh axis)
                └─ tiles of ``tile_docs`` rows  (double-buffered probe stream)
                     └─ fused_probe epilogue    (per-tile count + index lanes)

Inside a device, tiles stream through the ``fused_probe`` megakernel
with its in-kernel compaction epilogue; the loop is *double-buffered*:
the next tile's probe is issued before the current tile's lanes are
folded into the shard accumulator, so the two have no data dependency
and a real TPU overlaps the next tile's HBM->VMEM DMA with the current
tile's epilogue math (in interpret mode the structure is identical, the
overlap is just not observable). Every combine step — tile lanes ->
shard lane -> global candidate buffer — runs ``select_from_tiles`` over
tiny [G, NC] count/index lanes, never over the [D, T] survival bitmap.

Because per-tile and per-shard lanes keep the *first NC* survivors in
ascending flat order and true totals ride along, the final selection is
bit-identical to the unsharded ``engine.fused_filter_compact`` fast
path at any shard geometry (uneven shards, PAD-only shards,
zero-survivor shards, more shards than devices) — asserted in
``tests/test_sharded.py`` and re-checked by the sharded smoke bench.

Two PR 4 extensions ride the same lanes: the fused *variant* scheme's
set-hash key pairs travel as a [G, NC, 2] payload next to the index
lanes (``gather_from_tiles`` keeps payload and index selection in
lockstep), and ``ExtractParams(adaptive_lanes=True)`` narrows the tile
lanes to a measured width via a count-only sizing pass
(``stream_tile_counts`` + ``round_lane_width``; under ``shard_map`` a
count *wave* runs first and the width is traced in statically).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.dictionary import PAD
from repro.extraction import engine
from repro.extraction.results import (
    gather_from_tiles,
    load_lane_checkpoint,
    save_lane_checkpoint,
    select_from_tiles,
)

#: default rows per streaming tile: big enough to amortise kernel launch
#: overhead, small enough that two tiles' working sets double-buffer in
#: VMEM (docs + packed bitmap + candidate lanes per tile).
DEFAULT_TILE_DOCS = 64

DEFAULT_AXIS = "workers"


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """Static geometry of one sharded streaming run."""

    total_docs: int  # true corpus rows (pre-padding)
    shard_docs: int  # rows per shard (last shard PAD-padded up to this)
    num_shards: int
    tile_docs: int  # rows per double-buffered probe tile within a shard

    @property
    def tiles_per_shard(self) -> int:
        return -(-self.shard_docs // self.tile_docs)


def plan_shards(
    total_docs: int,
    n_workers: int = 1,
    shard_docs: int | None = None,
    tile_docs: int | None = None,
) -> ShardSpec:
    """Choose a shard geometry: default one shard per worker per wave.

    Both the shard height and the tile height are clamped to the corpus:
    a requested ``shard_docs`` (or ``tile_docs``) larger than
    ``total_docs`` would otherwise pad every shard — and therefore every
    tile — to the full requested width with PAD rows that can never
    survive, paying kernel work proportional to the *request* instead of
    the corpus (the 1-shard tiny-corpus edge).
    """
    assert total_docs > 0
    sd = min(shard_docs or -(-total_docs // max(n_workers, 1)), total_docs)
    td = min(tile_docs or DEFAULT_TILE_DOCS, sd)
    return ShardSpec(
        total_docs=total_docs,
        shard_docs=sd,
        num_shards=-(-total_docs // sd),
        tile_docs=td,
    )


def resolve_streamed(params: engine.ExtractParams, n_tiles: int) -> bool:
    """Per-shard launch mode: single streamed launch vs per-tile loop.

    ``params.streamed`` is the override (True/False); ``None`` is auto —
    stream whenever the shard spans >= 2 tiles, since a single tile has
    no copy-in to overlap (the per-tile launch is then one launch too).
    """
    if params.streamed is not None:
        return bool(params.streamed)
    return n_tiles >= 2


def _streamed_layout(docs, td: int, n_tiles: int, bd: int):
    """Chunk layout for the single-launch streamed kernel.

    The per-tile loop pads each [td, T] tile *independently* to a
    multiple of the NC-derived sub-tile height ``bd`` inside
    ``fused_probe_compact``; to be bit-identical the streamed buffer
    replays that layout — each tile padded to ``td_p = ceil(td/bd)*bd``
    rows, concatenated — and the per-chunk row offsets carry the
    *original* (unpadded) row numbering ``i*td + j*bd`` so flat indices
    match the per-tile path exactly. Returns ``(docs [n_tiles*td_p, T],
    offs [n_tiles*(td_p//bd)] int32 host array)``.
    """
    T = docs.shape[1]
    td_p = -(-td // bd) * bd
    if td_p != td:
        docs = jnp.pad(
            docs.reshape(n_tiles, td, T),
            ((0, 0), (0, td_p - td), (0, 0)),
            constant_values=PAD,
        ).reshape(n_tiles * td_p, T)
    gp = td_p // bd
    offs = (np.arange(n_tiles)[:, None] * td
            + np.arange(gp)[None, :] * bd).reshape(-1).astype(np.int32)
    return docs, offs


def stream_probe_tiles(
    docs,
    max_len: int,
    flt: tuple | None,
    params: engine.ExtractParams,
    tile_docs: int = DEFAULT_TILE_DOCS,
    row_offset=0,
    lane_width: int | None = None,
    sig_mode: str | None = None,
    stream_stats: dict | None = None,
):
    """Stream a [S, T] doc shard through ``fused_probe`` tile by tile.

    Returns ``(counts [G], cands [G, W], vkeys)`` candidate lanes
    covering the whole shard (``W = lane_width or NC``; ``vkeys``
    [G, W, 2] variant key payload when ``sig_mode == "variant"``, else
    ``None``), with flat indices globalised by ``row_offset`` rows
    (``row_offset`` may be a traced scalar, e.g. a worker index inside
    ``shard_map``).

    Launch mode is per-shard (``resolve_streamed``): by default a shard
    spanning >= 2 tiles goes through the *single-launch* streamed
    megakernel (``ops.fused_probe_stream`` — the tile loop runs inside
    the kernel over a double-buffered DMA pipeline, and only the tiny
    per-chunk lanes come back); otherwise — or with
    ``params.streamed=False`` — the per-tile launch loop runs, itself
    double-buffered at the dispatch level: tile i+1's probe is issued
    before tile i's lanes are globalised, so the probe DMA and the
    combine arithmetic have no dependency edge between them. Both modes
    are bit-identical at any geometry (same sub-tile grid, same
    epilogue). ``lane_width`` is the adaptive emit width — the sub-tile
    grid stays NC-derived so counts line up with a
    ``stream_tile_counts`` sizing pass at the same geometry.
    ``stream_stats`` (mutable dict) accumulates streaming observability
    counters: ``streamed_launches``, ``tiles_streamed``, ``dma_waits``
    (one per in-kernel chunk).
    """
    from repro.kernels import ops as kops
    from repro.kernels.fused_probe import (
        SIG_MODE_NONE,
        SIG_MODE_VARIANT,
        compact_tile_height,
    )

    sig_mode = SIG_MODE_NONE if sig_mode is None else sig_mode
    var = sig_mode == SIG_MODE_VARIANT
    S, T = docs.shape
    L = max_len
    NC = params.max_candidates
    td = min(tile_docs, S)
    n_tiles = -(-S // td)
    if n_tiles * td != S:
        docs = jnp.pad(docs, ((0, n_tiles * td - S), (0, 0)),
                       constant_values=PAD)

    if resolve_streamed(params, n_tiles):
        bd = compact_tile_height(td, T, NC)
        sdocs, offs = _streamed_layout(docs, td, n_tiles, bd)
        row_offs = (row_offset + jnp.asarray(offs)).astype(jnp.int32)
        counts, cands, vkeys = kops.fused_probe_stream(
            sdocs, flt, L, NC, row_offs, sig_mode=sig_mode, bd=bd,
            lane_width=lane_width,
        )
        if stream_stats is not None:
            chunks = int(offs.shape[0])
            stream_stats["streamed_launches"] = (
                stream_stats.get("streamed_launches", 0) + 1)
            stream_stats["tiles_streamed"] = (
                stream_stats.get("tiles_streamed", 0) + chunks)
            stream_stats["dma_waits"] = (
                stream_stats.get("dma_waits", 0) + chunks)
        return counts, cands, vkeys

    def probe(i):
        return kops.fused_probe_compact(
            docs[i * td:(i + 1) * td], flt, L, NC, sig_mode,
            params.lsh.bands, params.lsh.rows, lane_width=lane_width,
        )

    def globalise(cnt, cd, vk, tile_row):
        off = (row_offset + tile_row) * T * L
        return cnt, jnp.where(cd >= 0, cd + off, -1), vk

    out_counts, out_cands, out_keys = [], [], []

    def emit(cur, cur_row):
        c, x, vk = globalise(*cur, cur_row)
        out_counts.append(c)
        out_cands.append(x)
        if var:
            out_keys.append(vk)

    _, _, cnt, cd, vk = probe(0)
    cur, cur_row = (cnt, cd, vk), 0
    for i in range(1, n_tiles):
        _, _, cnt, cd, vk = probe(i)  # issue next probe (buffer B) ...
        emit(cur, cur_row)  # ... while current tile combines
        cur, cur_row = (cnt, cd, vk), i * td
    emit(cur, cur_row)
    return (
        jnp.concatenate(out_counts),
        jnp.concatenate(out_cands, axis=0),
        jnp.concatenate(out_keys, axis=0) if var else None,
    )


def stream_tile_counts(
    docs,
    max_len: int,
    flt: tuple | None,
    params: engine.ExtractParams,
    tile_docs: int = DEFAULT_TILE_DOCS,
    stream_stats: dict | None = None,
):
    """Count-only streaming pass: per-sub-tile survivor counts [G].

    The cheap sizing half of the adaptive two-pass scheme — streams the
    exact tile/sub-tile grid of ``stream_probe_tiles`` (the emit width
    never changes the grid) but stores only the per-tile counts.
    ``round_lane_width(counts.max(), NC)`` then sizes the emit pass so
    every sub-tile's lane holds all of its survivors. Follows the same
    ``resolve_streamed`` launch-mode choice as the emit pass, so a
    streamed run's sizing pass is one launch too.
    """
    from repro.kernels import ops as kops
    from repro.kernels.fused_probe import compact_tile_height

    S, T = docs.shape
    NC = params.max_candidates
    td = min(tile_docs, S)
    n_tiles = -(-S // td)
    if n_tiles * td != S:
        docs = jnp.pad(docs, ((0, n_tiles * td - S), (0, 0)),
                       constant_values=PAD)
    if resolve_streamed(params, n_tiles):
        bd = compact_tile_height(td, T, NC)
        sdocs, offs = _streamed_layout(docs, td, n_tiles, bd)
        counts, _, _ = kops.fused_probe_stream(
            sdocs, flt, max_len, NC, jnp.asarray(offs), bd=bd,
            count_only=True,
        )
        if stream_stats is not None:
            chunks = int(offs.shape[0])
            stream_stats["streamed_launches"] = (
                stream_stats.get("streamed_launches", 0) + 1)
            stream_stats["tiles_streamed"] = (
                stream_stats.get("tiles_streamed", 0) + chunks)
            stream_stats["dma_waits"] = (
                stream_stats.get("dma_waits", 0) + chunks)
        return counts
    return jnp.concatenate([
        kops.fused_probe_count(docs[i * td:(i + 1) * td], flt, max_len, NC)
        for i in range(n_tiles)
    ])


def _adaptive_width(docs, max_len, flt, params, tile_docs) -> int:
    """Measure per-tile survivor maxima and round to the emit width."""
    from repro.kernels.fused_probe import MIN_LANE_WIDTH, round_lane_width

    counts = stream_tile_counts(docs, max_len, flt, params, tile_docs)
    return round_lane_width(
        int(np.asarray(counts).max()),
        params.max_candidates,
        params.lane_width or MIN_LANE_WIDTH,
    )


def _stream_sig_mode(params: engine.ExtractParams, D: int, T: int,
                     max_len: int) -> str:
    """Signature mode for the streaming tile lanes.

    Tile lanes carry the variant key payload, but *dense* in-kernel
    band-sig tensors ([td, T, L, B], lsh) have no lane to ride — the
    streaming path computes bit-identical band sigs post-compaction
    instead (``engine.window_sigs_for``), so the lsh mode is coerced to
    ``none`` here rather than paying a kernel store that would be
    discarded. An explicit ``kernel_sigs=True`` force for lsh therefore
    cannot be honored on this path and raises instead of silently
    falling back.
    """
    from repro.kernels.fused_probe import SIG_MODE_LSH, SIG_MODE_NONE

    mode = engine.resolve_sig_mode(params, D, T, max_len)
    if mode == SIG_MODE_LSH:
        if params.kernel_sigs:
            raise ValueError(
                "ExtractParams(kernel_sigs=True, scheme='lsh') cannot run "
                "on the sharded/serving streaming path: dense in-kernel "
                "band sigs do not ride the candidate lanes, so the kernel "
                "store would be discarded and the sigs recomputed "
                "post-compaction anyway; use the single-call "
                "engine.fused_filter_compact for forced in-kernel band "
                "sigs, or leave kernel_sigs unset (the streaming path "
                "recomputes bit-identical band sigs post-compaction)"
            )
        return SIG_MODE_NONE
    return mode


def stream_filter_compact(
    doc_tokens,
    max_len: int,
    flt: tuple | None,
    params: engine.ExtractParams,
    tile_docs: int = DEFAULT_TILE_DOCS,
) -> dict:
    """Single-device streaming equivalent of ``engine.fused_filter_compact``.

    Tiles the doc array through the megakernel (double-buffered) instead
    of one monolithic ``pallas_call``, then merges the per-tile lanes.
    Output is bit-identical to the unsharded fast path; LSH schemes get
    their signatures post-compaction (``window_sigs_for`` recomputes
    bit-identical band sigs from the gathered windows), so the dict
    never carries in-kernel band ``sigs`` — the *variant* scheme's key
    pairs, however, ride the tile lanes ([G, W, 2] payload) and arrive
    attached exactly as on the unsharded path. Honors
    ``params.adaptive_lanes`` (two-pass: count stream sizes the emit
    stream's lane width). Falls back to the single-call engine path
    when the epilogue cannot run (L > 32 or
    ``params.kernel_compact=False``).
    """
    from repro.kernels.fused_probe import SIG_MODE_VARIANT

    if max_len > 32 or not params.kernel_compact:
        return engine.fused_filter_compact(doc_tokens, max_len, flt, params)
    D, T = doc_tokens.shape
    sig_mode = _stream_sig_mode(params, D, T, max_len)
    NC = params.max_candidates
    lane_w = None
    if params.adaptive_lanes:
        lane_w = _adaptive_width(doc_tokens, max_len, flt, params, tile_docs)
    counts, cands, vkeys = stream_probe_tiles(
        doc_tokens, max_len, flt, params, tile_docs,
        lane_width=lane_w, sig_mode=sig_mode,
    )
    sel, ok, n = select_from_tiles(
        counts, cands, NC, complete_tiles=lane_w is not None
    )
    out = engine.candidates_from_flat(doc_tokens, sel, ok, n, max_len, NC)
    if sig_mode == SIG_MODE_VARIANT:
        out = engine.attach_variant_keys(
            out, gather_from_tiles(counts, vkeys, NC)
        )
    return out


def shard_lane(docs, row_offset, max_len, flt, params,
               tile_docs: int = DEFAULT_TILE_DOCS,
               lane_width: int | None = None,
               sig_mode: str | None = None,
               stream_stats: dict | None = None):
    """Stream one doc shard and reduce it to a single candidate lane —
    the *wire unit* of every lane-shipping consumer (sharded driver
    waves, the serving probe→verify handoff).

    Lane wire format (``[G, NC]`` with ``G = 1`` here):

    * ``cand`` — ``[1, NC]`` **int32**: the shard's first ``NC``
      (``params.max_candidates``) surviving windows as **ascending**
      global flat indices ``(doc * T + pos) * L + (len - 1)``, where
      ``doc`` is globalised by ``row_offset`` rows and ``L`` is
      ``max_len``. Unused slots hold the sentinel ``-1`` (PAD); real
      indices are always ``>= 0``, so sign is the validity bit.
    * ``count`` — ``[1]`` **int32**: the shard's *true* survivor total,
      which may exceed ``NC`` (overflow is surfaced downstream, never
      silent).
    * ``keys`` — ``[1, NC, 2]`` **uint32** or ``None``: the lane
      slots' variant key pairs when the fused variant scheme is on
      (``sig_mode == "variant"``), 0 in padded slots — the verify side
      then never recomputes set hashes.

    One ``(cand, count[, keys])`` triple is exactly one row of a
    ``results.select_from_tiles`` (+ ``gather_from_tiles``) input, so
    lanes compose hierarchically — tile lanes into a shard lane, shard
    lanes across waves or micro-batches into a global selection — and
    are cheap enough (``(1 + NC) * 4`` [+ ``8 NC``] bytes) to ship
    across hosts or device pools. ``row_offset`` may be a traced scalar
    (e.g. a worker index inside ``shard_map``).

    With ``params.adaptive_lanes`` the internal tile lanes are two-pass
    sized (the wire lane stays ``NC`` wide — its ``G = 1`` makes it
    cheap already). Under jit/shard_map tracing the sizing host sync is
    impossible, so the traced caller must pre-measure and pass
    ``lane_width`` explicitly (see ``sharded_filter_compact``'s count
    wave); a traced call with ``adaptive_lanes`` and no ``lane_width``
    raises rather than silently falling back to worst-case lanes.
    """
    from repro.kernels.fused_probe import SIG_MODE_VARIANT

    if sig_mode is None:
        D, T = docs.shape
        sig_mode = _stream_sig_mode(params, D, T, max_len)
    NC = params.max_candidates
    if params.adaptive_lanes and lane_width is None:
        if isinstance(docs, jax.core.Tracer):
            raise ValueError(
                "shard_lane: ExtractParams(adaptive_lanes=True) under "
                "jit/shard_map tracing needs an explicit lane_width — the "
                "count-pass host sync cannot run inside a trace; measure "
                "with stream_tile_counts + round_lane_width outside the "
                "trace (sharded_filter_compact's count wave does this) "
                "and pass the width in"
            )
        lane_width = _adaptive_width(docs, max_len, flt, params, tile_docs)
    counts, cands, vkeys = stream_probe_tiles(
        docs, max_len, flt, params, tile_docs, row_offset=row_offset,
        lane_width=lane_width, sig_mode=sig_mode, stream_stats=stream_stats,
    )
    complete = lane_width is not None and lane_width < NC
    sel, ok, n = select_from_tiles(counts, cands, NC, complete_tiles=complete)
    keys = None
    if sig_mode == SIG_MODE_VARIANT:
        keys = gather_from_tiles(counts, vkeys, NC)[None, :, :]
    return jnp.where(ok, sel, -1)[None, :], n[None].astype(jnp.int32), keys


def shard_lane_steady(docs, row_offset, max_len, flt, params,
                      tile_docs: int = DEFAULT_TILE_DOCS,
                      width_hint: int | None = None,
                      sig_mode: str | None = None,
                      stream_stats: dict | None = None):
    """``shard_lane`` with steady-state adaptive sizing for serving.

    The adaptive two-pass scheme pays a count-only probe pass per call
    to size the emit lanes; on steady serving traffic consecutive
    batches of the same (session, bucket) see near-identical survivor
    densities, so the previous batch's measured per-tile maximum
    (``width_hint``) sizes this batch's emit width directly and the
    count pass is amortised away. Correctness never depends on the
    hint: the emit pass's SMEM counts are *true* totals, so an
    undersized hint is detected (``max(counts) > width``) and the emit
    re-runs at the measured width — still no count pass.

    Returns ``(lane, count, keys, tile_max, sizing)``: the ``shard_lane``
    wire triple plus the measured per-tile survivor max (the next
    batch's hint; ``-1`` on the non-adaptive path) and the sizing mode
    actually used (``fixed`` | ``count_pass`` | ``hint`` | ``refit``).
    """
    from repro.kernels.fused_probe import (
        MIN_LANE_WIDTH,
        SIG_MODE_VARIANT,
        round_lane_width,
    )

    if sig_mode is None:
        D, T = docs.shape
        sig_mode = _stream_sig_mode(params, D, T, max_len)
    NC = params.max_candidates
    if not params.adaptive_lanes:
        lane, n, keys = shard_lane(
            docs, row_offset, max_len, flt, params, tile_docs,
            sig_mode=sig_mode, stream_stats=stream_stats,
        )
        return lane, n, keys, -1, "fixed"
    if isinstance(docs, jax.core.Tracer):
        raise ValueError(
            "shard_lane_steady cannot run under jit/shard_map tracing: "
            "both the hint-overflow check and the count-pass fallback "
            "need host reads of the per-tile counts; serving calls it "
            "un-traced (the kernel passes are jitted internally)"
        )
    floor = params.lane_width or MIN_LANE_WIDTH
    if width_hint is not None and width_hint >= 0:
        W, sizing = round_lane_width(width_hint, NC, floor), "hint"
    else:
        counts = stream_tile_counts(docs, max_len, flt, params, tile_docs,
                                    stream_stats=stream_stats)
        W = round_lane_width(int(np.asarray(counts).max()), NC, floor)
        sizing = "count_pass"

    def emit(width):
        return stream_probe_tiles(
            docs, max_len, flt, params, tile_docs, row_offset=row_offset,
            lane_width=width, sig_mode=sig_mode, stream_stats=stream_stats,
        )

    counts, cands, vkeys = emit(W)
    tile_max = int(np.asarray(counts).max())
    if tile_max > W and W < NC:
        # stale hint undersized the lanes: the emit pass's counts are
        # true totals, so refit straight to the measured maximum — the
        # fallback costs one extra emit pass, never a count pass. At
        # W == NC there is nothing to refit (lanes never exceed the
        # merge capacity, and the select below is exact regardless).
        W = round_lane_width(tile_max, NC, floor)
        counts, cands, vkeys = emit(W)
        sizing = "refit"
    sel, ok, n = select_from_tiles(counts, cands, NC, complete_tiles=W < NC)
    keys = None
    if sig_mode == SIG_MODE_VARIANT:
        keys = gather_from_tiles(counts, vkeys, NC)[None, :, :]
    return (jnp.where(ok, sel, -1)[None, :], n[None].astype(jnp.int32),
            keys, tile_max, sizing)


def lanes_to_wire(docs, lanes, meta: dict | None = None) -> bytes:
    """Frame a probed batch's lanes for transport (fabric FT_LANES).

    ``lanes`` is the probe→verify handoff list: per plan side one
    ``(count [G] i32, cand [G, NC] i32, keys [G, NC, 2] u32 | None)``
    triple as produced by ``shard_lane`` / ``shard_lane_steady``;
    ``docs`` the batch's ``[D, T]`` token rows the remote verify pool
    gathers candidate windows from. The payload is the sha256-guarded
    npz container of ``updates.delta.pack_arrays``, so a truncated or
    cross-wired lane frame is detected at decode, and round-trips are
    bit-exact — remote ``select_from_tiles`` merges stay bit-identical
    to the in-process handoff.
    """
    from repro.updates.delta import pack_arrays

    m = dict(meta or {})
    m["kind"] = "lane_frame"
    m["n_sides"] = len(lanes)
    arrays = {"docs": np.asarray(docs, dtype=np.int32)}
    for i, (count, cand, keys) in enumerate(lanes):
        arrays[f"side{i}_count"] = np.asarray(count, dtype=np.int32)
        arrays[f"side{i}_cand"] = np.asarray(cand, dtype=np.int32)
        if keys is not None:
            arrays[f"side{i}_keys"] = np.asarray(keys, dtype=np.uint32)
    return pack_arrays(m, arrays)


def lanes_from_wire(data: bytes):
    """Inverse of ``lanes_to_wire`` → ``(meta, docs, lanes)``.

    Raises ``ValueError`` (from the container's fingerprint check) on
    any corruption; a decoded frame is the exact arrays that were
    framed.
    """
    from repro.updates.delta import unpack_arrays

    meta, arrays = unpack_arrays(data)
    if meta.get("kind") != "lane_frame":
        raise ValueError(
            f"lanes_from_wire: payload kind {meta.get('kind')!r} is not "
            "a lane_frame"
        )
    lanes = []
    for i in range(int(meta["n_sides"])):
        lanes.append((
            arrays[f"side{i}_count"],
            arrays[f"side{i}_cand"],
            arrays.get(f"side{i}_keys"),
        ))
    return meta, arrays["docs"], lanes


def sharded_filter_compact(
    doc_tokens,
    max_len: int,
    flt: tuple | None,
    params: engine.ExtractParams,
    mesh: Mesh | None = None,
    axis_name: str = DEFAULT_AXIS,
    shard_docs: int | None = None,
    tile_docs: int | None = None,
    checkpoint_dir: str | None = None,
    stream_stats: dict | None = None,
) -> dict:
    """Shard-parallel streaming candidate front end.

    Splits the corpus into ``shard_docs``-row shards, maps each wave of
    ``n_workers`` shards onto the mesh axis with ``shard_map`` (each
    device streams its shard's tiles through ``fused_probe``), and
    merges the per-shard candidate lanes into one global
    ``compact_candidates`` dict — bit-identical to running the
    unsharded ``engine.fused_filter_compact`` on the whole array. With
    ``mesh=None`` the wave loop degenerates to a sequential stream on
    the local device (same lanes, same merge, same outputs). More
    shards than devices are handled by multiple waves; short corpora
    and ragged tails are PAD-padded (PAD rows can never survive, so
    padding never perturbs the selection).

    ``checkpoint_dir`` makes the run killable and resumable: every
    finished shard's lane wire unit is persisted there (atomic npz, see
    ``LaneCheckpointStore``) and a restarted call with the same
    geometry/params/filter loads finished lanes instead of re-probing —
    the merge consumes the identical lanes either way, so resumed
    results are bit-identical. A manifest guards against resuming into
    a different job. ``stream_stats`` accumulates streaming +
    checkpoint observability counters.
    """
    from repro.kernels.fused_probe import SIG_MODE_VARIANT

    if max_len > 32 or not params.kernel_compact:
        # no epilogue -> no lanes to shard over; single-call fallback
        return engine.fused_filter_compact(doc_tokens, max_len, flt, params)
    D, T = doc_tokens.shape
    engine.check_flat_index_space(D, T, max_len)
    sig_mode = _stream_sig_mode(params, D, T, max_len)
    var = sig_mode == SIG_MODE_VARIANT
    n_workers = int(mesh.shape[axis_name]) if mesh is not None else 1
    spec = plan_shards(D, n_workers, shard_docs, tile_docs)
    NC = params.max_candidates
    n_waves = -(-spec.num_shards // n_workers)
    rows_padded = n_waves * n_workers * spec.shard_docs
    padded = doc_tokens
    if rows_padded != D:
        padded = jnp.pad(doc_tokens, ((0, rows_padded - D), (0, 0)),
                         constant_values=PAD)
    store = None
    if checkpoint_dir is not None:
        store = LaneCheckpointStore(
            checkpoint_dir,
            job_manifest(spec, T, max_len, params, flt, sig_mode),
        )

    lanes, totals, keys = [], [], []
    if mesh is None:
        for s in range(n_waves * n_workers):
            if store is not None and store.has(s):
                lane, n, vk = store.load(s)
            else:
                lane, n, vk = shard_lane(
                    padded[s * spec.shard_docs:(s + 1) * spec.shard_docs],
                    s * spec.shard_docs,
                    max_len, flt, params, spec.tile_docs, sig_mode=sig_mode,
                    stream_stats=stream_stats,
                )
                if store is not None:
                    store.save(s, lane, n, vk if var else None)
            lanes.append(lane)
            totals.append(n)
            if var:
                keys.append(vk)
    else:
        def wave_body(docs, row_off, lane_width=None):
            out = shard_lane(
                docs, row_off[0], max_len, flt, params, spec.tile_docs,
                lane_width=lane_width, sig_mode=sig_mode,
            )
            return out if var else out[:2]

        n_out = 3 if var else 2
        if params.adaptive_lanes:
            # adaptive under shard_map: the sizing host sync cannot live
            # inside the trace, so each wave runs a count-only shard_map
            # pass first and the measured width is traced in statically
            # (power-of-two rounding bounds the retrace count).
            from repro.kernels.fused_probe import (
                MIN_LANE_WIDTH,
                round_lane_width,
            )

            def count_body(docs):
                c = stream_tile_counts(
                    docs, max_len, flt, params, spec.tile_docs
                )
                return jnp.max(c)[None]

            count_fn = shard_map(
                count_body,
                mesh=mesh,
                in_specs=(P(axis_name),),
                out_specs=P(axis_name),
                check_vma=False,
            )
        else:
            count_fn = None
        wave_cache: dict = {}

        def wave_fn_for(lane_width):
            if lane_width not in wave_cache:
                wave_cache[lane_width] = shard_map(
                    lambda d, o: wave_body(d, o, lane_width=lane_width),
                    mesh=mesh,
                    in_specs=(P(axis_name), P(axis_name)),
                    out_specs=tuple([P(axis_name)] * n_out),
                    check_vma=False,
                )
            return wave_cache[lane_width]

        for w in range(n_waves):
            wave_shards = [w * n_workers + k for k in range(n_workers)]
            if store is not None and all(store.has(s) for s in wave_shards):
                # whole wave already checkpointed: load, skip the probes
                loaded = [store.load(s) for s in wave_shards]
                lanes.append(jnp.concatenate([x[0] for x in loaded], axis=0))
                totals.append(jnp.concatenate([x[1] for x in loaded]))
                if var:
                    keys.append(
                        jnp.concatenate([x[2] for x in loaded], axis=0)
                    )
                continue
            block = padded[
                w * n_workers * spec.shard_docs:(w + 1) * n_workers * spec.shard_docs
            ]
            offs = (
                (w * n_workers + jnp.arange(n_workers)) * spec.shard_docs
            ).astype(jnp.int32)
            lane_w = None
            if count_fn is not None:
                lane_w = round_lane_width(
                    int(np.asarray(count_fn(block)).max()),
                    NC,
                    params.lane_width or MIN_LANE_WIDTH,
                )
            out = wave_fn_for(lane_w)(block, offs)
            wave_lanes = out[0].reshape(n_workers, NC)
            wave_totals = out[1].reshape(n_workers)
            wave_keys = out[2].reshape(n_workers, NC, 2) if var else None
            if store is not None:
                for k, s in enumerate(wave_shards):
                    store.save(
                        s, wave_lanes[k:k + 1], wave_totals[k:k + 1],
                        wave_keys[k:k + 1] if var else None,
                    )
            lanes.append(wave_lanes)
            totals.append(wave_totals)
            if var:
                keys.append(wave_keys)

    if store is not None and stream_stats is not None:
        store.flush_stats(stream_stats)
    counts = jnp.concatenate(totals)
    cands = jnp.concatenate(lanes, axis=0)
    sel, ok, n = select_from_tiles(counts, cands, NC)
    out = engine.candidates_from_flat(doc_tokens, sel, ok, n, max_len, NC)
    if var:
        out = engine.attach_variant_keys(
            out, gather_from_tiles(counts, jnp.concatenate(keys, axis=0), NC)
        )
    return out


# --------------------------------------------------------------------------
# Corpus spill streaming: shards as *file regions*, resumable merges
# --------------------------------------------------------------------------

#: default device-resident budget for spill streaming: how many bytes of
#: staged documents one shard may occupy on device (see
#: ``shard_docs_for_budget`` for the headroom rule).
DEFAULT_DEVICE_BUDGET_BYTES = 256 << 20


def filter_fingerprint(flt: tuple | None) -> str:
    """Content hash of an ISH filter triple (checkpoint-manifest guard).

    Resuming a corpus job against a *different* filter would merge
    lanes probed under incompatible survival sets — the sha256 of the
    bit array (plus the probe parameters) makes that a manifest
    mismatch instead of silent corruption.
    """
    if flt is None:
        return "none"
    bits, num_bits, num_hashes = flt
    h = hashlib.sha256(np.asarray(bits).tobytes())
    h.update(f":{num_bits}:{num_hashes}".encode())
    return h.hexdigest()


def job_manifest(spec: ShardSpec, seq_len: int, max_len: int,
                 params: engine.ExtractParams, flt: tuple | None,
                 sig_mode: str) -> dict:
    """Everything that must match for two runs to share lane checkpoints.

    Geometry (shard/tile heights fix the lane layout and flat-index
    numbering), extraction params (capacity, scheme, lane sizing...) and
    the filter fingerprint (survival sets). JSON-round-tripped so the
    stored and compared forms are identical.
    """
    m = {
        "format": 1,
        "total_docs": spec.total_docs,
        "shard_docs": spec.shard_docs,
        "num_shards": spec.num_shards,
        "tile_docs": spec.tile_docs,
        "seq_len": seq_len,
        "max_len": max_len,
        "sig_mode": sig_mode,
        "filter": filter_fingerprint(flt),
        "params": dataclasses.asdict(params),
    }
    return json.loads(json.dumps(m))


class LaneCheckpointStore:
    """Per-shard lane checkpoints + job manifest under one directory.

    Layout: ``manifest.json`` (the ``job_manifest`` of the run) plus one
    ``shard_NNNNNN.npz`` per finished shard (atomic writes — a kill
    leaves whole files or none). A second run with an equal manifest
    resumes: ``has``/``load`` skip finished probes; a run with a
    *different* manifest raises instead of merging foreign lanes
    (``reset=True`` wipes the stale checkpoints and starts over).
    """

    def __init__(self, root: str, manifest: dict, reset: bool = False):
        self.root = root
        self.writes = 0
        self.hits = 0
        os.makedirs(root, exist_ok=True)
        mpath = os.path.join(root, "manifest.json")
        existing = None
        if os.path.exists(mpath):
            with open(mpath) as f:
                existing = json.load(f)
        if existing is not None and not reset:
            if existing != manifest:
                diff = sorted(
                    k for k in set(existing) | set(manifest)
                    if existing.get(k) != manifest.get(k)
                )
                raise ValueError(
                    f"checkpoint manifest mismatch in {root!r} (differing "
                    f"keys: {diff}): these lane checkpoints belong to a "
                    "different corpus job (other geometry, params, or "
                    "filter) and merging them would corrupt the selection; "
                    "point checkpoint_dir at a fresh directory, or pass "
                    "reset=True to discard the stale checkpoints"
                )
            return  # same job: resume against the existing checkpoints
        if existing is not None:
            for name in os.listdir(root):
                if name.startswith("shard_") and name.endswith(".npz"):
                    os.remove(os.path.join(root, name))
        tmp = f"{mpath}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(manifest, f, indent=1)
        os.replace(tmp, mpath)

    def _path(self, shard: int) -> str:
        return os.path.join(self.root, f"shard_{shard:06d}.npz")

    def has(self, shard: int) -> bool:
        return os.path.exists(self._path(shard))

    def load(self, shard: int):
        self.hits += 1
        return load_lane_checkpoint(self._path(shard))

    def save(self, shard: int, lane, count, keys=None) -> None:
        save_lane_checkpoint(self._path(shard), lane, count, keys)
        self.writes += 1

    def flush_stats(self, stream_stats: dict) -> None:
        """Fold this store's counters into a ``stream_stats`` dict."""
        stream_stats["checkpoint_writes"] = (
            stream_stats.get("checkpoint_writes", 0) + self.writes)
        stream_stats["checkpoint_hits"] = (
            stream_stats.get("checkpoint_hits", 0) + self.hits)


@dataclasses.dataclass
class MemmapCorpus:
    """A corpus as a *file*, not an array: flat int32 bin + JSON header.

    The spill-streaming driver treats a shard as a region of this file:
    only one staged shard is ever host/device resident. ``tokens`` is
    usually an ``np.memmap`` (``open``), but any [D, T] int32 array
    duck-types, so the driver also accepts in-memory corpora untouched.
    """

    tokens: np.ndarray  # [D, T] int32 (np.memmap after ``open``)

    @property
    def rows(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def seq_len(self) -> int:
        return int(self.tokens.shape[1])

    @classmethod
    def write(cls, path_base: str, docs) -> "MemmapCorpus":
        """Persist ``docs`` [D, T] as ``<base>.bin`` + ``<base>.json``."""
        arr = np.ascontiguousarray(np.asarray(docs, dtype=np.int32))
        with open(path_base + ".bin", "wb") as f:
            f.write(arr.tobytes())
        with open(path_base + ".json", "w") as f:
            json.dump({"format": 1, "rows": int(arr.shape[0]),
                       "seq_len": int(arr.shape[1]), "dtype": "int32"}, f)
        return cls.open(path_base)

    @classmethod
    def open(cls, path_base: str) -> "MemmapCorpus":
        with open(path_base + ".json") as f:
            hdr = json.load(f)
        assert hdr.get("dtype", "int32") == "int32", hdr
        tokens = np.memmap(path_base + ".bin", dtype=np.int32, mode="r",
                           shape=(hdr["rows"], hdr["seq_len"]))
        return cls(tokens=tokens)


class HostSpillStreamer:
    """Host->device spill feed: one reusable staging buffer per job.

    Stages shard-sized file regions through a single preallocated
    [shard_docs, T] host buffer (the pinned-host staging slot: on TPU
    this is the page-locked array ``device_put`` DMAs from; in
    interpret mode a plain ndarray plays the role) — no per-shard host
    allocation, ragged tails PAD-padded in place. ``bytes_staged``
    accumulates the host->device spill traffic for the corpus bench.
    """

    def __init__(self, corpus: MemmapCorpus, shard_docs: int):
        self.corpus = corpus
        self.shard_docs = shard_docs
        self._buf = np.empty((shard_docs, corpus.seq_len), dtype=np.int32)
        self.bytes_staged = 0

    def stage(self, shard: int):
        """Copy shard ``shard``'s file region in; return the device array."""
        start = shard * self.shard_docs
        rows = min(self.shard_docs, self.corpus.rows - start)
        assert rows > 0, f"shard {shard} starts past the corpus"
        self._buf[:rows] = self.corpus.tokens[start:start + rows]
        if rows < self.shard_docs:
            self._buf[rows:] = PAD
        self.bytes_staged += self._buf.nbytes
        return jnp.asarray(self._buf)


def shard_docs_for_budget(total_docs: int, seq_len: int, budget_bytes: int,
                          tile_docs: int | None = None) -> int:
    """Largest shard height whose working set fits ``budget_bytes``.

    The spill-buffer sizing rule: per-shard residency is dominated by
    the staged doc region (``rows * T * 4`` bytes) and the budget must
    hold *two* of them — the shard being probed plus the next one's
    host staging copy in flight (the host-level double buffer mirroring
    the in-kernel one). Lane outputs are O(G * W) ints and ride in the
    slack. Rounded down to whole tiles so shard geometry stays
    tile-aligned, floored at one tile (a budget below one tile streams
    tile-sized shards rather than failing).
    """
    td = tile_docs or DEFAULT_TILE_DOCS
    rows = int(budget_bytes) // (seq_len * 4 * 2)
    rows = max(td, (rows // td) * td)
    return max(1, min(rows, total_docs))


def spill_filter_compact(
    corpus,
    max_len: int,
    flt: tuple | None,
    params: engine.ExtractParams,
    device_budget_bytes: int | None = None,
    shard_docs: int | None = None,
    tile_docs: int | None = None,
    checkpoint_dir: str | None = None,
    reset_checkpoints: bool = False,
    stream_stats: dict | None = None,
    fail_after_shards: int | None = None,
) -> dict:
    """Corpus-scale candidate front end: shards as file regions.

    Streams a corpus that need not (and typically cannot) be
    device-resident: each shard is a region of ``corpus`` (a
    ``MemmapCorpus`` or any host [D, T] int32 array) staged through one
    reusable host buffer (``HostSpillStreamer``), probed by the
    streamed megakernel (``shard_lane`` -> single-launch DMA pipeline),
    and reduced to its lane wire unit; only lanes and one staged shard
    ever exist on device. Shard height comes from ``shard_docs`` or the
    ``device_budget_bytes`` sizing rule (``shard_docs_for_budget``;
    default ``DEFAULT_DEVICE_BUDGET_BYTES``).

    With ``checkpoint_dir`` every finished shard's lane is persisted
    (``LaneCheckpointStore``) and an interrupted run resumes from the
    last finished shard to *bit-identical* merged results — the final
    ``select_from_tiles`` merge consumes the same lanes either way. The
    final [N, L] window gather reads straight from the host corpus
    (``engine.candidates_from_flat_host``), so the merged output is
    field-for-field identical to ``sharded_filter_compact`` on a
    resident copy.

    ``fail_after_shards`` is the kill-switch test hook: raise after
    probing that many *fresh* shards this run (checkpoint loads don't
    count), simulating an interrupted job.
    """
    from repro.kernels.fused_probe import SIG_MODE_VARIANT

    if not isinstance(corpus, MemmapCorpus):
        corpus = MemmapCorpus(tokens=np.asarray(corpus))
    D, T = corpus.rows, corpus.seq_len
    engine.check_flat_index_space(D, T, max_len)
    if max_len > 32 or not params.kernel_compact:
        raise ValueError(
            "spill_filter_compact requires the in-kernel compaction "
            "epilogue (use_kernel=True with kernel_compact on, and "
            "max_len <= 32): without per-shard lanes there is nothing to "
            "spill-merge — run engine.fused_filter_compact on a resident "
            "corpus instead"
        )
    if shard_docs is None:
        budget = (DEFAULT_DEVICE_BUDGET_BYTES
                  if device_budget_bytes is None else device_budget_bytes)
        shard_docs = shard_docs_for_budget(D, T, budget, tile_docs)
    spec = plan_shards(D, 1, shard_docs, tile_docs)
    sig_mode = _stream_sig_mode(params, D, T, max_len)
    var = sig_mode == SIG_MODE_VARIANT
    NC = params.max_candidates
    store = None
    if checkpoint_dir is not None:
        store = LaneCheckpointStore(
            checkpoint_dir,
            job_manifest(spec, T, max_len, params, flt, sig_mode),
            reset=reset_checkpoints,
        )
    streamer = HostSpillStreamer(corpus, spec.shard_docs)

    lanes, totals, keys = [], [], []
    fresh = 0
    for s in range(spec.num_shards):
        if store is not None and store.has(s):
            lane, n, vk = store.load(s)
        else:
            if fail_after_shards is not None and fresh >= fail_after_shards:
                raise RuntimeError(
                    f"spill_filter_compact: simulated interruption after "
                    f"{fresh} fresh shards (fail_after_shards test hook)"
                )
            lane, n, vk = shard_lane(
                streamer.stage(s), s * spec.shard_docs, max_len, flt,
                params, spec.tile_docs, sig_mode=sig_mode,
                stream_stats=stream_stats,
            )
            if store is not None:
                store.save(s, lane, n, vk if var else None)
            fresh += 1
        lanes.append(lane)
        totals.append(n)
        if var:
            keys.append(vk)

    if stream_stats is not None:
        stream_stats["spill_bytes_staged"] = (
            stream_stats.get("spill_bytes_staged", 0) + streamer.bytes_staged)
        if store is not None:
            store.flush_stats(stream_stats)
    counts = jnp.concatenate(totals)
    cands = jnp.concatenate(lanes, axis=0)
    sel, ok, n = select_from_tiles(counts, cands, NC)
    out = engine.candidates_from_flat_host(
        corpus.tokens, sel, ok, n, max_len, NC
    )
    if var:
        out = engine.attach_variant_keys(
            out, gather_from_tiles(counts, jnp.concatenate(keys, axis=0), NC)
        )
    return out

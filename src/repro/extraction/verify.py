"""Verification join: batched similarity of (candidate window, entity)
pairs. This is the per-signature reducer verify of Def. 4 and the
post-lookup verify of Def. 3 — the compute hot-spot the
``kernels/jaccard_verify`` Pallas kernel accelerates; this module is the
jnp fallback + dispatch point.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.semantics import similarity


def verify_pairs(
    win_tokens,
    ent_ids,
    dict_tokens,
    token_weight,
    gamma: float,
    sim_name: str,
    use_kernel: bool = False,
):
    """Verify candidate (window, entity) pairs.

    win_tokens: [N, L] padded windows; ent_ids: [N, K] int32 (-1
    invalid); dict_tokens: [E, L]. Returns (hits [N, K] bool,
    scores [N, K] f32).
    """
    if use_kernel:
        from repro.kernels import ops as kops

        scores = kops.jaccard_verify(
            win_tokens, ent_ids, dict_tokens, token_weight, sim_name
        )
    else:
        safe_ids = jnp.maximum(ent_ids, 0)
        ent_toks = dict_tokens[safe_ids]  # [N, K, L]
        scores = similarity(
            sim_name,
            ent_toks,
            win_tokens[:, None, :],
            token_weight,
            xp=jnp,
        )
    hits = (scores >= gamma - 1e-6) & (ent_ids >= 0)
    return hits, scores


def dedup_hits(hit_mask, ent_ids):
    """Drop duplicate (window, entity) hits within each window's K list.

    The same entity can be reached through several signatures/tokens;
    keep only the first hit per (row, entity).
    """
    same = (ent_ids[:, :, None] == ent_ids[:, None, :]) & hit_mask[:, None, :]
    K = ent_ids.shape[1]
    earlier = jnp.tril(jnp.ones((K, K), dtype=bool), k=-1)
    dup = (same & earlier[None]).any(axis=-1)
    return hit_mask & ~dup

"""Sharded checkpointing with atomic writes and elastic restore.

Format: one ``.npz`` per checkpoint step holding every leaf under its
flattened key path, plus a small JSON manifest. Writes go to a temp dir
and are renamed into place (atomic on POSIX), so a crash mid-save never
corrupts the latest checkpoint — the restart logic always finds a
consistent one.

Elasticity: leaves are saved as *global* arrays keyed by logical path,
not by device layout. Restore re-shards onto whatever mesh/specs the
restarted job runs with (``device_put`` with the new NamedSharding), so
a 2-pod run can restart as 1-pod (or a differently-factored mesh)
without conversion — the re-mesh test in tests/test_train.py does
exactly this. On a multi-host deployment each host writes its addressable
shards (process-local slice of the same keys) — the single-host layout
here keeps that key scheme.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

_BF16_TAG = "::bf16"  # npz cannot hold bfloat16; stored as uint16 views


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            key += _BF16_TAG
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat


def save(ckpt_dir: str, step: int, params, opt_state, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = {f"params/{k}": v for k, v in _flatten(params).items()}
    flat |= {f"opt/{k}": v for k, v in _flatten(opt_state).items()}
    tmp = tempfile.mkdtemp(dir=ckpt_dir)
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "num_arrays": len(flat)}, f)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(ckpt_dir) if d.startswith("step_")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in reversed(steps):
        if os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            return int(d.split("_")[1])
    return None


def _unflatten_into(template, flat: dict, prefix: str, mesh=None, specs=None):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    spec_leaves = None
    if specs is not None:
        spec_leaves = jax.tree.flatten(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        )[0]
    leaves = []
    for i, (path, leaf) in enumerate(paths):
        key = prefix + "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        if key in flat:
            arr = flat[key]
        else:
            arr = flat[key + _BF16_TAG].view(jnp.bfloat16)
        if mesh is not None and spec_leaves is not None and i < len(spec_leaves):
            arr = jax.device_put(arr, NamedSharding(mesh, spec_leaves[i]))
        else:
            arr = jax.numpy.asarray(arr)
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree.unflatten(jax.tree.structure(template), leaves)


def restore(ckpt_dir: str, step: int, params_t, opt_t, mesh=None, specs=None):
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "arrays.npz")
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    params = _unflatten_into(params_t, flat, "params/", mesh, specs)
    opt = _unflatten_into(opt_t, flat, "opt/")
    return params, opt


def try_restore_latest(ckpt_dir: str, params_t, opt_t, mesh=None, specs=None):
    step = latest_step(ckpt_dir)
    if step is None:
        return None
    params, opt = restore(ckpt_dir, step, params_t, opt_t, mesh, specs)
    return params, opt, step

"""AdamW with fp32 master weights, global-norm clipping, LR schedules.

Self-contained (no optax in the environment). The optimizer state keeps
fp32 master params alongside m/v so model params can live in bf16; all
three share the model's PartitionSpecs (fully sharded optimizer state,
ZeRO-style, since params are FSDP-sharded over the ``data`` axis).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    # fp32 master copy of the params. Disabling saves 4 B/param of
    # optimizer state (the 132B-param dbrx config's HBM-fit lever —
    # §Perf log); updates then round through bf16 each step.
    fp32_master: bool = True


def lr_schedule(cfg: AdamWConfig, step):
    """Linear warmup -> cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_opt_state(params, fp32_master: bool = True):
    """(m, v[, master]) matching the param tree; master is fp32."""
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    state = {
        "m": zeros,
        "v": jax.tree.map(jnp.copy, zeros),
        "step": jnp.int32(0),
    }
    if fp32_master:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree):
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(lambda a, b: a + b, sq))


def adamw_update(cfg: AdamWConfig, params, grads, opt_state):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * master
        master_new = master - lr * delta
        return m_new, v_new, master_new

    has_master = "master" in opt_state
    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    flat_p = tdef.flatten_up_to(params)
    if has_master:
        flat_ma = tdef.flatten_up_to(opt_state["master"])
    else:
        flat_ma = [p.astype(jnp.float32) for p in flat_p]
    out = [upd(g, m, v, ma) for g, m, v, ma in zip(flat_g, flat_m, flat_v, flat_ma)]
    new_m = tdef.unflatten([o[0] for o in out])
    new_v = tdef.unflatten([o[1] for o in out])
    new_master = tdef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(
        lambda ma, p: ma.astype(p.dtype), new_master, params
    )
    new_state = {"m": new_m, "v": new_v, "step": step}
    if has_master:
        new_state["master"] = new_master
    return (
        new_params,
        new_state,
        {"grad_norm": gnorm, "lr": lr},
    )

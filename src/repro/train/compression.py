"""Int8 error-feedback gradient compression for cross-pod reductions.

Cross-pod DP traffic rides the slowest links of a multi-pod job; int8
quantisation cuts those bytes 4x vs fp32 (2x vs bf16). Plain
quantisation biases the update, so we keep the classic error-feedback
residual (1-bit Adam / EF-SGD lineage): the quantisation error of step t
is added back into the gradient at step t+1, making the long-run update
unbiased.

``compressed_psum`` is built for use inside a shard_map over the ``pod``
axis; ``quantize``/``dequantize`` are exposed separately so the trainer
can also use them for checkpoint-size reduction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import axis_size


def quantize(g, axis=None):
    """fp -> (int8, scale). Symmetric per-tensor scaling."""
    gf = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(gf))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, residual):
    """Error-feedback: corrected = grads + residual; returns
    (quantized tree [(q, scale) leaves], new residual tree)."""
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, s = quantize(corrected)
        deq = dequantize(q, s)
        return (q, s), corrected - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    qtree = tdef.unflatten([o[0] for o in outs])
    new_res = tdef.unflatten([o[1] for o in outs])
    return qtree, new_res


def compressed_psum(grads, residual, axis_name: str):
    """Inside shard_map: int8-quantise (with error feedback), all-gather
    the int8 payload over ``axis_name``, and dequant-sum locally.

    Summing int8 directly overflows, so the exchange is an all_gather of
    int8 + local fp32 reduction — the wire bytes are the int8 payload.
    Returns (mean-reduced grads, new residual).
    """
    n = axis_size(axis_name)

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, s = quantize(corrected)
        new_r = corrected - dequantize(q, s)
        qs = jax.lax.all_gather(q, axis_name)  # [n, ...] int8 on the wire
        ss = jax.lax.all_gather(s, axis_name)  # [n] scales (negligible)
        summed = (qs.astype(jnp.float32) * ss.reshape((n,) + (1,) * g.ndim)).sum(0)
        return summed / n, new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        tdef.unflatten([o[0] for o in outs]),
        tdef.unflatten([o[1] for o in outs]),
    )


def init_residual(grads_template):
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_template
    )

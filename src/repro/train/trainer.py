"""Training loop: microbatch gradient accumulation, sharded train_step,
metrics, periodic checkpointing, deterministic resume.

``make_train_step`` builds the jitted step for any (model, mesh):
  * the global batch enters sharded over (pod, data);
  * gradient accumulation scans over microbatches (the memory lever that
    fits dbrx-132b's train_4k — see EXPERIMENTS.md runtime table);
  * grads are accumulated in fp32 and fed to AdamW with fp32 masters;
  * optional int8 error-feedback compression for the cross-pod
    gradient reduction (train/compression.py).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Iterator

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import set_mesh
from repro.models.model import LM, fused_ce_loss
from repro.train import checkpoint as ckpt_lib
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    total_steps: int = 100
    microbatches: int = 1  # gradient-accumulation chunks per step
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    seed: int = 0


def make_train_step(model: LM, opt_cfg: AdamWConfig, microbatches: int = 1):
    """Returns jit-able fn(params, opt_state, batch) -> (params, opt, metrics)."""
    cfg = model.cfg

    def loss_fn(params, tokens, labels, context):
        x, aux = model.forward_features(params, tokens, context)
        loss, parts = fused_ce_loss(
            cfg, x, params["lm_head"], labels, moe_aux=aux["moe_aux"]
        )
        return loss, parts

    def step(params, opt_state, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        context = batch.get("context")
        B = tokens.shape[0]
        assert B % microbatches == 0, (B, microbatches)
        mb = B // microbatches

        if microbatches == 1:
            (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, tokens, labels, context
            )
        else:
            t_r = tokens.reshape(microbatches, mb, *tokens.shape[1:])
            l_r = labels.reshape(microbatches, mb, *labels.shape[1:])
            c_r = (
                context.reshape(microbatches, mb, *context.shape[1:])
                if context is not None
                else None
            )

            def acc_fn(carry, xs):
                g_acc, loss_acc = carry
                t, l = xs[0], xs[1]
                c = xs[2] if len(xs) > 2 else None
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, t, l, c
                )
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / microbatches, g_acc, g
                )
                return (g_acc, loss_acc + loss / microbatches), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            xs = (t_r, l_r) + ((c_r,) if c_r is not None else ())
            (grads, loss), _ = jax.lax.scan(acc_fn, (g0, 0.0), xs)
            parts = {"nll": loss, "zloss": jnp.float32(0.0)}

        new_params, new_opt, om = adamw_update(opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, **{k: v for k, v in parts.items()}, **om}
        return new_params, new_opt, metrics

    return step


def data_shardings(mesh, batch_axes=("data",)):
    spec = P(batch_axes)
    return NamedSharding(mesh, spec)


def train(
    model: LM,
    data_iter: Iterator[dict],
    opt_cfg: AdamWConfig,
    tcfg: TrainerConfig,
    mesh,
    params=None,
    specs=None,
    resume: bool = False,
) -> dict:
    """Run the loop; returns final metrics history. Restart-safe."""
    if params is None:
        params, specs = model.init(jax.random.PRNGKey(tcfg.seed))
    opt_state = init_opt_state(params)
    start_step = 0

    if resume:
        restored = ckpt_lib.try_restore_latest(
            tcfg.checkpoint_dir, params, opt_state, mesh, specs
        )
        if restored is not None:
            params, opt_state, start_step = restored

    step_fn = jax.jit(make_train_step(model, opt_cfg, tcfg.microbatches))
    history = []
    t0 = time.time()
    with set_mesh(mesh):
        for step in range(start_step, tcfg.total_steps):
            batch = next(data_iter)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if (step + 1) % tcfg.log_every == 0 or step == start_step:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step + 1
                m["sec_per_step"] = (time.time() - t0) / max(step - start_step + 1, 1)
                history.append(m)
            if (step + 1) % tcfg.checkpoint_every == 0:
                ckpt_lib.save(
                    tcfg.checkpoint_dir, step + 1, params, opt_state,
                    keep=tcfg.keep_checkpoints,
                )
    return {"history": history, "params": params, "opt_state": opt_state}

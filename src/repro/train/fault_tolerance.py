"""Fault tolerance: restart supervision, elastic re-meshing, stragglers.

At 1000+ nodes the failure model is: (a) a worker dies mid-step →
the whole synchronous job dies and is restarted by the cluster scheduler;
(b) the replacement capacity differs → the mesh must re-factor; (c) a
worker is slow → on a synchronous TPU mesh this is *skew*, not
straggling, and is handled at the data/shuffle level (capacity-bounded
all_to_all + the EE-Join job-completion objective), not by speculative
re-execution.

This module implements the supervisor side:

* ``run_with_restarts`` — supervises a training function, restoring from
  the newest consistent checkpoint on every crash (bounded retries,
  exponential backoff). Fault injection hooks make this testable.
* ``elastic_remesh`` — restores a checkpoint onto a *different* mesh
  factorisation (checkpoints are logical-keyed global arrays, so this is
  just a re-device_put; see train/checkpoint.py).
* ``StepBarrierMonitor`` — wall-clock watchdog per step; on a real
  deployment it feeds the scheduler's slow-node eviction. Here it
  records per-step durations and flags outliers (> k·median).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

import jax


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 3
    backoff_s: float = 0.1
    backoff_mult: float = 2.0


def run_with_restarts(
    train_fn: Callable[[bool], dict],
    policy: RestartPolicy = RestartPolicy(),
    on_restart: Callable[[int, BaseException], None] | None = None,
) -> dict:
    """Supervise ``train_fn(resume: bool)``; restart from checkpoints.

    ``train_fn`` must be restart-safe: when called with resume=True it
    restores the newest checkpoint and continues (trainer.train is).
    """
    delay = policy.backoff_s
    attempt = 0
    while True:
        try:
            return train_fn(attempt > 0)
        except (KeyboardInterrupt, SystemExit):
            raise
        except BaseException as e:  # noqa: BLE001 - supervisor boundary
            attempt += 1
            if attempt > policy.max_restarts:
                raise
            if on_restart is not None:
                on_restart(attempt, e)
            time.sleep(delay)
            delay *= policy.backoff_mult


def elastic_remesh(ckpt_dir: str, params_t, opt_t, new_mesh, new_specs):
    """Restore the latest checkpoint onto a different mesh factorisation."""
    from repro.train import checkpoint as C

    restored = C.try_restore_latest(ckpt_dir, params_t, opt_t, new_mesh, new_specs)
    if restored is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    return restored


@dataclasses.dataclass
class StepBarrierMonitor:
    """Flags steps whose wall time is an outlier (straggler telemetry)."""

    threshold: float = 3.0
    window: int = 50
    durations: list = dataclasses.field(default_factory=list)
    flagged: list = dataclasses.field(default_factory=list)
    _t0: float | None = None

    def start(self) -> None:
        self._t0 = time.time()

    def stop(self, step: int) -> bool:
        assert self._t0 is not None
        dt = time.time() - self._t0
        self.durations.append(dt)
        recent = self.durations[-self.window :]
        med = float(np.median(recent))
        slow = len(recent) >= 5 and dt > self.threshold * med
        if slow:
            self.flagged.append((step, dt, med))
        return slow

"""Version-compat shims for jax APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` to the
top-level ``jax`` namespace, and its replication-check kwarg was renamed
``check_rep`` -> ``check_vma`` in the move. All repro code imports
``shard_map`` from here and uses the *new* spelling (``check_vma``);
on older jax the shim translates the kwarg and delegates to the
experimental entry point.
"""
from __future__ import annotations

import functools

try:  # jax >= 0.6: top-level export, kwarg is check_vma
    from jax import shard_map as _shard_map

    shard_map = _shard_map
except ImportError:  # older jax: experimental module, kwarg is check_rep
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    @functools.wraps(_exp_shard_map)
    def shard_map(f, *args, check_vma: bool | None = None, **kwargs):
        if check_vma is not None:
            kwargs.setdefault("check_rep", check_vma)
        return _exp_shard_map(f, *args, **kwargs)


def set_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh for jit bodies.

    ``jax.set_mesh`` only exists on newer jax; on older releases the
    ``Mesh`` object itself is the equivalent context manager (it installs
    the physical mesh that ``shard_map``/``NamedSharding`` resolve axis
    names against), so the shim just returns ``mesh``.
    """
    import jax

    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def _make_barrier():
    # module-scope one-time custom_jvp registration (a per-call wrapper
    # would defeat jax's function-identity caches and re-register on
    # every retrace of a scanned layer body)
    import jax

    @jax.custom_jvp
    def _barrier(v):
        return jax.lax.optimization_barrier(v)

    @_barrier.defjvp
    def _barrier_jvp(primals, tangents):
        (v,), (t,) = primals, tangents
        return jax.lax.optimization_barrier(v), t

    return _barrier


_BARRIER = _make_barrier()


def optimization_barrier(x):
    """Differentiable ``jax.lax.optimization_barrier``.

    Older jax ships the primitive without a differentiation rule, which
    breaks ``grad`` through remat'd scan bodies that use the barrier as a
    scheduling hint. The hint never changes values, so the JVP barriers
    the primal and passes the tangent through untouched (linear, hence
    transposable for reverse mode).
    """
    return _BARRIER(x)


def axis_size(name: str):
    """Size of a named mesh axis from inside a shard_map/pmap body.

    ``jax.lax.axis_size`` is a newer addition; older jax gets the same
    value as a (constant-folded) ``psum(1)`` over the axis.
    """
    import jax

    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


__all__ = ["shard_map", "axis_size", "set_mesh", "optimization_barrier"]

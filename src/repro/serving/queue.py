"""Bounded request queue with admission control and backpressure.

The front door of the serving subsystem: producers (load generators,
RPC handlers) submit variable-length token documents; the service loop
drains admitted requests into the micro-batcher. The queue is the one
place load is shed — ``try_submit`` rejects when full (admission
control, surfaced in metrics as ``rejected``) and, with a
``session_quota``, when one dictionary's in-flight count hits its cap
(per-session shed, counted in ``rejected_by_session``). Backpressure lives one
level up: ``ExtractionService.submit(block=True)`` makes the producer
itself drain the queue into the batcher (``tick``) until space frees —
the ingest thread owns the batcher, so no second thread is needed.
Everything downstream is therefore bounded: batcher bins cap at one
un-flushed batch per (session, bucket), and the probe→verify handoff
holds at most two lanes.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
from collections import deque

import numpy as np


@dataclasses.dataclass
class ExtractRequest:
    """One in-flight extraction request (a single document).

    ``tokens`` is the raw variable-length int32 token sequence (PAD-free
    tail; the batcher pads to its length bucket). ``doc_id`` is the
    caller's global document id — match tuples are reported against it,
    so serving results can be compared 1:1 with a one-shot batch run.
    Timestamps are clock stamps filled in as the request moves through
    the pipeline (arrival → flush → done).
    """

    req_id: int
    doc_id: int
    tokens: np.ndarray
    session_key: str
    arrival_s: float
    error: str | None = None  # set when the request's batch failed
    flush_s: float = -1.0
    done_s: float = -1.0
    batch_id: int = -1
    # match tuples (doc_id, pos, length, entity, score) filled at completion
    matches: list = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def latency_s(self) -> float:
        return self.done_s - self.arrival_s if self.done else float("nan")


class AdmissionQueue:
    """Thread-safe bounded FIFO of admitted requests.

    ``try_submit`` is the admission-control path: reject-and-count when
    the system is saturated (open-loop producers read ``rejected`` as
    shed load). Request ids are assigned at admission, in admission
    order, so downstream tie-breaks (batcher flush ordering) are
    deterministic for a deterministic producer.
    """

    def __init__(self, capacity: int = 256, session_quota: int | None = None):
        if capacity <= 0:
            raise ValueError(f"AdmissionQueue capacity={capacity} must be positive")
        if session_quota is not None and session_quota <= 0:
            raise ValueError(
                f"AdmissionQueue session_quota={session_quota} must be "
                "positive (or None to disable per-session admission caps)"
            )
        self.capacity = capacity
        self.session_quota = session_quota
        self._q: deque[ExtractRequest] = deque()
        self._lock = threading.Lock()
        self._ids = itertools.count()
        self.accepted = 0
        self.rejected = 0
        self.rejected_quota = 0
        # per dictionary-fingerprint quota rejections (serving metrics
        # surface these: one hot dictionary shedding must be visible
        # separately from global queue saturation)
        self.rejected_by_session: dict[str, int] = {}

    def try_submit(self, doc_id, tokens, session_key: str, now: float,
                   session_inflight: int = 0) -> ExtractRequest | None:
        """Admit or reject (never block): returns None when full.

        ``session_inflight`` is the session's admitted-but-not-completed
        count (``DictionarySession.inflight``); with a ``session_quota``
        configured, a session at or past its quota is rejected even when
        the global queue has room — per-dictionary admission control, so
        one hot watchlist cannot monopolise the pipeline. Quota
        rejections are counted globally (``rejected_quota``) and per
        session (``rejected_by_session``), on top of ``rejected``.

        Counter semantics: the queue counts *admission attempts* (one
        per call); ``ServingMetrics`` counts one outcome per
        ``ExtractionService.submit`` call. The service's blocking
        backpressure loop therefore waits out a quota without
        re-attempting, so the two stay comparable.
        """
        with self._lock:
            if (self.session_quota is not None
                    and session_inflight >= self.session_quota):
                self.rejected += 1
                self.rejected_quota += 1
                self.rejected_by_session[session_key] = (
                    self.rejected_by_session.get(session_key, 0) + 1
                )
                return None
            if len(self._q) >= self.capacity:
                self.rejected += 1
                return None
            req = ExtractRequest(
                req_id=next(self._ids),
                doc_id=doc_id,
                tokens=np.asarray(tokens, dtype=np.int32).reshape(-1),
                session_key=session_key,
                arrival_s=now,
            )
            self._q.append(req)
            self.accepted += 1
            return req

    def take(self, max_n: int | None = None) -> list[ExtractRequest]:
        """Pop up to ``max_n`` requests in FIFO order (all when None)."""
        with self._lock:
            n = len(self._q) if max_n is None else min(max_n, len(self._q))
            return [self._q.popleft() for _ in range(n)]

    def depth(self) -> int:
        with self._lock:
            return len(self._q)

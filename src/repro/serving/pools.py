"""Disjoint probe / verify device pools.

The two stages of the serving pipeline have opposite resource shapes:
*probe* is bandwidth-bound (stream doc tiles through ``fused_probe``,
emit tiny [G, NC] lanes) while *verify* is compute-bound (gather
windows, signature-table probes, the ``jaccard_verify`` pair join).
Running them on **disjoint** device pools lets batch i+1's probe overlap
batch i's verify with no device contention — the [G, NC] lane is the
only traffic between the pools (see ``extraction.sharded.shard_lane``
for the wire format).

On a one-device host (CPU CI) both pools degenerate to the same device
(``shared=True``): the pipeline structure — double-buffered handoff,
per-stage placement, per-stage timing — is identical, only the physical
overlap is not observable, exactly like interpret-mode kernel runs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax


@dataclasses.dataclass(frozen=True)
class DevicePools:
    """Probe and verify device pools (disjoint unless ``shared``)."""

    probe: tuple[Any, ...]
    verify: tuple[Any, ...]
    shared: bool  # True only on the one-device degenerate host

    def probe_device(self, batch_id: int):
        """Round-robin probe placement for a batch."""
        return self.probe[batch_id % len(self.probe)]

    def verify_device(self, batch_id: int):
        return self.verify[batch_id % len(self.verify)]

    def describe(self) -> str:
        tag = "shared" if self.shared else "disjoint"
        return (
            f"probe pool {len(self.probe)} device(s), verify pool "
            f"{len(self.verify)} device(s) ({tag})"
        )


def make_pools(
    devices: Sequence[Any] | None = None,
    probe_fraction: float = 0.5,
) -> DevicePools:
    """Split the visible devices into disjoint probe/verify pools.

    ``probe_fraction`` of the devices (at least one) go to the probe
    pool, the rest to verify. With a single device both pools alias it —
    flagged ``shared`` so callers (metrics, benches) can report that
    overlap is structural only.
    """
    devs = tuple(devices if devices is not None else jax.devices())
    if not devs:
        raise ValueError("make_pools: no devices visible")
    if not 0.0 < probe_fraction < 1.0:
        raise ValueError(
            f"make_pools(probe_fraction={probe_fraction}) must be in (0, 1)"
        )
    if len(devs) == 1:
        return DevicePools(probe=devs, verify=devs, shared=True)
    n_probe = min(max(1, round(len(devs) * probe_fraction)), len(devs) - 1)
    return DevicePools(probe=devs[:n_probe], verify=devs[n_probe:], shared=False)

"""Micro-batcher: variable-length documents -> [D, T] probe tiles.

Admitted requests are routed into *bins* keyed by (session, length
bucket): each bucket is a power-of-two-ish tile width T and a document
joins the smallest bucket that holds it, so PAD waste is bounded by the
bucket ratio instead of the worst document in the batch. A bin flushes
into an immutable ``MicroBatch`` when either

* it is **full** (``max_batch_docs`` rows — the [D, T] tile the probe
  pool consumes), or
* its **deadline** expires (oldest admitted request waited
  ``max_delay_s`` — the latency/occupancy trade of every micro-batching
  serving system).

Flush ordering is deterministic: due bins flush in (session, bucket)
order and rows within a batch in admission order, so a seeded load
generator reproduces the exact same batch stream run-to-run (asserted
in tests; the serving benches depend on it).

Batch geometry reuses the sharded driver's ``plan_shards``: each batch
carries the ``ShardSpec`` that the probe stage streams tiles with, so
serving and offline sharding agree on tile heights by construction.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dictionary import PAD
from repro.extraction.sharded import ShardSpec, plan_shards
from repro.serving.queue import ExtractRequest

#: default length buckets (tile widths T); docs longer than the last
#: bucket are rejected at admission — growing this tuple is the knob.
DEFAULT_BUCKETS = (32, 64, 128, 256, 512)


@dataclasses.dataclass(frozen=True)
class BatcherConfig:
    """Static micro-batching knobs."""

    max_batch_docs: int = 32  # rows per flushed [D, T] batch
    max_delay_s: float = 0.005  # deadline from a bin's oldest admission
    buckets: tuple[int, ...] = DEFAULT_BUCKETS
    tile_docs: int | None = None  # probe-stream tile rows (None: driver default)

    def __post_init__(self):
        if self.max_batch_docs <= 0:
            raise ValueError(
                f"BatcherConfig.max_batch_docs={self.max_batch_docs} must be "
                "positive (rows per flushed [D, T] batch)"
            )
        if self.max_delay_s < 0:
            raise ValueError(
                f"BatcherConfig.max_delay_s={self.max_delay_s} must be >= 0 "
                "(0 flushes every poll: pure latency mode)"
            )
        if not self.buckets or any(
            b <= 0 or (i and b <= self.buckets[i - 1])
            for i, b in enumerate(self.buckets)
        ):
            raise ValueError(
                f"BatcherConfig.buckets={self.buckets} must be a non-empty "
                "strictly ascending tuple of tile widths"
            )

    def bucket_for(self, n_tokens: int) -> int:
        """Smallest bucket width holding ``n_tokens`` (admission check)."""
        for b in self.buckets:
            if n_tokens <= b:
                return b
        raise ValueError(
            f"document of {n_tokens} tokens exceeds the largest length "
            f"bucket {self.buckets[-1]}; add a bigger bucket to "
            "BatcherConfig.buckets or split the document upstream"
        )


@dataclasses.dataclass
class MicroBatch:
    """One flushed [D, T] unit of probe work (immutable after flush)."""

    batch_id: int
    session_key: str
    bucket: int  # tile width T
    reqs: list[ExtractRequest]
    docs: np.ndarray  # [Db, T] int32, PAD-padded rows in admission order
    spec: ShardSpec  # probe-stream geometry (plan_shards of this batch)
    flush_s: float
    capacity: int  # max_batch_docs at flush time
    # dictionary epoch the batch executes under, stamped at dispatch
    # (ExtractionService._dispatch pins it): the whole batch runs on one
    # epoch's prepared state even if the session hot-swaps mid-flight
    epoch: int = -1

    @property
    def rows(self) -> int:
        return len(self.reqs)

    @property
    def occupancy(self) -> float:
        return self.rows / self.capacity


class MicroBatcher:
    """Length-bucketed bins with deadline-based flush (single-threaded:
    the service's ingest loop owns it; threads only see flushed
    batches)."""

    def __init__(self, config: BatcherConfig = BatcherConfig()):
        self.config = config
        self._bins: dict[tuple[str, int], list[ExtractRequest]] = {}
        self._next_batch = 0

    def pending(self) -> int:
        return sum(len(v) for v in self._bins.values())

    def add(self, req: ExtractRequest) -> None:
        bucket = self.config.bucket_for(len(req.tokens))
        self._bins.setdefault((req.session_key, bucket), []).append(req)

    def _make_batch(self, key: tuple[str, int], reqs: list[ExtractRequest],
                    now: float) -> MicroBatch:
        session_key, bucket = key
        docs = np.full((len(reqs), bucket), PAD, dtype=np.int32)
        for i, r in enumerate(reqs):
            docs[i, : len(r.tokens)] = r.tokens
            r.flush_s = now
        batch = MicroBatch(
            batch_id=self._next_batch,
            session_key=session_key,
            bucket=bucket,
            reqs=reqs,
            docs=docs,
            spec=plan_shards(
                len(reqs),
                n_workers=1,
                shard_docs=len(reqs),
                tile_docs=self.config.tile_docs,
            ),
            flush_s=now,
            capacity=self.config.max_batch_docs,
        )
        self._next_batch += 1
        return batch

    def poll(self, now: float) -> list[MicroBatch]:
        """Flush every due bin: full, or oldest admission past deadline.

        Deterministic order: (session, bucket) ascending; a bin holding
        more than ``max_batch_docs`` rows (possible when one ``poll``
        admitted a burst) flushes in admission-order chunks.
        """
        return self._flush(now, force=False)

    def flush_all(self, now: float) -> list[MicroBatch]:
        """Drain every bin regardless of deadline (shutdown / drain)."""
        return self._flush(now, force=True)

    def _flush(self, now: float, force: bool) -> list[MicroBatch]:
        out: list[MicroBatch] = []
        cap = self.config.max_batch_docs
        for key in sorted(self._bins):
            reqs = self._bins.pop(key)
            while len(reqs) >= cap:  # full bins always flush
                head, reqs = reqs[:cap], reqs[cap:]
                out.append(self._make_batch(key, head, now))
            due = reqs and (force or now - reqs[0].arrival_s >= self.config.max_delay_s)
            if due:
                out.append(self._make_batch(key, reqs, now))
            elif reqs:
                self._bins[key] = reqs
        return out

"""Dictionary session cache: fingerprint -> prepared extraction state.

Preparing a dictionary for serving is expensive relative to one request:
building the ISH/Bloom filter, entity signature tables or index
partitions, gathering statistics and (optionally) calibrating the cost
model to choose a plan. A *session* is that prepared state, keyed by a
content fingerprint of the dictionary (plus the config knobs that shape
the prepared structures), so

* a stream of requests against the same dictionary pays the build cost
  once (the cost-based plan choice of the paper amortised across the
  stream), and
* multiple dictionaries are served concurrently — the micro-batcher
  keys its bins by session, so batches never mix dictionaries.

Eviction is LRU over ``max_sessions`` (prepared state is device memory:
filters + signature tables + dictionary slices).
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict

import numpy as np

from repro.core.cost_model import OBJ_JOB, CostParams, SideCost
from repro.core.dictionary import Dictionary
from repro.core.eejoin import EEJoinConfig, EEJoinOperator, PreparedPlan
from repro.core.plan import Plan, PlanSide


def dictionary_fingerprint(dictionary: Dictionary,
                           config: EEJoinConfig) -> str:
    """Content hash of (dictionary, prepared-structure knobs).

    Two dictionaries with identical token matrices, weights and
    frequencies — and identical config knobs that shape the prepared
    filter/signatures/plan — share a session; anything else gets its
    own. Config is folded in via its dataclass repr (EEJoinConfig is a
    frozen dataclass of scalars/tuples, so the repr is canonical).
    """
    h = hashlib.sha256()
    for arr in (
        dictionary.tokens,
        dictionary.lengths,
        dictionary.freq,
        dictionary.token_weight,
        dictionary.entity_weight,
    ):
        a = np.ascontiguousarray(arr)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    h.update(repr(config).encode())
    return h.hexdigest()[:16]


def pure_plan(scheme: str, algo: str = "ssjoin") -> Plan:
    """Forced single-algorithm plan (split=0 tail) for stat-less sessions.

    Public: the ``serve_extract --plan forced`` mode, the serving bench
    and tests all serve against forced pure plans.
    """
    z = SideCost(0, 0, 0, 0, 0, 0, 0, 0, 0)
    return Plan(0, PlanSide(algo, scheme), PlanSide(algo, scheme),
                OBJ_JOB, 0.0, z, z, 0)


@dataclasses.dataclass
class DictionarySession:
    """One cached dictionary's serving state (lives on device)."""

    key: str
    dictionary: Dictionary
    config: EEJoinConfig
    operator: EEJoinOperator
    plan: Plan
    prepared: PreparedPlan
    calibrated: bool
    # the cost constants the plan was chosen/prepared under; after a
    # calibrated build this carries the measured survivor density
    # (CostParams.lane_density) that sizes adaptive candidate lanes —
    # kept on the session so serving dashboards and the bench can
    # compare planned vs measured lane widths.
    cost_params: CostParams | None = None
    # serving counters (metrics reads them)
    requests: int = 0
    batches: int = 0
    # admitted-but-not-completed requests: pins the session against LRU
    # eviction (maintained by ExtractionService.submit/_complete)
    inflight: int = 0

    @property
    def max_len(self) -> int:
        return self.prepared.max_entity_len


class SessionCache:
    """LRU cache of ``DictionarySession`` keyed by dictionary fingerprint."""

    def __init__(self, max_sessions: int = 8):
        if max_sessions <= 0:
            raise ValueError(
                f"SessionCache max_sessions={max_sessions} must be positive"
            )
        self.max_sessions = max_sessions
        self._sessions: OrderedDict[str, DictionarySession] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._sessions)

    def get(self, key: str) -> DictionarySession:
        """Lookup by fingerprint (raises KeyError on unknown sessions)."""
        sess = self._sessions[key]
        self._sessions.move_to_end(key)
        return sess

    def get_or_create(
        self,
        dictionary: Dictionary,
        config: EEJoinConfig | None = None,
        plan: Plan | None = None,
        sample_docs: np.ndarray | None = None,
        cost_params: CostParams | None = None,
        calibrate: bool = False,
        default_scheme: str = "prefix",
    ) -> DictionarySession:
        """Return the cached session for ``dictionary`` (building it on miss).

        Plan choice on miss, most- to least-informed:

        * ``plan`` given — use it verbatim (tests / forced plans);
        * ``sample_docs`` given — gather statistics and run the §5 plan
          search, after rescaling the cost constants to this host when
          ``calibrate=True`` (``core/calibrate``);
        * neither — a pure ``ssjoin:default_scheme`` plan (stat-less
          cold start; the session can be evicted and rebuilt with stats
          once traffic provides a sample).
        """
        cfg = config or EEJoinConfig(use_kernel=True)
        if not cfg.use_kernel:
            raise ValueError(
                "serving sessions require EEJoinConfig(use_kernel=True): the "
                "probe stage streams batches through fused_probe and hands "
                "[G, NC] lanes to the verify pool — there is no unfused "
                "serving path"
            )
        if dictionary.max_len > 32:
            raise ValueError(
                f"dictionary.max_len={dictionary.max_len} exceeds 32: the "
                "probe stage's packed survival bitmap holds one window "
                "length per uint32 bit (ops.fused_probe_compact), so served "
                "dictionaries must keep entities <= 32 tokens"
            )
        key = dictionary_fingerprint(dictionary, cfg)
        if key in self._sessions:
            self.hits += 1
            self._sessions.move_to_end(key)
            return self._sessions[key]
        self.misses += 1
        # make room *before* the expensive build: LRU among *idle*
        # sessions only — evicting one with admitted or in-flight
        # requests would strand them (the service's flush/verify would
        # KeyError mid-pipeline)
        while len(self._sessions) >= self.max_sessions:
            victim = next(
                (k for k, s in self._sessions.items() if s.inflight == 0),
                None,
            )
            if victim is None:
                raise RuntimeError(
                    f"SessionCache is full ({self.max_sessions} sessions) "
                    "and every session has in-flight requests; drain the "
                    "service before adding dictionaries, or raise "
                    "max_sessions"
                )
            del self._sessions[victim]
            self.evictions += 1
        op = EEJoinOperator(dictionary, cfg)
        cp = cost_params or CostParams(num_devices=1)
        calibrated = False
        if plan is None:
            if sample_docs is not None:
                if calibrate:
                    from repro.core.calibrate import calibrate as _calib

                    cp = _calib(op, np.asarray(sample_docs), cp,
                                scheme=default_scheme)
                    calibrated = True
                stats = op.gather_statistics(
                    np.asarray(sample_docs), total_docs=len(sample_docs)
                )
                plan = op.choose_plan(stats, cp)
            else:
                plan = pure_plan(default_scheme)
        prepared = op.prepare(plan, cp)
        sess = DictionarySession(
            key=key,
            dictionary=dictionary,
            config=cfg,
            operator=op,
            plan=plan,
            prepared=prepared,
            calibrated=calibrated,
            cost_params=cp,
        )
        self._sessions[key] = sess
        return sess

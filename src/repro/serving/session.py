"""Dictionary session cache: fingerprint -> prepared extraction state.

Preparing a dictionary for serving is expensive relative to one request:
building the ISH/Bloom filter, entity signature tables or index
partitions, gathering statistics and (optionally) calibrating the cost
model to choose a plan. A *session* is that prepared state, keyed by a
content fingerprint of the dictionary (plus the config knobs that shape
the prepared structures), so

* a stream of requests against the same dictionary pays the build cost
  once (the cost-based plan choice of the paper amortised across the
  stream), and
* multiple dictionaries are served concurrently — the micro-batcher
  keys its bins by session, so batches never mix dictionaries.

Eviction is LRU over ``max_sessions`` (prepared state is device memory:
filters + signature tables + dictionary slices).
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
from collections import OrderedDict

import numpy as np

from repro.core.cost_model import (
    MAINT_ABSORB,
    MAINT_COMPACT,
    MAINT_REBUILD,
    OBJ_JOB,
    CostParams,
    MaintenancePlan,
    SideCost,
    maintenance_plan,
)
from repro.core.dictionary import Dictionary
from repro.core.eejoin import EEJoinConfig, EEJoinOperator, PreparedPlan
from repro.core.plan import Plan, PlanSide
from repro.updates import builders as _upd
from repro.updates.delta import DictionaryDelta


def dictionary_fingerprint(dictionary: Dictionary,
                           config: EEJoinConfig) -> str:
    """Content hash of (dictionary, prepared-structure knobs).

    Two dictionaries with identical token matrices, weights and
    frequencies — and identical config knobs that shape the prepared
    filter/signatures/plan — share a session; anything else gets its
    own. Config is folded in via its dataclass repr (EEJoinConfig is a
    frozen dataclass of scalars/tuples, so the repr is canonical).
    """
    h = hashlib.sha256()
    for arr in (
        dictionary.tokens,
        dictionary.lengths,
        dictionary.freq,
        dictionary.token_weight,
        dictionary.entity_weight,
    ):
        a = np.ascontiguousarray(arr)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    h.update(repr(config).encode())
    return h.hexdigest()[:16]


def pure_plan(scheme: str, algo: str = "ssjoin") -> Plan:
    """Forced single-algorithm plan (split=0 tail) for stat-less sessions.

    Public: the ``serve_extract --plan forced`` mode, the serving bench
    and tests all serve against forced pure plans.
    """
    z = SideCost(0, 0, 0, 0, 0, 0, 0, 0, 0)
    return Plan(0, PlanSide(algo, scheme), PlanSide(algo, scheme),
                OBJ_JOB, 0.0, z, z, 0)


@dataclasses.dataclass
class DictionarySession:
    """One cached dictionary's serving state (lives on device)."""

    key: str
    dictionary: Dictionary
    config: EEJoinConfig
    operator: EEJoinOperator
    plan: Plan
    prepared: PreparedPlan
    calibrated: bool
    # the cost constants the plan was chosen/prepared under; after a
    # calibrated build this carries the measured survivor density
    # (CostParams.lane_density) that sizes adaptive candidate lanes —
    # kept on the session so serving dashboards and the bench can
    # compare planned vs measured lane widths.
    cost_params: CostParams | None = None
    # serving counters (metrics reads them)
    requests: int = 0
    batches: int = 0
    # admitted-but-not-completed requests: pins the session against LRU
    # eviction (maintained by ExtractionService.submit/_complete)
    inflight: int = 0
    # ---- live updates (repro.updates): epoch-versioned hot swap ----
    # epoch number -> executable state; ``epoch`` is the current one.
    # Past epochs stay alive while batches are pinned to them (see
    # pin_epoch/unpin_epoch) and are dropped at the last unpin — no
    # drain, no eviction on apply_delta.
    epochs: dict = dataclasses.field(default_factory=dict)
    epoch: int = 0
    maintenance_log: list = dataclasses.field(default_factory=list)
    # replication source of truth (fabric.cluster): every applied
    # change in order, carrying exactly what a replica needs to replay
    # it deterministically — the delta + the maintenance action
    # actually taken (compaction renumbers ids, so replicas must never
    # re-decide), the sample docs when the action was a rebuild, and
    # the (plan, cost_params) pair for replans.
    delta_log: list = dataclasses.field(default_factory=list)
    # the (possibly telemetry-refitted) constants the last
    # plan_maintenance call actually costed with — inspection hook for
    # tests and the serve report
    last_maintenance_params: CostParams | None = None
    # steady-state lane sizing hints: (side_idx, bucket) -> (epoch,
    # measured per-tile survivor max of the last batch). A hint from
    # another epoch is stale (density may have shifted with the delta)
    # and falls back to a count pass.
    lane_hints: dict = dataclasses.field(default_factory=dict)
    # ---- continuous calibration (serving.replan) ----
    # per-session serving telemetry (ObservedStats), attached lazily by
    # the service's Replanner; None when replanning is off.
    observed: object | None = None
    # the frozen PlanBaseline drift is measured against (replanner-owned)
    replan_baseline: object | None = None
    # operator escape hatch: a pinned plan is never replanned (see
    # pin_plan / docs/serving.md "how to pin a plan")
    replan_pinned: bool = False
    _lock: threading.Lock = dataclasses.field(default_factory=threading.Lock)
    # serializes whole apply_delta calls (read chain -> build -> install).
    # Separate from _lock on purpose: the segment build is slow and must
    # not block dispatch's pin_current, which only needs _lock briefly.
    _apply_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock
    )

    @property
    def max_len(self) -> int:
        return self.prepared.max_entity_len

    # ------------------------------------------------------------- epochs
    @property
    def current_state(self) -> _upd.EpochState:
        with self._lock:
            return self.epochs[self.epoch]

    def state_for(self, epoch: int) -> _upd.EpochState:
        """The executable state of one (possibly past, pinned) epoch."""
        with self._lock:
            return self.epochs[epoch]

    def pin_current(self) -> int:
        """Atomically pin the current epoch and return its number.

        Dispatch must use this rather than reading ``epoch`` and then
        pinning: between the two steps a concurrent ``apply_delta``
        could swap and garbage-collect the epoch just read.
        """
        with self._lock:
            self.epochs[self.epoch].pins += 1
            return self.epoch

    def unpin_epoch(self, epoch: int) -> None:
        """Batch finished: release, GC non-current epochs at zero pins."""
        with self._lock:
            state = self.epochs[epoch]
            state.pins -= 1
            if state.pins <= 0 and epoch != self.epoch:
                del self.epochs[epoch]

    def lane_hint(self, side_idx: int, bucket: int, epoch: int) -> int | None:
        """Previous batch's per-tile survivor max for (side, bucket)."""
        got = self.lane_hints.get((side_idx, bucket))
        if got is None or got[0] != epoch:
            return None  # never measured, or stale (other epoch)
        return got[1]

    def update_lane_hint(self, side_idx: int, bucket: int, epoch: int,
                         tile_max: int) -> None:
        if tile_max >= 0:
            self.lane_hints[(side_idx, bucket)] = (epoch, int(tile_max))

    def pin_plan(self, pinned: bool = True) -> None:
        """Pin (or unpin) the current plan against online replanning.

        A pinned session still feeds its ``ObservedStats`` (telemetry
        keeps flowing) but the replanner skips it entirely — no drift
        evaluation, no refit, no swap.
        """
        self.replan_pinned = pinned

    def apply_replan(self, plan: Plan, cost_params: CostParams,
                     reason: str = "drift") -> _upd.EpochState:
        """Hot-swap to a new epoch running ``plan`` — same dictionary.

        The online-replanning analogue of ``apply_delta``: the new
        epoch shares the dictionary version's entity id space (no
        renumbering, segments and tombstones carry over — see
        ``updates.builders.replan_epoch``), so a replan never changes
        the results of any batch, only its cost. In-flight batches
        pinned to earlier epochs finish on their admitted state;
        admissions after this call probe and verify under the new plan.
        Serializes with ``apply_delta`` on ``_apply_lock``.
        """
        with self._apply_lock:
            cur = self.current_state
            state = _upd.replan_epoch(cur, plan, self.config, cost_params)
            with self._lock:
                old_epoch = self.epoch
                self.epochs[state.epoch] = state
                self.epoch = state.epoch
                if self.epochs[old_epoch].pins <= 0:
                    del self.epochs[old_epoch]
                self.plan = state.plan
                self.prepared = PreparedPlan(
                    plan=state.plan,
                    sides=[es.base for es in state.sides],
                    max_entity_len=state.max_len,
                )
                self.cost_params = cost_params
            self.maintenance_log.append({
                "epoch": state.epoch,
                "action": "replan",
                "reason": reason,
                "open_segments": state.open_segments,
            })
            self.delta_log.append({
                "parent_epoch": cur.epoch,
                "epoch": state.epoch,
                "action": "replan",
                "plan": plan,
                "cost_params": cost_params,
            })
            return state

    def plan_maintenance(
        self,
        delta: DictionaryDelta,
        horizon_batches: float | None = None,
        stat_drift: float = 0.0,
        drift_threshold: float = 0.5,
    ) -> MaintenancePlan:
        """Cost the absorb/compact/rebuild choice for ``delta``.

        The probe-volume estimate is the candidate-lane capacity (the
        static upper bound on windows a batch probes). Overestimating
        it inflates the open-segment overhead term, which sits on the
        absorb side of the comparison — so the bias is toward *earlier
        compaction*, trading some redundant fold work for never
        under-accounting LSM read amplification. The horizon defaults
        to the batches served so far (the past predicts the next
        window).
        """
        cur = self.current_state
        cp = self.cost_params or CostParams(num_devices=1)
        if self.observed is not None:
            # continuous calibration reaches the maintenance planner
            # too: the absorb/compact/rebuild comparison runs over the
            # same measurement-rescaled constants the extraction replan
            # uses, so both planners see one consistent cost world. The
            # refit is pure and idempotent (core.calibrate.refit_params)
            # — a cold ObservedStats refits to the identity.
            from repro.core.calibrate import refit_params
            from repro.serving.replan import plan_schemes

            cp = refit_params(
                cp, self.observed,
                schemes=plan_schemes(self.plan,
                                     self.dictionary.num_entities),
            )
        self.last_maintenance_params = cp
        return maintenance_plan(
            cp,
            live_entities=cur.version.num_live + delta.num_added
            - delta.num_tombstoned,
            delta_entities=delta.num_added,
            open_segments=cur.open_segments + (1 if delta.num_added else 0),
            dead_entities=int(cur.version.tombstones.sum())
            + delta.num_tombstoned,
            total_entities=cur.version.total_entities + delta.num_added,
            probes_per_batch=float(self.config.max_candidates),
            horizon_batches=(
                horizon_batches
                if horizon_batches is not None
                else float(max(self.batches, 1))
            ),
            stat_drift=stat_drift,
            drift_threshold=drift_threshold,
        )

    def apply_delta(
        self,
        delta: DictionaryDelta,
        sample_docs: np.ndarray | None = None,
        horizon_batches: float | None = None,
        drift_threshold: float = 0.5,
        force_action: str | None = None,
    ) -> _upd.EpochState:
        """Hot-swap to a new epoch with ``delta`` applied — no drain.

        The cost model's maintenance terms pick the action (absorb an
        open segment / compact / full rebuild) unless ``force_action``
        overrides; ``sample_docs`` lets the session measure stat drift
        (survivor-density shift vs the density the plan was calibrated
        under) — the only trigger for a re-plan, per the carry-the-
        warm-plan-forward contract. In-flight batches pinned to earlier
        epochs keep executing against their state; admissions after
        this call see the new epoch. Returns the new current state.

        Whole calls serialize on ``_apply_lock`` (chain read → build →
        install is one critical section): two concurrent deltas applied
        against the same parent would otherwise silently drop one.
        """
        if force_action == MAINT_REBUILD and sample_docs is None:
            raise ValueError(
                "apply_delta(force_action='rebuild') requires sample_docs: "
                "a re-plan gathers statistics and re-runs the plan search "
                "over them — pass a document sample, or use "
                "force_action='compact' to fold without re-planning"
            )
        with self._apply_lock:
            return self._apply_delta_locked(
                delta, sample_docs, horizon_batches, drift_threshold,
                force_action,
            )

    def _apply_delta_locked(
        self, delta, sample_docs, horizon_batches, drift_threshold,
        force_action,
    ) -> _upd.EpochState:
        drift, new_density = 0.0, None
        if sample_docs is not None:
            from repro.core.calibrate import measured_lane_density

            stats = self.operator.gather_statistics(
                np.asarray(sample_docs), total_docs=len(sample_docs)
            )
            new_density = measured_lane_density(stats)
            old = (self.cost_params.lane_density
                   if self.cost_params is not None else 0.0)
            if old > 0.0:
                drift = abs(new_density - old) / old
        decision = self.plan_maintenance(
            delta, horizon_batches, stat_drift=drift,
            drift_threshold=drift_threshold,
        )
        action = force_action or decision.action
        if action == MAINT_REBUILD and sample_docs is None:
            # planner-chosen (never forced — apply_delta validates that):
            # without a sample there are no statistics to re-plan over,
            # so fold the drift-suspect state and keep serving
            action = MAINT_COMPACT
        cur = self.current_state
        cp = self.cost_params or CostParams(num_devices=1)
        new_op = None
        if action == MAINT_ABSORB:
            state = _upd.absorb_delta(cur, delta, self.config, cp)
        else:
            # fold the delta in version-space first (O(delta)), then
            # compact/rebuild the whole live set in one build pass —
            # never build segment structures that are about to fold
            applied = dataclasses.replace(
                cur, version=cur.version.apply(delta)
            )
            if action == MAINT_COMPACT:
                state, new_op = _upd.compact_epoch(applied, self.config, cp)
            elif action == MAINT_REBUILD:
                state, new_op = _upd.rebuild_epoch(
                    applied, self.config, cp, np.asarray(sample_docs)
                )
            else:
                raise ValueError(f"unknown maintenance action {action!r}")
        with self._lock:
            old_epoch = self.epoch
            self.epochs[state.epoch] = state
            self.epoch = state.epoch
            if self.epochs[old_epoch].pins <= 0:
                del self.epochs[old_epoch]
            if new_op is not None:
                # the compacted/re-planned base becomes the session's
                # frozen-path view (one_shot_reference, future deltas)
                self.operator = new_op
                self.dictionary = new_op.dictionary
                self.plan = state.plan
                self.prepared = PreparedPlan(
                    plan=state.plan,
                    sides=[es.base for es in state.sides],
                    max_entity_len=state.max_len,
                )
            if action == MAINT_REBUILD and new_density is not None:
                # the re-plan resolved the drift: reset the baseline so
                # the *next* delta is measured against the density this
                # plan was chosen under, not the stale pre-drift value
                # (which would re-trigger a full rebuild on every delta)
                self.cost_params = dataclasses.replace(
                    self.cost_params or CostParams(num_devices=1),
                    lane_density=new_density,
                )
        self.maintenance_log.append({
            "epoch": state.epoch,
            "action": action,
            "added": delta.num_added,
            "tombstoned": delta.num_tombstoned,
            "open_segments": state.open_segments,
            "absorb_s": decision.absorb_s,
            "compact_s": decision.compact_s,
            "overhead_per_batch_s": decision.overhead_per_batch_s,
            "stat_drift": decision.stat_drift,
        })
        self.delta_log.append({
            "parent_epoch": cur.epoch,
            "epoch": state.epoch,
            "action": action,
            "delta": delta,
            # replicas replaying a rebuild need the exact statistics
            # sample the plan search ran over; other actions replay
            # sample-free (forced action skips the drift question)
            "sample_docs": (
                np.asarray(sample_docs)
                if action == MAINT_REBUILD and sample_docs is not None
                else None
            ),
        })
        return state


class SessionCache:
    """LRU cache of ``DictionarySession`` keyed by dictionary fingerprint."""

    def __init__(self, max_sessions: int = 8):
        if max_sessions <= 0:
            raise ValueError(
                f"SessionCache max_sessions={max_sessions} must be positive"
            )
        self.max_sessions = max_sessions
        self._sessions: OrderedDict[str, DictionarySession] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._sessions)

    def get(self, key: str) -> DictionarySession:
        """Lookup by fingerprint (raises KeyError on unknown sessions)."""
        sess = self._sessions[key]
        self._sessions.move_to_end(key)
        return sess

    def get_or_create(
        self,
        dictionary: Dictionary,
        config: EEJoinConfig | None = None,
        plan: Plan | None = None,
        sample_docs: np.ndarray | None = None,
        cost_params: CostParams | None = None,
        calibrate: bool = False,
        default_scheme: str = "prefix",
    ) -> DictionarySession:
        """Return the cached session for ``dictionary`` (building it on miss).

        Plan choice on miss, most- to least-informed:

        * ``plan`` given — use it verbatim (tests / forced plans);
        * ``sample_docs`` given — gather statistics and run the §5 plan
          search, after rescaling the cost constants to this host when
          ``calibrate=True`` (``core/calibrate``);
        * neither — a pure ``ssjoin:default_scheme`` plan (stat-less
          cold start; the session can be evicted and rebuilt with stats
          once traffic provides a sample).
        """
        cfg = config or EEJoinConfig(use_kernel=True)
        if not cfg.use_kernel:
            raise ValueError(
                "serving sessions require EEJoinConfig(use_kernel=True): the "
                "probe stage streams batches through fused_probe and hands "
                "[G, NC] lanes to the verify pool — there is no unfused "
                "serving path"
            )
        if dictionary.max_len > 32:
            raise ValueError(
                f"dictionary.max_len={dictionary.max_len} exceeds 32: the "
                "probe stage's packed survival bitmap holds one window "
                "length per uint32 bit (ops.fused_probe_compact), so served "
                "dictionaries must keep entities <= 32 tokens"
            )
        key = dictionary_fingerprint(dictionary, cfg)
        if key in self._sessions:
            self.hits += 1
            self._sessions.move_to_end(key)
            return self._sessions[key]
        self.misses += 1
        # make room *before* the expensive build: LRU among *idle*
        # sessions only — evicting one with admitted or in-flight
        # requests would strand them (the service's flush/verify would
        # KeyError mid-pipeline)
        while len(self._sessions) >= self.max_sessions:
            victim = next(
                (k for k, s in self._sessions.items() if s.inflight == 0),
                None,
            )
            if victim is None:
                raise RuntimeError(
                    f"SessionCache is full ({self.max_sessions} sessions) "
                    "and every session has in-flight requests; drain the "
                    "service before adding dictionaries, or raise "
                    "max_sessions"
                )
            del self._sessions[victim]
            self.evictions += 1
        op = EEJoinOperator(dictionary, cfg)
        cp = cost_params or CostParams(num_devices=1)
        calibrated = False
        if plan is None:
            if sample_docs is not None:
                if calibrate:
                    from repro.core.calibrate import calibrate as _calib

                    cp = _calib(op, np.asarray(sample_docs), cp,
                                scheme=default_scheme)
                    calibrated = True
                stats = op.gather_statistics(
                    np.asarray(sample_docs), total_docs=len(sample_docs)
                )
                plan = op.choose_plan(stats, cp)
            else:
                plan = pure_plan(default_scheme)
        prepared = op.prepare(plan, cp)
        sess = DictionarySession(
            key=key,
            dictionary=dictionary,
            config=cfg,
            operator=op,
            plan=plan,
            prepared=prepared,
            calibrated=calibrated,
            cost_params=cp,
            epochs={0: _upd.initial_epoch(dictionary, plan, prepared)},
        )
        self._sessions[key] = sess
        return sess

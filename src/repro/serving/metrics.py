"""Serving metrics: queue depth, batch occupancy, latency percentiles,
lane throughput — plus the two-stage pipeline schedule model the benches
use to account latency under overlap.

Everything here is host-side bookkeeping (plain floats/ints, numpy for
percentiles): recording a sample never touches a device or a jit cache,
so metrics cannot perturb the pipeline they observe.
"""
from __future__ import annotations

import contextlib
import dataclasses

import numpy as np

PERCENTILES = (50, 95, 99)


def stage_trace(name: str):
    """Profiler annotation for one pipeline stage (context manager).

    ``jax.profiler.TraceAnnotation`` when the installed jax provides it
    (the span then shows up in captured profiler traces around the
    probe/verify stage bodies); a ``nullcontext`` otherwise — the
    virtual-clock / ``time.perf_counter`` timings recorded alongside
    remain the source the replan loop actually consumes, so replanning
    never depends on profiler availability.
    """
    try:
        from jax.profiler import TraceAnnotation

        return TraceAnnotation(name)
    except Exception:  # pragma: no cover - depends on jax build
        return contextlib.nullcontext()


def percentiles(xs, ps=PERCENTILES) -> dict[str, float]:
    """{'p50': ..., 'p95': ..., 'p99': ...} (NaN on empty input)."""
    if len(xs) == 0:
        return {f"p{p}": float("nan") for p in ps}
    arr = np.asarray(xs, dtype=np.float64)
    vals = np.percentile(arr, ps)
    return {f"p{p}": float(v) for p, v in zip(ps, vals)}


def pipeline_schedule(
    ready_s,
    probe_s,
    verify_s,
    overlap: bool,
    buffer_depth: int = 2,
):
    """Completion times of batches through the two-stage pipeline.

    ``ready_s[i]`` is when batch i is flushed (available to probe);
    ``probe_s``/``verify_s`` its measured stage service times. With
    ``overlap`` the stages run on disjoint pools connected by a
    ``buffer_depth``-slot handoff queue: probe i starts once probe i-1
    finished AND verify has started draining batch i-buffer_depth (the
    double-buffer backpressure), verify i once probe i finished AND
    verify i-1 finished. Without overlap one worker runs both stages
    back-to-back. Returns (probe_done, verify_done) float arrays —
    request latency is ``verify_done[batch] - arrival``.
    """
    n = len(ready_s)
    assert len(probe_s) == n and len(verify_s) == n
    probe_done = np.zeros(n)
    verify_done = np.zeros(n)
    verify_start = np.zeros(n)
    for i in range(n):
        if overlap:
            start_p = max(ready_s[i], probe_done[i - 1] if i else 0.0)
            if i >= buffer_depth:
                # handoff queue full until verify pulls batch i - depth
                start_p = max(start_p, verify_start[i - buffer_depth])
            probe_done[i] = start_p + probe_s[i]
            verify_start[i] = max(probe_done[i],
                                  verify_done[i - 1] if i else 0.0)
            verify_done[i] = verify_start[i] + verify_s[i]
        else:
            start = max(ready_s[i], verify_done[i - 1] if i else 0.0)
            probe_done[i] = start + probe_s[i]
            verify_start[i] = probe_done[i]
            verify_done[i] = verify_start[i] + verify_s[i]
    return probe_done, verify_done


@dataclasses.dataclass
class ServingMetrics:
    """Mutable counters + samples for one service run."""

    submitted: int = 0
    rejected: int = 0
    rejected_quota: int = 0  # of which: per-session admission quota
    rejected_by_session: dict = dataclasses.field(default_factory=dict)
    completed: int = 0
    batches: int = 0
    # steady-state lane sizing: how each probed side sized its emit
    # lanes (fixed | count_pass | hint | refit) — 'hint' is the
    # amortised steady state, 'count_pass' the cold/stale fallback,
    # 'refit' the undersized-hint recovery (one extra emit pass)
    lane_sizing: dict = dataclasses.field(default_factory=dict)
    lanes: int = 0  # [1, NC] probe->verify handoffs (one per batch per side)
    # streamed probe path (single-launch DMA megakernel): launches taken,
    # in-kernel tiles consumed, DMA waits issued (one per tile chunk),
    # and checkpoint writes/hits when a probed side persists lanes —
    # mirrors sharded.stream_probe_tiles' stream_stats keys, so the
    # streamed path is observable like lane sizing already is.
    streamed_launches: int = 0
    tiles_streamed: int = 0
    dma_waits: int = 0
    checkpoint_writes: int = 0
    checkpoint_hits: int = 0
    docs: int = 0
    overflow_windows: int = 0  # candidate-buffer overflow, summed over batches
    depth_samples: list = dataclasses.field(default_factory=list)
    occupancy_samples: list = dataclasses.field(default_factory=list)
    batch_records: list = dataclasses.field(default_factory=list)  # per-batch rows
    latencies_s: list = dataclasses.field(default_factory=list)
    probe_s: list = dataclasses.field(default_factory=list)
    verify_s: list = dataclasses.field(default_factory=list)
    # continuous calibration: replanner triggers (events carry trigger
    # reason, drift values, old→new plan and predicted gain; swaps are
    # the subset that actually installed a new plan epoch)
    replans: int = 0
    replan_swaps: int = 0
    replan_events: list = dataclasses.field(default_factory=list)
    # multi-host fabric (fabric.cluster): per-replica transport and
    # routing counters — lane bytes on the wire, frames retried,
    # replication lag in epochs, routed/shed — folded in by
    # ClusterCoordinator.poll_stats and surfaced in the serve_cluster
    # report
    replicas: dict = dataclasses.field(default_factory=dict)
    first_arrival_s: float = float("nan")
    last_done_s: float = float("nan")

    def record_submit(self, accepted: bool, depth: int, now: float,
                      quota: bool = False,
                      session_key: str | None = None) -> None:
        self.submitted += 1
        if accepted:
            if np.isnan(self.first_arrival_s):
                self.first_arrival_s = now
        else:
            self.rejected += 1
            if quota:
                self.rejected_quota += 1
                if session_key is not None:
                    self.rejected_by_session[session_key] = (
                        self.rejected_by_session.get(session_key, 0) + 1
                    )
        self.depth_samples.append(depth)

    def record_sizing(self, sizing: str) -> None:
        """One probed side sized its lanes via ``sizing`` (see field doc)."""
        self.lane_sizing[sizing] = self.lane_sizing.get(sizing, 0) + 1

    def record_stream(self, stream_stats: dict,
                      observed=None) -> None:
        """Fold one probe call's ``stream_stats`` dict into the counters.

        The dict is the mutable accumulator the streaming drivers fill
        (``sharded.stream_probe_tiles`` / ``LaneCheckpointStore``);
        empty when the per-tile launch loop ran instead — recording it
        is then a no-op, so the counters directly read "how much of the
        probe traffic took the streamed path". Partial dicts are fine
        (every key defaults to 0). ``observed`` — a per-session
        ``serving.replan.ObservedStats`` — receives the same dict when
        the continuous-calibration loop is on.
        """
        self.streamed_launches += stream_stats.get("streamed_launches", 0)
        self.tiles_streamed += stream_stats.get("tiles_streamed", 0)
        self.dma_waits += stream_stats.get("dma_waits", 0)
        self.checkpoint_writes += stream_stats.get("checkpoint_writes", 0)
        self.checkpoint_hits += stream_stats.get("checkpoint_hits", 0)
        if observed is not None:
            observed.record_stream(stream_stats)

    def record_batch(self, batch_id: int, rows: int, occupancy: float,
                     n_lanes: int, flush_s: float, probe_s: float,
                     verify_s: float, overflow: int = 0,
                     epoch: int = 0, windows: int = 0,
                     survivors: int = 0, observed=None) -> None:
        self.batches += 1
        self.docs += rows
        self.lanes += n_lanes
        self.occupancy_samples.append(occupancy)
        self.probe_s.append(probe_s)
        self.verify_s.append(verify_s)
        self.overflow_windows += overflow
        self.batch_records.append({
            "batch_id": batch_id,
            "rows": rows,
            "occupancy": occupancy,
            "flush_s": flush_s,
            "probe_s": probe_s,
            "verify_s": verify_s,
            "epoch": epoch,
            "windows": windows,
            "survivors": survivors,
        })
        if observed is not None:
            # the telemetry feedback path: the session's ObservedStats
            # (serving.replan) folds the same sample into its EWMAs
            observed.record_batch(
                rows=rows, windows=windows, survivors=survivors,
                probe_s=probe_s, verify_s=verify_s,
            )

    def record_replan(self, event: dict) -> None:
        """One replanner trigger (swapped or not) — see serving.replan."""
        self.replans += 1
        if event.get("swapped"):
            self.replan_swaps += 1
        self.replan_events.append(dict(event))

    def record_replica(self, name: str, row: dict) -> None:
        """Latest per-replica fabric counters (overwrites the old row —
        these are cumulative gauges, not samples)."""
        self.replicas[name] = dict(row)

    def record_done(self, latency_s: float, done_s: float) -> None:
        self.completed += 1
        self.latencies_s.append(latency_s)
        if np.isnan(self.last_done_s) or done_s > self.last_done_s:
            self.last_done_s = done_s

    @property
    def elapsed_s(self) -> float:
        return self.last_done_s - self.first_arrival_s

    def summary(self) -> dict:
        """Flat dict: the serving bench row / entrypoint report."""
        lat = percentiles(self.latencies_s)
        elapsed = self.elapsed_s
        rate = (lambda x: x / elapsed) if elapsed and elapsed > 0 else (
            lambda x: float("nan"))
        return {
            "submitted": self.submitted,
            "rejected": self.rejected,
            "completed": self.completed,
            "batches": self.batches,
            "queue_depth_mean": float(np.mean(self.depth_samples))
            if self.depth_samples else 0.0,
            "queue_depth_max": int(max(self.depth_samples))
            if self.depth_samples else 0,
            "occupancy_mean": float(np.mean(self.occupancy_samples))
            if self.occupancy_samples else 0.0,
            "latency_p50_s": lat["p50"],
            "latency_p95_s": lat["p95"],
            "latency_p99_s": lat["p99"],
            "probe_s_mean": float(np.mean(self.probe_s))
            if self.probe_s else 0.0,
            "verify_s_mean": float(np.mean(self.verify_s))
            if self.verify_s else 0.0,
            "docs_per_s": rate(self.docs),
            "lanes_per_s": rate(self.lanes),
            "overflow_windows": self.overflow_windows,
            "rejected_quota": self.rejected_quota,
            "lane_sizing": dict(self.lane_sizing),
            "streamed_launches": self.streamed_launches,
            "tiles_streamed": self.tiles_streamed,
            "dma_waits": self.dma_waits,
            "checkpoint_writes": self.checkpoint_writes,
            "checkpoint_hits": self.checkpoint_hits,
            "replans": self.replans,
            "replan_swaps": self.replan_swaps,
            "replan_events": [dict(e) for e in self.replan_events],
            "replicas": {
                name: {
                    "alive": row.get("alive", True),
                    "routed": row.get("routed", 0),
                    "shed": row.get("shed", 0),
                    "failures": row.get("failures", 0),
                    "frames_retried": row.get("frames_retried", 0),
                    "lane_bytes": row.get("lane_bytes", 0),
                    "bytes_sent": row.get("bytes_sent", 0),
                    "bytes_received": row.get("bytes_received", 0),
                    "replication_lag_epochs": row.get(
                        "replication_lag_epochs", 0
                    ),
                }
                for name, row in self.replicas.items()
            },
        }


def session_cache_summary(cache) -> dict:
    """SessionCache + per-session serving state, one flat report dict.

    The cache-level counters (hit/miss/eviction) say whether dictionary
    churn is thrashing the LRU; the per-session rows surface what the
    live-updates subsystem is doing — current epoch, open delta
    segments, live/tombstoned entity counts and the maintenance actions
    taken — next to the serving counters. Consumed by the
    ``serve_extract --check`` report and the updates bench.
    """
    sessions = {}
    for key, s in cache._sessions.items():
        state = s.current_state
        sessions[key] = {
            "epoch": s.epoch,
            "requests": s.requests,
            "batches": s.batches,
            "inflight": s.inflight,
            "open_segments": state.open_segments,
            "live_entities": state.version.num_live,
            "tombstoned": int(state.version.tombstones.sum()),
            "pinned_epochs": sorted(s.epochs),
            "calibrated": s.calibrated,
            "maintenance": [m["action"] for m in s.maintenance_log],
        }
    return {
        "sessions": len(cache),
        "max_sessions": cache.max_sessions,
        "hits": cache.hits,
        "misses": cache.misses,
        "evictions": cache.evictions,
        "per_session": sessions,
    }

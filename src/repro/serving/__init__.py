"""Async extraction serving: the EE-Join operator as an online service.

The paper frames the operator as an offline MapReduce job; this package
turns the batch pipeline into a request/response system serving a
stream of documents against cached dictionaries:

    requests ─► queue.AdmissionQueue       (bounded, backpressure)
                  └─► batcher.MicroBatcher (length buckets, deadline flush)
                        └─► service.ExtractionService
                              probe pool  ─ shard_lane ─►  verify pool
                              (fused_probe,  [G, NC] lane   (sig probe +
                               compaction     handoff,       jaccard_verify)
                               epilogue)      depth-2 queue)
                  session.SessionCache: dictionary fingerprint ->
                      prepared filter / sig tables / plan (shared
                      across requests, multiple dictionaries live)
                  metrics.ServingMetrics: depth, occupancy, p50/p95/p99

Results are bit-identical to a one-shot ``eejoin.execute`` over the
same documents (asserted in ``tests/test_serving.py`` and re-checked by
``benchmarks/bench_serving.py``).
"""
from repro.serving.batcher import BatcherConfig, MicroBatch, MicroBatcher
from repro.serving.metrics import (
    ServingMetrics,
    pipeline_schedule,
    session_cache_summary,
)
from repro.serving.pools import DevicePools, make_pools
from repro.serving.queue import AdmissionQueue, ExtractRequest
from repro.serving.replan import (
    ObservedStats,
    ReplanConfig,
    Replanner,
    realized_gain,
)
from repro.serving.service import ExtractionService, one_shot_reference
from repro.serving.session import (
    DictionarySession,
    SessionCache,
    dictionary_fingerprint,
    pure_plan,
)

__all__ = [
    "AdmissionQueue",
    "BatcherConfig",
    "DevicePools",
    "DictionarySession",
    "ExtractRequest",
    "ExtractionService",
    "MicroBatch",
    "MicroBatcher",
    "ObservedStats",
    "ReplanConfig",
    "Replanner",
    "ServingMetrics",
    "SessionCache",
    "dictionary_fingerprint",
    "make_pools",
    "one_shot_reference",
    "pipeline_schedule",
    "pure_plan",
    "realized_gain",
    "session_cache_summary",
]

"""Two-stage async extraction service: probe pool -> lanes -> verify pool.

The pipeline splits one request's work exactly where the sharded driver
splits a shard's: the *probe* stage streams a micro-batch's ``[D, T]``
tile through ``fused_probe`` (with the in-kernel compaction epilogue)
and reduces it to one ``[1, NC]`` candidate lane per plan side
(``extraction.sharded.shard_lane`` — the wire unit, ``(1 + NC) * 4``
bytes, plus a ``[1, NC, 2]`` variant-key payload when the fused
variant scheme is on); the *verify* stage re-expands the lane into
compacted candidate windows (attaching the shipped variant keys, so
set hashes are never recomputed) and runs the plan's probe+verify join
(``EEJoinOperator.side_matches``). The stages run on **disjoint device
pools** connected by a **double-buffered handoff queue** (depth 2):
while the verify pool joins batch i, the probe pool is already
streaming batch i+1 — the serving-time analogue of the driver's
per-tile DMA overlap.

Results are bit-identical to a one-shot ``eejoin.execute`` over the
same documents (windows never span documents and lane merging is exact,
so micro-batching cannot change any match) — asserted per scheme and
geometry in ``tests/test_serving.py``.

Threading model: the caller's thread owns ingest (``submit`` → admission
queue → ``tick`` → micro-batcher); a probe worker and a verify worker
own the two stages (one combined worker when ``overlap=False``). All
queues are bounded, so a slow verify pool backpressures probe, a slow
probe backpressures the flush queue, and the admission queue sheds or
blocks producers — nothing in the pipeline can grow without limit.
"""
from __future__ import annotations

import queue as _pyqueue
import threading
import time
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.extraction import engine
from repro.extraction.results import (
    Matches,
    gather_from_tiles,
    merge_matches,
    select_from_tiles,
)
from repro.extraction.sharded import shard_lane_steady
from repro.serving.batcher import BatcherConfig, MicroBatch, MicroBatcher
from repro.serving.metrics import ServingMetrics, stage_trace
from repro.serving.pools import DevicePools, make_pools
from repro.serving.queue import AdmissionQueue, ExtractRequest
from repro.serving.session import SessionCache

#: probe->verify handoff queue depth: 2 slots double-buffer the pools
#: (verify drains batch i while probe fills batch i+1).
HANDOFF_DEPTH = 2


def one_shot_reference(session, docs, epoch: int | None = None
                       ) -> set[tuple[int, int, int, int]]:
    """The serving parity target: one-shot ``execute`` over ``docs``.

    Pads the variable-length documents into a single [N, T] array (row
    i = doc_id i) and runs the session's current (or a pinned past)
    epoch in one batch call — for an epoch-0 session this is exactly
    the frozen-dictionary ``execute`` of the prepared plan; with live
    deltas applied it probes base + open segments and masks tombstones,
    identically to the served pipeline. ``ExtractionService.
    results_set()`` over the same documents must equal this set — the
    single reference implementation used by tests, the serving bench,
    and ``serve_extract --check``.
    """
    from repro.core.dictionary import PAD
    from repro.updates.builders import execute_epoch

    docs = [np.asarray(d, dtype=np.int32).reshape(-1) for d in docs]
    T = max((len(d) for d in docs), default=1)
    padded = np.full((len(docs), max(T, 1)), PAD, dtype=np.int32)
    for i, d in enumerate(docs):
        padded[i, : len(d)] = d
    state = session.state_for(epoch if epoch is not None else session.epoch)
    return execute_epoch(
        state, jnp.asarray(padded), session.config
    ).to_set()


class _Handoff:
    """One probed batch in flight between the pools."""

    __slots__ = ("batch", "lanes", "probe_s", "windows", "survivors")

    def __init__(self, batch: MicroBatch, lanes: list, probe_s: float,
                 windows: int = 0, survivors: int = 0):
        self.batch = batch
        # per plan side: (count [1] i32, cand [1, NC] i32,
        #                 keys [1, NC, 2] u32 | None  — fused variant)
        self.lanes = lanes
        self.probe_s = probe_s
        # telemetry for the continuous-calibration loop: enumerated
        # candidate windows and true filter survivors of this batch
        self.windows = windows
        self.survivors = survivors


class ExtractionService:
    """Online micro-batched EE-Join extraction over device pools."""

    def __init__(
        self,
        sessions: SessionCache,
        pools: DevicePools | None = None,
        batcher_config: BatcherConfig | None = None,
        queue_capacity: int = 256,
        overlap: bool = True,
        clock: Callable[[], float] = time.monotonic,
        session_quota: int | None = None,
        replan=None,
        remote_verify=None,
    ):
        self.sessions = sessions
        self.pools = pools or make_pools()
        self.batcher = MicroBatcher(batcher_config or BatcherConfig())
        self.queue = AdmissionQueue(queue_capacity, session_quota=session_quota)
        self.overlap = overlap
        self.clock = clock
        self.metrics = ServingMetrics()
        self.completed: list[ExtractRequest] = []
        # fail at config time, not deep inside the kernel: the largest
        # possible batch must keep flat lane indices inside int32
        engine.check_flat_index_space(
            self.batcher.config.max_batch_docs,
            self.batcher.config.buckets[-1],
            32,
        )
        self._flush_q: _pyqueue.Queue = _pyqueue.Queue()
        self._handoff_q: _pyqueue.Queue = _pyqueue.Queue(maxsize=HANDOFF_DEPTH)
        self._workers: list[threading.Thread] = []
        self._started = False
        self._lock = threading.Lock()  # completed-list + metrics writes
        self._ingest_lock = threading.Lock()  # batcher is not thread-safe
        self.errors: list[tuple[int, Exception]] = []  # (batch_id, exc)
        # continuous calibration: ``replan`` is a serving.replan.
        # ReplanConfig (None = off). With replan.thread the loop polls
        # in the background; otherwise it steps inline from tick() —
        # deterministic on a virtual clock.
        self.replanner = None
        if replan is not None:
            from repro.serving.replan import Replanner

            self.replanner = Replanner(
                sessions, replan, metrics=self.metrics, clock=clock
            )
        # multi-host fabric: when set, the verify pool sits behind a
        # transport channel — probed lanes are framed and shipped to an
        # epoch-agreed replica instead of joined on the local verify
        # device (``fabric.cluster.ClusterCoordinator.verify_lanes`` or
        # anything duck-typed like it). The probe stage, batching,
        # epoch pinning and result fan-out are unchanged.
        self.remote_verify = remote_verify

    # ------------------------------------------------------------- lifecycle
    def start(self) -> None:
        """Spawn the stage workers (idempotent)."""
        if self._started:
            return
        self._started = True
        if self.overlap:
            targets = [self._probe_worker, self._verify_worker]
        else:
            targets = [self._serial_worker]
        for fn in targets:
            t = threading.Thread(target=fn, daemon=True, name=fn.__name__)
            t.start()
            self._workers.append(t)
        if self.replanner is not None:
            self.replanner.start()  # no-op unless ReplanConfig.thread

    def stop(self) -> None:
        """Drain and terminate the workers.

        The shutdown sentinel and joins run even when ``drain``
        re-raises a batch failure — workers never outlive the service.
        """
        if not self._started:
            return
        try:
            self.drain()
        finally:
            if self.replanner is not None:
                self.replanner.stop()
            self._flush_q.put(None)
            for t in self._workers:
                t.join()
            self._workers.clear()
            self._started = False

    def __enter__(self) -> "ExtractionService":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # ---------------------------------------------------------------- ingest
    def submit(self, doc_id: int, tokens, session_key: str,
               now: float | None = None,
               block: bool = False) -> ExtractRequest | None:
        """Admit one document; None when shed by admission control.

        ``block=True`` switches to backpressure mode: instead of being
        rejected, the producer itself drains the admission queue into
        the batcher (``tick``) until space frees — the calling thread
        owns ingest, so backpressure is "do the flushing work", not
        "wait for someone else to". Raises ValueError on caller errors —
        unknown session, document longer than the largest length
        bucket — rather than shedding them silently.
        """
        try:
            sess = self.sessions.get(session_key)
        except KeyError:
            raise ValueError(
                f"submit: unknown session {session_key!r}; create it first "
                "with SessionCache.get_or_create(dictionary, ...)"
            ) from None
        self.batcher.config.bucket_for(len(np.asarray(tokens).reshape(-1)))
        now = self.clock() if now is None else now

        def _quota_limited() -> bool:
            return (self.queue.session_quota is not None
                    and sess.inflight >= self.queue.session_quota)

        req = self.queue.try_submit(doc_id, tokens, session_key, now,
                                    session_inflight=sess.inflight)
        while req is None and block:
            # one tick always empties the admission queue into the bins,
            # so a single pass frees *queue* space. The tick reads a
            # fresh clock: deadline flushes must keep firing while the
            # producer spins here, or a quota-limited session whose
            # last batch sits in an unflushed bin would never complete
            # and the loop would never exit.
            self.tick()
            if _quota_limited():
                # quota frees only when the stage workers complete this
                # session's batches — yield so they can, and do not
                # re-attempt (each attempt would count another
                # rejection in the queue's admission stats)
                time.sleep(1e-4)
                continue
            req = self.queue.try_submit(doc_id, tokens, session_key, now,
                                        session_inflight=sess.inflight)
        if req is not None:
            with self._lock:  # vs the -= in _complete/_fail_batch
                sess.inflight += 1  # pins the session against LRU eviction
        quota = req is None and _quota_limited()
        self.metrics.record_submit(
            req is not None, self.queue.depth(), now,
            quota=quota, session_key=session_key if quota else None,
        )
        return req

    def tick(self, now: float | None = None) -> int:
        """Move admitted requests into bins and flush due batches.

        Returns the number of batches handed to the probe stage. The
        ingest loop (or the load generator) calls this between submits;
        ``drain`` calls it with a forced flush.
        """
        now = self.clock() if now is None else now
        with self._ingest_lock:  # concurrent producers may tick via submit
            for req in self.queue.take():
                self.batcher.add(req)
            n = self._dispatch(self.batcher.poll(now))
        if self.replanner is not None and not self.replanner.config.thread:
            # inline replan mode: the loop steps on the ingest thread
            # (outside the ingest lock — a swap only takes session locks)
            self.replanner.step(now)
        return n

    def drain(self) -> None:
        """Force-flush everything pending and wait until it completes.

        Re-raises the first stage-worker failure (with its batch id)
        after the queues empty: a failed batch marks its requests
        ``error`` and never hangs the join (see ``_fail_batch``).
        """
        if not self._started:
            self.start()
        now = self.clock()
        with self._ingest_lock:
            for req in self.queue.take():
                self.batcher.add(req)
            self._dispatch(self.batcher.flush_all(now))
        self._flush_q.join()
        if self.overlap:
            self._handoff_q.join()
        if self.errors:
            errs, self.errors = self.errors, []  # report once, then reset
            batch_id, exc = errs[0]
            raise RuntimeError(
                f"{len(errs)} batch(es) failed in the serving pipeline; "
                f"first failure on batch {batch_id} (per-request details "
                "on ExtractRequest.error)"
            ) from exc

    def _dispatch(self, batches: list[MicroBatch]) -> int:
        for b in batches:
            sess = self.sessions.get(b.session_key)
            sess.requests += b.rows
            sess.batches += 1
            if self.replanner is not None:
                self.replanner.attach(sess)  # lazy ObservedStats
            # epoch stamp + pin: the batch executes on the dictionary
            # epoch current at dispatch, even if apply_delta hot-swaps
            # the session before its probe/verify runs (the swap
            # protocol: in-flight work finishes on its admitted epoch).
            # Read-and-pin is one atomic step under the session lock —
            # a separate read could see an epoch that a concurrent
            # apply_delta garbage-collects before the pin lands.
            b.epoch = sess.pin_current()
            self._flush_q.put(b)
        return len(batches)

    # ---------------------------------------------------------- stage bodies
    def _probe_batch(self, batch: MicroBatch) -> _Handoff:
        """Probe stage: stream the batch's tiles, reduce to [1, NC] lanes.

        Versioned: each plan side probes with its epoch's (possibly
        delta-unioned) Bloom filter. Adaptive lane widths are sized
        steady-state — the previous batch's measured per-tile survivor
        max for the same (side, bucket, epoch) skips the count pass
        (``shard_lane_steady``; sizing decisions land in metrics).
        """
        sess = self.sessions.get(batch.session_key)
        state = sess.state_for(batch.epoch)
        dev = self.pools.probe_device(batch.batch_id)
        t0 = time.perf_counter()
        with stage_trace("eejoin.serve.probe"):
            docs = jax.device_put(jnp.asarray(batch.docs), dev)
            lanes = []
            for i, eside in enumerate(state.sides):
                stream_stats: dict = {}
                lane, count, keys, tile_max, sizing = shard_lane_steady(
                    docs, 0, state.max_len, eside.flt, eside.params,
                    batch.spec.tile_docs,
                    width_hint=sess.lane_hint(i, batch.bucket, batch.epoch),
                    stream_stats=stream_stats,
                )
                sess.update_lane_hint(i, batch.bucket, batch.epoch, tile_max)
                with self._lock:
                    self.metrics.record_sizing(sizing)
                    self.metrics.record_stream(stream_stats,
                                               observed=sess.observed)
                lanes.append((count, lane, keys))
            jax.block_until_ready(lanes)
        probe_s = time.perf_counter() - t0
        windows = survivors = 0
        if sess.observed is not None:
            # telemetry for the replan loop: enumerated-window count
            # (drift denominator) + true survivor totals per side, and
            # the raw rows into the recent-document ring the next
            # replan gathers statistics from. Host-side numpy; skipped
            # entirely when replanning is off.
            from repro.serving.replan import batch_windows

            windows = batch_windows(batch.docs, state.max_len)
            survivors = sum(
                int(np.asarray(count).sum()) for count, _, _ in lanes
            )
            sess.observed.observe_docs(batch.docs)
        return _Handoff(batch, lanes, probe_s, windows, survivors)

    def _verify_batch(self, handoff: _Handoff) -> None:
        """Verify stage: lanes -> candidate windows -> probe+verify join.

        Versioned: every side verifies against its epoch's base
        structures plus each open delta segment (same candidate dict,
        matches merged), then tombstoned entities are masked before
        results fan back out.
        """
        from repro.extraction.results import filter_matches
        from repro.updates.builders import epoch_side_matches

        batch = handoff.batch
        sess = self.sessions.get(batch.session_key)
        if self.remote_verify is not None:
            self._verify_batch_remote(handoff, sess)
            return
        state = sess.state_for(batch.epoch)
        dev = self.pools.verify_device(batch.batch_id)
        t0 = time.perf_counter()
        with stage_trace("eejoin.serve.verify"):
            # the handoff traffic: per side one (1 + NC)-int lane, plus
            # the raw [D, T] tokens the verify pool gathers windows from
            docs = jax.device_put(jnp.asarray(batch.docs), dev)
            out: Matches | None = None
            overflow = 0
            for eside, (count, lane, keys) in zip(state.sides, handoff.lanes):
                count, lane = jax.device_put((count, lane), dev)
                NC = eside.params.max_candidates
                sel, ok, n = select_from_tiles(count, lane, NC)
                cands = engine.candidates_from_flat(
                    docs, sel, ok, n, state.max_len, NC
                )
                if keys is not None:
                    # fused variant keys rode the handoff lane: the verify
                    # pool attaches them instead of recomputing set hashes
                    keys = jax.device_put(keys, dev)
                    cands = engine.attach_variant_keys(
                        cands, gather_from_tiles(count, keys, NC)
                    )
                overflow += int(cands["overflow"])
                m = epoch_side_matches(
                    cands, eside, sess.config.result_capacity
                )
                out = m if out is None else merge_matches(
                    out, m, sess.config.result_capacity
                )
            if state.has_tombstones:
                out = filter_matches(
                    out, state.live, sess.config.result_capacity
                )
            jax.block_until_ready(out)
        verify_s = time.perf_counter() - t0
        self._complete(batch, out, handoff.probe_s, verify_s, overflow,
                       windows=handoff.windows, survivors=handoff.survivors)

    def _verify_batch_remote(self, handoff: _Handoff, sess) -> None:
        """Remote verify: frame the lanes, ship, complete on the reply.

        The lanes come back to host memory once (they are a few KB —
        the whole point of the compaction), get framed by
        ``sharded.lanes_to_wire`` and routed to a replica that has
        acked the batch's epoch; the replica runs the identical verify
        sequence over its replicated epoch state
        (``fabric.replica.verify_lanes_on_state``), so the reply is
        bit-identical to the local join.
        """
        batch = handoff.batch
        t0 = time.perf_counter()
        with stage_trace("eejoin.serve.verify_remote"):
            lanes = [
                (np.asarray(count), np.asarray(lane),
                 None if keys is None else np.asarray(keys))
                for count, lane, keys in handoff.lanes
            ]
            matches, overflow = self.remote_verify.verify_lanes(
                batch.session_key, batch.epoch, batch.docs, lanes
            )
        verify_s = time.perf_counter() - t0
        self._complete(batch, matches, handoff.probe_s, verify_s, overflow,
                       windows=handoff.windows, survivors=handoff.survivors)

    def _complete(self, batch: MicroBatch, matches: Matches,
                  probe_s: float, verify_s: float, overflow: int,
                  windows: int = 0, survivors: int = 0) -> None:
        """Fan the batch's Matches back out to its requests (host side)."""
        now = self.clock()
        doc = np.asarray(matches.doc)
        pos = np.asarray(matches.pos)
        length = np.asarray(matches.length)
        ent = np.asarray(matches.entity)
        score = np.asarray(matches.score)
        keep = doc >= 0
        by_row: dict[int, list] = {}
        for d, p, l, e, s in zip(doc[keep], pos[keep], length[keep],
                                 ent[keep], score[keep]):
            by_row.setdefault(int(d), []).append(
                (int(p), int(l), int(e), float(s))
            )
        with self._lock:
            sess = self.sessions.get(batch.session_key)
            sess.inflight -= batch.rows
            n_lanes = len(sess.state_for(batch.epoch).sides)
            sess.unpin_epoch(batch.epoch)
            for row, req in enumerate(batch.reqs):
                req.matches = [
                    (req.doc_id, p, l, e, s)
                    for (p, l, e, s) in sorted(by_row.get(row, []))
                ]
                req.done = True
                req.done_s = now
                req.batch_id = batch.batch_id
                self.completed.append(req)
                self.metrics.record_done(req.done_s - req.arrival_s, now)
            self.metrics.record_batch(
                batch_id=batch.batch_id,
                rows=batch.rows,
                occupancy=batch.occupancy,
                n_lanes=n_lanes,
                flush_s=batch.flush_s,
                probe_s=probe_s,
                verify_s=verify_s,
                overflow=overflow,
                epoch=batch.epoch,
                windows=windows,
                survivors=survivors,
                observed=sess.observed,
            )

    def _fail_batch(self, batch: MicroBatch, exc: Exception) -> None:
        """A stage raised: surface the error, never wedge the pipeline.

        The batch's requests complete with ``error`` set (empty
        matches), the exception is parked on ``self.errors`` for
        ``drain`` to re-raise, and the worker loop stays alive so the
        queue joins always terminate.
        """
        now = self.clock()
        with self._lock:
            self.errors.append((batch.batch_id, exc))
            try:
                sess = self.sessions.get(batch.session_key)
                sess.inflight -= batch.rows
                if batch.epoch >= 0:
                    sess.unpin_epoch(batch.epoch)
            except KeyError:
                pass  # session evicted while busy is itself the failure
            for req in batch.reqs:
                req.error = f"{type(exc).__name__}: {exc}"
                req.done = True
                req.done_s = now
                req.batch_id = batch.batch_id
                self.completed.append(req)

    # -------------------------------------------------------------- workers
    def _probe_worker(self) -> None:
        while True:
            batch = self._flush_q.get()
            if batch is None:
                self._flush_q.task_done()
                self._handoff_q.put(None)
                return
            try:
                handoff = self._probe_batch(batch)
            except Exception as exc:  # noqa: BLE001 — parked for drain()
                self._fail_batch(batch, exc)
            else:
                self._handoff_q.put(handoff)
            finally:
                self._flush_q.task_done()

    def _verify_worker(self) -> None:
        while True:
            handoff = self._handoff_q.get()
            if handoff is None:
                self._handoff_q.task_done()
                return
            try:
                self._verify_batch(handoff)
            except Exception as exc:  # noqa: BLE001 — parked for drain()
                self._fail_batch(handoff.batch, exc)
            finally:
                self._handoff_q.task_done()

    def _serial_worker(self) -> None:
        """overlap=False: one worker runs both stages back-to-back."""
        while True:
            batch = self._flush_q.get()
            if batch is None:
                self._flush_q.task_done()
                return
            try:
                self._verify_batch(self._probe_batch(batch))
            except Exception as exc:  # noqa: BLE001 — parked for drain()
                self._fail_batch(batch, exc)
            finally:
                self._flush_q.task_done()

    # ------------------------------------------------------------ inspection
    def results_set(self) -> set[tuple[int, int, int, int]]:
        """Dedup'd (doc_id, pos, length, entity) across completed requests
        — directly comparable with ``Matches.to_set()`` of a one-shot
        batch run over the same documents."""
        with self._lock:
            return {
                (d, p, l, e)
                for req in self.completed
                for (d, p, l, e, _s) in req.matches
            }

"""Continuous calibration + online replanning (the observe→refit→replan
→swap loop).

The paper's §5 plan search runs once, at session build time, under
one-shot calibrated cost constants. Serving already *observes* reality —
per-batch stage wall times, true survivor counts, document lengths —
so this module closes the loop and turns the search into a continuously
running optimizer:

1. **observe** — every completed batch folds into a per-session
   ``ObservedStats``: boundary-invariant EWMAs of seconds-per-window
   (probe), seconds-per-survivor (verify), survivor density and doc
   length, plus a ring buffer of the most recent documents (the
   post-drift statistics sample);
2. **refit** — ``core.calibrate.refit_params`` rescales the cost
   constants so the model's per-unit times match the measurements
   (pure, idempotent — see its docstring);
3. **replan** — when any drift measure exceeds its configured bound,
   the §5 search (``core.search``) re-runs over statistics gathered
   from the recent-document ring under the refitted constants, floored
   by the stale plan's cost under the *same* refitted constants (so
   the chosen plan's modeled cost never exceeds the stale plan's);
4. **swap** — ``DictionarySession.apply_replan`` installs the new plan
   as a fresh epoch through the PR-5 pin/unpin machinery: in-flight
   batches keep executing on their admitted epoch, and the search is
   restricted to plan options that share the current plan's similarity
   semantics (the Jaccard-variant scheme computes ``SIM_VARIANT_EXACT``;
   every other scheme ``SIM_EXTRA`` — see ``core.semantics``), so a
   replan can never change any batch's results — only its cost.

The replanner runs either as a background thread (``ReplanConfig.
thread=True``, polling every ``interval_s``) or inline from
``ExtractionService.tick`` (``thread=False`` — deterministic on the
virtual clock, which is how the drift-injection tests and benches run
it). Every trigger — swapped or not — lands as an event in
``ServingMetrics.replan_events``.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from collections import deque

import numpy as np

from repro.core.calibrate import measured_lane_density, refit_params
from repro.core.cost_model import ALGO_SSJOIN, CostParams
from repro.core.dictionary import PAD
from repro.core.plan import Plan
from repro.core.search import plan_cost, search_plan
from repro.core.semantics import SIM_EXTRA, SIM_VARIANT_EXACT

_TINY = 1e-30


def batch_windows(docs, max_len: int) -> int:
    """Enumerated candidate windows in a PAD-padded [D, T] doc batch.

    Matches the valid-window definition of ``core.stats.gather_stats``
    (windows live entirely inside each row's leading non-PAD prefix):
    a row with ``n`` valid tokens contributes ``sum_l max(0, n-l+1)``
    windows for ``l`` in ``1..max_len``. This is the denominator of the
    measured survivor density — host-side numpy only.
    """
    arr = np.asarray(docs)
    lens = (arr != PAD).cumprod(axis=-1).sum(axis=-1).astype(np.int64)
    total = 0
    for length in range(1, max_len + 1):
        total += int(np.maximum(0, lens - length + 1).sum())
    return total


class Ewma:
    """Exponentially decayed mean, decayed per *unit of weight*.

    ``update(x, n)`` treats the sample as ``n`` units (windows,
    survivors, rows) each at rate ``x``:

        value' = x + (value - x) * alpha ** n

    which makes the estimator invariant to batch-boundary placement —
    a segment of ``n`` units at rate ``x`` folds identically whether it
    arrives as one batch or split into ``n1 + n2`` (property-tested in
    ``tests/test_replan_prop.py``). ``halflife`` is in weight units.
    """

    __slots__ = ("alpha", "value")

    def __init__(self, halflife: float):
        self.alpha = 0.5 ** (1.0 / max(halflife, 1e-9))
        self.value = float("nan")

    def update(self, x: float, weight: float) -> None:
        if weight <= 0 or not math.isfinite(x):
            return
        if math.isnan(self.value):
            self.value = float(x)
        else:
            self.value = float(x + (self.value - x) * self.alpha ** weight)


class ObservedStats:
    """Per-session serving telemetry: EWMAs + a recent-document ring.

    Fed by ``ServingMetrics.record_batch`` / ``record_stream`` (the
    service passes the session's instance along) and read by the
    replanner and by ``core.calibrate.refit_params`` (which only needs
    the three ``density`` / ``probe_s_per_window`` /
    ``verify_s_per_survivor`` properties — all NaN until the first
    batch lands, so a cold refit is the identity).
    """

    def __init__(self, capacity: int = 128,
                 halflife_windows: float = 20000.0):
        if capacity <= 0:
            raise ValueError(f"ObservedStats capacity={capacity} must be > 0")
        self.capacity = capacity
        self.batches = 0
        self.windows = 0
        self.survivors = 0
        self.rows = 0
        self._density = Ewma(halflife_windows)
        self._probe = Ewma(halflife_windows)
        self._verify = Ewma(halflife_windows)
        # doc length moves at per-row cadence, not per-window
        self._doc_len = Ewma(max(halflife_windows / 256.0, 1.0))
        self._docs: deque = deque(maxlen=capacity)
        self.stream_counters: dict[str, int] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------- feeding
    def record_batch(self, *, rows: int, windows: int, survivors: int,
                     probe_s: float, verify_s: float) -> None:
        with self._lock:
            self.batches += 1
            self.rows += int(rows)
            self.windows += int(windows)
            self.survivors += int(survivors)
            if windows > 0:
                self._density.update(survivors / windows, windows)
                self._probe.update(probe_s / windows, windows)
            if survivors > 0:
                self._verify.update(verify_s / survivors, survivors)

    def record_stream(self, stream_stats: dict) -> None:
        with self._lock:
            for k, v in (stream_stats or {}).items():
                self.stream_counters[k] = self.stream_counters.get(k, 0) + v

    def observe_docs(self, docs) -> None:
        """Ring-buffer the batch's rows (trimmed of PAD tails)."""
        arr = np.asarray(docs)
        lens = (arr != PAD).cumprod(axis=-1).sum(axis=-1)
        with self._lock:
            for row, n in zip(arr, lens):
                n = int(n)
                if n > 0:
                    self._docs.append(np.array(row[:n], dtype=np.int32))
                    self._doc_len.update(float(n), 1.0)

    # ------------------------------------------------------------- reading
    @property
    def density(self) -> float:
        return self._density.value

    @property
    def probe_s_per_window(self) -> float:
        return self._probe.value

    @property
    def verify_s_per_survivor(self) -> float:
        return self._verify.value

    @property
    def doc_len_mean(self) -> float:
        return self._doc_len.value

    def sample_docs(self) -> np.ndarray | None:
        """The recent-document ring as one PAD-padded [S, T] array."""
        with self._lock:
            docs = list(self._docs)
        if not docs:
            return None
        T = max(len(d) for d in docs)
        out = np.full((len(docs), T), PAD, dtype=np.int32)
        for i, d in enumerate(docs):
            out[i, : len(d)] = d
        return out


@dataclasses.dataclass(frozen=True)
class PlanBaseline:
    """The calibration snapshot drift is measured against.

    ``density`` comes from the plan's calibrated ``CostParams.
    lane_density`` when the session has one (the density the plan was
    *chosen* under); everything else freezes from the first
    ``min_batches`` of observed traffic.
    """

    density: float
    doc_len: float
    probe_s_per_window: float
    verify_s_per_survivor: float
    at_batches: int


@dataclasses.dataclass(frozen=True)
class ReplanConfig:
    """Knobs of the continuous-calibration loop (all drift bounds are
    *relative*: ``|observed - baseline| / baseline``)."""

    density_drift: float = 0.3  # survivor-rate bound
    doc_len_drift: float = 0.3  # document-length bound
    time_drift: float = 1.0  # per-stage wall-time bound (noisy; coarse)
    min_batches: int = 4  # warm-up before the baseline freezes
    cooldown_batches: int = 4  # quiet period after any trigger
    min_gain: float = 0.02  # modeled relative gain required to swap
    interval_s: float = 0.05  # background-thread poll period
    thread: bool = True  # False: step inline from service.tick (tests)
    refit: bool = True  # False: re-search under the stale constants
    halflife_windows: float = 20000.0  # EWMA halflife (weight units)

    def __post_init__(self):
        for name in ("density_drift", "doc_len_drift", "time_drift",
                     "min_gain", "interval_s", "halflife_windows"):
            if getattr(self, name) < 0:
                raise ValueError(f"ReplanConfig.{name} must be >= 0")
        if self.min_batches < 1:
            raise ValueError("ReplanConfig.min_batches must be >= 1")


def effective_plan_key(plan: Plan, num_entities: int) -> tuple:
    """Identity of what a plan *executes* (degenerate splits collapse:
    at split 0 the head side does not exist, at split E the tail)."""
    parts = []
    if plan.split > 0:
        parts.append(("head", min(plan.split, num_entities),
                      plan.head.algo, plan.head.scheme))
    if plan.split < num_entities:
        parts.append(("tail", plan.tail.algo, plan.tail.scheme))
    return tuple(parts)


def scheme_semantics(scheme: str) -> str:
    """The similarity predicate a scheme's matches satisfy exactly.

    The Jaccard-variant machinery matches ``SIM_VARIANT_EXACT`` (an
    under-approximation of ``SIM_EXTRA`` — see ``core.semantics``);
    every other scheme verifies ``SIM_EXTRA``. Plans from different
    classes produce different match sets, so a replan must never cross
    the boundary.
    """
    return SIM_VARIANT_EXACT if scheme == "variant" else SIM_EXTRA


def plan_semantics(plan: Plan, num_entities: int) -> frozenset[str]:
    """Semantics classes of a plan's active sides (degenerate splits
    collapse, as in ``effective_plan_key``)."""
    out = set()
    if plan.split > 0:
        out.add(scheme_semantics(plan.head.scheme))
    if plan.split < num_entities:
        out.add(scheme_semantics(plan.tail.scheme))
    return frozenset(out)


def plan_schemes(plan: Plan, num_entities: int) -> tuple[str, ...]:
    """Schemes of the plan's active ssjoin sides (refit's sig weights)."""
    out = []
    if plan.split > 0 and plan.head.algo == ALGO_SSJOIN:
        out.append(plan.head.scheme)
    if plan.split < num_entities and plan.tail.algo == ALGO_SSJOIN:
        out.append(plan.tail.scheme)
    return tuple(out) or ("prefix",)


def replan_choice(stats, params, stale_plan: Plan, objective: str,
                  options) -> tuple[Plan, float]:
    """§5 search under ``params``, floored by the stale plan.

    Returns ``(choice, stale_cost)``. The choice is the searched plan
    or — when the stale plan still models at least as cheap — the stale
    plan re-costed under the fresh params; either way
    ``choice.predicted_cost <= stale_cost`` by construction.
    """
    searched = search_plan(stats, params, objective, options=options)
    stale_cost = plan_cost(stats, params, stale_plan, objective)
    if stale_cost <= searched.predicted_cost:
        keep = dataclasses.replace(
            stale_plan,
            split=min(max(stale_plan.split, 0), stats.num_entities),
            objective=objective,
            predicted_cost=stale_cost,
        )
        return keep, stale_cost
    return searched, stale_cost


def realized_gain(metrics, event: dict) -> float:
    """Measured per-doc stage-time gain across one swap event.

    Splits ``metrics.batch_records`` at the event's epoch (batches
    pinned to earlier epochs ran the old plan) and compares mean
    ``(probe_s + verify_s) / rows``; positive means the swap made
    serving cheaper. NaN until both sides have batches.
    """
    epoch = event.get("epoch")
    if epoch is None or not event.get("swapped"):
        return float("nan")
    pre = [r for r in metrics.batch_records if r["epoch"] < epoch]
    post = [r for r in metrics.batch_records if r["epoch"] >= epoch]
    if not pre or not post:
        return float("nan")

    def per_doc(rs):
        return (sum(r["probe_s"] + r["verify_s"] for r in rs)
                / max(sum(r["rows"] for r in rs), 1))

    before, after = per_doc(pre), per_doc(post)
    if before <= 0:
        return float("nan")
    return (before - after) / before


class Replanner:
    """Drives the observe→refit→replan→swap loop over a session cache.

    One instance per ``ExtractionService``; sessions opt in lazily via
    ``attach`` (the service attaches at dispatch). ``step`` is the
    whole loop body and is safe to call from any thread — session swaps
    serialize on the session's own apply lock, and the per-session
    bookkeeping (baseline, cooldown) is only touched here.
    """

    def __init__(self, sessions, config: ReplanConfig,
                 metrics=None, clock=time.monotonic):
        self.sessions = sessions
        self.config = config
        self.metrics = metrics
        self.clock = clock
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        self._step_lock = threading.Lock()

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        if not self.config.thread or self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run, daemon=True, name="replanner"
        )
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None

    def _run(self) -> None:
        while not self._stop.wait(self.config.interval_s):
            try:
                self.step()
            except Exception:  # noqa: BLE001 — telemetry must not kill serving
                pass

    # ----------------------------------------------------------------- loop
    def attach(self, sess) -> ObservedStats:
        """Ensure the session has an ObservedStats (idempotent)."""
        if sess.observed is None:
            sess.observed = ObservedStats(
                capacity=sess.config.observe_capacity,
                halflife_windows=self.config.halflife_windows,
            )
        return sess.observed

    def step(self, now: float | None = None) -> list[dict]:
        """One loop pass over every attached session; returns the events
        fired (also recorded on ``self.metrics``)."""
        with self._step_lock:
            events = []
            for sess in list(self.sessions._sessions.values()):
                ev = self._step_session(sess, now)
                if ev is not None:
                    events.append(ev)
            return events

    def _baseline(self, sess, obs: ObservedStats) -> PlanBaseline:
        cp = sess.cost_params
        density = obs.density
        if cp is not None and cp.lane_density > 0:
            density = cp.lane_density  # the plan's calibration snapshot
        return PlanBaseline(
            density=density,
            doc_len=obs.doc_len_mean,
            probe_s_per_window=obs.probe_s_per_window,
            verify_s_per_survivor=obs.verify_s_per_survivor,
            at_batches=obs.batches,
        )

    def _drifts(self, base: PlanBaseline, obs: ObservedStats) -> dict:
        def rel(now_v, base_v):
            if not (math.isfinite(now_v) and math.isfinite(base_v)):
                return 0.0
            return abs(now_v - base_v) / max(abs(base_v), _TINY)

        return {
            "lane_density": rel(obs.density, base.density),
            "doc_len": rel(obs.doc_len_mean, base.doc_len),
            "probe_time": rel(obs.probe_s_per_window,
                              base.probe_s_per_window),
            "verify_time": rel(obs.verify_s_per_survivor,
                               base.verify_s_per_survivor),
        }

    def _trigger(self, drifts: dict) -> str | None:
        cfg = self.config
        bounds = {
            "lane_density": cfg.density_drift,
            "doc_len": cfg.doc_len_drift,
            "probe_time": cfg.time_drift,
            "verify_time": cfg.time_drift,
        }
        for name, value in drifts.items():
            if math.isfinite(bounds[name]) and value > bounds[name]:
                return name
        return None

    def _step_session(self, sess, now: float | None) -> dict | None:
        obs = sess.observed
        if obs is None or sess.replan_pinned:
            return None
        if obs.batches < self.config.min_batches:
            return None
        if sess.replan_baseline is None:
            # warm-up done: freeze the snapshot drift is measured against
            sess.replan_baseline = self._baseline(sess, obs)
            return None
        if obs.batches - sess.replan_baseline.at_batches \
                < self.config.cooldown_batches:
            return None
        drifts = self._drifts(sess.replan_baseline, obs)
        reason = self._trigger(drifts)
        if reason is None:
            return None
        event = self._replan(sess, obs, reason, drifts, now)
        # reset the baseline after *any* trigger (swapped or not): the
        # new plan/constants absorbed this drift, and re-triggering on
        # the same shift every step would thrash
        sess.replan_baseline = self._baseline(sess, obs)
        if self.metrics is not None:
            self.metrics.record_replan(event)
        return event

    def _replan(self, sess, obs: ObservedStats, reason: str,
                drifts: dict, now: float | None) -> dict:
        docs = obs.sample_docs()
        E = sess.operator.dictionary.num_entities
        old_params = sess.cost_params or CostParams(num_devices=1)
        params = old_params
        if self.config.refit:
            params = refit_params(
                old_params, obs, schemes=plan_schemes(sess.plan, E)
            )
        event = {
            "t": self.clock() if now is None else now,
            "session": sess.key,
            "reason": reason,
            "drift": {k: float(v) for k, v in drifts.items()},
            "at_batches": obs.batches,
            "old_plan": sess.plan.describe(E),
            "swapped": False,
        }
        if docs is None:
            event["skipped"] = "no observed documents"
            return event
        # result-preservation guard: only consider options in the current
        # plan's semantics class (a swap must change cost, never matches)
        sem = plan_semantics(sess.plan, E)
        if len(sem) != 1:
            event["skipped"] = "mixed-semantics plan"
            sess.cost_params = params
            return event
        options = tuple(o for o in sess.config.options
                        if scheme_semantics(o[1]) in sem)
        if not options:
            event["skipped"] = "no semantics-preserving options"
            sess.cost_params = params
            return event
        stats = sess.operator.gather_statistics(docs, total_docs=len(docs))
        choice, stale_cost = replan_choice(
            stats, params, sess.plan, sess.config.objective, options,
        )
        params = dataclasses.replace(
            params, lane_density=measured_lane_density(stats)
        )
        gain = (stale_cost - choice.predicted_cost) / max(stale_cost, _TINY)
        event.update(
            new_plan=choice.describe(E),
            stale_cost_s=float(stale_cost),
            new_cost_s=float(choice.predicted_cost),
            predicted_gain=float(gain),
        )
        changed = (effective_plan_key(choice, E)
                   != effective_plan_key(sess.plan, E))
        if changed and gain >= self.config.min_gain:
            state = sess.apply_replan(choice, params, reason=reason)
            event["swapped"] = True
            event["epoch"] = state.epoch
        else:
            # no swap, but keep the refitted constants + fresh density:
            # the model stays honest even while the plan stands
            sess.cost_params = params
        return event

"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM
(scalar memory, block-diagonal recurrence).

Baseline implementation is the *exact sequential recurrence* via
``lax.scan`` over time with log-space stabilisation (the paper's m-state)
— numerically faithful and O(1)-state for long_500k decode. The
chunkwise-parallel mLSTM form is a §Perf hillclimb (see EXPERIMENTS.md):
it rewrites the same math as intra-chunk attention + inter-chunk state
so the MXU sees large matmuls instead of a length-T scan.

State layouts (per block):
  mLSTM: C [B, H, D, D], n [B, H, D], m [B, H]
  sLSTM: c, n, h, m each [B, H, D]
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.models.sharding import ShardingRules


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------


def init_mlstm(rng, cfg, rules: ShardingRules):
    d = cfg.d_model
    H = cfg.num_heads
    dh = (2 * d) // H  # cell operates on the 2x up-projected branch
    ks = jax.random.split(rng, 8)
    p, s = {}, {}
    p["w_up"], s["w_up"] = dense_init(ks[0], (d, 2 * d), ("embed", "mlp"), rules)
    p["w_gate"], s["w_gate"] = dense_init(ks[1], (d, 2 * d), ("embed", "mlp"), rules)
    p["w_q"], s["w_q"] = dense_init(ks[2], (2 * d, H, dh), ("mlp", "heads", None), rules)
    p["w_k"], s["w_k"] = dense_init(ks[3], (2 * d, H, dh), ("mlp", "heads", None), rules)
    p["w_v"], s["w_v"] = dense_init(ks[4], (2 * d, H, dh), ("mlp", "heads", None), rules)
    p["w_if"], s["w_if"] = dense_init(ks[5], (2 * d, H, 2), ("mlp", "heads", None), rules)
    p["b_if"] = jnp.zeros((H, 2), jnp.float32)
    s["b_if"] = jax.sharding.PartitionSpec(None, None)
    p["w_down"], s["w_down"] = dense_init(ks[6], (2 * d, d), ("mlp", "embed"), rules)
    p["conv"], s["conv"] = dense_init(ks[7], (4, 2 * d), (None, "mlp"), rules)
    return p, s


def mlstm_state(cfg, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    H = cfg.num_heads
    dh = (2 * d) // H
    return {
        "C": jnp.zeros((batch, H, dh, dh), dtype),
        "n": jnp.zeros((batch, H, dh), dtype),
        "m": jnp.full((batch, H), -1e30, dtype),
        "conv": jnp.zeros((batch, 3, 2 * d), dtype),  # causal conv tail
    }


def _mlstm_cell(state, q, k, v, logi, logf):
    """One step of the stabilised mLSTM recurrence.

    q,k,v [B,H,D]; logi,logf [B,H]. Returns (state', h [B,H,D]).
    """
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(logf + m, logi)
    a = jnp.exp(logf + m - m_new)[..., None]  # decay
    b = jnp.exp(logi - m_new)[..., None]  # input scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C_new = C * a[..., None] + b[..., None] * jnp.einsum("bhd,bhe->bhde", vf, kf)
    n_new = n * a + b * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhde,bhe->bhd", C_new, qf)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, qf))
    den = jnp.maximum(den, jnp.exp(-m_new))[..., None]
    h = num / den
    return {"C": C_new, "n": n_new, "m": m_new, "conv": state["conv"]}, h


def _mlstm_qkv(cfg, p, up):
    """Projections from the (conv'd) up branch: up [B,S,2d]."""
    q = jnp.einsum("bsd,dhe->bshe", up, p["w_q"])
    k = jnp.einsum("bsd,dhe->bshe", up, p["w_k"]) / np.sqrt(q.shape[-1])
    v = jnp.einsum("bsd,dhe->bshe", up, p["w_v"])
    gates = jnp.einsum("bsd,dhg->bshg", up, p["w_if"]).astype(jnp.float32) + p["b_if"]
    logi = gates[..., 0]
    logf = jax.nn.log_sigmoid(gates[..., 1])
    return q, k, v, logi, logf


def _causal_conv4(x, w, tail=None):
    """Depthwise causal conv (kernel 4) over [B,S,C]; optional carry tail
    [B,3,C] for decode. Returns (y, new_tail)."""
    B, S, C = x.shape
    if tail is None:
        tail = jnp.zeros((B, 3, C), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)  # [B, S+3, C]
    y = (
        xp[:, 0:S] * w[0]
        + xp[:, 1 : S + 1] * w[1]
        + xp[:, 2 : S + 2] * w[2]
        + xp[:, 3 : S + 3] * w[3]
    )
    return y, xp[:, -3:]


def apply_mlstm(cfg, p, x, state=None):
    """x [B,S,d] -> (y [B,S,d], state').

    Dispatches to the chunkwise-parallel form (§Perf hillclimb: intra-
    chunk attention + inter-chunk state, MXU-sized matmuls instead of a
    length-S scan) unless ``cfg.mlstm_chunk == 0`` (exact sequential
    baseline). Both compute the same stabilised recurrence; equivalence
    is tested to 1e-4 in tests/test_xlstm_chunkwise.py.
    """
    B, S, d = x.shape
    H = cfg.num_heads
    if state is None:
        state = mlstm_state(cfg, B)
    up = x @ p["w_up"]
    gate = x @ p["w_gate"]
    conv_in, new_tail = _causal_conv4(up, p["conv"], state["conv"])
    conv_in = jax.nn.silu(conv_in)
    q, k, v, logi, logf = _mlstm_qkv(cfg, p, conv_in)
    # v comes from the un-conv'd branch (paper fig. 10)
    v = jnp.einsum("bsd,dhe->bshe", up, p["w_v"])

    chunk = getattr(cfg, "mlstm_chunk", 0)
    if chunk and S > 1:
        final, h = _mlstm_chunkwise(
            state, q, k, v, logi, logf, min(chunk, S)
        )
        final = dict(final, conv=new_tail)
        h = h.reshape(B, S, 2 * d).astype(x.dtype)
    else:
        cell_state = {k_: state[k_] for k_ in ("C", "n", "m")} | {
            "conv": new_tail
        }

        def step(carry, xs):
            qt, kt, vt, it, ft = xs
            new, hh = _mlstm_cell(carry, qt, kt, vt, it, ft)
            return new, hh

        xs = (
            q.swapaxes(0, 1),
            k.swapaxes(0, 1),
            v.swapaxes(0, 1),
            logi.swapaxes(0, 1),
            logf.swapaxes(0, 1),
        )
        final, hs = jax.lax.scan(step, cell_state, xs)
        h = hs.swapaxes(0, 1).reshape(B, S, 2 * d).astype(x.dtype)
    y = (h * jax.nn.silu(gate)) @ p["w_down"]
    return y, final


def _mlstm_chunkwise(state, q, k, v, logi, logf, L: int):
    """Chunkwise-parallel stabilised mLSTM (exact rewrite).

    Derivation: with ``B_t = Σ_{s≤t} logf_s`` (within-chunk cumsum),
    ``a_s = logi_s − B_s`` and ``M_t = max(m_prev, cummax_{s≤t} a_s)``,
    the sequential recurrence unrolls to

        m_t = B_t + M_t
        C_t = e^{m_prev−M_t} C_prev + Σ_{s≤t} e^{a_s−M_t} v_s k_sᵀ
        h_t = [e^{m_prev−M_t} C_prev q_t + ((q Kᵀ ⊙ D) V)_t] / den_t

    where ``D_ts = e^{a_s−M_t}`` masked to s≤t (all exponents ≤ 0 —
    stable), and den_t = max(|analogous n·q|, e^{−m_t}). The scan runs
    over S/L chunks; each step is L×L / L×dh matmuls.
    """
    B, S, H, dh = q.shape
    while S % L:
        L -= 1
    n_chunks = S // L

    def re(x):  # [B,S,...] -> [n, B, L, ...]
        return x.reshape((B, n_chunks, L) + x.shape[2:]).swapaxes(0, 1)

    tri = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(carry, xs):
        C0, n0, m0 = carry  # [B,H,dh,dh], [B,H,dh], [B,H]
        qc, kc, vc, lic, lfc = xs  # [B,L,H,dh] / [B,L,H]
        qf = qc.astype(jnp.float32)
        kf = kc.astype(jnp.float32)
        vf = vc.astype(jnp.float32)
        Bc = jnp.cumsum(lfc, axis=1)  # [B,L,H]
        a = lic - Bc
        Mt = jnp.maximum(jax.lax.cummax(a, axis=1), m0[:, None])  # [B,L,H]

        scores = jnp.einsum("blhd,bshd->bhls", qf, kf)  # [B,H,L,L]
        D = jnp.exp(a.transpose(0, 2, 1)[:, :, None, :]
                    - Mt.transpose(0, 2, 1)[:, :, :, None])  # [B,H,L(t),L(s)]
        D = jnp.where(tri[None, None], D, 0.0)
        sd = scores * D
        num_intra = jnp.einsum("bhls,bshd->blhd", sd, vf)
        den_intra = jnp.einsum("bhls->bhl", sd).transpose(0, 2, 1)  # [B,L,H]

        inter_w = jnp.exp(m0[:, None] - Mt)  # [B,L,H]
        num = (inter_w[..., None]
               * jnp.einsum("bhde,blhe->blhd", C0, qf)) + num_intra
        den_vec = inter_w * jnp.einsum("bhd,blhd->blh", n0, qf) + den_intra
        m_t = Bc + Mt
        den = jnp.maximum(jnp.abs(den_vec), jnp.exp(-m_t))[..., None]
        h = num / den  # [B,L,H,dh]

        ML = Mt[:, -1]  # [B,H]
        w_s = jnp.exp(a - ML[:, None])  # [B,L,H]
        decay = jnp.exp(m0 - ML)
        C_L = decay[..., None, None] * C0 + jnp.einsum(
            "blhd,blhe->bhde", vf * w_s[..., None], kf
        )
        n_L = decay[..., None] * n0 + jnp.einsum("blhd,blh->bhd", kf, w_s)
        m_L = Bc[:, -1] + ML
        return (C_L, n_L, m_L), h

    carry0 = (state["C"], state["n"], state["m"])
    (C_f, n_f, m_f), hs = jax.lax.scan(
        chunk_step, carry0, (re(q), re(k), re(v), re(logi), re(logf))
    )
    h = hs.swapaxes(0, 1).reshape(B, S, H, dh)
    return {"C": C_f, "n": n_f, "m": m_f}, h


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------


def init_slstm(rng, cfg, rules: ShardingRules):
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    f_up = max(1, int(round(d * 4 / 3 / 64)) * 64)
    ks = jax.random.split(rng, 5)
    p, s = {}, {}
    # 4 gates (i, f, z, o) from input and block-diagonal recurrence
    p["w_x"], s["w_x"] = dense_init(ks[0], (d, H, 4 * dh), ("embed", "heads", None), rules)
    p["r"], s["r"] = dense_init(ks[1], (H, dh, 4 * dh), ("heads", None, None), rules)
    p["b"] = jnp.zeros((H, 4 * dh), jnp.float32)
    s["b"] = jax.sharding.PartitionSpec(None, None)
    p["w_up1"], s["w_up1"] = dense_init(ks[2], (d, f_up), ("embed", "mlp"), rules)
    p["w_up2"], s["w_up2"] = dense_init(ks[3], (d, f_up), ("embed", "mlp"), rules)
    p["w_down"], s["w_down"] = dense_init(ks[4], (f_up, d), ("mlp", "embed"), rules)
    return p, s


def slstm_state(cfg, batch: int, dtype=jnp.float32):
    d, H = cfg.d_model, cfg.num_heads
    dh = d // H
    return {
        "c": jnp.zeros((batch, H, dh), dtype),
        "n": jnp.zeros((batch, H, dh), dtype),
        "h": jnp.zeros((batch, H, dh), dtype),
        "m": jnp.full((batch, H, dh), -1e30, dtype),
    }


def _slstm_cell(cfg, p, state, gx):
    """gx [B,H,4dh] pre-activations from the input projection."""
    c, n, h, m = state["c"], state["n"], state["h"], state["m"]
    dh = c.shape[-1]
    rec = jnp.einsum("bhd,hdg->bhg", h, p["r"].astype(jnp.float32))
    g = gx.astype(jnp.float32) + rec + p["b"]
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)
    m_new = jnp.maximum(jax.nn.log_sigmoid(gf) + m, gi)
    i = jnp.exp(gi - m_new)
    f = jnp.exp(jax.nn.log_sigmoid(gf) + m - m_new)
    z = jnp.tanh(gz)
    o = jax.nn.sigmoid(go)
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}, h_new


def apply_slstm(cfg, p, x, state=None):
    B, S, d = x.shape
    H = cfg.num_heads
    dh = d // H
    if state is None:
        state = slstm_state(cfg, B)
    gx = jnp.einsum("bsd,dhg->bshg", x, p["w_x"])  # [B,S,H,4dh]

    def step(carry, g):
        return _slstm_cell(cfg, p, carry, g)

    # sLSTM's h->gates dependency is inherently sequential (no chunkwise
    # rewrite exists); unrolling amortises loop overhead + weight reads
    # across iterations (§Perf hillclimb, xlstm cell).
    unroll = min(getattr(cfg, "slstm_unroll", 1), S)
    while S % unroll:
        unroll -= 1
    final, hs = jax.lax.scan(step, state, gx.swapaxes(0, 1), unroll=unroll)
    h = hs.swapaxes(0, 1).reshape(B, S, d).astype(x.dtype)
    # post-block gated FFN (factor 4/3, paper App. figure)
    y = (jax.nn.gelu(h @ p["w_up1"]) * (h @ p["w_up2"])) @ p["w_down"]
    return y, final

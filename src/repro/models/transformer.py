"""Generic decoder LM assembly covering all assigned architecture families.

A model is a stack of *layer groups*; each group instantiates the
config's ``block_pattern`` (e.g. ``("rglru","rglru","local_attn")`` for
RecurrentGemma, ``("attn",)*4 + ("cross_attn_gated",)`` for
Llama-3.2-Vision, ``("attn_nomlp","cross_attn")`` per Whisper decoder
layer). Groups are identical, so the stack runs under ``lax.scan`` with
per-group stacked params (compact HLO at 40+ layers) and optional remat.

Block kinds:
  attn              pre-norm GQA self-attention (+MLP/MoE sub-block)
  local_attn        sliding-window self-attention (+MLP)
  attn_nomlp        self-attention only (whisper decoder first half)
  cross_attn        cross-attention to a context (+MLP)
  cross_attn_gated  tanh-gated cross-attention (VLM; zero-init gate)
  rglru             Griffin recurrent block (+MLP)
  mlstm / slstm     xLSTM blocks (bring their own FFN)
"""
from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import flash as F
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import xlstm as X
from repro.models.sharding import ShardingRules

HAS_MLP = {"attn", "local_attn", "cross_attn", "cross_attn_gated", "rglru"}
ATTN_KINDS = {"attn", "local_attn", "attn_nomlp"}
CROSS_KINDS = {"cross_attn", "cross_attn_gated"}


def num_groups(cfg: ModelConfig) -> int:
    return cfg.num_groups


# --------------------------------------------------------------------------
# per-block init / apply
# --------------------------------------------------------------------------


def init_block(rng, cfg: ModelConfig, kind: str, rules: ShardingRules):
    ks = jax.random.split(rng, 4)
    p, s = {}, {}
    p["norm1"], s["norm1"] = L.init_norm(cfg.norm, cfg.d_model, rules)
    if kind in ATTN_KINDS:
        p["attn"], s["attn"] = L.init_attention(ks[0], cfg, rules)
    elif kind in CROSS_KINDS:
        p["attn"], s["attn"] = L.init_attention(ks[0], cfg, rules, cross=True)
        if kind == "cross_attn_gated":
            p["gate"] = jnp.zeros((), jnp.float32)
            s["gate"] = P()
    elif kind == "rglru":
        p["rnn"], s["rnn"] = R.init_rglru(ks[0], cfg, rules)
    elif kind == "mlstm":
        p["cell"], s["cell"] = X.init_mlstm(ks[0], cfg, rules)
    elif kind == "slstm":
        p["cell"], s["cell"] = X.init_slstm(ks[0], cfg, rules)
    else:
        raise ValueError(kind)
    if kind in HAS_MLP:
        p["norm2"], s["norm2"] = L.init_norm(cfg.norm, cfg.d_model, rules)
        if cfg.num_experts > 0:
            p["mlp"], s["mlp"] = M.init_moe(ks[1], cfg, rules)
        else:
            p["mlp"], s["mlp"] = L.init_mlp(ks[1], cfg, rules)
    return p, s


def _tp_pad_heads(q, k, v, rules: ShardingRules):
    """GQA expansion + head padding + sharding constraints for the flash
    path (§Perf iterations #5/#6/#8).

    * K/V are expanded to the full query-head count so every attention
      tensor is shardable by heads (per-device K/V bytes unchanged —
      each shard holds H/tp expanded heads instead of KH replicated).
    * Head counts that do not divide the TP axis (whisper 20, starcoder2
      36 on a 16-way axis) are padded to the next multiple; padded q
      heads are zeros, outputs sliced off by the caller — exact, with
      zero gradients to the pads (tests/test_flash.py).
    * Explicit constraints pin the expanded/padded K/V to the heads
      sharding — without them SPMD kept the expanded K/V replicated and
      every q-chunk re-read the full buffer (the prefill regression in
      the §Perf log, iteration #8).
    Returns (q', k', v', H_original).
    """
    H = q.shape[2]
    tp = rules.mesh.shape.get("model", 1)
    KH = k.shape[2]
    if KH != H:  # expand GQA groups at the call site
        k = jnp.repeat(k, H // KH, axis=2)
        v = jnp.repeat(v, H // KH, axis=2)
    if tp > 1 and H % tp:
        Hp = -(-H // tp) * tp
        pad = Hp - H
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.concatenate([k, k[:, :, :pad]], axis=2)
        v = jnp.concatenate([v, v[:, :, :pad]], axis=2)
    q = L.constraint(q, ("batch", "seq", "heads", None), rules)
    k = L.constraint(k, ("batch", "seq", "heads", None), rules)
    v = L.constraint(v, ("batch", "seq", "heads", None), rules)
    return q, k, v, H


def _mlp_sub(cfg, p, x, rules, aux):
    h = L.apply_norm(cfg.norm, p["norm2"], x)
    if cfg.num_experts > 0:
        y, a = M.apply_moe(cfg, p["mlp"], h, rules)
        for k, v in a.items():
            aux[k] = aux.get(k, 0.0) + v
    else:
        y = L.apply_mlp(cfg, p["mlp"], h)
    return x + y


def apply_block_seq(
    cfg, kind: str, p, x, rules, *, positions, context, causal, aux,
    state=None,
):
    """Full-sequence (train/prefill) application; returns (x, new_state)."""
    h = L.apply_norm(cfg.norm, p["norm1"], x)
    new_state = state
    if kind in ATTN_KINDS:
        B, S, d = x.shape
        hd = cfg.resolved_head_dim
        q = jnp.einsum("bsd,dhe->bshe", h, p["attn"]["wq"])
        k = jnp.einsum("bsd,dhe->bshe", h, p["attn"]["wk"])
        v = jnp.einsum("bsd,dhe->bshe", h, p["attn"]["wv"])
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        q = L.constraint(q, ("batch", "seq", "heads", None), rules)
        window = cfg.local_window if kind == "local_attn" else 0
        if cfg.use_flash:
            q, k, v, H_orig = _tp_pad_heads(q, k, v, rules)
            attn_fn = F.flash_attention
        else:
            H_orig, attn_fn = q.shape[2], L.chunked_attention
        o = attn_fn(
            q, k, v,
            q_positions=positions[0] if positions.ndim > 1 else positions,
            kv_positions=positions[0] if positions.ndim > 1 else positions,
            causal=causal,
            window=window,
            q_chunk=cfg.attn_chunk,
            kv_chunk=cfg.attn_chunk,
        )[:, :, :H_orig]
        x = x + jnp.einsum("bshe,hed->bsd", o, p["attn"]["wo"])
    elif kind in CROSS_KINDS:
        q = jnp.einsum("bsd,dhe->bshe", h, p["attn"]["wq"])
        k = jnp.einsum("bcd,dhe->bche", context, p["attn"]["wk"])
        v = jnp.einsum("bcd,dhe->bche", context, p["attn"]["wv"])
        Sc = context.shape[1]
        if cfg.use_flash:
            q, k, v, H_orig = _tp_pad_heads(q, k, v, rules)
            attn_fn = F.flash_attention
        else:
            H_orig, attn_fn = q.shape[2], L.chunked_attention
        o = attn_fn(
            q, k, v,
            q_positions=positions[0] if positions.ndim > 1 else positions,
            kv_positions=jnp.arange(Sc),
            causal=False,
            q_chunk=cfg.attn_chunk,
            kv_chunk=min(cfg.attn_chunk, Sc),
        )[:, :, :H_orig]
        o = jnp.einsum("bshe,hed->bsd", o, p["attn"]["wo"])
        if kind == "cross_attn_gated":
            o = jnp.tanh(p["gate"]).astype(o.dtype) * o
        x = x + o
    elif kind == "rglru":
        y, new_state = R.apply_rglru(cfg, p["rnn"], h, state)
        x = x + y
    elif kind == "mlstm":
        y, new_state = X.apply_mlstm(cfg, p["cell"], h, state)
        x = x + y
    elif kind == "slstm":
        y, new_state = X.apply_slstm(cfg, p["cell"], h, state)
        x = x + y
    else:
        raise ValueError(kind)
    if kind in HAS_MLP:
        x = _mlp_sub(cfg, p, x, rules, aux)
    x = L.constraint(x, ("batch", "seq", None), rules)
    return x, new_state


def apply_block_decode(cfg, kind: str, p, x, rules, *, pos, cache, aux):
    """Single-token application; x [B,1,d]; returns (x, new_cache)."""
    h = L.apply_norm(cfg.norm, p["norm1"], x)
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    if kind in ATTN_KINDS or kind in CROSS_KINDS:
        q = jnp.einsum("bsd,dhe->bshe", h, p["attn"]["wq"])[:, 0]  # [B,H,hd]
        if kind in ATTN_KINDS:
            k_new = jnp.einsum("bsd,dhe->bshe", h, p["attn"]["wk"])[:, 0]
            v_new = jnp.einsum("bsd,dhe->bshe", h, p["attn"]["wv"])[:, 0]
            posv = jnp.full((B,), pos, jnp.int32)
            q = L.apply_rope(q[:, None], posv[:, None], cfg.rope_theta)[:, 0]
            k_new = L.apply_rope(k_new[:, None], posv[:, None], cfg.rope_theta)[:, 0]
            window = cfg.local_window if kind == "local_attn" else 0
            kc, vc, kvpos = cache["k"], cache["v"], cache["kvpos"]
            NS, Sc = kc.shape[1], kc.shape[2]
            slot = pos % (NS * Sc) if window else pos  # ring for local attn
            s_idx, i_idx = slot // Sc, slot % Sc
            kc = jax.lax.dynamic_update_slice(
                kc, k_new[:, None, None], (0, s_idx, i_idx, 0, 0)
            )
            vc = jax.lax.dynamic_update_slice(
                vc, v_new[:, None, None], (0, s_idx, i_idx, 0, 0)
            )
            kvpos = jax.lax.dynamic_update_slice(
                kvpos, jnp.full((B, 1, 1), pos, jnp.int32), (0, s_idx, i_idx)
            )
            o = L.decode_attention(q, kc, vc, kvpos, jnp.full((B,), pos), window)
            cache = dict(cache, k=kc, v=vc, kvpos=kvpos)
        else:
            o = L.decode_attention(
                q, cache["ck"], cache["cv"], cache["ckpos"],
                jnp.full((B,), jnp.iinfo(jnp.int32).max // 2),
            )
            if kind == "cross_attn_gated":
                o = jnp.tanh(p["gate"]).astype(o.dtype) * o
        x = x + jnp.einsum("bhe,hed->bd", o, p["attn"]["wo"])[:, None]
    elif kind == "rglru":
        y, new = R.apply_rglru_step(cfg, p["rnn"], h, cache)
        x = x + y
        cache = new
    elif kind == "mlstm":
        up = h[:, 0] @ p["cell"]["w_up"]
        gate = h[:, 0] @ p["cell"]["w_gate"]
        conv_in, tail = X._causal_conv4(up[:, None], p["cell"]["conv"], cache["conv"])
        conv_in = jax.nn.silu(conv_in)
        q, k, v, logi, logf = X._mlstm_qkv(cfg, p["cell"], conv_in)
        v = jnp.einsum("bsd,dhe->bshe", up[:, None], p["cell"]["w_v"])
        new, hh = X._mlstm_cell(
            dict(cache, conv=tail), q[:, 0], k[:, 0], v[:, 0], logi[:, 0], logf[:, 0]
        )
        d = cfg.d_model
        hh = hh.reshape(B, 2 * d).astype(x.dtype)
        y = ((hh * jax.nn.silu(gate)) @ p["cell"]["w_down"])[:, None]
        x = x + y
        cache = new
    elif kind == "slstm":
        gx = jnp.einsum("bd,dhg->bhg", h[:, 0], p["cell"]["w_x"])
        new, hh = X._slstm_cell(cfg, p["cell"], cache, gx)
        d = cfg.d_model
        hh = hh.reshape(B, d).astype(x.dtype)
        y = (jax.nn.gelu(hh @ p["cell"]["w_up1"]) * (hh @ p["cell"]["w_up2"])) @ p["cell"]["w_down"]
        x = x + y[:, None]
        cache = new
    else:
        raise ValueError(kind)
    if kind in HAS_MLP:
        x = _mlp_sub(cfg, p, x, rules, aux)
    return x, cache


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------


def init_block_cache(
    cfg: ModelConfig, kind: str, batch: int, max_len: int, kv_splits: int, dtype
):
    hd = cfg.resolved_head_dim
    KH = cfg.num_kv_heads
    if kind in ATTN_KINDS:
        S = min(cfg.local_window, max_len) if kind == "local_attn" else max_len
        ns = kv_splits if (kind != "local_attn" and S % kv_splits == 0) else 1
        return {
            "k": jnp.zeros((batch, ns, S // ns, KH, hd), dtype),
            "v": jnp.zeros((batch, ns, S // ns, KH, hd), dtype),
            "kvpos": jnp.full((batch, ns, S // ns), -1, jnp.int32),
        }
    if kind in CROSS_KINDS:
        Sc = cfg.context_len
        return {
            "ck": jnp.zeros((batch, 1, Sc, KH, hd), dtype),
            "cv": jnp.zeros((batch, 1, Sc, KH, hd), dtype),
            "ckpos": jnp.zeros((batch, 1, Sc), jnp.int32),
        }
    if kind == "rglru":
        return R.rglru_state(cfg, batch)
    if kind == "mlstm":
        return X.mlstm_state(cfg, batch)
    if kind == "slstm":
        return X.slstm_state(cfg, batch)
    raise ValueError(kind)


def cache_specs(cfg: ModelConfig, kind: str, rules: ShardingRules, shapes):
    """PartitionSpecs for one block's cache pytree (G-stacked upstream)."""
    def spec_for(path, arr):
        if path in ("k", "v"):
            return rules.spec(("batch", "cache_seq", None, "kv_heads", None), arr.shape)
        if path in ("ck", "cv"):
            return rules.spec(("batch", None, None, "kv_heads", None), arr.shape)
        if path in ("kvpos", "ckpos"):
            return rules.spec(("batch", "cache_seq", None), arr.shape) if path == "kvpos" else rules.spec(("batch", None, None), arr.shape)
        if path == "C":
            return rules.spec(("batch", "heads", None, None), arr.shape)
        if path in ("n", "h", "c", "m"):
            dims = ("batch",) + tuple([None] * (arr.ndim - 1))
            return rules.spec(dims, arr.shape)
        if path == "conv":
            return rules.spec(("batch", None, None), arr.shape)
        return rules.spec(tuple([None] * arr.ndim), arr.shape)

    return {k: spec_for(k, v) for k, v in shapes.items()}

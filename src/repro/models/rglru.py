"""Griffin / RecurrentGemma RG-LRU recurrent block (arXiv:2402.19427).

The gated diagonal linear recurrence

    a_t = exp(-c softplus(Λ) ⊙ σ(W_a x_t))
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (σ(W_x x_t) ⊙ x_t)

is elementwise-affine in h, so training/prefill runs as a parallel
``associative_scan`` over time — O(S log S) depth, no O(S²) memory —
which is what makes the hybrid arch long_500k-capable. Decode is the
plain O(1)-state step.

Block layout (Griffin recurrent block): two d→d_rnn branches; branch A
goes conv1d(4, causal) → RG-LRU, branch B is a GeLU gate; merged output
projects back to d.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.models.sharding import ShardingRules
from repro.models.xlstm import _causal_conv4

_C = 8.0


def init_rglru(rng, cfg, rules: ShardingRules):
    d = cfg.d_model
    dr = d  # lru width == model width (RecurrentGemma)
    ks = jax.random.split(rng, 7)
    p, s = {}, {}
    p["w_in"], s["w_in"] = dense_init(ks[0], (d, dr), ("embed", "mlp"), rules)
    p["w_gate"], s["w_gate"] = dense_init(ks[1], (d, dr), ("embed", "mlp"), rules)
    p["conv"], s["conv"] = dense_init(ks[2], (4, dr), (None, "mlp"), rules)
    # square recurrent gates: column-parallel only (a spec may use each
    # mesh axis once; activations stay dr-sharded over `model`)
    p["w_a"], s["w_a"] = dense_init(ks[3], (dr, dr), (None, "mlp"), rules)
    p["w_x"], s["w_x"] = dense_init(ks[4], (dr, dr), (None, "mlp"), rules)
    # Λ init so a^(1/c) ~ U[0.9, 0.999] (paper init)
    u = jax.random.uniform(ks[5], (dr,), jnp.float32, 0.9, 0.999)
    p["lam"] = jnp.log(jnp.expm1(-jnp.log(u)))  # inverse softplus
    s["lam"] = jax.sharding.PartitionSpec(None)
    p["w_out"], s["w_out"] = dense_init(ks[6], (dr, d), ("mlp", "embed"), rules)
    return p, s


def rglru_state(cfg, batch: int, dtype=jnp.float32):
    dr = cfg.d_model
    return {
        "h": jnp.zeros((batch, dr), dtype),
        "conv": jnp.zeros((batch, 3, dr), dtype),
    }


def _gates(p, u):
    """u [.., dr] -> (a, b) of the affine recurrence h' = a h + b."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ p["w_x"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    return a, b


def apply_rglru(cfg, p, x, state=None):
    """x [B,S,d] -> (y, state'). Parallel associative scan over time."""
    B, S, d = x.shape
    if state is None:
        state = rglru_state(cfg, B)
    u = x @ p["w_in"]
    u, new_tail = _causal_conv4(u, p["conv"], state["conv"])
    a, b = _gates(p, u)
    # fold the carried h0 into the first step
    b = b.at[:, 0].add(a[:, 0] * state["h"])

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
    gate = jax.nn.gelu(x @ p["w_gate"])
    y = (hs.astype(x.dtype) * gate) @ p["w_out"]
    return y, {"h": hs[:, -1], "conv": new_tail}


def apply_rglru_step(cfg, p, x, state):
    """Decode step: x [B,1,d] -> (y [B,1,d], state')."""
    u = x @ p["w_in"]
    u, new_tail = _causal_conv4(u, p["conv"], state["conv"])
    a, b = _gates(p, u[:, 0])
    h = a * state["h"] + b
    gate = jax.nn.gelu(x @ p["w_gate"])
    y = (h[:, None].astype(x.dtype) * gate) @ p["w_out"]
    return y, {"h": h, "conv": new_tail}

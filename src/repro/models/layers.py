"""Shared model components: norms, RoPE, MLPs, attention.

Attention comes in two memory-aware forms:

* ``chunked_attention`` — training/prefill. Online-softmax over KV chunks
  (lax.scan) inside an outer loop over Q chunks, so peak score memory is
  ``q_chunk x kv_chunk`` instead of ``S x S`` (mandatory at 32k).
  Supports causal + sliding-window masks and GQA grouping.
* ``decode_attention`` — single-token decode against a KV cache laid out
  as ``[B, n_splits, S/n_splits, KH, D]``. The splits dim is sharded over
  the ``model`` mesh axis (flash-decoding style split-KV): each shard
  produces partial (max, denom, weighted-V) and the combine over the
  splits dim lowers to a tiny cross-shard reduction instead of an
  all-gather of the cache.

All matmuls run in the config dtype (bf16); softmax statistics and norms
accumulate in fp32.
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.models.sharding import ShardingRules

# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------


def dense_init(rng, shape, logical, rules: ShardingRules, scale=None, dtype=jnp.bfloat16):
    """Truncated-normal dense weight + its PartitionSpec."""
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    w = jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32) * std
    return w.astype(dtype), rules.spec(logical, shape)


def constraint(x, logical, rules: ShardingRules):
    """with_sharding_constraint via logical names (no-op on 1-device)."""
    if rules.mesh.size <= 1:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(rules.mesh, rules.spec(logical, x.shape))
    )


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------


def init_norm(kind: str, dim: int, rules: ShardingRules):
    if kind == "rmsnorm":
        return {"w": jnp.ones((dim,), jnp.float32)}, {"w": P(None)}
    if kind == "layernorm":
        return (
            {"w": jnp.ones((dim,), jnp.float32), "b": jnp.zeros((dim,), jnp.float32)},
            {"w": P(None), "b": P(None)},
        )
    if kind == "layernorm_nonparam":  # olmo: non-parametric LN
        return {}, {}
    raise ValueError(kind)


def apply_norm(kind: str, p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (y * p["w"]).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    if kind == "layernorm":
        y = y * p["w"] + p["b"]
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def apply_rope(x, positions, theta: float):
    """x [..., S, H, D], positions [..., S] (broadcastable)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # [..., S, 1, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------


def init_mlp(rng, cfg, rules: ShardingRules, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    p, s = {}, {}
    if cfg.act in ("swiglu", "geglu"):
        p["wi"], s["wi"] = dense_init(ks[0], (d, f), ("embed", "mlp"), rules)
        p["wg"], s["wg"] = dense_init(ks[1], (d, f), ("embed", "mlp"), rules)
    else:
        p["wi"], s["wi"] = dense_init(ks[0], (d, f), ("embed", "mlp"), rules)
    p["wo"], s["wo"] = dense_init(ks[2], (f, d), ("mlp", "embed"), rules)
    return p, s


def apply_mlp(cfg, p, x):
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    elif cfg.act == "geglu":
        h = jax.nn.gelu(x @ p["wg"]) * (x @ p["wi"])
    elif cfg.act == "gelu":
        h = jax.nn.gelu(x @ p["wi"])
    else:
        raise ValueError(cfg.act)
    return h @ p["wo"]


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------


def init_attention(rng, cfg, rules: ShardingRules, cross: bool = False):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    H, KH = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(rng, 4)
    kv_in = cfg.context_dim or d if cross else d
    p, s = {}, {}
    p["wq"], s["wq"] = dense_init(ks[0], (d, H, hd), ("embed", "heads", None), rules)
    p["wk"], s["wk"] = dense_init(ks[1], (kv_in, KH, hd), ("embed", "kv_heads", None), rules)
    p["wv"], s["wv"] = dense_init(ks[2], (kv_in, KH, hd), ("embed", "kv_heads", None), rules)
    p["wo"], s["wo"] = dense_init(ks[3], (H, hd, d), ("heads", None, "embed"), rules)
    return p, s


_KV_PAD_POS = -(1 << 30)  # sentinel position marking padded KV slots


def _fit_chunk(S: int, chunk: int) -> int:
    """Largest divisor of S that is <= chunk (trace-time only)."""
    c = min(chunk, S)
    while S % c:
        c -= 1
    return c


def _gqa_scores(q, k):
    """q [B,Sq,KH,G,D] x k [B,Skv,KH,D] -> [B,KH,G,Sq,Skv] (fp32)."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)


def chunked_attention(
    q,
    k,
    v,
    *,
    q_positions,
    kv_positions,
    causal: bool,
    window: int = 0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
):
    """Online-softmax attention. q [B,Sq,H,D]; k,v [B,Skv,KH,D].

    Returns [B,Sq,H,D] in q.dtype. ``window > 0`` restricts to a sliding
    causal window (local attention).
    """
    B, Sq, H, D = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = 1.0 / np.sqrt(D)
    q = (q * scale).reshape(B, Sq, KH, G, D)

    q_chunk = _fit_chunk(Sq, q_chunk)
    n_q = Sq // q_chunk
    # KV side: pad to a multiple of the chunk (context lengths like 1601
    # are prime — _fit_chunk alone would degrade to a length-1 scan) and
    # mask the padded slots out via sentinel positions.
    kv_chunk = min(kv_chunk, Skv)
    pad_kv = (-Skv) % kv_chunk
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        kv_positions = jnp.concatenate(
            [kv_positions, jnp.full((pad_kv,), _KV_PAD_POS, jnp.int32)]
        )
        Skv = Skv + pad_kv
    n_kv = Skv // kv_chunk

    k_r = k.reshape(B, n_kv, kv_chunk, KH, D)
    v_r = v.reshape(B, n_kv, kv_chunk, KH, D)
    kpos_r = kv_positions.reshape(n_kv, kv_chunk)

    def q_block(args):
        qc, qpos = args  # [B,qc,KH,G,D], [qc]

        def kv_step(carry, xs):
            m, l, acc = carry
            kc, vc, kpos = xs  # [B,ck,KH,D], [B,ck,KH,D], [ck]
            s = _gqa_scores(qc, kc)  # [B,KH,G,qc,ck] fp32
            mask = (kpos[None, :] != _KV_PAD_POS)  # padded KV slots
            mask = jnp.broadcast_to(mask, (qpos.shape[0], kpos.shape[0]))
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window:
                mask &= qpos[:, None] - kpos[None, :] < window
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(qc.dtype), vc,
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        qc_sz = qc.shape[1]
        m0 = jnp.full((B, KH, G, qc_sz), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KH, G, qc_sz), jnp.float32)
        a0 = jnp.zeros((B, KH, G, qc_sz, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step,
            (m0, l0, a0),
            (k_r.swapaxes(0, 1), v_r.swapaxes(0, 1), kpos_r),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out  # [B,KH,G,qc,D]

    q_r = q.reshape(B, n_q, q_chunk, KH, G, D).swapaxes(0, 1)  # [n_q,B,qc,KH,G,D]
    qpos_r = q_positions.reshape(n_q, q_chunk)
    outs = jax.lax.map(q_block, (q_r, qpos_r))  # [n_q,B,KH,G,qc,D]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, KH * G, D)
    return out.astype(v.dtype)


def decode_attention(q, k_cache, v_cache, kv_positions, q_position, window: int = 0):
    """Single-token attention against a split-KV cache.

    q [B,H,D]; k/v_cache [B,n_splits,Sc,KH,D]; kv_positions [B,n_splits,Sc]
    (-1 for empty slots); q_position [B]. Returns [B,H,D].
    """
    B, H, D = q.shape
    _, NS, Sc, KH, _ = k_cache.shape
    G = H // KH
    scale = 1.0 / np.sqrt(D)
    qg = (q * scale).reshape(B, KH, G, D)

    s = jnp.einsum(
        "bhgd,bnkhd->bnhgk", qg, k_cache, preferred_element_type=jnp.float32
    )  # [B,NS,KH,G,Sc]
    mask = (kv_positions >= 0) & (kv_positions <= q_position[:, None, None])
    if window:
        mask &= q_position[:, None, None] - kv_positions < window
    s = jnp.where(mask[:, :, None, None, :], s, -jnp.inf)

    # per-split partials, then combine across the (sharded) splits dim
    m = s.max(axis=-1)  # [B,NS,KH,G]
    m_glob = m.max(axis=1, keepdims=True)
    m_safe = jnp.where(jnp.isfinite(m_glob), m_glob, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(mask[:, :, None, None, :], p, 0.0)
    l = p.sum(axis=(1, 4))  # [B,KH,G]
    pv = jnp.einsum(
        "bnhgk,bnkhd->bhgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    out = pv / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, H, D).astype(v_cache.dtype)

"""Logical-axis sharding rules (MaxText-style), with divisibility fallback.

Every parameter/activation annotates its dims with *logical* axis names;
``ShardingRules`` resolves them to mesh axes, replicating any dim whose
size does not divide the assigned mesh axes (e.g. starcoder2's 36 heads
on a 16-way model axis). The resolution is recorded so DESIGN/EXPERIMENTS
can report which dims fell back.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from jax.sharding import Mesh, PartitionSpec as P

# logical axis -> preferred mesh axes (first that divides wins; tuples
# mean "shard over the product of these axes")
DEFAULT_RULES: dict[str, tuple] = {
    "batch": (("pod", "data"), ("data",), None),
    "embed": (("data",), None),  # FSDP param sharding dim
    "mlp": (("model",), None),
    "heads": (("model",), None),
    "kv_heads": (("model",), None),
    "vocab": (("model",), None),
    "experts": (("model",), None),
    "seq": (None,),
    "cache_seq": (("model",), None),  # split-KV decode sharding
    "qkv": (("model",), None),  # fused q/k/v head*dim output dim
    None: (None,),
}


@dataclasses.dataclass
class ShardingRules:
    mesh: Mesh
    rules: dict | None = None
    fallbacks: list | None = None

    def __post_init__(self):
        self.rules = dict(DEFAULT_RULES, **(self.rules or {}))
        self.fallbacks = []

    def _axes_size(self, axes) -> int:
        if axes is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in axes if a in self.mesh.shape]))

    def resolve(self, logical: str | None, dim_size: int):
        """logical name + dim size -> mesh axes (or None)."""
        for cand in self.rules.get(logical, (None,)):
            if cand is None:
                return None
            axes = tuple(
                a for a in cand if self.mesh.shape.get(a, 1) > 1
            )
            if not axes:
                continue
            n = self._axes_size(axes)
            if dim_size % n == 0:
                return axes if len(axes) > 1 else axes[0]
            self.fallbacks.append((logical, dim_size, axes))
        return None

    def spec(self, logical_dims: tuple, shape: tuple) -> P:
        """Tuple of logical names (len == rank) -> PartitionSpec."""
        assert len(logical_dims) == len(shape), (logical_dims, shape)
        return P(*[self.resolve(l, s) for l, s in zip(logical_dims, shape)])

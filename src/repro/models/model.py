"""Model facade: init / forward / decode, assembled from transformer.py.

``LM`` covers all ten assigned architectures:
  * decoder-only (dense / MoE / ssm / hybrid) — groups of blocks
  * VLM — ``cross_attn_gated`` blocks consume projected image embeddings
  * enc-dec (whisper) — a bidirectional encoder stack feeds the decoder's
    ``cross_attn`` blocks; the conv frontend is a stub (precomputed frame
    embeddings arrive as the context input, per the assignment).

Params are plain pytrees; ``init`` also returns a matching pytree of
PartitionSpecs derived from logical axis rules (models/sharding.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import optimization_barrier
from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.sharding import ShardingRules


@dataclasses.dataclass
class LM:
    cfg: ModelConfig
    rules: ShardingRules

    # ---------------------------------------------------------------- init
    def init(self, rng):
        cfg = self.cfg
        rules = self.rules
        dt = jnp.dtype(cfg.dtype)
        G = T.num_groups(cfg)
        ks = jax.random.split(rng, 8)
        p, s = {}, {}

        V = cfg.padded_vocab
        emb, emb_spec = L.dense_init(ks[0], (V, cfg.d_model), ("vocab", "embed"), rules, scale=0.02, dtype=dt)
        p["embed"], s["embed"] = emb, emb_spec
        p["lm_head"], s["lm_head"] = L.dense_init(
            ks[1], (cfg.d_model, V), ("embed", "vocab"), rules, dtype=dt
        )

        def one_group(k):
            gp, gs = {}, {}
            kk = jax.random.split(k, len(cfg.block_pattern))
            for i, kind in enumerate(cfg.block_pattern):
                gp[f"b{i}"], gs[f"b{i}"] = T.init_block(kk[i], cfg, kind, rules)
            return gp, gs

        gkeys = jax.random.split(ks[2], G)
        gp0, gs0 = one_group(gkeys[0])
        stacked = jax.vmap(lambda k: one_group(k)[0])(gkeys)
        p["groups"] = stacked
        s["groups"] = jax.tree.map(
            lambda spec: P(*((None,) + tuple(spec))), gs0,
            is_leaf=lambda x: isinstance(x, P),
        )

        if cfg.extra_tail_blocks:
            tk = jax.random.split(ks[3], len(cfg.extra_tail_blocks))
            p["tail"], s["tail"] = [], []
            for i, kind in enumerate(cfg.extra_tail_blocks):
                tp, ts = T.init_block(tk[i], cfg, kind, rules)
                p["tail"].append(tp)
                s["tail"].append(ts)

        p["final_norm"], s["final_norm"] = L.init_norm(cfg.norm, cfg.d_model, rules)

        if cfg.context_dim and cfg.context_dim != cfg.d_model:
            p["ctx_proj"], s["ctx_proj"] = L.dense_init(
                ks[4], (cfg.context_dim, cfg.d_model), (None, "embed"), rules, dtype=dt
            )

        if cfg.encoder_layers:
            ekeys = jax.random.split(ks[5], cfg.encoder_layers)
            enc0_p, enc0_s = T.init_block(ekeys[0], cfg, "attn", rules)
            enc_stack = jax.vmap(lambda k: T.init_block(k, cfg, "attn", rules)[0])(ekeys)
            p["encoder"] = {"groups": enc_stack}
            s["encoder"] = {
                "groups": jax.tree.map(
                    lambda spec: P(*((None,) + tuple(spec))), enc0_s,
                    is_leaf=lambda x: isinstance(x, P),
                )
            }
            fp, fs = L.init_norm(cfg.norm, cfg.d_model, rules)
            p["encoder"]["final_norm"], s["encoder"]["final_norm"] = fp, fs

        return p, s

    # ------------------------------------------------------------- context
    def _encode_context(self, params, context):
        """Project / encode the raw context (image patches or frames)."""
        cfg = self.cfg
        if context is None:
            return None
        if "ctx_proj" in params:
            context = context @ params["ctx_proj"]
        if cfg.encoder_layers:
            x = context
            pos = jnp.arange(x.shape[1])

            def enc_step(carry, gp):
                aux: dict = {}
                y, _ = T.apply_block_seq(
                    cfg, "attn", gp, carry, self.rules,
                    positions=pos, context=None, causal=False, aux=aux,
                )
                return y, None

            body = enc_step
            if cfg.remat:
                body = jax.checkpoint(enc_step)
            x, _ = jax.lax.scan(body, x, params["encoder"]["groups"])
            context = L.apply_norm(cfg.norm, params["encoder"]["final_norm"], x)
        return context

    # ------------------------------------------------------------- forward
    def forward_features(self, params, tokens, context=None):
        """tokens [B,S] -> final-norm features [B,S,d] (+ aux dict).

        Split from ``forward`` so training can fuse the unembedding into
        the chunked CE loss (``fused_ce_loss``) without materialising
        [B,S,V] logits."""
        cfg = self.cfg
        rules = self.rules
        B, S = tokens.shape
        x = params["embed"][tokens]
        x = L.constraint(x, ("batch", "seq", None), rules)
        pos = jnp.arange(S)
        ctx = self._encode_context(params, context)

        def group_fn(x, gp):
            aux_g = {"moe_aux": jnp.float32(0.0), "moe_drop_frac": jnp.float32(0.0)}
            for i, kind in enumerate(cfg.block_pattern):
                x, _ = T.apply_block_seq(
                    cfg, kind, gp[f"b{i}"], x, rules,
                    positions=pos, context=ctx, causal=True, aux=aux_g,
                )
            # barrier pins the remat-saved carry to bf16 — without it XLA
            # hoists the next layernorm's f32 convert into the stacked
            # residual buffer, doubling the stash (§Perf iteration #10)
            x = optimization_barrier(x)
            return x, (aux_g["moe_aux"], aux_g["moe_drop_frac"])

        body = group_fn
        if cfg.remat:
            body = jax.checkpoint(group_fn)
        if cfg.scan_layers:
            x, (aux_v, drop_v) = jax.lax.scan(body, x, params["groups"])
            moe_aux, drop = aux_v.mean(), drop_v.mean()
        else:
            moe_aux = drop = jnp.float32(0.0)
            G = T.num_groups(cfg)
            for g in range(G):
                gp = jax.tree.map(lambda a: a[g], params["groups"])
                x, (a, dr) = body(x, gp)
                moe_aux, drop = moe_aux + a / G, drop + dr / G

        for i, kind in enumerate(cfg.extra_tail_blocks):
            aux_g: dict = {}
            x, _ = T.apply_block_seq(
                cfg, kind, params["tail"][i], x, rules,
                positions=pos, context=ctx, causal=True, aux=aux_g,
            )

        x = L.apply_norm(cfg.norm, params["final_norm"], x)
        return x, {"moe_aux": moe_aux, "moe_drop_frac": drop}

    def forward(self, params, tokens, context=None):
        """tokens [B,S] -> logits [B,S,V_pad] (+ aux dict)."""
        x, aux = self.forward_features(params, tokens, context)
        logits = x @ params["lm_head"]
        logits = L.constraint(logits, ("batch", "seq", "vocab"), self.rules)
        return logits, aux

    def prefill(self, params, tokens, context=None):
        """Serving prefill: logits of the last position only [B, V]."""
        logits, _ = self.forward(params, tokens, context)
        return logits[:, -1]

    # -------------------------------------------------------------- decode
    def init_cache(self, params, batch: int, max_len: int, kv_splits: int = 1,
                   context=None):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        G = T.num_groups(cfg)
        ctx = self._encode_context(params, context)

        def one_block_cache(kind, gp_block):
            c = T.init_block_cache(cfg, kind, batch, max_len, kv_splits, dt)
            if kind in T.CROSS_KINDS and ctx is not None:
                ck = jnp.einsum("bcd,dhe->bche", ctx, gp_block["attn"]["wk"])
                cv = jnp.einsum("bcd,dhe->bche", ctx, gp_block["attn"]["wv"])
                c = dict(c, ck=ck[:, None], cv=cv[:, None])
            return c

        caches = {}
        for i, kind in enumerate(cfg.block_pattern):
            gp_i = jax.tree.map(lambda a: a, params["groups"][f"b{i}"])
            # build per-group caches by vmapping over the stacked dim
            def mk(gp_block):
                return one_block_cache(kind, gp_block)
            caches[f"b{i}"] = jax.vmap(mk)(gp_i) if _has_ctx_kv(kind, ctx) else (
                jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (G,) + a.shape),
                    T.init_block_cache(cfg, kind, batch, max_len, kv_splits, dt),
                )
            )
        tail = []
        for i, kind in enumerate(cfg.extra_tail_blocks):
            tail.append(one_block_cache(kind, params["tail"][i]))
        return {"layers": caches, "tail": tail, "pos": jnp.int32(0)}

    def decode_step(self, params, cache, tokens, context=None):
        """tokens [B] -> (logits [B, V_pad], new cache).

        The group loop CARRIES the stacked cache and updates it in place
        (dynamic_update_index) instead of passing it as scan xs/ys —
        the xs/ys form double-buffers the whole KV cache in temps
        (whisper decode_32k: 12.2 GB of scratch for a 4.7 GB cache;
        §Perf log).
        """
        cfg = self.cfg
        rules = self.rules
        B = tokens.shape[0]
        x = params["embed"][tokens][:, None]  # [B,1,d]
        pos = cache["pos"]

        def group_fn(carry, g):
            x, caches = carry
            gp = jax.tree.map(lambda a: a[g], params["groups"])
            cg = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, g, 0, keepdims=False),
                caches,
            )
            aux: dict = {}
            new_cg = {}
            for i, kind in enumerate(cfg.block_pattern):
                x, new_cg[f"b{i}"] = T.apply_block_decode(
                    cfg, kind, gp[f"b{i}"], x, rules, pos=pos,
                    cache=cg[f"b{i}"], aux=aux,
                )
            caches = jax.tree.map(
                lambda full, new: jax.lax.dynamic_update_index_in_dim(
                    full, new, g, 0
                ),
                caches, new_cg,
            )
            return (x, caches), None

        G = T.num_groups(cfg)
        (x, new_caches), _ = jax.lax.scan(
            group_fn, (x, cache["layers"]), jnp.arange(G)
        )
        new_tail = []
        for i, kind in enumerate(cfg.extra_tail_blocks):
            aux: dict = {}
            x, nc = T.apply_block_decode(
                cfg, kind, params["tail"][i], x, rules, pos=pos,
                cache=cache["tail"][i], aux=aux,
            )
            new_tail.append(nc)

        x = L.apply_norm(cfg.norm, params["final_norm"], x)
        logits = (x @ params["lm_head"])[:, 0]
        return logits, {"layers": new_caches, "tail": new_tail, "pos": pos + 1}


def _has_ctx_kv(kind, ctx):
    return kind in T.CROSS_KINDS and ctx is not None


# --------------------------------------------------------------------------
# loss / steps
# --------------------------------------------------------------------------


def fused_ce_loss(cfg: ModelConfig, x, lm_head, labels, z_coef: float = 1e-4,
                  moe_aux=None, chunk: int = 512):
    """Cross-entropy fused with the unembedding, chunked over sequence.

    Never materialises the full [B, S, V] logits (the peak buffer on
    every large-vocab train cell: glm 151k / llama 128k vocab × f32 —
    §Perf log iteration #9). Per-position CE is independent, so chunking
    the S dim is exact. x [B,S,d] (final-norm output), lm_head [d,V].
    """
    B, S, d = x.shape
    c = chunk
    while S % c:
        c -= 1
    n = S // c

    xc = x.reshape(B, n, c, d).swapaxes(0, 1)  # [n,B,c,d]
    lc = labels.reshape(B, n, c).swapaxes(0, 1)

    def chunk_loss(args):
        xi, li = args  # [B,c,d], [B,c]
        logits = (xi @ lm_head).astype(jnp.float32)  # [B,c,V]
        lse = jax.nn.logsumexp(logits, axis=-1)
        mask = li >= 0
        safe = jnp.maximum(li, 0)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        nll = ((lse - gold) * mask).sum()
        zsq = ((lse * mask) ** 2).sum()
        return nll, zsq, mask.sum()

    # checkpoint: without it the map's backward STACKS every chunk's f32
    # logits as residuals — the exact buffer this function exists to kill.
    # Recomputing one [B,c,d]@[d,V] matmul per chunk in the backward is
    # the cheap side of that trade.
    nll, zsq, cnt = jax.lax.map(jax.checkpoint(chunk_loss), (xc, lc))
    denom = jnp.maximum(cnt.sum(), 1)
    loss = nll.sum() / denom
    zloss = z_coef * zsq.sum() / denom
    total = loss + zloss
    if moe_aux is not None:
        total = total + 0.01 * moe_aux
    return total, {"nll": loss, "zloss": zloss}


def lm_loss(cfg: ModelConfig, logits, labels, z_coef: float = 1e-4, moe_aux=None):
    """Cross-entropy with label mask (-1), z-loss, and MoE aux loss.

    ``logits`` may be vocab-padded; padded ids never appear in labels.
    """
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    gold = jnp.take_along_axis(lf, safe[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    denom = jnp.maximum(mask.sum(), 1)
    loss = nll.sum() / denom
    zloss = z_coef * ((lse * mask) ** 2).sum() / denom
    total = loss + zloss
    if moe_aux is not None:
        total = total + 0.01 * moe_aux
    return total, {"nll": loss, "zloss": zloss}


def build_model(cfg: ModelConfig, rules: ShardingRules) -> LM:
    return LM(cfg=cfg, rules=rules)

"""Top-k MoE with expert parallelism over the ``model`` mesh axis.

Two execution paths, chosen by sequence length:

* **train/prefill** (``S % n_model == 0``): an explicit shard_map — the
  tokens are fully partitioned over (pod, data, model) (sequence goes to
  the model axis for the MoE block), each device routes its local tokens
  with the same sort-into-capacity-buckets dispatch the EE-Join shuffle
  uses (see extraction/distributed.py), exchanges them over the model
  axis with ``all_to_all``, runs its expert shard, and reverses the
  exchange. Per-device expert compute waste is ``E / n_model`` relative
  to a perfect grouped GEMM (== 1 for dbrx's 16 experts on a 16-way
  axis).
* **decode** (``S == 1``): tokens are too few to shard further, so all
  (sharded) experts evaluate the batch densely and the router mask
  combines — compute waste E/top_k, negligible at decode arithmetic
  intensities and free of routing collectives beyond the psum TP already
  pays. Flagged in EXPERIMENTS.md as a hillclimb target.

Dropping semantics: per-destination capacity ``C = ceil(N*k/n * cf)``;
overflowing assignments contribute zero (standard dropping MoE) and the
dropped fraction is returned for diagnostics. Router aux loss is the
usual load-balancing loss ``E * Σ_e f_e P_e``.
"""
from __future__ import annotations

import math
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.models.layers import dense_init
from repro.models.sharding import ShardingRules


def init_moe(rng, cfg, rules: ShardingRules):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(rng, 4)
    p, s = {}, {}
    p["wr"], s["wr"] = dense_init(ks[0], (d, E), (None, None), rules)
    # Expert weights are EP(model)×FSDP(data)-sharded like every other
    # weight. (§Perf hillclimb #3 REFUTED the EP-only variant: replicating
    # experts over `data` swaps the per-microbatch bf16 all-gather for a
    # per-microbatch full-size f32 grad accumulator + all-reduce — granite
    # train_4k collective went 12.2s -> 20.8s. Whether FSDP applies at all
    # is decided per-arch by the param-memory rule in launch/specs.py.)
    p["wi"], s["wi"] = dense_init(ks[1], (E, d, f), ("experts", "embed", None), rules)
    p["wg"], s["wg"] = dense_init(ks[2], (E, d, f), ("experts", "embed", None), rules)
    p["wo"], s["wo"] = dense_init(ks[3], (E, f, d), ("experts", "embed", None), rules)
    return p, s


def _expert_ffn(wi, wg, wo, x):
    """x [..., d] through one (or a stacked batch of) expert(s)."""
    return (jax.nn.silu(x @ wg) * (x @ wi)) @ wo


def _aux_loss(probs, ids, E: int):
    """Load-balancing loss: E * sum_e mean(route frac) * mean(prob)."""
    f = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    f = f / jnp.maximum(ids.size, 1)
    pbar = probs.mean(axis=0)
    return E * jnp.sum(f * pbar)


def apply_moe(cfg, p, x, rules: ShardingRules, capacity_factor: float | None = None):
    """x [B, S, d] -> (y [B, S, d], aux dict)."""
    E, k = cfg.num_experts, cfg.top_k
    mesh = rules.mesh
    n_model = int(mesh.shape.get("model", 1))
    S = x.shape[1]
    cf = capacity_factor or cfg.moe_capacity_factor

    if S == 1 or n_model == 1 or S % n_model != 0 or E % n_model != 0:
        return _apply_moe_dense(cfg, p, x)

    batch_axes = tuple(a for a in mesh.axis_names if a != "model")
    E_loc = E // n_model

    def body(xl, wr, wi, wg, wo):
        # xl [B_loc, S_loc, d]; wi/wg/wo local expert shards [E_loc, d, f]
        B_loc, S_loc, d = xl.shape
        N = B_loc * S_loc
        toks = xl.reshape(N, d)
        logits = (toks @ wr).astype(jnp.float32)  # [N, E]
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_ids = jax.lax.top_k(probs, k)  # [N, k]
        top_w = (top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)).astype(xl.dtype)
        aux = _aux_loss(probs, top_ids, E)

        # ---- dispatch (same sort-into-buckets as the EE-Join shuffle)
        C = max(8, math.ceil(N * k / n_model * cf))
        a_rank = (top_ids // E_loc).reshape(-1)  # [N*k]
        a_eloc = (top_ids % E_loc).reshape(-1)
        order = jnp.argsort(a_rank, stable=True)
        counts = jnp.bincount(a_rank, length=n_model + 1)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(N * k) - starts[a_rank[order]]
        keep = pos < C
        dst_r = jnp.where(keep, a_rank[order], n_model - 1)
        dst_p = jnp.where(keep, pos, C)  # C -> dropped by mode="drop"

        tok_of = order // k
        send_x = jnp.zeros((n_model, C, d), xl.dtype)
        send_e = jnp.full((n_model, C), -1, jnp.int32)
        send_x = send_x.at[dst_r, dst_p].set(toks[tok_of], mode="drop")
        send_e = send_e.at[dst_r, dst_p].set(a_eloc[order].astype(jnp.int32), mode="drop")
        # remember where each assignment went (original order)
        slot = jnp.full((N * k,), n_model * C, jnp.int32)
        slot = slot.at[order].set(
            jnp.where(keep, dst_r * C + dst_p, n_model * C), mode="drop"
        )
        dropped = (~keep).sum()

        a2a = partial(jax.lax.all_to_all, axis_name="model", split_axis=0, concat_axis=0)
        recv_x = a2a(send_x)  # [n_model, C, d]
        recv_e = a2a(send_e)

        # ---- local expert compute (masked per local expert)
        rx = recv_x.reshape(n_model * C, d)
        re = recv_e.reshape(n_model * C)
        out = jnp.zeros((n_model * C, d), xl.dtype)
        for e in range(E_loc):
            h = _expert_ffn(wi[e], wg[e], wo[e], rx)
            out = out + h * (re == e)[:, None].astype(h.dtype)

        back = a2a(out.reshape(n_model, C, d))  # [n_model, C, d] at sender
        back_flat = jnp.concatenate(
            [back.reshape(n_model * C, d), jnp.zeros((1, d), xl.dtype)], axis=0
        )
        per_assign = back_flat[slot].reshape(N, k, d)
        y = (per_assign * top_w[..., None]).sum(axis=1).reshape(B_loc, S_loc, d)

        aux = jax.lax.pmean(aux, batch_axes + ("model",))
        drop_frac = jax.lax.pmean(dropped / (N * k), batch_axes + ("model",))
        return y, aux, drop_frac

    x_spec = P(batch_axes, "model", None)
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            x_spec,
            P(None, None),
            P("model", None, None),
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=(x_spec, P(), P()),
        check_vma=False,
    )
    y, aux, drop = fn(x, p["wr"], p["wi"], p["wg"], p["wo"])
    return y, {"moe_aux": aux, "moe_drop_frac": drop}


def _apply_moe_dense(cfg, p, x):
    """Decode fallback: every (sharded) expert computes the whole batch."""
    E, k = cfg.num_experts, cfg.top_k
    B, S, d = x.shape
    toks = x.reshape(B * S, d)
    logits = (toks @ p["wr"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_ids = jax.lax.top_k(probs, k)
    top_w = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    gate = jnp.zeros((B * S, E), jnp.float32)
    gate = jax.vmap(lambda g, i, w: g.at[i].set(w))(gate, top_ids, top_w)

    h = jnp.einsum("nd,edf->nef", toks, p["wg"])
    hi = jnp.einsum("nd,edf->nef", toks, p["wi"])
    h = jax.nn.silu(h) * hi
    y_e = jnp.einsum("nef,efd->ned", h, p["wo"])
    y = jnp.einsum("ned,ne->nd", y_e, gate.astype(y_e.dtype))
    aux = _aux_loss(probs, top_ids, E)
    return y.reshape(B, S, d), {"moe_aux": aux, "moe_drop_frac": jnp.float32(0.0)}

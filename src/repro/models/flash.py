"""Flash-attention with a custom VJP (§Perf hillclimbs #1 and #4).

Two structural choices vs the naive baseline (layers.chunked_attention):

1. **Flash backward** — the baseline's autodiff backward saves the
   probability matrices of every (q-chunk × kv-chunk) pair. We save only
   ``(q, k, v, out, lse)`` and recompute scores chunkwise in a two-pass
   backward (dq sweep + dk/dv sweep). Exact math; verified against the
   naive reference in tests/test_flash.py.

2. **GQA-flattened layout** — the baseline computes in ``[B,S,KH,G,D]``,
   which is shardable over the ``model`` axis only via KH. Most assigned
   archs have KH ∈ {1,2,4,8} < 16, so every attention tensor fell back
   to replicated and XLA inserted per-layer q/out all-gathers (the +156
   GB/device all-gather regression on granite, §Perf log). Here K/V are
   expanded to the full H heads *outside* the custom VJP (autodiff sums
   the cotangents back to KH automatically) and everything runs in
   ``[B,S,H,D]`` — head-sharded TP for every arch whose H divides the
   model axis (8 of 10). Per-device K/V bytes are unchanged: each shard
   holds H/16 expanded heads instead of the full KH replicated.
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

_KV_PAD_POS = -(1 << 30)
_NEG = -1e30


def _mask(qpos, kpos, causal: bool, window: int):
    m = (kpos[None, :] != _KV_PAD_POS)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window:
        m &= qpos[:, None] - kpos[None, :] < window
    return m  # [qc, kc]


@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _flash(q, k, v, q_positions, kv_positions, causal, window, q_chunk,
           kv_chunk):
    out, _ = _fwd(q, k, v, q_positions, kv_positions, causal, window,
                  q_chunk, kv_chunk)
    return out


def _fwd(q, k, v, q_positions, kv_positions, causal, window, q_chunk,
         kv_chunk):
    """q [B,Sq,H,D]; k/v [B,Skv,H,D] (pre-expanded heads)."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    n_q, n_kv = Sq // q_chunk, Skv // kv_chunk
    k_r = k.reshape(B, n_kv, kv_chunk, H, D)
    v_r = v.reshape(B, n_kv, kv_chunk, H, D)
    kpos_r = kv_positions.reshape(n_kv, kv_chunk)

    def q_block(args):
        qc, qpos = args  # [B,qc,H,D], [qc]

        def kv_step(carry, xs):
            m, l, acc = carry
            kc, vc, kpos = xs
            s = jnp.einsum("bqhd,bkhd->bhqk", qc, kc,
                           preferred_element_type=jnp.float32)
            msk = _mask(qpos, kpos, causal, window)
            s = jnp.where(msk[None, None], s, _NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(msk[None, None], p, 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(qc.dtype), vc,
                            preferred_element_type=jnp.float32)
            return (m_new, l_new, acc * corr[..., None] + pv), None

        qc_sz = qc.shape[1]
        m0 = jnp.full((B, H, qc_sz), _NEG, jnp.float32)
        l0 = jnp.zeros((B, H, qc_sz), jnp.float32)
        a0 = jnp.zeros((B, H, qc_sz, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (k_r.swapaxes(0, 1), v_r.swapaxes(0, 1), kpos_r),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), jnp.inf)
        return out.astype(v.dtype), lse  # [B,H,qc,D], [B,H,qc]

    q_r = q.reshape(B, n_q, q_chunk, H, D).swapaxes(0, 1)
    qpos_r = q_positions.reshape(n_q, q_chunk)
    outs, lses = jax.lax.map(q_block, (q_r, qpos_r))  # [n_q,B,H,qc,D]
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, Sq, H, D)
    lse = lses.transpose(1, 2, 0, 3).reshape(B, H, Sq)
    return out, lse


def _fwd_vjp(q, k, v, q_positions, kv_positions, causal, window, q_chunk,
             kv_chunk):
    out, lse = _fwd(q, k, v, q_positions, kv_positions, causal, window,
                    q_chunk, kv_chunk)
    return out, (q, k, v, q_positions, kv_positions, out, lse)


def _bwd_vjp(causal, window, q_chunk, kv_chunk, res, dout):
    q, k, v, q_positions, kv_positions, out, lse = res
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    n_q, n_kv = Sq // q_chunk, Skv // kv_chunk

    # delta_t = sum_d dout_t,d * out_t,d (flash-attention bwd identity)
    delta = jnp.einsum(
        "bshd,bshd->bhs", dout.astype(jnp.float32), out.astype(jnp.float32)
    )  # [B,H,Sq]

    q_r = q.reshape(B, n_q, q_chunk, H, D).swapaxes(0, 1)
    do_r = dout.reshape(B, n_q, q_chunk, H, D).swapaxes(0, 1)
    k_r = k.reshape(B, n_kv, kv_chunk, H, D).swapaxes(0, 1)
    v_r = v.reshape(B, n_kv, kv_chunk, H, D).swapaxes(0, 1)
    qpos_r = q_positions.reshape(n_q, q_chunk)
    kpos_r = kv_positions.reshape(n_kv, kv_chunk)
    lse_r = lse.reshape(B, H, n_q, q_chunk).transpose(2, 0, 1, 3)
    dl_r = delta.reshape(B, H, n_q, q_chunk).transpose(2, 0, 1, 3)

    def _p(qc, kc, qpos, kpos, lse_c):
        s = jnp.einsum("bqhd,bkhd->bhqk", qc, kc,
                       preferred_element_type=jnp.float32)
        msk = _mask(qpos, kpos, causal, window)
        s = jnp.where(msk[None, None], s, _NEG)
        lse_safe = jnp.where(jnp.isfinite(lse_c), lse_c, 0.0)
        p = jnp.exp(s - lse_safe[..., None])
        p = jnp.where(msk[None, None], p, 0.0)
        p = jnp.where(jnp.isfinite(lse_c)[..., None], p, 0.0)
        return p  # [B,H,qc,kc] f32

    # ---- pass A: dq (map q chunks; scan kv chunks)
    def dq_block(args):
        qc, doc, qpos, lse_c, dl_c = args

        def kv_step(dq_acc, xs):
            kc, vc, kpos = xs
            p = _p(qc, kc, qpos, kpos, lse_c)
            dp = jnp.einsum("bqhd,bkhd->bhqk", doc, vc,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - dl_c[..., None])
            dq_c = jnp.einsum("bhqk,bkhd->bqhd", ds.astype(qc.dtype), kc,
                              preferred_element_type=jnp.float32)
            return dq_acc + dq_c, None

        dq0 = jnp.zeros((B, q_chunk, H, D), jnp.float32)
        dq_c, _ = jax.lax.scan(kv_step, dq0, (k_r, v_r, kpos_r))
        return dq_c

    dq_r = jax.lax.map(dq_block, (q_r, do_r, qpos_r, lse_r, dl_r))
    dq = dq_r.swapaxes(0, 1).reshape(B, Sq, H, D).astype(q.dtype)

    # ---- pass B: dk/dv (map kv chunks; scan q chunks)
    def dkv_block(args):
        kc, vc, kpos = args

        def q_step(carry, xs):
            dk_acc, dv_acc = carry
            qc, doc, qpos, lse_c, dl_c = xs
            p = _p(qc, kc, qpos, kpos, lse_c)
            dv_c = jnp.einsum("bhqk,bqhd->bkhd", p.astype(doc.dtype), doc,
                              preferred_element_type=jnp.float32)
            dp = jnp.einsum("bqhd,bkhd->bhqk", doc, vc,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - dl_c[..., None])
            dk_c = jnp.einsum("bhqk,bqhd->bkhd", ds.astype(qc.dtype), qc,
                              preferred_element_type=jnp.float32)
            return (dk_acc + dk_c, dv_acc + dv_c), None

        z = jnp.zeros((B, kv_chunk, H, D), jnp.float32)
        (dk_c, dv_c), _ = jax.lax.scan(
            q_step, (z, z), (q_r, do_r, qpos_r, lse_r, dl_r)
        )
        return dk_c, dv_c

    dk_r, dv_r = jax.lax.map(dkv_block, (k_r, v_r, kpos_r))
    dk = dk_r.swapaxes(0, 1).reshape(B, Skv, H, D).astype(k.dtype)
    dv = dv_r.swapaxes(0, 1).reshape(B, Skv, H, D).astype(v.dtype)
    return dq, dk, dv, None, None


_flash.defvjp(_fwd_vjp, _bwd_vjp)


def flash_attention(q, k, v, *, q_positions, kv_positions, causal,
                    window: int = 0, q_chunk: int = 1024,
                    kv_chunk: int = 1024):
    """Drop-in for layers.chunked_attention with flash-style backward.

    q [B,Sq,H,D] (unscaled); k/v [B,Skv,KH,D]. Returns [B,Sq,H,D].
    """
    B, Sq, H, D = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    G = H // KH
    # Python-float scale: a np.float64 scalar would silently promote
    # bf16 activations to f32 through the whole attention block.
    q = q * float(1.0 / np.sqrt(D))
    if G > 1:
        # GQA flattening: expand K/V to H heads so every tensor is
        # head-shardable; autodiff sums dk/dv back over the G copies.
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)

    # chunk fitting + KV padding (same policy as the baseline)
    from repro.models.layers import _fit_chunk

    q_chunk = _fit_chunk(Sq, q_chunk)
    kv_chunk = min(kv_chunk, Skv)
    pad_kv = (-Skv) % kv_chunk
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        kv_positions = jnp.concatenate(
            [kv_positions, jnp.full((pad_kv,), _KV_PAD_POS, jnp.int32)]
        )
    return _flash(q, k, v, q_positions, kv_positions, causal, window,
                  q_chunk, kv_chunk)

"""Training launcher.

CPU demo (default): train a reduced config of any assigned arch on the
synthetic corpus with the EE-Join annotation stage in the pipeline:

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 50

Production: the same code path with ``--mesh data,model`` sizes; on a
real TPU pod slice the mesh axes map onto the slice topology and the
dry-run artifacts (launch/dryrun.py) prove every cell lowers + fits.
Checkpoints land in --ckpt-dir; --resume restarts from the latest one
(fault tolerance: kill the process at any step and relaunch with
--resume; tests/test_train.py exercises exactly that).
"""
from __future__ import annotations

import argparse

import numpy as np

import jax

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.core.cost_model import CostParams
from repro.core.eejoin import EEJoinConfig, EEJoinOperator
from repro.data.pipeline import PipelineConfig, batches
from repro.data.synth import make_corpus
from repro.launch.mesh import make_cpu_mesh
from repro.models.model import build_model
from repro.models.sharding import ShardingRules
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import TrainerConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="olmo-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--no-annotate", action="store_true",
                    help="skip the EE-Join pipeline annotation stage")
    ap.add_argument("--mesh", default="1,1",
                    help="data,model mesh sizes (CPU demo: 1,1)")
    args = ap.parse_args()

    d, m = (int(x) for x in args.mesh.split(","))
    mesh = make_cpu_mesh(d, m)
    cfg = get_smoke_config(args.arch)
    rules = ShardingRules(mesh)
    model = build_model(cfg, rules)

    corpus = make_corpus(
        num_docs=64, doc_len=256, vocab_size=cfg.vocab_size,
        num_entities=64, mention_dist="zipf", seed=0,
    )
    op = prepared = None
    if not args.no_annotate:
        op = EEJoinOperator(corpus.dictionary, EEJoinConfig(gamma=0.8))
        stats = op.gather_statistics(corpus.doc_tokens[:16],
                                     total_docs=len(corpus.doc_tokens))
        plan = op.choose_plan(stats, CostParams(num_devices=1))
        prepared = op.prepare(plan)
        print(f"[train] EE-Join plan: {plan.head.algo}:{plan.head.scheme} | "
              f"{plan.tail.algo}:{plan.tail.scheme} @ split {plan.split}")

    data = batches(
        corpus,
        PipelineConfig(seq_len=args.seq, global_batch=args.batch,
                       annotate=not args.no_annotate),
        op, prepared,
    )
    out = train(
        model,
        data,
        AdamWConfig(lr=args.lr, total_steps=args.steps, warmup_steps=10),
        TrainerConfig(
            total_steps=args.steps, microbatches=args.microbatches,
            log_every=max(args.steps // 10, 1),
            checkpoint_every=args.ckpt_every, checkpoint_dir=args.ckpt_dir,
        ),
        mesh,
        resume=args.resume,
    )
    for h in out["history"]:
        print(f"[train] step {h['step']:5d} loss {h['loss']:.4f} "
              f"({h['sec_per_step']:.2f}s/step)")


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The paper-representative dry-run cell (§Perf pick #3): the EE-Join
# extraction job itself, lowered + compiled on a production-scale worker
# mesh with abstract document shards (ShapeDtypeStruct), exactly like the
# LM cells. Records the same roofline JSON under results/dryrun/.
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import time  # noqa: E402

import numpy as np  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.compat import set_mesh  # noqa: E402
from repro.core.cost_model import ALGO_INDEX, ALGO_SSJOIN, CostParams  # noqa: E402
from repro.core.eejoin import EEJoinConfig, EEJoinOperator  # noqa: E402
from repro.core.plan import PlanSide  # noqa: E402
from repro.core.cost_model import SideCost, OBJ_JOB  # noqa: E402
from repro.core.plan import Plan  # noqa: E402
from repro.data.synth import make_corpus  # noqa: E402
from repro.launch import roofline as RL  # noqa: E402
from repro.launch.dryrun import OUT_DIR, _mem_dict  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=256)
    ap.add_argument("--docs-per-worker", type=int, default=64)
    ap.add_argument("--doc-len", type=int, default=512)
    ap.add_argument("--entities", type=int, default=8192)
    ap.add_argument("--scheme", default="variant",
                    choices=("word", "prefix", "lsh", "variant"))
    ap.add_argument("--max-candidates", type=int, default=8192)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    n = args.workers
    mesh = jax.make_mesh((n,), ("workers",),
                         axis_types=(jax.sharding.AxisType.Auto,))

    # host-side dictionary/structures are REAL (they're the broadcast
    # side); only the document stream is abstract.
    corpus = make_corpus(
        num_docs=8, doc_len=args.doc_len, vocab_size=32768,
        num_entities=args.entities, mention_dist="zipf", seed=1,
    )
    op = EEJoinOperator(
        corpus.dictionary,
        EEJoinConfig(gamma=0.8, max_candidates=args.max_candidates,
                     result_capacity=args.max_candidates),
    )
    z = SideCost(0, 0, 0, 0, 0, 0, 0, 0, 0)
    plan = Plan(0, PlanSide(ALGO_INDEX, "prefix"),
                PlanSide(ALGO_SSJOIN, args.scheme), OBJ_JOB, 0.0, z, z, 0)
    prepared = op.prepare_distributed(plan, n, CostParams(num_devices=n))
    side = prepared.sides[0]

    D = n * args.docs_per_worker
    docs = jax.ShapeDtypeStruct((D, args.doc_len), jnp.int32)
    docs_sh = NamedSharding(mesh, P("workers"))

    from repro.extraction.distributed import distributed_extract_ssjoin

    def job(doc_tokens):
        m, diag = distributed_extract_ssjoin(
            mesh, ("workers",), doc_tokens, side, prepared.max_entity_len
        )
        return m.count, diag.bytes_shuffled, diag.max_received

    t0 = time.time()
    with set_mesh(mesh):
        lowered = jax.jit(job, in_shardings=(docs_sh,)).lower(docs)
        compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()

    cfg = op  # roofline.model_flops not meaningful here; report terms only
    stats = RL.derive(ca, hlo, _FakeCfg(), _FakeShape(), n)
    mem = _mem_dict(compiled.memory_analysis())
    rec = {
        "arch": f"eejoin-extract-{args.scheme}",
        "shape": f"docs{D}x{args.doc_len}_E{args.entities}",
        "mesh": f"{n}workers", "chips": n, "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        "memory": mem,
        "device_live_bytes": (
            mem.get("argument_size_in_bytes", 0)
            + mem.get("temp_size_in_bytes", 0)
            + mem.get("output_size_in_bytes", 0)
            - mem.get("alias_size_in_bytes", 0)
        ),
        "roofline": stats.to_dict(),
        "tag": args.tag,
    }
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    name = f"eejoin-extract__{args.scheme}{('_' + args.tag) if args.tag else ''}.json"
    (OUT_DIR / name).write_text(json.dumps(rec, indent=1))
    r = rec["roofline"]
    print(f"eejoin-extract[{args.scheme}] {n}w: compute={r['compute_s']:.4f}s "
          f"memory={r['memory_s']:.4f}s collective={r['collective_s']:.4f}s "
          f"-> {r['bottleneck']}; live={rec['device_live_bytes']/1e9:.2f}GB")
    hh = r["hlo"]
    print("  collective bytes:", {k: f"{v/1e6:.1f}MB"
                                  for k, v in hh["collective_bytes"].items()})


@dataclasses.dataclass
class _FakeCfg:
    d_model: int = 0
    num_layers: int = 0
    padded_vocab: int = 0
    num_heads: int = 1
    num_kv_heads: int = 1
    d_ff: int = 0
    head_dim: int = 1
    act: str = "gelu"
    num_experts: int = 0
    top_k: int = 0
    encoder_layers: int = 0

    @property
    def resolved_head_dim(self):
        return 1


@dataclasses.dataclass
class _FakeShape:
    mode: str = "prefill"
    global_batch: int = 1
    seq_len: int = 1


if __name__ == "__main__":
    main()

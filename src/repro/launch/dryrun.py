import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST run before any other import (jax locks the
# device count on first init). Everything below is ordinary.
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.compat import set_mesh  # noqa: E402
from repro.configs.base import SHAPES  # noqa: E402
from repro.configs.registry import (  # noqa: E402
    ARCH_IDS, get_config, shape_applicable,
)
from repro.launch import roofline as RL  # noqa: E402
from repro.launch import tuning  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import build_cell  # noqa: E402

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _mem_dict(ma):
    if ma is None:
        return {}
    return {
        k: getattr(ma, k)
        for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        )
        if hasattr(ma, k)
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, tuned: bool) -> dict:
    """Lower + compile one cell on the production mesh; return the record."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    base_cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(base_cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": mesh.size, "tuned": tuned,
    }
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    cfg, knobs = tuning.resolve(base_cfg, shape, mesh, tuned)
    rec["knobs"] = {k: v for k, v in knobs.items()}

    t0 = time.time()
    cell = build_cell(cfg, shape, mesh, **knobs)
    with set_mesh(mesh):
        jitted = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums,
        )
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    roof = RL.derive(ca, hlo, cfg, shape, mesh.size)

    mem = _mem_dict(ma)
    live = (
        mem.get("argument_size_in_bytes", 0)
        + mem.get("temp_size_in_bytes", 0)
        + mem.get("output_size_in_bytes", 0)
        - mem.get("alias_size_in_bytes", 0)
    )
    rec.update(
        status="ok",
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        memory=mem,
        device_live_bytes=live,
        fits_16g=bool(live < 16e9),
        cost={k: ca.get(k) for k in ("flops", "bytes accessed") if k in ca},
        roofline=roof.to_dict(),
        static_info=cell.static_info,
    )
    return rec


def cell_path(arch, shape_name, multi_pod, tuned) -> pathlib.Path:
    tag = "multi" if multi_pod else "single"
    suff = "_tuned" if tuned else ""
    return OUT_DIR / f"{arch}__{shape_name}__{tag}{suff}.json"


def main() -> None:
    ap = argparse.ArgumentParser(description="40-cell multi-pod dry-run")
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--tuned", action="store_true",
                    help="apply §Perf hillclimb overrides from tuning.TUNED")
    ap.add_argument("--all", action="store_true",
                    help="orchestrate every cell as a subprocess")
    ap.add_argument("--both-meshes", action="store_true",
                    help="with --all: run single-pod AND multi-pod")
    ap.add_argument("--force", action="store_true", help="re-run cached cells")
    args = ap.parse_args()
    OUT_DIR.mkdir(parents=True, exist_ok=True)

    if args.all:
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        todo = []
        for arch in ARCH_IDS:
            for shape_name in SHAPES:
                for mp in meshes:
                    p = cell_path(arch, shape_name, mp, args.tuned)
                    if p.exists() and not args.force:
                        continue
                    todo.append((arch, shape_name, mp))
        print(f"[dryrun] {len(todo)} cells to run", flush=True)
        fails = []
        for i, (arch, shape_name, mp) in enumerate(todo):
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape_name]
            if mp:
                cmd.append("--multi-pod")
            if args.tuned:
                cmd.append("--tuned")
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True)
            dt = time.time() - t0
            tag = "multi" if mp else "single"
            if r.returncode != 0:
                fails.append((arch, shape_name, tag))
                print(f"[{i+1}/{len(todo)}] FAIL {arch} {shape_name} {tag} "
                      f"({dt:.0f}s)\n{r.stdout[-2000:]}\n{r.stderr[-2000:]}",
                      flush=True)
            else:
                print(f"[{i+1}/{len(todo)}] ok   {arch} {shape_name} {tag} "
                      f"({dt:.0f}s)", flush=True)
        print(f"[dryrun] done, {len(fails)} failures: {fails}", flush=True)
        sys.exit(1 if fails else 0)

    assert args.arch and args.shape, "--arch and --shape (or --all) required"
    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod, args.tuned)
    except Exception:
        rec = {
            "arch": args.arch, "shape": args.shape,
            "mesh": "2x16x16" if args.multi_pod else "16x16",
            "status": "error", "trace": traceback.format_exc(),
        }
        p = cell_path(args.arch, args.shape, args.multi_pod, args.tuned)
        p.write_text(json.dumps(rec, indent=1))
        print(rec["trace"], file=sys.stderr)
        sys.exit(1)

    p = cell_path(args.arch, args.shape, args.multi_pod, args.tuned)
    p.write_text(json.dumps(rec, indent=1))
    if rec["status"] == "ok":
        r = rec["roofline"]
        print(
            f"{args.arch} {args.shape} {rec['mesh']}: "
            f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
            f"collective={r['collective_s']:.4f}s -> {r['bottleneck']}-bound; "
            f"live={rec['device_live_bytes']/1e9:.2f}GB/dev "
            f"fits16G={rec['fits_16g']} "
            f"roofline_frac={r['roofline_fraction']:.3f}"
        )
    else:
        print(f"{args.arch} {args.shape}: {rec['status']} ({rec.get('reason','')})")


if __name__ == "__main__":
    main()

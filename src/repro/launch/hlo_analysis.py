"""Trip-count-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` counts every ``while`` body ONCE (verified:
a scan of 8 matmuls reports the flops of 1). Our models scan over layer
groups, microbatches, and attention chunks, so XLA's numbers understate
flops/bytes/collectives by the product of trip counts. This module walks
the HLO call graph instead:

* ``while`` bodies are weighted by ``backend_config.known_trip_count``
  (present on all scan-derived loops);
* ``fusion`` call sites contribute their *call-site* operand+result bytes
  (fusion internals live in registers/VMEM — the right HBM model) plus
  the exact dot/conv flops of the fused computation;
* collective operand bytes are accumulated per op kind with ring-model
  wire bytes;
* MXU flops (dot/conv, counted exactly from shapes) are separated from
  approximate VPU flops (1/elementwise output element, reduce inputs,
  n·log n for sorts) since they hit different roofs.

Shapes in the post-SPMD module are PER-DEVICE, so every number here is
per-device per-step.
"""
from __future__ import annotations

import dataclasses
import math
import re
from functools import reduce

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^{]*)?\{\s*$")
# result type: either a (tuple, of, shapes) — no nested parens occur in
# HLO types — or a single dtype[dims]{layout} token
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(\([^)]*\)|[a-z0-9]+\[[^\]]*\]\S*)\s+"
    r"([\w\-]+)\("
)
_ATTR_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_ATTR_BODY = re.compile(r"body=%?([\w.\-]+)")
_ATTR_TO_APPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_ATTR_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
}


def _elems(dims: str) -> int:
    if not dims:
        return 1
    return reduce(lambda a, b: a * b, (int(d) for d in dims.split(",")), 1)


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        total += _elems(dims) * _DTYPE_BYTES.get(dt, 0)
    return total


def _first_shape(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, ([int(d) for d in dims.split(",")] if dims else [])


def _operand_names(line: str, op_end: int) -> list[str]:
    """Operand %names inside the op's balanced paren group only."""
    depth = 1
    j = op_end
    while j < len(line) and depth:
        c = line[j]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
        j += 1
    return _OPERAND_RE.findall(line[op_end: j - 1])


@dataclasses.dataclass
class Stats:
    mxu_flops: float = 0.0
    vpu_flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict = dataclasses.field(default_factory=dict)
    coll_counts: dict = dataclasses.field(default_factory=dict)
    wire_bytes: float = 0.0
    unknown_trip_whiles: int = 0

    def add(self, other: "Stats", w: float = 1.0) -> None:
        self.mxu_flops += w * other.mxu_flops
        self.vpu_flops += w * other.vpu_flops
        self.bytes += w * other.bytes
        self.wire_bytes += w * other.wire_bytes
        self.unknown_trip_whiles += other.unknown_trip_whiles
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + w * v
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + w * v

    @property
    def coll_operand_bytes(self) -> float:
        return sum(self.coll_bytes.values())

    def to_dict(self):
        return {
            "mxu_flops": self.mxu_flops,
            "vpu_flops": self.vpu_flops,
            "bytes": self.bytes,
            "collective_bytes": dict(self.coll_bytes),
            "collective_counts": {k: int(v) for k, v in self.coll_counts.items()},
            "wire_bytes": self.wire_bytes,
            "unknown_trip_whiles": self.unknown_trip_whiles,
        }


class Module:
    """Parsed HLO module: computations + result-type table."""

    def __init__(self, text: str):
        self.comps: dict[str, list[str]] = {}
        self.shapes: dict[str, str] = {}  # instr name -> result type str
        self.roots: dict[str, str] = {}  # comp name -> ROOT line
        self.entry: str | None = None
        cur: list[str] | None = None
        cur_name = None
        for line in text.splitlines():
            if cur is None:
                if "{" in line and ("->" in line or line.startswith("ENTRY")):
                    m = _COMP_HDR_RE.match(line.strip())
                    if m:
                        cur_name = m.group(1)
                        cur = []
                        if line.lstrip().startswith("ENTRY"):
                            self.entry = cur_name
                continue
            if line.strip() == "}":
                self.comps[cur_name] = cur
                cur = None
                continue
            cur.append(line)
            if line.lstrip().startswith("ROOT "):
                self.roots[cur_name] = line
            im = _INSTR_RE.match(line)
            if im:
                self.shapes[im.group(1)] = im.group(2)

    def operand_shape(self, name: str):
        t = self.shapes.get(name)
        return _first_shape(t) if t else None

    def root_op(self, comp: str):
        """(op, operand names) of a computation's ROOT, or (None, [])."""
        line = self.roots.get(comp)
        if not line:
            return None, []
        im = _INSTR_RE.match(line)
        if not im:
            return None, []
        return im.group(3), _operand_names(line, im.end())


def _dot_flops(mod: Module, line: str, result_type: str, op_end: int) -> float:
    out = _first_shape(result_type)
    if not out:
        return 0.0
    out_elems = reduce(lambda a, b: a * b, out[1], 1)
    cm = _LHS_CONTRACT.search(line)
    ops = _operand_names(line, op_end)
    contract = 1
    if cm and ops:
        lhs = mod.operand_shape(ops[0])
        if lhs:
            for idx in (int(i) for i in cm.group(1).split(",") if i != ""):
                if idx < len(lhs[1]):
                    contract *= lhs[1][idx]
    return 2.0 * out_elems * contract


def _conv_flops(mod: Module, line: str, result_type: str, op_end: int) -> float:
    out = _first_shape(result_type)
    if not out:
        return 0.0
    out_elems = reduce(lambda a, b: a * b, out[1], 1)
    ops = _operand_names(line, op_end)
    if len(ops) >= 2:
        ker = mod.operand_shape(ops[1])
        if ker:
            ker_elems = reduce(lambda a, b: a * b, ker[1], 1)
            out_feat = max(out[1][-1] if out[1] else 1, 1)
            return 2.0 * out_elems * ker_elems / out_feat
    return 2.0 * out_elems


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


def _collective(stats: Stats, base: str, line: str, result_type: str) -> None:
    """Ring-model accounting: operand bytes + wire bytes per device."""
    res = _type_bytes(result_type)
    n = max(_group_size(line), 1)
    if base == "all-gather":
        operand, w = res / n, res * (n - 1) / n
    elif base == "reduce-scatter":
        operand, w = res * n, res * (n - 1)
    elif base == "all-reduce":
        operand, w = res, 2 * res * (n - 1) / n
    elif base == "all-to-all":
        operand, w = res, res * (n - 1) / n
    else:  # collective-permute
        operand, w = res, res
    stats.coll_bytes[base] = stats.coll_bytes.get(base, 0.0) + operand
    stats.coll_counts[base] = stats.coll_counts.get(base, 0) + 1
    stats.wire_bytes += w


def analyze(text: str) -> Stats:
    mod = Module(text)
    memo: dict[str, Stats] = {}

    def comp_stats(name: str) -> Stats:
        if name in memo:
            return memo[name]
        memo[name] = Stats()  # cycle guard
        s = Stats()
        for line in mod.comps.get(name, ()):
            im = _INSTR_RE.match(line)
            if not im:
                continue
            _, rtype, op = im.groups()
            op_end = im.end()
            base = op
            for suf in ("-start", "-done", "-update"):
                if base.endswith(suf):
                    base = base[: -len(suf)]
            if op.endswith("-done") or op.endswith("-update"):
                continue
            if base in COLLECTIVES:
                _collective(s, base, line, rtype)
                continue
            if op == "while":
                bm = _ATTR_BODY.search(line)
                tm = _TRIP_RE.search(line)
                trip = int(tm.group(1)) if tm else 1
                if not tm:
                    s.unknown_trip_whiles += 1
                if bm:
                    s.add(comp_stats(bm.group(1)), trip)
                continue
            if op == "fusion":
                cm = _ATTR_CALLS.search(line)
                root_op, root_ops = (None, [])
                if cm:
                    inner = comp_stats(cm.group(1))
                    s.mxu_flops += inner.mxu_flops
                    s.vpu_flops += inner.vpu_flops
                    root_op, root_ops = mod.root_op(cm.group(1))
                shp = _SHAPE_RE.search(rtype)
                if shp:
                    s.vpu_flops += _elems(shp.group(2))
                opnds = _operand_names(line, op_end)
                opnd_bytes = [_type_bytes(mod.shapes.get(o, "")) for o in opnds]
                rbytes = _type_bytes(rtype)
                if root_op == "dynamic-update-slice" and len(root_ops) > 1:
                    # in-place scan-carry write: traffic = slice, not buffer.
                    # Drop the aliased operand (type == result) and replace
                    # the result write with 2× the update slice (r+w).
                    upd = _type_bytes(mod.shapes.get(root_ops[1], ""))
                    for i, b in enumerate(opnd_bytes):
                        if b == rbytes:
                            opnd_bytes[i] = 0
                            break
                    s.bytes += sum(opnd_bytes) + 2 * upd
                elif root_op == "dynamic-slice" and opnd_bytes:
                    # slice read from a big (stacked) buffer: traffic =
                    # slice out + slice in, not the whole source buffer.
                    big = max(range(len(opnd_bytes)), key=lambda i: opnd_bytes[i])
                    opnd_bytes[big] = rbytes
                    s.bytes += sum(opnd_bytes) + rbytes
                else:
                    s.bytes += rbytes + sum(opnd_bytes)
                continue
            if op == "call":
                cm = _ATTR_TO_APPLY.search(line) or _ATTR_CALLS.search(line)
                if cm:
                    s.add(comp_stats(cm.group(1)))
                continue
            if op == "conditional":
                bm = _ATTR_BRANCHES.search(line)
                if bm:
                    branches = _OPERAND_RE.findall(bm.group(1))
                    best = None
                    for b in branches:
                        st = comp_stats(b)
                        if best is None or st.mxu_flops > best.mxu_flops:
                            best = st
                    if best is not None:
                        s.add(best)
                continue
            if op == "dot":
                s.mxu_flops += _dot_flops(mod, line, rtype, op_end)
            elif op == "convolution":
                s.mxu_flops += _conv_flops(mod, line, rtype, op_end)
            elif op == "sort":
                shp = _first_shape(rtype)
                if shp:
                    n = reduce(lambda a, b: a * b, shp[1], 1)
                    s.vpu_flops += n * max(math.log2(max(n, 2)), 1.0)
            elif op not in _SKIP_BYTES:
                shp = _SHAPE_RE.search(rtype)
                if shp:
                    s.vpu_flops += _elems(shp.group(2))
            # ---- bytes: result + operands, with slice-accurate traffic
            if op == "dynamic-update-slice":
                opnds = _operand_names(line, op_end)
                upd = _type_bytes(mod.shapes.get(opnds[1], "")) if len(opnds) > 1 else 0
                s.bytes += 2 * upd
            elif op in ("dynamic-slice", "gather"):
                s.bytes += 2 * _type_bytes(rtype)
            elif op == "scatter":
                opnds = _operand_names(line, op_end)
                upd = _type_bytes(mod.shapes.get(opnds[2], "")) if len(opnds) > 2 else 0
                s.bytes += 3 * upd
            elif op not in _SKIP_BYTES:
                opnds = _operand_names(line, op_end)
                s.bytes += _type_bytes(rtype) + sum(
                    _type_bytes(mod.shapes.get(o, "")) for o in opnds
                )
        memo[name] = s
        return s

    assert mod.entry, "no ENTRY computation found"
    return comp_stats(mod.entry)

"""Distributed-extraction self-test: runs on N fake CPU devices.

Executed as a subprocess by tests/test_distributed.py (the device-count
flag must be set before jax initialises, so this cannot run inside the
main pytest process):

    python -m repro.launch.selftest_distributed [n_devices]

Prints one JSON line with pass/fail per check.
"""
import os
import sys

N_DEV = int(sys.argv[1]) if len(sys.argv) > 1 else 8
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N_DEV} "
    + os.environ.get("XLA_FLAGS", "")
)

import json  # noqa: E402

import numpy as np  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.cost_model import CostParams  # noqa: E402
from repro.core.eejoin import EEJoinConfig, EEJoinOperator  # noqa: E402
from repro.core.plan import Plan, PlanSide  # noqa: E402
from repro.core.cost_model import OBJ_JOB  # noqa: E402
from repro.data.synth import make_corpus  # noqa: E402
from repro.extraction.oracle import oracle_extract  # noqa: E402


def forced_plan(E: int, split: int, head: PlanSide, tail: PlanSide) -> Plan:
    from repro.core.cost_model import SideCost

    z = SideCost(0, 0, 0, 0, 0, 0, 0, 0, 0)
    return Plan(split, head, tail, OBJ_JOB, 0.0, z, z, 0)


def main() -> None:
    gamma = 0.8
    checks: dict[str, bool | float] = {"n_devices": len(jax.devices())}
    c = make_corpus(
        num_docs=16, doc_len=64, vocab_size=512, num_entities=32, seed=7
    )
    docs = jnp.asarray(c.doc_tokens)
    mesh = jax.make_mesh((N_DEV,), ("workers",))
    axes = ("workers",)
    E = c.dictionary.num_entities

    truth_extra = oracle_extract(c.doc_tokens, c.dictionary, gamma, "extra")
    truth_var = oracle_extract(c.doc_tokens, c.dictionary, gamma, "variant_exact")

    op = EEJoinOperator(
        c.dictionary,
        EEJoinConfig(gamma=gamma, max_candidates=2048, result_capacity=8192),
    )

    # 1) pure distributed index plan == oracle
    plan = forced_plan(E, E, PlanSide("index", "prefix"), PlanSide("index", "prefix"))
    prepared = op.prepare_distributed(plan, N_DEV, CostParams(num_devices=N_DEV))
    with mesh:
        ms, _ = op.execute_distributed(prepared, docs, mesh, axes)
    got = set().union(*[m.to_set() for m in ms])
    checks["index_prefix_exact"] = got == truth_extra

    # 2) pure distributed ssjoin (prefix sigs) == oracle
    plan = forced_plan(E, 0, PlanSide("index", "prefix"), PlanSide("ssjoin", "prefix"))
    prepared = op.prepare_distributed(plan, N_DEV, CostParams(num_devices=N_DEV))
    with mesh:
        ms, diags = op.execute_distributed(prepared, docs, mesh, axes)
    got = set().union(*[m.to_set() for m in ms])
    checks["ssjoin_prefix_exact"] = got == truth_extra
    d = diags[0]
    checks["shuffle_bytes_positive"] = int(d.bytes_shuffled) > 0
    checks["no_send_overflow"] = int(d.send_overflow) == 0
    checks["skew_measured"] = float(d.max_received) >= float(d.mean_received)

    # 3) distributed ssjoin variant == variant oracle
    plan = forced_plan(E, 0, PlanSide("index", "prefix"), PlanSide("ssjoin", "variant"))
    prepared = op.prepare_distributed(plan, N_DEV, CostParams(num_devices=N_DEV))
    with mesh:
        ms, _ = op.execute_distributed(prepared, docs, mesh, axes)
    got = set().union(*[m.to_set() for m in ms])
    checks["ssjoin_variant_exact"] = got == truth_var

    # 4) hybrid plan: head index:variant + tail ssjoin:prefix
    split = E // 2
    plan = forced_plan(E, split, PlanSide("index", "variant"), PlanSide("ssjoin", "prefix"))
    prepared = op.prepare_distributed(plan, N_DEV, CostParams(num_devices=N_DEV))
    with mesh:
        ms, _ = op.execute_distributed(prepared, docs, mesh, axes)
    got = set().union(*[m.to_set() for m in ms])
    want = {t for t in truth_var if t[3] < split} | {
        t for t in truth_extra if t[3] >= split
    }
    checks["hybrid_exact"] = got == want

    # 5) sharded streaming driver (kernel path, 1-doc shards -> 16
    # shards over 8 devices = 2 waves, exercising the wave queue) ==
    # unsharded fused execute
    opk = EEJoinOperator(
        c.dictionary,
        EEJoinConfig(
            gamma=gamma, max_candidates=2048, result_capacity=8192, use_kernel=True
        ),
    )
    plan = forced_plan(E, 0, PlanSide("index", "prefix"), PlanSide("ssjoin", "prefix"))
    prepared = opk.prepare(plan, CostParams(num_devices=N_DEV))
    want = opk.execute(prepared, docs).to_set()
    with mesh:
        got = opk.execute_sharded(
            prepared, docs, mesh=mesh, shard_docs=1, tile_docs=1
        ).to_set()
    checks["sharded_driver_exact"] = got == want

    # 6) distributed token histogram == numpy histogram
    from repro.extraction.distributed import distributed_token_histogram

    with mesh:
        h = distributed_token_histogram(mesh, axes, docs, c.dictionary.vocab_size)
    hn = np.bincount(c.doc_tokens.reshape(-1), minlength=c.dictionary.vocab_size)
    checks["histogram_exact"] = bool((np.asarray(h) == hn).all())

    checks["ok"] = all(v for k, v in checks.items() if isinstance(v, bool))
    print(json.dumps(checks))


if __name__ == "__main__":
    main()

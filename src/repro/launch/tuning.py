"""Per-(arch × shape × mesh) execution knobs.

Two layers:

* ``default_knobs`` — BASELINE memory-fit levers (microbatch count chosen
  so the remat activation stash fits HBM, KV-split count matching the
  model axis). These are *feasibility* settings, not perf hillclimbs; the
  paper-faithful baseline uses them as-is.
* ``TUNED`` — §Perf hillclimb overrides, applied only with ``--tuned``.
  Every entry corresponds to one hypothesis→change→measure row in
  EXPERIMENTS.md §Perf. ``cfg`` keys are ``dataclasses.replace``d into
  the ModelConfig; the rest feed ``build_cell``.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ShapeConfig

# activation-stash budget for the scan carry checkpoint per device
_STASH_BUDGET = 4e9


def default_microbatches(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    """Smallest power-of-two microbatch count whose remat stash
    (G groups × per-device microbatch tokens × d_model × 2B) fits.

    (A large-vocab logits term lived here briefly — §Perf iteration #7 —
    but the fused chunked CE loss (#9) removed the [mb,S,V] peak
    entirely, and fewer microbatches mean fewer FSDP re-gathers.)"""
    if shape.mode != "train":
        return 1
    dp = mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)
    per_dev_batch = max(shape.global_batch // dp, 1)
    stash = (
        cfg.num_groups * per_dev_batch * shape.seq_len * cfg.d_model * 2.0
    )
    micro = 1
    while stash / micro > _STASH_BUDGET and micro < per_dev_batch:
        micro *= 2
    return micro


# Cell-specific overrides that are part of the OPTIMIZED build's
# defaults (each is one §Perf iteration; the formula alone can't see
# XLA's f32 residual stacking or flash workspace):
#   whisper train: remat residuals stack in f32 ([G,mb,S,d] — §Perf #10);
#     halving the microbatch tokens halves the dominant live buffer.
#   dbrx train: 0.4 GB over budget at the microbatch cap; smaller flash
#     chunks shrink the attention workspace.
_DEFAULT_OVERRIDES: dict[tuple[str, str], dict] = {
    ("whisper-large-v3", "train_4k"): {"microbatches": 8},
    ("dbrx-132b", "train_4k"): {"fp32_master": False},
    # the stash formula can't see per-microbatch f32 residual internals:
    # rglru's associative_scan saves log-depth stage tensors; llama's
    # cross+self attention saves stack in f32 (§Perf #10) — both scale
    # with microbatch tokens, so give these cells more microbatches.
    ("recurrentgemma-9b", "train_4k"): {"microbatches": 8},
    ("llama-3.2-vision-11b", "train_4k"): {"microbatches": 8},
}


def default_knobs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    knobs = {"microbatches": default_microbatches(cfg, shape, mesh)}
    knobs.update(_DEFAULT_OVERRIDES.get((cfg.name, shape.name), {}))
    return knobs


# ---------------------------------------------------------------------------
# §Perf hillclimb overrides — see EXPERIMENTS.md §Perf for the
# hypothesis → change → before/after log behind every entry.
# key: (arch, shape_name)
# ---------------------------------------------------------------------------
TUNED: dict[tuple[str, str], dict] = {}


def resolve(cfg: ModelConfig, shape: ShapeConfig, mesh, tuned: bool):
    """-> (possibly-replaced cfg, build_cell kwargs)."""
    knobs = default_knobs(cfg, shape, mesh)
    cfg_ov = knobs.pop("cfg", None)
    if cfg_ov:
        cfg = dataclasses.replace(cfg, **cfg_ov)
    if tuned:
        ov = dict(TUNED.get((cfg.name, shape.name), {}))
        cfg_ov = ov.pop("cfg", None)
        if cfg_ov:
            cfg = dataclasses.replace(cfg, **cfg_ov)
        knobs.update(ov)
    return cfg, knobs

"""Abstract input specs + shardings for every (arch × shape) dry-run cell.

Everything here is ``jax.ShapeDtypeStruct`` — no device allocation ever
happens (the full configs are exercised ONLY via lower/compile). The same
builders drive the real launchers with concrete arrays.

Cell kinds (configs/base.SHAPES):
  train_4k    -> ``train_step(params, opt_state, batch)``
  prefill_32k -> ``prefill(params, tokens[, context])``
  decode_32k  -> ``serve_step(params, cache, tokens)`` with a seq_len cache
  long_500k   -> same as decode, batch=1, sub-quadratic archs only
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as T
from repro.models.model import LM, build_model
from repro.models.sharding import ShardingRules
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.trainer import make_train_step


def tree_shardings(mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def abstract_params(model: LM):
    """(param ShapeDtypeStructs, PartitionSpec tree) with zero allocation."""
    captured: list = []

    def init_only(k):
        p, s = model.init(k)
        captured.append(s)
        return p

    p_shapes = jax.eval_shape(init_only, jax.random.PRNGKey(0))
    return p_shapes, captured[0]


def opt_specs(param_specs, fp32_master: bool = True):
    s = {
        "m": param_specs,
        "v": param_specs,
        "step": P(),
    }
    if fp32_master:
        s["master"] = param_specs
    return s


def _batch_axes(rules: ShardingRules, B: int):
    """Resolved mesh axes for the global-batch dim (with divisibility
    fallback, e.g. long_500k's batch=1 -> replicated)."""
    return rules.resolve("batch", B)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, rules: ShardingRules):
    """Training/prefill batch ShapeDtypeStructs + PartitionSpec tree."""
    B, S = shape.global_batch, shape.seq_len
    ba = _batch_axes(rules, B)
    shapes: dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    specs: dict[str, Any] = {
        "tokens": P(ba, None),
        "labels": P(ba, None),
    }
    if cfg.context_len:
        shapes["context"] = jax.ShapeDtypeStruct(
            (B, cfg.context_len, cfg.context_dim or cfg.d_model),
            jnp.dtype(cfg.dtype),
        )
        specs["context"] = P(ba, None, None)
    return shapes, specs


def cache_abstract(model: LM, batch: int, max_len: int, kv_splits: int):
    """Abstract KV/state cache for decode cells (ShapeDtypeStructs)."""
    cfg = model.cfg
    if cfg.context_len:
        ctx = jax.ShapeDtypeStruct(
            (batch, cfg.context_len, cfg.context_dim or cfg.d_model),
            jnp.dtype(cfg.dtype),
        )
        return jax.eval_shape(
            lambda p, c: model.init_cache(p, batch, max_len, kv_splits, context=c),
            model_abstract_params_cached(model), ctx,
        )
    return jax.eval_shape(
        lambda p: model.init_cache(p, batch, max_len, kv_splits),
        model_abstract_params_cached(model),
    )


_ABSTRACT_CACHE: dict[int, tuple] = {}


def model_abstract_params_cached(model: LM):
    key = id(model)
    if key not in _ABSTRACT_CACHE:
        _ABSTRACT_CACHE[key] = abstract_params(model)
    return _ABSTRACT_CACHE[key][0]


def model_abstract_specs_cached(model: LM):
    key = id(model)
    if key not in _ABSTRACT_CACHE:
        _ABSTRACT_CACHE[key] = abstract_params(model)
    return _ABSTRACT_CACHE[key][1]


def cache_spec_tree(model: LM, cache_shapes, rules: ShardingRules):
    """PartitionSpec tree matching ``init_cache``'s pytree.

    ``layers`` leaves carry a leading G (group-stack) dim -> prepend None
    to the per-block spec; ``tail`` blocks are unstacked.
    """
    cfg = model.cfg

    def block_specs(kind: str, shapes_dict, stacked: bool):
        if stacked:
            stripped = {
                k: jax.ShapeDtypeStruct(v.shape[1:], v.dtype)
                for k, v in shapes_dict.items()
            }
        else:
            stripped = shapes_dict
        sp = T.cache_specs(cfg, kind, rules, stripped)
        if stacked:
            sp = {k: P(*((None,) + tuple(v))) for k, v in sp.items()}
        return sp

    layers = {}
    for i, kind in enumerate(cfg.block_pattern):
        layers[f"b{i}"] = block_specs(kind, cache_shapes["layers"][f"b{i}"], True)
    tail = [
        block_specs(kind, cache_shapes["tail"][i], False)
        for i, kind in enumerate(cfg.extra_tail_blocks)
    ]
    return {"layers": layers, "tail": tail, "pos": P()}


@dataclasses.dataclass
class Cell:
    """Everything needed to lower one (arch × shape × mesh) dry-run cell."""

    arch: str
    shape: ShapeConfig
    fn: Any  # callable to jit
    args: tuple  # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple
    static_info: dict


# params (bf16) + optimizer state (3x f32) per model-shard below this
# threshold -> skip FSDP entirely (params replicated over `data`): the
# whole state fits, and per-layer-per-microbatch weight all-gathers
# disappear (§Perf hillclimb #3, second attempt — measured win on the
# small archs; dbrx-class models keep FSDP because they must).
_FSDP_FREE_BYTES = 2e9


def _model_unshardable_state(cfg: ModelConfig, tp: int) -> float:
    """Param+opt bytes that stay REPLICATED under model-only sharding
    (attention weights whose head dims don't divide the TP axis — for
    those, the `embed` dim is the only shardable one, so dropping FSDP
    replicates their full fp32 optimizer state on every device; this is
    what blew whisper's argument bytes to 9.7 GB, §Perf log)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KH = cfg.num_heads, cfg.num_kv_heads
    per_layer = 0.0
    if tp > 1 and H % tp:
        per_layer += 2.0 * d * H * hd  # wq + wo
    if tp > 1 and KH % tp:
        per_layer += 2.0 * d * KH * hd  # wk + wv
    layers = cfg.num_layers + cfg.encoder_layers
    return per_layer * layers * 14.0


def default_rules(cfg: ModelConfig, mesh) -> ShardingRules:
    from repro.launch import roofline as RL

    n_model = mesh.shape.get("model", 1)
    state_bytes = RL.total_params(cfg) * 14.0 / max(n_model, 1)
    if (
        state_bytes <= _FSDP_FREE_BYTES
        and _model_unshardable_state(cfg, n_model) <= _FSDP_FREE_BYTES / 4
    ):
        return ShardingRules(mesh, rules={"embed": (None,)})
    return ShardingRules(mesh)


def build_cell(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh,
    *,
    microbatches: int = 1,
    kv_splits: int = 0,
    fp32_master: bool = True,
    rules: ShardingRules | None = None,
) -> Cell:
    """Assemble the jit-able (fn, abstract args, shardings) for one cell."""
    rules = rules or default_rules(cfg, mesh)
    model = build_model(cfg, rules)
    p_shapes = model_abstract_params_cached(model)
    p_specs = model_abstract_specs_cached(model)
    param_sh = tree_shardings(mesh, p_specs)
    scalar_sh = NamedSharding(mesh, P())

    if shape.mode == "train":
        o_shapes = jax.eval_shape(
            lambda p: init_opt_state(p, fp32_master), p_shapes
        )
        opt_sh = tree_shardings(mesh, opt_specs(p_specs, fp32_master))
        b_shapes, b_specs = batch_specs(cfg, shape, rules)
        batch_sh = tree_shardings(mesh, b_specs)
        step = make_train_step(
            model, AdamWConfig(fp32_master=fp32_master), microbatches
        )
        return Cell(
            arch=cfg.name,
            shape=shape,
            fn=step,
            args=(p_shapes, o_shapes, b_shapes),
            in_shardings=(param_sh, opt_sh, batch_sh),
            out_shardings=(param_sh, opt_sh, scalar_sh),
            donate_argnums=(0, 1),
            static_info={"microbatches": microbatches,
                         "fp32_master": fp32_master,
                         "fallbacks": list(rules.fallbacks)},
        )

    if shape.mode == "prefill":
        b_shapes, b_specs = batch_specs(cfg, shape, rules)
        batch_sh = tree_shardings(mesh, b_specs)
        ba = _batch_axes(rules, shape.global_batch)
        out_sh = NamedSharding(mesh, P(ba, rules.resolve("vocab", cfg.padded_vocab)))
        if cfg.context_len:
            fn = lambda p, t, c: model.prefill(p, t, context=c)  # noqa: E731
            args = (p_shapes, b_shapes["tokens"], b_shapes["context"])
            in_sh = (param_sh, batch_sh["tokens"], batch_sh["context"])
        else:
            fn = lambda p, t: model.prefill(p, t)  # noqa: E731
            args = (p_shapes, b_shapes["tokens"])
            in_sh = (param_sh, batch_sh["tokens"])
        return Cell(
            arch=cfg.name, shape=shape, fn=fn, args=args,
            in_shardings=in_sh, out_shardings=out_sh, donate_argnums=(),
            static_info={"fallbacks": list(rules.fallbacks)},
        )

    # ---- decode (decode_32k / long_500k): one new token vs seq_len cache
    B, S = shape.global_batch, shape.seq_len
    if not kv_splits:
        # split-KV decode shards the cache over `model` ONLY when the KV
        # heads can't (a spec may use each mesh axis at most once)
        m = mesh.shape.get("model", 1)
        if cfg.num_kv_heads % m == 0:
            kv_splits = 1
        else:
            kv_splits = m if S % m == 0 else 1
    model_d = model
    c_shapes = cache_abstract(model_d, B, S, kv_splits)
    c_specs = cache_spec_tree(model_d, c_shapes, rules)
    cache_sh = tree_shardings(mesh, c_specs)
    ba = _batch_axes(rules, B)
    tok = jax.ShapeDtypeStruct((B,), jnp.int32)
    tok_sh = NamedSharding(mesh, P(ba))
    logits_sh = NamedSharding(mesh, P(ba, rules.resolve("vocab", cfg.padded_vocab)))

    def serve_step(p, cache, t):
        return model_d.decode_step(p, cache, t)

    return Cell(
        arch=cfg.name, shape=shape, fn=serve_step,
        args=(p_shapes, c_shapes, tok),
        in_shardings=(param_sh, cache_sh, tok_sh),
        out_shardings=(logits_sh, cache_sh),
        donate_argnums=(1,),
        static_info={"kv_splits": kv_splits,
                     "fallbacks": list(rules.fallbacks)},
    )

"""Production mesh definitions (deliverable e).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state — the dry-run sets
the fake-device flag before any jax initialisation.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh across versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist on newer jax."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_cpu_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests/examples."""
    return _make_mesh((data, model), ("data", "model"))


def make_extraction_mesh(n_workers: int | None = None, axis: str = "workers"):
    """Flat 1-axis worker pool for the EE-Join extraction job.

    This is the device pool the sharded streaming driver
    (``extraction/sharded.py``) maps document shards onto: one shard per
    worker per wave, extra shards queueing into later waves. ``axis``
    must match the driver's ``axis_name`` (default ``"workers"``).
    """
    n = n_workers or len(jax.devices())
    return _make_mesh((n,), (axis,))

"""Serving launcher: batched KV-cache decode with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --requests 12

Uses the reduced config on CPU; the production decode path is the same
``decode_step`` the dry-run lowers for decode_32k/long_500k cells.
Optionally annotates generated text with EE-Join entity mentions
(--annotate), demonstrating the operator as a serve-time output stage.
"""
from __future__ import annotations

import argparse

import numpy as np

import jax

from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.core.eejoin import EEJoinConfig, EEJoinOperator
from repro.data.synth import make_corpus
from repro.launch.mesh import make_cpu_mesh
from repro.models.model import build_model
from repro.models.sharding import ShardingRules
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="olmo-1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--annotate", action="store_true")
    args = ap.parse_args()

    mesh = make_cpu_mesh(1, 1)
    cfg = get_smoke_config(args.arch)
    rules = ShardingRules(mesh)
    model = build_model(cfg, rules)
    params, _ = model.init(jax.random.PRNGKey(0))

    eng = ServeEngine(
        model, params, batch_slots=args.slots, max_len=args.max_len,
        temperature=args.temperature,
    )
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size, size=rng.integers(4, 12)).tolist()
        r = Request(prompt=prompt, max_new_tokens=args.max_new)
        reqs.append(r)
        eng.submit(r)
    eng.run()
    done = sum(r.done for r in reqs)
    print(f"[serve] completed {done}/{len(reqs)} requests "
          f"(slots={args.slots}, cache pos={int(eng.cache['pos'])})")

    if args.annotate:
        corpus = make_corpus(num_docs=4, doc_len=64,
                             vocab_size=cfg.vocab_size, num_entities=32, seed=1)
        op = EEJoinOperator(corpus.dictionary, EEJoinConfig(gamma=0.8))
        plan = op.choose_plan(
            op.gather_statistics(corpus.doc_tokens, total_docs=4)
        )
        prepared = op.prepare(plan)
        outs = np.zeros((len(reqs), args.max_new), np.int32)
        for i, r in enumerate(reqs):
            toks = (r.prompt + r.out)[: args.max_new]
            outs[i, : len(toks)] = toks
        m = op.execute(prepared, outs)
        n = int((np.asarray(m.doc) >= 0).sum())
        print(f"[serve] EE-Join annotation: {n} entity mentions "
              f"in {len(reqs)} generations")
    for r in reqs[:3]:
        print(f"[serve] prompt={r.prompt[:6]}... -> out={r.out[:8]}...")


if __name__ == "__main__":
    main()

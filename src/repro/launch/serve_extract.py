"""Online extraction serving entrypoint (the serving-subsystem demo).

    PYTHONPATH=src python -m repro.launch.serve_extract \
        --requests 32 --rate 200 --overlap --check

Builds a synthetic dictionary + request pool, creates a cached serving
session (statistics → cost-based plan choice, optionally calibrated to
this host), and drives the two-stage probe/verify service with a seeded
open-loop load generator in *real time* (arrivals realised with
``time.sleep``; the serving benches use a virtual clock instead — see
``benchmarks/bench_serving.py``). Prints the metrics summary and, with
``--check``, asserts bit-parity of the served matches against a
one-shot ``eejoin.execute`` over the same documents (exit 1 on drift).
"""
from __future__ import annotations

import argparse
import sys
import threading
import time

import numpy as np

from repro.core.eejoin import EEJoinConfig
from repro.data.synth import make_corpus
from repro.serving import (
    BatcherConfig,
    ExtractionService,
    ReplanConfig,
    SessionCache,
    make_pools,
    one_shot_reference,
    realized_gain,
    session_cache_summary,
)
from repro.serving.session import pure_plan


def build_request_pool(args):
    """Seeded variable-length documents cut from a synthetic corpus."""
    corpus = make_corpus(
        num_docs=max(args.requests, 8),
        doc_len=args.doc_len,
        vocab_size=2048,
        num_entities=args.entities,
        seed=args.seed,
    )
    rng = np.random.default_rng(args.seed + 1)
    lens = rng.integers(args.doc_len // 4, args.doc_len + 1, size=args.requests)
    docs = [corpus.doc_tokens[i % corpus.doc_tokens.shape[0], : lens[i]]
            for i in range(args.requests)]
    return corpus, docs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="open-loop arrival rate (docs/s, Poisson)")
    ap.add_argument("--doc-len", type=int, default=96)
    ap.add_argument("--entities", type=int, default=32)
    ap.add_argument("--batch-docs", type=int, default=8)
    ap.add_argument("--max-delay-ms", type=float, default=20.0)
    ap.add_argument("--queue-capacity", type=int, default=256)
    ap.add_argument("--scheme", default="prefix",
                    choices=("word", "prefix", "lsh", "variant"))
    ap.add_argument("--plan", default="auto", choices=("auto", "forced"),
                    help="auto: stats + §5 plan search; forced: pure "
                         "ssjoin:<scheme>")
    ap.add_argument("--calibrate", action="store_true",
                    help="rescale cost constants to this host before the "
                         "plan search (implies --plan auto)")
    ap.add_argument("--overlap", dest="overlap", action="store_true",
                    default=True)
    ap.add_argument("--no-overlap", dest="overlap", action="store_false")
    ap.add_argument("--check", action="store_true",
                    help="assert parity vs one-shot eejoin.execute")
    ap.add_argument("--replan", action="store_true",
                    help="continuous calibration: background replanner "
                         "thread (drift-triggered §5 re-search + epoch "
                         "plan swap)")
    ap.add_argument("--drift-bound", type=float, default=0.3,
                    help="relative survivor/doc-length drift that "
                         "triggers a replan (with --replan)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    corpus, docs = build_request_pool(args)
    cfg = EEJoinConfig(
        gamma=0.8, max_candidates=8192, result_capacity=16384, use_kernel=True
    )
    cache = SessionCache()
    if args.plan == "forced" and not args.calibrate:
        sess = cache.get_or_create(corpus.dictionary, cfg,
                                   plan=pure_plan(args.scheme))
    else:
        sess = cache.get_or_create(
            corpus.dictionary, cfg,
            sample_docs=corpus.doc_tokens[:8],
            calibrate=args.calibrate,
            default_scheme=args.scheme,
        )
    pools = make_pools()
    print(f"[serve_extract] session {sess.key} "
          f"plan: {sess.plan.describe(corpus.dictionary.num_entities)}"
          f"{' (calibrated)' if sess.calibrated else ''}")
    print(f"[serve_extract] pools: {pools.describe()}; "
          f"overlap={'on' if args.overlap else 'off'}")

    replan = None
    if args.replan:
        replan = ReplanConfig(
            density_drift=args.drift_bound,
            doc_len_drift=args.drift_bound,
            thread=True,
        )
        print(f"[serve_extract] replan: on (drift bound "
              f"{args.drift_bound:.2f}, background thread)")
    svc = ExtractionService(
        cache,
        pools=pools,
        batcher_config=BatcherConfig(
            max_batch_docs=args.batch_docs,
            max_delay_s=args.max_delay_ms / 1e3,
        ),
        queue_capacity=args.queue_capacity,
        overlap=args.overlap,
        replan=replan,
    )

    rng = np.random.default_rng(args.seed + 2)
    gaps = rng.exponential(1.0 / max(args.rate, 1e-9), size=len(docs))

    def loadgen():
        # block=True: backpressure instead of shedding, so every doc is
        # served and the --check reference covers the full request set
        for i, d in enumerate(docs):
            time.sleep(gaps[i])
            svc.submit(i, d, sess.key, block=True)
            svc.tick()

    with svc:
        t = threading.Thread(target=loadgen)
        t.start()
        t.join()
        svc.drain()

    s = svc.metrics.summary()
    print(f"[serve_extract] {s['completed']}/{s['submitted']} requests in "
          f"{s['batches']} batches (rejected {s['rejected']}, occupancy "
          f"{s['occupancy_mean']:.2f}, depth max {s['queue_depth_max']})")
    print(f"[serve_extract] latency p50/p95/p99 = {s['latency_p50_s']:.4f}/"
          f"{s['latency_p95_s']:.4f}/{s['latency_p99_s']:.4f} s; "
          f"{s['docs_per_s']:.1f} docs/s, {s['lanes_per_s']:.1f} lanes/s")
    print(f"[serve_extract] streaming: {s['streamed_launches']} streamed "
          f"launches, {s['tiles_streamed']} tiles streamed, "
          f"{s['dma_waits']} DMA waits, {s['checkpoint_writes']} checkpoint "
          f"writes (sizing {s['lane_sizing'] or '{}'})")
    if args.replan:
        events = s["replan_events"]
        print(f"[serve_extract] replan: {s['replans']} trigger(s), "
              f"{s['replan_swaps']} swap(s)")
        for e in events:
            line = (f"[serve_extract]   [{e['reason']}] "
                    f"{e['old_plan']} -> {e.get('new_plan', '(kept)')}")
            if "predicted_gain" in e:
                line += f", predicted gain {e['predicted_gain']:+.1%}"
            rg = realized_gain(svc.metrics, e)
            if np.isfinite(rg):
                line += f", realized {rg:+.1%}"
            print(line)
    cs = session_cache_summary(cache)
    row = cs["per_session"][sess.key]
    print(f"[serve_extract] session cache: {cs['sessions']}/"
          f"{cs['max_sessions']} sessions, hits {cs['hits']}, misses "
          f"{cs['misses']}, evictions {cs['evictions']}")
    print(f"[serve_extract] session {sess.key}: epoch {row['epoch']}, "
          f"{row['open_segments']} open segment(s), "
          f"{row['live_entities']} live / {row['tombstoned']} tombstoned "
          f"entities, maintenance {row['maintenance'] or '[]'}")

    if args.check:
        want = one_shot_reference(sess, docs)
        got = svc.results_set()
        if got != want:
            print(f"[serve_extract] PARITY FAILED: served {len(got)} vs "
                  f"one-shot {len(want)} matches", file=sys.stderr)
            return 1
        print(f"[serve_extract] parity OK: {len(got)} matches identical to "
              "one-shot execute")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Roofline-term derivation from a compiled dry-run artifact (deliverable g).

This container is CPU-only; TPU v5e is the TARGET. We derive the three
roofline terms structurally from the compiled SPMD module via
``launch/hlo_analysis.py`` (trip-count-aware — XLA's own cost_analysis
counts scan bodies once, which understates a 16-group layer scan 16×):

    compute term    = max(MXU_s, VPU_s)
        MXU_s = exact dot/conv FLOPs / 197e12   (bf16 MXU peak)
        VPU_s = approx elementwise FLOPs / 3e12 (VPU model, see below)
    memory term     = fusion-boundary HBM bytes / 819e9
    collective term = ring-model wire bytes / 50e9

All inputs are PER-DEVICE (the SPMD module is the per-device program;
verified: a 16-way sharded 1024³ matmul reports 2·1024³/16 flops), so the
prompt's ``/(chips × …)`` normalisation is already folded in.

VPU model: v4's VPU is ≈4.3 TFLOP/s against a 275 TFLOP/s MXU; scaling to
v5e's 197 TFLOP/s gives ≈3 TFLOP/s. Elementwise counts are 1 op/output
element (transcendentals cost more, masks less), so VPU_s is a ±3×
estimate — good enough to flag "softmax-bound" cells, and iteration-over-
iteration deltas (what §Perf optimizes) are exact in the byte/flop counts.

``memory_analysis()`` (peak live bytes) is taken from XLA directly — its
buffer assignment handles loops correctly.
"""
from __future__ import annotations

import dataclasses

from repro.launch import hlo_analysis as H

# ---- TPU v5e hardware model (per chip) ------------------------------------
PEAK_FLOPS = 197e12  # bf16 MXU FLOP/s
VPU_FLOPS = 3e12  # modeled VPU throughput (see module docstring)
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link (per-direction, per axis)


def total_params(cfg) -> float:
    """Total parameter count (MoE: ALL experts), embeddings included."""
    d, L, V = cfg.d_model, cfg.num_layers, cfg.padded_vocab
    hd = cfg.resolved_head_dim
    H_, KH = cfg.num_heads, cfg.num_kv_heads
    attn_p = d * hd * (H_ + 2 * KH) + H_ * hd * d
    gated = cfg.act in ("swiglu", "geglu")
    mlp_p = d * cfg.d_ff * (3 if gated else 2)
    if cfg.num_experts:
        mlp_p = cfg.num_experts * mlp_p + d * cfg.num_experts
    n = L * (attn_p + mlp_p) + 2 * d * V
    if cfg.encoder_layers:
        n += cfg.encoder_layers * (attn_p + d * cfg.d_ff * 2)
    return float(n)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (fwd-only); N counts active
    params (MoE: top_k experts + router), D = tokens processed."""
    d, L, V = cfg.d_model, cfg.num_layers, cfg.padded_vocab
    hd = cfg.resolved_head_dim
    H_, KH = cfg.num_heads, cfg.num_kv_heads
    attn_p = d * hd * (H_ + 2 * KH) + H_ * hd * d
    gated = cfg.act in ("swiglu", "geglu")
    mlp_p = d * cfg.d_ff * (3 if gated else 2)
    if cfg.num_experts:
        mlp_active = cfg.top_k * mlp_p + d * cfg.num_experts
    else:
        mlp_active = mlp_p
    per_layer = attn_p + mlp_active
    n_active = L * per_layer + 2 * d * V
    if cfg.encoder_layers:
        n_active += cfg.encoder_layers * (attn_p + mlp_p)
    if shape.mode == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.mode == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: 1 token/sequence


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    mxu_s: float
    vpu_s: float
    stats: H.Stats
    model_flops: float
    useful_frac: float  # MODEL_FLOPS / (MXU_FLOPs × chips)
    bottleneck: str
    step_time_s: float  # max of the three terms (no-overlap bound)
    chips: int
    xla_cost: dict

    def roofline_fraction(self) -> float:
        """Achievable MFU bound: useful FLOPs / (chips × peak × step_time)."""
        if self.step_time_s <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * self.step_time_s)

    def to_dict(self):
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "mxu_s": self.mxu_s,
            "vpu_s": self.vpu_s,
            "hlo": self.stats.to_dict(),
            "model_flops": self.model_flops,
            "useful_frac": self.useful_frac,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
            "chips": self.chips,
            "roofline_fraction": self.roofline_fraction(),
            "xla_cost_reference": self.xla_cost,
        }


def derive(cost_analysis: dict, hlo_text: str, cfg, shape, chips: int) -> Roofline:
    stats = H.analyze(hlo_text)
    mxu_s = stats.mxu_flops / PEAK_FLOPS
    vpu_s = stats.vpu_flops / VPU_FLOPS
    ct = max(mxu_s, vpu_s)
    mt = stats.bytes / HBM_BW
    st = stats.wire_bytes / ICI_BW
    terms = {"compute": ct, "memory": mt, "collective": st}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    return Roofline(
        compute_s=ct,
        memory_s=mt,
        collective_s=st,
        mxu_s=mxu_s,
        vpu_s=vpu_s,
        stats=stats,
        model_flops=mf,
        useful_frac=mf / max(stats.mxu_flops * chips, 1.0),
        bottleneck=bottleneck,
        step_time_s=max(ct, mt, st),
        chips=chips,
        xla_cost={k: cost_analysis.get(k) for k in ("flops", "bytes accessed")
                  if k in cost_analysis},
    )

"""Multi-host serving fabric entrypoint (the distributed-tier demo).

    PYTHONPATH=src python -m repro.launch.serve_cluster \
        --replicas 2 --requests 16 --deltas 2 --check

Spawns N replica *processes* on this machine (the CI stand-in for N
hosts — same spawn path, same TCP socket channels, same wire frames),
bootstraps a session onto each from a compacted base snapshot, then
serves a mixed workload through the cluster coordinator: requests are
consistent-hash routed to epoch-agreed replicas while serialized
``DictionaryDelta``s replicate live between batches. With ``--check``
every response is asserted bit-identical to the single-host
``one_shot_reference`` at the epoch the request was admitted under
(exit 1 on drift). ``--mode verify`` runs the same workload through
``ExtractionService`` with the verify pool behind the transport
(``remote_verify``) instead of direct request routing.

The report ends with the per-replica fabric section of
``ServingMetrics.summary``: lane/request bytes on the wire, frames
retried, replication lag, routed/shed per replica.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core.eejoin import EEJoinConfig
from repro.data.synth import make_corpus
from repro.fabric.cluster import ClusterCoordinator, launch_local_cluster
from repro.serving import (
    BatcherConfig,
    ExtractionService,
    ServingMetrics,
    SessionCache,
    one_shot_reference,
)
from repro.serving.session import pure_plan
from repro.updates.delta import random_delta


def build_workload(args):
    corpus = make_corpus(
        num_docs=max(args.requests, 8),
        doc_len=args.doc_len,
        vocab_size=512,
        num_entities=args.entities,
        seed=args.seed,
    )
    rng = np.random.default_rng(args.seed + 1)
    lens = rng.integers(args.doc_len // 4, args.doc_len + 1,
                        size=args.requests)
    docs = [corpus.doc_tokens[i % corpus.doc_tokens.shape[0], : lens[i]]
            for i in range(args.requests)]
    return corpus, docs, rng


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch-docs", type=int, default=4,
                    help="documents per routed request batch")
    ap.add_argument("--deltas", type=int, default=2,
                    help="live dictionary deltas replicated mid-stream")
    ap.add_argument("--doc-len", type=int, default=64)
    ap.add_argument("--entities", type=int, default=32)
    ap.add_argument("--scheme", default="prefix",
                    choices=("word", "prefix", "lsh", "variant"))
    ap.add_argument("--mode", default="route",
                    choices=("route", "verify"),
                    help="route: full requests to replicas; verify: "
                         "local probe + remote verify through "
                         "ExtractionService")
    ap.add_argument("--check", action="store_true",
                    help="assert per-request parity vs one_shot_reference "
                         "at the admitted epoch")
    ap.add_argument("--timeout", type=float, default=300.0,
                    help="per-RPC timeout (first request pays jit "
                         "compilation on the replica)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    corpus, docs, rng = build_workload(args)
    cfg = EEJoinConfig(
        gamma=0.8, max_candidates=4096, result_capacity=8192,
        use_kernel=True,
    )
    cache = SessionCache()
    sess = cache.get_or_create(corpus.dictionary, cfg,
                               plan=pure_plan(args.scheme))

    names = [f"replica{i}" for i in range(args.replicas)]
    t0 = time.perf_counter()
    procs, endpoints = launch_local_cluster(
        names, endpoint_timeout=args.timeout
    )
    print(f"[serve_cluster] spawned {len(procs)} replica process(es) in "
          f"{time.perf_counter() - t0:.1f}s: {', '.join(names)}")
    metrics = ServingMetrics()
    coord = ClusterCoordinator(
        endpoints, metrics=metrics, hold_epochs=args.check
    )
    t0 = time.perf_counter()
    coord.add_session(sess)
    print(f"[serve_cluster] session {sess.key} (scheme {args.scheme}) "
          f"bootstrapped on {len(endpoints)} replicas in "
          f"{time.perf_counter() - t0:.1f}s")

    version = lambda: sess.current_state.version  # noqa: E731
    batches = [docs[i:i + args.batch_docs]
               for i in range(0, len(docs), args.batch_docs)]
    delta_every = max(len(batches) // (args.deltas + 1), 1)
    checked = 0
    failures = 0
    t0 = time.perf_counter()

    if args.mode == "route":
        admitted = []  # (epoch, batch_docs, served set)
        for bi, batch in enumerate(batches):
            if args.deltas and bi and bi % delta_every == 0 \
                    and len(sess.maintenance_log) < args.deltas:
                delta = random_delta(rng, version(), 512)
                coord.apply_delta(sess.key, delta)
                print(f"[serve_cluster] delta replicated before batch "
                      f"{bi}: +{delta.num_added}/-{delta.num_tombstoned} "
                      f"-> epoch {sess.epoch} "
                      f"({sess.maintenance_log[-1]['action']})")
            epoch, matches = coord.extract(sess.key, batch,
                                           timeout=args.timeout)
            admitted.append((epoch, batch, matches.to_set()))
        if args.check:
            for epoch, batch, got in admitted:
                want = one_shot_reference(sess, batch, epoch=epoch)
                checked += 1
                if got != want:
                    failures += 1
                    print(f"[serve_cluster] PARITY FAILED at epoch "
                          f"{epoch}: {len(got)} vs {len(want)} matches",
                          file=sys.stderr)
    else:  # verify mode: ExtractionService with the remote verify pool
        svc = ExtractionService(
            cache,
            batcher_config=BatcherConfig(max_batch_docs=args.batch_docs,
                                         max_delay_s=0.005),
            overlap=False,
            remote_verify=coord,
        )
        with svc:
            for bi, batch in enumerate(batches):
                if args.deltas and bi and bi % delta_every == 0 \
                        and len(sess.maintenance_log) < args.deltas:
                    svc.drain()  # route pending lanes at their epochs
                    delta = random_delta(rng, version(), 512)
                    coord.apply_delta(sess.key, delta)
                    print(f"[serve_cluster] delta replicated before "
                          f"batch {bi} -> epoch {sess.epoch}")
                for j, d in enumerate(batch):
                    svc.submit(bi * args.batch_docs + j, d, sess.key,
                               block=True)
                svc.tick()
            svc.drain()
        if args.check:
            got = svc.results_set()
            want = _verify_mode_reference(svc, sess, docs)
            checked = 1
            if got != want:
                failures = 1
                print(f"[serve_cluster] PARITY FAILED (verify mode): "
                      f"{len(got)} vs {len(want)} matches",
                      file=sys.stderr)
    elapsed = time.perf_counter() - t0

    print(f"[serve_cluster] served {len(batches)} batch(es) / "
          f"{len(docs)} doc(s) in {elapsed:.1f}s "
          f"({len(docs) / max(elapsed, 1e-9):.1f} docs/s), final epoch "
          f"{sess.epoch}, maintenance "
          f"{[m['action'] for m in sess.maintenance_log] or '[]'}")
    coord.poll_stats()
    s = metrics.summary()
    for name, row in s["replicas"].items():
        print(f"[serve_cluster] replica {name}: "
              f"{'alive' if row['alive'] else 'DEAD'}, routed "
              f"{row['routed']}, shed {row['shed']}, retried frames "
              f"{row['frames_retried']}, lag {row['replication_lag_epochs']}"
              f" epoch(s), lane bytes {row['lane_bytes']}, wire tx/rx "
              f"{row['bytes_sent']}/{row['bytes_received']} B")
    coord.shutdown()
    for p in procs:
        p.join(timeout=30)

    if args.check:
        if failures:
            return 1
        print(f"[serve_cluster] parity OK: {checked} response(s) "
              "bit-identical to one_shot_reference at their admitted "
              "epochs")
    return 0


def _verify_mode_reference(svc, sess, docs) -> set:
    """Exact reference for verify mode: replay each batch's docs at its
    admitted epoch (epochs recorded on the metrics batch rows)."""
    want = set()
    by_batch: dict[int, list] = {}
    for req in svc.completed:
        by_batch.setdefault(req.batch_id, []).append(req)
    epoch_of = {rec["batch_id"]: rec["epoch"]
                for rec in svc.metrics.batch_records}
    for bid, reqs in by_batch.items():
        bdocs = [docs[r.doc_id] for r in sorted(reqs, key=lambda r: r.doc_id)]
        ref = one_shot_reference(sess, bdocs, epoch=epoch_of[bid])
        id_map = {row: r.doc_id
                  for row, r in enumerate(sorted(reqs,
                                                 key=lambda r: r.doc_id))}
        want |= {(id_map[d], p, l, e) for (d, p, l, e) in ref}
    return want


if __name__ == "__main__":
    sys.exit(main())

"""Entity indexes for the Index-on-Entities algorithm (§3.2).

Three index types, built host-side (numpy) and queried device-side
(jnp) with static shapes:

* ``word``    — inverted list per token over *all* entity tokens. Fast to
  build; lists for frequent tokens grow long (the paper's noted
  weakness), which shows up as a large ``max_postings`` gather.
* ``prefix``  — inverted list per token over *prefix tokens* only (see
  ``signatures.prefix_token_sets``). Complete for the containment
  predicate with far shorter lists; requires verification.
* ``variant`` — hash table over all Jaccard variants of all entities
  (Def. 2). Lookups need **no verification** (64-bit keys); costliest to
  build.

Static-shape querying: inverted lists are CSR (offsets/postings) padded
to ``max_postings`` per probed token; hash-table buckets have a fixed
``bucket_cap``. Overflows are impossible by construction (arrays are
sized from the data at build time) — the *memory budget* ``M_e``
(Def. 3) instead partitions entities into ranges, each with its own
index, and the algorithm loops passes over candidates (see
``extraction/index_extract.py``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp

from repro.core import hashing
from repro.core.dictionary import Dictionary
from repro.core.signatures import prefix_token_sets
from repro.core.variants import variant_keys

INDEX_WORD = "word"
INDEX_PREFIX = "prefix"
INDEX_VARIANT = "variant"
INDEX_NAMES = (INDEX_WORD, INDEX_PREFIX, INDEX_VARIANT)

NEEDS_VERIFY = {INDEX_WORD: True, INDEX_PREFIX: True, INDEX_VARIANT: False}


@dataclasses.dataclass
class InvertedIndex:
    """CSR token -> entity-id postings, padded for static gathers.

    ``postings_padded``: [V, max_postings] int32, -1 padded — a dense
    view used for device gathers. ``offsets``/``postings`` keep the exact
    CSR for host-side cost statistics.
    """

    offsets: np.ndarray  # [V+1] int32
    postings: np.ndarray  # [nnz] int32
    postings_padded: np.ndarray  # [V, P] int32 (-1 pad)
    max_postings: int

    @property
    def nbytes(self) -> int:
        return int(self.postings_padded.nbytes)

    def list_lengths(self) -> np.ndarray:
        return np.diff(self.offsets)


@dataclasses.dataclass
class VariantIndex:
    """Static open-bucket hash table: variant key -> entity id.

    ``keys1/keys2``: [n_buckets, bucket_cap] uint32 (two independent
    32-bit hashes = 64-bit effective key), 0-key slots invalid via mask.
    """

    keys1: np.ndarray
    keys2: np.ndarray
    entity_id: np.ndarray  # [n_buckets, cap] int32, -1 pad
    n_buckets: int
    bucket_cap: int
    dropped: int  # variants dropped to bucket overflow (0 unless capped)

    @property
    def nbytes(self) -> int:
        return int(self.keys1.nbytes + self.keys2.nbytes + self.entity_id.nbytes)


def build_inverted_index(
    dictionary: Dictionary, kind: str, gamma: float
) -> InvertedIndex:
    """Build a word- or prefix- inverted index."""
    V = dictionary.vocab_size
    pairs: list[tuple[int, int]] = []  # (token, entity)
    if kind == INDEX_WORD:
        for i in range(dictionary.num_entities):
            n = int(dictionary.lengths[i])
            for t in dictionary.tokens[i, :n]:
                pairs.append((int(t), i))
    elif kind == INDEX_PREFIX:
        for i, toks in enumerate(prefix_token_sets(dictionary, gamma)):
            for t in toks:
                pairs.append((int(t), i))
    else:
        raise ValueError(f"not an inverted index kind: {kind!r}")

    pairs.sort()
    toks = np.array([p[0] for p in pairs], dtype=np.int32)
    ents = np.array([p[1] for p in pairs], dtype=np.int32)
    counts = np.bincount(toks, minlength=V)
    offsets = np.zeros((V + 1,), dtype=np.int32)
    np.cumsum(counts, out=offsets[1:])
    P = max(1, int(counts.max()) if counts.size else 1)
    padded = np.full((V, P), -1, dtype=np.int32)
    if len(toks):
        # vectorised CSR->padded scatter: rank of each posting within its
        # token's list is its flat position minus the list start.
        rank = np.arange(len(toks)) - offsets[toks.astype(np.int64)]
        padded[toks, rank] = ents
    return InvertedIndex(offsets, ents, padded, P)


def build_variant_index(
    dictionary: Dictionary,
    gamma: float,
    max_variants: int = 256,
    load_factor: float = 0.5,
    bucket_cap: int | None = None,
) -> VariantIndex:
    """Hash all Jaccard variants into a static bucketed table."""
    k1, k2, eid = variant_keys(dictionary, gamma, max_variants)
    n = max(len(k1), 1)
    n_buckets = 1 << max(3, int(np.ceil(np.log2(n / load_factor + 1))))
    bucket = (k1 % np.uint32(n_buckets)).astype(np.int64)
    counts = np.bincount(bucket, minlength=n_buckets)
    cap = bucket_cap or max(4, int(counts.max()) if counts.size else 4)
    keys1 = np.zeros((n_buckets, cap), dtype=np.uint32)
    keys2 = np.zeros((n_buckets, cap), dtype=np.uint32)
    ents = np.full((n_buckets, cap), -1, dtype=np.int32)
    dropped = 0
    if len(k1):
        # vectorised bucket fill (see engine.build_sig_table): stable sort
        # by bucket preserves insertion order; ranks >= cap are dropped.
        order = np.argsort(bucket, kind="stable")
        sb = bucket[order]
        rank = np.arange(len(k1)) - np.searchsorted(sb, sb)
        keep = rank < cap
        dropped = int((~keep).sum())
        keys1[sb[keep], rank[keep]] = k1[order][keep]
        keys2[sb[keep], rank[keep]] = k2[order][keep]
        ents[sb[keep], rank[keep]] = eid[order][keep]
    return VariantIndex(keys1, keys2, ents, n_buckets, cap, dropped)


# --------------------------------------------------------------------------
# Device-side queries (jnp, static shapes)
# --------------------------------------------------------------------------


def query_inverted(postings_padded, win_tokens, win_valid):
    """Gather candidate entity ids for each window.

    ``postings_padded``: [V, P] int32 (-1 pad), ``win_tokens``: [..., L].
    Returns candidates [..., L*P] int32 with -1 for invalid (duplicates
    across tokens possible; verification dedups by similarity emit).
    """
    cands = postings_padded[win_tokens]  # [..., L, P]
    cands = jnp.where(win_valid[..., None], cands, -1)
    return cands.reshape(*cands.shape[:-2], -1)


def query_variant(index_keys1, index_keys2, entity_id, n_buckets: int, key1, key2):
    """Probe the variant table with window set-hash pairs.

    ``key1/key2``: [...] uint32. Returns matched entity ids [..., cap]
    (-1 where no match).
    """
    b = (key1 % jnp.uint32(n_buckets)).astype(jnp.int32)
    k1 = index_keys1[b]  # [..., cap]
    k2 = index_keys2[b]
    ent = entity_id[b]
    hit = (k1 == key1[..., None]) & (k2 == key2[..., None]) & (ent >= 0)
    return jnp.where(hit, ent, -1)

"""ISH filter — compact membership filter pruning candidate windows (§3.3).

Chakrabarti et al.'s inverted signature hashtable is a CPU cache-resident
structure; on TPU we adapt its *role* (a filter small enough to live in
fast memory that prunes the L×|d| substring explosion before any shuffle
or index lookup) as a **Bloom filter over the prefix tokens of all
dictionary entities**, probed in a single fused pass over every document
window.

Soundness: a window matching any entity under ``JaccCont_extra >= gamma``
must contain at least one of that entity's prefix tokens (see
``signatures.prefix_token_sets``), and Bloom filters have no false
negatives — so the filter never drops a true mention. False positives
only cost work; the measured FP rate feeds the cost model.

The filter bitmap is sized to fit VMEM (default 2^18 bits = 32 KiB) so
the Pallas ``window_filter`` kernel can keep it resident while streaming
document tiles HBM→VMEM.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp

from repro.core import hashing
from repro.core.dictionary import Dictionary
from repro.core.signatures import prefix_token_sets

_BLOOM_SEED_BASE = 9100


@dataclasses.dataclass
class BloomFilter:
    """k-hash Bloom filter over token ids, bit-packed into uint32 words."""

    bits: np.ndarray  # [n_words] uint32
    num_bits: int
    num_hashes: int
    member_tokens: np.ndarray  # [n] int32, the inserted token ids

    @property
    def nbytes(self) -> int:
        return int(self.bits.nbytes)


def build_ish_filter(
    dictionary: Dictionary,
    gamma: float,
    num_bits: int = 1 << 18,
    num_hashes: int = 3,
) -> BloomFilter:
    """Bloom filter over the union of all entities' prefix tokens."""
    toks = np.unique(np.concatenate(prefix_token_sets(dictionary, gamma)))
    words = np.zeros((num_bits // 32,), dtype=np.uint32)
    for k in range(num_hashes):
        h = hashing.hash_u32(toks, seed=_BLOOM_SEED_BASE + k, xp=np)
        pos = h % np.uint32(num_bits)
        np.bitwise_or.at(words, pos // 32, np.uint32(1) << (pos % 32))
    return BloomFilter(
        bits=words, num_bits=num_bits, num_hashes=num_hashes, member_tokens=toks
    )


def token_in_filter(bits, num_bits: int, num_hashes: int, tokens):
    """jnp probe: True where ``tokens`` are (probable) filter members."""
    hit = jnp.ones(tokens.shape, dtype=bool)
    for k in range(num_hashes):
        h = hashing.hash_u32(tokens, seed=_BLOOM_SEED_BASE + k, xp=jnp)
        pos = h % jnp.uint32(num_bits)
        word = bits[(pos // 32).astype(jnp.int32)]
        bit = (word >> (pos % 32)) & jnp.uint32(1)
        hit = hit & (bit == 1)
    return hit


def window_survives(bits, num_bits: int, num_hashes: int, win_tokens, win_valid):
    """A window survives iff any valid token probes into the filter."""
    hit = token_in_filter(bits, num_bits, num_hashes, win_tokens)
    return (hit & win_valid).any(axis=-1)


def measure_fp_rate(flt: BloomFilter, sample_tokens: np.ndarray) -> float:
    """Empirical false-positive rate of the token probe on a host sample."""
    bits = jnp.asarray(flt.bits)
    probe = np.asarray(
        token_in_filter(bits, flt.num_bits, flt.num_hashes, jnp.asarray(sample_tokens))
    )
    truth = np.isin(sample_tokens, flt.member_tokens)
    fp = probe & ~truth
    denom = max(int((~truth).sum()), 1)
    return float(fp.sum()) / denom

"""Vectorised integer hashing shared by numpy (host build) and jnp (device).

All hashes are uint32. We stay in 32-bit because jax runs with x64
disabled; where more entropy is needed we combine two independent
32-bit hashes (``hash2``).

The same bit-exact function is exposed for numpy and jax so that
host-built structures (indexes, filters, variant tables) agree with
device-computed probes.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

# splitmix32 constants (Stafford mix / murmur3-finaliser family).
_C1 = 0x85EBCA6B
_C2 = 0xC2B2AE35
_GOLDEN = 0x9E3779B9


def _mix(x, *, xp):
    """murmur3 finaliser; ``xp`` is numpy or jax.numpy."""
    with np.errstate(over="ignore"):
        x = x.astype(xp.uint32)
        x = x ^ (x >> xp.uint32(16))
        x = x * xp.uint32(_C1)
        x = x ^ (x >> xp.uint32(13))
        x = x * xp.uint32(_C2)
        x = x ^ (x >> xp.uint32(16))
        return x


def hash_u32(x, seed: int = 0, *, xp=jnp):
    """Hash int array -> uint32, parameterised by ``seed``."""
    off = (_GOLDEN * (int(seed) + 1)) & 0xFFFFFFFF  # python-int, pre-wrapped
    with np.errstate(over="ignore"):
        x = x.astype(xp.uint32) + xp.uint32(off)
    return _mix(x, xp=xp)


def hash2(x, seed: int = 0, *, xp=jnp):
    """Two decorrelated uint32 hashes, returned as a tuple."""
    return hash_u32(x, seed=2 * seed, xp=xp), hash_u32(x, seed=2 * seed + 1, xp=xp)


def combine(h, g, *, xp=jnp):
    """Order-dependent combine of two uint32 hash arrays."""
    h = h.astype(xp.uint32)
    g = g.astype(xp.uint32)
    return _mix(h ^ (g + xp.uint32(_GOLDEN) + (h << xp.uint32(6)) + (h >> xp.uint32(2))), xp=xp)


def set_hash(tokens, valid, seed: int = 0, *, xp=jnp, axis: int = -1):
    """Order-insensitive hash of a padded token-id set.

    ``tokens``: integer array, padded entries arbitrary.
    ``valid``: boolean mask of the same shape.

    Commutative combine of per-token hashes: (sum, xor, count) folded
    through the finaliser. Identical in numpy and jnp.
    """
    per = hash_u32(tokens, seed=seed, xp=xp)
    per = xp.where(valid, per, xp.uint32(0))
    with np.errstate(over="ignore"):
        s = per.sum(axis=axis, dtype=xp.uint32)
        if xp is np:
            x = np.bitwise_xor.reduce(per, axis=axis)
            cnt = valid.sum(axis=axis).astype(np.uint32)
        else:
            x = jnp.bitwise_xor.reduce(per, axis=axis)
            cnt = valid.sum(axis=axis).astype(jnp.uint32)
        return _mix(s ^ (x * xp.uint32(_C1)) ^ (cnt * xp.uint32(_GOLDEN)), xp=xp)

"""Cost-constant calibration (the paper's "means to gather data
statistics leveraged by the cost model", applied to the constants).

Def. 3/4 are linear in per-record constants (probe, verify, signature
costs). Their defaults are order-of-magnitude hardware estimates; on a
concrete host the right values differ enough to misrank plans in
crossover regimes (bench_hybrid exposed this: a multi-pass index plan
predicted cheaper than a pure ssjoin plan measured 14x faster).

``calibrate`` executes ONE small pure plan per core algorithm on a
document sample, compares measured seconds with predicted seconds, and
rescales each side's record-work constants by the measured/predicted
ratio. Side-level scaling preserves the monotonicity that Lemma 1 needs
(every term is multiplied by a positive scalar), so the §5.2 search
remains correct; only the relative weighting between algorithm families
changes.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

import jax

from repro.core.cost_model import (
    ALGO_INDEX, ALGO_SSJOIN, OBJ_JOB, CostParams, cost_side, objective_value,
)
from repro.core.plan import Plan, PlanSide
from repro.core.cost_model import SideCost


def _forced(split: int, head: PlanSide, tail: PlanSide) -> Plan:
    z = SideCost(0, 0, 0, 0, 0, 0, 0, 0, 0)
    return Plan(split, head, tail, OBJ_JOB, 0.0, z, z, 0)


def _time(fn, iters: int = 2) -> float:
    jax.block_until_ready(fn())  # compile + warm
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def measured_lane_density(stats) -> float:
    """Filter-survivor density (survivors / enumerated windows).

    The density term behind the adaptive lane-width plan
    (``cost_model.planned_lane_width``): full-dictionary survivors over
    total candidate windows, both already corpus-scaled in ``EEStats``.
    """
    if stats.num_windows <= 0:
        return 0.0
    return float(stats.head_survivors(stats.num_entities) / stats.num_windows)


def refit_params(params: CostParams, observed,
                 schemes: tuple[str, ...] = ("prefix",)) -> CostParams:
    """Pure per-stage refit of the cost constants from serving telemetry.

    ``observed`` is duck-typed (so the core layer never imports the
    serving package): it needs ``density`` (filter survivors per
    enumerated window), ``probe_s_per_window`` and
    ``verify_s_per_survivor`` — the EWMA estimators a
    ``serving.replan.ObservedStats`` maintains. Each *stage family* is
    rescaled by one positive scalar so that the model's canonical
    per-unit time matches the measurement:

    * probe family (``c_enum_per_window``, ``c_filter_per_window``, all
      ``c_sig_per_window`` entries) — matched against seconds per
      enumerated window, with the signature term weighted by the
      measured survivor density (signatures are only built for
      survivors);
    * verify family (``c_probe``, ``c_verify_pair``, ``c_probe_index``,
      ``c_verify_index``) — matched against seconds per surviving
      window.

    Scaling a whole family by a positive scalar preserves the
    monotonicity Lemma 1's split search relies on (same argument as
    ``calibrate``), and because each family's model is homogeneous of
    degree 1 in its constants the refit is idempotent: refitting twice
    against the same observation is a no-op (property-tested in
    ``tests/test_replan_prop.py``). Non-positive / NaN observations
    leave their family untouched, so a cold ``ObservedStats`` refits to
    the identity.
    """
    def _ok(x) -> bool:
        return x is not None and np.isfinite(x) and x > 0.0

    density = observed.density if _ok(observed.density) else params.lane_density
    sig_mean = float(np.mean([params.sig_cost(s) for s in schemes])) \
        if schemes else params.sig_cost("prefix")

    k_probe = 1.0
    obs_p = observed.probe_s_per_window
    model_p = (params.c_enum_per_window + params.c_filter_per_window
               + max(density, 0.0) * sig_mean)
    if _ok(obs_p) and model_p > 0.0:
        k_probe = obs_p / model_p

    k_verify = 1.0
    obs_v = observed.verify_s_per_survivor
    model_v = params.c_probe + params.c_verify_pair
    if _ok(obs_v) and model_v > 0.0:
        k_verify = obs_v / model_v

    sig = {s: params.sig_cost(s) * k_probe
           for s in ("word", "prefix", "lsh", "variant")}
    return dataclasses.replace(
        params,
        c_enum_per_window=params.c_enum_per_window * k_probe,
        c_filter_per_window=params.c_filter_per_window * k_probe,
        c_sig_per_window=sig,
        c_probe=params.c_probe * k_verify,
        c_verify_pair=params.c_verify_pair * k_verify,
        c_probe_index=params.c_probe_index * k_verify,
        c_verify_index=params.c_verify_index * k_verify,
        lane_density=density if _ok(density) else params.lane_density,
    )


def calibrate(op, sample_docs, params: CostParams,
              scheme: str = "variant") -> CostParams:
    """Returns CostParams with per-family constants rescaled to this host.

    ``op`` is an EEJoinOperator; ``sample_docs`` a small [D, T] array.
    The ssjoin timing runs through ``op.execute``, so with
    ``EEJoinConfig(use_kernel=True)`` the per-scheme signature constants
    (``c_sig_per_window`` — notably ``"variant"``, whose window keys now
    come out of the fused megakernel) are rescaled against the *fused*
    pipeline, not the retired jnp one. The returned params also carry
    the measured filter-survivor density (``lane_density``) that sizes
    adaptive candidate lanes.
    """
    stats = op.gather_statistics(sample_docs, total_docs=len(sample_docs))
    E = op.dictionary.num_entities
    density = measured_lane_density(stats)

    # measured seconds per family on the sample
    plan_idx = _forced(E, PlanSide(ALGO_INDEX, scheme),
                       PlanSide(ALGO_SSJOIN, scheme))
    prep_idx = op.prepare(plan_idx, params)
    t_idx = _time(lambda: op.execute(prep_idx, sample_docs))

    plan_ssj = _forced(0, PlanSide(ALGO_INDEX, scheme),
                       PlanSide(ALGO_SSJOIN, scheme))
    prep_ssj = op.prepare(plan_ssj, params)
    t_ssj = _time(lambda: op.execute(prep_ssj, sample_docs))

    # predicted seconds on the same sample (num_devices=1)
    p1 = dataclasses.replace(params, num_devices=1)
    pred_idx = objective_value(
        cost_side(stats, p1, 0, E, ALGO_INDEX, scheme, head=True), OBJ_JOB)
    pred_ssj = objective_value(
        cost_side(stats, p1, 0, E, ALGO_SSJOIN, scheme, head=False), OBJ_JOB)

    k_idx = t_idx / max(pred_idx, 1e-12)
    k_ssj = t_ssj / max(pred_ssj, 1e-12)
    sig = {s: params.sig_cost(s) * k_ssj
           for s in ("word", "prefix", "lsh", "variant")}
    return dataclasses.replace(
        params,
        c_probe_index=params.c_probe_index * k_idx,
        c_verify_index=params.c_verify_index * k_idx,
        c_probe=params.c_probe * k_ssj,
        c_verify_pair=params.c_verify_pair * k_ssj,
        c_sig_per_window=sig,
        lane_density=density,
    )

"""Data-statistics gathering for the EE-Join cost model (paper §4/§5).

The cost model must evaluate plan costs for *any* dictionary split point
``p`` in O(1). The key observation (which also proves Lemma 1) is that
every cost term is either

* **additive per entity** — postings lengths, verify loads, variant
  counts — so a prefix-sum over the frequency-sorted entities gives any
  range ``[a, b)`` by subtraction; or
* a **cumulative survivor curve** — the number of windows passing the
  ISH filter of entity range ``[0, p)`` equals ``#{w : minrank(w) < p}``
  where ``minrank(w)`` is the smallest entity rank whose prefix tokens
  intersect ``w`` (dually ``maxrank`` for tails) — again O(1) per query
  after one pass over the sample; or
* a **grid-interpolated curve** for the one genuinely non-additive term,
  the padded index footprint (its max-postings padding is range-max, not
  range-sum).

Statistics are gathered from a document *sample* and scaled; in
production the same counters run as a distributed shard_map job (see
``extraction/distributed.py::distributed_stats``) — this module is the
host-side reference.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dictionary import PAD, Dictionary
from repro.core.signatures import LshParams, prefix_token_sets
from repro.core.variants import variant_keys
from repro.core import hashing
from repro.extraction.substrings import window_base_np

_LSH_WINDOW_CAP = 4096


@dataclasses.dataclass
class EEStats:
    """Everything the cost model needs, queryable in O(1) per range."""

    num_entities: int
    max_len: int
    scale: float  # full-corpus windows / sample windows
    num_windows: float  # total candidates |C| = L * |d| (scaled)
    avg_sigs_per_window: float  # deduped tokens per surviving window
    survivors_head: np.ndarray  # [E+1] windows passing filter of [0, p)
    survivors_tail: np.ndarray  # [E+1] windows passing filter of [p, E)
    cum: dict[str, np.ndarray]  # name -> [E+1] prefix sums (scaled)
    index_bytes: dict[str, tuple[np.ndarray, np.ndarray, np.ndarray]]
    # kind -> (grid_p, bytes_head_at_grid, bytes_tail_at_grid)
    sig_skew: dict[str, float]  # scheme -> max/mean shuffle-bucket load
    table_bytes_per_entity: dict[str, float]  # ssjoin table footprint

    def range_sum(self, name: str, a: int, b: int) -> float:
        c = self.cum[name]
        return float(c[b] - c[a])

    def head_survivors(self, p: int) -> float:
        return float(self.survivors_head[p])

    def tail_survivors(self, p: int) -> float:
        return float(self.survivors_tail[p])

    def head_index_bytes(self, kind: str, p: int) -> float:
        grid, head, _ = self.index_bytes[kind]
        return float(np.interp(p, grid, head))

    def tail_index_bytes(self, kind: str, p: int) -> float:
        grid, _, tail = self.index_bytes[kind]
        return float(np.interp(p, grid, tail))


def _padded_index_bytes(
    dictionary: Dictionary, kind: str, gamma: float, grid: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Padded [V, Pmax] footprint of head/tail range indexes at grid points."""
    V = dictionary.vocab_size
    E = dictionary.num_entities
    if kind == "variant":
        # hash-table bytes ≈ 12B per variant / load_factor; additive.
        per_e = np.zeros((E,), dtype=np.float64)
        _k1, _k2, eid = variant_keys(dictionary, gamma)
        np.add.at(per_e, eid, 12.0 / 0.5)
        c = np.concatenate([[0.0], np.cumsum(per_e)])
        return c[grid], c[E] - c[grid]
    if kind == "word":
        tok_lists = [
            dictionary.tokens[i, : dictionary.lengths[i]] for i in range(E)
        ]
    else:  # prefix
        tok_lists = prefix_token_sets(dictionary, gamma)
    # counts[t, k] via incremental bincount over grid prefixes
    head = np.zeros(len(grid))
    tail = np.zeros(len(grid))
    for gi, p in enumerate(grid):
        if p > 0:
            toks = np.concatenate(tok_lists[:p])
            cnt = np.bincount(toks, minlength=V)
            head[gi] = 4.0 * V * max(int(cnt.max()), 1)
        if p < E:
            toks = np.concatenate(tok_lists[p:])
            cnt = np.bincount(toks, minlength=V)
            tail[gi] = 4.0 * V * max(int(cnt.max()), 1)
    return head, tail


def gather_stats(
    dictionary: Dictionary,
    sample_docs: np.ndarray,
    total_docs: int,
    gamma: float,
    lsh: LshParams = LshParams(),
    num_shuffle_buckets: int = 256,
    index_grid_points: int = 17,
    seed: int = 0,
) -> EEStats:
    """One pass over a document sample -> EEStats."""
    rng = np.random.default_rng(seed)
    E = dictionary.num_entities
    L = dictionary.max_len
    V = dictionary.vocab_size
    Ds, T = sample_docs.shape
    scale = float(total_docs) / max(Ds, 1)

    base = window_base_np(sample_docs, L)  # [Ds, T, L]
    valid = np.cumprod(base != PAD, axis=-1).astype(bool)
    n_windows = float(valid.sum()) * scale

    # --- per-token min/max prefix-owner rank
    prefix_lists = prefix_token_sets(dictionary, gamma)
    minrank = np.full((V,), E, dtype=np.int64)
    maxrank = np.full((V,), -1, dtype=np.int64)
    for rank, toks in enumerate(prefix_lists):
        np.minimum.at(minrank, toks, rank)
        np.maximum.at(maxrank, toks, rank)

    # window-level min/max rank (min over token mins / max over token maxs)
    w_min = np.where(valid, minrank[base], E).min(axis=-1)  # [Ds, T] per pos
    w_max = np.where(valid, maxrank[base], -1).max(axis=-1)
    # expand back per (pos, len) candidate: candidate (p, l) sees tokens
    # 0..l -> running min/max along the length axis
    run_min = np.minimum.accumulate(np.where(valid, minrank[base], E), axis=-1)
    run_max = np.maximum.accumulate(np.where(valid, maxrank[base], -1), axis=-1)
    cand_min = np.where(valid, run_min, E).reshape(-1)
    cand_max = np.where(valid, run_max, -1).reshape(-1)
    cand_ok = valid.reshape(-1)
    cand_min = cand_min[cand_ok]
    cand_max = cand_max[cand_ok]

    # survivor curves: head [0,p): minrank < p ; tail [p,E): maxrank >= p
    hist_min = np.bincount(np.clip(cand_min, 0, E), minlength=E + 1)
    survivors_head = np.concatenate([[0], np.cumsum(hist_min[:E])]) * scale
    hist_max = np.bincount(np.clip(cand_max + 1, 0, E), minlength=E + 1)
    # #{maxrank >= p} = total_hit - #{maxrank < p}; maxrank=-1 => never hits
    cum_lt = np.cumsum(hist_max)[:E + 1] - hist_max[0]  # exclude the -1 bin
    total_hit = float(len(cand_max)) - hist_max[0]
    survivors_tail = (total_hit - cum_lt) * scale
    survivors_tail = np.maximum(survivors_tail, 0.0)

    # --- surviving windows under the full filter, for load counting
    surviving = valid & (run_min < E)
    from repro.core.semantics import first_occurrence_mask

    # candidate (pos, len) token views: [Ds*T*L, L]
    keep = np.tril(np.ones((L, L), dtype=bool))
    cand_flat = np.where(keep[None, None], base[:, :, None, :], PAD).reshape(-1, L)
    valid_flat = valid.reshape(-1)
    surviving_flat = surviving.reshape(-1)
    first_flat = first_occurrence_mask(cand_flat, xp=np)

    # deduped token occurrences among surviving candidates
    emit = first_flat & surviving_flat[:, None]
    occ = np.bincount(cand_flat[emit].ravel(), minlength=V).astype(np.float64)
    n_surv = max(float(surviving_flat.sum()), 1.0)
    avg_sigs = float(emit.sum()) / n_surv

    # --- additive per-entity loads
    cum: dict[str, np.ndarray] = {}

    def _cumsum(per_e: np.ndarray) -> np.ndarray:
        return np.concatenate([[0.0], np.cumsum(per_e * scale)])

    word_load = np.array(
        [occ[dictionary.tokens[i, : dictionary.lengths[i]]].sum() for i in range(E)]
    )
    prefix_load = np.array([occ[toks].sum() for toks in prefix_lists])
    cum["verify_word"] = _cumsum(word_load)
    cum["verify_prefix"] = _cumsum(prefix_load)

    # postings lengths (CSR work per lookup)
    cum["postings_word"] = _cumsum(
        np.array([float(dictionary.lengths[i]) for i in range(E)])
    )
    cum["postings_prefix"] = _cumsum(np.array([float(len(t)) for t in prefix_lists]))

    # variant machinery: per-entity variant counts + window hit loads
    k1, _k2, eid = variant_keys(dictionary, gamma)
    var_count = np.bincount(eid, minlength=E).astype(np.float64)
    cum["variants"] = _cumsum(var_count)
    win_tokens_f = cand_flat[surviving_flat]
    win_valid_f = first_flat[surviving_flat]
    wkeys = hashing.set_hash(win_tokens_f, win_valid_f, seed=101, xp=np)
    key_to_ents: dict[int, list[int]] = {}
    for k, e in zip(k1.tolist(), eid.tolist()):
        key_to_ents.setdefault(k, []).append(e)
    var_hits = np.zeros((E,), dtype=np.float64)
    uniq, counts = np.unique(wkeys, return_counts=True)
    for k, c in zip(uniq.tolist(), counts.tolist()):
        for e in key_to_ents.get(k, ()):
            var_hits[e] += c
    cum["verify_variant"] = _cumsum(var_hits)

    # LSH collision loads (subsampled windows, chunked entities)
    from repro.core.signatures import _minhash_np

    n_rows = win_tokens_f.shape[0]
    if n_rows > _LSH_WINDOW_CAP:
        surv_idx = rng.choice(n_rows, size=_LSH_WINDOW_CAP, replace=False)
    else:
        surv_idx = np.arange(n_rows)
    sub_scale = n_surv / max(len(surv_idx), 1)
    wsig = _minhash_np(win_tokens_f[surv_idx], win_valid_f[surv_idx], lsh)  # [W,B]
    esig = _minhash_np(dictionary.tokens, dictionary.valid_mask(), lsh)  # [E,B]
    lsh_load = np.zeros((E,), dtype=np.float64)
    for e0 in range(0, E, 1024):
        m = wsig[:, None, :] == esig[None, e0 : e0 + 1024, :]
        lsh_load[e0 : e0 + 1024] = m.any(axis=-1).sum(axis=0) * sub_scale
    cum["verify_lsh"] = _cumsum(lsh_load)

    # --- shuffle skew per scheme (bucket = sig % num_shuffle_buckets)
    sig_skew: dict[str, float] = {}
    tok_sigs = hashing.hash_u32(cand_flat[emit].ravel(), seed=11, xp=np)
    for scheme, sigs in (
        ("word", tok_sigs),
        ("prefix", tok_sigs),
        ("lsh", wsig.ravel()),
        ("variant", wkeys),
    ):
        if len(sigs) == 0:
            sig_skew[scheme] = 1.0
            continue
        b = np.bincount(
            (sigs % np.uint32(num_shuffle_buckets)).astype(np.int64),
            minlength=num_shuffle_buckets,
        )
        sig_skew[scheme] = float(b.max() / max(b.mean(), 1e-9))

    # --- index footprints at grid points
    grid = np.unique(
        np.round(np.linspace(0, E, index_grid_points)).astype(np.int64)
    )
    index_bytes = {}
    for kind in ("word", "prefix", "variant"):
        h, t = _padded_index_bytes(dictionary, kind, gamma, grid)
        index_bytes[kind] = (grid.astype(np.float64), h, t)

    table_bytes = {
        "word": 24.0,  # 12B/slot / 0.5 load factor per signature instance
        "prefix": 24.0,
        "lsh": 24.0 * lsh.bands,
        "variant": 24.0,
    }

    return EEStats(
        num_entities=E,
        max_len=L,
        scale=scale,
        num_windows=n_windows,
        avg_sigs_per_window=avg_sigs,
        survivors_head=survivors_head.astype(np.float64),
        survivors_tail=survivors_tail.astype(np.float64),
        cum=cum,
        index_bytes=index_bytes,
        sig_skew=sig_skew,
        table_bytes_per_entity=table_bytes,
    )

"""Similarity semantics for approximate dictionary entity extraction.

Implements the paper's Definition 1 (weighted Jaccard containment, the
``missing`` and ``extra`` variations) plus symmetric weighted Jaccard,
in two bit-compatible forms:

* a numpy oracle used by tests and host-side planning, and
* a jnp batched form used inside the distributed algorithms.

Inputs are PAD(=0)-padded token-id arrays; token sets are assumed
duplicate-free per row (the window generator and dictionary builder
enforce this; duplicated window tokens are deduplicated here via a
first-occurrence mask so semantics stay set-based).

Conventions for a candidate window ``s`` and an entity ``e`` with token
weight function ``w``:

  JaccCont_missing(e, s) = w(e ∩ s) / w(s)   (tolerates words of e
                                              missing from s)
  JaccCont_extra(e, s)   = w(e ∩ s) / w(e)   (tolerates extra words in s)
  Jaccard(e, s)          = w(e ∩ s) / w(e ∪ s)

The extraction predicate is ``sim(e, s) >= gamma``; ``sim`` is selected
by name. The default used throughout the framework is ``extra``: a
mention must cover a γ-fraction of the entity's weight — this is the
variation the Jaccard-variant machinery (Def. 2) computes exactly.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.dictionary import PAD

SIM_MISSING = "missing"
SIM_EXTRA = "extra"
SIM_JACCARD = "jaccard"
# The predicate the Jaccard-variant machinery computes *exactly*:
# set(s) ⊆ set(e) and w(s) >= gamma * w(e). It under-approximates
# SIM_EXTRA (any variant_exact match is an extra match); the paper treats
# the two interchangeably, we keep them distinct and testable.
SIM_VARIANT_EXACT = "variant_exact"
SIM_NAMES = (SIM_MISSING, SIM_EXTRA, SIM_JACCARD, SIM_VARIANT_EXACT)


def first_occurrence_mask(tokens, *, xp=jnp):
    """Mask of first occurrences (dedup within each row's padded set)."""
    t = tokens[..., :, None] == tokens[..., None, :]  # [.., L, L]
    L = tokens.shape[-1]
    if xp is np:
        earlier = np.tril(np.ones((L, L), dtype=bool), k=-1)
        dup = (t & earlier).any(axis=-1)
    else:
        earlier = jnp.tril(jnp.ones((L, L), dtype=bool), k=-1)
        dup = (t & earlier).any(axis=-1)
    return (tokens != PAD) & ~dup


def _intersection_weight(ent_tokens, ent_valid, win_tokens, win_valid, token_weight, *, xp):
    """w(e ∩ s) for batched padded rows.

    ent_tokens: [..., Le], win_tokens: [..., Lw] — broadcastable leading
    dims. Returns [...] float32.
    """
    eq = ent_tokens[..., :, None] == win_tokens[..., None, :]  # [..., Le, Lw]
    both = eq & ent_valid[..., :, None] & win_valid[..., None, :]
    hit = both.any(axis=-1)  # entity token present in window
    w = token_weight[ent_tokens] * hit
    return w.sum(axis=-1).astype(xp.float32)


def similarity(
    sim_name: str,
    ent_tokens,
    win_tokens,
    token_weight,
    *,
    xp=jnp,
    ent_valid=None,
    win_valid=None,
):
    """Batched weighted similarity between entities and windows.

    Shapes: ``ent_tokens [..., Le]``, ``win_tokens [..., Lw]`` with
    broadcastable leading dims. PAD entries are ignored; duplicate window
    tokens are counted once. Empty windows get similarity 0.
    """
    if ent_valid is None:
        ent_valid = ent_tokens != PAD
    if win_valid is None:
        win_valid = first_occurrence_mask(win_tokens, xp=xp)
    else:
        win_valid = win_valid & first_occurrence_mask(win_tokens, xp=xp)

    inter = _intersection_weight(ent_tokens, ent_valid, win_tokens, win_valid, token_weight, xp=xp)
    w_e = (token_weight[ent_tokens] * ent_valid).sum(axis=-1).astype(xp.float32)
    w_s = (token_weight[win_tokens] * win_valid).sum(axis=-1).astype(xp.float32)

    eps = xp.float32(1e-30)
    if sim_name == SIM_MISSING:
        denom = w_s
    elif sim_name == SIM_EXTRA:
        denom = w_e
    elif sim_name == SIM_JACCARD:
        denom = w_e + w_s - inter
    elif sim_name == SIM_VARIANT_EXACT:
        # subset check: every valid window token occurs in the entity
        eq = win_tokens[..., :, None] == ent_tokens[..., None, :]
        in_e = (eq & ent_valid[..., None, :]).any(axis=-1)
        subset = (~win_valid | in_e).all(axis=-1)
        out = inter / xp.maximum(w_e, eps)
        return xp.where(subset & (w_s > 0), out, xp.float32(0.0))
    else:
        raise ValueError(f"unknown similarity {sim_name!r}")
    out = inter / xp.maximum(denom, eps)
    return xp.where(w_s > 0, out, xp.float32(0.0))

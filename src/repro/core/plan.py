"""Execution-plan space for the EE-Join operator (§5.1).

A plan splits the frequency-sorted dictionary at ``split``: entities
``[0, split)`` (the most frequently mentioned) are processed by the
*head* (algorithm, scheme) pair and ``[split, E)`` by the *tail* pair.
``split == 0`` / ``split == E`` degenerate to the pure single-algorithm
plans, so the hybrid space strictly contains the paper's §3.5 options.
"""
from __future__ import annotations

import dataclasses

from repro.core.cost_model import SideCost


@dataclasses.dataclass(frozen=True)
class PlanSide:
    algo: str  # "index" | "ssjoin"
    scheme: str  # index kind or signature scheme

    def __str__(self) -> str:
        return f"{self.algo}:{self.scheme}"


@dataclasses.dataclass(frozen=True)
class Plan:
    split: int
    head: PlanSide
    tail: PlanSide
    objective: str
    predicted_cost: float
    head_cost: SideCost
    tail_cost: SideCost
    evaluations: int  # cost-model evaluations spent finding this plan

    @property
    def is_pure(self) -> bool:
        return self.split == 0 or self.head == self.tail

    def describe(self, num_entities: int) -> str:
        if self.split == 0:
            return f"pure {self.tail} (cost {self.predicted_cost:.4g}s)"
        if self.split >= num_entities:
            return f"pure {self.head} (cost {self.predicted_cost:.4g}s)"
        return (
            f"hybrid head[0:{self.split}]={self.head} "
            f"tail[{self.split}:{num_entities}]={self.tail} "
            f"(cost {self.predicted_cost:.4g}s)"
        )

"""Entity dictionary container.

Host-side (numpy) representation of the dictionary of entities:
fixed-width padded token-id matrix, token weights, and the descending
mention-frequency order required by the plan-search (Lemma 1).

Token id 0 is reserved as PAD and never appears in an entity.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

PAD = 0


@dataclasses.dataclass
class Dictionary:
    """Padded entity dictionary, sorted by descending mention frequency.

    Attributes:
      tokens: [E, L] int32, PAD-padded entity token ids (duplicate-free
        per entity, original order preserved).
      lengths: [E] int32 number of valid tokens.
      freq: [E] float32 estimated mention frequency (descending).
      token_weight: [V] float32 per-token weight table (w[PAD] = 0).
      entity_weight: [E] float32 total weight per entity.
    """

    tokens: np.ndarray
    lengths: np.ndarray
    freq: np.ndarray
    token_weight: np.ndarray
    entity_weight: np.ndarray

    @property
    def num_entities(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def max_len(self) -> int:
        return int(self.tokens.shape[1])

    @property
    def vocab_size(self) -> int:
        return int(self.token_weight.shape[0])

    def slice(self, start: int, stop: int) -> "Dictionary":
        """Entity-range slice (keeps full weight table)."""
        return Dictionary(
            tokens=self.tokens[start:stop],
            lengths=self.lengths[start:stop],
            freq=self.freq[start:stop],
            token_weight=self.token_weight,
            entity_weight=self.entity_weight[start:stop],
        )

    def valid_mask(self) -> np.ndarray:
        return self.tokens != PAD


def build_dictionary(
    entities: Sequence[Sequence[int]],
    vocab_size: int,
    token_weight: np.ndarray | None = None,
    freq: np.ndarray | None = None,
    max_len: int | None = None,
) -> Dictionary:
    """Build a Dictionary from per-entity token-id lists.

    Duplicate tokens within an entity are dropped (set semantics, first
    occurrence kept). Entities are sorted by descending ``freq``.
    """
    dedup = []
    for ent in entities:
        seen: list[int] = []
        for t in ent:
            t = int(t)
            if t == PAD:
                raise ValueError("token id 0 is reserved as PAD")
            if t >= vocab_size:
                raise ValueError(f"token id {t} out of range {vocab_size}")
            if t not in seen:
                seen.append(t)
        if not seen:
            raise ValueError("empty entity")
        dedup.append(seen)

    L = max_len or max(len(e) for e in dedup)
    if any(len(e) > L for e in dedup):
        raise ValueError("entity longer than max_len")
    E = len(dedup)
    toks = np.zeros((E, L), dtype=np.int32)
    lens = np.zeros((E,), dtype=np.int32)
    for i, ent in enumerate(dedup):
        toks[i, : len(ent)] = ent
        lens[i] = len(ent)

    if token_weight is None:
        token_weight = np.ones((vocab_size,), dtype=np.float32)
    token_weight = token_weight.astype(np.float32).copy()
    token_weight[PAD] = 0.0

    if freq is None:
        freq = np.ones((E,), dtype=np.float32)
    freq = np.asarray(freq, dtype=np.float32)

    order = np.argsort(-freq, kind="stable")
    toks, lens, freq = toks[order], lens[order], freq[order]
    ent_w = token_weight[toks].sum(axis=1).astype(np.float32)
    return Dictionary(toks, lens, freq, token_weight, ent_w)

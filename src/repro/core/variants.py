"""Jaccard variants (paper Definition 2).

A *Jaccard variant* of an entity ``e`` with weight ``w(e)`` is any token
subset ``v ⊆ e`` with ``w(v) >= gamma * w(e)``. A document window whose
token *set* equals a variant of ``e`` is an approximate mention of ``e``
under ``JaccCont_extra >= gamma`` — exactly, with no verification step.

Dictionary-side enumeration happens on the host (numpy) at index /
signature build time with branch-and-bound pruning; the number of
variants is output-bounded and capped per entity. Document-side, windows
are hashed as sets (``hashing.set_hash``) and matched against the
dictionary variants — we never enumerate document-side subsets (the
explosion the paper §2 warns about): every *contiguous* sub-window is
already an extraction candidate, so document-side enumeration is
redundant for contiguous mentions.
"""
from __future__ import annotations

import numpy as np

from repro.core import hashing
from repro.core.dictionary import Dictionary

# Two independent 32-bit set hashes give an effective 64-bit variant key.
VARIANT_SEEDS = (101, 202)


def enumerate_entity_variants(
    tokens: np.ndarray,
    weights: np.ndarray,
    gamma: float,
    max_variants: int = 256,
) -> list[np.ndarray]:
    """All subsets of ``tokens`` with weight >= gamma * total, heaviest first.

    ``tokens``: [n] valid (non-PAD) token ids. Returns a list of index
    subsets (as token-id arrays). Branch-and-bound over tokens sorted by
    descending weight; capped at ``max_variants`` (heaviest kept).
    """
    n = len(tokens)
    order = np.argsort(-weights, kind="stable")
    toks = tokens[order]
    ws = weights[order]
    total = float(ws.sum())
    thresh = gamma * total - 1e-6
    suffix = np.concatenate([np.cumsum(ws[::-1])[::-1], [0.0]])

    out: list[tuple[float, np.ndarray]] = []

    def rec(i: int, cur: list[int], cur_w: float) -> None:
        if len(out) >= 4 * max_variants:
            return
        if cur_w + suffix[i] < thresh:  # cannot reach threshold
            return
        if i == n:
            if cur_w >= thresh and cur:
                out.append((cur_w, np.array(cur, dtype=np.int32)))
            return
        if cur_w >= thresh and cur:
            # Early emit: remaining tokens optional; still recurse to get
            # all supersets/others.
            pass
        rec(i + 1, cur + [int(toks[i])], cur_w + float(ws[i]))
        rec(i + 1, cur, cur_w)

    rec(0, [], 0.0)
    out.sort(key=lambda t: -t[0])
    return [v for _, v in out[:max_variants]]


def variant_keys(
    dictionary: Dictionary, gamma: float, max_variants: int = 256
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Enumerate variant hash keys for every entity.

    Returns (keys1 uint32 [M], keys2 uint32 [M], entity_id int32 [M]),
    where M is the total variant count across entities.
    """
    k1, k2, eid = [], [], []
    for i in range(dictionary.num_entities):
        n = int(dictionary.lengths[i])
        toks = dictionary.tokens[i, :n]
        ws = dictionary.token_weight[toks]
        for v in enumerate_entity_variants(toks, ws, gamma, max_variants):
            valid = np.ones(v.shape, dtype=bool)
            k1.append(int(hashing.set_hash(v, valid, seed=VARIANT_SEEDS[0], xp=np)))
            k2.append(int(hashing.set_hash(v, valid, seed=VARIANT_SEEDS[1], xp=np)))
            eid.append(i)
    return (
        np.array(k1, dtype=np.uint32),
        np.array(k2, dtype=np.uint32),
        np.array(eid, dtype=np.int32),
    )


def window_variant_key(win_tokens, win_valid, *, xp):
    """Set-hash pair of a padded window, matching ``variant_keys``."""
    from repro.core.semantics import first_occurrence_mask

    v = win_valid & first_occurrence_mask(win_tokens, xp=xp)
    return (
        hashing.set_hash(win_tokens, v, seed=VARIANT_SEEDS[0], xp=xp),
        hashing.set_hash(win_tokens, v, seed=VARIANT_SEEDS[1], xp=xp),
    )

"""Signature schemes for the SSJoin shuffle and the entity indexes (§3.3).

A scheme produces, for every item (dictionary entity or document
window), a fixed-width array of uint32 signatures plus a validity mask.
Completeness contract: if ``sim(e, s) >= gamma`` (for the configured
similarity) then ``sigs(e) ∩ sigs(s) != ∅`` — exactly for word/prefix/
variant (contiguous mentions), with high probability for LSH.

Schemes
-------
word     every token is a signature. Complete; heavily skewed.
prefix   entity side emits only its *prefix tokens* — the minimal set of
         rarest tokens whose weight exceeds (1-gamma)*w(e); any window
         covering a gamma-fraction of the entity weight must contain at
         least one of them. Window side emits all tokens. Complete, far
         less entity-side skew, requires verification.
lsh      MinHash banding (B bands × R rows). Probabilistic, requires
         verification; tunable via (B, R).
variant  entity side emits one signature per Jaccard variant (set-hash);
         window side emits its set-hash. Exact for JaccCont_extra on
         contiguous mentions — *no verification needed* (64-bit keys).

Entity-side generation is host-side numpy (dictionary prep); window-side
is jnp (it runs inside the distributed job), with bit-identical hashing.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp

from repro.core import hashing
from repro.core.dictionary import PAD, Dictionary
from repro.core.semantics import first_occurrence_mask
from repro.core.variants import VARIANT_SEEDS, variant_keys

SIG_WORD = "word"
SIG_PREFIX = "prefix"
SIG_LSH = "lsh"
SIG_VARIANT = "variant"
SIG_NAMES = (SIG_WORD, SIG_PREFIX, SIG_LSH, SIG_VARIANT)

# Schemes whose reducer-side matches need verification (paper §3.3).
NEEDS_VERIFY = {SIG_WORD: True, SIG_PREFIX: True, SIG_LSH: True, SIG_VARIANT: False}

_LSH_SEED_BASE = 7000
_TOKEN_SIG_SEED = 11


@dataclasses.dataclass(frozen=True)
class LshParams:
    bands: int = 4
    rows: int = 2


@dataclasses.dataclass
class EntitySignatures:
    """Host-side entity signatures: ragged as (sig, entity_id) pairs."""

    sig: np.ndarray  # [M] uint32
    entity_id: np.ndarray  # [M] int32

    @property
    def count(self) -> int:
        return int(self.sig.shape[0])


def prefix_token_sets(dictionary: Dictionary, gamma: float) -> list[np.ndarray]:
    """Per-entity prefix tokens: minimal rarest-first set with
    cumulative weight > (1-gamma) * w(e) (plus epsilon)."""
    out = []
    # Global order: ascending document frequency == ascending freq rank.
    # We use the token weight table as the rarity proxy (IDF-style weights
    # make rare tokens heavy): order by descending weight, tie-break id.
    for i in range(dictionary.num_entities):
        n = int(dictionary.lengths[i])
        toks = dictionary.tokens[i, :n]
        ws = dictionary.token_weight[toks]
        order = np.lexsort((toks, -ws))  # heaviest (rarest) first
        total = float(ws.sum())
        need = (1.0 - gamma) * total + 1e-6
        acc, chosen = 0.0, []
        for j in order:
            chosen.append(int(toks[j]))
            acc += float(ws[j])
            if acc > need:
                break
        out.append(np.array(chosen, dtype=np.int32))
    return out


def _minhash_np(tokens: np.ndarray, valid: np.ndarray, params: LshParams) -> np.ndarray:
    """[.., B] banded minhash signatures (numpy)."""
    B, R = params.bands, params.rows
    outs = []
    for b in range(B):
        row_mins = []
        for r in range(R):
            h = hashing.hash_u32(tokens, seed=_LSH_SEED_BASE + b * R + r, xp=np)
            h = np.where(valid, h, np.uint32(0xFFFFFFFF))
            row_mins.append(h.min(axis=-1))
        band = row_mins[0]
        for m in row_mins[1:]:
            band = hashing.combine(band, m, xp=np)
        # Tag with band id so bands occupy distinct signature spaces.
        band = hashing.combine(band, np.full_like(band, np.uint32(b + 1)), xp=np)
        outs.append(band)
    return np.stack(outs, axis=-1)


def _minhash_jnp(tokens, valid, params: LshParams):
    B, R = params.bands, params.rows
    outs = []
    for b in range(B):
        row_mins = []
        for r in range(R):
            h = hashing.hash_u32(tokens, seed=_LSH_SEED_BASE + b * R + r, xp=jnp)
            h = jnp.where(valid, h, jnp.uint32(0xFFFFFFFF))
            row_mins.append(h.min(axis=-1))
        band = row_mins[0]
        for m in row_mins[1:]:
            band = hashing.combine(band, m, xp=jnp)
        band = hashing.combine(band, jnp.full_like(band, jnp.uint32(b + 1)), xp=jnp)
        outs.append(band)
    return jnp.stack(outs, axis=-1)


def entity_signatures(
    scheme: str,
    dictionary: Dictionary,
    gamma: float,
    lsh: LshParams = LshParams(),
    max_variants: int = 256,
) -> EntitySignatures:
    """Host-side signature generation for all dictionary entities."""
    E, L = dictionary.tokens.shape
    valid = dictionary.valid_mask()
    if scheme == SIG_WORD:
        sig = hashing.hash_u32(dictionary.tokens, seed=_TOKEN_SIG_SEED, xp=np)
        eid = np.broadcast_to(np.arange(E, dtype=np.int32)[:, None], (E, L))
        keep = valid.ravel()
        return EntitySignatures(sig.ravel()[keep], eid.ravel()[keep].astype(np.int32))
    if scheme == SIG_PREFIX:
        sigs, eids = [], []
        for i, toks in enumerate(prefix_token_sets(dictionary, gamma)):
            h = hashing.hash_u32(toks, seed=_TOKEN_SIG_SEED, xp=np)
            sigs.append(h)
            eids.append(np.full((len(toks),), i, dtype=np.int32))
        return EntitySignatures(np.concatenate(sigs), np.concatenate(eids))
    if scheme == SIG_LSH:
        sig = _minhash_np(dictionary.tokens, valid, lsh)  # [E, B]
        eid = np.broadcast_to(np.arange(E, dtype=np.int32)[:, None], sig.shape)
        return EntitySignatures(
            sig.ravel().astype(np.uint32), eid.ravel().astype(np.int32).copy()
        )
    if scheme == SIG_VARIANT:
        k1, _k2, eid = variant_keys(dictionary, gamma, max_variants)
        return EntitySignatures(k1, eid)
    raise ValueError(f"unknown signature scheme {scheme!r}")


def window_signatures(
    scheme: str,
    win_tokens,
    win_valid,
    gamma: float,
    lsh: LshParams = LshParams(),
):
    """Device-side signatures for padded windows ``[..., L]``.

    Returns (sig uint32 [..., S], mask bool [..., S]).
    """
    del gamma  # window side emits all tokens for word/prefix
    first = win_valid & first_occurrence_mask(win_tokens, xp=jnp)
    if scheme in (SIG_WORD, SIG_PREFIX):
        sig = hashing.hash_u32(win_tokens, seed=_TOKEN_SIG_SEED, xp=jnp)
        return sig, first
    if scheme == SIG_LSH:
        sig = _minhash_jnp(win_tokens, first, lsh)
        has_any = first.any(axis=-1, keepdims=True)
        return sig, jnp.broadcast_to(has_any, sig.shape)
    if scheme == SIG_VARIANT:
        k1 = hashing.set_hash(win_tokens, first, seed=VARIANT_SEEDS[0], xp=jnp)
        sig = k1[..., None]
        has_any = first.any(axis=-1, keepdims=True)
        return sig, has_any
    raise ValueError(f"unknown signature scheme {scheme!r}")


def num_window_signatures(scheme: str, max_len: int, lsh: LshParams = LshParams()) -> int:
    """Static window-side signature width S for a scheme."""
    if scheme in (SIG_WORD, SIG_PREFIX):
        return max_len
    if scheme == SIG_LSH:
        return lsh.bands
    if scheme == SIG_VARIANT:
        return 1
    raise ValueError(f"unknown signature scheme {scheme!r}")

"""EE-Join cost model (paper §4, Definitions 3 & 4) re-derived for a TPU mesh.

Definition 3 (Index-on-Entities), job completion time:

    Cost^index = (|C| / |M|) * C_lookup * ceil(|E| / M_e)

Definition 4 (ISHFilter & SSJoin):

    Cost^ssj = (|C| / |M|) * C_sig + |Sig| * (C_shuffle + C_verify)

We keep the exact structure, re-binding each constant to the TPU memory /
interconnect hierarchy:

* ``|M|``        -> number of devices in the mesh (mappers == shards).
* ``M_e``        -> per-device HBM budget for the replicated index; the
                    index is partitioned and candidates are re-scanned
                    once per partition (the paper's multi-pass).
* ``C_lookup``   -> HBM gather of the postings rows + the verify
                    arithmetic for the candidates they produce.
* ``C_shuffle``  -> all_to_all bytes over ICI. *Work-done* counts
                    aggregate bytes over aggregate bandwidth;
                    *job-completion* divides per-device bytes by a single
                    device's link bandwidth and multiplies by the
                    measured signature skew (the synchronous-mesh
                    analogue of MapReduce stragglers).
* ``C_sig`` / ``C_verify`` -> per-record VPU work, calibrated constants.

Both objectives from the paper are implemented:

* ``work_done``       — aggregate chip-seconds across the mesh,
* ``job_completion``  — critical-path seconds (max over devices), i.e.
                        the work-done divided by |M| with skew
                        multipliers on the shuffle + the per-pass
                        barrier.

All inputs come from ``EEStats`` so any entity range evaluates in O(1);
monotonicity over the frequency-sorted entity order (Lemma 1) follows
from every term being a nonneg. prefix-sum or survivor curve — tested
property-based in ``tests/test_cost_model.py``.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.stats import EEStats

OBJ_WORK = "work_done"
OBJ_JOB = "job_completion"
OBJECTIVES = (OBJ_WORK, OBJ_JOB)

ALGO_INDEX = "index"
ALGO_SSJOIN = "ssjoin"

# (algorithm, scheme) options the operator searches over (§3.5: the two
# kept algorithms; index kinds / signature schemes are the parameters).
INDEX_KINDS = ("word", "prefix", "variant")
SSJ_SCHEMES = ("word", "prefix", "lsh", "variant")
ALL_OPTIONS: tuple[tuple[str, str], ...] = tuple(
    [(ALGO_INDEX, k) for k in INDEX_KINDS] + [(ALGO_SSJOIN, s) for s in SSJ_SCHEMES]
)


@dataclasses.dataclass(frozen=True)
class CostParams:
    """Hardware + calibrated per-record constants (seconds / bytes)."""

    num_devices: int = 256
    hbm_budget_bytes: float = 4e9  # M_e: index budget per device
    ici_bytes_per_s: float = 50e9  # per-device all_to_all throughput
    # calibrated per-record costs (seconds); defaults are TPU-scale
    # estimates, benchmarks re-calibrate on the host (see calibrate()).
    c_enum_per_window: float = 2e-10
    c_filter_per_window: float = 5e-10
    c_sig_per_window: dict | None = None  # scheme -> s/window
    c_probe: float = 2e-9  # ssjoin: per table/bucket probe
    c_verify_pair: float = 6e-9  # ssjoin: per (cand, entity) verification
    # index-family constants, calibrated separately (core/calibrate.py) —
    # a postings probe touches padded index rows and repeats per pass, so
    # its real cost differs from a hash-table probe by large factors.
    c_probe_index: float = 2e-9
    c_verify_index: float = 6e-9
    shuffle_bytes_per_record: float = 4.0 * 8 + 16.0  # window tokens + meta
    dict_prep_per_entity: float = 2e-7  # host-side build, amortised
    # measured filter-survivor density (survivors / enumerated windows),
    # filled in by core.calibrate from gathered statistics; 0.0 = unknown
    # (planning then assumes worst-case [G, NC] candidate lanes). Drives
    # the adaptive lane-width plan below.
    lane_density: float = 0.0

    def sig_cost(self, scheme: str) -> float:
        d = self.c_sig_per_window or {}
        default = {"word": 2e-9, "prefix": 2e-9, "lsh": 1.2e-8, "variant": 4e-9}
        return d.get(scheme, default[scheme])


@dataclasses.dataclass(frozen=True)
class SideCost:
    """Cost breakdown of one plan side (seconds, job-completion basis)."""

    enum: float
    filter: float
    sig: float
    shuffle: float
    lookup: float
    verify: float
    passes: int
    work_done: float  # chip-seconds
    job_completion: float  # wall seconds

    @property
    def total(self) -> dict:
        return dataclasses.asdict(self)


def _zero_side() -> SideCost:
    return SideCost(0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0, 0.0, 0.0)


def cost_index(
    stats: EEStats, params: CostParams, a: int, b: int, kind: str, head: bool
) -> SideCost:
    """Def. 3 for entity range [a, b) processed by Index-on-Entities."""
    if a >= b:
        return _zero_side()
    M = params.num_devices
    p = b if head else a
    surv = stats.head_survivors(p) if head else stats.tail_survivors(p)
    idx_bytes = (
        stats.head_index_bytes(kind, p) if head else stats.tail_index_bytes(kind, p)
    )
    passes = max(1, math.ceil(idx_bytes / params.hbm_budget_bytes))

    enum = stats.num_windows * params.c_enum_per_window
    filt = stats.num_windows * params.c_filter_per_window
    # per pass: every surviving candidate probes its tokens' postings rows
    probes = surv * stats.avg_sigs_per_window
    verify_pairs = stats.range_sum(f"verify_{kind}", a, b)
    lookup = passes * probes * params.c_probe_index
    verify = verify_pairs * params.c_verify_index

    work = enum + filt + lookup + verify  # aggregate record-work
    per_dev = work / M
    return SideCost(
        enum=enum / M,
        filter=filt / M,
        sig=0.0,
        shuffle=0.0,
        lookup=lookup / M,
        verify=verify / M,
        passes=passes,
        work_done=work,
        job_completion=per_dev,
    )


def cost_ssjoin(
    stats: EEStats, params: CostParams, a: int, b: int, scheme: str, head: bool
) -> SideCost:
    """Def. 4 for entity range [a, b) processed by ISHFilter & SSJoin."""
    if a >= b:
        return _zero_side()
    M = params.num_devices
    p = b if head else a
    surv = stats.head_survivors(p) if head else stats.tail_survivors(p)

    if scheme in ("word", "prefix"):
        sigs_per_window = stats.avg_sigs_per_window
    elif scheme == "lsh":
        sigs_per_window = 4.0  # LshParams.bands default; stats carry skew
    else:  # variant
        sigs_per_window = 1.0
    emissions = surv * sigs_per_window  # |Sig|

    enum = stats.num_windows * params.c_enum_per_window
    filt = stats.num_windows * params.c_filter_per_window
    sig = surv * params.sig_cost(scheme)
    shuffle_bytes = emissions * params.shuffle_bytes_per_record
    verify_pairs = stats.range_sum(f"verify_{scheme}", a, b)
    probes = emissions
    verify = probes * params.c_probe + verify_pairs * params.c_verify_pair

    work = enum + filt + sig + verify
    shuffle_work_s = shuffle_bytes / params.ici_bytes_per_s  # aggregate
    skew = stats.sig_skew.get(scheme, 1.0)
    shuffle_job_s = (shuffle_bytes / M) / params.ici_bytes_per_s * skew

    return SideCost(
        enum=enum / M,
        filter=filt / M,
        sig=sig / M,
        shuffle=shuffle_job_s,
        lookup=probes * params.c_probe / M,
        verify=verify_pairs * params.c_verify_pair / M,
        passes=1,
        work_done=work + shuffle_work_s,
        job_completion=(work / M) * skew_mix(skew) + shuffle_job_s,
    )


def skew_mix(skew: float, alpha: float = 0.5) -> float:
    """Verification work lands on signature owners: partially skewed.

    A bucket-skew of ``s`` inflates the critical path of the reducer-side
    work; map-side work stays balanced. ``alpha`` mixes the two.
    """
    return 1.0 + alpha * (skew - 1.0)


def cost_side(
    stats: EEStats,
    params: CostParams,
    a: int,
    b: int,
    algo: str,
    scheme: str,
    head: bool,
) -> SideCost:
    if algo == ALGO_INDEX:
        return cost_index(stats, params, a, b, scheme, head)
    if algo == ALGO_SSJOIN:
        return cost_ssjoin(stats, params, a, b, scheme, head)
    raise ValueError(f"unknown algorithm {algo!r}")


def objective_value(side: SideCost, objective: str) -> float:
    if objective == OBJ_WORK:
        return side.work_done
    if objective == OBJ_JOB:
        return side.job_completion
    raise ValueError(f"unknown objective {objective!r}")


# --------------------------------------------------------------------------
# Adaptive lane-width planning (the density term feeding the two-pass
# compaction in kernels/fused_probe; density measured by core.calibrate)
# --------------------------------------------------------------------------


# --------------------------------------------------------------------------
# Maintenance planning (live dictionary updates, ``repro.updates``):
# the paper's "choice among execution plans" applied to the *maintenance*
# axis — absorb a delta as an open segment, compact segments + tombstones
# into a fresh base, or fully rebuild (re-sort + re-run the §5 search).
# --------------------------------------------------------------------------

MAINT_ABSORB = "absorb"
MAINT_COMPACT = "compact"
MAINT_REBUILD = "rebuild"
MAINT_ACTIONS = (MAINT_ABSORB, MAINT_COMPACT, MAINT_REBUILD)


@dataclasses.dataclass(frozen=True)
class MaintenancePlan:
    """Chosen maintenance action + the cost terms behind it (seconds)."""

    action: str
    absorb_s: float  # build the delta's segment structures (O(delta))
    compact_s: float  # rebuild prepared structures over live entities
    overhead_per_batch_s: float  # extra probe/verify cost of the open
    # segments + tombstones after absorbing, per served batch
    horizon_batches: float  # expected future batches amortising either
    stat_drift: float  # measured-stats drift vs the current plan's


def maintenance_overhead_per_batch(
    params: CostParams,
    probes_per_batch: float,
    open_segments: int,
    dead_entities: int,
    total_entities: int,
) -> float:
    """Per-batch serving overhead of the delta state vs a compacted base.

    Two terms, both straight out of Def. 4's per-record constants:

    * every open segment is one more table/bucket probe per window
      signature (the LSM read amplification) — ``probes_per_batch *
      c_probe`` each;
    * tombstoned entities still occupy the base structures, so the
      dead fraction of probe hits is verified and then masked —
      modeled as that fraction of the batch's pair verifications.
    """
    seg = probes_per_batch * params.c_probe * max(open_segments, 0)
    dead_frac = dead_entities / max(total_entities, 1)
    dead = probes_per_batch * params.c_verify_pair * dead_frac
    return seg + dead


def maintenance_plan(
    params: CostParams,
    *,
    live_entities: int,
    delta_entities: int,
    open_segments: int,
    dead_entities: int,
    total_entities: int,
    probes_per_batch: float,
    horizon_batches: float,
    stat_drift: float = 0.0,
    drift_threshold: float = 0.5,
) -> MaintenancePlan:
    """Absorb vs compact vs rebuild for one incoming delta.

    ``open_segments`` counts the segments *after* absorbing this delta.
    Decision structure (the maintenance analogue of §5's plan choice):

    * **rebuild** when measured statistics drifted past
      ``drift_threshold`` — the plan itself is stale, so paying the
      re-sort + §5 search beats serving a mis-ranked plan;
    * else **compact** when the one-time fold
      (``live_entities * dict_prep_per_entity``) undercuts the open-
      segment + tombstone overhead accumulated over the expected
      horizon — amortised rebuild beats LSM read amplification;
    * else **absorb** (O(delta) build, one more open segment).
    """
    absorb_s = max(delta_entities, 0) * params.dict_prep_per_entity
    compact_s = max(live_entities, 0) * params.dict_prep_per_entity
    overhead = maintenance_overhead_per_batch(
        params, probes_per_batch, open_segments, dead_entities, total_entities
    )
    if stat_drift > drift_threshold:
        action = MAINT_REBUILD
    elif absorb_s + horizon_batches * overhead > compact_s:
        action = MAINT_COMPACT
    else:
        action = MAINT_ABSORB
    return MaintenancePlan(
        action=action,
        absorb_s=absorb_s,
        compact_s=compact_s,
        overhead_per_batch_s=overhead,
        horizon_batches=horizon_batches,
        stat_drift=stat_drift,
    )


def planned_lane_width(
    density: float,
    windows_per_tile: int,
    nc: int,
    slack: float = 2.0,
    floor: int = 8,
) -> int:
    """Predicted emit-pass lane width for a measured survivor density.

    ``density`` is survivors / enumerated windows (``lane_density``);
    a tile of ``windows_per_tile`` windows then carries ~``density *
    windows_per_tile`` survivors, padded by ``slack`` for tile-to-tile
    variance and rounded to the same power-of-two grid the runtime
    sizing uses (``fused_probe.round_lane_width``) so the planned and
    measured widths land on comparable values. Clamped to [floor, nc];
    ``density <= 0`` (unknown) plans the worst-case ``nc`` lanes.
    """
    from repro.kernels.fused_probe import round_lane_width

    if density <= 0.0:
        return int(nc)
    expect = density * float(max(windows_per_tile, 1)) * slack
    return round_lane_width(int(math.ceil(expect)), nc, floor)


def lane_plan(
    D: int,
    T: int,
    max_len: int,
    nc: int,
    density: float,
    bands: int = 4,
    variant_keys: bool = False,
    streamed: bool = False,
) -> dict:
    """Cost the two-pass vs fixed lane trade for one probe geometry.

    Evaluates ``fused_probe.hbm_bytes_fused`` at the worst-case one-pass
    [G, NC] lanes and at the density-planned two-pass width, and
    recommends whichever moves fewer modeled bytes. Returns a dict with
    ``width`` (planned emit width), ``two_pass`` (recommendation),
    ``bytes_fixed`` / ``bytes_two_pass`` and per-pipeline lane bytes —
    the numbers the kernel bench asserts against its measured lanes.

    ``streamed=True`` accounts the single-launch DMA pipeline instead of
    the per-tile launch loop (the packed-bitmap round trip disappears
    from both passes — see ``hbm_bytes_fused``); ``bytes_streamed_delta``
    reports how many modeled bytes streaming saves at the recommended
    plan, the number the corpus bench asserts direction against.
    """
    from repro.kernels.fused_probe import compact_tile_height, hbm_bytes_fused

    bd = compact_tile_height(D, T, nc)
    G = -(-D // bd)
    W = planned_lane_width(density, bd * T * max_len, nc)

    def cost(two_pass: bool, is_streamed: bool) -> int:
        return hbm_bytes_fused(
            D, T, max_len, nc, bands, False, sig_width=1,
            kernel_compact=True,
            lane_width=W if two_pass else None,
            two_pass=two_pass,
            variant_keys=variant_keys,
            streamed=is_streamed,
        )

    fixed = cost(False, streamed)
    two = cost(True, streamed)
    best_per_tile = min(cost(False, False), cost(True, False))
    return {
        "width": W,
        "two_pass": two < fixed,
        "bytes_fixed": fixed,
        "bytes_two_pass": two,
        "lane_bytes_fixed": 2 * G * (1 + nc) * 4,
        "lane_bytes_two_pass": 2 * G * (1 + W) * 4,
        "tiles": G,
        "streamed": streamed,
        "bytes_streamed_delta": best_per_tile - min(fixed, two),
    }

"""The EE-Join operator: statistics → cost-based plan choice → execution.

This is the paper's contribution as a composable module. Usage::

    op = EEJoinOperator(dictionary, EEJoinConfig(gamma=0.8))
    stats = op.gather_statistics(sample_docs, total_docs=len(corpus))
    plan = op.choose_plan(stats)
    prepared = op.prepare(plan)
    matches = op.execute(prepared, doc_tokens)          # single shard
    matches = op.execute_distributed(prepared, sharded) # shard_map (launch/)

The operator is deliberately split into prepare (host-side structure
builds, done once) and execute (pure jitted device function) so the same
prepared plan runs on a laptop shard or a 512-chip mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

import jax.numpy as jnp

from repro.core.cost_model import (
    ALGO_INDEX,
    ALGO_SSJOIN,
    ALL_OPTIONS,
    OBJ_JOB,
    CostParams,
)
from repro.core.dictionary import Dictionary
from repro.core.filter import build_ish_filter
from repro.core.plan import Plan, PlanSide
from repro.core.search import search_plan
from repro.core.signatures import LshParams, entity_signatures
from repro.core.stats import EEStats, gather_stats
from repro.extraction import engine
from repro.extraction.results import Matches, merge_matches


@dataclasses.dataclass(frozen=True)
class EEJoinConfig:
    gamma: float = 0.8
    sim_name: str = "extra"
    objective: str = OBJ_JOB
    use_filter: bool = True
    max_candidates: int = 8192
    result_capacity: int = 16384
    lsh: LshParams = LshParams()
    options: Sequence[tuple[str, str]] = ALL_OPTIONS
    use_kernel: bool = False
    filter_bits: int = 1 << 18
    # kernel-path lane compaction knobs, forwarded to every side's
    # ExtractParams (validated there): adaptive two-pass lane sizing,
    # its emit-width floor, and forced/suppressed in-kernel signatures.
    adaptive_lanes: bool = False
    lane_width: int | None = None
    kernel_sigs: bool | None = None
    # kernel-path streaming: per-shard launch mode for the streaming
    # drivers (None = auto: stream shards spanning >= 2 tiles through
    # the single-launch DMA megakernel; see ExtractParams.streamed) and
    # the device-resident byte budget ``execute_corpus`` sizes spill
    # shards against (None -> sharded.DEFAULT_DEVICE_BUDGET_BYTES).
    streamed: bool | None = None
    device_budget_bytes: int | None = None
    # continuous calibration (serving.replan): how many recent documents
    # a session's ObservedStats ring retains as the statistics sample an
    # online replan re-runs the §5 search over.
    observe_capacity: int = 128


@dataclasses.dataclass
class PreparedSide:
    """One executable side of a plan (device-resident structures)."""

    side: PlanSide
    params: engine.ExtractParams
    ddict: engine.DeviceDictionary
    flt: tuple | None  # (bits, num_bits, num_hashes)
    index_parts: list[engine.BuiltIndex] | None = None
    sig_table: engine.SigTable | None = None


@dataclasses.dataclass
class PreparedPlan:
    plan: Plan
    sides: list[PreparedSide]
    max_entity_len: int


def side_matches(cands: dict, side: PreparedSide, result_capacity: int) -> Matches:
    """Probe + verify one prepared side over compacted candidates.

    Module-level so state that outlives (or never had) an operator can
    execute it — the live-updates subsystem probes base and delta-
    segment ``PreparedSide``s of *pinned past epochs* through here
    (``updates.builders.epoch_side_matches``) while the session's
    operator has already moved on to a compacted base.
    """
    if side.side.algo == ALGO_INDEX:
        m: Matches | None = None
        for part in side.index_parts:
            pm = engine.extract_index_part(cands, part, side.ddict, side.params)
            m = pm if m is None else merge_matches(m, pm, result_capacity)
        return m
    return engine.extract_ssjoin_local(
        cands, side.sig_table, side.ddict, side.params
    )


class EEJoinOperator:
    def __init__(self, dictionary: Dictionary, config: EEJoinConfig = EEJoinConfig()):
        self.dictionary = dictionary
        self.config = config

    # -- §"a means to gather data statistics" --------------------------------
    def gather_statistics(
        self, sample_docs: np.ndarray, total_docs: int, num_shuffle_buckets: int = 256
    ) -> EEStats:
        return gather_stats(
            self.dictionary,
            sample_docs,
            total_docs,
            self.config.gamma,
            lsh=self.config.lsh,
            num_shuffle_buckets=num_shuffle_buckets,
        )

    # -- §5 optimisation ------------------------------------------------------
    def choose_plan(self, stats: EEStats, cost_params: CostParams | None = None) -> Plan:
        return search_plan(
            stats,
            cost_params or CostParams(num_devices=1),
            self.config.objective,
            options=self.config.options,
        )

    # -- plan -> device structures -------------------------------------------
    def _prepare_side(
        self, side: PlanSide, a: int, b: int, hbm_budget: float
    ) -> PreparedSide | None:
        if a >= b:
            return None
        cfg = self.config
        sl = self.dictionary.slice(a, b)
        ddict = engine.DeviceDictionary.from_host(sl, entity_offset=a)
        flt = None
        if cfg.use_filter:
            f = build_ish_filter(sl, cfg.gamma, num_bits=cfg.filter_bits)
            flt = (jnp.asarray(f.bits), f.num_bits, f.num_hashes)
        params = engine.ExtractParams(
            gamma=cfg.gamma,
            scheme=side.scheme,
            sim_name=cfg.sim_name,
            use_filter=cfg.use_filter,
            max_candidates=cfg.max_candidates,
            result_capacity=cfg.result_capacity,
            lsh=cfg.lsh,
            use_kernel=cfg.use_kernel,
            adaptive_lanes=cfg.adaptive_lanes,
            lane_width=cfg.lane_width,
            kernel_sigs=cfg.kernel_sigs,
            streamed=cfg.streamed,
        )
        prepared = PreparedSide(side=side, params=params, ddict=ddict, flt=flt)
        if side.algo == ALGO_INDEX:
            prepared.index_parts = engine.build_index_partitions(
                sl, side.scheme, cfg.gamma, int(hbm_budget), entity_offset=a
            )
        elif side.algo == ALGO_SSJOIN:
            esig = entity_signatures(side.scheme, sl, cfg.gamma, cfg.lsh)
            prepared.sig_table = engine.build_sig_table(esig, entity_offset=a)
        else:
            raise ValueError(side.algo)
        return prepared

    def prepare(
        self, plan: Plan, cost_params: CostParams | None = None
    ) -> PreparedPlan:
        cp = cost_params or CostParams(num_devices=1)
        E = self.dictionary.num_entities
        sides = []
        head = self._prepare_side(plan.head, 0, plan.split, cp.hbm_budget_bytes)
        tail = self._prepare_side(plan.tail, plan.split, E, cp.hbm_budget_bytes)
        for s in (head, tail):
            if s is not None:
                sides.append(s)
        return PreparedPlan(plan=plan, sides=sides, max_entity_len=self.dictionary.max_len)

    # -- distributed preparation / execution ----------------------------------
    def prepare_distributed(
        self, plan: Plan, n_workers: int, cost_params: CostParams | None = None
    ) -> PreparedPlan:
        """Like prepare(), but SSJoin sides get owner-sharded signature
        tables (stacked [n_workers, ...]) for the all_to_all shuffle."""
        from repro.extraction.distributed import build_sharded_sig_tables

        prepared = self.prepare(plan, cost_params)
        for side in prepared.sides:
            if side.side.algo == ALGO_SSJOIN:
                a = side.ddict.entity_offset
                b = a + side.ddict.tokens.shape[0]
                esig = entity_signatures(
                    side.side.scheme,
                    self.dictionary.slice(a, b),
                    self.config.gamma,
                    self.config.lsh,
                )
                side.sig_table, _ = build_sharded_sig_tables(
                    esig, n_workers, entity_offset=a
                )
        return prepared

    def execute_distributed(
        self, prepared: PreparedPlan, doc_tokens, mesh, axis_names: tuple[str, ...]
    ):
        """Run every plan side on the mesh; returns (list[Matches], diags)."""
        from repro.extraction import distributed as D

        out, diags = [], []
        for side in prepared.sides:
            if side.side.algo == ALGO_INDEX:
                m = D.distributed_extract_index(
                    mesh, axis_names, doc_tokens, side, prepared.max_entity_len
                )
                diags.append(None)
            else:
                m, diag = D.distributed_extract_ssjoin(
                    mesh, axis_names, doc_tokens, side, prepared.max_entity_len
                )
                diags.append(diag)
            out.append(m)
        return out, diags

    # -- execution (single shard; distributed wrapper in extraction/) --------
    def side_matches(self, cands: dict, side: PreparedSide) -> Matches:
        """Probe + verify one prepared side over compacted candidates.

        Public because it is the verify-stage body of the serving
        pipeline (``repro.serving.service``): any candidate front end
        that produces the ``compact_candidates`` dict — single-call,
        sharded streaming, or a served micro-batch lane — feeds the
        same probe+verify join through here.
        """
        return side_matches(cands, side, self.config.result_capacity)

    def execute_epoch(self, state, doc_tokens) -> Matches:
        """Versioned execution against one live-updates epoch.

        ``state`` is an ``updates.builders.EpochState``: every plan
        side probes its base structures plus the open delta segments
        over one shared candidate pass, and tombstoned entities are
        masked after the merge. Epoch 0 of an unchanged dictionary is
        bit-identical to ``execute``.
        """
        from repro.updates.builders import execute_epoch as _exec

        return _exec(state, doc_tokens, self.config)

    def execute(self, prepared: PreparedPlan, doc_tokens) -> Matches:
        cfg = self.config
        out: Matches | None = None
        for side in prepared.sides:
            if cfg.use_kernel:
                # fused megakernel: one pass emits survival + (lsh) sigs
                cands = engine.fused_filter_compact(
                    doc_tokens, prepared.max_entity_len, side.flt, side.params
                )
            else:
                base, surv = engine.survival_mask(
                    doc_tokens, prepared.max_entity_len, side.flt, False
                )
                cands = engine.compact_candidates(
                    base, surv, side.params.max_candidates
                )
            m = self.side_matches(cands, side)
            out = m if out is None else merge_matches(out, m, cfg.result_capacity)
        assert out is not None, "empty plan"
        return out

    def execute_sharded(
        self,
        prepared: PreparedPlan,
        doc_tokens,
        mesh=None,
        axis_name: str = "workers",
        shard_docs: int | None = None,
        tile_docs: int | None = None,
        checkpoint_dir: str | None = None,
        stream_stats: dict | None = None,
    ) -> Matches:
        """Streaming execution: the sharded per-device ``fused_probe``
        driver feeds the candidate front end (documents split into
        shards, each device streaming its shard's tiles with the
        in-kernel compaction epilogue), then each plan side verifies
        over the merged global candidate buffer. Bit-identical to
        ``execute`` with ``use_kernel=True``; requires it (candidate
        streaming is a kernel-path feature). With ``mesh=None`` shards
        stream sequentially on the local device. ``checkpoint_dir``
        makes the candidate waves resumable (per-shard lane
        checkpoints, one subdirectory per plan side)."""
        from repro.extraction import sharded as S

        assert self.config.use_kernel, "execute_sharded requires use_kernel=True"
        cfg = self.config
        out: Matches | None = None
        for i, side in enumerate(prepared.sides):
            cands = S.sharded_filter_compact(
                doc_tokens,
                prepared.max_entity_len,
                side.flt,
                side.params,
                mesh=mesh,
                axis_name=axis_name,
                shard_docs=shard_docs,
                tile_docs=tile_docs,
                checkpoint_dir=None if checkpoint_dir is None
                else f"{checkpoint_dir}/side{i}",
                stream_stats=stream_stats,
            )
            m = self.side_matches(cands, side)
            out = m if out is None else merge_matches(out, m, cfg.result_capacity)
        assert out is not None, "empty plan"
        return out

    def execute_corpus(
        self,
        prepared: PreparedPlan,
        corpus,
        shard_docs: int | None = None,
        tile_docs: int | None = None,
        checkpoint_dir: str | None = None,
        stream_stats: dict | None = None,
        fail_after_shards: int | None = None,
    ) -> Matches:
        """Corpus-scale execution over a *file-backed* document set.

        ``corpus`` is a ``sharded.MemmapCorpus`` (or any host [D, T]
        int32 array): shards are file regions staged through one
        reusable host buffer and probed by the single-launch streamed
        megakernel — the corpus is never device-resident, so it may
        exceed the device budget (``config.device_budget_bytes`` sizes
        the shards). With ``checkpoint_dir`` the per-shard lanes are
        persisted (one subdirectory per plan side) and an interrupted
        run resumes to bit-identical merged matches. Verification runs
        over the merged candidate buffer exactly as in ``execute``.
        """
        from repro.extraction import sharded as S

        assert self.config.use_kernel, "execute_corpus requires use_kernel=True"
        cfg = self.config
        out: Matches | None = None
        for i, side in enumerate(prepared.sides):
            cands = S.spill_filter_compact(
                corpus,
                prepared.max_entity_len,
                side.flt,
                side.params,
                device_budget_bytes=cfg.device_budget_bytes,
                shard_docs=shard_docs,
                tile_docs=tile_docs,
                checkpoint_dir=None if checkpoint_dir is None
                else f"{checkpoint_dir}/side{i}",
                stream_stats=stream_stats,
                fail_after_shards=fail_after_shards,
            )
            m = self.side_matches(cands, side)
            out = m if out is None else merge_matches(out, m, cfg.result_capacity)
        assert out is not None, "empty plan"
        return out

"""Plan search (paper §5.2).

For every ordered (head, tail) option pair the split cost is

    f(p) = Cost_head([0, p)) + Cost_tail([p, E))

where ``Cost_head`` is non-decreasing and ``Cost_tail`` non-increasing in
``p`` (Lemma 1 — both are prefix sums / survivor curves over the
frequency-sorted entities). The paper narrows an iterated binary search
over this structure; we implement it as a discrete ternary search over
the bracketed minimum (each iteration shrinks the range by 1/3 — the
same O(log N) evaluation count) plus a tiny local sweep to absorb
plateaus from the ceil() pass term, and verify optimality against
exhaustive enumeration in tests.

The pair loop is a small constant (7 options -> 49 pairs; the paper's
"nine pairs" for three schemes), so total cost-model evaluations are
O(pairs * log N) vs the naive O(pairs * N).
"""
from __future__ import annotations

from typing import Iterable, Sequence

from repro.core.cost_model import (
    ALL_OPTIONS,
    CostParams,
    cost_side,
    objective_value,
)
from repro.core.plan import Plan, PlanSide
from repro.core.stats import EEStats


def _linspace(a: int, b: int, n: int):
    if b <= a:
        return [a]
    step = (b - a) / (n - 1)
    return [a + step * i for i in range(n)]


def _plan_cost(
    stats: EEStats,
    params: CostParams,
    p: int,
    head: PlanSide,
    tail: PlanSide,
    objective: str,
) -> tuple[float, object, object]:
    hc = cost_side(stats, params, 0, p, head.algo, head.scheme, head=True)
    tc = cost_side(stats, params, p, stats.num_entities, tail.algo, tail.scheme, head=False)
    return objective_value(hc, objective) + objective_value(tc, objective), hc, tc


def plan_cost(
    stats: EEStats,
    params: CostParams,
    plan: Plan,
    objective: str | None = None,
) -> float:
    """Modeled cost of an *existing* plan under (possibly newer) params.

    The replan loop's comparison primitive: evaluate a stale plan's
    split/option choice against fresh statistics and refitted constants
    without re-running the search. The split is clamped to the current
    entity count (the dictionary may have grown or compacted since the
    plan was chosen).
    """
    obj = objective or plan.objective
    p = min(max(plan.split, 0), stats.num_entities)
    c, _hc, _tc = _plan_cost(stats, params, p, plan.head, plan.tail, obj)
    return c


def search_pair(
    stats: EEStats,
    params: CostParams,
    head: PlanSide,
    tail: PlanSide,
    objective: str,
    refine_radius: int = 2,
) -> Plan:
    """Ternary-search the split for one (head, tail) option pair."""
    E = stats.num_entities
    evals = 0
    cache: dict[int, tuple[float, object, object]] = {}

    def f(p: int):
        nonlocal evals
        if p not in cache:
            cache[p] = _plan_cost(stats, params, p, head, tail, objective)
            evals += 1
        return cache[p]

    # coarse bracket (always includes the pure plans p=0 and p=E), then
    # ternary-narrow inside the best bracket — O(grid + log N) evals.
    grid = sorted({int(round(x)) for x in _linspace(0, E, 17)})
    gbest = min(grid, key=lambda p: f(p)[0])
    gi = grid.index(gbest)
    lo = grid[max(gi - 1, 0)]
    hi = grid[min(gi + 1, len(grid) - 1)]
    while hi - lo > 3:
        m1 = lo + (hi - lo) // 3
        m2 = hi - (hi - lo) // 3
        if f(m1)[0] <= f(m2)[0]:
            hi = m2
        else:
            lo = m1
    best_p = min(range(lo, hi + 1), key=lambda p: f(p)[0])
    # local refinement absorbs small non-unimodal plateaus (ceil passes)
    for p in range(max(0, best_p - refine_radius), min(E, best_p + refine_radius) + 1):
        if f(p)[0] < f(best_p)[0]:
            best_p = p
    c, hc, tc = f(best_p)
    return Plan(
        split=best_p,
        head=head,
        tail=tail,
        objective=objective,
        predicted_cost=c,
        head_cost=hc,
        tail_cost=tc,
        evaluations=evals,
    )


def search_plan(
    stats: EEStats,
    params: CostParams,
    objective: str,
    options: Sequence[tuple[str, str]] = ALL_OPTIONS,
) -> Plan:
    """Full §5.2 search: all option pairs × split search; returns argmin."""
    best: Plan | None = None
    total_evals = 0
    for ha, hs in options:
        for ta, ts in options:
            plan = search_pair(
                stats, params, PlanSide(ha, hs), PlanSide(ta, ts), objective
            )
            total_evals += plan.evaluations
            if best is None or plan.predicted_cost < best.predicted_cost:
                best = plan
    assert best is not None
    return Plan(
        split=best.split,
        head=best.head,
        tail=best.tail,
        objective=best.objective,
        predicted_cost=best.predicted_cost,
        head_cost=best.head_cost,
        tail_cost=best.tail_cost,
        evaluations=total_evals,
    )


def exhaustive_plan(
    stats: EEStats,
    params: CostParams,
    objective: str,
    options: Sequence[tuple[str, str]] = ALL_OPTIONS,
    stride: int = 1,
) -> Plan:
    """O(pairs * N) oracle search used to validate ``search_plan``."""
    E = stats.num_entities
    best: Plan | None = None
    evals = 0
    for ha, hs in options:
        for ta, ts in options:
            head, tail = PlanSide(ha, hs), PlanSide(ta, ts)
            for p in range(0, E + 1, stride):
                c, hc, tc = _plan_cost(stats, params, p, head, tail, objective)
                evals += 1
                if best is None or c < best.predicted_cost:
                    best = Plan(p, head, tail, objective, c, hc, tc, evals)
    assert best is not None
    return dataclasses_replace_evals(best, evals)


def dataclasses_replace_evals(plan: Plan, evals: int) -> Plan:
    import dataclasses

    return dataclasses.replace(plan, evaluations=evals)

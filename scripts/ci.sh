#!/usr/bin/env bash
# Minimal CI: tier-1 tests + benchmark smoke (fused-kernel parity/drift).
#   bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Seed-inherited model-layer failures (see ROADMAP "Open items") are
# excluded so -x gates on the extraction/kernel suite this repo owns.
python -m pytest -x -q \
  --ignore=tests/test_models_smoke.py \
  --ignore=tests/test_train.py \
  --ignore=tests/test_xlstm_chunkwise.py \
  --ignore=tests/test_flash.py \
  --ignore=tests/test_fused_loss.py
python -m benchmarks.run --smoke

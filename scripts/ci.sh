#!/usr/bin/env bash
# Minimal CI: tier-1 tests + benchmark smoke + docs link check.
#   bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Full tier-1 suite. The model-layer files that used to be excluded here
# (seed-inherited jax.set_mesh / optimization_barrier incompatibilities)
# are green since the repro.compat shims landed, so -x gates on everything.
python -m pytest -x -q

# Benchmark smoke: fused-pipeline parity/drift, the sharded streaming
# scenario (driver + in-kernel compaction epilogue vs legacy XLA
# compaction), the variant + adaptive-lane scenario (fused in-kernel
# variant keys vs window_variant_key, two-pass vs fixed lane bit
# identity, two-pass lane bytes asserted under the fixed [G, NC]
# bytes), the corpus-streaming scenario (single-launch DMA megakernel
# vs per-tile launch loop with bit parity + model-vs-measured
# direction asserted, plus spill streaming with a kill-then-resume
# checkpoint leg), the serving loadgen (N=16 seeded open-loop requests
# through the probe/verify split), the continuous-calibration
# scenario (stationary leg: replanner provably idle with its observe
# overhead reported; drift leg: mid-stream distribution shift ->
# drift-triggered §5 re-search + epoch plan swap, with the swapped
# plan asserted equal to the post-drift oracle search and bit-parity
# held across the swap), and the live-updates scenario
# (delta absorb vs from-scratch rebuild with oracle parity + the
# epoch hot-swap serving leg), and the serving-fabric scenario (framed
# lane transport over loopback vs TCP socket with echoed payloads
# asserted byte-identical, plus delta-replication catch-up vs snapshot
# bootstrap with the caught-up replica's answers asserted bit-equal to
# the one-shot reference). Parity is asserted inside each bench,
# so drift fails CI; rows land in results/bench/{kernels,sharded,
# variant,corpus,corpus_spill,serving,replan,updates,fabric,
# fabric_replication}_smoke.json.
python -m benchmarks.run --smoke

# Serving smoke leg: the real-time (threaded, double-buffered) service
# end to end via the launch entrypoint; --check asserts bit-parity of
# the served matches against a one-shot eejoin.execute.
python -m repro.launch.serve_extract --requests 16 --rate 400 \
    --plan forced --check --replan

# Cluster smoke leg: two replica *processes* over TCP socket channels,
# mixed workload with live replicated deltas mid-stream; --check
# asserts every routed response bit-identical to one_shot_reference at
# the request's admitted epoch.
python -m repro.launch.serve_cluster --replicas 2 --requests 16 \
    --deltas 2 --check

# Docs link check: every relative link in docs/*.md and README.md must
# resolve inside the repo.
python - <<'EOF'
import pathlib
import re
import sys

bad = []
for f in sorted(pathlib.Path("docs").glob("*.md")) + [pathlib.Path("README.md")]:
    if not f.exists():
        bad.append(f"{f}: file missing")
        continue
    for m in re.finditer(r"\[[^\]]*\]\(([^)]+)\)", f.read_text()):
        target = m.group(1).split("#", 1)[0].strip()
        if not target or target.startswith(("http://", "https://", "mailto:")):
            continue
        if not (f.parent / target).exists():
            bad.append(f"{f}: dead link -> {target}")
if bad:
    sys.exit("docs link check failed:\n" + "\n".join(bad))
print("docs link check OK")
EOF

"""Render EXPERIMENTS.md tables from results/dryrun* JSON records.

    PYTHONPATH=src:. python -m benchmarks.report [--section dryrun|roofline|perf]

Markdown to stdout; EXPERIMENTS.md embeds the output.
"""
from __future__ import annotations

import argparse
import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parents[1]
OPT = ROOT / "results" / "dryrun"
BASE = ROOT / "results" / "dryrun_baseline"


def _load(d: pathlib.Path) -> dict:
    out = {}
    for p in sorted(d.glob("*.json")):
        r = json.loads(p.read_text())
        out[(r["arch"], r["shape"], r.get("mesh", "?"))] = r
    return out


def _f(x, n=3):
    if x is None:
        return "—"
    if x == 0:
        return "0"
    if abs(x) >= 1000 or abs(x) < 0.001:
        return f"{x:.2e}"
    return f"{x:.{n}f}"


def section_dryrun(opt: dict) -> None:
    print("| arch | shape | mesh | status | lower+compile s | live GB/dev "
          "| fits 16G | collectives (count) |")
    print("|---|---|---|---|---|---|---|---|")
    for (a, s, m), r in sorted(opt.items()):
        if r["status"] == "ok":
            cc = r["roofline"]["hlo"]["collective_counts"]
            cstr = " ".join(f"{k.replace('all-','a')}:{v}" for k, v in
                            sorted(cc.items()))
            print(f"| {a} | {s} | {m} | ok | "
                  f"{r.get('lower_s',0)}+{r.get('compile_s',0)} | "
                  f"{_f(r['device_live_bytes']/1e9,2)} | "
                  f"{'Y' if r['fits_16g'] else 'N'} | {cstr} |")
        elif r["status"] == "skipped":
            print(f"| {a} | {s} | {m} | skip | — | — | — | "
                  f"{r.get('reason','')[:48]} |")
        else:
            print(f"| {a} | {s} | {m} | **{r['status']}** | — | — | — | |")


def section_roofline(opt: dict, mesh: str = "16x16") -> None:
    print("| arch | shape | compute s | memory s | collective s | "
          "bottleneck | MODEL TFLOPs | useful frac | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for (a, s, m), r in sorted(opt.items()):
        if m != mesh or r["status"] != "ok":
            continue
        rf = r["roofline"]
        print(f"| {a} | {s} | {_f(rf['compute_s'])} | {_f(rf['memory_s'])} "
              f"| {_f(rf['collective_s'])} | {rf['bottleneck']} | "
              f"{_f(rf['model_flops']/1e12,1)} | {_f(rf['useful_frac'])} | "
              f"{_f(rf['roofline_fraction'],4)} |")


def section_perf(opt: dict, base: dict) -> None:
    print("| arch | shape | mesh | term | baseline s | optimized s | Δ |")
    print("|---|---|---|---|---|---|---|")
    for key in sorted(opt):
        ro, rb = opt.get(key), base.get(key)
        if not ro or not rb or ro["status"] != "ok" or rb["status"] != "ok":
            continue
        a, s, m = key
        fo, fb = ro["roofline"], rb["roofline"]
        for term in ("compute_s", "memory_s", "collective_s", "step_time_s"):
            b, o = fb[term], fo[term]
            if b <= 0:
                continue
            delta = (b - o) / b * 100.0
            if abs(delta) < 1.0 and term != "step_time_s":
                continue
            print(f"| {a} | {s} | {m} | {term[:-2]} | {_f(b)} | {_f(o)} | "
                  f"{delta:+.0f}% |")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all",
                    choices=("all", "dryrun", "roofline", "perf"))
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    opt = _load(OPT)
    if args.section in ("all", "dryrun"):
        print("\n### Dry-run (optimized build)\n")
        section_dryrun(opt)
    if args.section in ("all", "roofline"):
        print(f"\n### Roofline ({args.mesh})\n")
        section_roofline(opt, args.mesh)
    if args.section in ("all", "perf"):
        base = _load(BASE)
        print("\n### Perf deltas (baseline -> optimized)\n")
        section_perf(opt, base)


if __name__ == "__main__":
    main()

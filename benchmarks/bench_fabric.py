"""Serving-fabric bench: lane transport throughput + delta replication.

Two questions, parity asserted in-bench so drift fails CI:

* **Transport**: what does the framed lane channel cost? The
  probe→verify handoff frame (``lanes_to_wire`` container inside one
  crc-guarded wire frame) is round-tripped through an in-process
  loopback channel and a real TCP socket pair at several lane
  geometries; every echoed payload is asserted byte-identical before
  it counts. The loopback row isolates codec cost; the socket row adds
  the kernel's loopback TCP path — the gap is the wire tax a remote
  verify pool pays per batch.
* **Replication catch-up**: a replica that missed K deltas can catch
  up two ways — replay the shipped delta chain (epoch-exact, the
  fabric's normal path) or re-bootstrap from a fresh compacted
  snapshot. Rows time both against the same lag and report the bytes
  each moves as the dictionary grows; the caught-up replica's answers
  are asserted bit-identical to ``one_shot_reference`` at the final
  epoch either way.

Rows land in ``results/bench/fabric{,_smoke}.json``.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import emit
from repro.core.eejoin import EEJoinConfig
from repro.data.synth import make_corpus
from repro.extraction.sharded import lanes_from_wire, lanes_to_wire
from repro.fabric.cluster import ClusterCoordinator
from repro.fabric.replica import ReplicaServer, encode_request
from repro.fabric.transport import (
    Endpoint,
    loopback_pair,
    serve_frames,
    socket_pair,
)
from repro.fabric.wire import FT_ACK, FT_LANES, FT_REQUEST, matches_from_wire
from repro.serving import SessionCache, one_shot_reference
from repro.serving.session import pure_plan
from repro.updates.delta import random_delta


def _echo_server(channel):
    def handler(frame):
        return FT_ACK, frame.payload

    th = threading.Thread(target=serve_frames, args=(channel, handler),
                          kwargs={"idle_timeout": 30.0}, daemon=True)
    th.start()
    return th


def _lane_payload(rng, G: int, NC: int, D: int, T: int) -> bytes:
    docs = rng.integers(1, 1000, size=(D, T)).astype(np.int32)
    count = rng.integers(0, NC, size=G).astype(np.int32)
    cand = np.full((G, NC), -1, np.int32)
    for g in range(G):
        n = int(count[g])
        cand[g, :n] = np.sort(rng.choice(100_000, size=n, replace=False))
    keys = rng.integers(0, 2**32, size=(G, NC, 2),
                        dtype=np.uint64).astype(np.uint32)
    return lanes_to_wire(docs, [(count, cand, keys)],
                         {"session": "bench", "epoch": 0})


def bench_transport(smoke: bool) -> list[dict]:
    rng = np.random.default_rng(0)
    geometries = [(1, 512, 4, 64)] if smoke else [
        (1, 512, 4, 64), (2, 2048, 8, 128), (4, 8192, 16, 256),
    ]
    iters = 10 if smoke else 50
    rows = []
    for G, NC, D, T in geometries:
        payload = _lane_payload(rng, G, NC, D, T)
        for chan_name, make_pair in (("loopback", loopback_pair),
                                     ("socket", socket_pair)):
            a, b = make_pair()
            th = _echo_server(b)
            ep = Endpoint(a, timeout=30.0)
            ep.call(FT_LANES, payload)  # warm the path
            t0 = time.perf_counter()
            for _ in range(iters):
                resp = ep.call(FT_LANES, payload)
                assert resp.payload == payload, "echo parity broke"
            dt = time.perf_counter() - t0
            # decoded arrays must survive the trip bit-exactly too
            _meta, docs2, lanes2 = lanes_from_wire(resp.payload)
            assert docs2.dtype == np.int32 and lanes2[0][2].dtype == np.uint32
            a.close()
            th.join(timeout=10)
            rows.append({
                "channel": chan_name,
                "lanes_G": G, "lane_NC": NC, "docs": D, "doc_len": T,
                "frame_bytes": len(payload),
                "rpc_s": dt / iters,
                "mb_per_s": len(payload) * 2 * iters / dt / 1e6,
            })
    return rows


def bench_replication(smoke: bool) -> list[dict]:
    sizes = [128] if smoke else [128, 512, 2048]
    lags = [4] if smoke else [4, 16]
    rows = []
    for num_entities in sizes:
        for lag in lags:
            corpus = make_corpus(num_docs=8, doc_len=48, vocab_size=64,
                                 num_entities=num_entities, seed=5)
            cfg = EEJoinConfig(gamma=0.8, max_candidates=4096,
                               result_capacity=8192, use_kernel=True)
            cache = SessionCache()
            sess = cache.get_or_create(corpus.dictionary, cfg,
                                       plan=pure_plan("word"))
            rng = np.random.default_rng(6)

            # a lagging replica: bootstrapped at epoch 0, then the
            # coordinator applies `lag` deltas it never hears about.
            # Socket channel so the byte counters measure real wire.
            a, b = socket_pair()
            srv = ReplicaServer("lagger")
            th = threading.Thread(target=serve_frames,
                                  args=(b, srv.handle),
                                  kwargs={"idle_timeout": 60.0},
                                  daemon=True)
            th.start()
            coord = ClusterCoordinator({"lagger": Endpoint(a, timeout=60.0)})
            coord.add_session(sess)
            h = coord.handles["lagger"]
            for _ in range(lag):
                sess.apply_delta(
                    random_delta(rng, sess.current_state.version, 64)
                )

            # path 1: replay the delta chain (the fabric's sync path)
            tx0 = getattr(a, "bytes_sent", 0)
            t0 = time.perf_counter()
            coord.sync_session(sess.key)
            catchup_s = time.perf_counter() - t0
            catchup_bytes = getattr(a, "bytes_sent", 0) - tx0
            assert h.acked[sess.key] == sess.epoch, "catch-up diverged"

            docs = np.asarray([corpus.doc_tokens[i] for i in range(4)])
            frame = h.endpoint.call(
                FT_REQUEST, encode_request(sess.key, sess.epoch, docs)
            )
            _m, matches = matches_from_wire(frame.payload)
            want = one_shot_reference(sess, list(docs), epoch=sess.epoch)
            assert matches.to_set() == want, "replayed replica drifted"

            # path 2: fresh snapshot of the same end state (what a
            # brand-new replica would bootstrap from). Snapshots need a
            # compacted base, so compact a coordinator-side copy first.
            from repro.fabric.replica import snapshot_session
            t0 = time.perf_counter()
            sess.apply_delta(
                random_delta(rng, sess.current_state.version, 64),
                force_action="compact",
            )
            snap = snapshot_session(sess)
            snapshot_s = time.perf_counter() - t0
            coord.sync_session(sess.key)  # keep the replica current too
            assert h.acked[sess.key] == sess.epoch

            coord.shutdown()
            th.join(timeout=10)
            rows.append({
                "entities": num_entities,
                "lag_deltas": lag,
                "final_epoch": int(sess.epoch),
                "catchup_s": catchup_s,
                "catchup_bytes": catchup_bytes,
                "snapshot_s": snapshot_s,
                "snapshot_bytes": len(snap),
                "parity_matches": len(want),
            })
    return rows


def main(smoke: bool = False) -> None:
    emit("fabric_smoke" if smoke else "fabric", bench_transport(smoke))
    emit("fabric_replication_smoke" if smoke else "fabric_replication",
         bench_replication(smoke))


if __name__ == "__main__":
    main()

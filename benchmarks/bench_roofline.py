"""Roofline-table reader: aggregates results/dryrun/*.json (deliverable g)
into the per-(arch × shape × mesh) table EXPERIMENTS.md §Roofline embeds.
Run the dry-run first: ``python -m repro.launch.dryrun --all [--both-meshes]``.
"""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import emit

DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_rows(tuned: bool | None = None) -> list[dict]:
    rows = []
    for p in sorted(DRYRUN.glob("*.json")):
        r = json.loads(p.read_text())
        is_tuned = bool(r.get("tuned"))
        if tuned is not None and is_tuned != tuned:
            continue
        base = {
            "arch": r["arch"], "shape": r["shape"], "mesh": r.get("mesh", "?"),
            "tuned": is_tuned, "status": r["status"],
        }
        if r["status"] == "ok":
            rf = r["roofline"]
            base.update({
                "compute_s": rf["compute_s"],
                "memory_s": rf["memory_s"],
                "collective_s": rf["collective_s"],
                "bottleneck": rf["bottleneck"],
                "step_s": rf["step_time_s"],
                "model_gflops": rf["model_flops"] / 1e9,
                "useful_frac": rf["useful_frac"],
                "roofline_frac": rf["roofline_fraction"],
                "live_gb": r.get("device_live_bytes", 0) / 1e9,
                "fits_16g": r.get("fits_16g"),
            })
        else:
            base["bottleneck"] = r.get("reason", r.get("trace", ""))[:60]
        rows.append(base)
    return rows


def main() -> None:
    rows = load_rows()
    emit("roofline", rows)
    ok = [r for r in rows if r["status"] == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r.get("roofline_frac", 1.0))
        coll = max(ok, key=lambda r: r.get("collective_s", 0.0))
        print(f"# worst roofline fraction: {worst['arch']} {worst['shape']} "
              f"{worst['mesh']} ({worst['roofline_frac']:.4f})")
        print(f"# most collective-bound:  {coll['arch']} {coll['shape']} "
              f"{coll['mesh']} ({coll['collective_s']:.3f}s)")


if __name__ == "__main__":
    main()

"""Corpus-scale streaming bench: the single-launch DMA megakernel vs the
per-tile launch loop, plus host spill streaming with resumable shard
merges.

Methodology: both launch modes run the identical sub-tile grid and
epilogue (parity is asserted field-for-field before any timing, CI
fails on drift), so the interpret-mode wall-clock difference measures
the launch restructuring — one ``pallas_call`` whose in-kernel tile
loop replaces ``tiles_per_shard`` separate kernel dispatches. On a real
TPU the same structure additionally overlaps tile i+1's HBM->VMEM DMA
with tile i's recurrence; that claim is carried by the analytic HBM
model (``hbm_bytes_fused(streamed=True)`` — the packed-bitmap round
trip disappears) whose *direction* is asserted against the measured
direction in-bench, and by the guarded real-device leg that records the
first non-interpret validation when a TPU backend is present.

Row schema (see docs/benchmarks.md):
    corpus_streamed — per geometry: per_tile_s / streamed_s / speedup,
        tiles, tiles_per_s, modeled HBM bytes both ways + bytes_saved.
    corpus_spill — over-budget corpus through ``spill_filter_compact``:
        shards, bytes_staged, checkpoint writes/hits for the
        kill-then-resume leg, tiles_per_s end to end.
"""
from __future__ import annotations

import tempfile

import numpy as np

import jax
import jax.numpy as jnp

from repro.extraction import engine as E
from repro.extraction import sharded as SH
from repro.kernels import fused_probe as fp

from benchmarks.common import emit, timeit

GAMMA = 0.8
L = 8
PARITY_KEYS = ("win_tokens", "win_valid", "doc", "pos", "length",
               "n_survive", "overflow")

#: wall-clock floor the streamed launch must clear over the per-tile
#: loop at >= MIN_TILES tiles per shard (the PR's perf acceptance bar)
MIN_SPEEDUP = 1.3
MIN_TILES = 4


def _filter(rng, num_bits=1 << 18, density=0.1):
    w = (rng.random((num_bits // 32, 32)) < density).astype(np.uint32)
    bits = (w << np.arange(32, dtype=np.uint32)).sum(axis=1).astype(np.uint32)
    return (jnp.asarray(bits), num_bits, 3)


def _params(streamed, NC, **kw):
    return E.ExtractParams(gamma=GAMMA, scheme="prefix", max_candidates=NC,
                           use_kernel=True, streamed=streamed, **kw)


def run_streamed(smoke: bool = False) -> list[dict]:
    """Single-launch streamed megakernel vs the per-tile launch loop."""
    rows = []
    rng = np.random.default_rng(41)
    flt = _filter(rng)
    scales = (
        ((32, 128, 8, 256),)
        if smoke
        else ((32, 128, 8, 256), (64, 128, 8, 256), (64, 256, 16, 1024))
    )
    for D, T, td, NC in scales:
        docs = jnp.asarray(rng.integers(1, 65536, size=(D, T)), jnp.int32)
        n_tiles = -(-D // td)
        per_tile = _params(False, NC)
        streamed = _params(True, NC)

        # parity: the full compacted dicts agree field for field (and
        # match the unsharded single call), so the timed probe stage
        # below compares two bit-identical computations
        c_pt = SH.stream_filter_compact(docs, L, flt, per_tile, tile_docs=td)
        c_st = SH.stream_filter_compact(docs, L, flt, streamed, tile_docs=td)
        c_ref = E.fused_filter_compact(docs, L, flt, _params(None, NC))
        for k in PARITY_KEYS:
            assert (np.asarray(c_pt[k]) == np.asarray(c_st[k])).all(), (
                f"streamed parity drift: {k}"
            )
            assert (np.asarray(c_ref[k]) == np.asarray(c_st[k])).all(), (
                f"unsharded parity drift: {k}"
            )
        assert int(c_st["n_survive"]) > 0, "parity must cover real survivors"
        # timing: the probe stage — the launch loop the streamed mode
        # restructures (n_tiles dispatches -> one); the lane merge and
        # window gather after it are identical code either way
        f_pt = lambda: SH.stream_probe_tiles(docs, L, flt, per_tile,
                                             tile_docs=td)[:2]
        f_st = lambda: SH.stream_probe_tiles(docs, L, flt, streamed,
                                             tile_docs=td)[:2]
        t_pt, t_st = timeit(f_pt, iters=7), timeit(f_st, iters=7)
        speedup = t_pt / t_st
        bytes_pt = fp.hbm_bytes_fused(D, T, L, NC, 4, False, sig_width=L,
                                      kernel_compact=True)
        bytes_st = fp.hbm_bytes_fused(D, T, L, NC, 4, False, sig_width=L,
                                      kernel_compact=True, streamed=True)
        # model-vs-measured direction: the model says streaming moves
        # strictly fewer bytes; the measurement must agree on direction
        assert bytes_st < bytes_pt, "HBM model must favor streaming"
        assert speedup > 1.0, (
            f"measured direction contradicts the HBM model at D{D}xT{T}: "
            f"streamed {t_st:.4f}s vs per-tile {t_pt:.4f}s"
        )
        if n_tiles >= MIN_TILES:
            assert speedup >= MIN_SPEEDUP, (
                f"streamed launch must beat the per-tile loop by "
                f">= {MIN_SPEEDUP}x at {n_tiles} tiles/shard, got "
                f"{speedup:.2f}x (D{D}xT{T}/td{td})"
            )
        rows.append({
            "kernel": "corpus_streamed", "shape": f"D{D}xT{T}/td{td}",
            "tiles": n_tiles,
            "per_tile_s": t_pt, "streamed_s": t_st, "speedup": speedup,
            "tiles_per_s": n_tiles / t_st,
            "hbm_bytes_per_tile": bytes_pt, "hbm_bytes_streamed": bytes_st,
            "bytes_saved": bytes_pt - bytes_st,
        })
    return rows


def run_spill(smoke: bool = False) -> list[dict]:
    """Over-budget corpus through spill streaming + kill-then-resume.

    The corpus is a file (``MemmapCorpus``) several times larger than
    the device budget; shards are file regions staged through one host
    buffer. The resume leg kills the job after 2 fresh shards
    (``fail_after_shards``) and restarts it against the checkpoints —
    merged results are asserted bit-identical to the uninterrupted run.
    """
    rows = []
    rng = np.random.default_rng(42)
    flt = _filter(rng)
    D, T, td, NC = (96, 128, 4, 256) if smoke else (384, 256, 16, 1024)
    docs = rng.integers(1, 65536, size=(D, T)).astype(np.int32)
    # budget holds one 4-tile shard double-buffered -> 6-shard corpus,
    # 3x over the device budget
    shard_rows = 4 * td
    budget = shard_rows * T * 4 * 2
    params = _params(True, NC)
    with tempfile.TemporaryDirectory() as tmp:
        corpus = SH.MemmapCorpus.write(f"{tmp}/corpus", docs)
        stats: dict = {}
        f_spill = lambda: SH.spill_filter_compact(
            corpus, L, flt, params, device_budget_bytes=budget,
            tile_docs=td, stream_stats=stats,
        )
        c_spill = f_spill()
        c_ref = E.fused_filter_compact(jnp.asarray(docs), L, flt,
                                       _params(None, NC))
        for k in PARITY_KEYS:
            assert (np.asarray(c_ref[k]) == np.asarray(c_spill[k])).all(), (
                f"spill parity drift: {k}"
            )
        n_shards = -(-D // shard_rows)
        # single-run counters (timeit below re-runs and re-accumulates)
        bytes_staged = stats["spill_bytes_staged"]
        n_tiles = stats["tiles_streamed"]
        assert bytes_staged == n_shards * shard_rows * T * 4
        t_spill = timeit(lambda: f_spill()["n_survive"], iters=3)

        # kill-then-resume: interrupt after 2 fresh shards, restart
        ck: dict = {}
        try:
            SH.spill_filter_compact(
                corpus, L, flt, params, device_budget_bytes=budget,
                tile_docs=td, checkpoint_dir=f"{tmp}/ckpt",
                fail_after_shards=2,
            )
            raise AssertionError("fail_after_shards hook did not fire")
        except RuntimeError:
            pass
        c_resumed = SH.spill_filter_compact(
            corpus, L, flt, params, device_budget_bytes=budget,
            tile_docs=td, checkpoint_dir=f"{tmp}/ckpt", stream_stats=ck,
        )
        for k in PARITY_KEYS:
            assert (np.asarray(c_spill[k]) == np.asarray(c_resumed[k])).all(), (
                f"resume parity drift: {k}"
            )
        assert ck["checkpoint_hits"] == 2, "resume must consume the 2 lanes"
        rows.append({
            "kernel": "corpus_spill", "shape": f"D{D}xT{T}/s{shard_rows}t{td}",
            "shards": n_shards,
            "budget_bytes": budget,
            "corpus_bytes": docs.nbytes,
            "bytes_staged": bytes_staged,
            "spill_s": t_spill,
            "tiles_per_s": n_tiles / t_spill,
            "resume_checkpoint_hits": ck["checkpoint_hits"],
            "resume_checkpoint_writes": ck["checkpoint_writes"],
        })
    return rows


def run_device() -> list[dict]:
    """Real-device leg: re-run the streamed comparison compiled (not
    interpreted) on an accelerator backend. Skips cleanly in interpret
    mode — the first run on a TPU host records the first non-interpret
    validation of the streamed HBM model."""
    if jax.default_backend() != "tpu":
        print("# corpus_device: skipped (no TPU backend; interpret-mode "
              "rows above carry the launch-restructuring measurement)")
        return []
    return run_streamed(smoke=False)


def main(smoke: bool = False) -> None:
    emit("corpus_smoke" if smoke else "corpus_streamed",
         run_streamed(smoke=smoke))
    emit("corpus_spill_smoke" if smoke else "corpus_spill",
         run_spill(smoke=smoke))
    if not smoke:
        rows = run_device()
        if rows:
            emit("corpus_device", rows)


if __name__ == "__main__":
    main()

"""Benchmark aggregator: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--smoke] [--only NAME]

``--smoke`` runs a seconds-long subset (tiny shapes, fused-vs-unfused
parity asserted) so CI catches benchmark drift without a full run.

Sections:
    algorithms   §6 main table (plans × mention distributions)
    cost_model   §4 fidelity (predicted vs measured + rank corr.)
    search       §5.2 plan search vs exhaustive oracle
    signatures   §3.3 signature study (shuffle bytes / skew / recall)
    scaling      §6 dictionary/corpus scaling + plan crossover
    kernels      Pallas kernels vs jnp oracle (interpret mode)
    corpus       corpus-scale streaming: DMA megakernel vs per-tile loop,
                 spill streaming + kill-then-resume checkpoint merges
    serving      async probe/verify serving: load vs latency percentiles
    replan       continuous calibration: replanner overhead + drift swap
    updates      live dictionary deltas: absorb vs rebuild + epoch swap
    fabric       multi-host serving fabric: lane transport throughput
                 (loopback vs socket) + delta-replication catch-up vs
                 snapshot bootstrap, parity asserted in-bench
    roofline     deliverable (g) reader over results/dryrun/
"""
from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import (
    bench_algorithms,
    bench_corpus,
    bench_cost_model,
    bench_fabric,
    bench_hybrid,
    bench_kernels,
    bench_replan,
    bench_roofline,
    bench_scaling,
    bench_search,
    bench_serving,
    bench_signatures,
    bench_updates,
)

SECTIONS = [
    ("algorithms", bench_algorithms.main),
    ("hybrid", bench_hybrid.main),
    ("cost_model", bench_cost_model.main),
    ("search", bench_search.main),
    ("signatures", bench_signatures.main),
    ("scaling", bench_scaling.main),
    ("kernels", bench_kernels.main),
    ("corpus", bench_corpus.main),
    ("serving", bench_serving.main),
    ("replan", bench_replan.main),
    ("updates", bench_updates.main),
    ("fabric", bench_fabric.main),
    ("roofline", bench_roofline.main),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="seconds-long CI subset: kernel pipeline parity + timing only",
    )
    args = ap.parse_args()
    if args.smoke and args.only:
        ap.error("--smoke runs a fixed subset; it cannot be combined with --only")
    if args.smoke:
        t0 = time.time()
        bench_kernels.main(smoke=True)
        print(f"# [kernels --smoke] done in {time.time() - t0:.1f}s", flush=True)
        t0 = time.time()
        bench_corpus.main(smoke=True)
        print(f"# [corpus --smoke] done in {time.time() - t0:.1f}s", flush=True)
        t0 = time.time()
        bench_serving.main(smoke=True)
        print(f"# [serving --smoke] done in {time.time() - t0:.1f}s", flush=True)
        t0 = time.time()
        bench_replan.main(smoke=True)
        print(f"# [replan --smoke] done in {time.time() - t0:.1f}s", flush=True)
        t0 = time.time()
        bench_updates.main(smoke=True)
        print(f"# [updates --smoke] done in {time.time() - t0:.1f}s", flush=True)
        t0 = time.time()
        bench_fabric.main(smoke=True)
        print(f"# [fabric --smoke] done in {time.time() - t0:.1f}s", flush=True)
        return
    failures = []
    for name, fn in SECTIONS:
        if args.only and name != args.only:
            continue
        t0 = time.time()
        try:
            fn()
            print(f"# [{name}] done in {time.time() - t0:.1f}s\n", flush=True)
        except Exception:
            failures.append(name)
            print(f"# [{name}] FAILED\n{traceback.format_exc()}\n", flush=True)
    if failures:
        raise SystemExit(f"benchmark sections failed: {failures}")


if __name__ == "__main__":
    main()

"""The paper's central claim: a HYBRID plan (dictionary split between two
algorithm instances, each with its own filter/index) can beat every pure
plan. This bench measures it in the regime the cost model identifies
(mid-size per-device index budget, large zipf dictionary): each side's
ISH filter prunes to its own entity range, so two half-dictionary passes
verify fewer candidates than one full-dictionary pass.
"""
from __future__ import annotations

import numpy as np

from repro.core.cost_model import ALGO_INDEX, ALGO_SSJOIN, CostParams
from repro.core.eejoin import EEJoinConfig, EEJoinOperator
from repro.core.plan import PlanSide
from repro.data.synth import make_corpus

from benchmarks.common import emit, execute_time, forced_plan

GAMMA = 0.8


def run(iters: int = 3) -> list[dict]:
    rows = []
    c = make_corpus(
        num_docs=32, doc_len=160, vocab_size=16384, num_entities=1024,
        mention_dist="zipf", mentions_per_doc=6.0, seed=5,
    )
    docs = np.asarray(c.doc_tokens)
    E = c.dictionary.num_entities
    op = EEJoinOperator(
        c.dictionary,
        EEJoinConfig(gamma=GAMMA, max_candidates=65536, result_capacity=65536),
    )
    from repro.core.calibrate import calibrate

    cp0 = CostParams(num_devices=1, hbm_budget_bytes=5e4)
    cp = calibrate(op, docs[:8], cp0)
    stats = op.gather_statistics(docs[:16], total_docs=len(docs))
    chosen = op.choose_plan(stats, cp)
    uncal = op.choose_plan(stats, cp0)

    candidates = {
        "pure index:variant": forced_plan(
            E, PlanSide(ALGO_INDEX, "variant"), PlanSide(ALGO_SSJOIN, "variant")
        ),
        "pure ssjoin:variant": forced_plan(
            0, PlanSide(ALGO_INDEX, "variant"), PlanSide(ALGO_SSJOIN, "variant")
        ),
        "pure index:prefix": forced_plan(
            E, PlanSide(ALGO_INDEX, "prefix"), PlanSide(ALGO_SSJOIN, "variant")
        ),
        f"chosen-uncalibrated @{uncal.split}": uncal,
        f"chosen-calibrated @{chosen.split}": chosen,
    }
    for name, plan in candidates.items():
        prepared = op.prepare(plan, cp)
        t = execute_time(op, prepared, docs, iters=iters)
        rows.append({
            "plan": name, "split": plan.split, "seconds": t,
            "head": f"{plan.head.algo}:{plan.head.scheme}",
            "tail": f"{plan.tail.algo}:{plan.tail.scheme}",
            "index_parts": sum(
                len(s.index_parts or []) for s in prepared.sides
            ),
        })
    chosen_t = rows[-1]["seconds"]
    uncal_t = rows[-2]["seconds"]
    best_pure = min(r["seconds"] for r in rows[:-2])
    rows.append({
        "plan": "SUMMARY", "split": chosen.split, "seconds": chosen_t,
        "head": f"chosen/best_pure={chosen_t / best_pure:.2f}x",
        "tail": f"calibration_gain={uncal_t / chosen_t:.1f}x",
        "index_parts": 0,
    })
    return rows


def main() -> None:
    emit("hybrid", run())


if __name__ == "__main__":
    main()

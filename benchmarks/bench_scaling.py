"""Scaling study: how the chosen plan and its runtime move as the
dictionary and corpus grow (paper §6 scaling figures). The interesting
output is the *crossover*: small dictionaries favour pure index plans,
large/hot dictionaries shift the split toward ssjoin.
"""
from __future__ import annotations

import numpy as np

from repro.core.cost_model import CostParams
from repro.core.eejoin import EEJoinConfig, EEJoinOperator
from repro.data.synth import make_corpus

from benchmarks.common import emit, execute_time

GAMMA = 0.8


def run(iters: int = 2) -> list[dict]:
    rows = []
    for E in (32, 128, 512):
        for D in (16, 64):
            c = make_corpus(
                num_docs=D, doc_len=192, vocab_size=8192, num_entities=E,
                mention_dist="zipf", mentions_per_doc=4.0, seed=53,
            )
            docs = np.asarray(c.doc_tokens)
            op = EEJoinOperator(
                c.dictionary,
                EEJoinConfig(gamma=GAMMA, max_candidates=16384,
                             result_capacity=32768),
            )
            cp = CostParams(num_devices=1, hbm_budget_bytes=2e5)
            stats = op.gather_statistics(docs[: max(8, D // 4)], total_docs=D)
            plan = op.choose_plan(stats, cp)
            prepared = op.prepare(plan, cp)
            t = execute_time(op, prepared, docs, iters=iters)
            rows.append({
                "E": E, "docs": D,
                "plan": f"{plan.head.algo}:{plan.head.scheme}|"
                        f"{plan.tail.algo}:{plan.tail.scheme}",
                "split": plan.split,
                "predicted_s": plan.predicted_cost,
                "measured_s": t,
                "search_evals": plan.evaluations,
            })
    return rows


def main() -> None:
    emit("scaling", run())


if __name__ == "__main__":
    main()

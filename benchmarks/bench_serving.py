"""Serving-subsystem bench: open-loop load vs latency/throughput.

A seeded Poisson load generator drives the micro-batched probe/verify
service at several offered loads (docs/s). Arrivals, admission, and
batch composition run on a **virtual clock** (deterministic run-to-run
for a given seed — the batcher's deadline flush compares virtual
stamps only); each flushed batch is then executed for real, its probe
and verify stage wall-times measured separately. Request latency is
accounted with the two-stage pipeline schedule model
(``serving.metrics.pipeline_schedule``) fed with those measured stage
times — once with the double-buffered probe/verify **overlap enabled**
(disjoint pools) and once **disabled** (one worker, stages
back-to-back), so the overlap comparison is controlled: identical
batches, identical measured stage times, only the schedule differs.

As with the kernel benches, CPU interpret-mode wall-clock carries the
*pipeline structure* claim, not TPU memory-system effects. Parity of
the served matches against a one-shot ``eejoin.execute`` over the same
documents is asserted before any row is emitted (CI fails on drift).

Rows land in ``results/bench/serving.json`` (``serving_smoke.json``
for the ``--smoke`` CI leg: loadgen N=16, one load level).
"""
from __future__ import annotations

import numpy as np

from repro.core.eejoin import EEJoinConfig
from repro.data.synth import make_corpus
from repro.serving import (
    BatcherConfig,
    ExtractionService,
    SessionCache,
    make_pools,
    one_shot_reference,
    pipeline_schedule,
)
from repro.serving.metrics import percentiles
from repro.serving.session import pure_plan

from benchmarks.common import emit

SEED = 23
GAMMA = 0.8


class _SimClock:
    """Mutable virtual clock (the load loop advances ``t``)."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def _request_stream(corpus, n_requests: int, rate: float, seed: int):
    """Seeded open-loop arrivals: (arrival_s, doc_id, tokens) tuples."""
    rng = np.random.default_rng(seed)
    lens = rng.integers(16, corpus.doc_tokens.shape[1] + 1, size=n_requests)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
    D = corpus.doc_tokens.shape[0]
    return [
        (float(arrivals[i]), i, corpus.doc_tokens[i % D, : lens[i]])
        for i in range(n_requests)
    ]


def _run_level(cache, sess, stream, batch_docs: int, max_delay_s: float):
    """Execute one load level (serial workers, virtual arrivals).

    Returns (service, batch_records sorted by batch_id). Serial
    execution keeps the per-stage timings clean; both overlap schedules
    are derived from the same records afterwards.
    """
    clock = _SimClock()
    svc = ExtractionService(
        cache,
        pools=make_pools(),
        batcher_config=BatcherConfig(
            max_batch_docs=batch_docs, max_delay_s=max_delay_s
        ),
        queue_capacity=4 * len(stream),
        overlap=False,
        clock=clock,
    )
    with svc:
        for arrival, doc_id, toks in stream:
            clock.t = arrival
            svc.submit(doc_id, toks, sess.key, now=arrival)
            svc.tick(now=arrival)
        svc.drain()
    records = sorted(svc.metrics.batch_records, key=lambda r: r["batch_id"])
    return svc, records


def deterministic_summary(svc, records) -> dict:
    """The virtual-clock-deterministic slice of one level's run.

    Everything here is a pure function of (seed, load level): arrival
    times, admission decisions, batch compositions, flush stamps and
    request->batch assignment — but *not* measured stage wall times.
    The seed-determinism regression test asserts two runs of the same
    level produce byte-identical JSON for this slice.
    """
    reqs = sorted(svc.completed, key=lambda r: r.req_id)
    return {
        "submitted": svc.metrics.submitted,
        "rejected": svc.metrics.rejected,
        "completed": svc.metrics.completed,
        "matches": len(svc.results_set()),
        "batches": [
            {"batch_id": r["batch_id"], "rows": r["rows"],
             "occupancy": r["occupancy"], "flush_s": r["flush_s"],
             "epoch": r["epoch"]}
            for r in records
        ],
        "assignment": [[r.req_id, r.batch_id] for r in reqs],
    }


def _assert_parity(svc, sess, stream) -> int:
    """Served matches must equal one-shot execute over the same docs."""
    docs = [toks for _, _, toks in sorted(stream, key=lambda x: x[1])]
    want = one_shot_reference(sess, docs)
    got = svc.results_set()
    assert got == want, (
        f"serving parity drift: served {len(got)} matches vs one-shot "
        f"{len(want)}"
    )
    assert svc.metrics.overflow_windows == 0, "parity run overflowed"
    return len(want)


def _schedule_rows(level_name, rate, stream, svc, records, n_matches):
    """One row per overlap mode from the same measured stage times."""
    ready = [r["flush_s"] for r in records]
    probe_s = [r["probe_s"] for r in records]
    verify_s = [r["verify_s"] for r in records]
    batch_pos = {r["batch_id"]: i for i, r in enumerate(records)}
    reqs = sorted(svc.completed, key=lambda r: r.req_id)
    arrivals = {r.req_id: r.arrival_s for r in reqs}
    first_arrival = min(a for a, _, _ in stream)
    rows = []
    for overlap in (True, False):
        _, done = pipeline_schedule(ready, probe_s, verify_s, overlap=overlap)
        lat = [done[batch_pos[r.batch_id]] - arrivals[r.req_id] for r in reqs]
        span = max(done) - first_arrival
        p = percentiles(lat)
        rows.append({
            "section": "serving",
            "load": level_name,
            "offered_docs_s": rate,
            "overlap": overlap,
            "requests": len(stream),
            "rejected": svc.metrics.rejected,
            "batches": len(records),
            "occupancy_mean": float(np.mean([r["occupancy"] for r in records])),
            "probe_s_mean": float(np.mean(probe_s)),
            "verify_s_mean": float(np.mean(verify_s)),
            "latency_p50_s": p["p50"],
            "latency_p95_s": p["p95"],
            "latency_p99_s": p["p99"],
            "throughput_docs_s": svc.metrics.docs / span,
            "lanes_per_s": svc.metrics.lanes / span,
            "matches": n_matches,
        })
    return rows


def run_serving(smoke: bool = False) -> list[dict]:
    corpus = make_corpus(
        num_docs=16 if smoke else 64,
        doc_len=96,
        vocab_size=2048,
        num_entities=32,
        seed=SEED,
    )
    cfg = EEJoinConfig(
        gamma=GAMMA, max_candidates=8192, result_capacity=16384,
        use_kernel=True,
    )
    cache = SessionCache()
    sess = cache.get_or_create(corpus.dictionary, cfg,
                               plan=pure_plan("prefix"))
    n = 16 if smoke else 64
    levels = (
        (("smoke", 120.0),)
        if smoke
        else (("low", 40.0), ("med", 120.0), ("high", 360.0))
    )
    # warmup: absorb first-touch op compilation so measured stage times
    # reflect steady-state serving, not cold caches
    warm = _request_stream(corpus, min(n, 8), levels[0][1], SEED + 7)
    _run_level(cache, sess, warm, batch_docs=8, max_delay_s=0.02)

    rows = []
    for name, rate in levels:
        stream = _request_stream(corpus, n, rate, SEED + 1)
        svc, records = _run_level(cache, sess, stream, batch_docs=8,
                                  max_delay_s=0.02)
        n_matches = _assert_parity(svc, sess, stream)
        rows.extend(_schedule_rows(name, rate, stream, svc, records, n_matches))
    return rows


def main(smoke: bool = False) -> None:
    emit("serving_smoke" if smoke else "serving", run_serving(smoke=smoke))


if __name__ == "__main__":
    main()

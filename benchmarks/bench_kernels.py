"""Pallas-kernel microbench: interpret-mode correctness vs the pure-jnp
oracle plus wall-time of the jnp path (the kernels target TPU; interpret
mode timing is meaningless for per-kernel numbers, so we report oracle
timing + max|Δ|).

The ``fused`` section is the exception: it times the *whole*
filter→compact→signature pipeline, fused megakernel vs unfused jnp, both
jitted end-to-end on the same backend. Methodology: interpret-mode
pallas lowers the kernel body through XLA like any jnp code, so the
CPU wall-clock comparison measures the pipeline restructuring (one
streaming pass, packed survival bitmap, no [D,T,L] base materialisation,
two-stage compaction off the bitmap) rather than TPU memory-system
effects; the analytic HBM byte counts (``fused_probe.hbm_bytes_*``)
carry the device-traffic claim.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels import fused_probe as fp

from benchmarks.common import emit, timeit


def run_fused(smoke: bool = False) -> list[dict]:
    """Fused megakernel pipeline vs the unfused jnp pipeline.

    Both sides produce identical (asserted) candidate buffers and
    window signatures; rows record wall-clock and the analytic HBM
    bytes each variant moves per document scale.
    """
    from repro.core.dictionary import PAD
    from repro.core.signatures import LshParams, window_signatures
    from repro.extraction import engine as E

    rows = []
    rng = np.random.default_rng(7)
    L, NC = 8, 4096
    lshp = LshParams()
    # ~5% bit density: the regime the ISH filter targets (sparse survivors)
    w = (rng.random(((1 << 18) // 32, 32)) < 0.05).astype(np.uint32)
    bits = (w << np.arange(32, dtype=np.uint32)).sum(axis=1).astype(np.uint32)
    flt = (jnp.asarray(bits), 1 << 18, 3)
    scales = ((16, 128),) if smoke else ((64, 256), (128, 512), (256, 512))
    for D, T in scales:
        docs = jnp.asarray(rng.integers(1, 65536, size=(D, T)), jnp.int32)
        for scheme in ("prefix", "lsh", "variant"):
            params = E.ExtractParams(
                gamma=0.8, scheme=scheme, max_candidates=NC, use_kernel=True
            )

            def unfused(d):
                base, surv = E.survival_mask(d, L, flt, False)
                c = E.compact_candidates(base, surv, NC)
                s, m = window_signatures(
                    scheme, c["win_tokens"], c["win_tokens"] != PAD, 0.8, lshp
                )
                return c, s, m

            def fused(d):
                c = E.fused_filter_compact(d, L, flt, params)
                s, m = E.window_sigs_for(c, params)
                return c, s, m

            ju, jf = jax.jit(unfused), jax.jit(fused)
            cu, cf = ju(docs), jf(docs)
            assert (np.asarray(cu[1]) == np.asarray(cf[1])).all(), "sig parity"
            assert (
                np.asarray(cu[0]["win_tokens"]) == np.asarray(cf[0]["win_tokens"])
            ).all(), "candidate parity"
            tu, tf = timeit(ju, docs, iters=7), timeit(jf, docs, iters=7)
            S = {"prefix": L, "lsh": lshp.bands, "variant": 1}[scheme]
            rows.append({
                "kernel": "fused_pipeline", "shape": f"D{D}xT{T}/{scheme}",
                "unfused_s": tu, "fused_s": tf, "speedup": tu / tf,
                "hbm_bytes_unfused": fp.hbm_bytes_unfused(D, T, L, NC, S),
                # lsh=False: at these densities resolve_sig_mode picks
                # post-compaction signatures for every scheme, so the
                # model must charge the [N, S] sig store, not the dense
                # in-kernel tensor (the variant key-lane model lives in
                # the variant_adaptive rows)
                "hbm_bytes_fused": fp.hbm_bytes_fused(
                    D, T, L, NC, lshp.bands, False, sig_width=S
                ),
            })
    return rows


def run_variant_adaptive(smoke: bool = False) -> list[dict]:
    """Fused variant scheme + adaptive two-pass lane compaction.

    Two row kinds per document scale, parity asserted before timing:

    * ``variant_fused`` — the fused variant pipeline (in-kernel set-hash
      keys riding the candidate lanes) vs the unfused jnp pipeline
      (survival_mask -> compact -> window_signatures), keys asserted
      bit-identical to ``window_variant_key``.
    * ``adaptive_lanes`` — two-pass (count pass sizes the emit lanes)
      vs the fixed worst-case [G, NC] lanes: bit parity asserted, the
      measured emit width and lane bytes reported next to the HBM
      model's numbers, and the two-pass lane bytes asserted strictly
      below the fixed lane bytes at the measured density.
    """
    from repro.core.cost_model import lane_plan
    from repro.core.dictionary import PAD
    from repro.core.signatures import window_signatures
    from repro.core.variants import window_variant_key
    from repro.extraction import engine as E

    rows = []
    rng = np.random.default_rng(23)
    L, NC = 8, 4096
    # denser filter at the tiny smoke scale so the parity assertions
    # cover real survivors there too (full scales survive at 5%)
    w = (rng.random(((1 << 18) // 32, 32)) < (0.15 if smoke else 0.05))
    w = w.astype(np.uint32)
    bits = (w << np.arange(32, dtype=np.uint32)).sum(axis=1).astype(np.uint32)
    flt = (jnp.asarray(bits), 1 << 18, 3)
    scales = ((16, 128),) if smoke else ((64, 256), (128, 512), (256, 512))
    for D, T in scales:
        docs = jnp.asarray(rng.integers(1, 65536, size=(D, T)), jnp.int32)
        fixed = E.ExtractParams(gamma=0.8, scheme="variant",
                                max_candidates=NC, use_kernel=True)
        adaptive = E.ExtractParams(gamma=0.8, scheme="variant",
                                   max_candidates=NC, use_kernel=True,
                                   adaptive_lanes=True)

        def unfused(d):
            base, surv = E.survival_mask(d, L, flt, False)
            c = E.compact_candidates(base, surv, NC)
            s, m = window_signatures(
                "variant", c["win_tokens"], c["win_tokens"] != PAD, 0.8
            )
            return c, s, m

        f_unf = jax.jit(unfused)
        f_fix = jax.jit(lambda d: E.fused_filter_compact(d, L, flt, fixed))
        f_ad = lambda d: E.fused_filter_compact(d, L, flt, adaptive)
        cu, cf, ca = f_unf(docs), f_fix(docs), f_ad(docs)
        assert int(cf["n_survive"]) > 0, "parity must cover real survivors"
        # fused-vs-unfused parity: candidates, sigs, and raw key pairs
        assert (np.asarray(cu[0]["win_tokens"])
                == np.asarray(cf["win_tokens"])).all(), "candidate parity"
        assert (np.asarray(cu[1]) == np.asarray(cf["sigs"])).all(), "sig parity"
        toks = cu[0]["win_tokens"]
        k1, k2 = window_variant_key(toks, toks != PAD, xp=jnp)
        assert (np.asarray(k1) == np.asarray(cf["variant_keys"][0])).all()
        assert (np.asarray(k2) == np.asarray(cf["variant_keys"][1])).all()
        # two-pass vs one-pass bit identity
        for k in ("win_tokens", "doc", "pos", "length", "n_survive"):
            assert (np.asarray(cf[k]) == np.asarray(ca[k])).all(), (
                f"adaptive parity drift: {k}"
            )
        for a, b in zip(cf["variant_keys"], ca["variant_keys"]):
            assert (np.asarray(a) == np.asarray(b)).all(), "key parity"
        # measured lane geometry
        counts = ops.fused_probe_count(docs, flt, L, NC)
        width = fp.round_lane_width(int(np.asarray(counts).max()), NC)
        bd = fp.compact_tile_height(D, T, NC)
        G = -(-D // bd)
        lane_fixed = 2 * G * (1 + NC) * 4 + 2 * G * NC * 8
        lane_two = 2 * G * (1 + width) * 4 + 2 * G * width * 8
        assert lane_two < lane_fixed, (
            f"two-pass lanes must undercut fixed lanes (W={width}, NC={NC})"
        )
        density = float(int(cf["n_survive"])) / (D * T * L)
        plan = lane_plan(D, T, L, NC, density, variant_keys=True)
        # ~10 ms medians are noisy on small CPU hosts: use wide medians
        iters = 5 if smoke else 15
        tu = timeit(f_unf, docs, iters=iters)
        tf = timeit(f_fix, docs, iters=iters)
        ta = timeit(f_ad, docs, iters=iters)
        rows.append({
            "kernel": "variant_fused", "shape": f"D{D}xT{T}",
            "unfused_s": tu, "fused_s": tf, "speedup": tu / tf,
            "hbm_bytes_unfused": fp.hbm_bytes_unfused(D, T, L, NC, 1),
            "hbm_bytes_fused": fp.hbm_bytes_fused(
                D, T, L, NC, 4, False, sig_width=1, kernel_compact=True,
                variant_keys=True,
            ),
            "width": "", "planned_width": "", "density": "",
            "lane_bytes_fixed": "", "lane_bytes_two_pass": "",
        })
        rows.append({
            "kernel": "adaptive_lanes", "shape": f"D{D}xT{T}",
            "unfused_s": tf, "fused_s": ta, "speedup": tf / ta,
            "hbm_bytes_unfused": fp.hbm_bytes_fused(
                D, T, L, NC, 4, False, sig_width=1, kernel_compact=True,
                variant_keys=True,
            ),
            "hbm_bytes_fused": fp.hbm_bytes_fused(
                D, T, L, NC, 4, False, sig_width=1, kernel_compact=True,
                lane_width=width, two_pass=True, variant_keys=True,
            ),
            "width": width, "planned_width": plan["width"],
            "density": density,
            "lane_bytes_fixed": lane_fixed, "lane_bytes_two_pass": lane_two,
        })
    return rows


def run_variant_calibration() -> list[dict]:
    """Recalibrate c_sig_per_window["variant"] against the fused path.

    Builds a small synthetic corpus, runs ``core.calibrate`` with a
    ``use_kernel=True`` operator (so the ssjoin timing exercises the
    fused variant pipeline end to end), and reports the before/after
    signature constants, the measured lane density, and whether the §5
    plan choice flips under the recalibrated constants.
    """
    from repro.core.calibrate import calibrate, measured_lane_density
    from repro.core.cost_model import CostParams
    from repro.core.eejoin import EEJoinConfig, EEJoinOperator
    from repro.data.synth import make_corpus

    c = make_corpus(num_docs=24, doc_len=96, vocab_size=1024,
                    num_entities=48, seed=3)
    op = EEJoinOperator(
        c.dictionary,
        EEJoinConfig(gamma=0.8, max_candidates=4096, result_capacity=8192,
                     use_kernel=True),
    )
    before = CostParams(num_devices=1)
    after = calibrate(op, np.asarray(c.doc_tokens), before, scheme="variant")
    stats = op.gather_statistics(np.asarray(c.doc_tokens),
                                 total_docs=len(c.doc_tokens))
    plan_before = op.choose_plan(stats, before)
    plan_after = op.choose_plan(stats, after)
    fmt = lambda p: (f"{p.head.algo}:{p.head.scheme}@{p.split}/"
                     f"{p.tail.algo}:{p.tail.scheme}")
    return [{
        "kernel": "variant_calibration", "shape": "D24xT96",
        "c_sig_variant_before": before.sig_cost("variant"),
        "c_sig_variant_after": after.sig_cost("variant"),
        "lane_density": measured_lane_density(stats),
        "plan_before": fmt(plan_before), "plan_after": fmt(plan_after),
        "plan_flipped": fmt(plan_before) != fmt(plan_after),
    }]


def run_sharded(smoke: bool = False) -> list[dict]:
    """Sharded streaming driver + in-kernel compaction epilogue.

    Two comparisons per document scale, parity asserted field-for-field
    before any timing (CI fails on drift):

    * ``compact`` rows: the fused single-call pipeline with the
      in-kernel compaction epilogue vs the legacy XLA bitmap compaction
      (``kernel_compact=False``) — the "last full-bitmap pass" the
      epilogue removes, with the modeled HBM bytes for both.
    * ``driver`` rows: the sharded streaming driver (shards + double-
      buffered tile stream + lane merge) vs the unsharded fused call.
    """
    from repro.extraction import engine as E
    from repro.extraction import sharded as SH

    rows = []
    rng = np.random.default_rng(11)
    L, NC = 8, 4096
    w = (rng.random(((1 << 18) // 32, 32)) < 0.05).astype(np.uint32)
    bits = (w << np.arange(32, dtype=np.uint32)).sum(axis=1).astype(np.uint32)
    flt = (jnp.asarray(bits), 1 << 18, 3)
    scales = (
        ((16, 128, 4, 2),)
        if smoke
        else ((64, 256, 16, 8), (128, 512, 32, 8), (256, 512, 32, 16))
    )
    for D, T, shard_docs, tile_docs in scales:
        docs = jnp.asarray(rng.integers(1, 65536, size=(D, T)), jnp.int32)
        epi = E.ExtractParams(gamma=0.8, scheme="prefix", max_candidates=NC,
                              use_kernel=True)
        xla = E.ExtractParams(gamma=0.8, scheme="prefix", max_candidates=NC,
                              use_kernel=True, kernel_compact=False)

        f_epi = jax.jit(lambda d: E.fused_filter_compact(d, L, flt, epi))
        f_xla = jax.jit(lambda d: E.fused_filter_compact(d, L, flt, xla))
        f_drv = lambda d: SH.sharded_filter_compact(
            d, L, flt, epi, shard_docs=shard_docs, tile_docs=tile_docs
        )
        c_epi, c_xla, c_drv = f_epi(docs), f_xla(docs), f_drv(docs)
        for name, c in (("xla-compact", c_xla), ("sharded-driver", c_drv)):
            for k in ("win_tokens", "doc", "pos", "length", "n_survive"):
                assert (np.asarray(c_epi[k]) == np.asarray(c[k])).all(), (
                    f"parity drift: {name}/{k}"
                )
        t_epi, t_xla = timeit(f_epi, docs), timeit(f_xla, docs)
        t_drv = timeit(f_drv, docs)
        rows.append({
            "kernel": "compact_epilogue", "shape": f"D{D}xT{T}",
            "baseline": "xla-compact", "baseline_s": t_xla,
            "variant": "epilogue", "variant_s": t_epi,
            "speedup": t_xla / t_epi,
            "hbm_bytes_baseline": fp.hbm_bytes_fused(D, T, L, NC, 4, False,
                                                     sig_width=L),
            "hbm_bytes_variant": fp.hbm_bytes_fused(D, T, L, NC, 4, False,
                                                    sig_width=L,
                                                    kernel_compact=True),
            "shards": "", "tiles_per_shard": "",
        })
        rows.append({
            "kernel": "sharded_driver",
            "shape": f"D{D}xT{T}/s{shard_docs}t{tile_docs}",
            "baseline": "unsharded", "baseline_s": t_epi,
            "variant": "sharded-stream", "variant_s": t_drv,
            "speedup": t_epi / t_drv,
            "hbm_bytes_baseline": "", "hbm_bytes_variant": "",
            "shards": -(-D // shard_docs),
            "tiles_per_shard": -(-shard_docs // tile_docs),
        })
    return rows


def run() -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)

    # ---- jaccard_verify: [N, K] pair verification
    for N, K, L in ((256, 8, 8), (1024, 16, 8)):
        V = 4096
        win = jnp.asarray(rng.integers(0, V, size=(N, L)), jnp.int32)
        ent = jnp.asarray(rng.integers(0, V, size=(N, K, L)), jnp.int32)
        w = jnp.asarray(rng.random(V), jnp.float32)
        win_w = w[win]
        ent_w = w[ent] * (ent != 0)
        for mode in ("extra", "missing"):
            got = np.asarray(
                __import__("repro.kernels.jaccard_verify", fromlist=["x"])
                .jaccard_verify_pallas(win, win_w, ent, ent_w, mode=mode,
                                       interpret=True)
            )
            want = np.asarray(ref.jaccard_verify_ref(win, win_w, ent, ent_w, mode))
            t = timeit(jax.jit(
                lambda a, b, c, d: ref.jaccard_verify_ref(a, b, c, d, mode)
            ), win, win_w, ent, ent_w)
            rows.append({
                "kernel": "jaccard_verify", "shape": f"N{N}xK{K}xL{L}/{mode}",
                "max_abs_err": float(np.abs(got - want).max()),
                "oracle_jit_s": t,
            })

    # ---- minhash: banded signatures
    for N, L in ((512, 8), (2048, 16)):
        toks = jnp.asarray(rng.integers(1, 1 << 20, size=(N, L)), jnp.int32)
        valid = jnp.asarray(rng.random((N, L)) < 0.8)
        got = np.asarray(ops.minhash(toks, valid, bands=4, rows=2))
        want = np.asarray(ref.minhash_ref(toks, valid, bands=4, rows=2))
        t = timeit(jax.jit(lambda a, b: ref.minhash_ref(a, b, 4, 2)), toks, valid)
        rows.append({
            "kernel": "minhash", "shape": f"N{N}xL{L}",
            "max_abs_err": float((got != want).sum()),  # exact-match count
            "oracle_jit_s": t,
        })

    # ---- window_filter: fused Bloom probe over all windows
    for D, T in ((4, 128), (8, 256)):
        docs = jnp.asarray(rng.integers(1, 4096, size=(D, T)), jnp.int32)
        bits = jnp.asarray(rng.integers(0, 2, size=(1 << 14,)), jnp.uint8)
        got = np.asarray(ops.window_filter(docs, bits, 1 << 14, 3, 6))
        want = np.asarray(ref.window_filter_ref(docs, bits, 1 << 14, 3, 6))
        t = timeit(jax.jit(
            lambda a, b: ref.window_filter_ref(a, b, 1 << 14, 3, 6)), docs, bits)
        rows.append({
            "kernel": "window_filter", "shape": f"D{D}xT{T}",
            "max_abs_err": float((got != want).sum()),
            "oracle_jit_s": t,
        })
    return rows


def main(smoke: bool = False) -> None:
    # smoke rows go to a separate artifact so CI never clobbers the
    # published full-scale kernels_fused.json / sharded.json evidence
    emit("kernels_smoke" if smoke else "kernels_fused", run_fused(smoke=smoke))
    emit("sharded_smoke" if smoke else "sharded", run_sharded(smoke=smoke))
    # variant-scheme + adaptive-lane leg: fused variant pipeline parity
    # and the two-pass lane model vs measured lane bytes (CI smoke runs
    # the small scale; the full run adds the calibration study)
    emit("variant_smoke" if smoke else "variant_adaptive",
         run_variant_adaptive(smoke=smoke))
    if not smoke:
        emit("variant_calibration", run_variant_calibration())
        emit("kernels", run())


if __name__ == "__main__":
    main()

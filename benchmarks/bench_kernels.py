"""Pallas-kernel microbench: interpret-mode correctness vs the pure-jnp
oracle plus wall-time of the jnp path (the kernels target TPU; interpret
mode timing is meaningless, so we report oracle timing + max|Δ|).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

from benchmarks.common import emit, timeit


def run() -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)

    # ---- jaccard_verify: [N, K] pair verification
    for N, K, L in ((256, 8, 8), (1024, 16, 8)):
        V = 4096
        win = jnp.asarray(rng.integers(0, V, size=(N, L)), jnp.int32)
        ent = jnp.asarray(rng.integers(0, V, size=(N, K, L)), jnp.int32)
        w = jnp.asarray(rng.random(V), jnp.float32)
        win_w = w[win]
        ent_w = w[ent] * (ent != 0)
        for mode in ("extra", "missing"):
            got = np.asarray(
                __import__("repro.kernels.jaccard_verify", fromlist=["x"])
                .jaccard_verify_pallas(win, win_w, ent, ent_w, mode=mode,
                                       interpret=True)
            )
            want = np.asarray(ref.jaccard_verify_ref(win, win_w, ent, ent_w, mode))
            t = timeit(jax.jit(
                lambda a, b, c, d: ref.jaccard_verify_ref(a, b, c, d, mode)
            ), win, win_w, ent, ent_w)
            rows.append({
                "kernel": "jaccard_verify", "shape": f"N{N}xK{K}xL{L}/{mode}",
                "max_abs_err": float(np.abs(got - want).max()),
                "oracle_jit_s": t,
            })

    # ---- minhash: banded signatures
    for N, L in ((512, 8), (2048, 16)):
        toks = jnp.asarray(rng.integers(1, 1 << 20, size=(N, L)), jnp.int32)
        valid = jnp.asarray(rng.random((N, L)) < 0.8)
        got = np.asarray(ops.minhash(toks, valid, bands=4, rows=2))
        want = np.asarray(ref.minhash_ref(toks, valid, bands=4, rows=2))
        t = timeit(jax.jit(lambda a, b: ref.minhash_ref(a, b, 4, 2)), toks, valid)
        rows.append({
            "kernel": "minhash", "shape": f"N{N}xL{L}",
            "max_abs_err": float((got != want).sum()),  # exact-match count
            "oracle_jit_s": t,
        })

    # ---- window_filter: fused Bloom probe over all windows
    for D, T in ((4, 128), (8, 256)):
        docs = jnp.asarray(rng.integers(1, 4096, size=(D, T)), jnp.int32)
        bits = jnp.asarray(rng.integers(0, 2, size=(1 << 14,)), jnp.uint8)
        got = np.asarray(ops.window_filter(docs, bits, 1 << 14, 3, 6))
        want = np.asarray(ref.window_filter_ref(docs, bits, 1 << 14, 3, 6))
        t = timeit(jax.jit(
            lambda a, b: ref.window_filter_ref(a, b, 1 << 14, 3, 6)), docs, bits)
        rows.append({
            "kernel": "window_filter", "shape": f"D{D}xT{T}",
            "max_abs_err": float((got != want).sum()),
            "oracle_jit_s": t,
        })
    return rows


def main() -> None:
    emit("kernels", run())


if __name__ == "__main__":
    main()

"""Pallas-kernel microbench: interpret-mode correctness vs the pure-jnp
oracle plus wall-time of the jnp path (the kernels target TPU; interpret
mode timing is meaningless for per-kernel numbers, so we report oracle
timing + max|Δ|).

The ``fused`` section is the exception: it times the *whole*
filter→compact→signature pipeline, fused megakernel vs unfused jnp, both
jitted end-to-end on the same backend. Methodology: interpret-mode
pallas lowers the kernel body through XLA like any jnp code, so the
CPU wall-clock comparison measures the pipeline restructuring (one
streaming pass, packed survival bitmap, no [D,T,L] base materialisation,
two-stage compaction off the bitmap) rather than TPU memory-system
effects; the analytic HBM byte counts (``fused_probe.hbm_bytes_*``)
carry the device-traffic claim.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels import fused_probe as fp

from benchmarks.common import emit, timeit


def run_fused(smoke: bool = False) -> list[dict]:
    """Fused megakernel pipeline vs the unfused jnp pipeline.

    Both sides produce identical (asserted) candidate buffers and
    window signatures; rows record wall-clock and the analytic HBM
    bytes each variant moves per document scale.
    """
    from repro.core.dictionary import PAD
    from repro.core.signatures import LshParams, window_signatures
    from repro.extraction import engine as E

    rows = []
    rng = np.random.default_rng(7)
    L, NC = 8, 4096
    lshp = LshParams()
    # ~5% bit density: the regime the ISH filter targets (sparse survivors)
    w = (rng.random(((1 << 18) // 32, 32)) < 0.05).astype(np.uint32)
    bits = (w << np.arange(32, dtype=np.uint32)).sum(axis=1).astype(np.uint32)
    flt = (jnp.asarray(bits), 1 << 18, 3)
    scales = ((16, 128),) if smoke else ((64, 256), (128, 512), (256, 512))
    for D, T in scales:
        docs = jnp.asarray(rng.integers(1, 65536, size=(D, T)), jnp.int32)
        for scheme in ("prefix", "lsh"):
            params = E.ExtractParams(
                gamma=0.8, scheme=scheme, max_candidates=NC, use_kernel=True
            )

            def unfused(d):
                base, surv = E.survival_mask(d, L, flt, False)
                c = E.compact_candidates(base, surv, NC)
                s, m = window_signatures(
                    scheme, c["win_tokens"], c["win_tokens"] != PAD, 0.8, lshp
                )
                return c, s, m

            def fused(d):
                c = E.fused_filter_compact(d, L, flt, params)
                s, m = E.window_sigs_for(c, params)
                return c, s, m

            ju, jf = jax.jit(unfused), jax.jit(fused)
            cu, cf = ju(docs), jf(docs)
            assert (np.asarray(cu[1]) == np.asarray(cf[1])).all(), "sig parity"
            assert (
                np.asarray(cu[0]["win_tokens"]) == np.asarray(cf[0]["win_tokens"])
            ).all(), "candidate parity"
            tu, tf = timeit(ju, docs), timeit(jf, docs)
            S = L if scheme == "prefix" else lshp.bands
            rows.append({
                "kernel": "fused_pipeline", "shape": f"D{D}xT{T}/{scheme}",
                "unfused_s": tu, "fused_s": tf, "speedup": tu / tf,
                "hbm_bytes_unfused": fp.hbm_bytes_unfused(D, T, L, NC, S),
                "hbm_bytes_fused": fp.hbm_bytes_fused(
                    D, T, L, NC, lshp.bands, False, sig_width=S
                ),
            })
    return rows


def run_sharded(smoke: bool = False) -> list[dict]:
    """Sharded streaming driver + in-kernel compaction epilogue.

    Two comparisons per document scale, parity asserted field-for-field
    before any timing (CI fails on drift):

    * ``compact`` rows: the fused single-call pipeline with the
      in-kernel compaction epilogue vs the legacy XLA bitmap compaction
      (``kernel_compact=False``) — the "last full-bitmap pass" the
      epilogue removes, with the modeled HBM bytes for both.
    * ``driver`` rows: the sharded streaming driver (shards + double-
      buffered tile stream + lane merge) vs the unsharded fused call.
    """
    from repro.extraction import engine as E
    from repro.extraction import sharded as SH

    rows = []
    rng = np.random.default_rng(11)
    L, NC = 8, 4096
    w = (rng.random(((1 << 18) // 32, 32)) < 0.05).astype(np.uint32)
    bits = (w << np.arange(32, dtype=np.uint32)).sum(axis=1).astype(np.uint32)
    flt = (jnp.asarray(bits), 1 << 18, 3)
    scales = (
        ((16, 128, 4, 2),)
        if smoke
        else ((64, 256, 16, 8), (128, 512, 32, 8), (256, 512, 32, 16))
    )
    for D, T, shard_docs, tile_docs in scales:
        docs = jnp.asarray(rng.integers(1, 65536, size=(D, T)), jnp.int32)
        epi = E.ExtractParams(gamma=0.8, scheme="prefix", max_candidates=NC,
                              use_kernel=True)
        xla = E.ExtractParams(gamma=0.8, scheme="prefix", max_candidates=NC,
                              use_kernel=True, kernel_compact=False)

        f_epi = jax.jit(lambda d: E.fused_filter_compact(d, L, flt, epi))
        f_xla = jax.jit(lambda d: E.fused_filter_compact(d, L, flt, xla))
        f_drv = lambda d: SH.sharded_filter_compact(
            d, L, flt, epi, shard_docs=shard_docs, tile_docs=tile_docs
        )
        c_epi, c_xla, c_drv = f_epi(docs), f_xla(docs), f_drv(docs)
        for name, c in (("xla-compact", c_xla), ("sharded-driver", c_drv)):
            for k in ("win_tokens", "doc", "pos", "length", "n_survive"):
                assert (np.asarray(c_epi[k]) == np.asarray(c[k])).all(), (
                    f"parity drift: {name}/{k}"
                )
        t_epi, t_xla = timeit(f_epi, docs), timeit(f_xla, docs)
        t_drv = timeit(f_drv, docs)
        rows.append({
            "kernel": "compact_epilogue", "shape": f"D{D}xT{T}",
            "baseline": "xla-compact", "baseline_s": t_xla,
            "variant": "epilogue", "variant_s": t_epi,
            "speedup": t_xla / t_epi,
            "hbm_bytes_baseline": fp.hbm_bytes_fused(D, T, L, NC, 4, False,
                                                     sig_width=L),
            "hbm_bytes_variant": fp.hbm_bytes_fused(D, T, L, NC, 4, False,
                                                    sig_width=L,
                                                    kernel_compact=True),
            "shards": "", "tiles_per_shard": "",
        })
        rows.append({
            "kernel": "sharded_driver",
            "shape": f"D{D}xT{T}/s{shard_docs}t{tile_docs}",
            "baseline": "unsharded", "baseline_s": t_epi,
            "variant": "sharded-stream", "variant_s": t_drv,
            "speedup": t_epi / t_drv,
            "hbm_bytes_baseline": "", "hbm_bytes_variant": "",
            "shards": -(-D // shard_docs),
            "tiles_per_shard": -(-shard_docs // tile_docs),
        })
    return rows


def run() -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)

    # ---- jaccard_verify: [N, K] pair verification
    for N, K, L in ((256, 8, 8), (1024, 16, 8)):
        V = 4096
        win = jnp.asarray(rng.integers(0, V, size=(N, L)), jnp.int32)
        ent = jnp.asarray(rng.integers(0, V, size=(N, K, L)), jnp.int32)
        w = jnp.asarray(rng.random(V), jnp.float32)
        win_w = w[win]
        ent_w = w[ent] * (ent != 0)
        for mode in ("extra", "missing"):
            got = np.asarray(
                __import__("repro.kernels.jaccard_verify", fromlist=["x"])
                .jaccard_verify_pallas(win, win_w, ent, ent_w, mode=mode,
                                       interpret=True)
            )
            want = np.asarray(ref.jaccard_verify_ref(win, win_w, ent, ent_w, mode))
            t = timeit(jax.jit(
                lambda a, b, c, d: ref.jaccard_verify_ref(a, b, c, d, mode)
            ), win, win_w, ent, ent_w)
            rows.append({
                "kernel": "jaccard_verify", "shape": f"N{N}xK{K}xL{L}/{mode}",
                "max_abs_err": float(np.abs(got - want).max()),
                "oracle_jit_s": t,
            })

    # ---- minhash: banded signatures
    for N, L in ((512, 8), (2048, 16)):
        toks = jnp.asarray(rng.integers(1, 1 << 20, size=(N, L)), jnp.int32)
        valid = jnp.asarray(rng.random((N, L)) < 0.8)
        got = np.asarray(ops.minhash(toks, valid, bands=4, rows=2))
        want = np.asarray(ref.minhash_ref(toks, valid, bands=4, rows=2))
        t = timeit(jax.jit(lambda a, b: ref.minhash_ref(a, b, 4, 2)), toks, valid)
        rows.append({
            "kernel": "minhash", "shape": f"N{N}xL{L}",
            "max_abs_err": float((got != want).sum()),  # exact-match count
            "oracle_jit_s": t,
        })

    # ---- window_filter: fused Bloom probe over all windows
    for D, T in ((4, 128), (8, 256)):
        docs = jnp.asarray(rng.integers(1, 4096, size=(D, T)), jnp.int32)
        bits = jnp.asarray(rng.integers(0, 2, size=(1 << 14,)), jnp.uint8)
        got = np.asarray(ops.window_filter(docs, bits, 1 << 14, 3, 6))
        want = np.asarray(ref.window_filter_ref(docs, bits, 1 << 14, 3, 6))
        t = timeit(jax.jit(
            lambda a, b: ref.window_filter_ref(a, b, 1 << 14, 3, 6)), docs, bits)
        rows.append({
            "kernel": "window_filter", "shape": f"D{D}xT{T}",
            "max_abs_err": float((got != want).sum()),
            "oracle_jit_s": t,
        })
    return rows


def main(smoke: bool = False) -> None:
    # smoke rows go to a separate artifact so CI never clobbers the
    # published full-scale kernels_fused.json / sharded.json evidence
    emit("kernels_smoke" if smoke else "kernels_fused", run_fused(smoke=smoke))
    emit("sharded_smoke" if smoke else "sharded", run_sharded(smoke=smoke))
    if not smoke:
        emit("kernels", run())


if __name__ == "__main__":
    main()

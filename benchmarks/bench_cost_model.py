"""Cost-model fidelity (paper §4): predicted cost vs measured runtime.

The cost model only has to *rank* plans correctly for the operator to
pick well (its constants are calibrated order-of-magnitude, not
per-host). We report predicted vs measured seconds per plan and the
Spearman rank correlation per distribution.
"""
from __future__ import annotations

import numpy as np

from repro.core.cost_model import (
    ALGO_INDEX, ALGO_SSJOIN, OBJ_JOB, CostParams, cost_side, objective_value,
)
from repro.core.eejoin import EEJoinConfig, EEJoinOperator
from repro.core.plan import PlanSide
from repro.data.synth import MENTION_DISTS, make_corpus

from benchmarks.common import emit, execute_time, forced_plan

GAMMA = 0.8
PLANS = [
    (ALGO_INDEX, "word"), (ALGO_INDEX, "prefix"), (ALGO_INDEX, "variant"),
    (ALGO_SSJOIN, "word"), (ALGO_SSJOIN, "prefix"), (ALGO_SSJOIN, "lsh"),
    (ALGO_SSJOIN, "variant"),
]


def _spearman(a, b) -> float:
    ra = np.argsort(np.argsort(a)).astype(float)
    rb = np.argsort(np.argsort(b)).astype(float)
    ca = ra - ra.mean()
    cb = rb - rb.mean()
    d = np.sqrt((ca * ca).sum() * (cb * cb).sum())
    return float((ca * cb).sum() / d) if d else 0.0


def run(iters: int = 3) -> list[dict]:
    rows = []
    for dist in MENTION_DISTS:
        c = make_corpus(
            num_docs=48, doc_len=192, vocab_size=4096, num_entities=96,
            mention_dist=dist, mentions_per_doc=4.0, seed=23,
        )
        docs = np.asarray(c.doc_tokens)
        op = EEJoinOperator(
            c.dictionary,
            EEJoinConfig(gamma=GAMMA, max_candidates=8192, result_capacity=16384),
        )
        cp = CostParams(num_devices=1, hbm_budget_bytes=2e5)
        stats = op.gather_statistics(docs[:24], total_docs=len(docs))
        E = c.dictionary.num_entities

        preds, meas = [], []
        for algo, scheme in PLANS:
            sc = cost_side(stats, cp, 0, E, algo, scheme, head=True)
            pred = objective_value(sc, OBJ_JOB)
            plan = forced_plan(E, PlanSide(algo, scheme), PlanSide(ALGO_SSJOIN, "prefix"))
            prepared = op.prepare(plan, cp)
            t = execute_time(op, prepared, docs, iters=iters)
            preds.append(pred)
            meas.append(t)
            rows.append({
                "dist": dist, "plan": f"{algo}:{scheme}",
                "predicted_s": pred, "measured_s": t,
            })
        rows.append({
            "dist": dist, "plan": "SPEARMAN",
            "predicted_s": _spearman(np.array(preds), np.array(meas)),
            "measured_s": float("nan"),
        })
    return rows


def main() -> None:
    emit("cost_model", run())


if __name__ == "__main__":
    main()

"""Shared benchmark plumbing: timing, forced plans, CSV/JSON output."""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

import jax

from repro.core.cost_model import OBJ_JOB, CostParams, SideCost
from repro.core.eejoin import EEJoinConfig, EEJoinOperator
from repro.core.plan import Plan, PlanSide

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "bench"


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of ``fn(*args)`` (blocks on jax outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def forced_plan(split: int, head: PlanSide, tail: PlanSide,
                objective: str = OBJ_JOB) -> Plan:
    z = SideCost(0, 0, 0, 0, 0, 0, 0, 0, 0)
    return Plan(split, head, tail, objective, 0.0, z, z, 0)


def execute_time(op: EEJoinOperator, prepared, docs, iters: int = 3) -> float:
    return timeit(lambda: op.execute(prepared, docs), iters=iters)


def emit(name: str, rows: list[dict]) -> None:
    """Print a CSV block and persist JSON under results/bench/."""
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{name}.json").write_text(json.dumps(rows, indent=1, default=str))
    if not rows:
        print(f"# {name}: (no rows)")
        return
    cols = list(rows[0].keys())
    print(f"# ---- {name} ----")
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r.get(c)) for c in cols))


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)

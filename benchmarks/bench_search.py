"""Plan-search quality (paper §5.2): the O(pairs · log N) search vs the
O(pairs · N) exhaustive oracle — same minimum, far fewer cost-model
evaluations. Reported per mention distribution and dictionary size.
"""
from __future__ import annotations

import numpy as np

from repro.core.cost_model import OBJ_JOB, OBJ_WORK, CostParams
from repro.core.eejoin import EEJoinConfig, EEJoinOperator
from repro.core.search import exhaustive_plan, search_plan
from repro.data.synth import MENTION_DISTS, make_corpus

from benchmarks.common import emit

GAMMA = 0.8


def run() -> list[dict]:
    rows = []
    for dist in MENTION_DISTS:
        for E in (64, 256, 1024):
            c = make_corpus(
                num_docs=32, doc_len=160, vocab_size=8192, num_entities=E,
                mention_dist=dist, mentions_per_doc=3.0, seed=31,
            )
            docs = np.asarray(c.doc_tokens)
            op = EEJoinOperator(c.dictionary, EEJoinConfig(gamma=GAMMA))
            stats = op.gather_statistics(docs[:16], total_docs=len(docs))
            cp = CostParams(num_devices=8, hbm_budget_bytes=2e5)
            for obj in (OBJ_JOB, OBJ_WORK):
                fast = search_plan(stats, cp, obj)
                oracle = exhaustive_plan(stats, cp, obj)
                rows.append({
                    "dist": dist, "E": E, "objective": obj,
                    "search_cost": fast.predicted_cost,
                    "oracle_cost": oracle.predicted_cost,
                    "gap_pct": 100.0 * (fast.predicted_cost - oracle.predicted_cost)
                    / max(oracle.predicted_cost, 1e-12),
                    "search_evals": fast.evaluations,
                    "oracle_evals": oracle.evaluations,
                    "search_split": fast.split,
                    "oracle_split": oracle.split,
                    "plan": f"{fast.head.algo}:{fast.head.scheme}|"
                            f"{fast.tail.algo}:{fast.tail.scheme}",
                })
    return rows


def main() -> None:
    emit("search", run())


if __name__ == "__main__":
    main()

"""Live-updates bench: delta absorb vs full rebuild + epoch-swap serving.

Two questions, parity asserted in-bench so drift fails CI:

* **Maintenance**: how much cheaper is absorbing a delta (segment build
  + Bloom bit-union, O(delta)) than the from-scratch rebuild it
  replaces (filter + tables over every live entity, O(|E|))? The
  subsystem's reason to exist is this gap — the acceptance bar is
  ``>= 5x`` at ``<= 10%`` churn on the standard geometry. Every row
  also re-checks the oracle: extraction over the absorbed state must
  equal the rebuild, match for match.
* **Serving swap**: apply a delta to a *live* session between two
  served streams and check both streams against their own epoch's
  one-shot reference (the no-drain hot-swap contract), reporting the
  swap latency next to the full session-rebuild latency it replaces.

Rows land in ``results/bench/updates{,_smoke}.json``.
"""
from __future__ import annotations

import time

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.cost_model import CostParams
from repro.core.eejoin import EEJoinConfig, EEJoinOperator
from repro.extraction import engine
from repro.data.synth import make_corpus
from repro.serving import (
    BatcherConfig,
    ExtractionService,
    SessionCache,
    make_pools,
    one_shot_reference,
    session_cache_summary,
)
from repro.serving.session import pure_plan
from repro import updates as U


def _best_time(fn, iters: int = 5) -> float:
    """Min wall seconds over ``iters`` runs: host-side build timing is
    noise-above-floor (GC, page faults, co-running work), so the
    minimum estimates the true cost far more stably than the median —
    and the absorb-vs-rebuild assertion must not flake under CI load."""
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts))


def _churn_delta(rng, version, corpus, churn: float) -> U.DictionaryDelta:
    """~churn * |E| changed entities, half adds (noisy copies of real
    entities, so they match documents) and half tombstones."""
    d = version.base
    n = max(int(round(churn * d.num_entities)), 2)
    n_add, n_dead = n - n // 2, n // 2
    adds = []
    for _ in range(n_add):
        i = int(rng.integers(0, d.num_entities))
        toks = [int(t) for t in d.tokens[i, : int(d.lengths[i])]]
        if len(toks) > 1:
            toks = toks[:-1]
        # perturb one token so adds are distinct entities
        toks[0] = int(rng.integers(1, d.vocab_size))
        adds.append(tuple(dict.fromkeys(toks)))
    live = np.nonzero(version.live_mask())[0]
    tombs = rng.choice(live, size=min(n_dead, len(live) - 1), replace=False)
    return U.DictionaryDelta(
        added=tuple(adds), tombstones=tuple(int(t) for t in tombs)
    )


def run_delta_vs_rebuild(smoke: bool = False) -> list[dict]:
    """Absorb-vs-rebuild timing + oracle parity per scheme x churn."""
    E = 96 if smoke else 512
    D, T = (8, 128) if smoke else (16, 256)
    # variant over word for the second full leg: the bench times
    # *builds*, and the word scheme's skewed buckets make its verify
    # gather explode at lossless NC (GBs of [N, S*cap] temporaries on
    # CPU) while variant keeps verify tiny and builds expensive —
    # exactly the axis under test
    schemes = ("prefix",) if smoke else ("prefix", "variant")
    churns = (0.05, 0.10) if smoke else (0.02, 0.05, 0.10)
    corpus = make_corpus(
        num_docs=D, doc_len=T, vocab_size=4096, num_entities=E, seed=0
    )
    docs = jnp.asarray(corpus.doc_tokens)
    # capacities sized so neither path overflows (checked below): the
    # union filter admits a superset of the rebuild's survivors, and
    # truncation (surfaced as cands["overflow"]) breaks exact parity —
    # the timing target is the O(delta)-vs-O(|E|) *build* gap, so the
    # probed corpus stays small enough to verify losslessly
    nc = 8192 if smoke else 32768
    cfg = EEJoinConfig(
        gamma=0.8, max_candidates=nc, result_capacity=2 * nc, use_kernel=True
    )
    rows = []
    for scheme in schemes:
        plan = pure_plan(scheme)
        op = EEJoinOperator(corpus.dictionary, cfg)
        prepared = op.prepare(plan)
        state0 = U.initial_epoch(corpus.dictionary, plan, prepared)
        # untimed warmup: first-call dispatch/allocator costs hit both
        # paths once, not the first timed churn row
        warm = _churn_delta(np.random.default_rng(99), state0.version,
                            corpus, 0.05)
        U.rebuild_oracle(
            U.absorb_delta(state0, warm, cfg).version, cfg, plan
        )
        for churn in churns:
            rng = np.random.default_rng(int(churn * 1000))
            delta = _churn_delta(rng, state0.version, corpus, churn)

            t_delta = _best_time(
                lambda: U.absorb_delta(state0, delta, cfg)
            )
            state1 = U.absorb_delta(state0, delta, cfg)

            def rebuild():
                op2, prep2, _ = U.rebuild_oracle(state1.version, cfg, plan)
                return op2, prep2

            t_rebuild = _best_time(rebuild)

            es = state1.sides[-1]
            probe = engine.fused_filter_compact(
                docs, state1.max_len, es.flt, es.params
            )
            assert int(probe["overflow"]) == 0, (
                f"bench geometry overflows the candidate buffer "
                f"({int(probe['n_survive'])} survivors > {cfg.max_candidates}"
                "): truncation order differs between the delta and rebuild "
                "paths, so exact parity needs a lossless probe — shrink "
                "D/T or raise max_candidates"
            )
            got = U.epoch_matches(state1, docs, cfg)
            want = U.oracle_matches(state1.version, cfg, plan, docs)
            assert got == want, (
                f"delta-vs-rebuild parity broke: scheme={scheme} "
                f"churn={churn}: {len(got)} vs {len(want)} matches"
            )
            speedup = t_rebuild / max(t_delta, 1e-12)
            # the >=5x acceptance bar holds on the standard geometry
            # (E=512, where O(delta) vs O(|E|) dominates); the smoke
            # dictionary is small enough that fixed device-put costs
            # blunt the ratio, so it gates on a softer regression bar
            floor = 1.5 if smoke else 5.0
            if churn <= 0.10:
                assert speedup >= floor, (
                    f"delta absorb only {speedup:.1f}x faster than rebuild "
                    f"at churn {churn} (scheme={scheme}, E={E}) — below "
                    f"the >={floor}x bar"
                )
            from repro.core.cost_model import maintenance_plan

            decision = maintenance_plan(
                CostParams(num_devices=1),
                live_entities=state1.version.num_live,
                delta_entities=delta.num_added,
                open_segments=1,
                dead_entities=int(state1.version.tombstones.sum()),
                total_entities=state1.version.total_entities,
                probes_per_batch=float(cfg.max_candidates),
                horizon_batches=64.0,
            )
            rows.append({
                "scheme": scheme,
                "entities": E,
                "churn": churn,
                "added": delta.num_added,
                "tombstoned": delta.num_tombstoned,
                "t_delta_s": t_delta,
                "t_rebuild_s": t_rebuild,
                "speedup": speedup,
                "matches": len(got),
                "planned_action": decision.action,
            })
    emit("updates_smoke" if smoke else "updates", rows)
    return rows


def run_serving_swap(smoke: bool = False) -> list[dict]:
    """Hot-swap a live session between two served streams; parity per
    epoch + swap latency vs the session rebuild it replaces."""
    E = 48 if smoke else 128
    n_docs = 8 if smoke else 24
    corpus = make_corpus(
        num_docs=max(n_docs, 8), doc_len=96, vocab_size=2048,
        num_entities=E, seed=1,
    )
    cfg = EEJoinConfig(
        gamma=0.8, max_candidates=8192, result_capacity=16384, use_kernel=True
    )
    cache = SessionCache()
    sess = cache.get_or_create(corpus.dictionary, cfg,
                               plan=pure_plan("prefix"))
    rng = np.random.default_rng(2)
    lens = rng.integers(24, 97, size=n_docs)
    docs = [np.asarray(corpus.doc_tokens[i % 8, : lens[i]])
            for i in range(n_docs)]

    def serve():
        svc = ExtractionService(
            cache, pools=make_pools(),
            batcher_config=BatcherConfig(max_batch_docs=4, max_delay_s=0.0),
        )
        with svc:
            for i, d in enumerate(docs):
                assert svc.submit(i, d, sess.key, block=True) is not None
                svc.tick()
            svc.drain()
        return svc

    svc0 = serve()
    assert svc0.results_set() == one_shot_reference(sess, docs), \
        "epoch-0 serving parity broke"

    delta = _churn_delta(rng, sess.current_state.version, corpus, 0.10)
    t0 = time.perf_counter()
    sess.apply_delta(delta, force_action="absorb")
    t_swap = time.perf_counter() - t0
    # the eviction+rebuild the swap replaces: a fresh operator prepare
    t0 = time.perf_counter()
    op2 = EEJoinOperator(sess.dictionary, cfg)
    op2.prepare(pure_plan("prefix"))
    t_rebuild = time.perf_counter() - t0

    svc1 = serve()
    assert svc1.results_set() == one_shot_reference(sess, docs), \
        "post-swap serving parity broke"
    cs = session_cache_summary(cache)
    row = cs["per_session"][sess.key]
    return [{
        "entities": E,
        "docs": n_docs,
        "epoch": row["epoch"],
        "open_segments": row["open_segments"],
        "t_swap_s": t_swap,
        "t_session_rebuild_s": t_rebuild,
        "swap_speedup": t_rebuild / max(t_swap, 1e-12),
        "epoch0_matches": len(svc0.results_set()),
        "epoch1_matches": len(svc1.results_set()),
    }]


def main(smoke: bool = False) -> None:
    rows = run_delta_vs_rebuild(smoke=smoke)
    rows_swap = run_serving_swap(smoke=smoke)
    emit("updates_serving_smoke" if smoke else "updates_serving", rows_swap)
    best = max(r["speedup"] for r in rows)
    print(f"# updates: delta absorb up to {best:.1f}x faster than rebuild; "
          f"swap {rows_swap[0]['swap_speedup']:.1f}x faster than session "
          "rebuild (parity asserted)")


if __name__ == "__main__":
    main()

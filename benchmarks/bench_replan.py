"""Continuous-calibration bench: replanner overhead + drift recovery.

Two legs over the drift-injection workload (same two-phase shape as
``tests/harness_drift.py``, rebuilt here from the ``repro.data.synth``
primitives so the bench has no test-package dependency):

* **stationary** — identical phase-A-only streams served with the
  replanner off vs on (inline, tick-driven). The replanner must stay
  idle (0 triggers — drift never crosses the bound) and its observe
  path (window counting, document ring, EWMA folds, step polls) must
  cost < 2% end-to-end wall time. The bound is asserted in the full
  run on best-of-3 medians; the smoke leg reports the measured
  overhead without gating on it (single sample, CI wall-clock noise).
* **drift** — phase A -> phase B mid-stream shift (doc length x2,
  mention density x12, head->tail skew) with the stale plan pinned at
  ``pure index:prefix`` under an engineered cost model (index-probe
  constants x100). Asserted in-bench: the replanner fires and swaps,
  the direction of recovery — the swapped plan's modeled cost never
  exceeds the stale plan's under the same constants, and it equals the
  from-scratch §5 oracle search on a fresh post-drift sample — and
  bit-parity of every served match against ``one_shot_reference``
  across the swap. Measured (reported, not asserted: wall-clock under
  an engineered cost model carries no direction claim): per-doc stage
  time before/after the swap and ``realized_gain``.

Rows land in ``results/bench/replan.json`` (``replan_smoke.json`` for
the CI leg).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.cost_model import CostParams
from repro.core.eejoin import EEJoinConfig
from repro.core.search import search_plan
from repro.data.synth import drift_docs, make_corpus, skewed_mention_probs
from repro.serving import (
    BatcherConfig,
    ExtractionService,
    ReplanConfig,
    SessionCache,
    make_pools,
    one_shot_reference,
    realized_gain,
)
from repro.serving.replan import effective_plan_key
from repro.serving.session import pure_plan

from benchmarks.common import emit

SEED = 29
NUM_ENTITIES = 24
INDEX_COST_SCALE = 100.0

# (num_docs, doc_len, skew kind, mentions/doc, seed)
PHASE_A = (48, 48, "head", 0.5, 11)
PHASE_B = (64, 96, "tail", 6.0, 12)


class _SimClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def _build():
    corpus = make_corpus(num_docs=24, doc_len=64, vocab_size=2048,
                         num_entities=NUM_ENTITIES, max_entity_len=4,
                         seed=5)
    cfg = EEJoinConfig(
        use_kernel=True, max_candidates=32768, result_capacity=16384,
        options=(("index", "prefix"), ("ssjoin", "prefix")),
        observe_capacity=64,
    )
    base = CostParams(num_devices=1)
    cp = dataclasses.replace(
        base,
        c_probe_index=base.c_probe_index * INDEX_COST_SCALE,
        c_verify_index=base.c_verify_index * INDEX_COST_SCALE,
    )
    return corpus, cfg, cp


def _session(corpus, cfg, cp):
    cache = SessionCache()
    sess = cache.get_or_create(corpus.dictionary, cfg,
                               plan=pure_plan("prefix", algo="index"),
                               cost_params=cp)
    return cache, sess


def _phase_docs(dictionary, phase):
    num_docs, doc_len, kind, per_doc, seed = phase
    return drift_docs(
        dictionary, num_docs=num_docs, doc_len=doc_len,
        mention_probs=skewed_mention_probs(NUM_ENTITIES, kind),
        mentions_per_doc=per_doc, seed=seed,
    )


def _replan_cfg() -> ReplanConfig:
    return ReplanConfig(
        thread=False, refit=False, min_batches=3, cooldown_batches=2,
        density_drift=0.5, doc_len_drift=0.5, time_drift=float("inf"),
        halflife_windows=200.0,
    )


def _serve(cache, sess, phases, replan_cfg, wait_mid: int | None = None):
    """Drive the phases through the service; returns (svc, docs, wall_s).

    ``wait_mid``: documents into the final phase after which the loop
    spins (real-time bounded) until the replanner's swap lands — the
    remaining documents then admit on the post-swap epoch.
    """
    clock = _SimClock()
    svc = ExtractionService(
        cache, pools=make_pools(),
        batcher_config=BatcherConfig(max_batch_docs=8, max_delay_s=0.01),
        queue_capacity=4096, overlap=True, clock=clock,
        replan=replan_cfg,
    )
    all_docs = []
    t0 = time.perf_counter()
    with svc:
        doc_id = 0
        for p, docs in enumerate(phases):
            final = p == len(phases) - 1
            for j, row in enumerate(docs):
                if final and wait_mid is not None and j == wait_mid:
                    deadline = time.monotonic() + 90
                    while (svc.metrics.replan_swaps == 0
                           and time.monotonic() < deadline):
                        clock.t += 1e-3
                        svc.tick(now=clock.t)
                        time.sleep(2e-3)
                clock.t += 1 / 600
                svc.submit(doc_id, row, sess.key, now=clock.t)
                svc.tick(now=clock.t)
                doc_id += 1
                all_docs.append(row)
            if not final:
                svc.drain()
                svc.tick(now=clock.t)
                svc.tick(now=clock.t)
        svc.drain()
        svc.tick(now=clock.t)
    return svc, all_docs, time.perf_counter() - t0


def _stationary_wall(corpus, cfg, cp, docs_a, replan_on: bool) -> tuple:
    cache, sess = _session(corpus, cfg, cp)
    svc, docs, wall = _serve(cache, sess, [docs_a],
                             _replan_cfg() if replan_on else None)
    assert svc.metrics.replans == 0, (
        "stationary stream must never trigger a replan"
    )
    assert svc.results_set() == one_shot_reference(sess, docs)
    return wall, svc.metrics.batches


def run_replan(smoke: bool = False) -> list[dict]:
    corpus, cfg, cp = _build()
    docs_a = _phase_docs(corpus.dictionary, PHASE_A)
    docs_b = _phase_docs(corpus.dictionary, PHASE_B)
    rows = []

    # ------------------------------------------------------- stationary
    reps = 1 if smoke else 3
    # warmup absorbs first-touch compilation for both modes
    _stationary_wall(corpus, cfg, cp, docs_a, replan_on=False)
    off = [_stationary_wall(corpus, cfg, cp, docs_a, False)[0]
           for _ in range(reps)]
    on = [_stationary_wall(corpus, cfg, cp, docs_a, True)[0]
          for _ in range(reps)]
    wall_off, wall_on = float(np.median(off)), float(np.median(on))
    overhead = (wall_on - wall_off) / wall_off
    if not smoke:
        assert overhead < 0.02, (
            f"replanner observe-path overhead {overhead:.1%} >= 2% "
            f"(on {wall_on:.3f}s vs off {wall_off:.3f}s)"
        )
    rows.append({
        "section": "replan",
        "leg": "stationary",
        "docs": len(docs_a),
        "wall_off_s": wall_off,
        "wall_on_s": wall_on,
        "overhead_frac": overhead,
        "overhead_asserted": not smoke,
        "replans": 0,
    })

    # ------------------------------------------------------------ drift
    cache, sess = _session(corpus, cfg, cp)
    svc, docs, wall = _serve(cache, sess, [docs_a, docs_b],
                             _replan_cfg(), wait_mid=32)
    assert svc.metrics.replan_swaps >= 1, "drift leg never swapped"
    event = next(e for e in svc.metrics.replan_events if e["swapped"])
    # recovery direction, in the measure the planner optimizes: the
    # swapped plan models no costlier than the stale plan, and matches
    # the from-scratch §5 search on a fresh post-drift sample
    assert event["new_cost_s"] <= event["stale_cost_s"]
    fresh = drift_docs(
        corpus.dictionary, num_docs=32, doc_len=PHASE_B[1],
        mention_probs=skewed_mention_probs(NUM_ENTITIES, PHASE_B[2]),
        mentions_per_doc=PHASE_B[3], seed=99,
    )
    stats = sess.operator.gather_statistics(fresh, total_docs=len(fresh))
    oracle = search_plan(stats, sess.cost_params, sess.config.objective,
                         options=cfg.options)
    assert (effective_plan_key(oracle, NUM_ENTITIES)
            == effective_plan_key(sess.plan, NUM_ENTITIES)), (
        "swapped plan diverged from the post-drift oracle search"
    )
    assert svc.results_set() == one_shot_reference(sess, docs), (
        "bit-parity lost across the replan swap"
    )

    def per_doc_ms(records):
        rs = [r for r in records if r["rows"]]
        t = sum(r["probe_s"] + r["verify_s"] for r in rs)
        return 1e3 * t / max(sum(r["rows"] for r in rs), 1)

    pre = [r for r in svc.metrics.batch_records if r["epoch"] < event["epoch"]]
    post = [r for r in svc.metrics.batch_records
            if r["epoch"] >= event["epoch"]]
    rows.append({
        "section": "replan",
        "leg": "drift",
        "docs": len(docs),
        "wall_s": wall,
        "replans": svc.metrics.replans,
        "swaps": svc.metrics.replan_swaps,
        "trigger": event["reason"],
        "old_plan": event["old_plan"],
        "new_plan": event["new_plan"],
        "stale_cost_s": event["stale_cost_s"],
        "new_cost_s": event["new_cost_s"],
        "predicted_gain": event["predicted_gain"],
        "realized_gain": realized_gain(svc.metrics, event),
        "pre_swap_ms_per_doc": per_doc_ms(pre),
        "post_swap_ms_per_doc": per_doc_ms(post),
        "oracle_plan": oracle.describe(NUM_ENTITIES),
    })
    return rows


def main(smoke: bool = False) -> None:
    emit("replan_smoke" if smoke else "replan", run_replan(smoke=smoke))


if __name__ == "__main__":
    main()

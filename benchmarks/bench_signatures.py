"""Signature-scheme study (paper §3.3): shuffle volume, bucket skew, and
verification load per scheme, measured on the distributed path's own
diagnostics (single-device mesh — volumes and skew are device-count
independent statistics of the data).
"""
from __future__ import annotations

import numpy as np

import jax

from repro.core.cost_model import ALGO_INDEX, ALGO_SSJOIN, CostParams
from repro.core.eejoin import EEJoinConfig, EEJoinOperator
from repro.core.plan import PlanSide
from repro.data.synth import make_corpus
from repro.extraction.oracle import oracle_extract

from benchmarks.common import emit, forced_plan

GAMMA = 0.8
SCHEMES = ("word", "prefix", "lsh", "variant")


def run() -> list[dict]:
    rows = []
    c = make_corpus(
        num_docs=48, doc_len=192, vocab_size=4096, num_entities=96,
        mention_dist="zipf", mentions_per_doc=4.0, seed=41,
    )
    docs = np.asarray(c.doc_tokens)
    op = EEJoinOperator(
        c.dictionary,
        EEJoinConfig(gamma=GAMMA, max_candidates=8192, result_capacity=16384),
    )
    E = c.dictionary.num_entities
    truth_extra = oracle_extract(docs, c.dictionary, GAMMA, "extra")
    truth_var = oracle_extract(docs, c.dictionary, GAMMA, "variant_exact")
    import jax.numpy as jnp

    mesh = jax.make_mesh((1,), ("workers",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    for scheme in SCHEMES:
        plan = forced_plan(0, PlanSide(ALGO_INDEX, "prefix"),
                           PlanSide(ALGO_SSJOIN, scheme))
        prepared = op.prepare_distributed(plan, 1, CostParams(num_devices=1))
        with mesh:
            ms, diags = op.execute_distributed(
                prepared, jnp.asarray(docs), mesh, ("workers",)
            )
        d = diags[0]
        got = set().union(*[m.to_set() for m in ms])
        truth = truth_var if scheme == "variant" else truth_extra
        rows.append({
            "scheme": scheme,
            "shuffle_bytes": int(d.bytes_shuffled),
            "send_overflow": int(d.send_overflow),
            "max_received": float(d.max_received),
            "mean_received": float(d.mean_received),
            "recall": len(got & truth) / max(len(truth), 1),
            "precision": len(got & truth) / max(len(got), 1),
        })
    # host-side skew statistics (what the cost model consumes)
    stats = op.gather_statistics(docs[:24], total_docs=len(docs))
    for scheme in SCHEMES:
        rows.append({
            "scheme": f"{scheme}(stats-skew)",
            "shuffle_bytes": 0, "send_overflow": 0,
            "max_received": stats.sig_skew.get(scheme, 1.0),
            "mean_received": 1.0,
            "recall": float("nan"), "precision": float("nan"),
        })
    return rows


def main() -> None:
    emit("signatures", run())


if __name__ == "__main__":
    main()

"""Paper §6 main table: measured runtime of index-based vs
filter&verification-based vs EE-Join-chosen (possibly hybrid) plans,
across dictionaries with different mention-frequency distributions.

For each (mention_dist × plan) we run the *same* extraction job and
report median wall seconds plus recall vs the exact oracle — the
operator's chosen plan should track the per-distribution winner, which
is the paper's core claim.
"""
from __future__ import annotations

import numpy as np

from repro.core.cost_model import ALGO_INDEX, ALGO_SSJOIN, CostParams, OBJ_JOB
from repro.core.eejoin import EEJoinConfig, EEJoinOperator
from repro.core.plan import PlanSide
from repro.data.synth import MENTION_DISTS, make_corpus
from repro.extraction.oracle import oracle_extract

from benchmarks.common import emit, execute_time, forced_plan

GAMMA = 0.8

PURE_PLANS = {
    "index:word": (ALGO_INDEX, "word"),
    "index:prefix": (ALGO_INDEX, "prefix"),
    "index:variant": (ALGO_INDEX, "variant"),
    "ssjoin:prefix": (ALGO_SSJOIN, "prefix"),
    "ssjoin:lsh": (ALGO_SSJOIN, "lsh"),
    "ssjoin:variant": (ALGO_SSJOIN, "variant"),
}


def _recall(matches, truth) -> float:
    got = set()
    for m in matches if isinstance(matches, list) else [matches]:
        got |= m.to_set()
    return len(got & truth) / max(len(truth), 1)


def _plan_truth(docs, dictionary, plan):
    """Semantics-correct oracle for a (possibly hybrid) plan: variant
    sides match `variant_exact` semantics, others `extra`; filtered to
    each side's entity range."""
    t_extra = oracle_extract(docs, dictionary, GAMMA, "extra")
    t_var = oracle_extract(docs, dictionary, GAMMA, "variant_exact")
    out = set()
    for side, a, b in (
        (plan.head, 0, plan.split),
        (plan.tail, plan.split, dictionary.num_entities),
    ):
        t = t_var if side.scheme == "variant" else t_extra
        out |= {x for x in t if a <= x[3] < b}
    return out


def run(iters: int = 3) -> list[dict]:
    rows = []
    for dist in MENTION_DISTS:
        c = make_corpus(
            num_docs=48, doc_len=192, vocab_size=4096, num_entities=96,
            mention_dist=dist, mentions_per_doc=4.0, seed=11,
        )
        docs = np.asarray(c.doc_tokens)
        op = EEJoinOperator(
            c.dictionary,
            EEJoinConfig(gamma=GAMMA, max_candidates=65536,
                         result_capacity=65536),
        )
        E = c.dictionary.num_entities
        cp = CostParams(num_devices=1, hbm_budget_bytes=2e5)

        timings = {}
        for name, (algo, scheme) in PURE_PLANS.items():
            side = PlanSide(algo, scheme)
            plan = forced_plan(0, PlanSide(ALGO_INDEX, "prefix"), side)
            prepared = op.prepare(plan, cp)
            t = execute_time(op, prepared, docs, iters=iters)
            m = op.execute(prepared, docs)
            rec = _recall(m, _plan_truth(docs, c.dictionary, plan))
            timings[name] = t
            rows.append({
                "dist": dist, "plan": name, "split": 0,
                "seconds": t, "recall": rec, "kind": "pure",
            })

        # the operator's own cost-based choice (may be hybrid)
        stats = op.gather_statistics(docs[:24], total_docs=len(docs))
        plan = op.choose_plan(stats, cp)
        prepared = op.prepare(plan, cp)
        t = execute_time(op, prepared, docs, iters=iters)
        m = op.execute(prepared, docs)
        chosen = f"{plan.head.algo}:{plan.head.scheme}|{plan.tail.algo}:{plan.tail.scheme}"
        best_pure = min(timings.values())
        rows.append({
            "dist": dist, "plan": f"eejoin[{chosen}@{plan.split}]",
            "split": plan.split, "seconds": t,
            "recall": _recall(m, _plan_truth(docs, c.dictionary, plan)),
            "kind": "chosen",
        })
        rows.append({
            "dist": dist, "plan": "best_pure_oracle", "split": -1,
            "seconds": best_pure, "recall": 1.0, "kind": "reference",
        })
    return rows


def main() -> None:
    emit("algorithms", run())


if __name__ == "__main__":
    main()

"""Jaccard-variant enumeration (Def. 2) vs brute force."""
import itertools

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core.variants import (
    enumerate_entity_variants,
    variant_keys,
    window_variant_key,
)
from repro.core.dictionary import build_dictionary
from repro.core import hashing


@given(
    st.lists(st.integers(1, 1000), min_size=1, max_size=7, unique=True),
    st.floats(0.3, 1.0),
)
@settings(max_examples=60, deadline=None)
def test_enumeration_matches_bruteforce(tokens, gamma):
    toks = np.array(tokens, dtype=np.int32)
    rng = np.random.default_rng(7)
    ws = rng.uniform(0.5, 3.0, size=len(toks)).astype(np.float32)
    total = ws.sum()

    got = {
        tuple(sorted(v.tolist()))
        for v in enumerate_entity_variants(toks, ws, gamma, max_variants=1024)
    }
    want = set()
    for r in range(1, len(toks) + 1):
        for comb in itertools.combinations(range(len(toks)), r):
            if ws[list(comb)].sum() >= gamma * total - 1e-6:
                want.add(tuple(sorted(int(toks[i]) for i in comb)))
    assert got == want


def test_variant_keys_match_window_hash():
    d = build_dictionary([[3, 9, 5], [7, 2]], vocab_size=16)
    k1, k2, eid = variant_keys(d, gamma=0.6)
    assert len(k1) == len(eid) > 0
    # hashing a window with the same token set reproduces the key
    win = jnp.asarray([[5, 3, 9, 0]], dtype=jnp.int32)  # permuted, padded
    w1, w2 = window_variant_key(win, win != 0, xp=jnp)
    full_idx = [i for i in range(len(k1)) if eid[i] in (0, 1)]
    assert int(np.asarray(w1)[0]) in k1.tolist()
    pos = k1.tolist().index(int(np.asarray(w1)[0]))
    assert int(np.asarray(w2)[0]) == int(k2[pos])


def test_gamma_one_gives_only_full_set():
    toks = np.array([4, 8, 15], dtype=np.int32)
    ws = np.ones(3, dtype=np.float32)
    vs = enumerate_entity_variants(toks, ws, gamma=1.0)
    assert len(vs) == 1 and sorted(vs[0].tolist()) == [4, 8, 15]

"""Continuous calibration + online replanning (tests/harness_drift.py
drives the workload; see ISSUE/ROADMAP "observe -> refit -> replan ->
swap").

Covers the drift-injection acceptance surface:

* stationary traffic never triggers (the replanner fires only past the
  configured drift bound);
* a mid-run distribution shift (doc length, survivor density and
  dictionary skew all move) triggers exactly one replan, and the
  swapped plan matches what a from-scratch §5 search picks on a fresh
  sample of the post-drift distribution;
* every served request stays bit-identical to ``one_shot_reference``
  before / during / after the swap, with batches in flight on both
  sides of the epoch flip;
* the swap never crosses the similarity-semantics boundary (variant vs
  everything else), and a pinned plan is never replanned.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.core.plan import PlanSide
from repro.core.search import search_plan
from repro.core.semantics import SIM_EXTRA, SIM_VARIANT_EXACT
from repro.data.synth import make_corpus
from repro.serving import ReplanConfig, Replanner, one_shot_reference
from repro.serving.replan import (
    batch_windows,
    effective_plan_key,
    plan_semantics,
    scheme_semantics,
)
from repro.serving.session import pure_plan
from tests.harness_drift import (
    NUM_ENTITIES,
    PHASE_A,
    PHASE_B,
    build_session,
    drift_config,
    drift_replan_config,
    phase_docs,
    run_phases,
)


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(num_docs=24, doc_len=64, vocab_size=2048,
                       num_entities=NUM_ENTITIES, max_entity_len=4, seed=5)


# ------------------------------------------------------------------ helpers
def _plan_key(sess, plan):
    return effective_plan_key(plan, sess.dictionary.num_entities)


# ------------------------------------------------------- stationary control
def test_stationary_stream_never_triggers(corpus):
    """Phase-A-only traffic: drift stays inside the bound, so even
    though a cheaper plan exists under the engineered cost model, the
    drift-triggered replanner must not fire."""
    cache, sess = build_session(corpus.dictionary)
    svc, docs = run_phases(cache, sess, [phase_docs(corpus.dictionary,
                                                    PHASE_A)],
                           drift_replan_config())
    assert sess.observed is not None
    assert sess.observed.batches > drift_replan_config().min_batches
    assert sess.replan_baseline is not None  # warm-up completed
    assert svc.metrics.replans == 0
    assert svc.metrics.replan_swaps == 0
    assert sess.plan.describe(NUM_ENTITIES).startswith("pure index:prefix")
    assert svc.results_set() == one_shot_reference(sess, docs)


# ------------------------------------------------------------ the drift leg
def test_drift_triggers_one_replan_and_converges(corpus):
    """The acceptance scenario: a mid-run shift (doc length x2, mention
    density x12, head->tail skew) fires exactly one replan; the swapped
    plan equals the from-scratch §5 search on a *fresh* post-drift
    sample; all results stay bit-identical to the one-shot reference
    with batches in flight across the epoch swap."""
    cache, sess = build_session(corpus.dictionary)
    old_key = _plan_key(sess, sess.plan)
    sess.pin_current()  # hold epoch 0 resident for the replay assert
    svc, docs = run_phases(
        cache, sess,
        [phase_docs(corpus.dictionary, PHASE_A),
         phase_docs(corpus.dictionary, PHASE_B)],
        drift_replan_config(),
        wait_for_swap=True,
        wait_for_swap_at=32,  # last 32 docs admit on the new epoch
    )

    # exactly one trigger, and it swapped
    assert svc.metrics.replans == 1
    assert svc.metrics.replan_swaps == 1
    (event,) = svc.metrics.replan_events
    assert event["swapped"] is True
    assert event["reason"] in ("doc_len", "lane_density")
    assert event["new_cost_s"] <= event["stale_cost_s"]
    assert event["predicted_gain"] >= drift_replan_config().min_gain
    assert event["old_plan"].startswith("pure index:prefix")

    # the swap landed as a fresh epoch; batches ran on both sides of it
    assert sess.current_state.epoch == event["epoch"] == 1
    epochs = {r["epoch"] for r in svc.metrics.batch_records}
    assert epochs == {0, 1}
    assert _plan_key(sess, sess.plan) != old_key

    # convergence: a from-scratch §5 search over a fresh sample of the
    # post-drift distribution picks the same plan the replanner swapped
    # in (the sample seed is disjoint from every phase seed)
    fresh = phase_docs(corpus.dictionary,
                       dataclasses.replace(PHASE_B, num_docs=32, seed=99))
    stats = sess.operator.gather_statistics(fresh, total_docs=len(fresh))
    oracle = search_plan(stats, sess.cost_params, sess.config.objective,
                         options=sess.config.options)
    assert _plan_key(sess, oracle) == _plan_key(sess, sess.plan)
    assert sess.plan.describe(NUM_ENTITIES).startswith("pure ssjoin:prefix")

    # bit-parity across the whole run (pre-drift, in-flight, post-swap)
    assert svc.results_set() == one_shot_reference(sess, docs)

    # the swap must never change an admitted batch's results — replaying
    # the same docs on the *old* epoch reproduces the same match set
    assert one_shot_reference(sess, docs, epoch=0) == \
        one_shot_reference(sess, docs, epoch=1)


def test_drift_with_refit_keeps_parity(corpus):
    """With refit enabled the constants absorb measured wall times
    (nondeterministic), so only the invariants are asserted: at most
    one swap per trigger-cooldown window, and bit-parity throughout."""
    cache, sess = build_session(corpus.dictionary)
    svc, docs = run_phases(
        cache, sess,
        [phase_docs(corpus.dictionary, PHASE_A),
         phase_docs(corpus.dictionary, PHASE_B)],
        drift_replan_config(refit=True, time_drift=float("inf")),
        wait_for_swap=False,
    )
    assert svc.metrics.replans <= 2
    assert svc.metrics.replan_swaps <= svc.metrics.replans
    for event in svc.metrics.replan_events:
        if event["swapped"]:
            assert event["new_cost_s"] <= event["stale_cost_s"]
    assert svc.results_set() == one_shot_reference(sess, docs)


# --------------------------------------------------- guards (unit-level)
def test_scheme_semantics_classes():
    assert scheme_semantics("variant") == SIM_VARIANT_EXACT
    for scheme in ("word", "prefix", "lsh"):
        assert scheme_semantics(scheme) == SIM_EXTRA
    assert plan_semantics(pure_plan("variant"), 8) == {SIM_VARIANT_EXACT}
    assert plan_semantics(pure_plan("prefix", algo="index"), 8) == {SIM_EXTRA}
    mixed = dataclasses.replace(pure_plan("prefix"), split=4,
                                head=PlanSide("ssjoin", "variant"))
    assert plan_semantics(mixed, 8) == {SIM_VARIANT_EXACT, SIM_EXTRA}


def _stuffed_replanner(cache, sess, **cfg):
    """Replanner with enough synthetic telemetry to trigger on demand."""
    rp = Replanner(cache, ReplanConfig(thread=False, refit=False,
                                       min_batches=1, cooldown_batches=1,
                                       halflife_windows=200.0, **cfg))
    obs = rp.attach(sess)
    rng = np.random.default_rng(3)
    obs.observe_docs(rng.integers(1, 100, size=(8, 24), dtype=np.int32))
    obs.record_batch(rows=8, windows=1000, survivors=50,
                     probe_s=1e-3, verify_s=1e-4)
    rp.step()  # freezes the baseline
    # drifted follow-up: density jumps 10x past any default bound
    obs.record_batch(rows=8, windows=1000, survivors=500,
                     probe_s=1e-3, verify_s=1e-4)
    return rp


def test_replan_never_crosses_semantics_boundary(corpus):
    """A variant-plan session whose options are all extra-class must
    skip the swap (event fires, marked skipped) — swapping would change
    served match sets, not just cost."""
    cfg = dataclasses.replace(drift_config(),
                              options=(("ssjoin", "prefix"),))
    cache, sess = build_session(corpus.dictionary, config=cfg)
    sess.plan = pure_plan("variant")
    rp = _stuffed_replanner(cache, sess)
    (event,) = rp.step()
    assert event["skipped"] == "no semantics-preserving options"
    assert event["swapped"] is False
    assert sess.plan.describe(NUM_ENTITIES).startswith("pure ssjoin:variant")


def test_mixed_semantics_plan_is_never_replanned(corpus):
    cache, sess = build_session(corpus.dictionary)
    sess.plan = dataclasses.replace(pure_plan("prefix"), split=4,
                                    head=PlanSide("ssjoin", "variant"))
    rp = _stuffed_replanner(cache, sess)
    (event,) = rp.step()
    assert event["skipped"] == "mixed-semantics plan"
    assert event["swapped"] is False


def test_pinned_plan_is_never_replanned(corpus):
    cache, sess = build_session(corpus.dictionary)
    rp = _stuffed_replanner(cache, sess)  # baseline frozen, then drifted
    sess.pin_plan()
    assert rp.step() == []  # drifted, but pinned: no event at all
    sess.pin_plan(False)
    (event,) = rp.step()  # unpinned: the same drift now fires
    assert event["reason"] == "lane_density"


# ------------------------------------------------------ maintenance refit
def test_maintenance_plan_costs_with_refitted_constants(corpus):
    """The absorb/compact/rebuild planner runs over the same
    measurement-rescaled constants the extraction replan uses: with a
    warm ``ObservedStats`` attached, ``plan_maintenance`` refits the
    probe/verify families first (inspectable via
    ``last_maintenance_params``); a cold observer is the identity."""
    from repro.core.calibrate import refit_params
    from repro.serving.replan import ObservedStats, plan_schemes
    from repro.updates.delta import random_delta

    cache, sess = build_session(corpus.dictionary)
    rng = np.random.default_rng(77)
    delta = random_delta(rng, sess.current_state.version, 2048)
    base_cp = sess.cost_params

    # cold: NaN EWMAs leave every family untouched (the refit only
    # materializes the sig-cost dict; all scalars are the identity)
    sess.observed = ObservedStats()
    sess.plan_maintenance(delta)
    cold = sess.last_maintenance_params
    assert cold.c_verify_pair == base_cp.c_verify_pair
    assert cold.c_probe == base_cp.c_probe
    assert cold.c_enum_per_window == base_cp.c_enum_per_window
    assert cold.sig_cost("prefix") == base_cp.sig_cost("prefix")

    # warm: feed telemetry that is 100x the model's canonical verify
    # time — the verify family must rescale, and the maintenance
    # planner must see exactly the pure refit of the session params
    sess.observed.record_batch(
        rows=8, windows=4096, survivors=512,
        probe_s=1e-3, verify_s=(base_cp.c_probe + base_cp.c_verify_pair)
        * 100.0 * 512,
    )
    decision = sess.plan_maintenance(delta)
    got = sess.last_maintenance_params
    want = refit_params(
        base_cp, sess.observed,
        schemes=plan_schemes(sess.plan, sess.dictionary.num_entities),
    )
    assert got == want != base_cp
    assert got.c_verify_pair == pytest.approx(
        base_cp.c_verify_pair * 100.0, rel=1e-6
    )
    # the decision itself is still a valid maintenance action
    assert decision.action in ("absorb", "compact", "rebuild")


# ----------------------------------------------------------- small pieces
def test_batch_windows_matches_definition():
    docs = np.array([[5, 6, 7, 0, 0],
                     [9, 0, 0, 0, 0],
                     [0, 0, 0, 0, 0]], dtype=np.int32)
    # row lens 3, 1, 0; windows = sum_l max(0, n-l+1), l in 1..2
    assert batch_windows(docs, 2) == (3 + 2) + (1 + 0) + 0
    assert batch_windows(docs, 1) == 3 + 1
    assert batch_windows(np.zeros((2, 4), np.int32), 3) == 0

"""Sharded streaming driver vs the unsharded fused fast path.

Bit-parity contracts: ``sharded_filter_compact`` (and the single-device
``stream_filter_compact``) must reproduce ``engine.fused_filter_compact``
field for field at every shard geometry — uneven shard sizes, PAD-only
shards, zero-survivor shards, more shards than devices — and the
in-kernel compaction epilogue must agree with both the legacy XLA
bitmap compaction (``kernel_compact=False``) and the fully unfused
``compact_candidates`` reference, so neither fallback can rot.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.dictionary import PAD
from repro.extraction import engine as E
from repro.extraction import sharded as SH
from repro.extraction.results import select_from_tiles, select_nonzero
from repro.launch.mesh import make_extraction_mesh

GAMMA = 0.8
CAND_KEYS = ("win_tokens", "win_valid", "doc", "pos", "length",
             "n_survive", "overflow")


def _docs(rng, D, T, vocab=2048, pad_frac=0.15):
    d = rng.integers(1, vocab, size=(D, T)).astype(np.int32)
    d[rng.random((D, T)) < pad_frac] = PAD
    return jnp.asarray(d)


def _filter(rng, num_bits=1 << 14, density=0.3):
    w = (rng.random((num_bits // 32, 32)) < density).astype(np.uint32)
    bits = (w << np.arange(32, dtype=np.uint32)).sum(axis=1).astype(np.uint32)
    return (jnp.asarray(bits), num_bits, 3)


def _params(**kw):
    kw.setdefault("gamma", GAMMA)
    kw.setdefault("scheme", "prefix")
    kw.setdefault("use_kernel", True)
    return E.ExtractParams(**kw)


def _assert_cands_equal(got, want):
    for k in CAND_KEYS:
        np.testing.assert_array_equal(
            np.asarray(got[k]), np.asarray(want[k]), err_msg=k
        )


# ------------------------------------------------------- shard geometries
@pytest.mark.parametrize("shard_docs,tile_docs", [(4, 2), (5, 3), (13, 2), (3, 1)])
def test_sharded_parity_uneven_shards(shard_docs, tile_docs):
    """D=13 never divides evenly: ragged tails at every geometry."""
    rng = np.random.default_rng(11)
    docs = _docs(rng, 13, 96)
    flt = _filter(rng)
    params = _params(max_candidates=256)
    want = E.fused_filter_compact(docs, 7, flt, params)
    got = SH.sharded_filter_compact(
        docs, 7, flt, params, shard_docs=shard_docs, tile_docs=tile_docs
    )
    _assert_cands_equal(got, want)
    assert int(want["n_survive"]) > 0  # non-vacuous


def test_sharded_parity_pad_only_shards():
    """A shard made entirely of PAD rows must contribute nothing."""
    rng = np.random.default_rng(12)
    d = np.array(_docs(rng, 16, 64))
    d[4:8] = PAD  # shard 1 (shard_docs=4) is PAD-only
    docs = jnp.asarray(d)
    flt = _filter(rng)
    params = _params(max_candidates=256)
    want = E.fused_filter_compact(docs, 6, flt, params)
    got = SH.sharded_filter_compact(docs, 6, flt, params, shard_docs=4, tile_docs=2)
    _assert_cands_equal(got, want)
    assert not np.isin(np.asarray(got["doc"]), [4, 5, 6, 7]).any()


def test_sharded_parity_zero_survivor_shards():
    """Empty Bloom filter: every shard streams, none emits candidates."""
    rng = np.random.default_rng(13)
    docs = _docs(rng, 10, 64, pad_frac=0.0)
    flt = (jnp.zeros(((1 << 12) // 32,), jnp.uint32), 1 << 12, 3)
    params = _params(max_candidates=128)
    want = E.fused_filter_compact(docs, 6, flt, params)
    got = SH.sharded_filter_compact(docs, 6, flt, params, shard_docs=3, tile_docs=2)
    _assert_cands_equal(got, want)
    assert int(got["n_survive"]) == 0
    assert not bool(np.asarray(got["win_valid"]).any())


def test_sharded_parity_more_shards_than_devices():
    """shard count > device count: the wave loop must round-robin."""
    rng = np.random.default_rng(14)
    docs = _docs(rng, 12, 64)
    flt = _filter(rng)
    params = _params(max_candidates=256)
    mesh = make_extraction_mesh(1)  # 1 CPU device, 6 shards -> 6 waves
    want = E.fused_filter_compact(docs, 6, flt, params)
    got = SH.sharded_filter_compact(
        docs, 6, flt, params, mesh=mesh, shard_docs=2, tile_docs=2
    )
    _assert_cands_equal(got, want)


def test_sharded_overflow_surfaced():
    """Saturated filter + tiny capacity: overflow counts must agree."""
    rng = np.random.default_rng(15)
    docs = _docs(rng, 8, 48, pad_frac=0.0)
    flt = (jnp.full(((1 << 12) // 32,), 0xFFFFFFFF, jnp.uint32), 1 << 12, 3)
    params = _params(max_candidates=64)
    want = E.fused_filter_compact(docs, 5, flt, params)
    got = SH.sharded_filter_compact(docs, 5, flt, params, shard_docs=3, tile_docs=1)
    _assert_cands_equal(got, want)
    assert int(got["overflow"]) > 0


# ------------------------------------------------------- tile streaming
@pytest.mark.parametrize("tile_docs", [1, 3, 64])
def test_stream_filter_compact_parity(tile_docs):
    rng = np.random.default_rng(16)
    docs = _docs(rng, 11, 80)
    flt = _filter(rng)
    params = _params(max_candidates=256)
    want = E.fused_filter_compact(docs, 6, flt, params)
    got = SH.stream_filter_compact(docs, 6, flt, params, tile_docs=tile_docs)
    _assert_cands_equal(got, want)


# ------------------------------------------------------- compaction paths
def test_kernel_epilogue_vs_legacy_xla_compaction():
    """The in-kernel epilogue (kernel_compact=True), the legacy XLA
    bitmap compaction (False) and the fully unfused reference must all
    agree — the fallback paths stay exercised and correct."""
    rng = np.random.default_rng(17)
    docs = _docs(rng, 12, 96)
    flt = _filter(rng)
    epi = E.fused_filter_compact(docs, 7, flt, _params(max_candidates=512))
    legacy = E.fused_filter_compact(
        docs, 7, flt, _params(max_candidates=512, kernel_compact=False)
    )
    base, surv = E.survival_mask(docs, 7, flt, use_kernel=False)
    unfused = E.compact_candidates(base, surv, 512)
    _assert_cands_equal(epi, legacy)
    _assert_cands_equal(epi, unfused)


def test_sharded_delegates_legacy_compaction():
    """kernel_compact=False has no lanes to shard over: the driver must
    fall back to the (legacy) single-call path with identical output."""
    rng = np.random.default_rng(18)
    docs = _docs(rng, 9, 64)
    flt = _filter(rng)
    params = _params(max_candidates=128, kernel_compact=False)
    want = E.fused_filter_compact(docs, 6, flt, params)
    got = SH.sharded_filter_compact(docs, 6, flt, params, shard_docs=4)
    _assert_cands_equal(got, want)


def _assert_variant_equal(got, want):
    np.testing.assert_array_equal(np.asarray(got["sigs"]),
                                  np.asarray(want["sigs"]), err_msg="sigs")
    for i, (a, b) in enumerate(zip(got["variant_keys"],
                                   want["variant_keys"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"variant_keys[{i}]")


# ------------------------------------------- variant keys + adaptive lanes
@pytest.mark.parametrize("shard_docs,tile_docs", [(4, 2), (5, 3), (13, 2), (3, 1)])
@pytest.mark.parametrize("adaptive", [False, True])
def test_sharded_variant_keys_parity(shard_docs, tile_docs, adaptive):
    """Fused variant keys must ride the shard/tile lanes bit-identically
    to the unsharded fused path, one-pass and adaptive two-pass alike."""
    rng = np.random.default_rng(31)
    docs = _docs(rng, 13, 96)
    flt = _filter(rng)
    want = E.fused_filter_compact(
        docs, 7, flt, _params(scheme="variant", max_candidates=256)
    )
    params = _params(scheme="variant", max_candidates=256,
                     adaptive_lanes=adaptive)
    got = SH.sharded_filter_compact(
        docs, 7, flt, params, shard_docs=shard_docs, tile_docs=tile_docs
    )
    _assert_cands_equal(got, want)
    _assert_variant_equal(got, want)
    assert int(want["n_survive"]) > 0  # non-vacuous


@pytest.mark.parametrize("shard_docs,tile_docs", [(4, 2), (5, 3), (3, 1)])
@pytest.mark.parametrize("scheme", ["prefix", "variant"])
def test_sharded_adaptive_two_pass_parity(shard_docs, tile_docs, scheme):
    """Two-pass (count wave -> narrow emit) vs one-pass lane bit-identity
    at every shard geometry, sequential and mesh paths."""
    rng = np.random.default_rng(32)
    docs = _docs(rng, 11, 80)
    flt = _filter(rng)
    one = SH.sharded_filter_compact(
        docs, 6, flt, _params(scheme=scheme, max_candidates=256),
        shard_docs=shard_docs, tile_docs=tile_docs,
    )
    adaptive = _params(scheme=scheme, max_candidates=256, adaptive_lanes=True)
    two = SH.sharded_filter_compact(
        docs, 6, flt, adaptive, shard_docs=shard_docs, tile_docs=tile_docs
    )
    _assert_cands_equal(two, one)
    mesh = make_extraction_mesh(1)
    two_mesh = SH.sharded_filter_compact(
        docs, 6, flt, adaptive, mesh=mesh,
        shard_docs=shard_docs, tile_docs=tile_docs,
    )
    _assert_cands_equal(two_mesh, one)
    if scheme == "variant":
        _assert_variant_equal(two, one)
        _assert_variant_equal(two_mesh, one)


def test_stream_tile_counts_matches_emit_counts():
    """The count-only sizing pass must reproduce the emit pass's
    per-sub-tile counts exactly (same grid, same SMEM accumulation)."""
    rng = np.random.default_rng(33)
    docs = _docs(rng, 10, 64)
    flt = _filter(rng)
    params = _params(max_candidates=128)
    counts = SH.stream_tile_counts(docs, 6, flt, params, tile_docs=3)
    emitted, _, _ = SH.stream_probe_tiles(docs, 6, flt, params, tile_docs=3)
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(emitted))


def test_streaming_rejects_forced_lsh_kernel_sigs():
    """kernel_sigs=True + lsh cannot be honored on the streaming path
    (dense band sigs have no lane to ride): it must raise, not silently
    store-and-discard the kernel's sig tensor."""
    rng = np.random.default_rng(36)
    docs = _docs(rng, 8, 48)
    flt = _filter(rng)
    params = _params(scheme="lsh", max_candidates=64, kernel_sigs=True)
    with pytest.raises(ValueError, match="streaming path"):
        SH.stream_filter_compact(docs, 5, flt, params, tile_docs=4)
    with pytest.raises(ValueError, match="streaming path"):
        SH.sharded_filter_compact(docs, 5, flt, params, shard_docs=4)
    # unforced lsh streams fine: band sigs recomputed post-compaction
    ok = SH.stream_filter_compact(
        docs, 5, flt, _params(scheme="lsh", max_candidates=64), tile_docs=4
    )
    assert "sigs" not in ok


def test_shard_lane_adaptive_traced_requires_width():
    """Tracing shard_lane with adaptive_lanes and no explicit width must
    raise (the sizing host sync cannot run inside a trace), never fall
    back silently to worst-case lanes."""
    import jax

    rng = np.random.default_rng(34)
    docs = _docs(rng, 8, 48)
    flt = _filter(rng)
    params = _params(max_candidates=64, adaptive_lanes=True)
    with pytest.raises(ValueError, match="lane_width"):
        jax.jit(
            lambda d: SH.shard_lane(d, 0, 5, flt, params)
        )(docs)


@pytest.mark.parametrize("G,C,capacity", [(1, 8, 8), (4, 16, 16), (7, 32, 16)])
def test_select_from_tiles_matches_select_nonzero(G, C, capacity):
    """Lane merge == flat select_nonzero over the concatenated bitmap
    whenever lane width >= capacity (the driver's invariant)."""
    rng = np.random.default_rng(G * C + capacity)
    span = C  # elements per tile
    mask = rng.random(G * span) < 0.4
    counts = np.array([mask[g * span:(g + 1) * span].sum() for g in range(G)],
                      dtype=np.int32)
    cands = np.full((G, C), -1, dtype=np.int32)
    for g in range(G):
        idx = np.nonzero(mask[g * span:(g + 1) * span])[0] + g * span
        cands[g, :min(len(idx), C)] = idx[:C]
    got_idx, got_ok, got_n = select_from_tiles(
        jnp.asarray(counts), jnp.asarray(cands), capacity
    )
    want_idx, want_ok = select_nonzero(jnp.asarray(mask), capacity)
    np.testing.assert_array_equal(np.asarray(got_idx), np.asarray(want_idx))
    np.testing.assert_array_equal(np.asarray(got_ok), np.asarray(want_ok))
    assert int(got_n) == int(mask.sum())


def test_select_from_tiles_complete_tiles_narrow_lanes():
    """With complete tiles (every tile's survivors fit its lane), a
    narrow C < capacity merge must equal the full-width merge."""
    from repro.extraction.results import gather_from_tiles

    rng = np.random.default_rng(35)
    G, C, capacity = 5, 4, 16
    counts = rng.integers(0, C + 1, size=G).astype(np.int32)  # <= C each
    wide = np.full((G, capacity), -1, dtype=np.int32)
    payload = np.zeros((G, capacity, 2), dtype=np.uint32)
    base = 0
    for g in range(G):
        idx = base + np.sort(rng.choice(100, size=counts[g], replace=False))
        wide[g, :counts[g]] = idx
        payload[g, :counts[g]] = rng.integers(
            1, 2**32, size=(counts[g], 2), dtype=np.uint32
        )
        base += 100
    narrow = wide[:, :C]
    want_idx, want_ok, want_n = select_from_tiles(
        jnp.asarray(counts), jnp.asarray(wide), capacity
    )
    got_idx, got_ok, got_n = select_from_tiles(
        jnp.asarray(counts), jnp.asarray(narrow), capacity,
        complete_tiles=True,
    )
    np.testing.assert_array_equal(np.asarray(got_idx), np.asarray(want_idx))
    np.testing.assert_array_equal(np.asarray(got_ok), np.asarray(want_ok))
    assert int(got_n) == int(want_n)
    # payload gather picks the same survivors as the index merge
    pay = gather_from_tiles(
        jnp.asarray(counts), jnp.asarray(payload[:, :C]), capacity
    )
    want_pay = gather_from_tiles(
        jnp.asarray(counts), jnp.asarray(payload), capacity
    )
    np.testing.assert_array_equal(np.asarray(pay), np.asarray(want_pay))


# ------------------------------------------------------- end-to-end
@pytest.mark.parametrize("scheme", ["prefix", "lsh", "variant"])
def test_execute_sharded_equals_execute(small_corpus, scheme):
    from repro.core.cost_model import OBJ_JOB, SideCost
    from repro.core.eejoin import EEJoinConfig, EEJoinOperator
    from repro.core.plan import Plan, PlanSide

    c = small_corpus
    op = EEJoinOperator(
        c.dictionary,
        EEJoinConfig(gamma=GAMMA, max_candidates=4096, result_capacity=8192,
                     use_kernel=True),
    )
    z = SideCost(0, 0, 0, 0, 0, 0, 0, 0, 0)
    plan = Plan(0, PlanSide("index", "prefix"), PlanSide("ssjoin", scheme),
                OBJ_JOB, 0.0, z, z, 0)
    prepared = op.prepare(plan)
    docs = jnp.asarray(c.doc_tokens)
    want = op.execute(prepared, docs).to_set()
    got = op.execute_sharded(prepared, docs, shard_docs=3, tile_docs=2).to_set()
    assert got == want and len(want) > 0


def test_execute_adaptive_config_equals_fixed(small_corpus):
    """EEJoinConfig(adaptive_lanes=True) must flow through prepare into
    every side's ExtractParams and change nothing in the results."""
    from repro.core.cost_model import OBJ_JOB, SideCost
    from repro.core.eejoin import EEJoinConfig, EEJoinOperator
    from repro.core.plan import Plan, PlanSide

    c = small_corpus
    z = SideCost(0, 0, 0, 0, 0, 0, 0, 0, 0)
    plan = Plan(0, PlanSide("ssjoin", "variant"), PlanSide("ssjoin", "variant"),
                OBJ_JOB, 0.0, z, z, 0)
    docs = jnp.asarray(c.doc_tokens)
    outs = {}
    for adaptive in (False, True):
        op = EEJoinOperator(
            c.dictionary,
            EEJoinConfig(gamma=GAMMA, max_candidates=4096,
                         result_capacity=8192, use_kernel=True,
                         adaptive_lanes=adaptive),
        )
        prepared = op.prepare(plan)
        assert prepared.sides[0].params.adaptive_lanes is adaptive
        outs[adaptive] = op.execute(prepared, docs).to_set()
    assert outs[True] == outs[False] and len(outs[True]) > 0

"""Distributed extraction correctness (subprocess: needs fake devices).

The XLA host-device-count flag must be set before jax initialises, so
these checks run in a child process rather than the pytest process
(which must keep seeing 1 device for the smoke tests).
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(n_devices: int) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.selftest_distributed", str(n_devices)],
        capture_output=True,
        text=True,
        timeout=600,
        env=env,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_distributed_extraction_8_devices():
    checks = _run(8)
    assert checks["n_devices"] == 8
    failed = [k for k, v in checks.items() if isinstance(v, bool) and not v]
    assert not failed, f"failed distributed checks: {failed}"

"""Serving subsystem: parity with one-shot execute + component contracts.

The central contract: the micro-batched probe/verify service must
produce results **bit-identical** to a one-shot ``eejoin.execute`` over
the same documents — for every supported scheme, at every geometry
(uneven lengths, PAD-only docs, zero-survivor batches, multiple live
dictionary sessions), with overlap on and off. Windows never span
documents and lane merging is exact, so micro-batching must be
invisible in the results.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.cost_model import OBJ_JOB, SideCost
from repro.core.eejoin import EEJoinConfig
from repro.core.plan import Plan, PlanSide
from repro.data.synth import make_corpus
from repro.extraction import engine as E
from repro.serving import (
    AdmissionQueue,
    BatcherConfig,
    ExtractionService,
    MicroBatcher,
    SessionCache,
    make_pools,
    one_shot_reference,
    pipeline_schedule,
)
from repro.serving.queue import ExtractRequest
from repro.serving.session import pure_plan, dictionary_fingerprint

GAMMA = 0.8


def _config(**kw):
    kw.setdefault("gamma", GAMMA)
    kw.setdefault("max_candidates", 4096)
    kw.setdefault("result_capacity", 8192)
    kw.setdefault("use_kernel", True)
    return EEJoinConfig(**kw)


def _var_docs(corpus, seed, n=None, min_len=8):
    """Uneven-length documents cut from corpus rows (seeded)."""
    rng = np.random.default_rng(seed)
    D, T = corpus.doc_tokens.shape
    n = n or D
    lens = rng.integers(min_len, T + 1, size=n)
    return [np.asarray(corpus.doc_tokens[i % D, : lens[i]]) for i in range(n)]


def _one_shot(sess, docs):
    """Reference: one-shot execute over the same docs (row i = doc_id i)."""
    return one_shot_reference(sess, docs)


def _serve(cache, sess, docs, overlap, batch_docs=3, session_keys=None):
    svc = ExtractionService(
        cache,
        pools=make_pools(),
        batcher_config=BatcherConfig(max_batch_docs=batch_docs,
                                     max_delay_s=0.0),
        overlap=overlap,
    )
    with svc:
        for i, d in enumerate(docs):
            key = session_keys[i] if session_keys else sess.key
            assert svc.submit(i, d, key) is not None
        svc.drain()
    return svc


# ------------------------------------------------------ scheme x overlap
@pytest.mark.parametrize("scheme", ["word", "prefix", "lsh", "variant"])
@pytest.mark.parametrize("overlap", [True, False])
def test_serving_parity_all_schemes(small_corpus, scheme, overlap):
    cache = SessionCache()
    sess = cache.get_or_create(small_corpus.dictionary, _config(),
                               plan=pure_plan(scheme))
    docs = _var_docs(small_corpus, seed=5)
    svc = _serve(cache, sess, docs, overlap)
    want = _one_shot(sess, docs)
    assert svc.results_set() == want
    assert len(want) > 0, "vacuous parity"
    assert svc.metrics.completed == len(docs)


def test_serving_parity_hybrid_plan(small_corpus):
    """A split plan (index head + ssjoin tail) served batch by batch."""
    z = SideCost(0, 0, 0, 0, 0, 0, 0, 0, 0)
    plan = Plan(12, PlanSide("index", "prefix"), PlanSide("ssjoin", "prefix"),
                OBJ_JOB, 0.0, z, z, 0)
    cache = SessionCache()
    sess = cache.get_or_create(small_corpus.dictionary, _config(), plan=plan)
    docs = _var_docs(small_corpus, seed=6)
    svc = _serve(cache, sess, docs, overlap=True)
    assert svc.results_set() == _one_shot(sess, docs)


# ------------------------------------------------------------ geometries
def test_serving_parity_pad_only_docs(small_corpus):
    """All-PAD documents flow through and contribute nothing."""
    cache = SessionCache()
    sess = cache.get_or_create(small_corpus.dictionary, _config(),
                               plan=pure_plan("prefix"))
    docs = _var_docs(small_corpus, seed=7)
    docs[1] = np.zeros(17, np.int32)  # PAD-only rows of differing lengths
    docs[4] = np.zeros(40, np.int32)
    svc = _serve(cache, sess, docs, overlap=True)
    got = svc.results_set()
    assert got == _one_shot(sess, docs)
    assert not any(d in (1, 4) for (d, _p, _l, _e) in got)


def test_serving_zero_survivor_batches(small_corpus):
    """An impossible gamma prunes everything: served stream stays empty
    (and every request still completes)."""
    cache = SessionCache()
    # gamma=1.0 + an unrelated vocabulary region: no candidate verifies
    rng = np.random.default_rng(8)
    docs = [rng.integers(400, 512, size=rng.integers(8, 33)).astype(np.int32)
            for _ in range(6)]
    sess = cache.get_or_create(small_corpus.dictionary, _config(),
                               plan=pure_plan("prefix"))
    svc = _serve(cache, sess, docs, overlap=True, batch_docs=2)
    assert svc.results_set() == _one_shot(sess, docs)
    assert svc.metrics.completed == len(docs)


@pytest.mark.parametrize("overlap", [True, False])
def test_serving_multi_dictionary_sessions(small_corpus, zipf_corpus, overlap):
    """Two dictionaries live in one cache; interleaved requests route to
    their own session and each stream matches its own one-shot run."""
    cache = SessionCache()
    s1 = cache.get_or_create(small_corpus.dictionary, _config(),
                             plan=pure_plan("prefix"))
    s2 = cache.get_or_create(zipf_corpus.dictionary, _config(),
                             plan=pure_plan("word"))
    assert s1.key != s2.key and len(cache) == 2
    docs = _var_docs(small_corpus, seed=9, n=10)
    keys = [s1.key if i % 2 == 0 else s2.key for i in range(len(docs))]
    svc = _serve(cache, s1, docs, overlap, session_keys=keys)
    for sess in (s1, s2):
        mine = [i for i, k in enumerate(keys) if k == sess.key]
        want = {
            (mine[r], p, l, e)
            for (r, p, l, e) in _one_shot(sess, [docs[i] for i in mine])
        }
        got = {
            m for req in svc.completed if req.session_key == sess.key
            for m in ((d, p, l, e) for (d, p, l, e, _s) in req.matches)
        }
        assert got == want


# ---------------------------------------------------------------- batcher
def test_batcher_deterministic_flush_ordering():
    """Same admission stream -> identical batch composition run-to-run."""
    def run():
        b = MicroBatcher(BatcherConfig(max_batch_docs=2, max_delay_s=0.01,
                                       buckets=(16, 32)))
        rng = np.random.default_rng(3)
        out = []
        for i in range(9):
            tokens = rng.integers(1, 99, size=rng.integers(4, 33))
            b.add(ExtractRequest(req_id=i, doc_id=i,
                                 tokens=tokens.astype(np.int32),
                                 session_key="s", arrival_s=0.001 * i))
            out.extend(b.poll(now=0.001 * i))
        out.extend(b.flush_all(now=1.0))
        assert b.pending() == 0
        return [(x.bucket, [r.req_id for r in x.reqs]) for x in out]

    first, second = run(), run()
    assert first == second
    assert sorted(r for _, rs in first for r in rs) == list(range(9))


def test_batcher_full_bin_flushes_before_deadline():
    b = MicroBatcher(BatcherConfig(max_batch_docs=2, max_delay_s=100.0,
                                   buckets=(8,)))
    for i in range(2):
        b.add(ExtractRequest(req_id=i, doc_id=i,
                             tokens=np.ones(4, np.int32),
                             session_key="s", arrival_s=0.0))
    out = b.poll(now=0.0)  # full, despite an unexpired deadline
    assert len(out) == 1 and out[0].rows == 2
    assert out[0].occupancy == 1.0


def test_batcher_deadline_flush_partial_bin():
    b = MicroBatcher(BatcherConfig(max_batch_docs=8, max_delay_s=0.01,
                                   buckets=(8,)))
    b.add(ExtractRequest(req_id=0, doc_id=0, tokens=np.ones(3, np.int32),
                         session_key="s", arrival_s=0.0))
    assert b.poll(now=0.005) == []  # deadline not reached
    out = b.poll(now=0.02)
    assert len(out) == 1 and out[0].rows == 1


def test_batcher_rejects_oversized_docs():
    cfg = BatcherConfig(buckets=(16, 32))
    with pytest.raises(ValueError, match="largest length bucket"):
        cfg.bucket_for(33)


def test_batch_geometry_reuses_plan_shards():
    from repro.extraction.sharded import plan_shards

    b = MicroBatcher(BatcherConfig(max_batch_docs=4, max_delay_s=0.0,
                                   buckets=(8,), tile_docs=2))
    for i in range(3):
        b.add(ExtractRequest(req_id=i, doc_id=i, tokens=np.ones(5, np.int32),
                             session_key="s", arrival_s=0.0))
    (batch,) = b.poll(now=0.0)
    assert batch.spec == plan_shards(3, 1, shard_docs=3, tile_docs=2)
    assert batch.spec.tiles_per_shard == 2


# ------------------------------------------------------------------ queue
def test_admission_queue_sheds_when_full():
    q = AdmissionQueue(capacity=2)
    assert q.try_submit(0, [1, 2], "s", 0.0) is not None
    assert q.try_submit(1, [1, 2], "s", 0.0) is not None
    assert q.try_submit(2, [1, 2], "s", 0.0) is None  # admission control
    assert (q.accepted, q.rejected, q.depth()) == (2, 1, 2)
    taken = q.take()
    assert [r.req_id for r in taken] == [0, 1]  # FIFO, ids in admission order
    assert q.try_submit(3, [1, 2], "s", 0.0) is not None


def test_service_blocking_submit_backpressure(small_corpus):
    """block=True: the producer drains the queue itself (inline tick)
    instead of being rejected, so every doc lands despite a tiny
    admission queue."""
    cache = SessionCache()
    sess = cache.get_or_create(small_corpus.dictionary, _config(),
                               plan=pure_plan("prefix"))
    docs = _var_docs(small_corpus, seed=10, n=6)
    svc = ExtractionService(
        cache,
        batcher_config=BatcherConfig(max_batch_docs=2, max_delay_s=0.0),
        queue_capacity=2,
        overlap=False,
    )
    with svc:
        for i, d in enumerate(docs):
            assert svc.submit(i, d, sess.key, block=True) is not None
        svc.drain()
    assert svc.metrics.rejected == 0  # backpressure, not shedding
    assert svc.results_set() == _one_shot(sess, docs)


def test_service_worker_failure_surfaces_not_hangs(small_corpus, monkeypatch):
    """A raising stage must fail the batch's requests and re-raise from
    drain() — never wedge the queue joins."""
    cache = SessionCache()
    sess = cache.get_or_create(small_corpus.dictionary, _config(),
                               plan=pure_plan("prefix"))
    svc = ExtractionService(cache, overlap=True)
    monkeypatch.setattr(
        ExtractionService, "_probe_batch",
        lambda self, batch: (_ for _ in ()).throw(RuntimeError("boom")),
    )
    svc.start()
    for i in range(3):
        svc.submit(i, np.ones(8, np.int32), sess.key)
    with pytest.raises(RuntimeError, match="failed in the serving"):
        svc.drain()
    svc.stop()  # errors were reported once; stop must not hang or re-raise
    assert all(r.done and r.error and "boom" in r.error for r in svc.completed)
    assert len(svc.completed) == 3
    assert sess.inflight == 0  # failure path still unpins the session


def test_session_cache_never_evicts_busy_sessions(small_corpus, zipf_corpus):
    cache = SessionCache(max_sessions=1)
    busy = cache.get_or_create(small_corpus.dictionary, _config(),
                               plan=pure_plan("prefix"))
    busy.inflight = 2  # admitted work in flight
    with pytest.raises(RuntimeError, match="in-flight"):
        cache.get_or_create(zipf_corpus.dictionary, _config(),
                            plan=pure_plan("prefix"))
    busy.inflight = 0
    cache.get_or_create(zipf_corpus.dictionary, _config(),
                        plan=pure_plan("prefix"))  # idle -> evictable
    assert cache.evictions == 1


def test_service_rejects_unknown_session(small_corpus):
    cache = SessionCache()
    cache.get_or_create(small_corpus.dictionary, _config(),
                        plan=pure_plan("prefix"))
    svc = ExtractionService(cache)
    with pytest.raises(ValueError, match="unknown session"):
        svc.submit(0, np.ones(4, np.int32), "nope")


# ---------------------------------------------------------------- session
def test_session_cache_hits_and_lru_eviction(small_corpus, zipf_corpus):
    cache = SessionCache(max_sessions=1)
    s1 = cache.get_or_create(small_corpus.dictionary, _config(),
                             plan=pure_plan("prefix"))
    again = cache.get_or_create(small_corpus.dictionary, _config(),
                                plan=pure_plan("prefix"))
    assert again is s1 and cache.hits == 1
    cache.get_or_create(zipf_corpus.dictionary, _config(),
                        plan=pure_plan("prefix"))
    assert cache.evictions == 1 and len(cache) == 1
    with pytest.raises(KeyError):
        cache.get(s1.key)


def test_session_fingerprint_covers_dictionary_and_config(small_corpus):
    d = small_corpus.dictionary
    base = dictionary_fingerprint(d, _config())
    assert base == dictionary_fingerprint(d, _config())
    assert base != dictionary_fingerprint(d, _config(gamma=0.9))
    import dataclasses as dc

    mutated = dc.replace(d, tokens=d.tokens.copy())
    mutated.tokens[0, 0] += 1
    assert base != dictionary_fingerprint(mutated, _config())


def test_session_plan_choice_from_stats(small_corpus):
    """sample_docs -> statistics -> §5 plan search (no forced plan)."""
    cache = SessionCache()
    sess = cache.get_or_create(
        small_corpus.dictionary, _config(),
        sample_docs=small_corpus.doc_tokens[:4],
    )
    assert sess.plan.evaluations > 0  # came out of the search
    docs = _var_docs(small_corpus, seed=11, n=6)
    svc = _serve(cache, sess, docs, overlap=True)
    assert svc.results_set() == _one_shot(sess, docs)


def test_session_requires_kernel_path(small_corpus):
    with pytest.raises(ValueError, match="use_kernel=True"):
        SessionCache().get_or_create(
            small_corpus.dictionary, _config(use_kernel=False)
        )


# ------------------------------------------------------- params validation
def test_extract_params_kernel_compact_requires_kernel():
    with pytest.raises(ValueError, match="requires use_kernel=True"):
        E.ExtractParams(gamma=GAMMA, scheme="prefix", kernel_compact=True)


def test_extract_params_kernel_compact_tracks_use_kernel():
    assert E.ExtractParams(gamma=GAMMA, scheme="prefix").kernel_compact is False
    assert E.ExtractParams(
        gamma=GAMMA, scheme="prefix", use_kernel=True
    ).kernel_compact is True
    p = E.ExtractParams(gamma=GAMMA, scheme="prefix", use_kernel=True,
                        kernel_compact=False)
    assert p.kernel_compact is False  # explicit opt-out stays honoured


@pytest.mark.parametrize("kw,match", [
    (dict(scheme="bogus"), "not a known"),
    (dict(gamma=0.0), "must be in"),
    (dict(gamma=1.5), "must be in"),
    (dict(max_candidates=0), "must be positive"),
    (dict(result_capacity=-1), "must be positive"),
])
def test_extract_params_validation_messages(kw, match):
    base = dict(gamma=GAMMA, scheme="prefix")
    base.update(kw)
    with pytest.raises(ValueError, match=match):
        E.ExtractParams(**base)


def test_fused_probe_compact_rejects_bad_args():
    from repro.kernels import ops

    docs = jnp.ones((2, 8), jnp.int32)
    with pytest.raises(ValueError, match="positive"):
        ops.fused_probe_compact(docs, None, 4, 0)
    with pytest.raises(ValueError, match="max_len <= 32"):
        ops.fused_probe_compact(docs, None, 33, 16)


def test_check_flat_index_space_message():
    with pytest.raises(ValueError, match="overflows int32"):
        E.check_flat_index_space(1 << 20, 1 << 10, 32)


# ------------------------------------------------------- shard_lane format
def test_shard_lane_public_wire_format(small_corpus):
    """shard_lane is the public wire unit: [1, NC] int32 ascending flat
    indices, -1 sentinel, count may exceed NC; the variant-key payload
    slot is None for schemes without fused keys."""
    from repro.core.filter import build_ish_filter
    from repro.extraction.sharded import shard_lane

    d = small_corpus.dictionary
    f = build_ish_filter(d, GAMMA)
    flt = (jnp.asarray(f.bits), f.num_bits, f.num_hashes)
    params = E.ExtractParams(gamma=GAMMA, scheme="prefix", use_kernel=True,
                             max_candidates=64)
    docs = jnp.asarray(small_corpus.doc_tokens)
    lane, count, keys = shard_lane(docs, 0, d.max_len, flt, params)
    assert keys is None, "prefix scheme ships no key payload"
    lane, count = np.asarray(lane), np.asarray(count)
    assert lane.shape == (1, 64) and lane.dtype == np.int32
    assert count.shape == (1,) and count.dtype == np.int32
    valid = lane[0][lane[0] >= 0]
    assert (np.diff(valid) > 0).all(), "lane indices must ascend"
    assert (lane[0][len(valid):] == -1).all(), "-1 sentinel pads the tail"
    assert int(count[0]) >= len(valid)


def test_shard_lane_variant_key_payload(small_corpus):
    """Fused variant keys ride the wire as a [1, NC, 2] uint32 payload,
    0 in padded slots, bit-identical to window_variant_key over the
    lane's decoded windows."""
    from repro.core.filter import build_ish_filter
    from repro.core.variants import window_variant_key
    from repro.extraction.sharded import shard_lane

    d = small_corpus.dictionary
    f = build_ish_filter(d, GAMMA)
    flt = (jnp.asarray(f.bits), f.num_bits, f.num_hashes)
    params = E.ExtractParams(gamma=GAMMA, scheme="variant", use_kernel=True,
                             max_candidates=64)
    docs = jnp.asarray(small_corpus.doc_tokens)
    lane, count, keys = shard_lane(docs, 0, d.max_len, flt, params)
    assert keys is not None and keys.shape == (1, 64, 2)
    assert np.asarray(keys).dtype == np.uint32
    lane, keys = np.asarray(lane)[0], np.asarray(keys)[0]
    T, L = docs.shape[1], d.max_len
    docs_np = np.asarray(docs)
    for j, flat in enumerate(lane):
        if flat < 0:
            assert keys[j, 0] == 0 and keys[j, 1] == 0
            continue
        dd, rem = divmod(flat, T * L)
        p, l = divmod(rem, L)
        win = np.zeros((1, L), np.int32)
        n = min(l + 1, T - p)
        win[0, :n] = docs_np[dd, p:p + n]
        k1, k2 = window_variant_key(win, win != 0, xp=np)
        assert keys[j, 0] == k1[0] and keys[j, 1] == k2[0]


@pytest.mark.parametrize("overlap", [True, False])
def test_serving_parity_variant_adaptive_lanes(small_corpus, overlap):
    """Serving with adaptive two-pass lane sizing (probe stage is eager,
    so the per-batch count pass runs live) must stay bit-identical to
    the one-shot reference for the fused variant scheme."""
    cache = SessionCache()
    sess = cache.get_or_create(
        small_corpus.dictionary,
        _config(adaptive_lanes=True),
        plan=pure_plan("variant"),
    )
    docs = _var_docs(small_corpus, seed=41, n=7)
    svc = _serve(cache, sess, docs, overlap)
    assert svc.results_set() == _one_shot(sess, docs)


# ---------------------------------------------------------------- metrics
def test_pipeline_schedule_overlap_beats_serial():
    ready = [0.0, 0.0, 0.0, 0.0]
    probe = [1.0] * 4
    verify = [1.0] * 4
    _, over = pipeline_schedule(ready, probe, verify, overlap=True)
    _, serial = pipeline_schedule(ready, probe, verify, overlap=False)
    assert over[-1] == pytest.approx(5.0)  # 1 fill + 4 drains
    assert serial[-1] == pytest.approx(8.0)  # 4 * (probe + verify)
    assert (np.asarray(over) <= np.asarray(serial)).all()


def test_pipeline_schedule_double_buffer_backpressure():
    """A slow verify stage must stall probe once both buffers fill."""
    ready = [0.0] * 4
    probe = [0.1] * 4
    verify = [10.0] * 4
    pd, _ = pipeline_schedule(ready, probe, verify, overlap=True,
                              buffer_depth=2)
    # probe 2 can run ahead, probe 3 waits for verify to start batch 1
    assert pd[2] < 1.0 and pd[3] > 10.0


def test_metrics_percentiles_and_summary():
    from repro.serving.metrics import ServingMetrics, percentiles

    p = percentiles(np.arange(1, 101))
    assert p["p50"] == pytest.approx(50.5) and p["p99"] == pytest.approx(99.01)
    m = ServingMetrics()
    m.record_submit(True, depth=3, now=0.0)
    m.record_submit(False, depth=4, now=0.1)
    m.record_batch(batch_id=0, rows=2, occupancy=0.5, n_lanes=1,
                   flush_s=0.0, probe_s=0.01, verify_s=0.02)
    m.record_done(latency_s=0.5, done_s=1.0)
    s = m.summary()
    assert s["submitted"] == 2 and s["rejected"] == 1
    assert s["queue_depth_max"] == 4 and s["occupancy_mean"] == 0.5
    assert s["docs_per_s"] == pytest.approx(2.0)


# ------------------------------------------------- live updates (epochs)
def _delta_from(corpus, rows, tombstones=()):
    """Delta whose adds copy dictionary rows (so they match documents)."""
    from repro.updates import DictionaryDelta

    d = corpus.dictionary
    added = tuple(
        tuple(int(t) for t in d.tokens[i, : int(d.lengths[i])]) for i in rows
    )
    return DictionaryDelta(added=added, tombstones=tuple(tombstones))


def test_apply_delta_hot_swap_parity(small_corpus):
    """Serve, hot-swap a delta, serve again: each stream matches its own
    epoch's one-shot reference; the session was never evicted."""
    cache = SessionCache()
    sess = cache.get_or_create(small_corpus.dictionary, _config(),
                               plan=pure_plan("prefix"))
    docs = _var_docs(small_corpus, seed=31, n=6)
    svc = _serve(cache, sess, docs, overlap=True)
    ref0 = _one_shot(sess, docs)
    assert svc.results_set() == ref0

    e0 = sess.epoch
    sess.apply_delta(_delta_from(small_corpus, rows=(2, 3), tombstones=(0,)),
                     force_action="absorb")
    assert sess.epoch == e0 + 1
    assert cache.misses == 1 and len(cache) == 1  # same session object
    svc2 = _serve(cache, sess, docs, overlap=True)
    ref1 = _one_shot(sess, docs)
    assert svc2.results_set() == ref1
    assert ref1 != ref0  # the tombstone (a matching entity) changed results
    recs = svc2.metrics.batch_records
    assert all(r["epoch"] == e0 + 1 for r in recs)


@pytest.mark.parametrize("action", ["absorb", "compact"])
def test_epoch_swap_under_inflight_load(small_corpus, action):
    """The no-drain swap contract: batches dispatched before apply_delta
    finish on the old epoch, later ones on the new — and every request's
    results equal a single-epoch run of its own batch's epoch."""
    cache = SessionCache()
    sess = cache.get_or_create(small_corpus.dictionary, _config(),
                               plan=pure_plan("prefix"))
    docs = _var_docs(small_corpus, seed=32, n=10)
    svc = ExtractionService(
        cache, pools=make_pools(),
        batcher_config=BatcherConfig(max_batch_docs=2, max_delay_s=0.0),
        overlap=True,
    )
    e0 = sess.epoch
    with svc:
        for i in range(5):
            assert svc.submit(i, docs[i], sess.key) is not None
        svc.tick()  # dispatch: these batches are pinned to epoch e0
        ref0 = one_shot_reference(sess, docs, epoch=e0)
        state = sess.apply_delta(
            _delta_from(small_corpus, rows=(1, 2), tombstones=(0, 4)),
            force_action=action,
        )
        e1 = sess.epoch
        assert e1 > e0 and state is sess.current_state
        ref1 = one_shot_reference(sess, docs, epoch=e1)
        for i in range(5, 10):
            assert svc.submit(i, docs[i], sess.key) is not None
        svc.drain()
    epoch_of = {r["batch_id"]: r["epoch"] for r in svc.metrics.batch_records}
    seen = set()
    for req in svc.completed:
        ep = epoch_of[req.batch_id]
        seen.add(ep)
        ref = ref0 if ep == e0 else ref1
        want = {(d, p, l, e) for (d, p, l, e) in ref if d == req.doc_id}
        got = {(d, p, l, e) for (d, p, l, e, _s) in req.matches}
        assert got == want, (req.doc_id, ep)
    assert seen == {e0, e1}  # the swap really straddled in-flight work
    assert ref0 != ref1
    # old epoch state was garbage-collected once its last batch finished
    assert sorted(sess.epochs) == [e1]


def test_session_cache_summary_counters(small_corpus, zipf_corpus):
    from repro.serving import session_cache_summary
    from repro.serving.session import dictionary_fingerprint as fp

    cache = SessionCache()
    s1 = cache.get_or_create(small_corpus.dictionary, _config(),
                             plan=pure_plan("prefix"))
    cache.get_or_create(small_corpus.dictionary, _config(),
                        plan=pure_plan("prefix"))  # hit
    s2 = cache.get_or_create(zipf_corpus.dictionary, _config(),
                             plan=pure_plan("word"))
    s1.apply_delta(_delta_from(small_corpus, rows=(1,)),
                   force_action="absorb")
    cs = session_cache_summary(cache)
    assert cs["sessions"] == 2
    assert cs["hits"] == 1 and cs["misses"] == 2 and cs["evictions"] == 0
    row = cs["per_session"][s1.key]
    assert row["epoch"] == 1 and row["open_segments"] == 1
    assert row["maintenance"] == ["absorb"]
    assert cs["per_session"][s2.key]["epoch"] == 0


# ------------------------------------------------- per-session quotas
def test_session_quota_sheds_and_counts(small_corpus):
    cache = SessionCache()
    sess = cache.get_or_create(small_corpus.dictionary, _config(),
                               plan=pure_plan("prefix"))
    docs = _var_docs(small_corpus, seed=33, n=6)
    svc = ExtractionService(
        cache, pools=make_pools(),
        batcher_config=BatcherConfig(max_batch_docs=8, max_delay_s=0.0),
        session_quota=2,
    )
    with svc:
        got = [svc.submit(i, d, sess.key) for i, d in enumerate(docs)]
        assert sum(r is not None for r in got) == 2  # quota, not capacity
        assert svc.queue.rejected_quota == 4
        assert svc.queue.rejected_by_session[sess.key] == 4
        assert svc.metrics.rejected_quota == 4
        assert svc.metrics.rejected_by_session[sess.key] == 4
        svc.drain()
        # quota frees as batches complete: admission works again
        assert svc.submit(99, docs[0], sess.key) is not None
        svc.drain()
    assert svc.metrics.completed == 3


def test_session_quota_block_backpressures(small_corpus):
    """block=True at the quota: the producer waits for completions
    instead of shedding, and every request is eventually served. The
    nonzero flush deadline is load-bearing: quota-limited requests sit
    in a *non-full* bin, so the retry loop's ticks must read a fresh
    clock for the deadline flush to ever fire (the livelock regression
    this test pins down)."""
    cache = SessionCache()
    sess = cache.get_or_create(small_corpus.dictionary, _config(),
                               plan=pure_plan("prefix"))
    docs = _var_docs(small_corpus, seed=34, n=8)
    svc = ExtractionService(
        cache, pools=make_pools(),
        batcher_config=BatcherConfig(max_batch_docs=3, max_delay_s=0.002),
        session_quota=2,
    )
    with svc:
        for i, d in enumerate(docs):
            assert svc.submit(i, d, sess.key, block=True) is not None
        svc.drain()
    assert svc.metrics.completed == len(docs)
    assert svc.results_set() == _one_shot(sess, docs)


def test_quota_validation():
    with pytest.raises(ValueError, match="session_quota"):
        AdmissionQueue(4, session_quota=0)


# ----------------------------------------- steady-state lane sizing
def test_steady_state_lane_sizing_amortises_count_pass(small_corpus):
    """Same (session, bucket) batches: exactly one count pass per plan
    side, every later batch sizes off the previous batch's counts —
    with results identical to the one-shot reference."""
    cache = SessionCache()
    sess = cache.get_or_create(
        small_corpus.dictionary,
        _config(adaptive_lanes=True),
        plan=pure_plan("prefix"),
    )
    T = small_corpus.doc_tokens.shape[1]
    # equal-length docs -> one length bucket -> one (side, bucket) hint
    docs = [np.asarray(small_corpus.doc_tokens[i % 8, :T])
            for i in range(12)]
    svc = ExtractionService(
        cache, pools=make_pools(),
        batcher_config=BatcherConfig(max_batch_docs=3, max_delay_s=0.0),
    )
    with svc:
        for i, d in enumerate(docs):
            assert svc.submit(i, d, sess.key) is not None
            svc.tick()
        svc.drain()
    assert svc.results_set() == _one_shot(sess, docs)
    sizing = svc.metrics.lane_sizing
    n_sides = len(sess.current_state.sides)
    n_batches = svc.metrics.batches
    assert sizing.get("count_pass", 0) == n_sides  # first batch only
    assert sizing.get("fixed", 0) == 0
    total = sum(sizing.values())
    assert total == n_batches * n_sides
    assert sizing.get("hint", 0) + sizing.get("refit", 0) == total - n_sides
    # the hint cache holds the measured per-tile max for this epoch
    (key, (epoch, tile_max)), *_ = sess.lane_hints.items()
    assert epoch == sess.epoch and tile_max >= 0


def test_shard_lane_steady_matches_shard_lane(small_corpus):
    """Hint, count-pass, undersized-hint (refit) and fixed sizing all
    produce the wire lane of the reference shard_lane."""
    from repro.extraction.sharded import shard_lane, shard_lane_steady

    docs = jnp.asarray(small_corpus.doc_tokens[:8])
    d = small_corpus.dictionary
    from repro.core.filter import build_ish_filter

    f = build_ish_filter(d, GAMMA)
    flt = (jnp.asarray(f.bits), f.num_bits, f.num_hashes)
    base = E.ExtractParams(gamma=GAMMA, scheme="prefix", use_kernel=True,
                           max_candidates=1024)
    ref_lane, ref_n, _ = shard_lane(docs, 0, d.max_len, flt, base, 4)

    adaptive = E.ExtractParams(gamma=GAMMA, scheme="prefix", use_kernel=True,
                               max_candidates=1024, adaptive_lanes=True)
    lane, n, _k, tile_max, sizing = shard_lane_steady(
        docs, 0, d.max_len, flt, adaptive, 4)
    assert sizing == "count_pass" and tile_max >= 0
    np.testing.assert_array_equal(np.asarray(lane), np.asarray(ref_lane))
    assert int(n[0]) == int(ref_n[0])

    lane, n, _k, tm2, sizing = shard_lane_steady(
        docs, 0, d.max_len, flt, adaptive, 4, width_hint=tile_max)
    assert sizing == "hint" and tm2 == tile_max
    np.testing.assert_array_equal(np.asarray(lane), np.asarray(ref_lane))

    if tile_max > 1:  # an undersized hint must refit, never truncate
        lane, n, _k, tm3, sizing = shard_lane_steady(
            docs, 0, d.max_len, flt, adaptive, 4, width_hint=1)
        assert sizing in ("refit", "hint")  # hint iff rounding covered it
        np.testing.assert_array_equal(np.asarray(lane), np.asarray(ref_lane))
        assert int(n[0]) == int(ref_n[0])

    lane, n, _k, tm, sizing = shard_lane_steady(
        docs, 0, d.max_len, flt, base, 4)
    assert sizing == "fixed" and tm == -1
    np.testing.assert_array_equal(np.asarray(lane), np.asarray(ref_lane))


def test_rebuild_resets_drift_baseline(small_corpus):
    """A drift-triggered rebuild must re-anchor the density baseline:
    otherwise every later delta re-measures against the stale value and
    pays a full re-plan per update."""
    import dataclasses

    cache = SessionCache()
    sess = cache.get_or_create(small_corpus.dictionary, _config(),
                               plan=pure_plan("prefix"))
    # plant a far-off baseline so the first sampled delta drifts
    sess.cost_params = dataclasses.replace(
        sess.cost_params, lane_density=1e-6
    )
    sample = small_corpus.doc_tokens[:8]
    sess.apply_delta(_delta_from(small_corpus, rows=(1,)),
                     sample_docs=sample)
    assert sess.maintenance_log[-1]["action"] == "rebuild"
    assert sess.cost_params.lane_density > 1e-6  # baseline re-anchored
    # same sample again: density unchanged vs the new baseline -> no
    # drift, no second rebuild
    sess.apply_delta(_delta_from(small_corpus, rows=(2,)),
                     sample_docs=sample)
    assert sess.maintenance_log[-1]["action"] != "rebuild"


# --------------------------------------------- metrics edge cases (PR 7)
def test_metrics_percentiles_empty_and_single_sample():
    import math

    from repro.serving.metrics import percentiles

    empty = percentiles([])
    assert set(empty) == {"p50", "p95", "p99"}
    assert all(math.isnan(v) for v in empty.values())
    single = percentiles([0.25])
    assert all(v == pytest.approx(0.25) for v in single.values())


def test_metrics_summary_before_any_batch():
    """summary() on a fresh collector: zero counters, NaN-not-crash
    for every rate and percentile, and empty replan telemetry."""
    import math

    from repro.serving.metrics import ServingMetrics

    s = ServingMetrics().summary()
    assert s["submitted"] == s["rejected"] == s["completed"] == 0
    assert s["batches"] == 0 and s["queue_depth_max"] == 0
    assert s["occupancy_mean"] == 0.0 and s["probe_s_mean"] == 0.0
    assert math.isnan(s["latency_p50_s"]) and math.isnan(s["docs_per_s"])
    assert s["replans"] == 0 and s["replan_events"] == []
    # and the whole report stays JSON-serializable
    import json

    json.dumps(s)


def test_metrics_record_stream_partial_dicts():
    """Partial / empty / unknown-keyed stream dicts fold cleanly, and
    the same dict fans out to an attached ObservedStats."""
    from repro.serving import ObservedStats
    from repro.serving.metrics import ServingMetrics

    m = ServingMetrics()
    obs = ObservedStats(capacity=4)
    m.record_stream({})
    m.record_stream({"tiles_streamed": 3}, observed=obs)
    m.record_stream({"dma_waits": 2, "streamed_launches": 1,
                     "some_future_counter": 9}, observed=obs)
    assert m.tiles_streamed == 3 and m.dma_waits == 2
    assert m.streamed_launches == 1 and m.checkpoint_writes == 0
    assert obs.stream_counters["tiles_streamed"] == 3
    assert obs.stream_counters["some_future_counter"] == 9


def test_metrics_record_replan_counters():
    from repro.serving.metrics import ServingMetrics

    m = ServingMetrics()
    m.record_replan({"reason": "doc_len", "swapped": False})
    m.record_replan({"reason": "lane_density", "swapped": True, "epoch": 1})
    s = m.summary()
    assert s["replans"] == 2 and s["replan_swaps"] == 1
    assert [e["reason"] for e in s["replan_events"]] == [
        "doc_len", "lane_density"]
    # summary deep-copies events: mutating the report must not leak back
    s["replan_events"][0]["reason"] = "mutated"
    assert m.replan_events[0]["reason"] == "doc_len"

"""System-level tests: the dry-run/roofline stack and launch plumbing.

The 512-device production dry-run runs out of process (launch/dryrun.py);
here we exercise the same machinery in-process on small meshes so a
sharding or analysis regression fails fast in CI.
"""
from __future__ import annotations

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ShapeConfig
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.launch import hlo_analysis as H
from repro.launch import roofline as RL
from repro.launch.mesh import make_cpu_mesh
from repro.launch.specs import abstract_params, build_cell
from repro.launch.tuning import default_microbatches, resolve
from repro.models.model import build_model
from repro.models.sharding import ShardingRules
from repro.compat import set_mesh


# --------------------------------------------------------------------------
# hlo_analysis: trip-count awareness + parser robustness
# --------------------------------------------------------------------------


def _analyze(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return H.analyze(txt)


def test_scan_flops_are_trip_multiplied():
    A = jnp.zeros((128, 128), jnp.float32)

    def body(x, _):
        return x @ A, None

    def scanned(x):
        return jax.lax.scan(body, x, None, length=8)[0]

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    s_scan = _analyze(scanned, x)
    one = 2 * 128**3
    # XLA's own cost_analysis reports ~1x here; ours must report ~8x.
    assert s_scan.mxu_flops == pytest.approx(8 * one, rel=0.05), s_scan.mxu_flops


def test_nested_scan_flops():
    A = jnp.zeros((64, 64), jnp.float32)

    def inner(x, _):
        return x @ A, None

    def outer(x, _):
        return jax.lax.scan(inner, x, None, length=3)[0], None

    def fn(x):
        return jax.lax.scan(outer, x, None, length=5)[0]

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    s = _analyze(fn, x)
    assert s.mxu_flops == pytest.approx(15 * 2 * 64**3, rel=0.05)


def test_parser_stable_on_scan_without_collectives():
    def fn(x):
        return jax.lax.scan(lambda c, _: (c * 2.0, None), x, None, length=4)[0]

    s = _analyze(fn, jax.ShapeDtypeStruct((32,), jnp.float32))
    assert s.wire_bytes == 0.0
    assert s.unknown_trip_whiles == 0


def test_type_bytes_parses_tuples_and_layouts():
    assert H._type_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert H._type_bytes("(bf16[4,4]{1,0}, s32[2]{0})") == 32 + 8
    assert H._type_bytes("pred[]") == 1
    assert H._type_bytes("token[]") == 0


@given(st.lists(st.integers(1, 64), min_size=0, max_size=4))
@settings(max_examples=50, deadline=None)
def test_elems_matches_product(dims):
    s = ",".join(str(d) for d in dims)
    want = int(np.prod(dims)) if dims else 1
    assert H._elems(s) == want


def test_group_size_iota_and_list():
    assert H._group_size("replica_groups=[32,16]<=[512]") == 16
    assert H._group_size("replica_groups={{0,1,2,3}}") == 4
    assert H._group_size("no groups here") == 1


# --------------------------------------------------------------------------
# roofline model
# --------------------------------------------------------------------------


def test_model_flops_dense_train_matches_6nd():
    cfg = get_config("yi-9b")
    shape = SHAPES["train_4k"]
    mf = RL.model_flops(cfg, shape)
    # yi-9b ~8.8B params; 6*N*D within a loose band
    n_est = mf / (6.0 * shape.global_batch * shape.seq_len)
    assert 7e9 < n_est < 10e9, n_est


def test_model_flops_moe_counts_active_only():
    import dataclasses

    cfg = get_config("dbrx-132b")
    active = RL.model_flops(cfg, SHAPES["train_4k"])
    all_on = dataclasses.replace(cfg, top_k=cfg.num_experts)
    assert RL.model_flops(all_on, SHAPES["train_4k"]) > 2 * active


def test_decode_flops_scale_with_batch_not_seq():
    cfg = get_config("olmo-1b")
    d32 = RL.model_flops(cfg, SHAPES["decode_32k"])
    tr = RL.model_flops(cfg, SHAPES["train_4k"])
    assert d32 < tr / 1000


# --------------------------------------------------------------------------
# specs/build_cell on tiny meshes (same code path as the 512-dev dry-run)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["train", "prefill", "decode"])
def test_build_cell_lowers_on_cpu_mesh(mode):
    mesh = make_cpu_mesh(1, 1)
    cfg = get_smoke_config("olmo-1b")
    shape = ShapeConfig("t", seq_len=64, global_batch=4, mode=mode)
    cell = build_cell(cfg, shape, mesh)
    with set_mesh(mesh):
        lowered = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums,
        ).lower(*cell.args)
        compiled = lowered.compile()
    assert compiled.cost_analysis() is not None
    stats = H.analyze(compiled.as_text())
    assert stats.mxu_flops > 0


def test_abstract_params_allocate_nothing_and_match_init():
    mesh = make_cpu_mesh(1, 1)
    cfg = get_smoke_config("xlstm-125m")
    model = build_model(cfg, ShardingRules(mesh))
    p_shapes, specs = abstract_params(model)
    p_real, specs_real = model.init(jax.random.PRNGKey(0))
    flat_a = jax.tree.leaves(p_shapes)
    flat_b = jax.tree.leaves(p_real)
    assert len(flat_a) == len(flat_b)
    for a, b in zip(flat_a, flat_b):
        assert a.shape == b.shape and a.dtype == b.dtype
    assert jax.tree.structure(specs) == jax.tree.structure(specs_real)


def test_default_microbatches_fit_budget():
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    for arch in ("dbrx-132b", "yi-9b", "olmo-1b"):
        cfg = get_config(arch)
        mb = default_microbatches(cfg, SHAPES["train_4k"], FakeMesh())
        per_dev = SHAPES["train_4k"].global_batch // 16
        assert 1 <= mb <= per_dev
        stash = cfg.num_groups * (per_dev / mb) * 4096 * cfg.d_model * 2
        assert stash <= 4e9 or mb == per_dev, (arch, mb, stash)


def test_resolve_tuned_overrides_cfg():
    from repro.launch import tuning

    mesh = make_cpu_mesh(1, 1)
    cfg = get_config("olmo-1b")
    key = (cfg.name, "train_4k")
    old = tuning.TUNED.get(key)
    tuning.TUNED[key] = {"cfg": {"attn_chunk": 512}, "microbatches": 4}
    try:
        cfg2, knobs = resolve(cfg, SHAPES["train_4k"], mesh, tuned=True)
        assert cfg2.attn_chunk == 512 and knobs["microbatches"] == 4
        cfg3, _ = resolve(cfg, SHAPES["train_4k"], mesh, tuned=False)
        assert cfg3.attn_chunk == cfg.attn_chunk
    finally:
        if old is None:
            tuning.TUNED.pop(key)
        else:
            tuning.TUNED[key] = old


# --------------------------------------------------------------------------
# registry coverage: every assigned arch present with the exact shapes
# --------------------------------------------------------------------------


def test_all_ten_archs_registered_with_assigned_dims():
    assert len(ARCH_IDS) == 10
    spec = {
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "yi-9b": (48, 4096, 32, 4, 11008, 64000),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads,
                cfg.num_kv_heads, cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, ff, v), arch

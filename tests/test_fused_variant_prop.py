"""Property-based parity: the fused variant recurrence vs the jnp oracle.

Two contracts, fuzzed over adversarial windows (PAD-heavy, all-duplicate,
zero-survivor):

* the kernel's streaming duplicate mask (shifted compares against the
  previously shifted token streams, ``streaming_first_occurrence``) must
  be bit-identical to ``core.semantics.first_occurrence_mask``;
* the fused in-kernel variant keys (running (sum, xor, count) set-hash
  fold under that mask) must be bit-identical to
  ``core.variants.window_variant_key`` at every (pos, len) — dense mode
  checks the whole [D, T, L, 2] tensor, lane mode checks the epilogue's
  key payload at the emitted flat indices.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core.semantics import first_occurrence_mask
from repro.core.variants import window_variant_key
from repro.kernels import fused_probe as fp
from repro.kernels import ops as kops

# small vocabularies force duplicate-heavy windows; 0 is PAD
_rows = st.lists(
    st.lists(st.integers(0, 6), min_size=1, max_size=12),
    min_size=1,
    max_size=8,
)


def _pad(rows):
    L = max(len(r) for r in rows)
    out = np.zeros((len(rows), L), dtype=np.int32)
    for i, r in enumerate(rows):
        out[i, : len(r)] = r
    return out


@given(_rows)
@settings(max_examples=80, deadline=None)
def test_streaming_dup_mask_matches_first_occurrence(rows):
    toks = _pad(rows)
    got = fp.streaming_first_occurrence(toks, xp=np)
    want = np.asarray(first_occurrence_mask(toks, xp=np))
    np.testing.assert_array_equal(got, want)


@given(
    st.integers(1, 6),  # D
    st.integers(4, 24),  # T
    st.integers(1, 6),  # L
    st.integers(2, 9),  # vocab (incl. PAD -> duplicate- and PAD-heavy)
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_fused_variant_keys_match_oracle(D, T, L, vocab, seed):
    rng = np.random.default_rng(seed)
    docs = rng.integers(0, vocab, size=(D, T)).astype(np.int32)
    docsj = jnp.asarray(docs)
    # dense mode: every (pos, len) key must match the oracle
    _, sigs, _, _, _ = fp.fused_probe_pallas(
        docsj, jnp.zeros((8,), jnp.uint32), 256, 1, L,
        sig_mode="variant", use_filter=False,
    )
    sigs = np.asarray(sigs)  # [D, T, L, 2]
    for l in range(L):
        win = np.zeros((D, T, l + 1), dtype=np.int32)
        for o in range(l + 1):
            win[:, : T - o, o] = docs[:, o:]
        k1, k2 = window_variant_key(win, win != 0, xp=np)
        np.testing.assert_array_equal(sigs[..., l, 0], k1)
        np.testing.assert_array_equal(sigs[..., l, 1], k2)
    # lane mode: the epilogue's key payload must match at its indices
    _, _, _, cands, vkeys = fp.fused_probe_pallas(
        docsj, jnp.zeros((8,), jnp.uint32), 256, 1, L,
        sig_mode="variant", use_filter=False, candidates=16,
    )
    cands, vkeys = np.asarray(cands), np.asarray(vkeys)
    for g in range(cands.shape[0]):
        for j in range(cands.shape[1]):
            flat = cands[g, j]
            if flat < 0:
                assert vkeys[g, j, 0] == 0 and vkeys[g, j, 1] == 0
                continue
            d, rem = divmod(flat, T * L)
            p, l = divmod(rem, L)
            assert vkeys[g, j, 0] == sigs[d, p, l, 0]
            assert vkeys[g, j, 1] == sigs[d, p, l, 1]


@given(st.integers(0, 2**31 - 1), st.integers(1, 24))
@settings(max_examples=30, deadline=None)
def test_two_pass_lane_width_is_exact(seed, nc):
    """Any W >= the per-tile survivor max keeps the narrow emit pass a
    bit-exact prefix of the worst-case [G, NC] lanes."""
    rng = np.random.default_rng(seed)
    docs = jnp.asarray(rng.integers(0, 64, size=(9, 40)).astype(np.int32))
    counts = kops.fused_probe_count(docs, None, 5, nc)
    w = fp.round_lane_width(int(np.asarray(counts).max()), nc)
    _, _, c_wide, wide, _ = kops.fused_probe_compact(docs, None, 5, nc)
    _, _, c_narrow, narrow, _ = kops.fused_probe_compact(
        docs, None, 5, nc, lane_width=w
    )
    np.testing.assert_array_equal(np.asarray(c_wide), np.asarray(counts))
    np.testing.assert_array_equal(np.asarray(c_narrow), np.asarray(counts))
    np.testing.assert_array_equal(
        np.asarray(narrow), np.asarray(wide)[:, :w]
    )

import numpy as np
import pytest

from repro.data.synth import make_corpus


@pytest.fixture(scope="session")
def small_corpus():
    return make_corpus(
        num_docs=8, doc_len=64, vocab_size=512, num_entities=24, seed=1
    )


@pytest.fixture(scope="session")
def zipf_corpus():
    return make_corpus(
        num_docs=24,
        doc_len=96,
        vocab_size=1024,
        num_entities=48,
        mention_dist="zipf",
        seed=3,
    )

"""Similarity semantics: paper examples, parity, predicate relations."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core.semantics import (
    SIM_EXTRA,
    SIM_JACCARD,
    SIM_MISSING,
    SIM_VARIANT_EXACT,
    similarity,
)

# vocabulary for the paper's §2 example
APPLE, IPHONE, FOUR, G32, CHARGER, BLACK = 1, 2, 3, 4, 5, 6
V = 16


def _tw(weights=None):
    tw = np.ones((V,), dtype=np.float32)
    tw[0] = 0.0
    if weights:
        for t, w in weights.items():
            tw[t] = w
    return tw


def _sim(name, ent, win, tw, xp=np):
    ent = np.array([ent + [0] * (6 - len(ent))], dtype=np.int32)
    win = np.array([win + [0] * (6 - len(win))], dtype=np.int32)
    if xp is np:
        return float(similarity(name, ent, win, tw, xp=np)[0])
    return float(
        similarity(name, jnp.asarray(ent), jnp.asarray(win), jnp.asarray(tw), xp=jnp)[0]
    )


def test_paper_example_jaccard_containment():
    tw = _tw()
    e1 = [IPHONE, CHARGER]
    e2 = [APPLE, IPHONE, FOUR, BLACK, G32]  # stand-in for the long entity
    s1 = [IPHONE, FOUR]
    approx = pytest.approx
    # JaccCont_missing(E2, S1) = w(e∩s)/w(s) = 2/2 = 1 (S1 ⊆ E2)
    assert _sim(SIM_MISSING, e2, s1, tw) == approx(1.0)
    # JaccCont_missing(E1, S1) = 1/2
    assert _sim(SIM_MISSING, e1, s1, tw) == approx(0.5)
    # extra variation: coverage of the entity
    assert _sim(SIM_EXTRA, e2, s1, tw) == approx(2.0 / 5.0)
    assert _sim(SIM_EXTRA, e1, s1, tw) == approx(0.5)
    # symmetric jaccard
    assert _sim(SIM_JACCARD, e1, s1, tw) == approx(1.0 / 3.0)


def test_weighted_example_def2():
    # Apple:1 iPhone:8 4:2 32G:1, gamma=0.75 -> {iPhone 4} has weight 10/12
    tw = _tw({APPLE: 1.0, IPHONE: 8.0, FOUR: 2.0, G32: 1.0})
    e = [APPLE, IPHONE, FOUR, G32]
    assert _sim(SIM_EXTRA, e, [IPHONE, FOUR], tw) >= 0.75
    assert _sim(SIM_EXTRA, e, [IPHONE], tw) < 0.75
    assert _sim(SIM_EXTRA, e, [APPLE, IPHONE, FOUR], tw) >= 0.75


def test_variant_exact_requires_subset():
    tw = _tw()
    e = [APPLE, IPHONE, FOUR]
    assert _sim(SIM_VARIANT_EXACT, e, [APPLE, IPHONE], tw) == pytest.approx(2.0 / 3.0)
    # junk token breaks the subset requirement
    assert _sim(SIM_VARIANT_EXACT, e, [APPLE, IPHONE, CHARGER], tw) == 0.0
    # but plain extra-containment tolerates it
    assert _sim(SIM_EXTRA, e, [APPLE, IPHONE, CHARGER], tw) == pytest.approx(2.0 / 3.0)


def test_duplicate_window_tokens_counted_once():
    tw = _tw()
    e = [APPLE, IPHONE]
    assert _sim(SIM_MISSING, e, [APPLE, APPLE, APPLE], tw) == pytest.approx(1.0)
    assert _sim(SIM_JACCARD, e, [APPLE, APPLE], tw) == pytest.approx(0.5)


@given(
    st.lists(st.integers(1, V - 1), min_size=1, max_size=5, unique=True),
    st.lists(st.integers(1, V - 1), min_size=1, max_size=5),
)
@settings(max_examples=80, deadline=None)
def test_np_jnp_parity_and_relations(ent, win):
    tw = _tw()
    for name in (SIM_MISSING, SIM_EXTRA, SIM_JACCARD, SIM_VARIANT_EXACT):
        a = _sim(name, ent, win, tw, xp=np)
        b = _sim(name, ent, win, tw, xp=jnp)
        assert abs(a - b) < 1e-6
    # variant_exact(e,s) > 0 implies it equals extra(e,s)
    ve = _sim(SIM_VARIANT_EXACT, ent, win, tw)
    ex = _sim(SIM_EXTRA, ent, win, tw)
    if ve > 0:
        assert abs(ve - ex) < 1e-6
    assert ve <= ex + 1e-6
    # jaccard lower-bounds both containments
    assert _sim(SIM_JACCARD, ent, win, tw) <= min(ex, _sim(SIM_MISSING, ent, win, tw)) + 1e-6

"""Chunkwise-parallel mLSTM must match the exact sequential recurrence."""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import get_smoke_config
from repro.launch.mesh import make_cpu_mesh
from repro.models import xlstm as X
from repro.models.model import build_model
from repro.models.sharding import ShardingRules


def _setup(seq: int, chunk: int):
    cfg = dataclasses.replace(
        get_smoke_config("xlstm-125m"), dtype="float32",
        mlstm_chunk=chunk,
    )
    mesh = make_cpu_mesh(1, 1)
    rules = ShardingRules(mesh)
    p, _ = X.init_mlstm(jax.random.PRNGKey(0), cfg, rules)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, seq, cfg.d_model)) * 0.5,
                    jnp.float32)
    return cfg, p, x


@pytest.mark.parametrize("seq,chunk", [(64, 16), (96, 32), (50, 16)])
def test_chunkwise_matches_sequential(seq, chunk):
    cfg, p, x = _setup(seq, chunk)
    y_c, st_c = X.apply_mlstm(cfg, p, x)
    cfg_seq = dataclasses.replace(cfg, mlstm_chunk=0)
    y_s, st_s = X.apply_mlstm(cfg_seq, p, x)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                               rtol=1e-4, atol=1e-4)
    for k in ("C", "n", "m"):
        np.testing.assert_allclose(np.asarray(st_c[k]), np.asarray(st_s[k]),
                                   rtol=1e-4, atol=1e-4, err_msg=k)


def test_chunkwise_with_carried_state():
    """Splitting a sequence across two calls == one call (state carry)."""
    cfg, p, x = _setup(64, 16)
    y_full, st_full = X.apply_mlstm(cfg, p, x)
    y_a, st_a = X.apply_mlstm(cfg, p, x[:, :32])
    y_b, st_b = X.apply_mlstm(cfg, p, x[:, 32:], state=st_a)
    np.testing.assert_allclose(np.asarray(y_a), np.asarray(y_full[:, :32]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_b), np.asarray(y_full[:, 32:]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_b["C"]), np.asarray(st_full["C"]),
                               rtol=1e-4, atol=1e-4)


def test_chunkwise_grads_match_sequential():
    cfg, p, x = _setup(48, 16)
    cfg_seq = dataclasses.replace(cfg, mlstm_chunk=0)

    def loss(p, c):
        y, _ = X.apply_mlstm(c, p, x)
        return (y * y).mean()

    g_c = jax.grad(lambda p: loss(p, cfg))(p)
    g_s = jax.grad(lambda p: loss(p, cfg_seq))(p)
    for k in g_c:
        np.testing.assert_allclose(np.asarray(g_c[k]), np.asarray(g_s[k]),
                                   rtol=5e-3, atol=5e-3, err_msg=k)


def test_full_model_chunkwise_matches_sequential():
    cfg = dataclasses.replace(get_smoke_config("xlstm-125m"),
                              dtype="float32", mlstm_chunk=16)
    cfg_seq = dataclasses.replace(cfg, mlstm_chunk=0, slstm_unroll=1)
    mesh = make_cpu_mesh(1, 1)
    rules = ShardingRules(mesh)
    m_c = build_model(cfg, rules)
    m_s = build_model(cfg_seq, rules)
    params, _ = m_c.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 64)),
        jnp.int32)
    lc, _ = m_c.forward(params, toks)
    ls, _ = m_s.forward(params, toks)
    np.testing.assert_allclose(np.asarray(lc), np.asarray(ls),
                               rtol=2e-4, atol=2e-4)

"""Property-based statement of the live-updates parity contract.

Hypothesis drives random add/tombstone sequences (lengths, token
choices, delete targets and delta counts all generated — including the
empty-delta and delete-only corners) and asserts the same invariants
``test_updates.py`` checks with seeded sequences:

* delta-built prepared state answers filter membership exactly like a
  from-scratch build over base ∪ adds (bit-union identity);
* end-to-end extraction over an absorbed sequence equals the rebuild
  oracle over the live entity set, per scheme family;
* compaction is a pure renumbering (id_map bijection on results).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core.dictionary import Dictionary, build_dictionary
from repro.core.eejoin import EEJoinConfig, EEJoinOperator
from repro.core.filter import build_ish_filter
from repro.serving.session import pure_plan
from repro import updates as U

GAMMA = 0.8
VOCAB = 64  # tiny vocabulary: adds collide with base entities often


def _cfg(**kw):
    kw.setdefault("gamma", GAMMA)
    kw.setdefault("max_candidates", 2048)
    kw.setdefault("result_capacity", 4096)
    kw.setdefault("use_kernel", True)
    return EEJoinConfig(**kw)


_entity = st.lists(
    st.integers(1, VOCAB - 1), min_size=1, max_size=4, unique=True
)

# one generated update: entities to add + draw-indices for tombstones
# (resolved against the live set at apply time)
_delta_spec = st.tuples(
    st.lists(_entity, min_size=0, max_size=3),
    st.lists(st.integers(0, 10**6), min_size=0, max_size=3),
)

_sequence = st.lists(_delta_spec, min_size=1, max_size=3)


def _base_version(seed: int) -> tuple[Dictionary, np.ndarray]:
    rng = np.random.default_rng(seed)
    ents = []
    seen = set()
    while len(ents) < 8:
        n = int(rng.integers(1, 5))
        toks = tuple(int(t) for t in rng.choice(VOCAB - 1, n, replace=False) + 1)
        if toks not in seen:
            seen.add(toks)
            ents.append(list(toks))
    d = build_dictionary(ents, VOCAB)
    docs = rng.integers(0, VOCAB, size=(4, 32)).astype(np.int32)
    return d, docs


def _resolve(version: U.DictionaryVersion, spec) -> U.DictionaryDelta:
    adds, tomb_draws = spec
    live = np.nonzero(version.live_mask())[0]
    tombs = []
    for draw in tomb_draws:
        pool = [int(t) for t in live if t not in tombs]
        if len(pool) <= 1:
            break  # keep at least one live entity
        tombs.append(pool[draw % len(pool)])
    return U.DictionaryDelta(
        added=tuple(tuple(e) for e in adds), tombstones=tuple(tombs)
    )


@given(_sequence, st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_union_filter_is_merged_build(specs, seed):
    base, _docs = _base_version(seed)
    cfg = _cfg()
    version = U.DictionaryVersion.initial(base)
    words = build_ish_filter(base, GAMMA, num_bits=cfg.filter_bits).bits
    for spec in specs:
        delta = _resolve(version, spec)
        version = version.apply(delta)
        if delta.num_added:
            seg = version.segments[-1]
            segf = build_ish_filter(seg, GAMMA, num_bits=cfg.filter_bits)
            words = U.union_filter_words(words, segf)
    rows, lens, freq = version.entity_rows()
    full = Dictionary(
        tokens=rows, lengths=lens, freq=freq,
        token_weight=base.token_weight,
        entity_weight=base.token_weight[rows].sum(axis=1),
    )
    want = build_ish_filter(full, GAMMA, num_bits=cfg.filter_bits).bits
    np.testing.assert_array_equal(words, want)


@pytest.mark.parametrize(
    "plan", [pure_plan("prefix"), pure_plan("variant"),
             pure_plan("prefix", algo="index")],
    ids=["ssjoin-prefix", "ssjoin-variant", "index-prefix"],
)
@given(specs=_sequence, seed=st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_absorbed_sequence_matches_rebuild_oracle(plan, specs, seed):
    base, docs = _base_version(seed)
    cfg = _cfg()
    op = EEJoinOperator(base, cfg)
    state = U.initial_epoch(base, plan, op.prepare(plan))
    docs = jnp.asarray(docs)
    for spec in specs:
        state = U.absorb_delta(state, _resolve(state.version, spec), cfg)
    got = U.epoch_matches(state, docs, cfg)
    want = U.oracle_matches(state.version, cfg, plan, docs)
    assert got == want


@given(specs=_sequence, seed=st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_compaction_is_pure_renumbering(specs, seed):
    base, docs = _base_version(seed)
    cfg = _cfg()
    plan = pure_plan("prefix")
    op = EEJoinOperator(base, cfg)
    state = U.initial_epoch(base, plan, op.prepare(plan))
    docs = jnp.asarray(docs)
    for spec in specs:
        state = U.absorb_delta(state, _resolve(state.version, spec), cfg)
    before = U.epoch_matches(state, docs, cfg)
    state2, _ = U.compact_epoch(state, cfg)
    after = U.epoch_matches(state2, docs, cfg)
    id_map = state2.id_map
    assert {(d, p, l, int(id_map[e])) for (d, p, l, e) in after} == before
    # id_map is injective over the live set
    assert len(set(id_map.tolist())) == len(id_map)

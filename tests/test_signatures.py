"""Signature-scheme completeness contracts (§3.3)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core.dictionary import build_dictionary
from repro.core.semantics import SIM_EXTRA, SIM_VARIANT_EXACT, similarity
from repro.core.signatures import (
    SIG_LSH,
    SIG_PREFIX,
    SIG_VARIANT,
    SIG_WORD,
    LshParams,
    entity_signatures,
    prefix_token_sets,
    window_signatures,
)

V = 64
GAMMA = 0.7


def _dict_one(ent_tokens, tw=None):
    return build_dictionary([ent_tokens], V, token_weight=tw)


@given(
    st.lists(st.integers(1, V - 1), min_size=2, max_size=6, unique=True),
    st.data(),
)
@settings(max_examples=60, deadline=None)
def test_prefix_sets_are_hitting_sets(ent, data):
    """Any window with extra-containment >= gamma contains a prefix token."""
    d = _dict_one(ent)
    (prefix,) = prefix_token_sets(d, GAMMA)
    # adversarial window: entity tokens minus the prefix set
    rest = [t for t in ent if t not in prefix.tolist()]
    tw = d.token_weight
    if rest:
        win = np.array([rest + [0] * (6 - len(rest))], dtype=np.int32)
        s = similarity(SIM_EXTRA, d.tokens[:1], win, tw, xp=np)[0]
        assert s < GAMMA, "window avoiding all prefix tokens must not match"
    # random subsets that DO match must intersect the prefix
    idx = data.draw(st.lists(st.integers(0, len(ent) - 1), min_size=1, unique=True))
    sub = [ent[i] for i in idx]
    win = np.array([sub + [0] * (6 - len(sub))], dtype=np.int32)
    s = similarity(SIM_EXTRA, d.tokens[:1], win, tw, xp=np)[0]
    if s >= GAMMA:
        assert set(sub) & set(prefix.tolist())


@given(st.lists(st.integers(1, V - 1), min_size=2, max_size=5, unique=True))
@settings(max_examples=40, deadline=None)
def test_word_prefix_signature_overlap_on_match(ent):
    d = _dict_one(ent)
    L = d.max_len
    for scheme in (SIG_WORD, SIG_PREFIX):
        es = entity_signatures(scheme, d, GAMMA)
        # the full-entity window must share a signature
        win = jnp.asarray(d.tokens[:1])
        ws, wm = window_signatures(scheme, win, win != 0, GAMMA)
        shared = set(np.asarray(ws)[np.asarray(wm)].tolist()) & set(es.sig.tolist())
        assert shared, f"{scheme}: full mention must share a signature"


def test_variant_signatures_are_verification_free(zipf_corpus):
    """A variant signature collision implies a true variant_exact match."""
    c = zipf_corpus
    d = c.dictionary
    es = entity_signatures(SIG_VARIANT, d, GAMMA)
    # probe every window of the first few docs
    from repro.extraction.substrings import window_base_np

    base = window_base_np(c.doc_tokens[:4], d.max_len)
    cand = base.reshape(-1, d.max_len)
    ws, wm = window_signatures(SIG_VARIANT, jnp.asarray(cand), jnp.asarray(cand != 0), GAMMA)
    ws = np.asarray(ws)[:, 0]
    sig_to_ents: dict[int, list[int]] = {}
    for s, e in zip(es.sig.tolist(), es.entity_id.tolist()):
        sig_to_ents.setdefault(s, []).append(e)
    valid = np.cumprod(base.reshape(-1, d.max_len) != 0, axis=-1).astype(bool)[:, 0]
    checked = 0
    for i in range(len(cand)):
        if not valid[i]:
            continue
        for e in sig_to_ents.get(int(ws[i]), ()):
            s = similarity(
                SIM_VARIANT_EXACT,
                d.tokens[e : e + 1],
                cand[i : i + 1],
                d.token_weight,
                xp=np,
            )[0]
            assert s >= GAMMA - 1e-6
            checked += 1
    assert checked > 0, "test corpus produced no variant collisions"


def test_lsh_recall_reasonable():
    rng = np.random.default_rng(0)
    ents = [rng.choice(np.arange(1, V), size=4, replace=False).tolist() for _ in range(50)]
    d = build_dictionary(ents, V)
    lsh = LshParams(bands=8, rows=2)
    es = entity_signatures(SIG_LSH, d, GAMMA, lsh)
    # exact mentions: the entity itself as window
    win = jnp.asarray(d.tokens)
    ws, wm = window_signatures(SIG_LSH, win, win != 0, GAMMA, lsh)
    ws = np.asarray(ws)
    found = 0
    per_ent = {}
    for s, e in zip(es.sig.tolist(), es.entity_id.tolist()):
        per_ent.setdefault(e, set()).add(s)
    for e in range(d.num_entities):
        if set(ws[e].tolist()) & per_ent[e]:
            found += 1
    assert found == d.num_entities, "identical sets must share every band"

"""Multi-host serving fabric: wire codec, transport fault tolerance,
delta replication with epoch agreement, and cluster-routed parity.

The two contracts everything below drills into:

* **Bit-exactness across the wire.** Lane frames, match frames and
  dictionary snapshots/deltas round-trip byte-for-byte, so a remote
  ``select_from_tiles`` merge — and therefore every routed response —
  is bit-identical to the single-host ``one_shot_reference`` at the
  request's admitted epoch.
* **No silent corruption.** A dropped, duplicated, reordered,
  truncated or bit-flipped frame is either detected (crc / redundant
  length / sha256 container fingerprint) and retried, or decodes to
  the identical payload. Faults may cost retries; they may never
  change matches. Retried non-idempotent frames (delta application)
  execute exactly once via the server's seq-dedupe cache.

The multi-process test at the bottom is the CI stand-in for multiple
hosts: real ``spawn`` processes, real TCP sockets, live replicated
deltas mid-stream.
"""
from __future__ import annotations

import contextlib
import threading

import numpy as np
import pytest

from repro.core.eejoin import EEJoinConfig
from repro.data.synth import make_corpus
from repro.extraction.sharded import lanes_from_wire, lanes_to_wire
from repro.fabric.cluster import (
    ClusterCoordinator,
    ClusterShed,
    launch_local_cluster,
)
from repro.fabric.replica import ReplicaServer, encode_request
from repro.fabric.ring import HashRing
from repro.fabric.transport import (
    Endpoint,
    FaultPlan,
    FaultyChannel,
    LoopbackChannel,
    RemoteError,
    SocketChannel,
    TransportTimeout,
    loopback_pair,
    serve_frames,
    socket_pair,
)
from repro.fabric.wire import (
    FRAME_TYPES,
    FT_ACK,
    FT_REQUEST,
    FT_SHUTDOWN,
    FrameError,
    decode_frame,
    encode_frame,
    matches_from_wire,
)
from repro.serving import SessionCache, one_shot_reference
from repro.serving.metrics import ServingMetrics
from repro.serving.session import pure_plan
from repro.updates.delta import (
    DictionaryDelta,
    DictionaryVersion,
    pack_arrays,
    random_delta,
    unpack_arrays,
)

GAMMA = 0.8
SCHEMES = ("word", "prefix", "lsh", "variant")


def _config(**kw):
    kw.setdefault("gamma", GAMMA)
    kw.setdefault("max_candidates", 4096)
    kw.setdefault("result_capacity", 8192)
    kw.setdefault("use_kernel", True)
    return EEJoinConfig(**kw)


def _dense_corpus(seed=7, num_entities=24):
    # small vocab → real matches; a parity check over zero matches is
    # vacuous and every e2e test below asserts non-vacuity
    return make_corpus(num_docs=8, doc_len=48, vocab_size=48,
                      num_entities=num_entities, seed=seed)


def _session(corpus, scheme="word", **cfg):
    cache = SessionCache()
    return cache, cache.get_or_create(corpus.dictionary, _config(**cfg),
                                      plan=pure_plan(scheme))


def _var_docs(corpus, seed, n=6, min_len=8):
    rng = np.random.default_rng(seed)
    D, T = corpus.doc_tokens.shape
    lens = rng.integers(min_len, T + 1, size=n)
    return [np.asarray(corpus.doc_tokens[i % D, : lens[i]])
            for i in range(n)]


@contextlib.contextmanager
def _thread_cluster(n=2, fault_plans=None, ep_timeout=60.0, ep_retries=3,
                    **coord_kw):
    """In-process cluster: ReplicaServers on loopback serve threads."""
    endpoints, servers, threads = {}, {}, []
    for i in range(n):
        a, b = loopback_pair()
        if fault_plans and i in fault_plans:
            a = FaultyChannel(a, fault_plans[i])
        srv = ReplicaServer(f"t{i}")
        th = threading.Thread(target=serve_frames, args=(b, srv.handle),
                              kwargs={"idle_timeout": 600.0}, daemon=True)
        th.start()
        endpoints[f"t{i}"] = Endpoint(a, timeout=ep_timeout,
                                      retries=ep_retries, backoff=0.01)
        servers[f"t{i}"] = srv
        threads.append(th)
    coord = ClusterCoordinator(endpoints, **coord_kw)
    try:
        yield coord, servers
    finally:
        coord.shutdown()
        for th in threads:
            th.join(timeout=10)


# ------------------------------------------------------------ frame codec
def test_frame_roundtrip_all_types():
    payload = bytes(range(64))
    for ftype in FRAME_TYPES:
        f = decode_frame(encode_frame(ftype, 12345, payload))
        assert (f.ftype, f.seq, f.payload) == (ftype, 12345, payload)
    f = decode_frame(encode_frame(FT_ACK, 0, b""))
    assert (f.ftype, f.seq, f.payload) == (FT_ACK, 0, b"")


def test_frame_every_single_byte_flip_is_detected():
    wire = encode_frame(FT_REQUEST, 7, b"lane payload bytes")
    for i in range(len(wire)):
        for bit in (0x01, 0x80):
            bad = bytearray(wire)
            bad[i] ^= bit
            with pytest.raises(FrameError):
                decode_frame(bytes(bad))


def test_frame_every_truncation_is_detected():
    wire = encode_frame(FT_REQUEST, 9, b"0123456789abcdef")
    for cut in range(len(wire)):
        with pytest.raises(FrameError):
            decode_frame(wire[:cut])
    with pytest.raises(FrameError):
        decode_frame(wire + b"\x00")  # trailing garbage


def test_frame_rejects_unknown_type_and_version():
    with pytest.raises(FrameError):
        encode_frame(200, 1, b"")
    wire = bytearray(encode_frame(FT_ACK, 1, b""))
    wire[4] = 99  # version byte
    with pytest.raises(FrameError):
        decode_frame(bytes(wire))


def test_frame_roundtrip_property():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(
        ftype=st.sampled_from(sorted(FRAME_TYPES)),
        seq=st.integers(min_value=0, max_value=2**32 - 1),
        payload=st.binary(max_size=512),
    )
    @hyp.settings(deadline=None, max_examples=60)
    def run(ftype, seq, payload):
        f = decode_frame(encode_frame(ftype, seq, payload))
        assert (f.ftype, f.seq, f.payload) == (ftype, seq, payload)

    run()


# ------------------------------------------------------------- lane frames
def _lane_geometry(rng, n_sides, G, NC, with_keys):
    lanes = []
    for s in range(n_sides):
        count = rng.integers(0, 2 * NC, size=G).astype(np.int32)
        cand = np.full((G, NC), -1, np.int32)
        for g in range(G):
            n = min(int(count[g]), NC)
            if n:
                vals = np.sort(rng.choice(10_000, size=n, replace=False))
                cand[g, :n] = vals
        keys = (rng.integers(0, 2**32, size=(G, NC, 2), dtype=np.uint64)
                .astype(np.uint32) if with_keys[s] else None)
        lanes.append((count, cand, keys))
    return lanes


def test_lane_wire_roundtrip_property():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(
        seed=st.integers(min_value=0, max_value=2**31),
        n_sides=st.integers(min_value=1, max_value=3),
        G=st.integers(min_value=1, max_value=3),
        NC=st.integers(min_value=1, max_value=16),
        D=st.integers(min_value=1, max_value=4),
        T=st.integers(min_value=1, max_value=12),
        keys=st.lists(st.booleans(), min_size=3, max_size=3),
    )
    @hyp.settings(deadline=None, max_examples=40)
    def run(seed, n_sides, G, NC, D, T, keys):
        rng = np.random.default_rng(seed)
        docs = rng.integers(0, 40, size=(D, T)).astype(np.int32)
        docs[rng.random(size=(D, T)) < 0.2] = 0  # PAD holes + PAD rows
        lanes = _lane_geometry(rng, n_sides, G, NC, keys)
        meta, docs2, lanes2 = lanes_from_wire(
            lanes_to_wire(docs, lanes, {"session": "s", "epoch": 3})
        )
        assert meta["epoch"] == 3 and meta["n_sides"] == n_sides
        np.testing.assert_array_equal(docs2, docs)
        assert docs2.dtype == docs.dtype
        for (c1, l1, k1), (c2, l2, k2) in zip(lanes, lanes2):
            np.testing.assert_array_equal(c2, c1)
            np.testing.assert_array_equal(l2, l1)
            assert l2.dtype == np.int32
            if k1 is None:
                assert k2 is None
            else:
                np.testing.assert_array_equal(k2, k1)
                assert k2.dtype == np.uint32

    run()


def test_lane_wire_zero_survivor_and_pad_only():
    docs = np.zeros((2, 6), np.int32)  # PAD-only batch
    lanes = [(np.zeros(1, np.int32), np.full((1, 8), -1, np.int32), None)]
    meta, docs2, lanes2 = lanes_from_wire(lanes_to_wire(docs, lanes))
    np.testing.assert_array_equal(docs2, docs)
    assert int(lanes2[0][0][0]) == 0
    assert (lanes2[0][1] == -1).all() and lanes2[0][2] is None


def test_lane_wire_corruption_never_silently_wrong():
    rng = np.random.default_rng(0)
    docs = rng.integers(0, 40, size=(2, 8)).astype(np.int32)
    lanes = _lane_geometry(rng, 2, 2, 8, [True, False])
    wire = lanes_to_wire(docs, lanes)
    for off in range(0, len(wire), max(len(wire) // 200, 1)):
        bad = bytearray(wire)
        bad[off] ^= 0xFF
        try:
            _meta, docs2, lanes2 = lanes_from_wire(bytes(bad))
        except ValueError:
            continue  # detected — the required outcome for real damage
        # decode succeeded: the flip must have been in dead container
        # space and the arrays must be bit-identical
        np.testing.assert_array_equal(docs2, docs)
        for (c1, l1, k1), (c2, l2, k2) in zip(lanes, lanes2):
            np.testing.assert_array_equal(c2, c1)
            np.testing.assert_array_equal(l2, l1)
            if k1 is not None:
                np.testing.assert_array_equal(k2, k1)


def test_pack_arrays_fingerprint_guards_truncation():
    meta, arrays = {"kind": "x"}, {"a": np.arange(7, dtype=np.int32)}
    data = pack_arrays(meta, arrays)
    m2, a2 = unpack_arrays(data)
    assert m2["kind"] == "x"
    np.testing.assert_array_equal(a2["a"], arrays["a"])
    for cut in (0, 10, len(data) // 2, len(data) - 1):
        with pytest.raises(ValueError):
            unpack_arrays(data[:cut])


# ------------------------------------------- delta/version serialization
def test_delta_roundtrip():
    corpus = _dense_corpus()
    _cache, sess = _session(corpus)
    rng = np.random.default_rng(11)
    for _ in range(4):
        d = random_delta(rng, sess.current_state.version, 48)
        d2 = DictionaryDelta.from_bytes(d.to_bytes())
        assert d2.added == d.added
        assert sorted(d2.tombstones) == sorted(d.tombstones)
        if d.added_freq is None:
            assert d2.added_freq is None
        else:
            np.testing.assert_array_equal(d2.added_freq, d.added_freq)


def test_version_roundtrip_with_segments_and_tombstones():
    corpus = _dense_corpus()
    _cache, sess = _session(corpus)
    rng = np.random.default_rng(12)
    v = sess.current_state.version
    v = v.apply(random_delta(rng, v, 48))  # open segment + tombstones
    v2 = DictionaryVersion.from_bytes(v.to_bytes())
    assert v2.epoch == v.epoch
    assert v2.num_segments == v.num_segments
    np.testing.assert_array_equal(v2.tombstones, v.tombstones)
    d1, ids1 = v.effective_dictionary()
    d2, ids2 = v2.effective_dictionary()
    np.testing.assert_array_equal(ids1, ids2)
    np.testing.assert_array_equal(d1.tokens, d2.tokens)
    np.testing.assert_array_equal(d1.lengths, d2.lengths)
    np.testing.assert_array_equal(d1.token_weight, d2.token_weight)


# -------------------------------------------------------------------- ring
def test_ring_deterministic_and_distinct():
    r1 = HashRing(["a", "b", "c"])
    r2 = HashRing(["c", "a", "b"])  # member order must not matter
    for key in ("s1", "s2", "deadbeef", ""):
        owners = r1.owners(key, n=3)
        assert owners == r2.owners(key, n=3)
        assert sorted(owners) == ["a", "b", "c"]  # distinct, all members
        assert r1.primary(key) == owners[0]


def test_ring_minimal_movement_on_membership_change():
    keys = [f"k{i}" for i in range(200)]
    r = HashRing(["a", "b", "c"])
    before = {k: r.primary(k) for k in keys}
    r.add("d")
    moved = sum(1 for k in keys if r.primary(k) != before[k])
    # consistent hashing: only ~1/4 of keys should move to the newcomer,
    # and every moved key must have moved *to* d
    assert 0 < moved < len(keys) // 2
    assert all(r.primary(k) == "d" for k in keys
               if r.primary(k) != before[k])
    r.remove("d")
    assert {k: r.primary(k) for k in keys} == before


# --------------------------------------------------------------- transport
def _echo_server(channel, fail_seqs=(), calls=None):
    def handler(frame):
        if calls is not None:
            calls.append(frame.seq)
        if frame.seq in fail_seqs:
            raise RuntimeError("handler exploded")
        if frame.ftype == FT_SHUTDOWN:
            return None
        return FT_ACK, frame.payload[::-1]

    th = threading.Thread(target=serve_frames, args=(channel, handler),
                          kwargs={"idle_timeout": 30.0}, daemon=True)
    th.start()
    return th


@pytest.mark.parametrize("make_pair", [loopback_pair, socket_pair],
                         ids=["loopback", "socket"])
def test_endpoint_roundtrip_both_channels(make_pair):
    a, b = make_pair()
    th = _echo_server(b)
    ep = Endpoint(a, timeout=10.0)
    for i in range(5):
        body = f"payload-{i}".encode()
        resp = ep.call(FT_REQUEST, body)
        assert resp.ftype == FT_ACK and resp.payload == body[::-1]
    ep.channel.send(encode_frame(FT_SHUTDOWN, ep.next_seq(), b""))
    th.join(timeout=10)
    ep.close()


def test_endpoint_surfaces_remote_errors():
    a, b = loopback_pair()
    calls = []
    th = _echo_server(b, fail_seqs={1}, calls=calls)
    ep = Endpoint(a, timeout=5.0)
    with pytest.raises(RemoteError, match="handler exploded"):
        ep.call(FT_REQUEST, b"boom")
    assert ep.call(FT_REQUEST, b"ok").payload == b"ko"
    ep.channel.send(encode_frame(FT_SHUTDOWN, ep.next_seq(), b""))
    th.join(timeout=10)


@pytest.mark.parametrize("action", ["drop", "dup", "reorder", "truncate",
                                    "corrupt"])
@pytest.mark.parametrize("ftype", [FT_REQUEST, FT_ACK],
                         ids=["request", "ack"])
def test_fault_matrix_exactly_once_and_correct(action, ftype):
    """Every fault on every frame type: the call still returns the
    right payload, and the handler ran exactly once per seq."""
    a, b = loopback_pair()
    faulty = FaultyChannel(a, [FaultPlan(action, frames=frozenset({1, 3}))])
    calls = []
    th = _echo_server(b, calls=calls)
    ep = Endpoint(faulty, timeout=2.0, retries=4, backoff=0.01)
    for i in range(5):
        body = f"m{i}".encode()
        assert ep.call(ftype, body).payload == body[::-1]
    if action in ("drop", "truncate", "corrupt"):
        assert ep.frames_retried > 0  # fault cost retries, not answers
    assert faulty.faults_injected > 0
    # dedupe cache: retried/duplicated seqs executed exactly once
    assert sorted(calls) == sorted(set(calls))
    ep.channel.send(encode_frame(FT_SHUTDOWN, ep.next_seq(), b""))
    th.join(timeout=10)


def test_endpoint_times_out_on_dead_server():
    a, _b = loopback_pair()  # nobody serving
    ep = Endpoint(a, timeout=0.05, retries=1, backoff=0.01)
    with pytest.raises(TransportTimeout):
        ep.call(FT_REQUEST, b"anyone home?")
    assert ep.frames_retried == 1


def test_socket_channel_counts_bytes():
    a, b = socket_pair()
    wire = encode_frame(FT_ACK, 1, b"x" * 100)
    a.send(wire)
    assert b.recv(timeout=5.0) == wire
    assert a.bytes_sent == len(wire) + 4  # outer length prefix
    assert b.bytes_received == len(wire) + 4
    a.close()
    b.close()
    assert isinstance(a, SocketChannel) and isinstance(b, SocketChannel)


# -------------------------------------------- replication / epoch agreement
def test_replica_rejects_request_ahead_of_ack():
    corpus = _dense_corpus()
    _cache, sess = _session(corpus)
    with _thread_cluster(n=1) as (coord, servers):
        coord.add_session(sess)
        docs = np.asarray([corpus.doc_tokens[0]])
        ep = coord.handles["t0"].endpoint
        with pytest.raises(RemoteError, match="lags"):
            ep.call(FT_REQUEST,
                    encode_request(sess.key, sess.epoch + 1, docs))
        # at the acked epoch the same request serves fine
        frame = ep.call(FT_REQUEST,
                        encode_request(sess.key, sess.epoch, docs))
        meta, _m = matches_from_wire(frame.payload)
        assert int(meta["epoch"]) == sess.epoch


def test_coordinator_never_routes_to_lagging_replica():
    corpus = _dense_corpus()
    _cache, sess = _session(corpus)
    rng = np.random.default_rng(21)
    docs = _var_docs(corpus, 22, n=4)
    with _thread_cluster(n=2, hold_epochs=True) as (coord, servers):
        coord.add_session(sess)
        # replicate a delta to t0 only: t1 is marked dead during sync,
        # then comes back — alive but lagging
        coord.handles["t1"].alive = False
        coord.apply_delta(sess.key, random_delta(rng, sess.current_state.version, 48))
        coord.handles["t1"].alive = True
        assert coord.handles["t1"].acked[sess.key] < sess.epoch
        shed_before = coord.handles["t1"].shed
        epoch, matches = coord.extract(sess.key, docs)
        assert epoch == sess.epoch
        # epoch agreement: only t0 may have served it
        assert coord.handles["t0"].routed == 1
        assert coord.handles["t1"].routed == 0
        assert matches.to_set() == one_shot_reference(sess, docs,
                                                      epoch=epoch)
        # ...and if t1 was ring-preferred it was shed, not routed
        if coord.ring.primary(sess.key) == "t1":
            assert coord.handles["t1"].shed > shed_before
        # catch-up resync makes t1 eligible again
        coord.sync_session(sess.key)
        assert coord.handles["t1"].acked[sess.key] == sess.epoch


def test_all_replicas_lagging_sheds_cleanly():
    corpus = _dense_corpus()
    _cache, sess = _session(corpus)
    rng = np.random.default_rng(31)
    with _thread_cluster(n=2, route_retries=0) as (coord, servers):
        coord.add_session(sess)
        # local-only delta (bypasses coordinator replication): every
        # replica now lags the coordinator epoch
        sess.apply_delta(random_delta(rng, sess.current_state.version, 48))
        with pytest.raises(ClusterShed):
            coord.extract(sess.key, [corpus.doc_tokens[0]])
        coord.sync_session(sess.key)
        epoch, _m = coord.extract(sess.key, [corpus.doc_tokens[0]])
        assert epoch == sess.epoch


def test_replicated_compaction_is_identical():
    """Force a compaction (id renumbering!) through replication and
    check replicas land on the same epoch + identical results."""
    corpus = _dense_corpus()
    _cache, sess = _session(corpus)
    rng = np.random.default_rng(41)
    docs = _var_docs(corpus, 42, n=4)
    with _thread_cluster(n=2, hold_epochs=True) as (coord, servers):
        coord.add_session(sess)
        coord.apply_delta(sess.key,
                          random_delta(rng, sess.current_state.version, 48),
                          force_action="compact")
        for srv in servers.values():
            assert srv.sessions[sess.key].epoch == sess.epoch
        total = 0
        for name in coord.handles:  # pin each replica's answer directly
            ep = coord.handles[name].endpoint
            frame = ep.call(FT_REQUEST, encode_request(
                sess.key, sess.epoch, np.asarray(
                    [np.pad(d, (0, max(len(x) for x in docs) - len(d)))
                     for d in docs])))
            _meta, matches = matches_from_wire(frame.payload)
            got = matches.to_set()
            assert got == one_shot_reference(sess, docs, epoch=sess.epoch)
            total += len(got)
        assert total > 0, "compaction parity check is vacuous"


def test_epoch_release_protocol():
    corpus = _dense_corpus()
    _cache, sess = _session(corpus)
    rng = np.random.default_rng(51)
    with _thread_cluster(n=2) as (coord, servers):
        coord.add_session(sess)
        e0 = sess.epoch
        coord.extract(sess.key, [corpus.doc_tokens[0]])
        coord.apply_delta(sess.key,
                          random_delta(rng, sess.current_state.version, 48))
        # e0 drained before the delta: next request admits the new
        # epoch, and the old one is released everywhere
        epoch, _m = coord.extract(sess.key, [corpus.doc_tokens[1]])
        assert epoch == sess.epoch != e0
        for srv in servers.values():
            retained = srv.stats()["retained_epochs"][sess.key]
            assert e0 not in retained, (
                f"epoch {e0} still pinned on {srv.name}: {retained}"
            )
        assert (sess.key, e0) in coord.released


# ------------------------------------------------- e2e parity (in-process)
@pytest.mark.parametrize("scheme", SCHEMES)
def test_cluster_parity_with_live_deltas(scheme):
    corpus = _dense_corpus(seed=60 + SCHEMES.index(scheme))
    _cache, sess = _session(corpus, scheme)
    rng = np.random.default_rng(61)
    with _thread_cluster(n=2, hold_epochs=True) as (coord, servers):
        coord.add_session(sess)
        total = 0
        for round_i in range(3):
            docs = _var_docs(corpus, 62 + round_i, n=4)
            epoch, matches = coord.extract(sess.key, docs)
            got = matches.to_set()
            assert got == one_shot_reference(sess, docs, epoch=epoch)
            total += len(got)
            if round_i < 2:
                coord.apply_delta(
                    sess.key,
                    random_delta(rng, sess.current_state.version, 48),
                )
        assert total > 0, f"{scheme}: cluster parity check is vacuous"
        assert sum(h.routed for h in coord.handles.values()) == 3


def test_cluster_parity_under_fault_injection():
    """Bit-flips, drops and truncations on the wire to the replica:
    responses stay bit-identical, damage shows up only as retries.

    Single replica so every request must survive its fault (no quiet
    failover hiding a broken retry path); faults are armed only after
    a warm-up request so the retry timeout never races jit compilation,
    and fault indices are spaced so each faulted send's retry is clean
    (a retry re-sends through the same faulty channel and bumps the
    send index)."""
    corpus = _dense_corpus()
    _cache, sess = _session(corpus)
    # fixed-shape docs: one compiled executable serves every request
    docs = [np.asarray(corpus.doc_tokens[i]) for i in range(3)]
    with _thread_cluster(n=1, fault_plans={0: []}, ep_timeout=20.0,
                         hold_epochs=True) as (coord, servers):
        faulty = coord.handles["t0"].endpoint.channel
        assert isinstance(faulty, FaultyChannel)
        coord.add_session(sess)                      # send 0
        epoch, matches = coord.extract(sess.key, docs)   # send 1: warm
        want = one_shot_reference(sess, docs, epoch=epoch)
        assert matches.to_set() == want
        assert len(want) > 0, "fault-injection parity check is vacuous"
        # sends 2..: one fault per request, clean retry in between
        faulty.plans.extend([
            FaultPlan("corrupt", frames=frozenset({2})),
            FaultPlan("drop", frames=frozenset({4})),
            FaultPlan("truncate", frames=frozenset({6})),
            FaultPlan("dup", frames=frozenset({8})),
            FaultPlan("reorder", frames=frozenset({9})),
        ])
        for _ in range(5):
            epoch, matches = coord.extract(sess.key, docs)
            assert matches.to_set() == want, "faults changed matches"
        assert faulty.faults_injected >= 4, "faults did not fire"
        # corrupt/drop/truncate are invisible to the server (damaged
        # inbound frames are dropped) — only the client retry recovers
        assert coord.handles["t0"].endpoint.frames_retried >= 3
        assert coord.handles["t0"].alive
        # the dedupe cache kept every retried request exactly-once
        assert servers["t0"].requests_served == 6


def test_remote_verify_through_service():
    """ExtractionService with the verify pool behind the transport."""
    from repro.serving import BatcherConfig, ExtractionService

    corpus = _dense_corpus()
    cache, sess = _session(corpus, "prefix")
    docs = _var_docs(corpus, 80, n=6)
    with _thread_cluster(n=2) as (coord, servers):
        coord.add_session(sess)
        svc = ExtractionService(
            cache,
            batcher_config=BatcherConfig(max_batch_docs=3,
                                         max_delay_s=0.0),
            overlap=False,
            remote_verify=coord,
        )
        with svc:
            for i, d in enumerate(docs):
                assert svc.submit(i, d, sess.key) is not None
            svc.drain()
        got = svc.results_set()
        assert got == one_shot_reference(sess, docs)
        assert len(got) > 0, "remote-verify parity check is vacuous"
        assert sum(s.lane_batches_served for s in servers.values()) > 0
        assert all(s.requests_served == 0 for s in servers.values())


# ------------------------------------------------- e2e parity (processes)
@pytest.mark.slow
def test_multiprocess_cluster_parity_all_schemes():
    """The acceptance gate: >= 2 replica *processes* over TCP sockets,
    one session per scheme, live replicated deltas mid-stream, every
    response bit-identical to ``one_shot_reference`` at its admitted
    epoch."""
    procs, endpoints = launch_local_cluster(
        ["p0", "p1"], endpoint_timeout=300.0
    )
    try:
        metrics = ServingMetrics()
        coord = ClusterCoordinator(endpoints, metrics=metrics,
                                   hold_epochs=True)
        total = 0
        for si, scheme in enumerate(SCHEMES):
            corpus = _dense_corpus(seed=90 + si, num_entities=16)
            _cache, sess = _session(corpus, scheme)
            rng = np.random.default_rng(91 + si)
            coord.add_session(sess)
            for round_i in range(2):
                docs = _var_docs(corpus, 92 + round_i, n=3)
                epoch, matches = coord.extract(sess.key, docs,
                                               timeout=300.0)
                got = matches.to_set()
                assert got == one_shot_reference(sess, docs, epoch=epoch), \
                    f"{scheme}: drift at epoch {epoch}"
                total += len(got)
                if round_i == 0:
                    coord.apply_delta(
                        sess.key,
                        random_delta(rng, sess.current_state.version, 48),
                    )
        assert total > 0, "multi-process parity check is vacuous"
        stats = coord.poll_stats()
        assert sum(r["remote"].get("requests_served", 0)
                   for r in stats.values() if r["remote"]) == 8
        assert "replicas" in metrics.summary()
    finally:
        coord.shutdown()
        for p in procs:
            p.join(timeout=30)
    assert all(p.exitcode == 0 for p in procs)

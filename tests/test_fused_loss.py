"""Fused chunked CE must equal the full-logits loss exactly."""
import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.registry import get_smoke_config
from repro.launch.mesh import make_cpu_mesh
from repro.models.model import build_model, fused_ce_loss, lm_loss
from repro.models.sharding import ShardingRules


def test_fused_ce_matches_full_logits():
    cfg = dataclasses.replace(get_smoke_config("olmo-1b"), dtype="float32")
    mesh = make_cpu_mesh(1, 1)
    model = build_model(cfg, ShardingRules(mesh))
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 48)), jnp.int32)
    labels = jnp.asarray(
        np.where(rng.random((2, 48)) < 0.1, -1,
                 rng.integers(0, cfg.vocab_size, (2, 48))), jnp.int32)

    x, aux = model.forward_features(params, toks)
    logits = x @ params["lm_head"]
    l_full, p_full = lm_loss(cfg, logits, labels, moe_aux=aux["moe_aux"])
    l_fused, p_fused = fused_ce_loss(cfg, x, params["lm_head"], labels,
                                     moe_aux=aux["moe_aux"], chunk=16)
    np.testing.assert_allclose(float(l_full), float(l_fused), rtol=1e-5)
    np.testing.assert_allclose(float(p_full["nll"]), float(p_fused["nll"]),
                               rtol=1e-5)

    g_full = jax.grad(lambda x: lm_loss(cfg, x @ params["lm_head"], labels)[0])(x)
    g_fused = jax.grad(lambda x: fused_ce_loss(
        cfg, x, params["lm_head"], labels, chunk=16)[0])(x)
    np.testing.assert_allclose(np.asarray(g_full), np.asarray(g_fused),
                               rtol=1e-4, atol=1e-6)


def test_fused_ce_ragged_chunk():
    """S not divisible by the chunk hint still works (divisor fit)."""
    cfg = dataclasses.replace(get_smoke_config("xlstm-125m"), dtype="float32")
    mesh = make_cpu_mesh(1, 1)
    model = build_model(cfg, ShardingRules(mesh))
    params, _ = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 50)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 50)), jnp.int32)
    x, _ = model.forward_features(params, toks)
    logits = x @ params["lm_head"]
    l_full, _ = lm_loss(cfg, logits, labels)
    l_fused, _ = fused_ce_loss(cfg, x, params["lm_head"], labels, chunk=16)
    np.testing.assert_allclose(float(l_full), float(l_fused), rtol=1e-5)

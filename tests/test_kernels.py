"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import hashing
from repro.core.signatures import LshParams, _minhash_np
from repro.kernels import ops, ref
from repro.kernels.jaccard_verify import jaccard_verify_pallas
from repro.kernels.minhash import minhash_pallas
from repro.kernels.window_filter import window_filter_pallas


def _rand_tokens(rng, shape, vocab=512, pad_frac=0.3):
    t = rng.integers(1, vocab, size=shape).astype(np.int32)
    pad = rng.random(shape) < pad_frac
    return np.where(pad, 0, t).astype(np.int32)


# ------------------------------------------------------------- jaccard
@pytest.mark.parametrize("N,K,L", [(7, 3, 4), (128, 64, 8), (200, 130, 5), (1, 1, 2), (513, 17, 16)])
@pytest.mark.parametrize("mode", ["extra", "missing"])
def test_jaccard_verify_sweep(N, K, L, mode):
    rng = np.random.default_rng(N * 1000 + K + L)
    win = _rand_tokens(rng, (N, L))
    ent = _rand_tokens(rng, (N, K, L))
    win_w = (rng.uniform(0.1, 2.0, (N, L)) * (win != 0)).astype(np.float32)
    ent_w = (rng.uniform(0.1, 2.0, (N, K, L)) * (ent != 0)).astype(np.float32)
    got = jaccard_verify_pallas(
        jnp.asarray(win), jnp.asarray(win_w), jnp.asarray(ent), jnp.asarray(ent_w),
        mode=mode, bn=64, bk=32, interpret=True,
    )
    want = ref.jaccard_verify_ref(
        jnp.asarray(win), jnp.asarray(win_w), jnp.asarray(ent), jnp.asarray(ent_w), mode
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_jaccard_verify_matches_engine_semantics():
    """Kernel path == semantics.similarity on first-occurrence windows."""
    from repro.core.semantics import similarity, first_occurrence_mask

    rng = np.random.default_rng(0)
    N, K, L, V = 64, 8, 6, 128
    win = _rand_tokens(rng, (N, L), vocab=V)
    ids = rng.integers(0, 32, size=(N, K)).astype(np.int32)
    dict_tokens = _rand_tokens(rng, (32, L), vocab=V)
    dict_tokens[:, 0] = np.maximum(dict_tokens[:, 0], 1)  # no empty entities
    # dedup entity rows (dictionary invariant)
    for i in range(32):
        row = dict_tokens[i]
        seen = set()
        for j in range(L):
            if row[j] in seen:
                row[j] = 0
            elif row[j] != 0:
                seen.add(row[j])
    tw = np.zeros((V,), np.float32)
    tw[1:] = rng.uniform(0.2, 2.0, V - 1)
    got = ops.jaccard_verify(
        jnp.asarray(win), jnp.asarray(ids), jnp.asarray(dict_tokens),
        jnp.asarray(tw), "extra",
    )
    want = similarity(
        "extra", jnp.asarray(dict_tokens)[jnp.asarray(ids)],
        jnp.asarray(win)[:, None, :], jnp.asarray(tw), xp=jnp,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------- minhash
@pytest.mark.parametrize("N,L", [(5, 3), (256, 8), (300, 5), (1, 1)])
@pytest.mark.parametrize("bands,rows", [(4, 2), (8, 1), (2, 4)])
def test_minhash_sweep(N, L, bands, rows):
    rng = np.random.default_rng(N + bands * 10 + rows)
    toks = _rand_tokens(rng, (N, L))
    valid = toks != 0
    got = minhash_pallas(
        jnp.asarray(toks), jnp.asarray(valid), bands=bands, rows=rows,
        bn=64, interpret=True,
    )
    want = ref.minhash_ref(jnp.asarray(toks), jnp.asarray(valid), bands, rows)
    assert (np.asarray(got) == np.asarray(want)).all()
    # and bit-identical to the host-side dictionary path
    host = _minhash_np(toks, valid, LshParams(bands=bands, rows=rows))
    assert (np.asarray(got) == host).all()


# ------------------------------------------------------- window filter
@pytest.mark.parametrize("D,T,L", [(3, 32, 4), (16, 128, 8), (9, 64, 5)])
@pytest.mark.parametrize("num_bits", [1 << 12, 1 << 15])
def test_window_filter_sweep(D, T, L, num_bits):
    rng = np.random.default_rng(D * T)
    docs = _rand_tokens(rng, (D, T), vocab=2048, pad_frac=0.05)
    words = rng.integers(0, 2**32, size=(num_bits // 32,), dtype=np.uint32)
    got = window_filter_pallas(
        jnp.asarray(docs), jnp.asarray(words), num_bits=num_bits,
        num_hashes=3, max_len=L, bd=4, interpret=True,
    )
    want = ref.window_filter_ref(
        jnp.asarray(docs), jnp.asarray(words), num_bits, 3, L
    )
    assert (np.asarray(got) == np.asarray(want)).all()


def test_kernels_equal_engine_extraction(small_corpus):
    """End-to-end: extraction with use_kernel=True == use_kernel=False."""
    from repro.core.filter import build_ish_filter
    from repro.core.signatures import entity_signatures
    from repro.extraction import engine as E

    c = small_corpus
    d = c.dictionary
    flt = build_ish_filter(d, 0.8)
    fltt = (jnp.asarray(flt.bits), flt.num_bits, flt.num_hashes)
    docs = jnp.asarray(c.doc_tokens)
    ddict = E.DeviceDictionary.from_host(d)
    for use_kernel in (False, True):
        params = E.ExtractParams(
            gamma=0.8, scheme="prefix", max_candidates=4096,
            result_capacity=8192, use_kernel=use_kernel,
        )
        base, surv = E.survival_mask(docs, d.max_len, fltt, use_kernel)
        cands = E.compact_candidates(base, surv, params.max_candidates)
        table = E.build_sig_table(entity_signatures("prefix", d, 0.8))
        m = E.extract_ssjoin_local(cands, table, ddict, params)
        if use_kernel:
            got_k = m.to_set()
        else:
            got_j = m.to_set()
    assert got_k == got_j

"""Cost model: Lemma 1 monotonicity + §5.2 search optimality (property)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.cost_model import (
    ALL_OPTIONS,
    OBJ_JOB,
    OBJ_WORK,
    CostParams,
    cost_side,
    objective_value,
)
from repro.core.plan import PlanSide
from repro.core.search import exhaustive_plan, search_plan
from repro.core.stats import EEStats, gather_stats
from repro.data.synth import make_corpus


def _random_stats(rng: np.random.Generator, E: int = 64) -> EEStats:
    """Synthetic-but-valid EEStats: monotone curves, nonneg prefix sums."""
    def curve_up():
        return np.concatenate([[0.0], np.cumsum(rng.uniform(0, 100, E))])

    surv_head = curve_up()
    surv_tail = (surv_head[-1] - surv_head)  # complementary, non-increasing
    cum = {
        name: curve_up()
        for name in (
            "verify_word", "verify_prefix", "verify_lsh", "verify_variant",
            "postings_word", "postings_prefix", "variants",
        )
    }
    grid = np.linspace(0, E, 9)
    index_bytes = {}
    for kind in ("word", "prefix", "variant"):
        h = np.sort(rng.uniform(0, 1e6, len(grid)))
        t = np.sort(rng.uniform(0, 1e6, len(grid)))[::-1].copy()
        h[0] = 0.0
        t[-1] = 0.0
        index_bytes[kind] = (grid, h, t)
    return EEStats(
        num_entities=E,
        max_len=5,
        scale=10.0,
        num_windows=float(rng.uniform(1e4, 1e6)),
        avg_sigs_per_window=float(rng.uniform(1.5, 4.0)),
        survivors_head=surv_head,
        survivors_tail=surv_tail,
        cum=cum,
        index_bytes=index_bytes,
        sig_skew={k: float(rng.uniform(1, 30)) for k in ("word", "prefix", "lsh", "variant")},
        table_bytes_per_entity={k: 24.0 for k in ("word", "prefix", "lsh", "variant")},
    )


@given(st.integers(0, 10_000), st.sampled_from(ALL_OPTIONS), st.sampled_from([OBJ_JOB, OBJ_WORK]))
@settings(max_examples=60, deadline=None)
def test_lemma1_monotonicity(seed, option, objective):
    """Head cost non-decreasing, tail cost non-increasing in the split."""
    rng = np.random.default_rng(seed)
    stats = _random_stats(rng)
    params = CostParams(num_devices=8, hbm_budget_bytes=float(rng.uniform(1e4, 1e6)))
    algo, scheme = option
    E = stats.num_entities
    prev_h, prev_t = -1.0, float("inf")
    for p in range(0, E + 1, 4):
        h = objective_value(cost_side(stats, params, 0, p, algo, scheme, head=True), objective)
        t = objective_value(cost_side(stats, params, p, E, algo, scheme, head=False), objective)
        assert h >= prev_h - 1e-9, f"head cost decreased at p={p}"
        assert t <= prev_t + 1e-9, f"tail cost increased at p={p}"
        prev_h, prev_t = h, t


@given(st.integers(0, 10_000), st.sampled_from([OBJ_JOB, OBJ_WORK]))
@settings(max_examples=25, deadline=None)
def test_search_near_optimal(seed, objective):
    """Bracketed search within 10% of exhaustive even on adversarial
    step-shaped random stats (real curves are much smoother; see the
    real-stats test below for the tight bound)."""
    rng = np.random.default_rng(seed)
    stats = _random_stats(rng)
    params = CostParams(num_devices=8, hbm_budget_bytes=float(rng.uniform(1e4, 1e6)))
    opts = [("index", "prefix"), ("ssjoin", "variant"), ("ssjoin", "prefix")]
    got = search_plan(stats, params, objective, options=opts)
    want = exhaustive_plan(stats, params, objective, options=opts)
    assert got.predicted_cost <= want.predicted_cost * 1.10
    assert got.evaluations < want.evaluations / 2


def test_search_on_real_stats_matches_exhaustive():
    c = make_corpus(num_docs=24, doc_len=96, vocab_size=1024, num_entities=48, seed=3)
    stats = gather_stats(c.dictionary, c.doc_tokens[:8], 24, gamma=0.8)
    params = CostParams(num_devices=4)
    for objective in (OBJ_JOB, OBJ_WORK):
        got = search_plan(stats, params, objective)
        want = exhaustive_plan(stats, params, objective)
        assert got.predicted_cost <= want.predicted_cost * 1.02


def test_objectives_can_disagree():
    """Work-done ignores skew; job-completion pays it — plans may differ."""
    rng = np.random.default_rng(12)
    found = False
    for seed in range(40):
        stats = _random_stats(np.random.default_rng(seed))
        stats.sig_skew = {k: 200.0 for k in stats.sig_skew}  # brutal skew
        params = CostParams(num_devices=64)
        a = search_plan(stats, params, OBJ_WORK)
        b = search_plan(stats, params, OBJ_JOB)
        if (a.head, a.tail, a.split) != (b.head, b.tail, b.split):
            found = True
            break
    assert found, "objectives never disagreed across 40 random stats"


def test_memory_budget_forces_passes():
    c = make_corpus(num_docs=16, doc_len=64, vocab_size=512, num_entities=48, seed=5)
    stats = gather_stats(c.dictionary, c.doc_tokens[:8], 16, gamma=0.8)
    tight = CostParams(num_devices=4, hbm_budget_bytes=2e4)
    loose = CostParams(num_devices=4, hbm_budget_bytes=1e12)
    ct = cost_side(stats, tight, 0, 48, "index", "word", head=True)
    cl = cost_side(stats, loose, 0, 48, "index", "word", head=True)
    assert ct.passes > cl.passes == 1
    assert ct.job_completion > cl.job_completion

"""Training substrate: convergence, checkpoint/restart, fault tolerance,
elastic re-mesh, gradient compression, pipeline, serving."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import get_smoke_config
from repro.core.cost_model import CostParams
from repro.core.eejoin import EEJoinConfig, EEJoinOperator
from repro.data.pipeline import PipelineConfig, annotate_docs, batches
from repro.data.synth import make_corpus
from repro.models.model import build_model
from repro.models.sharding import ShardingRules
from repro.train.fault_tolerance import (
    RestartPolicy,
    StepBarrierMonitor,
    run_with_restarts,
)
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state, lr_schedule
from repro.train.trainer import TrainerConfig, make_train_step, train
from repro.train import checkpoint as ckpt_lib
from repro.compat import set_mesh


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("olmo-1b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    model = build_model(cfg, ShardingRules(mesh))
    corpus = make_corpus(
        num_docs=16, doc_len=256, vocab_size=cfg.vocab_size, num_entities=16, seed=0
    )
    return dict(cfg=cfg, mesh=mesh, model=model, corpus=corpus)


def _data(setup, batch=4, seq=32):
    return batches(
        setup["corpus"], PipelineConfig(seq_len=seq, global_batch=batch, annotate=False)
    )


def test_lr_schedule_shapes():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] < lrs[1] < lrs[2]  # warmup
    assert lrs[2] >= lrs[3] >= lrs[4]  # decay
    assert lrs[4] >= cfg.lr * cfg.min_lr_frac * 0.99


def test_training_reduces_loss(setup):
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60, grad_clip=1.0)
    tcfg = TrainerConfig(
        total_steps=60, log_every=10, checkpoint_every=1000,
        checkpoint_dir="/tmp/repro_test_nockpt",
    )
    out = train(setup["model"], _data(setup), opt, tcfg, setup["mesh"])
    hist = out["history"]
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.8, hist
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_microbatching_matches_full_batch(setup):
    """Gradient accumulation must not change the update (up to fp32 sum order)."""
    model = setup["model"]
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = AdamWConfig(lr=1e-3)
    batch = next(_data(setup, batch=4))
    s1 = jax.jit(make_train_step(model, opt, microbatches=1))
    s2 = jax.jit(make_train_step(model, opt, microbatches=2))
    with set_mesh(setup["mesh"]):
        p1, _, m1 = s1(params, init_opt_state(params), batch)
        p2, _, m2 = s2(params, init_opt_state(params), batch)
    d = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        p1, p2,
    )
    assert max(jax.tree.leaves(d)) < 2e-2  # bf16 params: one ulp-ish


def test_checkpoint_roundtrip_and_resume(setup, tmp_path):
    ckpt_dir = str(tmp_path / "ck")
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    tcfg = TrainerConfig(
        total_steps=10, log_every=5, checkpoint_every=5, checkpoint_dir=ckpt_dir,
    )
    # run 10 steps straight
    out_full = train(setup["model"], _data(setup), opt, tcfg, setup["mesh"])
    # run 5, then resume to 10 on a fresh data iterator (determinism)
    tcfg5 = TrainerConfig(
        total_steps=5, log_every=5, checkpoint_every=5,
        checkpoint_dir=ckpt_dir + "_b",
    )
    train(setup["model"], _data(setup), opt, tcfg5, setup["mesh"])
    tcfg10 = TrainerConfig(
        total_steps=10, log_every=5, checkpoint_every=5,
        checkpoint_dir=ckpt_dir + "_b",
    )
    # NOTE: the resumed run must skip consumed batches deterministically;
    # pipeline batches are a pure function of step, but the iterator
    # restarts at step 0 here — emulate by dropping the first 5 batches.
    it = _data(setup)
    for _ in range(5):
        next(it)
    out_res = train(
        setup["model"], it, opt, tcfg10, setup["mesh"], resume=True
    )
    pa = out_full["params"]
    pb = out_res["params"]
    diff = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        pa, pb,
    )
    assert max(jax.tree.leaves(diff)) < 1e-6, "resume must be bit-stable"


def test_checkpoint_gc_and_latest(setup, tmp_path):
    model = setup["model"]
    params, _ = model.init(jax.random.PRNGKey(0))
    opt_state = init_opt_state(params)
    d = str(tmp_path / "gc")
    for s in [5, 10, 15, 20]:
        ckpt_lib.save(d, s, params, opt_state, keep=2)
    assert ckpt_lib.latest_step(d) == 20
    kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(kept) == 2


def test_fault_injection_restart(setup, tmp_path):
    """Crash at step 7 -> supervisor restores from step-5 checkpoint."""
    ckpt_dir = str(tmp_path / "ft")
    opt = AdamWConfig(lr=1e-3)
    crashes = {"n": 0}
    restarts = []

    def train_fn(resume: bool) -> dict:
        tcfg = TrainerConfig(
            total_steps=12, log_every=4, checkpoint_every=5, checkpoint_dir=ckpt_dir,
        )
        it = _data(setup)
        if resume:
            start = ckpt_lib.latest_step(ckpt_dir) or 0
            for _ in range(start):
                next(it)
            return train(setup["model"], it, opt, tcfg, setup["mesh"], resume=True)
        # first attempt: wrap the iterator to crash mid-run
        def crashing():
            for i, b in enumerate(it):
                if i == 7 and crashes["n"] == 0:
                    crashes["n"] += 1
                    raise RuntimeError("injected node failure")
                yield b

        return train(setup["model"], crashing(), opt, tcfg, setup["mesh"])

    out = run_with_restarts(
        train_fn,
        RestartPolicy(max_restarts=2, backoff_s=0.01),
        on_restart=lambda a, e: restarts.append(str(e)),
    )
    assert crashes["n"] == 1 and len(restarts) == 1
    assert out["history"][-1]["step"] == 12


def test_elastic_remesh_restore(setup, tmp_path):
    """Checkpoint saved under one mesh restores onto another factorisation."""
    from repro.train.fault_tolerance import elastic_remesh

    model = setup["model"]
    params, specs = model.init(jax.random.PRNGKey(0))
    opt_state = init_opt_state(params)
    d = str(tmp_path / "re")
    ckpt_lib.save(d, 3, params, opt_state, keep=1)
    new_mesh = jax.make_mesh((1, 1), ("data", "model"))
    p2, o2, step = elastic_remesh(d, params, opt_state, new_mesh, specs)
    assert step == 3
    same = jax.tree.map(
        lambda a, b: bool(jnp.all(a == b)), params, p2
    )
    assert all(jax.tree.leaves(same))


def test_compression_error_feedback_unbiased():
    """EF residual makes repeated compression average to the truth."""
    from repro.train.compression import ef_compress_tree, dequantize, init_residual

    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))}
    res = init_residual(g)
    acc = jnp.zeros((64, 64), jnp.float32)
    n = 50
    for _ in range(n):
        q, res = ef_compress_tree(g, res)
        acc = acc + dequantize(*q["w"])
    err_ef = float(jnp.abs(acc / n - g["w"]).mean())
    # without EF the bias stays at the quantisation error level
    q1, _ = ef_compress_tree(g, init_residual(g))
    err_plain = float(jnp.abs(dequantize(*q1["w"]) - g["w"]).mean())
    assert err_ef < err_plain * 0.2, (err_ef, err_plain)


def test_straggler_monitor_flags_outliers():
    import time

    mon = StepBarrierMonitor(threshold=3.0)
    for i in range(8):
        mon.start()
        time.sleep(0.03 if i == 6 else 0.002)
        mon.stop(i)
    assert any(s == 6 for s, _, _ in mon.flagged)


def test_pipeline_annotation_marks_entities(zipf_corpus):
    c = zipf_corpus
    op = EEJoinOperator(c.dictionary, EEJoinConfig(gamma=0.8))
    stats = op.gather_statistics(c.doc_tokens[:8], total_docs=c.doc_tokens.shape[0])
    plan = op.choose_plan(stats, CostParams(num_devices=1))
    prepared = op.prepare(plan, CostParams(num_devices=1))
    mask = annotate_docs(op, prepared, c.doc_tokens)
    assert mask.shape == c.doc_tokens.shape
    assert mask.sum() > 0
    # every planted (unnoised) mention should be covered for the
    # variant-exact side at minimum; check coverage is plausible
    frac = mask.mean()
    assert 0.0 < frac < 0.5


def test_serve_engine_generates(setup):
    from repro.serve.engine import Request, ServeEngine

    model = setup["model"]
    params, _ = model.init(jax.random.PRNGKey(0))
    with set_mesh(setup["mesh"]):
        eng = ServeEngine(model, params, batch_slots=4, max_len=64)
        reqs = [Request(prompt=[5, 9, 12], max_new_tokens=4) for _ in range(6)]
        for r in reqs:
            eng.submit(r)
        eng.run()
    done = [r for r in reqs if r.done]
    assert len(done) >= 4  # 64-token window fits at least the first wave
    for r in done:
        assert len(r.out) == 4
        assert all(0 <= t < setup["cfg"].padded_vocab for t in r.out)

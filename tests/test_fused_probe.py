"""Fused filter→signature megakernel vs the unfused jnp pipeline.

Bit-parity contracts (interpret mode, CPU): the packed survival bitmap
must unpack to exactly ``survival_mask(..., use_kernel=False)``, the
compacted candidate buffers must equal ``compact_candidates`` field for
field, and in-kernel LSH band signatures must be bit-identical to
``core.signatures.window_signatures`` — across PAD-heavy, zero-survivor
and overflow regimes.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.dictionary import PAD
from repro.core.signatures import LshParams, window_signatures
from repro.extraction import engine as E
from repro.extraction.results import select_nonzero
from repro.kernels import ops as kops

GAMMA = 0.8


def _docs(rng, D, T, vocab=2048, pad_frac=0.1):
    d = rng.integers(1, vocab, size=(D, T)).astype(np.int32)
    d[rng.random((D, T)) < pad_frac] = PAD
    return jnp.asarray(d)


def _filter(rng, num_bits=1 << 14, density=0.05):
    w = (rng.random((num_bits // 32, 32)) < density).astype(np.uint32)
    bits = (w << np.arange(32, dtype=np.uint32)).sum(axis=1).astype(np.uint32)
    return (jnp.asarray(bits), num_bits, 3)


def _unfused(docs, L, flt, max_candidates):
    base, surv = E.survival_mask(docs, L, flt, use_kernel=False)
    return surv, E.compact_candidates(base, surv, max_candidates)


def _assert_cands_equal(got, want):
    for k in ("win_tokens", "win_valid", "doc", "pos", "length",
              "n_survive", "overflow"):
        np.testing.assert_array_equal(
            np.asarray(got[k]), np.asarray(want[k]), err_msg=k
        )


# ---------------------------------------------------------- survival
@pytest.mark.parametrize("D,T,L", [(3, 32, 4), (16, 128, 8), (9, 64, 5)])
@pytest.mark.parametrize("pad_frac", [0.0, 0.5])  # incl. PAD-heavy
def test_packed_survival_matches_unfused(D, T, L, pad_frac):
    rng = np.random.default_rng(D * T + int(pad_frac * 10))
    docs = _docs(rng, D, T, pad_frac=pad_frac)
    flt = _filter(rng)
    want, _ = _unfused(docs, L, flt, 256)
    packed, _ = kops.fused_probe(docs, flt, L)
    got = ((packed[..., None] >> jnp.arange(L, dtype=jnp.uint32)) & 1).astype(bool)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_packed_survival_no_filter_is_validity():
    rng = np.random.default_rng(0)
    docs = _docs(rng, 6, 48, pad_frac=0.3)
    _, want = E.survival_mask(docs, 5, None)
    packed, _ = kops.fused_probe(docs, None, 5)
    got = ((packed[..., None] >> jnp.arange(5, dtype=jnp.uint32)) & 1).astype(bool)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------- compaction
@pytest.mark.parametrize("pad_frac", [0.05, 0.6])
def test_fused_compact_matches_unfused(pad_frac):
    rng = np.random.default_rng(int(pad_frac * 100))
    docs = _docs(rng, 12, 96, pad_frac=pad_frac)
    flt = _filter(rng)
    params = E.ExtractParams(gamma=GAMMA, scheme="prefix", max_candidates=1024,
                             use_kernel=True)
    _, want = _unfused(docs, 7, flt, 1024)
    got = E.fused_filter_compact(docs, 7, flt, params)
    _assert_cands_equal(got, want)


def test_fused_compact_zero_survivors():
    rng = np.random.default_rng(1)
    docs = _docs(rng, 4, 64, pad_frac=0.0)
    # empty Bloom filter: nothing probes in, nothing survives
    flt = (jnp.zeros(((1 << 12) // 32,), jnp.uint32), 1 << 12, 3)
    params = E.ExtractParams(gamma=GAMMA, scheme="prefix", max_candidates=128,
                             use_kernel=True)
    _, want = _unfused(docs, 6, flt, 128)
    got = E.fused_filter_compact(docs, 6, flt, params)
    _assert_cands_equal(got, want)
    assert int(got["n_survive"]) == 0
    assert not bool(np.asarray(got["win_valid"]).any())


def test_fused_compact_overflow_surfaced():
    rng = np.random.default_rng(2)
    docs = _docs(rng, 8, 64, pad_frac=0.0)
    # saturated filter: every window survives -> tiny capacity overflows
    flt = (jnp.full(((1 << 12) // 32,), 0xFFFFFFFF, jnp.uint32), 1 << 12, 3)
    params = E.ExtractParams(gamma=GAMMA, scheme="prefix", max_candidates=64,
                             use_kernel=True)
    _, want = _unfused(docs, 6, flt, 64)
    got = E.fused_filter_compact(docs, 6, flt, params)
    _assert_cands_equal(got, want)
    assert int(got["overflow"]) > 0
    assert int(got["n_survive"]) > 64


# ---------------------------------------------------------- signatures
@pytest.mark.parametrize("bands,rows", [(4, 2), (8, 1), (2, 4)])
@pytest.mark.parametrize("pad_frac", [0.0, 0.5])
def test_fused_lsh_sigs_bit_identical(bands, rows, pad_frac):
    rng = np.random.default_rng(bands * 10 + rows)
    docs = _docs(rng, 10, 80, pad_frac=pad_frac)
    flt = _filter(rng)
    lsh = LshParams(bands=bands, rows=rows)
    params = E.ExtractParams(gamma=GAMMA, scheme="lsh", max_candidates=512,
                             lsh=lsh, use_kernel=True)
    got = E.fused_filter_compact(docs, 6, flt, params, sig_mode="lsh")
    _, ref_c = _unfused(docs, 6, flt, 512)
    want_sig, want_mask = window_signatures(
        "lsh", ref_c["win_tokens"], ref_c["win_tokens"] != PAD, GAMMA, lsh
    )
    np.testing.assert_array_equal(np.asarray(got["sigs"]), np.asarray(want_sig))
    np.testing.assert_array_equal(np.asarray(got["sig_mask"]), np.asarray(want_mask))


def test_fused_sig_mode_density_heuristic():
    rng = np.random.default_rng(3)
    docs = _docs(rng, 4, 32)
    flt = _filter(rng)
    sparse = E.ExtractParams(gamma=GAMMA, scheme="lsh", max_candidates=16,
                             use_kernel=True)
    dense = E.ExtractParams(gamma=GAMMA, scheme="lsh", max_candidates=4096,
                            use_kernel=True)
    assert "sigs" not in E.fused_filter_compact(docs, 4, flt, sparse)
    assert "sigs" in E.fused_filter_compact(docs, 4, flt, dense)


# ---------------------------------------------------------- end-to-end
@pytest.mark.parametrize("scheme", ["word", "prefix", "lsh", "variant"])
def test_fused_extraction_equals_unfused(small_corpus, scheme):
    from repro.core.filter import build_ish_filter
    from repro.core.signatures import entity_signatures

    c = small_corpus
    d = c.dictionary
    flt = build_ish_filter(d, GAMMA)
    fltt = (jnp.asarray(flt.bits), flt.num_bits, flt.num_hashes)
    docs = jnp.asarray(c.doc_tokens)
    ddict = E.DeviceDictionary.from_host(d)
    table = E.build_sig_table(entity_signatures(scheme, d, GAMMA))
    outs = {}
    for use_kernel in (False, True):
        params = E.ExtractParams(
            gamma=GAMMA, scheme=scheme, max_candidates=4096,
            result_capacity=8192, use_kernel=use_kernel,
        )
        if use_kernel:
            cands = E.fused_filter_compact(docs, d.max_len, fltt, params)
        else:
            _, cands = _unfused(docs, d.max_len, fltt, 4096)
        outs[use_kernel] = E.extract_ssjoin_local(cands, table, ddict, params).to_set()
    assert outs[True] == outs[False]


# ---------------------------------------------------------- selection
@pytest.mark.parametrize("n,density", [(100, 0.0), (1000, 0.01), (5000, 0.5), (333, 1.0)])
@pytest.mark.parametrize("capacity", [1, 64, 4096])
def test_select_nonzero_matches_jnp_nonzero(n, density, capacity):
    rng = np.random.default_rng(n + capacity)
    mask = jnp.asarray(rng.random(n) < density)
    got, ok = select_nonzero(mask, capacity)
    (want,) = jnp.nonzero(mask, size=capacity, fill_value=-1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(want) >= 0)


def test_build_sig_table_vectorised_fill_matches_loop():
    """The argsort-over-buckets scatter must place rows exactly where the
    original insertion-order Python loop did."""
    from repro.core import hashing
    from repro.core.signatures import EntitySignatures

    rng = np.random.default_rng(4)
    n = 500
    esigs = EntitySignatures(
        sig=rng.integers(0, 2**32, size=n, dtype=np.uint32),
        entity_id=rng.integers(0, 100, size=n).astype(np.int32),
    )
    t = E.build_sig_table(esigs)
    # reference loop fill over the same geometry
    sig = esigs.sig.astype(np.uint32)
    k2 = hashing.hash_u32(sig, seed=E._SIGKEY_SEED, xp=np)
    bucket = np.asarray(E._bucket_of(sig, t.n_buckets, xp=np)).astype(np.int64)
    keys1 = np.zeros((t.n_buckets, t.bucket_cap), dtype=np.uint32)
    keys2 = np.zeros((t.n_buckets, t.bucket_cap), dtype=np.uint32)
    ents = np.full((t.n_buckets, t.bucket_cap), -1, dtype=np.int32)
    fill = np.zeros((t.n_buckets,), dtype=np.int64)
    for i in range(n):
        b = bucket[i]
        keys1[b, fill[b]] = sig[i]
        keys2[b, fill[b]] = k2[i]
        ents[b, fill[b]] = esigs.entity_id[i]
        fill[b] += 1
    np.testing.assert_array_equal(np.asarray(t.keys1), keys1)
    np.testing.assert_array_equal(np.asarray(t.keys2), keys2)
    np.testing.assert_array_equal(np.asarray(t.ents), ents)

"""Fused filter→signature megakernel vs the unfused jnp pipeline.

Bit-parity contracts (interpret mode, CPU): the packed survival bitmap
must unpack to exactly ``survival_mask(..., use_kernel=False)``, the
compacted candidate buffers must equal ``compact_candidates`` field for
field, in-kernel LSH band signatures must be bit-identical to
``core.signatures.window_signatures``, and in-kernel variant keys (the
streaming set-hash fold + duplicate mask) must be bit-identical to
``core.variants.window_variant_key`` — across PAD-heavy, duplicate-heavy,
zero-survivor and overflow regimes. The adaptive two-pass lane
compaction must match the worst-case one-pass lanes bit for bit.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.dictionary import PAD
from repro.core.signatures import LshParams, window_signatures
from repro.core.variants import window_variant_key
from repro.extraction import engine as E
from repro.extraction.results import select_nonzero
from repro.kernels import ops as kops

GAMMA = 0.8


def _docs(rng, D, T, vocab=2048, pad_frac=0.1):
    d = rng.integers(1, vocab, size=(D, T)).astype(np.int32)
    d[rng.random((D, T)) < pad_frac] = PAD
    return jnp.asarray(d)


def _filter(rng, num_bits=1 << 14, density=0.05):
    w = (rng.random((num_bits // 32, 32)) < density).astype(np.uint32)
    bits = (w << np.arange(32, dtype=np.uint32)).sum(axis=1).astype(np.uint32)
    return (jnp.asarray(bits), num_bits, 3)


def _unfused(docs, L, flt, max_candidates):
    base, surv = E.survival_mask(docs, L, flt, use_kernel=False)
    return surv, E.compact_candidates(base, surv, max_candidates)


def _assert_cands_equal(got, want):
    for k in ("win_tokens", "win_valid", "doc", "pos", "length",
              "n_survive", "overflow"):
        np.testing.assert_array_equal(
            np.asarray(got[k]), np.asarray(want[k]), err_msg=k
        )


# ---------------------------------------------------------- survival
@pytest.mark.parametrize("D,T,L", [(3, 32, 4), (16, 128, 8), (9, 64, 5)])
@pytest.mark.parametrize("pad_frac", [0.0, 0.5])  # incl. PAD-heavy
def test_packed_survival_matches_unfused(D, T, L, pad_frac):
    rng = np.random.default_rng(D * T + int(pad_frac * 10))
    docs = _docs(rng, D, T, pad_frac=pad_frac)
    flt = _filter(rng)
    want, _ = _unfused(docs, L, flt, 256)
    packed, _ = kops.fused_probe(docs, flt, L)
    got = ((packed[..., None] >> jnp.arange(L, dtype=jnp.uint32)) & 1).astype(bool)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_packed_survival_no_filter_is_validity():
    rng = np.random.default_rng(0)
    docs = _docs(rng, 6, 48, pad_frac=0.3)
    _, want = E.survival_mask(docs, 5, None)
    packed, _ = kops.fused_probe(docs, None, 5)
    got = ((packed[..., None] >> jnp.arange(5, dtype=jnp.uint32)) & 1).astype(bool)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------- compaction
@pytest.mark.parametrize("pad_frac", [0.05, 0.6])
def test_fused_compact_matches_unfused(pad_frac):
    rng = np.random.default_rng(int(pad_frac * 100))
    docs = _docs(rng, 12, 96, pad_frac=pad_frac)
    flt = _filter(rng)
    params = E.ExtractParams(gamma=GAMMA, scheme="prefix", max_candidates=1024,
                             use_kernel=True)
    _, want = _unfused(docs, 7, flt, 1024)
    got = E.fused_filter_compact(docs, 7, flt, params)
    _assert_cands_equal(got, want)


def test_fused_compact_zero_survivors():
    rng = np.random.default_rng(1)
    docs = _docs(rng, 4, 64, pad_frac=0.0)
    # empty Bloom filter: nothing probes in, nothing survives
    flt = (jnp.zeros(((1 << 12) // 32,), jnp.uint32), 1 << 12, 3)
    params = E.ExtractParams(gamma=GAMMA, scheme="prefix", max_candidates=128,
                             use_kernel=True)
    _, want = _unfused(docs, 6, flt, 128)
    got = E.fused_filter_compact(docs, 6, flt, params)
    _assert_cands_equal(got, want)
    assert int(got["n_survive"]) == 0
    assert not bool(np.asarray(got["win_valid"]).any())


def test_fused_compact_overflow_surfaced():
    rng = np.random.default_rng(2)
    docs = _docs(rng, 8, 64, pad_frac=0.0)
    # saturated filter: every window survives -> tiny capacity overflows
    flt = (jnp.full(((1 << 12) // 32,), 0xFFFFFFFF, jnp.uint32), 1 << 12, 3)
    params = E.ExtractParams(gamma=GAMMA, scheme="prefix", max_candidates=64,
                             use_kernel=True)
    _, want = _unfused(docs, 6, flt, 64)
    got = E.fused_filter_compact(docs, 6, flt, params)
    _assert_cands_equal(got, want)
    assert int(got["overflow"]) > 0
    assert int(got["n_survive"]) > 64


# ---------------------------------------------------------- signatures
@pytest.mark.parametrize("bands,rows", [(4, 2), (8, 1), (2, 4)])
@pytest.mark.parametrize("pad_frac", [0.0, 0.5])
def test_fused_lsh_sigs_bit_identical(bands, rows, pad_frac):
    rng = np.random.default_rng(bands * 10 + rows)
    docs = _docs(rng, 10, 80, pad_frac=pad_frac)
    flt = _filter(rng)
    lsh = LshParams(bands=bands, rows=rows)
    params = E.ExtractParams(gamma=GAMMA, scheme="lsh", max_candidates=512,
                             lsh=lsh, use_kernel=True)
    got = E.fused_filter_compact(docs, 6, flt, params, sig_mode="lsh")
    _, ref_c = _unfused(docs, 6, flt, 512)
    want_sig, want_mask = window_signatures(
        "lsh", ref_c["win_tokens"], ref_c["win_tokens"] != PAD, GAMMA, lsh
    )
    np.testing.assert_array_equal(np.asarray(got["sigs"]), np.asarray(want_sig))
    np.testing.assert_array_equal(np.asarray(got["sig_mask"]), np.asarray(want_mask))


def test_fused_sig_mode_density_heuristic():
    rng = np.random.default_rng(3)
    docs = _docs(rng, 4, 32)
    flt = _filter(rng)
    sparse = E.ExtractParams(gamma=GAMMA, scheme="lsh", max_candidates=16,
                             use_kernel=True)
    dense = E.ExtractParams(gamma=GAMMA, scheme="lsh", max_candidates=4096,
                            use_kernel=True)
    assert "sigs" not in E.fused_filter_compact(docs, 4, flt, sparse)
    assert "sigs" in E.fused_filter_compact(docs, 4, flt, dense)


# ---------------------------------------------------------- variant scheme
def _variant_refs(docs, L, flt, NC):
    """Unfused reference: compacted candidates + oracle variant sigs/keys."""
    _, ref_c = _unfused(docs, L, flt, NC)
    toks = ref_c["win_tokens"]
    sig, mask = window_signatures("variant", toks, toks != PAD, GAMMA)
    k1, k2 = window_variant_key(toks, toks != PAD, xp=jnp)
    return ref_c, sig, mask, k1, k2


@pytest.mark.parametrize(
    "pad_frac,vocab",
    [(0.0, 2048), (0.5, 2048), (0.3, 8)],  # incl. PAD- and duplicate-heavy
)
def test_fused_variant_keys_bit_identical(pad_frac, vocab):
    rng = np.random.default_rng(int(pad_frac * 10) + vocab)
    docs = _docs(rng, 10, 80, vocab=vocab, pad_frac=pad_frac)
    flt = _filter(rng)
    params = E.ExtractParams(gamma=GAMMA, scheme="variant",
                             max_candidates=512, use_kernel=True)
    got = E.fused_filter_compact(docs, 6, flt, params)
    _, sig, mask, k1, k2 = _variant_refs(docs, 6, flt, 512)
    np.testing.assert_array_equal(np.asarray(got["sigs"]), np.asarray(sig))
    np.testing.assert_array_equal(np.asarray(got["sig_mask"]), np.asarray(mask))
    np.testing.assert_array_equal(np.asarray(got["variant_keys"][0]), np.asarray(k1))
    np.testing.assert_array_equal(np.asarray(got["variant_keys"][1]), np.asarray(k2))


def test_fused_variant_zero_survivors():
    rng = np.random.default_rng(21)
    docs = _docs(rng, 4, 64, pad_frac=0.0)
    flt = (jnp.zeros(((1 << 12) // 32,), jnp.uint32), 1 << 12, 3)  # empty
    params = E.ExtractParams(gamma=GAMMA, scheme="variant",
                             max_candidates=128, use_kernel=True)
    got = E.fused_filter_compact(docs, 6, flt, params)
    _, sig, mask, k1, k2 = _variant_refs(docs, 6, flt, 128)
    assert int(got["n_survive"]) == 0
    np.testing.assert_array_equal(np.asarray(got["sigs"]), np.asarray(sig))
    np.testing.assert_array_equal(np.asarray(got["variant_keys"][0]), np.asarray(k1))
    # empty-window set hash is 0 under either seed: padded slots carry it
    assert not np.asarray(got["variant_keys"][0]).any()
    assert not np.asarray(got["variant_keys"][1]).any()


def test_fused_variant_dense_mode_matches_lane_mode():
    """The legacy-XLA (kernel_compact=False) dense [D,T,L,2] emission and
    the epilogue's lane payload must attach identical keys."""
    rng = np.random.default_rng(22)
    docs = _docs(rng, 8, 64, pad_frac=0.2)
    flt = _filter(rng)
    lane = E.fused_filter_compact(docs, 6, flt, E.ExtractParams(
        gamma=GAMMA, scheme="variant", max_candidates=256, use_kernel=True))
    dense = E.fused_filter_compact(docs, 6, flt, E.ExtractParams(
        gamma=GAMMA, scheme="variant", max_candidates=256, use_kernel=True,
        kernel_compact=False, kernel_sigs=True))
    for a, b in zip(lane["variant_keys"], dense["variant_keys"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(lane["sigs"]),
                                  np.asarray(dense["sigs"]))


def test_streaming_first_occurrence_matches_semantics():
    from repro.core.semantics import first_occurrence_mask
    from repro.kernels.fused_probe import streaming_first_occurrence

    rng = np.random.default_rng(23)
    toks = rng.integers(0, 5, size=(300, 7)).astype(np.int32)  # dup-heavy
    got = streaming_first_occurrence(toks, xp=np)
    want = np.asarray(first_occurrence_mask(toks, xp=np))
    np.testing.assert_array_equal(got, want)


def test_variant_end_to_end_index_uses_fused_keys(small_corpus):
    """index:variant over fused candidates (keys from the kernel) must
    equal the unfused pipeline's matches."""
    from repro.core.filter import build_ish_filter

    c = small_corpus
    d = c.dictionary
    flt = build_ish_filter(d, GAMMA)
    fltt = (jnp.asarray(flt.bits), flt.num_bits, flt.num_hashes)
    docs = jnp.asarray(c.doc_tokens)
    ddict = E.DeviceDictionary.from_host(d)
    parts = E.build_index_partitions(d, "variant", GAMMA, 1 << 30)
    outs = {}
    for use_kernel in (False, True):
        params = E.ExtractParams(
            gamma=GAMMA, scheme="variant", max_candidates=4096,
            result_capacity=8192, use_kernel=use_kernel,
        )
        if use_kernel:
            cands = E.fused_filter_compact(docs, d.max_len, fltt, params)
            assert "variant_keys" in cands
        else:
            _, cands = _unfused(docs, d.max_len, fltt, 4096)
        m = E.extract_index_part(cands, parts[0], ddict, params)
        outs[use_kernel] = m.to_set()
    assert outs[True] == outs[False] and len(outs[True]) > 0


# ---------------------------------------------------------- two-pass lanes
@pytest.mark.parametrize("D,T,L", [(3, 32, 4), (16, 128, 8), (9, 64, 5)])
@pytest.mark.parametrize("scheme", ["prefix", "variant"])
def test_two_pass_equals_one_pass(D, T, L, scheme):
    """Adaptive two-pass lane compaction must be bit-identical to the
    worst-case one-pass lanes at every geometry."""
    rng = np.random.default_rng(D + T + L)
    docs = _docs(rng, D, T, pad_frac=0.2)
    flt = _filter(rng, density=0.3)
    one = E.fused_filter_compact(docs, L, flt, E.ExtractParams(
        gamma=GAMMA, scheme=scheme, max_candidates=256, use_kernel=True))
    two = E.fused_filter_compact(docs, L, flt, E.ExtractParams(
        gamma=GAMMA, scheme=scheme, max_candidates=256, use_kernel=True,
        adaptive_lanes=True))
    _assert_cands_equal(two, one)
    if scheme == "variant":
        for a, b in zip(two["variant_keys"], one["variant_keys"]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_two_pass_narrow_lane_is_prefix_of_wide():
    """Kernel-level: W-wide lanes == the first W slots of the NC lanes,
    and the count pass reproduces the emit pass's per-tile counts."""
    from repro.kernels.fused_probe import round_lane_width

    rng = np.random.default_rng(24)
    docs = _docs(rng, 16, 64, pad_frac=0.1)
    flt = _filter(rng)  # sparse: per-tile maxima well below NC
    NC = 512
    counts = kops.fused_probe_count(docs, flt, 6, NC)
    w = round_lane_width(int(np.asarray(counts).max()), NC)
    assert w < NC, "geometry should exercise an actually-narrow lane"
    _, _, c1, wide, _ = kops.fused_probe_compact(docs, flt, 6, NC)
    _, _, c2, narrow, _ = kops.fused_probe_compact(docs, flt, 6, NC,
                                                   lane_width=w)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(counts))
    np.testing.assert_array_equal(np.asarray(c2), np.asarray(counts))
    np.testing.assert_array_equal(np.asarray(narrow),
                                  np.asarray(wide)[:, :w])


def test_adaptive_lanes_rejected_under_jit():
    import jax

    rng = np.random.default_rng(25)
    docs = _docs(rng, 4, 32)
    flt = _filter(rng)
    params = E.ExtractParams(gamma=GAMMA, scheme="prefix", max_candidates=64,
                             use_kernel=True, adaptive_lanes=True)
    with pytest.raises(ValueError, match="host"):
        jax.jit(lambda d: E.fused_filter_compact(d, 4, flt, params))(docs)


# ---------------------------------------------------------- knob validation
def test_lane_and_sig_knob_validation_messages():
    base = dict(gamma=GAMMA, scheme="variant", max_candidates=64)
    with pytest.raises(ValueError, match="kernel_compact=True"):
        E.ExtractParams(**base, adaptive_lanes=True)
    with pytest.raises(ValueError, match="adaptive_lanes=True"):
        E.ExtractParams(**base, use_kernel=True, lane_width=8)
    with pytest.raises(ValueError, match="max_candidates"):
        E.ExtractParams(**base, use_kernel=True, adaptive_lanes=True,
                        lane_width=65)
    with pytest.raises(ValueError, match="use_kernel=True"):
        E.ExtractParams(**base, kernel_sigs=True)
    with pytest.raises(ValueError, match="no in-kernel signature"):
        E.ExtractParams(gamma=GAMMA, scheme="word", max_candidates=64,
                        use_kernel=True, kernel_sigs=True)
    with pytest.raises(ValueError, match="lane_width"):
        kops.fused_probe_compact(jnp.ones((2, 8), jnp.int32), None, 4, 16,
                                 lane_width=32)
    with pytest.raises(ValueError, match="positive"):
        kops.fused_probe_count(jnp.ones((2, 8), jnp.int32), None, 4, 0)


def test_resolve_sig_mode_variant_rules():
    mk = lambda **kw: E.ExtractParams(gamma=GAMMA, scheme="variant",
                                      max_candidates=64, **kw)
    # epilogue on -> lane-resident keys at any density
    assert E.resolve_sig_mode(mk(use_kernel=True), 64, 512, 8) == "variant"
    # epilogue off -> dense tensor only in the high-density regime
    off = mk(use_kernel=True, kernel_compact=False)
    assert E.resolve_sig_mode(off, 64, 512, 8) == "none"
    assert E.resolve_sig_mode(off, 2, 4, 4) == "variant"
    # explicit force / suppress
    forced = mk(use_kernel=True, kernel_compact=False, kernel_sigs=True)
    assert E.resolve_sig_mode(forced, 64, 512, 8) == "variant"
    off2 = mk(use_kernel=True, kernel_sigs=False)
    assert E.resolve_sig_mode(off2, 2, 4, 4) == "none"


# ---------------------------------------------------------- end-to-end
@pytest.mark.parametrize("scheme", ["word", "prefix", "lsh", "variant"])
def test_fused_extraction_equals_unfused(small_corpus, scheme):
    from repro.core.filter import build_ish_filter
    from repro.core.signatures import entity_signatures

    c = small_corpus
    d = c.dictionary
    flt = build_ish_filter(d, GAMMA)
    fltt = (jnp.asarray(flt.bits), flt.num_bits, flt.num_hashes)
    docs = jnp.asarray(c.doc_tokens)
    ddict = E.DeviceDictionary.from_host(d)
    table = E.build_sig_table(entity_signatures(scheme, d, GAMMA))
    outs = {}
    for use_kernel in (False, True):
        params = E.ExtractParams(
            gamma=GAMMA, scheme=scheme, max_candidates=4096,
            result_capacity=8192, use_kernel=use_kernel,
        )
        if use_kernel:
            cands = E.fused_filter_compact(docs, d.max_len, fltt, params)
        else:
            _, cands = _unfused(docs, d.max_len, fltt, 4096)
        outs[use_kernel] = E.extract_ssjoin_local(cands, table, ddict, params).to_set()
    assert outs[True] == outs[False]


# ---------------------------------------------------------- selection
@pytest.mark.parametrize("n,density", [(100, 0.0), (1000, 0.01), (5000, 0.5), (333, 1.0)])
@pytest.mark.parametrize("capacity", [1, 64, 4096])
def test_select_nonzero_matches_jnp_nonzero(n, density, capacity):
    rng = np.random.default_rng(n + capacity)
    mask = jnp.asarray(rng.random(n) < density)
    got, ok = select_nonzero(mask, capacity)
    (want,) = jnp.nonzero(mask, size=capacity, fill_value=-1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(want) >= 0)


def test_build_sig_table_vectorised_fill_matches_loop():
    """The argsort-over-buckets scatter must place rows exactly where the
    original insertion-order Python loop did."""
    from repro.core import hashing
    from repro.core.signatures import EntitySignatures

    rng = np.random.default_rng(4)
    n = 500
    esigs = EntitySignatures(
        sig=rng.integers(0, 2**32, size=n, dtype=np.uint32),
        entity_id=rng.integers(0, 100, size=n).astype(np.int32),
    )
    t = E.build_sig_table(esigs)
    # reference loop fill over the same geometry
    sig = esigs.sig.astype(np.uint32)
    k2 = hashing.hash_u32(sig, seed=E._SIGKEY_SEED, xp=np)
    bucket = np.asarray(E._bucket_of(sig, t.n_buckets, xp=np)).astype(np.int64)
    keys1 = np.zeros((t.n_buckets, t.bucket_cap), dtype=np.uint32)
    keys2 = np.zeros((t.n_buckets, t.bucket_cap), dtype=np.uint32)
    ents = np.full((t.n_buckets, t.bucket_cap), -1, dtype=np.int32)
    fill = np.zeros((t.n_buckets,), dtype=np.int64)
    for i in range(n):
        b = bucket[i]
        keys1[b, fill[b]] = sig[i]
        keys2[b, fill[b]] = k2[i]
        ents[b, fill[b]] = esigs.entity_id[i]
        fill[b] += 1
    np.testing.assert_array_equal(np.asarray(t.keys1), keys1)
    np.testing.assert_array_equal(np.asarray(t.keys2), keys2)
    np.testing.assert_array_equal(np.asarray(t.ents), ents)
